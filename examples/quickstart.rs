//! Quickstart: load the AOT artifacts, classify one test image the RACA
//! way (stochastic trials + majority vote) and compare against the ideal
//! software forward.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use raca::dataset::Dataset;
use raca::engine::{TrialParams, XlaEngine};
use raca::runtime::ArtifactStore;

fn main() -> Result<()> {
    raca::util::logging::init();

    // 1. Open artifacts (HLO text compiled once via PJRT; weights uploaded
    //    as device buffers).
    let dir = ArtifactStore::default_dir();
    let engine = XlaEngine::start(dir.clone())?;
    let handle = engine.handle();
    let m = handle.manifest()?;
    println!(
        "RACA quickstart — FCNN {:?}, σ_z={:.3}, θ={:.1} (V_th0=0.05 V)",
        m.layers, m.sigma_z, m.theta_norm
    );

    // 2. One test image.
    let ds = Dataset::load(&dir.join("data").join("test"))?;
    let x = ds.image(0).to_vec();
    let label = ds.label(0);

    // 3. Ideal (software) forward — what the analog circuit emulates.
    let probs = handle.run_ideal(x.clone(), 1)?;
    let ideal_pred = probs
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    println!("label={label}  ideal prediction={ideal_pred}  probs[pred]={:.3}", probs[ideal_pred]);

    // 4. RACA inference: repeated stochastic trials, majority vote.
    let p = TrialParams::default();
    let mut counts = [0u32; 10];
    let trials = 31;
    for seed in 0..trials {
        let w = handle.run_trials(x.clone(), 1, seed, p)?[0];
        if w >= 0 {
            counts[w as usize] += 1;
        }
    }
    let vote = counts.iter().enumerate().max_by_key(|(_, &c)| c).unwrap().0;
    println!("RACA vote over {trials} trials: class {vote}  (counts {counts:?})");
    println!(
        "agreement: label={} ideal={} raca={}",
        label, ideal_pred as i32, vote as i32
    );
    Ok(())
}
