//! End-to-end driver (EXPERIMENTS.md headline run): serve the full
//! synthetic-MNIST test set through the coordinator + AOT/PJRT engine and
//! report the paper's metrics — accuracy vs trial budget, throughput,
//! latency percentiles and early-stop savings.
//!
//! ```bash
//! cargo run --release --example mnist_e2e -- [N_IMAGES] [MAX_TRIALS]
//! ```

use anyhow::Result;
use raca::coordinator::{SchedulerConfig, Server};
use raca::dataset::Dataset;
use raca::engine::{TrialParams, XlaEngine};
use raca::runtime::ArtifactStore;
use raca::util::table::Table;

fn main() -> Result<()> {
    raca::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_images: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(2000);
    let max_trials: u32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(32);

    let dir = ArtifactStore::default_dir();
    let ds = Dataset::load(&dir.join("data").join("test"))?.take(n_images);
    println!("mnist_e2e: {} images, trial cap {max_trials}", ds.len());

    let engine = XlaEngine::start(dir)?;
    let handle = engine.handle();
    let manifest = handle.manifest()?;
    handle.warmup(32)?;

    let mut results = Table::new(
        "End-to-end RACA serving (XLA engine + coordinator)",
        &["config", "accuracy %", "trials/req", "req/s", "trials/s", "p50 ms", "p99 ms"],
    );

    for (name, confidence) in [("fixed budget", 0.0f64), ("early-stop 95%", 0.95)] {
        let mut cfg = SchedulerConfig::default();
        cfg.batch_size = 32;
        cfg.params = TrialParams::default();
        let server = Server::start(handle.clone(), cfg);
        let client = server.client();

        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..ds.len())
            .map(|i| client.submit(ds.image(i).to_vec(), max_trials, confidence).unwrap())
            .collect();
        let mut hits = 0usize;
        let mut trials_used = 0u64;
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv()?;
            if r.prediction == ds.label(i) {
                hits += 1;
            }
            trials_used += r.trials_used as u64;
        }
        let dt = t0.elapsed().as_secs_f64();
        let m = server.metrics().snapshot();
        results.row(vec![
            name.into(),
            format!("{:.2}", hits as f64 / ds.len() as f64 * 100.0),
            format!("{:.1}", trials_used as f64 / ds.len() as f64),
            format!("{:.1}", ds.len() as f64 / dt),
            format!("{:.0}", m.trials_executed as f64 / dt),
            format!("{:.1}", m.latency_p50_us as f64 / 1e3),
            format!("{:.1}", m.latency_p99_us as f64 / 1e3),
        ]);
        println!(
            "[{name}] done in {dt:.1}s — fill ratio {:.0}%, trials saved {}",
            m.fill_ratio(32) * 100.0,
            m.trials_saved
        );
    }
    results.emit(&raca::figures::results_dir(), "mnist_e2e")?;
    println!(
        "ideal software accuracy (training record): {:.2}%  | paper RACA saturates at 96.7%",
        manifest.ideal_test_accuracy * 100.0
    );
    Ok(())
}
