//! Design-space exploration over the hardware cost model: tile size,
//! readout architecture and read-voltage corner (Table I sensitivity).
//!
//! ```bash
//! cargo run --release --example design_space
//! ```

use raca::hwmodel::{Architecture, SystemModel, TechParams};
use raca::nn::ModelSpec;
use raca::util::table::{fmt_g, Table};

fn main() {
    // --- tile size × architecture ------------------------------------------
    let mut t = Table::new(
        "Design space: tile size × readout architecture",
        &["tile", "arch", "tiles", "E pJ/trial", "area mm²", "TOPS/W", "lat ns"],
    );
    for tile in [64usize, 128, 256] {
        for (name, arch) in [("1b-ADC", Architecture::OneBitAdc), ("RACA", Architecture::Raca)] {
            let mut tech = TechParams::default();
            tech.tile = tile;
            let m = SystemModel::new(ModelSpec::paper(), tech);
            t.row(vec![
                tile.to_string(),
                name.into(),
                m.num_tiles().to_string(),
                fmt_g(m.energy(arch).total()),
                fmt_g(m.area(arch).total()),
                fmt_g(m.tops_per_watt(arch)),
                fmt_g(m.latency_ns(arch)),
            ]);
        }
    }
    println!("{}", t.render());

    // --- read-voltage corner (the paper's low-SNR-read motivation) ----------
    let mut t2 = Table::new(
        "RACA read-voltage corner",
        &["corner", "Vr (V)", "array pJ", "total pJ", "TOPS/W"],
    );
    for (name, tech) in [
        ("conventional swing", TechParams::default()),
        ("noise-calibrated Vr", TechParams::default().with_calibrated_vr()),
    ] {
        let m = SystemModel::new(ModelSpec::paper(), tech);
        let e = m.energy(Architecture::Raca);
        t2.row(vec![
            name.into(),
            format!("{:.3}", m.tech.v_read_raca),
            fmt_g(e.array),
            fmt_g(e.total()),
            fmt_g(m.tops_per_watt(Architecture::Raca)),
        ]);
    }
    println!("{}", t2.render());

    // --- network scaling ------------------------------------------------------
    let mut t3 = Table::new(
        "Network scaling (RACA)",
        &["network", "params", "E pJ/trial", "area mm²", "TOPS/W"],
    );
    for (name, widths) in [
        ("paper [784,500,300,10]", vec![784usize, 500, 300, 10]),
        ("small [784,128,10]", vec![784, 128, 10]),
        ("wide  [784,1024,512,10]", vec![784, 1024, 512, 10]),
    ] {
        let m = SystemModel::new(ModelSpec::new(widths), TechParams::default());
        t3.row(vec![
            name.into(),
            m.spec.num_params().to_string(),
            fmt_g(m.energy(Architecture::Raca).total()),
            fmt_g(m.area(Architecture::Raca).total()),
            fmt_g(m.tops_per_watt(Architecture::Raca)),
        ]);
    }
    println!("{}", t3.render());
}
