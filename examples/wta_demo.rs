//! WTA SoftMax-neuron demo: watch ten neurons race the adaptive threshold
//! (paper Fig. 3/5) and the win histogram converge to softmax.
//!
//! ```bash
//! cargo run --release --example wta_demo
//! ```

use raca::circuit::{WtaCircuit, WtaParams};
use raca::neuron::softmax_wta::{softmax64, WtaLayer};
use raca::stats::GaussianSource;

fn main() {
    let sigma_v = 0.02;
    let z = vec![-1.2, -0.4, 0.3, -0.8, 2.1, 0.9, -1.6, 0.1, -0.3, 0.9];
    let v: Vec<f64> = z.iter().map(|&zi| zi * sigma_v / 1.702).collect();
    // Softmax-matching rest offset (DESIGN.md §6): θ_z − z̄ = 1.702².
    let v_mean = v.iter().sum::<f64>() / v.len() as f64;
    let vth0 = 1.702 * sigma_v - v_mean;
    let params = WtaParams { sigma_v, vth0, ..Default::default() };

    // --- one transient decision, step by step --------------------------
    let circuit = WtaCircuit::new(params.clone());
    let mut g = GaussianSource::new(3);
    let trace = circuit.run_trace(&v, 1, &mut g);
    println!("transient decision (σ_v = {sigma_v} V, rest θ = mean + {:.1} mV):", vth0 * 1e3);
    for (i, step) in trace.steps.iter().enumerate().take(12) {
        let vmax = step.v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let bar = "#".repeat(((vmax - step.vth + 0.06) * 400.0).max(0.0) as usize);
        match step.winner {
            Some(w) => println!("  t={i:2} ns  max(V)-Vth={:+.1} mV  → neuron {w} FIRES", (vmax - step.vth) * 1e3),
            None => println!("  t={i:2} ns  max(V)-Vth={:+.1} mV  {bar}", (vmax - step.vth) * 1e3),
        }
    }
    println!("  winner: {:?}\n", trace.winners);

    // --- many decisions → softmax ---------------------------------------
    let layer = WtaLayer::new(params);
    let mut g = GaussianSource::new(11);
    for trials in [100usize, 1000, 10_000] {
        let o = layer.run(&v, trials, &mut g);
        let f = o.frequencies();
        let s = softmax64(&z);
        let max_gap = f
            .iter()
            .zip(&s)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        println!(
            "{trials:>6} trials: prediction={} max|freq−softmax|={max_gap:.4} abstain={}",
            o.prediction(),
            o.abstentions
        );
    }
    println!("\nsoftmax   : {:?}", softmax64(&z).iter().map(|p| (p * 1000.0).round() / 1000.0).collect::<Vec<_>>());
    let o = layer.run(&v, 10_000, &mut g);
    println!("winner freq: {:?}", o.frequencies().iter().map(|p| (p * 1000.0).round() / 1000.0).collect::<Vec<_>>());
}
