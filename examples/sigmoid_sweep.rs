//! Single-neuron playground: sweep Z and the SNR knobs and watch the
//! activation probability trace the logistic function (paper Fig. 2/4).
//!
//! ```bash
//! cargo run --release --example sigmoid_sweep
//! ```

use raca::crossbar::{CrossbarArray, ReadMode, WeightMapping};
use raca::device::noise::NoiseParams;
use raca::device::variation::VariationModel;
use raca::device::DELTA_F;
use raca::stats::erf::{logistic, norm_cdf};
use raca::stats::GaussianSource;

fn main() {
    let mapping = WeightMapping::default();
    let n_col = 785; // layer-1 column height (784 + bias)
    let vr = mapping.calibrate_vr(n_col, DELTA_F, 1.0);
    let kappa = mapping.kappa(vr, n_col, DELTA_F);
    println!("calibrated: Vr = {:.2} mV, κ = {:.4} (target 1/1.702 = {:.4})", vr * 1e3, kappa, 1.0 / 1.702);
    println!("\n Z     P_measured  Φ(κZ)    logistic  |Δ|");

    let mut gauss = GaussianSource::new(7);
    for zi in -8..=8 {
        let z = zi as f64;
        // Program one column whose weights sum to Z.
        let w_each = (z / n_col as f64) as f32;
        let mut arr = CrossbarArray::program(
            n_col,
            1,
            &vec![w_each; n_col],
            mapping.clone(),
            &VariationModel::default(),
            NoiseParams::thermal_only(DELTA_F),
            &mut gauss,
        );
        let v = vec![vr; n_col];
        let mut out = [0.0f64];
        let n = 20_000;
        let mut fired = 0usize;
        for _ in 0..n {
            arr.read_differential(&v, ReadMode::ColumnAggregate, &mut out, &mut gauss);
            if out[0] > 0.0 {
                fired += 1;
            }
        }
        let p = fired as f64 / n as f64;
        let analytic = norm_cdf(kappa * z);
        let log = logistic(z);
        println!(
            "{z:+5.1}  {p:.4}      {analytic:.4}   {log:.4}    {:.4}",
            (p - log).abs()
        );
    }
    println!("\nThe comparator IS the sigmoid: max probit-vs-logit gap ≈ 0.0095 (Eq. 13).");
}
