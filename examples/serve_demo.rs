//! Serving demo: multiple client threads push freshly-generated digits at
//! the coordinator; reports throughput, latency percentiles, batch fill
//! and early-stop savings — the L3 contribution under load.
//!
//! ```bash
//! cargo run --release --example serve_demo -- [CLIENTS] [REQS_PER_CLIENT]
//! ```

use anyhow::Result;
use raca::coordinator::{SchedulerConfig, Server};
use raca::dataset::synth;
use raca::engine::{TrialParams, XlaEngine};
use raca::runtime::ArtifactStore;

fn main() -> Result<()> {
    raca::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let clients: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let per_client: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(50);

    let engine = XlaEngine::start(ArtifactStore::default_dir())?;
    let handle = engine.handle();
    handle.warmup(32)?;

    let mut cfg = SchedulerConfig::default();
    cfg.batch_size = 32;
    cfg.params = TrialParams::default();
    let server = Server::start(handle, cfg);

    println!("serve_demo: {clients} clients × {per_client} requests (max 32 trials, 95% early stop)");
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let client = server.client();
        joins.push(std::thread::spawn(move || -> (usize, usize) {
            let mut rng = raca::stats::Rng::new(c as u64 + 1);
            let mut correct = 0;
            for i in 0..per_client {
                let digit = (c * per_client + i) % 10;
                let img = synth::render_digit(digit, &mut rng);
                let r = client.classify(img, 32, 0.95).expect("classify");
                if r.prediction == digit as i32 {
                    correct += 1;
                }
            }
            (correct, per_client)
        }));
    }
    let mut correct = 0;
    let mut total = 0;
    for j in joins {
        let (c, t) = j.join().unwrap();
        correct += c;
        total += t;
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = server.metrics().snapshot();
    println!(
        "served {total} requests in {dt:.2}s — {:.1} req/s, accuracy {:.1}%",
        total as f64 / dt,
        correct as f64 / total as f64 * 100.0
    );
    println!(
        "coordinator: {m}\n  fill ratio {:.0}%  trials/request {:.1}  (cap 32 → early stop saved {:.0}%)",
        m.fill_ratio(32) * 100.0,
        m.trials_per_request(),
        m.trials_saved as f64 / (m.trials_saved + m.trials_executed).max(1) as f64 * 100.0
    );
    Ok(())
}
