//! Offline vendored mini-`anyhow`.
//!
//! The build environment has no crates.io access, so this path dependency
//! re-implements exactly the subset of the `anyhow` 1.x API the workspace
//! uses: [`Error`], [`Result`], the [`Context`] extension trait on
//! `Result`/`Option`, and the `anyhow!`/`bail!`/`ensure!` macros.
//!
//! Semantics intentionally mirror upstream where it matters here:
//! * `Error` does **not** implement `std::error::Error`, so the blanket
//!   `From<E: std::error::Error>` conversion (what makes `?` work) cannot
//!   conflict with `From<Error>`;
//! * `Display` prints the outermost message; the alternate form (`{:#}`)
//!   prints the whole context chain separated by `": "`;
//! * `.context(..)` wraps both plain `std::error::Error` values and
//!   already-wrapped `anyhow::Error`s, and turns `Option::None` into an
//!   error.

use std::fmt::{self, Debug, Display};

/// Context-chain error type (outermost message first).
pub struct Error {
    chain: Vec<String>,
}

/// `std::result::Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message or value.
    pub fn msg<M>(message: M) -> Self
    where
        M: Display + Send + Sync + 'static,
    {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C>(mut self, context: C) -> Self
    where
        C: Display + Send + Sync + 'static,
    {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost message first.
    pub fn chain_messages(&self) -> &[String] {
        &self.chain
    }

    fn from_std<E>(err: E) -> Self
    where
        E: std::error::Error,
    {
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Mirrors anyhow's unwrap-friendly debug output: message, then the
        // cause chain.
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        Error::from_std(err)
    }
}

/// Conversion into [`Error`] — implemented both for `std` errors and for
/// [`Error`] itself, so [`Context`] works on any `Result` in the workspace.
/// (Mirrors anyhow's private `ext::StdError` trick.)
#[doc(hidden)]
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

impl<E> IntoError for E
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn into_error(self) -> Error {
        Error::from_std(self)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T>: Sized {
    /// Wrap the error value with additional context.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: IntoError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a message, format string, or error value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(::std::concat!("condition failed: ", ::std::stringify!($cond)));
        }
    };
    ($cond:expr, $($tt:tt)*) => {
        if !($cond) {
            $crate::bail!($($tt)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing file");
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading weights").unwrap_err();
        assert_eq!(format!("{e}"), "reading weights");
        assert_eq!(format!("{e:#}"), "reading weights: missing file");
        let e2 = e.context("outer");
        assert_eq!(format!("{e2:#}"), "outer: reading weights: missing file");
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 7");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn macros_formats() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is right out");
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        let e = anyhow!(String::from("from a String"));
        assert_eq!(format!("{e}"), "from a String");
    }
}
