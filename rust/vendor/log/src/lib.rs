//! Offline vendored mini-`log` facade.
//!
//! API-compatible (for this workspace's usage) subset of the `log` crate:
//! [`Level`], [`LevelFilter`], [`Metadata`], [`Record`], the [`Log`] trait,
//! [`set_logger`]/[`set_max_level`], and the `error!`..`trace!` macros.
//! `raca::util::logging` installs the backend exactly as it would against
//! the real crate.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Log severity, most severe first (matches the `log` crate ordering:
/// `Error < Warn < Info < Debug < Trace`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.as_str())
    }
}

/// Maximum-level filter installed via [`set_max_level`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Metadata about a log request (level + target module).
#[derive(Debug, Clone)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the preformatted message arguments.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// Backend trait — implement and install with [`set_logger`].
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("attempted to set a logger after one was already set")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first call wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum log level.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global maximum log level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__log($lvl, ::std::module_path!(), ::std::format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    struct Counter {
        hits: AtomicUsize,
    }

    impl Log for Counter {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= Level::Info
        }

        fn log(&self, record: &Record) {
            if self.enabled(record.metadata()) {
                let _ = format!("{:5} {}: {}", record.level(), record.target(), record.args());
                self.hits.fetch_add(1, Ordering::Relaxed);
            }
        }

        fn flush(&self) {}
    }

    #[test]
    fn levels_order_like_upstream() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info <= Level::Info);
    }

    #[test]
    fn install_and_filter() {
        let logger: &'static Counter =
            Box::leak(Box::new(Counter { hits: AtomicUsize::new(0) }));
        let _ = set_logger(logger);
        set_max_level(LevelFilter::Trace);
        info!("hello {}", 42);
        debug!("filtered out by the backend");
        warn!("also counted: {:?}", (1, 2));
        assert_eq!(logger.hits.load(Ordering::Relaxed), 2);
        // Second install attempt fails but does not panic.
        assert!(set_logger(logger).is_err());
    }
}
