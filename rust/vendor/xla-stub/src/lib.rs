//! API-shaped stub of the `xla` crate (PJRT C API bindings).
//!
//! The real PJRT CPU plugin is not part of the offline vendor set, so the
//! `pjrt` cargo feature of the `raca` crate links against this stub by
//! default.  Every entry point type-checks identically to the subset of
//! the real crate the repo uses, and the *first* runtime call —
//! [`PjRtClient::cpu`] — fails with a clear error, so `raca` code paths
//! degrade gracefully (they already handle engine-start failure).
//!
//! Deploying against real PJRT: point the `xla` path dependency in
//! `rust/Cargo.toml` at the real bindings (or add a `[patch]` entry); no
//! `raca` source changes are required.

use std::fmt;
use std::path::Path;

/// Error type standing in for `xla::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT runtime unavailable: raca was built against the bundled xla stub \
         (see rust/vendor/xla-stub). Install the real xla crate + PJRT CPU \
         plugin and patch the `xla` dependency to enable this path."
            .to_string(),
    ))
}

/// Element types accepted by [`PjRtClient::buffer_from_host_buffer`].
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u8 {}

/// Stub of the PJRT client. Cannot be constructed; [`PjRtClient::cpu`]
/// always returns an error in stub builds.
#[derive(Clone)]
pub struct PjRtClient(Never);

#[derive(Clone)]
enum Never {}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        match self.0 {}
    }

    pub fn device_count(&self) -> usize {
        match self.0 {}
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self.0 {}
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        match self.0 {}
    }
}

/// Stub of a parsed HLO module proto.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        unavailable()
    }
}

/// Stub of an XLA computation.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Stub of a compiled + loaded PJRT executable.
pub struct PjRtLoadedExecutable(Never);

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.0 {}
    }

    pub fn execute_b<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.0 {}
    }
}

/// Stub of a device-resident buffer.
pub struct PjRtBuffer(Never);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self.0 {}
    }
}

/// Stub of a host literal.
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f32]) -> Self {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("PJRT runtime unavailable"));
    }

    #[test]
    fn literal_surface_is_callable() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
    }
}
