//! Bench: Fig. 4 workload — sigmoid-neuron sampling throughput.
//!
//! Measures the crossbar read+compare inner loop (the native engine's
//! hot-spot) in both noise modes, and regenerates the panel (c) sweep at
//! bench scale.

use raca::crossbar::{CrossbarArray, ReadMode, WeightMapping};
use raca::device::noise::NoiseParams;
use raca::device::variation::VariationModel;
use raca::device::DELTA_F;
use raca::stats::GaussianSource;
use raca::util::bench::bench_units;

fn main() {
    println!("== bench_fig4: sigmoid neuron sampling ==");
    let mapping = WeightMapping::default();
    let n_col = 785;
    let vr = mapping.calibrate_vr(n_col, DELTA_F, 1.0);
    let mut gauss = GaussianSource::new(1);
    let mut arr = CrossbarArray::program(
        n_col,
        128,
        &vec![0.3f32; n_col * 128],
        mapping,
        &VariationModel::default(),
        NoiseParams::thermal_only(DELTA_F),
        &mut gauss,
    );
    let v = vec![vr; n_col];
    let mut out = vec![0.0f64; 128];

    let reads = 200usize;
    bench_units(
        "column-aggregate read (785x128, per full-array read)",
        3,
        20,
        (reads * 128) as f64,
        || {
            for _ in 0..reads {
                arr.read_differential(&v, ReadMode::ColumnAggregate, &mut out, &mut gauss);
            }
        },
    );
    bench_units("per-device read (785x128, exact Eq.9/10)", 1, 5, 128.0, || {
        arr.read_differential(&v, ReadMode::PerDevice, &mut out, &mut gauss);
    });

    println!("\nregenerating Fig 4(c) at bench scale (800 samples/point)…");
    let t0 = std::time::Instant::now();
    raca::figures::fig4::panel_c(800).expect("fig4c");
    println!("fig4(c) wall time: {:?}", t0.elapsed());
}
