//! Bench: serving throughput across deployment topologies.
//!
//! One 4-layer model, every shape built from the same `serve::plan`
//! compiler:
//!
//! * `die` — the coordinator's batched scheduler on one engine (baseline);
//! * `Nx(die)` × {2,4,8} — fused per-chip worker threads + router dispatch
//!   (whole requests per die, σ=5% variation draws);
//! * `pipeline:N` × {2,4} — the model's layers sharded across dies,
//!   activation blocks streaming die-to-die (`:b8` message batching).
//!   The input die caches the per-request layer-0 pre-activation, so the
//!   deepest matmul leaves the per-trial path entirely — which is why the
//!   pipeline beats a single chip even before thread-level parallelism
//!   kicks in;
//! * `2x(pipeline:2)` — replicas of pipelines: the tree the flat backend
//!   switch could not express.  At equal die count it beats the deep
//!   pipeline because replication halves the bottleneck stage's load
//!   instead of adding more underutilized stages.
//!
//! `--smoke` runs a CI-sized workload and *asserts* the acceptance bars:
//! `pipeline:4` ≥ 2× the single-die trial throughput, and
//! `2x(pipeline:2)` ≥ `pipeline:4` at the same 4 dies.

use std::time::Instant;

use raca::device::VariationModel;
use raca::nn::{ModelSpec, Weights};
use raca::serve::{build, Backend, BuildOptions, InferRequest, Topology};

/// Push `reqs` fixed-budget requests through `backend`; trials/second.
fn throughput(backend: &dyn Backend, images: &[Vec<f32>], trials: u32, reqs: usize) -> f64 {
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..reqs)
        .map(|i| {
            backend
                .submit(
                    InferRequest::new(i as u64, images[i % images.len()].clone())
                        .with_budget(trials, 0.0),
                )
                .expect("submit")
        })
        .collect();
    let mut total = 0u64;
    for t in tickets {
        total += backend.wait(t).expect("wait").trials_used as u64;
    }
    total as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (warmup, reqs, trials) = if smoke { (12, 48, 8u32) } else { (24, 192, 12u32) };
    let spec = ModelSpec::new(vec![784, 256, 192, 128, 10]);
    let w = Weights::random(spec, 7);
    let seed = 0xBE7C;
    // Dense pseudo-images (~4% zeros): keeps the single-chip baseline
    // honest — sparse inputs would hand it an affine_aug shortcut.
    let images: Vec<Vec<f32>> = (0..32)
        .map(|i| (0..784).map(|j| ((i * 31 + j) % 23) as f32 / 23.0).collect())
        .collect();

    println!(
        "== bench_fleet: serving throughput by topology ({reqs} reqs × {trials} trials, 4-layer model) =="
    );

    let measure = |topo_spec: &str, variation: Option<VariationModel>| -> f64 {
        let topo = Topology::parse(topo_spec).expect("topology spec");
        let opts = BuildOptions { seed, variation, ..Default::default() };
        let b = build(&topo, &w, &opts).expect("building deployment");
        let _ = throughput(b.as_ref(), &images, trials, warmup);
        let tps = throughput(b.as_ref(), &images, trials, reqs);
        b.shutdown();
        tps
    };

    let single_tps = measure("die", None);
    println!("  die (batched scheduler)        : {single_tps:>9.0} trials/s  (baseline)");

    for chips in [2usize, 4, 8] {
        let tps = measure(&format!("{chips}x(die)"), Some(VariationModel::lognormal(0.05)));
        println!(
            "  {chips}x(die) worker fleet          : {tps:>9.0} trials/s  ({:.2}x)",
            tps / single_tps.max(1e-9)
        );
    }

    let mut pipelined_at_4 = 0.0f64;
    for dies in [2usize, 4] {
        let tps = measure(&format!("pipeline:{dies}"), None);
        if dies == 4 {
            pipelined_at_4 = tps;
        }
        println!(
            "  pipeline:{dies} die-sharded         : {tps:>9.0} trials/s  ({:.2}x)",
            tps / single_tps.max(1e-9)
        );
    }

    // Replicas of pipelines: the topology the flat BackendKind switch
    // could not express — throughput × capacity scaling in one tree.
    let replicated_pipes = measure("2x(pipeline:2)", None);
    println!(
        "  2x(pipeline:2) replicated pipes: {replicated_pipes:>9.0} trials/s  ({:.2}x)",
        replicated_pipes / single_tps.max(1e-9)
    );

    if smoke {
        let ratio = pipelined_at_4 / single_tps.max(1e-9);
        assert!(
            ratio >= 2.0,
            "--smoke: pipeline:4 must be ≥2x single-die throughput, got {ratio:.2}x"
        );
        println!("smoke OK: pipeline:4 = {ratio:.2}x single-die (≥ 2x required)");
        let rp = replicated_pipes / pipelined_at_4.max(1e-9);
        assert!(
            rp >= 1.0,
            "--smoke: 2x(pipeline:2) must be ≥ pipeline:4 at equal dies, got {rp:.2}x"
        );
        println!("smoke OK: 2x(pipeline:2) = {rp:.2}x pipeline:4 at 4 dies (≥ 1x required)");
    }
}
