//! Bench: serving throughput across deployment topologies.
//!
//! One 4-layer model, every shape built from the same `serve::plan`
//! compiler:
//!
//! * `die` — the coordinator's batched scheduler on one engine (baseline);
//! * `Nx(die)` × {2,4,8} — fused per-chip worker threads + router dispatch
//!   (whole requests per die, σ=5% variation draws);
//! * `pipeline:N` × {2,4} — the model's layers sharded across dies,
//!   activation blocks streaming die-to-die (`:b8` message batching).
//!   The input die caches the per-request layer-0 pre-activation, so the
//!   deepest matmul leaves the per-trial path entirely — which is why the
//!   pipeline beats a single chip even before thread-level parallelism
//!   kicks in;
//! * `2x(pipeline:2)` — replicas of pipelines: the tree the flat backend
//!   switch could not express.  At equal die count it beats the deep
//!   pipeline because replication halves the bottleneck stage's load
//!   instead of adding more underutilized stages.
//!
//! * `remote:die` — the same single die served over a loopback listener
//!   (the `serve::net` wire layer): a latency lane, catching socket-path
//!   regressions (frame codec bloat, missing TCP_NODELAY, relay stalls).
//!
//! * `http ingress` — the same die behind `serve::http`: keep-alive
//!   `POST /v1/infer` round trips, so the JSON parse, admission gates
//!   and batcher hop are the measured delta vs the framed socket lane.
//!
//! Before the topology lanes, a **native-kernel comparison** times the
//! raw engine on one image: the scalar one-trial-at-a-time loop vs the
//! §Perf iteration-5 trial-blocked bit-packed kernel at B ∈ {1, 8, 64}
//! (single-threaded `trials_cached`, so the B lanes isolate the kernel
//! itself), plus the full `infer` path (B=64 + block-level thread
//! sharding) — the production lane the smoke gate asserts on.
//!
//! Since §Perf iteration 6 the kernel lanes run on the runtime-dispatched
//! SIMD kernels (`util::simd`); the selected ISA is printed and embedded
//! in the report (`native_kernel.simd_isa`), so a report also records
//! *which* datapath produced its numbers (`RACA_NO_SIMD=1` runs show
//! `"scalar"`).
//!
//! `--json <path>` additionally writes every lane to a machine-readable
//! report (`BENCH_fleet.json` at the repo root is the checked-in
//! full-run baseline — see README §Performance).
//!
//! `--check <baseline.json>` turns the bench into a **trajectory gate**:
//! the fresh run is compared lane-by-lane against a previous `--json`
//! report and the process exits non-zero on any lane regressing beyond
//! `--tolerance` (default 0.5, i.e. a lane may lose up to 50% of its
//! baseline ratio before failing; improvements always pass).  Lanes are
//! compared as *dimensionless ratios* (blocked kernels ÷ scalar, backends
//! ÷ die, remote ÷ local latency), never absolute trials/s, so a baseline
//! recorded on one machine remains meaningful on another.  Thread-scaled
//! lanes (`blocked_infer`, `backend/*`) additionally clamp their pass bar
//! to the 2.0× acceptance bar: a many-core baseline must not demand more
//! parallel speedup than the checking machine's cores can offer — the
//! single-thread kernel lanes carry the full-tolerance regression signal.
//!
//! `--chaos` runs the PR-10 fault-tolerance lane *instead of* the perf
//! lanes: two loopback listeners under `(remote:a, remote:b)@weighted`,
//! every request carrying a deadline, one listener hard-killed with the
//! whole load in flight and rebound on the same port.  The lane asserts
//! availability ≥ 99% (completed answers, bit-identical to the unsharded
//! reference), zero hung requests (per-response receive timeouts are the
//! hang detector), and at least one journaled `session_reconnect`.
//! `--json` writes the chaos report instead of the perf report.
//!
//! `--smoke` runs a CI-sized workload and *asserts* the acceptance bars:
//! blocked native infer (B=64) ≥ 2.0× the scalar kernel on x86_64 with a
//! dispatched SIMD ISA (1.5× under `RACA_NO_SIMD=1` or on other arches),
//! `pipeline:4` ≥ 2× the single-die trial throughput,
//! `2x(pipeline:2)` ≥ `pipeline:4` at the same 4 dies, loopback
//! `remote:die` within 2× the local single-die request latency, and an
//! 8-way burst at a 1-deep HTTP ingress sheds with `429`s instead of
//! hanging or dropping connections.

use std::sync::Arc;
use std::time::Instant;

use raca::device::VariationModel;
use raca::engine::{NativeEngine, TrialParams};
use raca::nn::{ModelSpec, Weights};
use raca::serve::{build, Backend, BuildOptions, InferRequest, Topology};
use raca::util::json::{self, Json};

/// One request over an existing keep-alive HTTP connection; returns
/// `(status, body)`.  Hand-rolled like the server itself: explicit
/// `Content-Length` framing, no chunking.
fn http_roundtrip(
    r: &mut std::io::BufReader<std::net::TcpStream>,
    w: &mut std::net::TcpStream,
    path: &str,
    body: &str,
) -> (u16, String) {
    use std::io::{BufRead, Read, Write};
    write!(
        w,
        "POST {path} HTTP/1.1\r\nHost: bench\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .expect("http write");
    w.flush().expect("http flush");
    let mut line = String::new();
    r.read_line(&mut line).expect("http status line");
    let status: u16 =
        line.split_whitespace().nth(1).expect("status code").parse().expect("status code");
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        r.read_line(&mut h).expect("http header");
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().expect("content-length value");
            }
        }
    }
    let mut buf = vec![0u8; content_length];
    r.read_exact(&mut buf).expect("http body");
    (status, String::from_utf8(buf).expect("utf-8 body"))
}

/// `/v1/infer` body for request `i`: pixels formatted with `{}` (the
/// shortest round-trip repr, so the ingress recovers the exact bits).
fn infer_body(i: usize, img: &[f32], trials: u32) -> String {
    let px: Vec<String> = img.iter().map(|p| format!("{p}")).collect();
    format!(r#"{{"id": {i}, "pixels": [{}], "trials": {trials}}}"#, px.join(","))
}

/// Push `reqs` fixed-budget requests through `backend`; trials/second.
fn throughput(backend: &dyn Backend, images: &[Vec<f32>], trials: u32, reqs: usize) -> f64 {
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..reqs)
        .map(|i| {
            backend
                .submit(
                    InferRequest::new(i as u64, images[i % images.len()].clone())
                        .with_budget(trials, 0.0),
                )
                .expect("submit")
        })
        .collect();
    let mut total = 0u64;
    for t in tickets {
        total += backend.wait(t).expect("wait").trials_used as u64;
    }
    total as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

/// The `--chaos` lane: kill one of two listeners with the full load in
/// flight, rebind it, and hold the fabric to the availability contract.
fn chaos_lane(json_path: Option<&str>) {
    use raca::telemetry::EventKind;
    use std::sync::mpsc;
    use std::time::Duration;

    const N: u64 = 200;
    const TRIALS: u32 = 400;
    const DEADLINE_MS: u64 = 10_000;

    let spec = ModelSpec::new(vec![784, 64, 32, 10]);
    let w = Weights::random(spec, 7);
    let seed = 0xC4A05;
    let images: Vec<Vec<f32>> = (0..32)
        .map(|i| (0..784).map(|j| ((i * 31 + j) % 23) as f32 / 23.0).collect())
        .collect();

    println!(
        "== bench_fleet --chaos: kill 1 of 2 listeners under {N} reqs × {TRIALS} trials ==",
    );
    let serve_die = |addr: &str| {
        raca::serve::net::serve(
            build(
                &Topology::parse("die").unwrap(),
                &w,
                &BuildOptions { seed, ..Default::default() },
            )
            .expect("building hosted die"),
            addr,
        )
        .expect("loopback listener")
    };
    let a = serve_die("127.0.0.1:0");
    let addr_a = a.addr().to_string();
    let b_srv = serve_die("127.0.0.1:0");
    let topo =
        Topology::parse(&format!("(remote:{addr_a}, remote:{})@weighted", b_srv.addr())).unwrap();
    let fabric = build(&topo, &w, &BuildOptions::default()).expect("building fabric");

    let (tx, rx) = mpsc::channel();
    let t0 = Instant::now();
    for i in 0..N {
        fabric
            .submit_to(
                InferRequest::new(i, images[i as usize % images.len()].clone())
                    .with_budget(TRIALS, 0.0)
                    .with_deadline_ms(DEADLINE_MS),
                tx.clone(),
            )
            .expect("submit");
    }
    // The kill: every request is in flight at some leaf when child A's
    // sessions are hard-closed; a same-seed replacement takes its port.
    a.kill();
    let revived = serve_die(&addr_a);

    let reference = NativeEngine::new(Arc::new(w.clone()), seed);
    let p = TrialParams::default();
    let (mut ok, mut failed, mut hung) = (0u64, 0u64, 0u64);
    let mut seen = std::collections::HashSet::new();
    for _ in 0..N {
        match rx.recv_timeout(Duration::from_secs(60)) {
            Ok(r) => {
                assert!(seen.insert(r.id), "--chaos: request {} completed twice", r.id);
                match &r.error {
                    None => {
                        let want = reference.infer(
                            &images[r.id as usize % images.len()],
                            p,
                            TRIALS as usize,
                            raca::serve::trial_stream_base(seed, r.id),
                        );
                        assert_eq!(
                            r.outcome.counts, want.counts,
                            "--chaos: request {} lost bit-parity after the kill",
                            r.id
                        );
                        ok += 1;
                    }
                    Some(_) => failed += 1,
                }
            }
            Err(_) => {
                hung = N - (ok + failed);
                break;
            }
        }
    }
    let wall = t0.elapsed();
    let availability = ok as f64 / N as f64;
    let journal = fabric.journal().expect("fabric journal");
    let evs = journal.tail(journal.capacity());
    let reconnects = evs.iter().filter(|e| e.kind == EventKind::SessionReconnect).count();
    let resubmits = evs.iter().filter(|e| e.kind == EventKind::Resubmit).count();
    println!("  answered ok                    : {ok} of {N}");
    println!("  failed in-band                 : {failed}");
    println!("  hung past the detector         : {hung}");
    println!("  session_reconnect / resubmit   : {reconnects} / {resubmits}");
    println!("  availability                   : {availability:.4}  (bar 0.99)");
    println!("  wall                           : {} ms", wall.as_millis());

    // Evidence first: the report lands on disk even when a gate trips.
    if let Some(path) = json_path {
        let j = json::obj(vec![
            ("bench", Json::Str("bench_fleet_chaos".into())),
            ("requests", json::num(N as f64)),
            ("trials_per_request", json::num(TRIALS as f64)),
            ("deadline_ms", json::num(DEADLINE_MS as f64)),
            ("ok", json::num(ok as f64)),
            ("failed_in_band", json::num(failed as f64)),
            ("hung", json::num(hung as f64)),
            ("availability", json::num(availability)),
            ("session_reconnects", json::num(reconnects as f64)),
            ("resubmits", json::num(resubmits as f64)),
            ("wall_ms", json::num(wall.as_millis() as f64)),
        ]);
        std::fs::write(path, format!("{j}\n")).expect("writing --json report");
        println!("wrote {path}");
    }

    assert_eq!(hung, 0, "--chaos: {hung} request(s) hung — the availability contract is broken");
    assert!(reconnects > 0, "--chaos: the killed listener never reconnected");
    assert!(
        availability >= 0.99,
        "--chaos: availability {availability:.4} < 0.99 with one of two listeners killed mid-run"
    );
    println!(
        "chaos OK: availability {availability:.4} ≥ 0.99, zero hangs, {resubmits} in-flight request(s) resubmitted"
    );

    fabric.shutdown();
    drop(revived);
    drop(b_srv);
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let json_path = argv
        .windows(2)
        .find(|w| w[0] == "--json")
        .map(|w| w[1].clone());
    if argv.iter().any(|a| a == "--chaos") {
        return chaos_lane(json_path.as_deref());
    }
    let check_path = argv
        .windows(2)
        .find(|w| w[0] == "--check")
        .map(|w| w[1].clone());
    let tolerance = argv
        .windows(2)
        .find(|w| w[0] == "--tolerance")
        .map(|w| w[1].parse::<f64>().expect("--tolerance takes a fraction, e.g. 0.5"))
        .unwrap_or(0.5);
    let (warmup, reqs, trials) = if smoke { (12, 48, 8u32) } else { (24, 192, 12u32) };
    let spec = ModelSpec::new(vec![784, 256, 192, 128, 10]);
    let model_name = "784-256-192-128-10";
    let w = Weights::random(spec, 7);
    let seed = 0xBE7C;
    // Dense pseudo-images (~4% zeros): keeps the single-chip baseline
    // honest — sparse inputs would hand it an affine_aug shortcut.
    let images: Vec<Vec<f32>> = (0..32)
        .map(|i| (0..784).map(|j| ((i * 31 + j) % 23) as f32 / 23.0).collect())
        .collect();

    // --- native-kernel lanes: scalar loop vs trial-blocked kernel ----------
    // Raw engine on one image, no serving stack: isolates the §Perf
    // iteration-5 win (weight traffic amortized across a block + blocks
    // sharded over threads) from scheduler/channel effects.
    let p = TrialParams::default();
    let kernel_trials = if smoke { 4096usize } else { 16384 };
    let engine = NativeEngine::new(Arc::new(w.clone()), seed);
    let kimg = &images[0];
    let simd_isa = raca::util::simd::active().name();
    println!("== bench_fleet: native kernel, scalar vs blocked ({kernel_trials} trials/image) ==");
    println!("  simd dispatch                  : {simd_isa}");
    let time_tps = |f: &mut dyn FnMut()| -> f64 {
        f(); // warmup (touches weights, fills scratch)
        let t0 = Instant::now();
        f();
        kernel_trials as f64 / t0.elapsed().as_secs_f64().max(1e-9)
    };
    let scalar_tps = time_tps(&mut || {
        std::hint::black_box(engine.infer_scalar(kimg, p, kernel_trials, 0));
    });
    println!("  scalar (one trial at a time)   : {scalar_tps:>9.0} trials/s  (baseline)");
    // Kernel-only lanes: `trials_cached` is the raw blocked kernel with NO
    // thread sharding, so B = 1 really isolates loop-inversion overhead
    // and B = 64 is the pure weight-traffic amortization — one thread on
    // both sides of the comparison.
    let z1 = engine.precompute(kimg);
    let kernel_indices: Vec<u64> = (0..kernel_trials as u64).collect();
    let mut blocked_lanes: Vec<(String, f64)> = Vec::new();
    for b in [1usize, 8, 64] {
        let eb = engine.clone().with_trial_block(b);
        let tps = time_tps(&mut || {
            std::hint::black_box(eb.trials_cached(&z1, p, &kernel_indices));
        });
        println!(
            "  blocked B={b:<3} (1 thread)       : {tps:>9.0} trials/s  ({:.2}x)",
            tps / scalar_tps.max(1e-9)
        );
        blocked_lanes.push((format!("b{b}"), tps));
    }
    // The full production path: blocked kernel at B=64 *plus* block-level
    // thread sharding inside `NativeEngine::infer` — this is the lane the
    // smoke gate holds to ≥ 1.5× scalar.
    let blocked_infer_tps = time_tps(&mut || {
        std::hint::black_box(engine.infer(kimg, p, kernel_trials, 0));
    });
    println!(
        "  blocked infer B=64 + threads   : {blocked_infer_tps:>9.0} trials/s  ({:.2}x)",
        blocked_infer_tps / scalar_tps.max(1e-9)
    );

    println!(
        "== bench_fleet: serving throughput by topology ({reqs} reqs × {trials} trials, 4-layer model) =="
    );

    let mut backend_lanes: Vec<(String, f64)> = Vec::new();
    // Per-node telemetry of the richest lane (replicas of pipelines),
    // snapshotted before shutdown and embedded in the --json report so
    // BENCH_*.json doubles as a per-node regression baseline.
    let mut final_tree: Option<Json> = None;
    let mut measure = |topo_spec: &str, variation: Option<VariationModel>| -> f64 {
        let topo = Topology::parse(topo_spec).expect("topology spec");
        let opts = BuildOptions { seed, variation, ..Default::default() };
        let b = build(&topo, &w, &opts).expect("building deployment");
        let _ = throughput(b.as_ref(), &images, trials, warmup);
        let tps = throughput(b.as_ref(), &images, trials, reqs);
        if topo_spec == "2x(pipeline:2)" {
            final_tree = Some(b.metrics_tree().to_json());
        }
        b.shutdown();
        backend_lanes.push((topo_spec.to_string(), tps));
        tps
    };

    let single_tps = measure("die", None);
    println!("  die (batched scheduler)        : {single_tps:>9.0} trials/s  (baseline)");

    for chips in [2usize, 4, 8] {
        let tps = measure(&format!("{chips}x(die)"), Some(VariationModel::lognormal(0.05)));
        println!(
            "  {chips}x(die) worker fleet          : {tps:>9.0} trials/s  ({:.2}x)",
            tps / single_tps.max(1e-9)
        );
    }

    let mut pipelined_at_4 = 0.0f64;
    for dies in [2usize, 4] {
        let tps = measure(&format!("pipeline:{dies}"), None);
        if dies == 4 {
            pipelined_at_4 = tps;
        }
        println!(
            "  pipeline:{dies} die-sharded         : {tps:>9.0} trials/s  ({:.2}x)",
            tps / single_tps.max(1e-9)
        );
    }

    // Replicas of pipelines: the topology the flat BackendKind switch
    // could not express — throughput × capacity scaling in one tree.
    let replicated_pipes = measure("2x(pipeline:2)", None);
    println!(
        "  2x(pipeline:2) replicated pipes: {replicated_pipes:>9.0} trials/s  ({:.2}x)",
        replicated_pipes / single_tps.max(1e-9)
    );

    // Wire lane: the same die, reached through a loopback listener via
    // the remote:<addr> topology leaf.  Latency (not throughput): mean
    // submit→wait wall time of sequential requests, remote vs local —
    // the socket, codec and relay are the only difference.
    let mean_latency = |b: &dyn Backend, reqs: usize, lat_trials: u32| -> f64 {
        let mut total = 0.0;
        for i in 0..reqs {
            let t0 = Instant::now();
            let r = b
                .classify(
                    InferRequest::new(i as u64, images[i % images.len()].clone())
                        .with_budget(lat_trials, 0.0),
                )
                .expect("classify");
            assert_eq!(r.trials_used, lat_trials);
            total += t0.elapsed().as_secs_f64();
        }
        total / reqs.max(1) as f64
    };
    let (lat_reqs, lat_trials) = if smoke { (24usize, 48u32) } else { (64, 48) };
    let die = |s: u64| {
        let opts = BuildOptions { seed: s, ..Default::default() };
        build(&Topology::parse("die").unwrap(), &w, &opts).expect("building die")
    };
    let local = die(seed);
    let _ = mean_latency(local.as_ref(), 8, lat_trials); // warmup
    let local_lat = mean_latency(local.as_ref(), lat_reqs, lat_trials);
    local.shutdown();

    let server = raca::serve::net::serve(die(seed), "127.0.0.1:0").expect("loopback listener");
    let remote_topo = Topology::parse(&format!("remote:{}", server.addr())).unwrap();
    let remote = build(&remote_topo, &w, &BuildOptions::default()).expect("remote backend");
    let _ = mean_latency(remote.as_ref(), 8, lat_trials); // warmup
    let remote_lat = mean_latency(remote.as_ref(), lat_reqs, lat_trials);
    remote.shutdown();
    let lat_ratio = remote_lat / local_lat.max(1e-12);
    println!(
        "  remote:die loopback wire       : {:>9.0} µs/req vs {:.0} µs/req local ({lat_ratio:.2}x, {lat_trials} trials/req)",
        remote_lat * 1e6,
        local_lat * 1e6,
    );

    // HTTP ingress lane: the same die behind the serve::http front door,
    // keep-alive POSTs on one connection.  The delta vs the framed
    // socket above is the text protocol: request parse, lazy JSON body
    // scan, admission gates and the batcher hop.
    let http_server = raca::serve::serve_http(
        die(seed),
        &raca::serve::HttpConfig::new("127.0.0.1:0"),
    )
    .expect("http ingress");
    let http_lat = {
        let s = std::net::TcpStream::connect(http_server.addr()).expect("dialing http ingress");
        s.set_read_timeout(Some(std::time::Duration::from_secs(60))).unwrap();
        let mut hw = s.try_clone().unwrap();
        let mut hr = std::io::BufReader::new(s);
        for i in 0..8 {
            // warmup
            let body = infer_body(i, &images[i % images.len()], lat_trials);
            let (status, resp) = http_roundtrip(&mut hr, &mut hw, "/v1/infer", &body);
            assert_eq!(status, 200, "http warmup: {resp}");
        }
        let t0 = Instant::now();
        for i in 0..lat_reqs {
            let body = infer_body(i, &images[i % images.len()], lat_trials);
            let (status, resp) = http_roundtrip(&mut hr, &mut hw, "/v1/infer", &body);
            assert_eq!(status, 200, "http lane: {resp}");
        }
        t0.elapsed().as_secs_f64() / lat_reqs.max(1) as f64
    };
    drop(http_server);
    let http_ratio = http_lat / remote_lat.max(1e-12);
    println!(
        "  http ingress loopback          : {:>9.0} µs/req ({http_ratio:.2}x the framed socket, {lat_trials} trials/req)",
        http_lat * 1e6,
    );

    // Machine-readable trajectory: every lane of this run as one JSON
    // object (written before the smoke gates, so a failing gate still
    // leaves the evidence on disk).
    if let Some(path) = &json_path {
        let j = json::obj(vec![
            ("bench", Json::Str("bench_fleet".into())),
            ("smoke", Json::Bool(smoke)),
            ("model", Json::Str(model_name.into())),
            ("trials_per_request", json::num(trials as f64)),
            (
                "native_kernel",
                json::obj(vec![
                    ("simd_isa", Json::Str(simd_isa.into())),
                    ("trials_per_image", json::num(kernel_trials as f64)),
                    ("scalar_trials_per_s", json::num(scalar_tps)),
                    (
                        "blocked_trials_per_s",
                        json::obj(
                            blocked_lanes
                                .iter()
                                .map(|(k, v)| (k.as_str(), json::num(*v)))
                                .collect(),
                        ),
                    ),
                    ("blocked_infer_trials_per_s", json::num(blocked_infer_tps)),
                ]),
            ),
            (
                "backend_trials_per_s",
                json::obj(
                    backend_lanes
                        .iter()
                        .map(|(k, v)| (k.as_str(), json::num(*v)))
                        .collect(),
                ),
            ),
            (
                "loopback_us_per_req",
                json::obj(vec![
                    ("local_die", json::num(local_lat * 1e6)),
                    ("remote_die", json::num(remote_lat * 1e6)),
                ]),
            ),
            (
                "http_ingress",
                json::obj(vec![
                    ("http_us_per_req", json::num(http_lat * 1e6)),
                    ("socket_us_per_req", json::num(remote_lat * 1e6)),
                    ("http_over_socket", json::num(http_ratio)),
                ]),
            ),
            // Final per-node MetricsTree of the 2x(pipeline:2) lane.
            ("metrics_tree", final_tree.take().unwrap_or(Json::Null)),
        ]);
        std::fs::write(path, format!("{j}\n")).expect("writing --json report");
        println!("wrote {path}");
    }

    if smoke {
        let blocked_ratio = blocked_infer_tps / scalar_tps.max(1e-9);
        // With a dispatched SIMD ISA on x86_64 CI the bar rises to 2.0×;
        // the scalar fallback (RACA_NO_SIMD=1) and other arches keep the
        // pre-SIMD 1.5× bar.
        let blocked_bar =
            if cfg!(target_arch = "x86_64") && simd_isa != "scalar" { 2.0 } else { 1.5 };
        assert!(
            blocked_ratio >= blocked_bar,
            "--smoke: blocked native infer (B=64 + thread sharding, isa {simd_isa}) must be ≥{blocked_bar}x the scalar path, got {blocked_ratio:.2}x"
        );
        println!(
            "smoke OK: blocked infer = {blocked_ratio:.2}x scalar native path (≥ {blocked_bar}x required, isa {simd_isa})"
        );
        let ratio = pipelined_at_4 / single_tps.max(1e-9);
        assert!(
            ratio >= 2.0,
            "--smoke: pipeline:4 must be ≥2x single-die throughput, got {ratio:.2}x"
        );
        println!("smoke OK: pipeline:4 = {ratio:.2}x single-die (≥ 2x required)");
        let rp = replicated_pipes / pipelined_at_4.max(1e-9);
        assert!(
            rp >= 1.0,
            "--smoke: 2x(pipeline:2) must be ≥ pipeline:4 at equal dies, got {rp:.2}x"
        );
        println!("smoke OK: 2x(pipeline:2) = {rp:.2}x pipeline:4 at 4 dies (≥ 1x required)");
        assert!(
            lat_ratio <= 2.0,
            "--smoke: loopback remote:die must stay within 2x local single-die latency, got {lat_ratio:.2}x"
        );
        println!(
            "smoke OK: remote:die loopback = {lat_ratio:.2}x local latency (≤ 2x required)"
        );

        // Forced overflow at the HTTP front door: a 1-deep ingress hit
        // by an 8-way burst must shed with 429s — every connection
        // answered (the 20 s read timeouts are the hang detector), no
        // status outside {200, 429}.
        let tiny = {
            let mut c = raca::serve::HttpConfig::new("127.0.0.1:0");
            c.queue_depth = 1;
            c.in_flight = 1;
            raca::serve::serve_http(die(seed), &c).expect("tiny http ingress")
        };
        let tiny_addr = tiny.addr();
        let shared_images = Arc::new(images.clone());
        let hands: Vec<_> = (0..8usize)
            .map(|i| {
                let images = shared_images.clone();
                std::thread::spawn(move || {
                    let body = infer_body(i, &images[i % images.len()], 400);
                    let s = std::net::TcpStream::connect(tiny_addr).expect("overflow connect");
                    s.set_read_timeout(Some(std::time::Duration::from_secs(20))).unwrap();
                    let mut w = s.try_clone().unwrap();
                    let mut r = std::io::BufReader::new(s);
                    http_roundtrip(&mut r, &mut w, "/v1/infer", &body).0
                })
            })
            .collect();
        let statuses: Vec<u16> =
            hands.into_iter().map(|h| h.join().expect("overflow thread answered")).collect();
        assert!(
            statuses.iter().all(|s| *s == 200 || *s == 429),
            "--smoke: unexpected statuses under forced overflow: {statuses:?}"
        );
        assert!(
            statuses.contains(&429),
            "--smoke: an 8-way burst over a 1-deep ingress must shed, got {statuses:?}"
        );
        println!(
            "smoke OK: http ingress sheds under forced overflow ({} of 8 answered 429)",
            statuses.iter().filter(|s| **s == 429).count()
        );
    }

    // --- trajectory gate: fresh run vs a checked-in --json baseline --------
    if let Some(path) = &check_path {
        // (lane, fresh ratio, higher-is-better, bar cap).  Ratios are
        // dimensionless so a baseline from another machine stays
        // comparable; thread-scaled lanes cap their pass bar at the 2.0×
        // acceptance bar (a many-core baseline must not demand more
        // parallel speedup than this machine's cores can offer — the
        // single-thread kernel lanes carry the uncapped signal).
        const THREAD_CAP: f64 = 2.0;
        let s = scalar_tps.max(1e-9);
        let mut fresh: Vec<(String, f64, bool, f64)> = Vec::new();
        for (k, v) in &blocked_lanes {
            fresh.push((format!("kernel/{k}_over_scalar"), v / s, true, f64::INFINITY));
        }
        fresh.push((
            "kernel/blocked_infer_over_scalar".into(),
            blocked_infer_tps / s,
            true,
            THREAD_CAP,
        ));
        let die_tps = backend_lanes
            .iter()
            .find(|(k, _)| k == "die")
            .map(|(_, v)| *v)
            .unwrap_or(0.0)
            .max(1e-9);
        for (k, v) in backend_lanes.iter().filter(|(k, _)| k != "die") {
            fresh.push((format!("backend/{k}_over_die"), v / die_tps, true, THREAD_CAP));
        }
        fresh.push(("wire/remote_over_local".into(), lat_ratio, false, f64::INFINITY));
        fresh.push(("http/over_socket".into(), http_ratio, false, f64::INFINITY));

        // The same ratio derivations off the baseline report.
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("--check: reading {path}: {e}"));
        let base = Json::parse(&text).unwrap_or_else(|e| panic!("--check: parsing {path}: {e}"));
        let bget = |keys: &[&str]| base.path(keys).and_then(Json::as_f64);
        let mut baseline: Vec<(String, f64)> = Vec::new();
        if let Some(bs) = bget(&["native_kernel", "scalar_trials_per_s"]) {
            let bs = bs.max(1e-9);
            if let Some(m) =
                base.path(&["native_kernel", "blocked_trials_per_s"]).and_then(Json::as_obj)
            {
                for (k, v) in m {
                    if let Some(v) = v.as_f64() {
                        baseline.push((format!("kernel/{k}_over_scalar"), v / bs));
                    }
                }
            }
            if let Some(v) = bget(&["native_kernel", "blocked_infer_trials_per_s"]) {
                baseline.push(("kernel/blocked_infer_over_scalar".into(), v / bs));
            }
        }
        if let Some(bd) = bget(&["backend_trials_per_s", "die"]) {
            let bd = bd.max(1e-9);
            if let Some(m) = base.path(&["backend_trials_per_s"]).and_then(Json::as_obj) {
                for (k, v) in m {
                    if k != "die" {
                        if let Some(v) = v.as_f64() {
                            baseline.push((format!("backend/{k}_over_die"), v / bd));
                        }
                    }
                }
            }
        }
        if let (Some(l), Some(r)) = (
            bget(&["loopback_us_per_req", "local_die"]),
            bget(&["loopback_us_per_req", "remote_die"]),
        ) {
            baseline.push(("wire/remote_over_local".into(), r / l.max(1e-9)));
        }
        if let Some(v) = bget(&["http_ingress", "http_over_socket"]) {
            baseline.push(("http/over_socket".into(), v));
        }
        let base_isa = base
            .path(&["native_kernel", "simd_isa"])
            .and_then(Json::as_str)
            .unwrap_or("unknown");

        println!(
            "== bench_fleet --check vs {path} (tolerance {tolerance:.2}, isa {simd_isa} vs baseline {base_isa}) =="
        );
        let mut compared = 0usize;
        let mut failures = 0usize;
        for (lane, now, higher, cap) in &fresh {
            let Some((_, want)) = baseline.iter().find(|(k, _)| k == lane) else {
                println!("  {lane:<38} {now:>8.3}            (no baseline lane — skipped)");
                continue;
            };
            compared += 1;
            let (bar, ok) = if *higher {
                let bar = (want * (1.0 - tolerance)).min(*cap);
                (bar, *now >= bar)
            } else {
                let bar = want * (1.0 + tolerance);
                (bar, *now <= bar)
            };
            let verdict = if ok { "ok" } else { "REGRESSED" };
            println!(
                "  {lane:<38} {now:>8.3} vs {want:>8.3}  (bar {bar:.3}) {verdict}"
            );
            if !ok {
                failures += 1;
            }
        }
        for (lane, want) in &baseline {
            if !fresh.iter().any(|(k, _, _, _)| k == lane) {
                println!("  {lane:<38}      —   vs {want:>8.3}  (lane gone from this run — skipped)");
            }
        }
        assert!(compared > 0, "--check: no comparable lanes in {path}");
        if failures > 0 {
            eprintln!("--check: {failures} lane(s) regressed beyond tolerance {tolerance:.2} vs {path}");
            std::process::exit(1);
        }
        println!("check OK: {compared} lanes within tolerance {tolerance:.2} of {path}");
    }
}
