//! Bench: fleet fan-out — trial throughput scaling with replica count.
//!
//! Programs farms of 1/2/4/8 native-engine chips (σ=5% variation draws)
//! and pushes the same fixed trial batch through `FleetRunner::run`, which
//! shards rows across chips on scoped threads.  Throughput should scale
//! close to linearly until the batch is too small to feed every die.

use raca::coordinator::TrialRunner;
use raca::device::VariationModel;
use raca::engine::TrialParams;
use raca::fleet::{Fleet, RoutePolicy};
use raca::nn::{ModelSpec, Weights};
use raca::util::bench::bench_units;

fn main() {
    println!("== bench_fleet: trial throughput vs replica count ==");
    let w = Weights::random(ModelSpec::new(vec![784, 64, 10]), 7);
    let rows = 128usize;
    let x: Vec<f32> = (0..rows * 784).map(|i| (i % 23) as f32 / 23.0).collect();
    let p = TrialParams::default();

    let mut base = 0.0f64;
    for &chips in &[1usize, 2, 4, 8] {
        let fleet = Fleet::program_native(
            &w,
            chips,
            &VariationModel::lognormal(0.05),
            RoutePolicy::RoundRobin,
            1234,
        );
        let runner = fleet.into_runner();
        let mut seed = 0u32;
        let r = bench_units(
            &format!("fleet run {rows} rows, {chips} chip(s)"),
            2,
            12,
            rows as f64,
            || {
                seed = seed.wrapping_add(1);
                std::hint::black_box(runner.run(&x, rows, seed, p).expect("fleet run"));
            },
        );
        let tps = r.units_per_sec();
        if chips == 1 {
            base = tps;
            println!("  → {tps:.0} trials/s (baseline)");
        } else {
            println!("  → {tps:.0} trials/s ({:.2}x over 1 chip)", tps / base.max(1e-9));
        }
    }

    println!("\n(per-chip rows are contiguous shards; see fleet::runner docs)");
}
