//! Bench: serving throughput across `Backend` implementations.
//!
//! One 4-layer model, three deployment shapes behind the same trait:
//!
//! * single-chip — the coordinator's batched scheduler on one engine;
//! * replicated × {2,4,8} — per-chip worker threads + router dispatch
//!   (whole requests per die, σ=5% variation draws);
//! * pipelined × {2,4} — the model's layers sharded across dies,
//!   activations streaming die-to-die.  The input die caches the
//!   per-request layer-0 pre-activation, so the deepest matmul leaves the
//!   per-trial path entirely — which is why the pipeline beats a single
//!   chip even before thread-level parallelism kicks in.
//!
//! `--smoke` runs a CI-sized workload and *asserts* the acceptance bar:
//! pipelined @ 4 dies ≥ 2× single-chip trial throughput.

use std::sync::Arc;
use std::time::Instant;

use raca::coordinator::SchedulerConfig;
use raca::device::VariationModel;
use raca::engine::NativeEngine;
use raca::fleet::{Fleet, RoutePolicy};
use raca::nn::{ModelSpec, Weights};
use raca::serve::{
    Backend, InferRequest, PipelineOptions, PipelinedFleetBackend, ReplicatedFleetBackend,
    ReplicatedOptions, SingleChipBackend,
};

/// Push `reqs` fixed-budget requests through `backend`; trials/second.
fn throughput(backend: &dyn Backend, images: &[Vec<f32>], trials: u32, reqs: usize) -> f64 {
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..reqs)
        .map(|i| {
            backend
                .submit(
                    InferRequest::new(i as u64, images[i % images.len()].clone())
                        .with_budget(trials, 0.0),
                )
                .expect("submit")
        })
        .collect();
    let mut total = 0u64;
    for t in tickets {
        total += backend.wait(t).expect("wait").trials_used as u64;
    }
    total as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (warmup, reqs, trials) = if smoke { (12, 48, 8u32) } else { (24, 192, 12u32) };
    let spec = ModelSpec::new(vec![784, 256, 192, 128, 10]);
    let w = Weights::random(spec, 7);
    let seed = 0xBE7C;
    // Dense pseudo-images (~4% zeros): keeps the single-chip baseline
    // honest — sparse inputs would hand it an affine_aug shortcut.
    let images: Vec<Vec<f32>> = (0..32)
        .map(|i| (0..784).map(|j| ((i * 31 + j) % 23) as f32 / 23.0).collect())
        .collect();

    println!(
        "== bench_fleet: serving throughput by backend ({reqs} reqs × {trials} trials, 4-layer model) =="
    );

    let single_tps = {
        let engine = NativeEngine::new(Arc::new(w.clone()), seed);
        let mut cfg = SchedulerConfig::default();
        cfg.batch_size = 32;
        let b = SingleChipBackend::start(engine, cfg);
        let _ = throughput(&b, &images, trials, warmup);
        let tps = throughput(&b, &images, trials, reqs);
        println!("  single-chip (batched scheduler)  : {tps:>9.0} trials/s  (baseline)");
        tps
    };

    for chips in [2usize, 4, 8] {
        let fleet = Fleet::program_native(
            &w,
            chips,
            &VariationModel::lognormal(0.05),
            RoutePolicy::RoundRobin,
            seed,
        );
        let b = ReplicatedFleetBackend::start(fleet, None, ReplicatedOptions::default());
        let _ = throughput(&b, &images, trials, warmup);
        let tps = throughput(&b, &images, trials, reqs);
        println!(
            "  replicated × {chips} chips             : {tps:>9.0} trials/s  ({:.2}x)",
            tps / single_tps.max(1e-9)
        );
    }

    let mut pipelined_at_4 = 0.0f64;
    for dies in [2usize, 4] {
        let b = PipelinedFleetBackend::start(
            &w,
            PipelineOptions { dies, seed, ..Default::default() },
        )
        .expect("building pipelined backend");
        let _ = throughput(&b, &images, trials, warmup);
        let tps = throughput(&b, &images, trials, reqs);
        if dies == 4 {
            pipelined_at_4 = tps;
        }
        println!(
            "  pipelined  × {dies} dies              : {tps:>9.0} trials/s  ({:.2}x)",
            tps / single_tps.max(1e-9)
        );
    }

    if smoke {
        let ratio = pipelined_at_4 / single_tps.max(1e-9);
        assert!(
            ratio >= 2.0,
            "--smoke: pipelined @ 4 dies must be ≥2x single-chip throughput, got {ratio:.2}x"
        );
        println!("smoke OK: pipelined @ 4 dies = {ratio:.2}x single-chip (≥ 2x required)");
    }
}
