//! Bench: Fig. 5 workload — WTA decision throughput (transient circuit
//! vs analytic sampling) and the panel (d) regeneration time.

use raca::circuit::{WtaCircuit, WtaParams};
use raca::neuron::softmax_wta::WtaLayer;
use raca::stats::GaussianSource;
use raca::util::bench::bench_units;

fn main() {
    println!("== bench_fig5: WTA decisions ==");
    let sigma_v = 0.02;
    let z = [-1.2, -0.4, 0.3, -0.8, 2.1, 0.9, -1.6, 0.1, -0.3, 0.9];
    let v: Vec<f64> = z.iter().map(|&zi| zi * sigma_v / 1.702).collect();
    let v_mean = v.iter().sum::<f64>() / v.len() as f64;
    let vth0 = 1.702 * sigma_v - v_mean;
    let params = WtaParams { sigma_v, vth0, ..Default::default() };

    let circuit = WtaCircuit::new(params.clone());
    let mut g = GaussianSource::new(1);
    let decisions = 2000usize;
    bench_units("transient WTA decide() x2000", 2, 10, decisions as f64, || {
        for _ in 0..decisions {
            std::hint::black_box(circuit.decide(&v, &mut g));
        }
    });

    let layer = WtaLayer::new(params);
    bench_units("WtaLayer.run 2000 trials (counts)", 2, 10, decisions as f64, || {
        std::hint::black_box(layer.run(&v, decisions, &mut g));
    });

    println!("\nregenerating Fig 5 panels at bench scale…");
    let t0 = std::time::Instant::now();
    raca::figures::fig5::run("all", 2000).expect("fig5");
    println!("fig5 wall time: {:?}", t0.elapsed());
}
