//! Bench: Table I workload — cost-model evaluation speed plus the table
//! regeneration itself (with breakdowns and ablations).

use raca::hwmodel::table1::Table1Result;
use raca::hwmodel::{Architecture, SystemModel};
use raca::util::bench::bench_units;

fn main() {
    println!("== bench_table1: hardware cost model ==");
    let model = SystemModel::paper();
    bench_units("full energy+area+tops evaluation (both archs)", 10, 50, 2.0, || {
        for arch in [Architecture::OneBitAdc, Architecture::Raca] {
            std::hint::black_box(model.energy(arch).total());
            std::hint::black_box(model.area(arch).total());
            std::hint::black_box(model.tops_per_watt(arch));
        }
    });
    bench_units("Table1Result::compute", 10, 50, 1.0, || {
        std::hint::black_box(Table1Result::compute(&model));
    });

    println!("\nregenerating Table I + ablations…");
    let t0 = std::time::Instant::now();
    raca::figures::table1::run().expect("table1");
    raca::figures::table1::ablate_tiles().expect("tiles");
    raca::figures::table1::ablate_low_vr().expect("low-vr");
    println!("table1 wall time: {:?}", t0.elapsed());
}
