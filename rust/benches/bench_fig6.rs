//! Bench: Fig. 6 workload — end-to-end stochastic inference throughput of
//! the native engine (single-thread and parallel) and the panel (b)
//! regeneration time.

use std::sync::Arc;

use raca::engine::{NativeEngine, TrialParams};
use raca::figures::common::parallel_map;
use raca::nn::Weights;

use raca::util::bench::bench_units;

fn main() {
    println!("== bench_fig6: end-to-end stochastic trials (native engine) ==");
    let dir = raca::runtime::default_artifact_dir();
    let Ok(w) = Weights::load(&dir.join("weights").join("fcnn")) else {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    };
    let Ok(ds) = raca::dataset::Dataset::load(&dir.join("data").join("test")) else {
        eprintln!("SKIP: dataset missing");
        return;
    };
    let engine = NativeEngine::new(Arc::new(w), 1);
    let p = TrialParams::default();
    let x = ds.image(0);

    let k = 20usize;
    bench_units("native trial x20 uncached (single image)", 2, 10, k as f64, || {
        for t in 0..k {
            std::hint::black_box(engine.trial(x, p, t as u64));
        }
    });
    // §Perf iteration 1: cache the deterministic layer-0 pre-activation
    // across trials of one image (removes 72% of per-trial MACs).
    let z1 = engine.precompute(x);
    bench_units("native trial x20 cached-z1 (single image)", 2, 10, k as f64, || {
        for t in 0..k {
            std::hint::black_box(engine.trial_cached(&z1, p, t as u64));
        }
    });
    // §Perf iteration 3: + reusable scratch buffers (no per-trial allocs).
    let mut scratch = raca::nn::forward::TrialScratch::default();
    bench_units("native trial x20 cached+scratch (hot path)", 2, 10, k as f64, || {
        for t in 0..k {
            std::hint::black_box(engine.trial_scratch(&z1, p, t as u64, &mut scratch));
        }
    });

    let idx: Vec<usize> = (0..64).collect();
    bench_units("native trials, 64 images x 4 trials (parallel)", 1, 5, 256.0, || {
        let r = parallel_map(&idx, |_, &i| {
            (0..4).map(|t| engine.trial(ds.image(i), p, (i * 100 + t) as u64)).sum::<i32>()
        });
        std::hint::black_box(r);
    });

    println!("\nregenerating Fig 6(b) at bench scale (150 images)…");
    let t0 = std::time::Instant::now();
    raca::figures::fig6::run("b", 150, false).expect("fig6b");
    println!("fig6(b) wall time: {:?}", t0.elapsed());
}
