//! Bench: the production hot path — AOT/PJRT trial executables at every
//! batch size, the ideal executable, and coordinator overhead vs raw
//! engine calls.  This is the §Perf reference workload (EXPERIMENTS.md).
//!
//! `--json <path>` writes each lane's units/s to a machine-readable
//! report (same shape as `bench_fleet --json`); a missing artifact store
//! writes `{"skipped": true}` so trajectory tooling can tell "not run"
//! from "ran and regressed".

use raca::coordinator::{SchedulerConfig, Server};
use raca::dataset::Dataset;
use raca::engine::{TrialParams, XlaEngine};
use raca::runtime::ArtifactStore;
use raca::util::bench::{bench_units, BenchResult};
use raca::util::json::{self, Json};

fn write_report(path: &str, skipped: bool, lanes: &[BenchResult]) {
    let j = json::obj(vec![
        ("bench", Json::Str("bench_hotpath".into())),
        ("skipped", Json::Bool(skipped)),
        (
            "units_per_s",
            json::obj(
                lanes
                    .iter()
                    .map(|r| (r.name.as_str(), json::num(r.units_per_sec())))
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(path, format!("{j}\n")).expect("writing --json report");
    println!("wrote {path}");
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let json_path = argv
        .windows(2)
        .find(|w| w[0] == "--json")
        .map(|w| w[1].clone());
    println!("== bench_hotpath: AOT/PJRT + coordinator ==");
    let dir = ArtifactStore::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        if let Some(path) = &json_path {
            write_report(path, true, &[]);
        }
        return;
    }
    let mut lanes: Vec<BenchResult> = Vec::new();
    let ds = Dataset::load(&dir.join("data").join("test")).expect("dataset");
    let engine = XlaEngine::start(dir).expect("engine");
    let h = engine.handle();
    let m = h.manifest().expect("manifest");
    let p = TrialParams::default();

    // --- raw trial executables at each batch size ----------------------
    for &b in &m.trial_batches {
        h.warmup(b).expect("warmup");
        let mut xs = Vec::with_capacity(b * 784);
        for i in 0..b {
            xs.extend_from_slice(ds.image(i % ds.len()));
        }
        let mut seed = 0u32;
        lanes.push(bench_units(
            &format!("trial_fwd_b{b} execute (trials/iter={b})"),
            3,
            15,
            b as f64,
            || {
                seed = seed.wrapping_add(1);
                std::hint::black_box(h.run_trials(xs.clone(), b, seed, p).expect("run"));
            },
        ));
    }

    // --- ideal executable ------------------------------------------------
    for &b in &m.ideal_batches {
        let mut xs = Vec::with_capacity(b * 784);
        for i in 0..b {
            xs.extend_from_slice(ds.image(i % ds.len()));
        }
        lanes.push(bench_units(
            &format!("ideal_fwd_b{b} execute (images/iter={b})"),
            3,
            15,
            b as f64,
            || {
                std::hint::black_box(h.run_ideal(xs.clone(), b).expect("run"));
            },
        ));
    }

    // --- coordinator overhead -----------------------------------------
    // 64 requests × 8 trials through the scheduler vs the same trial count
    // as raw batch-32 executes.  The delta is pure coordination cost.
    let n_req = 64usize;
    let trials_per = 8u32;
    let total_trials = n_req * trials_per as usize;
    let raw_batches = total_trials / 32;
    let mut xs32 = Vec::with_capacity(32 * 784);
    for i in 0..32 {
        xs32.extend_from_slice(ds.image(i));
    }
    let mut seed = 1000u32;
    lanes.push(bench_units(
        &format!("raw engine: {raw_batches} batch-32 executes ({total_trials} trials)"),
        1,
        8,
        total_trials as f64,
        || {
            for _ in 0..raw_batches {
                seed = seed.wrapping_add(1);
                std::hint::black_box(h.run_trials(xs32.clone(), 32, seed, p).expect("run"));
            }
        },
    ));

    lanes.push(bench_units(
        &format!("coordinator: {n_req} requests x {trials_per} trials (batch 32)"),
        1,
        8,
        total_trials as f64,
        || {
            let mut cfg = SchedulerConfig::default();
            cfg.batch_size = 32;
            let server = Server::start(h.clone(), cfg);
            let client = server.client();
            let rxs: Vec<_> = (0..n_req)
                .map(|i| client.submit(ds.image(i).to_vec(), trials_per, 0.0).unwrap())
                .collect();
            for rx in rxs {
                rx.recv().expect("response");
            }
        },
    ));

    if let Some(path) = &json_path {
        write_report(path, false, &lanes);
    }
}
