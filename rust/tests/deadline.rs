//! Deadline propagation end-to-end: a request whose budget has expired
//! is shed with the in-band `deadline_exceeded` failure — never served
//! late, never hung — at every execution layer the tree can route it to
//! (the batched scheduler behind `die`, the pipelined fleet's admission,
//! the replicated worker fleet), while undeadlined requests on the same
//! backend are untouched.  The router-level budget arithmetic (subtract
//! observed queue wait, shed pre-dispatch) is unit-tested in
//! `serve::plan`; the HTTP 504 mapping in `tests/http.rs`.

use raca::dataset::synth;
use raca::nn::{ModelSpec, TrainConfig, Weights};
use raca::serve::{build, Backend, BuildOptions, InferRequest, Topology, DEADLINE_EXCEEDED};
use raca::telemetry::EventKind;

fn trained() -> Weights {
    let ds = synth::generate(160, 0x7A);
    let cfg = TrainConfig { epochs: 3, lr: 0.25, seed: 0x7B, minibatch: 1 };
    raca::nn::train(&ds, ModelSpec::new(vec![784, 20, 12, 10]), &cfg)
}

fn image(i: u64) -> Vec<f32> {
    (0..784).map(|j| ((j as u64 * 7 + i * 131) % 17) as f32 / 17.0).collect()
}

/// A zero budget is expired on arrival: every topology must shed it
/// in-band with the matchable prefix, and serve the undeadlined request
/// that follows as if nothing happened.
#[test]
fn expired_budgets_are_shed_in_band_at_every_layer() {
    let w = trained();
    // (spec, whether the shedding layer writes the shared journal —
    // the bare scheduler sheds without one).
    for (spec, journaled) in [("die", false), ("pipeline:2", true), ("2x(die)", true)] {
        let b = build(
            &Topology::parse(spec).unwrap(),
            &w,
            &BuildOptions { seed: 0xDEAD1, ..Default::default() },
        )
        .unwrap();

        let e = b
            .classify(InferRequest::new(0, image(0)).with_budget(6, 0.0).with_deadline_ms(0))
            .expect_err("an expired budget must not be served");
        let msg = format!("{e:#}");
        assert!(
            msg.contains(DEADLINE_EXCEEDED),
            "[{spec}] shed must carry the matchable prefix, got: {msg}"
        );

        // The backend is unharmed: an undeadlined request still serves,
        // and so does a generous one.
        let r = b.classify(InferRequest::new(1, image(1)).with_budget(6, 0.0)).unwrap();
        assert_eq!(r.trials_used, 6, "[{spec}] undeadlined request");
        let r = b
            .classify(InferRequest::new(2, image(2)).with_budget(6, 0.0).with_deadline_ms(60_000))
            .unwrap();
        assert_eq!(r.trials_used, 6, "[{spec}] generous deadline");

        if journaled {
            let j = b.journal().expect("built trees share a journal");
            assert!(
                j.tail(j.capacity())
                    .iter()
                    .any(|e| e.kind == EventKind::DeadlineExceeded),
                "[{spec}] shed was not journaled:\n{}",
                j.to_json_lines()
            );
        }
        b.shutdown();
    }
}

/// Deadlines cross the wire (protocol v5): a remote leaf relays the
/// budget in the Submit frame and the hosted tree sheds it on the far
/// side — the failure comes back in-band over the session, prefix
/// intact.
#[test]
fn expired_budgets_are_shed_across_the_wire() {
    let w = trained();
    let host =
        build(&Topology::parse("die").unwrap(), &w, &BuildOptions { seed: 0xDEAD2, ..Default::default() })
            .unwrap();
    let server = raca::serve::net::serve(host, "127.0.0.1:0").unwrap();
    let b = build(
        &Topology::parse(&format!("remote:{}", server.addr())).unwrap(),
        &w,
        &BuildOptions::default(),
    )
    .unwrap();

    let e = b
        .classify(InferRequest::new(0, image(0)).with_budget(6, 0.0).with_deadline_ms(0))
        .expect_err("an expired budget must be shed on the far side");
    assert!(
        format!("{e:#}").contains(DEADLINE_EXCEEDED),
        "prefix must survive the wire round-trip: {e:#}"
    );
    let r = b.classify(InferRequest::new(1, image(1)).with_budget(6, 0.0)).unwrap();
    assert_eq!(r.trials_used, 6);
    b.shutdown();
    drop(server);
}
