//! Fleet subsystem integration: reproducible per-chip RNG streams,
//! calibration that never hurts, routing, and scheduler fan-out.
//!
//! Everything here is artifact-free: the model is trained natively on
//! synthetic digits (`nn::train`), so the suite runs on a fresh checkout.

use raca::coordinator::{InferRequest, Metrics, Scheduler, SchedulerConfig};
use raca::dataset::synth;
use raca::device::VariationModel;
use raca::engine::TrialParams;
use raca::fleet::{Calibrator, Fleet, RoutePolicy};
use raca::nn::{ModelSpec, TrainConfig, Weights};

/// Small trained net shared across tests (accuracy matters for (b)).
fn trained() -> Weights {
    let ds = synth::generate(160, 0x7A);
    let cfg = TrainConfig { epochs: 3, lr: 0.25, seed: 0x7B, minibatch: 1 };
    raca::nn::train(&ds, ModelSpec::new(vec![784, 16, 10]), &cfg)
}

fn farm(w: &Weights, chips: usize, sigma: f64, seed: u64) -> Fleet<raca::engine::NativeEngine> {
    Fleet::program_native(
        w,
        chips,
        &VariationModel::lognormal(sigma),
        RoutePolicy::RoundRobin,
        seed,
    )
}

// ---- (a) per-chip RNG streams: reproducible and independent ---------------

#[test]
fn same_fleet_seed_reproduces_identical_chips() {
    let w = Weights::random(ModelSpec::new(vec![784, 12, 10]), 1);
    let mut a = farm(&w, 4, 0.10, 42);
    let mut b = farm(&w, 4, 0.10, 42);

    let x: Vec<f32> = (0..784).map(|i| (i % 19) as f32 / 19.0).collect();
    let p = TrialParams::default();
    for (ca, cb) in a.chips.iter_mut().zip(b.chips.iter_mut()) {
        // Identical programmed weights…
        assert_eq!(ca.engine.weights.mats, cb.engine.weights.mats);
        // …and identical trial streams, decision by decision.
        for t in 0..50u64 {
            assert_eq!(
                ca.engine.trial(&x, p, t),
                cb.engine.trial(&x, p, t),
                "chip {} trial {t} diverged across identically-seeded fleets",
                ca.id
            );
        }
    }
}

#[test]
fn chips_within_a_fleet_are_independent() {
    let w = Weights::random(ModelSpec::new(vec![784, 12, 10]), 1);
    let fleet = farm(&w, 4, 0.10, 42);
    // Distinct variation draws per die…
    for i in 0..fleet.len() {
        for j in i + 1..fleet.len() {
            assert_ne!(
                fleet.chips[i].engine.weights.mats, fleet.chips[j].engine.weights.mats,
                "chips {i} and {j} got identical variation draws"
            );
        }
    }
    // …and distinct trial-noise streams: zero the output layer so the WTA
    // winner is pure comparator noise (uniform over classes), then compare
    // the two chips' winner sequences at identical trial indices.
    let mut wz = w.clone();
    let last = wz.mats.len() - 1;
    for v in wz.mats[last].iter_mut() {
        *v = 0.0;
    }
    let mut ideal = farm(&wz, 2, 0.0, 42);
    let x: Vec<f32> = (0..784).map(|i| (i % 7) as f32 / 7.0).collect();
    let p = TrialParams::default();
    let (c0, c1) = {
        let (lo, hi) = ideal.chips.split_at_mut(1);
        (&mut lo[0], &mut hi[0])
    };
    let a: Vec<i32> = (0..200).map(|t| c0.engine.trial(&x, p, t)).collect();
    let b: Vec<i32> = (0..200).map(|t| c1.engine.trial(&x, p, t)).collect();
    assert_ne!(a, b, "two chips produced identical 200-trial winner streams");
}

#[test]
fn different_fleet_seed_changes_the_farm() {
    let w = Weights::random(ModelSpec::new(vec![784, 12, 10]), 1);
    let a = farm(&w, 2, 0.10, 7);
    let b = farm(&w, 2, 0.10, 8);
    assert_ne!(a.chips[0].engine.weights.mats, b.chips[0].engine.weights.mats);
}

// ---- (b) calibration recovers accuracy ------------------------------------

#[test]
fn calibrated_sigma10_fleet_is_no_worse_than_uncalibrated() {
    let w = trained();
    let mut fleet = farm(&w, 4, 0.10, 1234);
    let batch = synth::generate(24, 0x5E7);
    let calibrator = Calibrator::quick(5);

    let uncalibrated = fleet.mean_accuracy(&batch, &calibrator);
    let reports = fleet.calibrate(&batch, &calibrator);
    let calibrated = fleet.mean_accuracy(&batch, &calibrator);

    // Per-chip: argmax over a grid that contains the nominal point.
    for r in &reports {
        assert!(
            r.calibrated_accuracy >= r.baseline_accuracy,
            "chip {}: calibration regressed {} → {}",
            r.chip,
            r.baseline_accuracy,
            r.calibrated_accuracy
        );
    }
    // Fleet aggregate on the same batch, same seeds.
    assert!(
        calibrated >= uncalibrated,
        "fleet calibration regressed: {uncalibrated} → {calibrated}"
    );
}

// ---- routing + scheduler fan-out ------------------------------------------

#[test]
fn replicated_backend_spreads_a_served_workload_and_health_tracks_it() {
    // `Fleet::serve` is gone (PR-2): request-level serving goes through
    // the Backend trait, with one worker thread per chip — reached via
    // `serve::plan::lift_fleet` since the topology redesign (PR-3).
    use raca::serve::{plan, Backend, InferRequest as Req, ReplicatedOptions};

    let w = trained();
    let fleet = farm(&w, 3, 0.05, 99);
    let batch = synth::generate(30, 0xF00D);
    let backend = plan::lift_fleet(fleet, None, ReplicatedOptions::default());
    let tickets: Vec<_> = (0..batch.len())
        .map(|i| {
            backend
                .submit(
                    Req::new(i as u64, batch.image(i).to_vec())
                        .with_budget(5, 0.0)
                        .with_label(batch.label(i)),
                )
                .unwrap()
        })
        .collect();
    for t in tickets {
        assert_eq!(backend.wait(t).unwrap().trials_used, 5);
    }
    let snap = backend.snapshot();
    assert_eq!(snap.load_imbalance(), 0, "round-robin must balance");
    let agg = snap.aggregate();
    assert_eq!(agg.served, 30);
    assert_eq!(agg.trials, 150);
    assert_eq!(agg.labeled, 30, "labeled probes must reach the health monitor");
    for (_, s) in &snap.chips {
        assert_eq!(s.served, 10);
    }
}

#[test]
fn scheduler_fans_batches_across_the_fleet() {
    let w = trained();
    let fleet = farm(&w, 2, 0.05, 31);
    let runner = fleet.into_runner();
    let mut cfg = SchedulerConfig::default();
    cfg.batch_size = 16;
    let mut sched = Scheduler::new(runner, cfg, Metrics::new());
    let batch = synth::generate(10, 0xBEE);
    for i in 0..batch.len() {
        sched
            .submit(InferRequest::new(i as u64, batch.image(i).to_vec()).with_budget(6, 0.0))
            .unwrap();
    }
    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 10);
    for r in &done {
        assert_eq!(r.trials_used, 6);
    }
    // Both chips actually executed rows.
    let per_chip = sched.engine().per_chip_metrics();
    assert_eq!(per_chip.len(), 2);
    assert!(per_chip.iter().all(|m| m.rows_packed > 0));
    assert_eq!(
        per_chip.iter().map(|m| m.rows_packed).sum::<u64>(),
        60,
        "every (request, trial) row lands on exactly one chip"
    );
}
