//! HTTP ingress integration: protocol conformance of the hand-rolled
//! HTTP/1.1 listener, bit-parity of `POST /v1/infer` against a local
//! `die` backend, admission control under saturation (429 + Retry-After,
//! never a hang, never a dropped admitted request), per-tenant rate
//! limits, and the `/metrics` + `/tree` telemetry exports.
//!
//! The client half is deliberately hand-rolled too — raw std TCP with
//! explicit request framing — so the tests exercise the wire bytes the
//! server actually parses, not a shared helper's idea of HTTP.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use raca::dataset::synth;
use raca::engine::{NativeEngine, TrialParams};
use raca::nn::{ModelSpec, TrainConfig, Weights};
use raca::serve::{
    build, trial_stream_base, BuildOptions, HttpConfig, HttpServer, Topology,
};
use raca::util::json::Json;

/// Small trained net shared across tests.
fn trained() -> Weights {
    let ds = synth::generate(160, 0x7A);
    let cfg = TrainConfig { epochs: 3, lr: 0.25, seed: 0x7B, minibatch: 1 };
    raca::nn::train(&ds, ModelSpec::new(vec![784, 20, 12, 10]), &cfg)
}

fn image(i: u64) -> Vec<f32> {
    (0..784).map(|j| ((j as u64 * 7 + i * 131) % 17) as f32 / 17.0).collect()
}

/// A `die` topology behind an HTTP ingress on an ephemeral port.
fn http_die(w: &Weights, seed: u64, cfg_mod: impl FnOnce(&mut HttpConfig)) -> HttpServer {
    let backend = build(
        &Topology::parse("die").unwrap(),
        w,
        &BuildOptions { seed, ..Default::default() },
    )
    .unwrap();
    let mut cfg = HttpConfig::new("127.0.0.1:0");
    cfg_mod(&mut cfg);
    raca::serve::serve_http(backend, &cfg).unwrap()
}

/// `/v1/infer` body for `(id, pixels, trials)`.  Pixels are formatted
/// with `{}` — Rust's shortest-round-trip repr — so the server's
/// `str::parse::<f32>` recovers the exact bits.
fn infer_body(id: u64, pixels: &[f32], trials: u32) -> String {
    let px: Vec<String> = pixels.iter().map(|p| format!("{p}")).collect();
    format!(r#"{{"id": {id}, "pixels": [{}], "trials": {trials}}}"#, px.join(", "))
}

struct Resp {
    status: u16,
    headers: Vec<(String, String)>,
    body: String,
}

impl Resp {
    fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    fn json(&self) -> Json {
        Json::parse(&self.body).unwrap_or_else(|e| panic!("bad body {:?}: {e}", self.body))
    }
}

/// One keep-alive client connection.
struct Client {
    read: BufReader<TcpStream>,
    write: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(std::time::Duration::from_secs(60))).unwrap();
        Client { read: BufReader::new(s.try_clone().unwrap()), write: s }
    }

    /// Send one request and read its response (keep-alive framing via
    /// Content-Length, which the server always sends).
    fn request(&mut self, method: &str, path: &str, headers: &[(&str, &str)], body: &str) -> Resp {
        let mut req = format!("{method} {path} HTTP/1.1\r\nHost: test\r\n");
        for (k, v) in headers {
            req.push_str(&format!("{k}: {v}\r\n"));
        }
        req.push_str(&format!("Content-Length: {}\r\n\r\n{body}", body.len()));
        self.write.write_all(req.as_bytes()).unwrap();
        self.write.flush().unwrap();
        self.read_response()
    }

    fn read_response(&mut self) -> Resp {
        let mut line = String::new();
        self.read.read_line(&mut line).unwrap();
        let mut parts = line.split_whitespace();
        assert_eq!(parts.next(), Some("HTTP/1.1"), "status line: {line:?}");
        let status: u16 = parts.next().expect("status code").parse().unwrap();
        let mut headers = Vec::new();
        let mut content_length = 0usize;
        loop {
            let mut h = String::new();
            self.read.read_line(&mut h).unwrap();
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            let (k, v) = h.split_once(':').expect("header line");
            let (k, v) = (k.trim().to_ascii_lowercase(), v.trim().to_string());
            if k == "content-length" {
                content_length = v.parse().unwrap();
            }
            headers.push((k, v));
        }
        let mut body = vec![0u8; content_length];
        self.read.read_exact(&mut body).unwrap();
        Resp { status, headers, body: String::from_utf8(body).unwrap() }
    }
}

fn post_infer(addr: std::net::SocketAddr, id: u64, pixels: &[f32], trials: u32) -> Resp {
    Client::connect(addr).request("POST", "/v1/infer", &[], &infer_body(id, pixels, trials))
}

// ---- protocol conformance -------------------------------------------------

#[test]
fn keep_alive_connection_serves_many_requests() {
    let w = trained();
    let server = http_die(&w, 0xB00, |_| {});
    let mut c = Client::connect(server.addr());

    // Two inferences and a metrics read, one connection.
    for id in [3u64, 4] {
        let r = c.request("POST", "/v1/infer", &[], &infer_body(id, &image(id), 5));
        assert_eq!(r.status, 200, "body: {}", r.body);
        let j = r.json();
        assert_eq!(j.get("id").and_then(Json::as_str), Some(id.to_string().as_str()));
        assert_eq!(j.get("trials_used").and_then(Json::as_usize), Some(5));
        assert_eq!(r.header("connection"), Some("keep-alive"));
    }
    let m = c.request("GET", "/metrics", &[], "");
    assert_eq!(m.status, 200);
    let ingress = m.json();
    let snap = ingress.get("ingress").and_then(|i| i.get("snapshot")).expect("ingress snapshot");
    assert_eq!(snap.get("requests_completed").and_then(Json::as_usize), Some(2));

    let h = c.request("GET", "/healthz", &[], "");
    assert_eq!(h.status, 200);
    assert_eq!(h.json().get("ok").and_then(Json::as_bool), Some(true));
}

#[test]
fn unknown_routes_and_methods_answer_cleanly() {
    let w = trained();
    let server = http_die(&w, 0xB01, |_| {});
    let mut c = Client::connect(server.addr());

    let r = c.request("GET", "/nope", &[], "");
    assert_eq!(r.status, 404);
    assert!(r.json().get("error").and_then(Json::as_str).unwrap().contains("/nope"));

    // Known path, wrong method: 405 with Allow.
    let r = c.request("GET", "/v1/infer", &[], "");
    assert_eq!(r.status, 405);
    assert_eq!(r.header("allow"), Some("POST"));
    let r = c.request("POST", "/metrics", &[], "");
    assert_eq!(r.status, 405);
    assert_eq!(r.header("allow"), Some("GET"));
}

#[test]
fn oversized_bodies_are_refused_with_413_before_reading() {
    let w = trained();
    let server = http_die(&w, 0xB02, |_| {});
    let mut s = TcpStream::connect(server.addr()).unwrap();
    s.set_read_timeout(Some(std::time::Duration::from_secs(30))).unwrap();
    // Declare a body over the cap and send none of it — the server must
    // answer off the headers alone (it refuses to allocate or drain).
    let too_big = raca::serve::http::server::MAX_BODY_BYTES + 1;
    write!(
        s,
        "POST /v1/infer HTTP/1.1\r\nHost: t\r\nContent-Length: {too_big}\r\n\r\n"
    )
    .unwrap();
    s.flush().unwrap();
    let mut read = BufReader::new(s.try_clone().unwrap());
    let mut line = String::new();
    read.read_line(&mut line).unwrap();
    assert!(line.starts_with("HTTP/1.1 413"), "status line: {line:?}");
    // The 413 closes the connection: the rest of the response drains to
    // EOF instead of hanging waiting for the body we never sent.
    let mut rest = String::new();
    read.read_to_string(&mut rest).unwrap();
    assert!(rest.contains("Connection: close"), "rest: {rest:?}");
}

#[test]
fn malformed_request_lines_and_bodies_get_400() {
    let w = trained();
    let server = http_die(&w, 0xB03, |_| {});

    // Garbage request line: 400, then close.
    let mut s = TcpStream::connect(server.addr()).unwrap();
    s.set_read_timeout(Some(std::time::Duration::from_secs(30))).unwrap();
    s.write_all(b"WHAT\r\n\r\n").unwrap();
    let mut resp = String::new();
    BufReader::new(s).read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.1 400"), "got: {resp:?}");

    // Well-framed HTTP, bad JSON bodies: per-request 400s, connection
    // stays usable.
    let mut c = Client::connect(server.addr());
    for (body, want) in [
        (r#"{"pixels": [0.5]}"#, "id"),
        (r#"{"id": 1}"#, "pixels"),
        (r#"{"id": 1, "pixels": []}"#, "pixels"),
        (r#"{"id": 1, "pixels": [0.5], "trials": 0}"#, "trials"),
        (r#"{"id": 1, "pixels": [0.5,"#, "bad body"),
    ] {
        let r = c.request("POST", "/v1/infer", &[], body);
        assert_eq!(r.status, 400, "body {body:?} → {}", r.body);
        let msg = r.json().get("error").and_then(Json::as_str).unwrap().to_string();
        assert!(msg.contains(want), "body {body:?} → error {msg:?}");
    }
    // …and a good request still lands on the same connection.
    let r = c.request("POST", "/v1/infer", &[], &infer_body(9, &image(9), 4));
    assert_eq!(r.status, 200, "body: {}", r.body);
}

// ---- the acceptance bar: bit-parity with a local die ----------------------

/// `POST /v1/infer` answers bit-identically to a local `die` backend at
/// equal `(seed, trial_idx)`: ids cross as-is, pixels round-trip exactly
/// through decimal JSON, and confidence is pinned to 0 server-side.
#[test]
fn http_infer_votes_bit_identical_to_local_die() {
    let w = trained();
    let seed = 0x177E;
    let server = http_die(&w, seed, |_| {});
    let reference = NativeEngine::new(Arc::new(w.clone()), seed);
    let p = TrialParams::default();

    let mut c = Client::connect(server.addr());
    for id in 0..6u64 {
        let img = image(id);
        let r = c.request("POST", "/v1/infer", &[], &infer_body(id, &img, 18));
        assert_eq!(r.status, 200, "body: {}", r.body);
        let j = r.json();
        let want = reference.infer(&img, p, 18, trial_stream_base(seed, id));
        let counts: Vec<u64> = j
            .get("counts")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|c| c.as_f64().unwrap() as u64)
            .collect();
        assert_eq!(counts, want.counts, "request {id} diverged from the local engine");
        assert_eq!(
            j.get("abstentions").and_then(Json::as_usize).unwrap() as u64,
            want.abstentions
        );
        assert_eq!(
            j.get("prediction").and_then(Json::as_f64).unwrap() as i32,
            want.prediction()
        );
        assert_eq!(j.get("trials_used").and_then(Json::as_usize), Some(18));
    }
}

/// Concurrent posts with duplicated pixels: the batcher merges equal
/// rows across requests, and every answer still matches the reference —
/// merging changes traffic, never votes.
#[test]
fn concurrent_duplicate_images_batch_without_changing_votes() {
    let w = trained();
    let seed = 0x7337;
    let server = http_die(&w, seed, |_| {});
    let reference = NativeEngine::new(Arc::new(w.clone()), seed);
    let p = TrialParams::default();
    let addr = server.addr();

    // 6 clients, 3 distinct images — duplicates are guaranteed whenever
    // the batcher catches two in one flush (and harmless otherwise).
    let hands: Vec<_> = (0..6u64)
        .map(|i| {
            std::thread::spawn(move || {
                let img = image(i % 3);
                let r = post_infer(addr, i, &img, 12);
                (i, r.status, r.body)
            })
        })
        .collect();
    for h in hands {
        let (i, status, body) = h.join().unwrap();
        assert_eq!(status, 200, "request {i}: {body}");
        let j = Json::parse(&body).unwrap();
        let want = reference.infer(&image(i % 3), p, 12, trial_stream_base(seed, i));
        let counts: Vec<u64> = j
            .get("counts")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|c| c.as_f64().unwrap() as u64)
            .collect();
        assert_eq!(counts, want.counts, "request {i} diverged under batching");
        assert_eq!(
            j.get("prediction").and_then(Json::as_f64).unwrap() as i32,
            want.prediction()
        );
    }
}

// ---- admission control under saturation -----------------------------------

/// Saturation sheds instead of hanging: with a 1-deep queue and an
/// in-flight budget of 2, a 16-way burst gets a mix of 200s and 429s —
/// every connection answered, every 429 carrying Retry-After, every
/// admitted request completing its full trial budget.
#[test]
fn saturation_sheds_with_429_and_never_drops_admitted_requests() {
    let w = trained();
    let server = http_die(&w, 0x5A7, |c| {
        c.queue_depth = 1;
        c.in_flight = 2;
    });
    let addr = server.addr();

    let hands: Vec<_> = (0..16u64)
        .map(|i| {
            std::thread::spawn(move || {
                // A big budget keeps slots occupied so the burst overlaps.
                let r = post_infer(addr, i, &image(i), 300);
                (r.status, r.header("retry-after").map(str::to_string), r.body)
            })
        })
        .collect();
    let (mut n200, mut n429) = (0usize, 0usize);
    for h in hands {
        let (status, retry_after, body) = h.join().unwrap();
        match status {
            200 => {
                let j = Json::parse(&body).unwrap();
                // Admitted requests run to completion, full budget.
                assert_eq!(j.get("trials_used").and_then(Json::as_usize), Some(300));
                n200 += 1;
            }
            429 => {
                let secs: u64 = retry_after.expect("429 must carry Retry-After").parse().unwrap();
                assert!(secs >= 1, "Retry-After must be at least a second");
                let j = Json::parse(&body).unwrap();
                assert!(j.get("error").and_then(Json::as_str).unwrap().starts_with("shed:"));
                n429 += 1;
            }
            s => panic!("unexpected status {s}: {body}"),
        }
    }
    assert_eq!(n200 + n429, 16, "every connection must be answered");
    assert!(n200 >= 1, "the budget admits at least one");
    assert!(n429 >= 1, "a 16-way burst over budget 2 must shed");

    // The ledger agrees: completions == 200s, sheds == 429s, and the
    // in-flight gauge drained back to zero.
    let m = Client::connect(addr).request("GET", "/metrics", &[], "");
    let ing = m.json();
    let ing = ing.get("ingress").expect("ingress block");
    let snap = ing.get("snapshot").expect("snapshot");
    assert_eq!(snap.get("requests_completed").and_then(Json::as_usize), Some(n200));
    assert_eq!(ing.get("shed_total").and_then(Json::as_usize), Some(n429));
    assert_eq!(ing.get("in_flight_now").and_then(Json::as_usize), Some(0));
}

/// Per-tenant token buckets: a tenant that burns its burst gets 429d
/// while other tenants (and the shared anonymous bucket) still pass.
#[test]
fn tenant_rate_limits_are_isolated() {
    let w = trained();
    // Burst 2, refill ~never (0.001/s): the third request in a row from
    // one tenant must shed, with a Retry-After reflecting the slow rate.
    let server = http_die(&w, 0x7E4A, |c| {
        c.tenant_rate = 0.001;
        c.tenant_burst = 2.0;
    });
    let mut c = Client::connect(server.addr());
    let alice = [("X-Raca-Tenant", "alice")];
    let bob = [("X-Raca-Tenant", "bob")];
    let body = infer_body(1, &image(1), 3);

    assert_eq!(c.request("POST", "/v1/infer", &alice, &body).status, 200);
    assert_eq!(c.request("POST", "/v1/infer", &alice, &body).status, 200);
    let shed = c.request("POST", "/v1/infer", &alice, &body);
    assert_eq!(shed.status, 429, "alice's burst is spent: {}", shed.body);
    let wait: u64 = shed.header("retry-after").unwrap().parse().unwrap();
    assert!(wait >= 1);

    // Bob has his own bucket; the anonymous bucket is its own tenant too.
    assert_eq!(c.request("POST", "/v1/infer", &bob, &body).status, 200);
    assert_eq!(c.request("POST", "/v1/infer", &[], &body).status, 200);
    assert_eq!(c.request("POST", "/v1/infer", &[], &body).status, 200);
    assert_eq!(c.request("POST", "/v1/infer", &[], &body).status, 429, "anonymous burst spent");
}

// ---- telemetry exports ----------------------------------------------------

/// `GET /tree` exports the PR-6 metrics tree (ingress root, backend
/// subtree) and the journal tail as JSON that round-trips through the
/// telemetry decoders.
#[test]
fn tree_endpoint_exports_metrics_tree_and_journal() {
    let w = trained();
    let server = http_die(&w, 0x73EE, |_| {});
    let mut c = Client::connect(server.addr());
    for id in 0..3u64 {
        assert_eq!(
            c.request("POST", "/v1/infer", &[], &infer_body(id, &image(id), 4)).status,
            200
        );
    }

    let r = c.request("GET", "/tree", &[], "");
    assert_eq!(r.status, 200);
    let j = r.json();
    let tree = raca::telemetry::MetricsTree::from_json(j.get("tree").expect("tree key")).unwrap();
    assert!(tree.label.starts_with("http:"), "root label: {}", tree.label);
    assert_eq!(tree.snapshot.requests_completed, 3);
    assert_eq!(tree.children.len(), 1, "backend subtree:\n{}", tree.render());
    assert_eq!(tree.children[0].label, "die#0");
    assert_eq!(tree.children[0].snapshot.requests_completed, 3);

    let events = j.get("events").and_then(Json::as_arr).expect("events key");
    assert!(!events.is_empty(), "hosted traffic must journal");
    let parsed: Vec<_> = events
        .iter()
        .map(|e| raca::telemetry::Event::from_json(e).expect("decodable event"))
        .collect();
    use raca::telemetry::EventKind;
    assert!(parsed.iter().any(|e| e.kind == EventKind::RequestAdmitted));
    assert!(parsed.iter().any(|e| e.kind == EventKind::RequestCompleted));
}

// ---- deadlines at the edge (PR-10) ----------------------------------------

/// `X-Raca-Deadline-Ms` sets the request's budget, and an expired budget
/// answers `504 Gateway Timeout` with the in-band `deadline_exceeded`
/// message — distinguishable from `500` without parsing prose — while a
/// generous budget serves normally.  The 504 must come back promptly:
/// a shed request is never served late.
#[test]
fn expired_deadline_header_answers_504_not_200_late() {
    let w = trained();
    let server = http_die(&w, 0xB504, |_| {});
    let mut c = Client::connect(server.addr());

    let t0 = std::time::Instant::now();
    let r = c.request(
        "POST",
        "/v1/infer",
        &[("X-Raca-Deadline-Ms", "0")],
        &infer_body(0, &image(0), 4),
    );
    assert_eq!(r.status, 504, "body: {}", r.body);
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(10),
        "a shed must answer promptly, took {:?}",
        t0.elapsed()
    );
    let msg = r.json().get("error").and_then(Json::as_str).unwrap().to_string();
    assert!(msg.starts_with("deadline_exceeded"), "unmatchable error: {msg}");

    // Same connection, generous budget: served, bit-parity untouched.
    let r = c.request(
        "POST",
        "/v1/infer",
        &[("X-Raca-Deadline-Ms", "60000")],
        &infer_body(1, &image(1), 4),
    );
    assert_eq!(r.status, 200, "body: {}", r.body);
    assert_eq!(r.json().get("trials_used").and_then(Json::as_usize), Some(4));

    // A malformed header is the client's bug: 400, not a guess.
    let r = c.request(
        "POST",
        "/v1/infer",
        &[("X-Raca-Deadline-Ms", "soon")],
        &infer_body(2, &image(2), 4),
    );
    assert_eq!(r.status, 400, "body: {}", r.body);
}
