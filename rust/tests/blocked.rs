//! Bit-parity of the §Perf iteration-5 trial-blocked bit-packed kernel.
//!
//! The contract: at equal `(seed, trial_idx)` the blocked path —
//! `NativeEngine::infer` / `trials_cached` / the pipelined backend's
//! per-message stage kernel — reproduces the scalar
//! `NativeEngine::trial_scratch` loop **bit-for-bit**, for every layer
//! width (including widths that are not multiples of 64), every block
//! size (including B = 1 and B > 64, which needs multi-lane trial
//! masks), partial tail blocks (trials % B ≠ 0), and abstention-heavy
//! parameter points (huge θ, where the WTA race runs its full horizon).

use std::sync::Arc;

use raca::engine::{NativeEngine, TrialParams};
use raca::nn::{ModelSpec, Weights};
use raca::serve::{build, trial_stream_base, BuildOptions, InferRequest, Topology};

fn image(dim: usize, salt: u64) -> Vec<f32> {
    (0..dim)
        .map(|j| ((j as u64 * 13 + salt * 31) % 11) as f32 / 11.0)
        .collect()
}

#[test]
fn blocked_matches_scalar_across_widths_blocks_and_tails() {
    // Odd widths on purpose: no layer is a multiple of 64, so the bit
    // masks always carry a ragged tail; 100 > 64 exercises two mask
    // lanes per neuron.
    let specs: [Vec<usize>; 3] = [
        vec![23, 17, 10, 5],
        vec![97, 65, 33, 10],
        vec![50, 129, 7],
    ];
    let p = TrialParams::default();
    for widths in &specs {
        let w = Weights::random(ModelSpec::new(widths.clone()), 9);
        let x = image(widths[0], 3);
        for block in [1usize, 3, 64, 100] {
            let e = NativeEngine::new(Arc::new(w.clone()), 0xB10C).with_trial_block(block);
            for trials in [1usize, 5, 63, 64, 65, 130] {
                let a = e.infer_scalar(&x, p, trials, 77);
                let b = e.infer(&x, p, trials, 77);
                assert_eq!(
                    a.counts, b.counts,
                    "votes diverged: widths {widths:?}, B={block}, {trials} trials"
                );
                assert_eq!(a.abstentions, b.abstentions);
            }
        }
    }
}

#[test]
fn blocked_parallel_shard_path_matches_scalar() {
    // Enough trials to cross the thread-sharding threshold: the
    // deterministic merge must not change a single vote.
    let w = Weights::random(ModelSpec::new(vec![97, 65, 33, 10]), 4);
    let x = image(97, 8);
    let p = TrialParams::default();
    let e = NativeEngine::new(Arc::new(w), 0x5AAD);
    let a = e.infer_scalar(&x, p, 1000, 0xFFFF_FFFF_0000_0000);
    let b = e.infer(&x, p, 1000, 0xFFFF_FFFF_0000_0000);
    assert_eq!(a.counts, b.counts);
    assert_eq!(a.abstentions, b.abstentions);
}

#[test]
fn blocked_winners_match_per_trial_at_arbitrary_indices() {
    // Stronger than vote equality: each individual winner, at
    // non-consecutive stream indices (the fleet runner's sharded rows).
    let w = Weights::random(ModelSpec::new(vec![41, 19, 6]), 2);
    let x = image(41, 1);
    let p = TrialParams::default();
    let e = NativeEngine::new(Arc::new(w), 0xCAFE).with_trial_block(5);
    let z1 = e.precompute(&x);
    let indices: Vec<u64> = (0..37u64).map(|k| k * k + 7).collect();
    let blocked = e.trials_cached(&z1, p, &indices);
    for (k, &idx) in indices.iter().enumerate() {
        assert_eq!(blocked[k], e.trial_cached(&z1, p, idx), "index {idx}");
    }
}

#[test]
fn abstention_heavy_params_stay_bit_identical() {
    // A huge θ forces every race to time out: the blocked WTA runs the
    // full T-step horizon per trial, drawing exactly the scalar stream.
    let w = Weights::random(ModelSpec::new(vec![23, 17, 10, 5]), 9);
    let x = image(23, 5);
    let p = TrialParams::default().with_theta(1e6);
    let e = NativeEngine::new(Arc::new(w), 0xDEAD).with_trial_block(8);
    let a = e.infer_scalar(&x, p, 50, 0);
    let b = e.infer(&x, p, 50, 0);
    assert_eq!(a.abstentions, 50);
    assert_eq!(b.abstentions, 50);
    assert_eq!(a.counts, b.counts);
}

#[test]
fn pipeline3_blocked_stages_match_the_scalar_reference() {
    // The serving-layer leg of the contract: a 3-die pipeline (whose
    // stages now execute StageMsg::Trials blocks through the bit-packed
    // kernel) still votes bit-identically to the *scalar* unsharded
    // engine at equal (seed, trial_idx), across message batch sizes.
    let w = Weights::random(ModelSpec::new(vec![784, 40, 24, 10]), 5);
    let seed = 0xB10C7;
    let p = TrialParams::default();
    let reference = NativeEngine::new(Arc::new(w.clone()), seed);
    for spec in ["pipeline:3", "pipeline:3:b1", "pipeline:3:b64"] {
        let topo = Topology::parse(spec).unwrap();
        let opts = BuildOptions { seed, trial: p, ..Default::default() };
        let b = build(&topo, &w, &opts).unwrap();
        for id in 0..3u64 {
            let x = image(784, id);
            let want = reference.infer_scalar(&x, p, 21, trial_stream_base(seed, id));
            let got = b
                .classify(InferRequest::new(id, x).with_budget(21, 0.0))
                .unwrap();
            assert_eq!(
                got.outcome.counts, want.counts,
                "{spec}: request {id} votes diverged"
            );
            assert_eq!(got.outcome.abstentions, want.abstentions);
            assert_eq!(got.trials_used, 21);
        }
        b.shutdown();
    }
}

#[test]
fn trial_block_knob_never_changes_votes_through_a_worker_fleet() {
    // serve.trial_block is performance-only: the same deployment at
    // B ∈ {1, 64} answers bit-identically.  The fused worker fleet's
    // per-request streams are `trial_stream_base(seed, id) + t` and
    // routing is decided at submit time, so the comparison is
    // deterministic (the scheduler-batched bare `die`, whose per-trial
    // seeds depend on batch composition, is deliberately not used here).
    let w = Weights::random(ModelSpec::new(vec![784, 20, 10]), 3);
    let votes = |trial_block: usize| -> Vec<Vec<u64>> {
        let opts = BuildOptions { seed: 0x7B, trial_block, ..Default::default() };
        let b = build(&Topology::parse("2x(die)").unwrap(), &w, &opts).unwrap();
        let tickets: Vec<_> = (0..4u64)
            .map(|i| {
                b.submit(InferRequest::new(i, image(784, i)).with_budget(9, 0.0)).unwrap()
            })
            .collect();
        let out = tickets
            .into_iter()
            .map(|t| b.wait(t).unwrap().outcome.counts)
            .collect();
        b.shutdown();
        out
    };
    assert_eq!(votes(1), votes(64));
}
