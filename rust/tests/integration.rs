//! Whole-stack integration: artifacts → runtime → engines → coordinator →
//! paper-level claims (accuracy rises with trials; voting recovers the
//! software accuracy).  Skips gracefully when artifacts are missing.

use std::sync::Arc;

use raca::dataset::Dataset;
use raca::engine::{NativeEngine, TrialParams};
use raca::nn::{forward, Weights};

#[cfg(feature = "pjrt")]
use raca::coordinator::{SchedulerConfig, Server};
#[cfg(feature = "pjrt")]
use raca::engine::XlaEngine;
#[cfg(feature = "pjrt")]
use raca::runtime::ArtifactStore;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = raca::runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        None
    }
}

#[test]
fn accuracy_increases_with_trials_native() {
    let Some(dir) = artifacts() else { return };
    let w = Arc::new(Weights::load(&dir.join("weights").join("fcnn")).unwrap());
    let ds = Dataset::load(&dir.join("data").join("test")).unwrap().take(300);
    let engine = NativeEngine::new(w, 3);
    let p = TrialParams::default();
    let max_trials = 33;
    let acc_at = |k: usize, winners: &[Vec<i32>]| -> f64 {
        let hits = winners
            .iter()
            .zip(&ds.labels)
            .filter(|(ws, &l)| {
                let mut c = [0u32; 10];
                for &w in &ws[..k] {
                    if w >= 0 {
                        c[w as usize] += 1;
                    }
                }
                c.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0 as i32 == l
            })
            .count();
        hits as f64 / ds.len() as f64
    };
    let winners: Vec<Vec<i32>> = (0..ds.len())
        .map(|i| (0..max_trials).map(|t| engine.trial(ds.image(i), p, (i * 7919 + t) as u64)).collect())
        .collect();
    let a1 = acc_at(1, &winners);
    let a9 = acc_at(9, &winners);
    let a33 = acc_at(33, &winners);
    eprintln!("accuracy: 1 trial {a1:.3}, 9 trials {a9:.3}, 33 trials {a33:.3}");
    assert!(a9 >= a1 - 0.02, "voting should not hurt: {a1} → {a9}");
    assert!(a33 >= a9 - 0.02);
    assert!(a33 > 0.9, "33-trial vote accuracy too low: {a33}");
}

#[test]
fn voting_recovers_software_accuracy() {
    // The paper's headline claim: stochastic inference + majority vote
    // reaches the deterministic software accuracy.
    let Some(dir) = artifacts() else { return };
    let w = Arc::new(Weights::load(&dir.join("weights").join("fcnn")).unwrap());
    let ds = Dataset::load(&dir.join("data").join("test")).unwrap().take(300);
    let sw_hits = (0..ds.len())
        .filter(|&i| {
            let p = forward::ideal_forward(&w, ds.image(i));
            p.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0 as i32
                == ds.label(i)
        })
        .count();
    let sw_acc = sw_hits as f64 / ds.len() as f64;

    let engine = NativeEngine::new(w, 11);
    let p = TrialParams::default();
    let hits = (0..ds.len())
        .filter(|&i| engine.infer(ds.image(i), p, 31, (i * 31) as u64).prediction() == ds.label(i))
        .count();
    let raca_acc = hits as f64 / ds.len() as f64;
    eprintln!("software {sw_acc:.3} vs RACA-31-trials {raca_acc:.3}");
    assert!(
        raca_acc >= sw_acc - 0.03,
        "vote accuracy {raca_acc} should approach software {sw_acc}"
    );
}

#[cfg(feature = "pjrt")]
#[test]
fn full_stack_xla_coordinator_end_to_end() {
    let Some(dir) = artifacts() else { return };
    let ds = Dataset::load(&dir.join("data").join("test")).unwrap().take(96);
    let engine = XlaEngine::start(dir).unwrap();
    let mut cfg = SchedulerConfig::default();
    cfg.batch_size = 32;
    let server = Server::start(engine.handle(), cfg);
    let client = server.client();
    let rxs: Vec<_> = (0..ds.len())
        .map(|i| client.submit(ds.image(i).to_vec(), 15, 0.9).unwrap())
        .collect();
    let mut hits = 0;
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv().unwrap();
        assert!(r.trials_used >= 1 && r.trials_used <= 15);
        if r.prediction == ds.label(i) {
            hits += 1;
        }
    }
    let acc = hits as f64 / ds.len() as f64;
    eprintln!("end-to-end coordinator accuracy: {acc:.3}");
    assert!(acc > 0.85, "end-to-end accuracy too low: {acc}");
    let m = server.metrics().snapshot();
    assert_eq!(m.requests_completed as usize, ds.len());
    assert!(m.engine_errors == 0);
}

#[cfg(feature = "pjrt")]
#[test]
fn manifest_matches_weights_and_data() {
    let Some(dir) = artifacts() else { return };
    let store = ArtifactStore::open(&dir).unwrap();
    assert_eq!(store.manifest.layers, vec![784, 500, 300, 10]);
    assert_eq!(store.weights.spec.widths, store.manifest.layers);
    assert!(store.manifest.sigma_z > 1.7 && store.manifest.sigma_z < 1.71);
    let train = Dataset::load(&store.data_prefix("train")).unwrap();
    let test = Dataset::load(&store.data_prefix("test")).unwrap();
    assert!(train.len() >= 10 * test.len() / 10); // both non-trivial
    assert!(test.len() >= 1000);
}

#[test]
fn snr_extremes_behave_sanely() {
    // Very low SNR → near-chance; very high SNR → near-deterministic
    // argmax of the *binarized* network (not necessarily software argmax).
    let Some(dir) = artifacts() else { return };
    let w = Arc::new(Weights::load(&dir.join("weights").join("fcnn")).unwrap());
    let ds = Dataset::load(&dir.join("data").join("test")).unwrap().take(100);
    let engine = NativeEngine::new(w, 23);

    let acc = |snr: f32, trials: usize| {
        let p = TrialParams::with_snr_scale(snr);
        (0..ds.len())
            .filter(|&i| {
                engine.infer(ds.image(i), p, trials, (i * 7) as u64).prediction() == ds.label(i)
            })
            .count() as f64
            / ds.len() as f64
    };
    let low = acc(0.02, 9);
    let cal = acc(1.0, 9);
    eprintln!("snr 0.02x → {low:.3}; snr 1x → {cal:.3}");
    assert!(cal > low + 0.2, "calibrated point must beat noise floor");
    assert!(low < 0.6, "0.02x SNR should be near chance, got {low}");
}
