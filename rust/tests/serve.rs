//! Serving-API integration: the `Backend` trait end-to-end over all three
//! implementations, and the sharded-pipeline bit-parity contract against
//! `arch::{Floorplan, ShardPlan}`.
//!
//! Everything is artifact-free (models are `Weights::random` or trained
//! natively on synthetic digits), so the suite runs on a fresh checkout.

use std::sync::Arc;

use raca::arch::{Floorplan, ShardPlan};
use raca::coordinator::SchedulerConfig;
use raca::dataset::synth;
use raca::device::VariationModel;
use raca::engine::{NativeEngine, TrialParams};
use raca::fleet::{Calibrator, Fleet, RoutePolicy};
use raca::nn::{ModelSpec, TrainConfig, Weights};
use raca::serve::{
    trial_stream_base, Backend, BackendKind, InferRequest, PipelineOptions,
    PipelinedFleetBackend, ReplicatedFleetBackend, ReplicatedOptions, SingleChipBackend,
};

/// Small trained net shared across tests (3 layers, so it shards 2 or 3 ways).
fn trained() -> Weights {
    let ds = synth::generate(160, 0x7A);
    let cfg = TrainConfig { epochs: 3, lr: 0.25, seed: 0x7B };
    raca::nn::train(&ds, ModelSpec::new(vec![784, 20, 12, 10]), &cfg)
}

fn image(i: u64) -> Vec<f32> {
    (0..784).map(|j| ((j as u64 * 7 + i * 131) % 17) as f32 / 17.0).collect()
}

// ---- the tentpole contract: one trait, three deployment shapes ------------

#[test]
fn every_backend_serves_the_same_workload() {
    let w = trained();
    let seed = 0x5EED5;
    let backends: Vec<(&str, Box<dyn Backend>)> = vec![
        ("single", {
            let mut cfg = SchedulerConfig::default();
            cfg.batch_size = 16;
            Box::new(SingleChipBackend::start(
                NativeEngine::new(Arc::new(w.clone()), seed),
                cfg,
            ))
        }),
        ("replicated", {
            let fleet = Fleet::program_native(
                &w,
                3,
                &VariationModel::lognormal(0.05),
                RoutePolicy::RoundRobin,
                seed,
            );
            Box::new(ReplicatedFleetBackend::start(
                fleet,
                None,
                ReplicatedOptions::default(),
            ))
        }),
        ("pipelined", {
            Box::new(
                PipelinedFleetBackend::start(
                    &w,
                    PipelineOptions { dies: 3, seed, ..Default::default() },
                )
                .unwrap(),
            )
        }),
    ];
    for (name, b) in backends {
        let tickets: Vec<_> = (0..12u64)
            .map(|i| b.submit(InferRequest::new(i, image(i)).with_budget(6, 0.0)).unwrap())
            .collect();
        for t in tickets {
            let r = b.wait(t).unwrap();
            assert_eq!(r.trials_used, 6, "[{name}] wrong trial spend");
            assert!((-1..10).contains(&r.prediction), "[{name}] bad prediction");
            assert_eq!(r.outcome.trials, 6);
        }
        let m = b.metrics();
        assert_eq!(m.requests_completed, 12, "[{name}] completion count");
        assert!(m.trials_executed >= 72, "[{name}] trial count {m}");
        b.shutdown();
    }
}

// ---- sharded pipeline vs arch::{Floorplan, ShardPlan} ---------------------

#[test]
fn shard_plan_agrees_with_the_floorplan() {
    let spec = ModelSpec::new(vec![784, 20, 12, 10]);
    let fp = Floorplan::place(spec.clone(), 128, 8);
    for dies in [2usize, 3] {
        let plan = ShardPlan::balanced(&spec, 128, dies).unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.dies(), dies);
        // Every die's tile budget is exactly the floorplan demand of its
        // layers, and the plan covers the whole chip's tile count.
        let mut total = 0usize;
        for (d, r) in plan.ranges.iter().enumerate() {
            let want: usize = r.clone().map(|l| fp.layer_tiles(l).len()).sum();
            assert_eq!(plan.tiles_per_die[d], want, "die {d} tile demand");
            total += want;
        }
        assert_eq!(total, fp.num_tiles());
    }
}

/// The acceptance bar: a 3-layer model split across 2 and 3 dies produces
/// bit-identical votes to the unsharded `NativeEngine` at equal
/// `(seed, trial_idx)`.
#[test]
fn pipelined_votes_are_bit_identical_to_unsharded_native() {
    let w = trained();
    let seed = 0xACA5;
    let p = TrialParams::default();
    let reference = NativeEngine::new(Arc::new(w.clone()), seed);
    for dies in [2usize, 3] {
        let b = PipelinedFleetBackend::start(
            &w,
            PipelineOptions { dies, seed, params: p, ..Default::default() },
        )
        .unwrap();
        let tickets: Vec<_> = (0..8u64)
            .map(|i| b.submit(InferRequest::new(i, image(i)).with_budget(24, 0.0)).unwrap())
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let got = b.wait(t).unwrap();
            let want = reference.infer(
                &image(i as u64),
                p,
                24,
                trial_stream_base(seed, i as u64),
            );
            assert_eq!(
                got.outcome.counts, want.counts,
                "{dies}-die pipeline diverged from the unsharded engine on request {i}"
            );
            assert_eq!(got.outcome.abstentions, want.abstentions);
            assert_eq!(got.prediction, want.prediction());
        }
        b.shutdown();
    }
}

#[test]
fn pipelined_variation_draws_differ_per_die_but_stay_deterministic() {
    // Random weights give near-tied logits, so vote patterns are a
    // sensitive fingerprint of the programmed conductances.
    let w = Weights::random(ModelSpec::new(vec![784, 16, 12, 10]), 3);
    let votes = |seed: u64, variation: Option<VariationModel>| -> Vec<Vec<u64>> {
        let opts = PipelineOptions { dies: 2, seed, variation, ..Default::default() };
        let b = PipelinedFleetBackend::start(&w, opts).unwrap();
        let tickets: Vec<_> = (0..8u64)
            .map(|i| b.submit(InferRequest::new(i, image(i)).with_budget(24, 0.0)).unwrap())
            .collect();
        tickets.into_iter().map(|t| b.wait(t).unwrap().outcome.counts).collect()
    };
    let varied = Some(VariationModel::lognormal(0.08));
    // Same seed reproduces the same programmed pipeline…
    assert_eq!(votes(42, varied.clone()), votes(42, varied.clone()));
    // …a different seed programs different dies…
    assert_ne!(votes(42, varied.clone()), votes(43, varied.clone()));
    // …and a varied pipeline differs from the nominal one.
    assert_ne!(votes(42, varied), votes(42, None));
}

// ---- validation: clear errors instead of downstream panics ----------------

#[test]
fn oversharding_and_zero_configs_error_clearly() {
    let w = trained(); // 3 layers
    let err = PipelinedFleetBackend::start(
        &w,
        PipelineOptions { dies: 4, ..Default::default() },
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("3-layer") && msg.contains("4 dies"), "unhelpful error: {msg}");

    assert!(raca::config::RunConfig::parse(r#"{"fleet": {"chips": 0}}"#).is_err());
    assert!(raca::config::RunConfig::parse(r#"{"serve": {"shards": 0}}"#).is_err());
    let c = raca::config::RunConfig::parse(
        r#"{"serve": {"backend": "pipelined", "shards": 2}}"#,
    )
    .unwrap();
    assert_eq!(c.serve.backend, BackendKind::Pipelined);
}

// ---- replicated: router spread, early stop, labeled health ----------------

#[test]
fn replicated_backend_spreads_load_and_tracks_health() {
    let w = trained();
    let fleet = Fleet::program_native(
        &w,
        3,
        &VariationModel::lognormal(0.05),
        RoutePolicy::RoundRobin,
        99,
    );
    let batch = synth::generate(30, 0xF00D);
    let cal = synth::generate(12, 0xCA1);
    let b = ReplicatedFleetBackend::start(
        fleet,
        Some((cal, Calibrator::quick(3))),
        ReplicatedOptions::default(),
    );
    let tickets: Vec<_> = (0..batch.len())
        .map(|i| {
            b.submit(
                InferRequest::new(i as u64, batch.image(i).to_vec())
                    .with_budget(5, 0.0)
                    .with_label(batch.label(i)),
            )
            .unwrap()
        })
        .collect();
    for t in tickets {
        assert_eq!(b.wait(t).unwrap().trials_used, 5);
    }
    let snap = b.snapshot();
    assert_eq!(snap.aggregate().served, 30);
    assert_eq!(snap.aggregate().trials, 150);
    assert_eq!(snap.load_imbalance(), 0, "round-robin must balance: {snap}");
    // Labeled traffic reached the monitor on every chip.
    assert_eq!(snap.aggregate().labeled, 30);
    assert_eq!(b.healthy().len(), 3);
}

#[test]
fn replicated_early_stop_saves_trials() {
    // Decisive network: plant a dominant output class (the same
    // construction the coordinator's early-stop test uses).
    let mut w = Weights::random(ModelSpec::new(vec![784, 8, 10]), 1);
    let last = w.mats.len() - 1;
    for row in 0..9 {
        w.mats[last][row * 10 + 3] = 4.0;
    }
    let fleet = Fleet::program_native(
        &w,
        2,
        &VariationModel::default(),
        RoutePolicy::LeastLoaded,
        7,
    );
    let b = ReplicatedFleetBackend::start(fleet, None, ReplicatedOptions::default());
    let r = b
        .classify(InferRequest::new(1, vec![0.5; 784]).with_budget(300, 0.95))
        .unwrap();
    assert_eq!(r.prediction, 3);
    assert!(r.trials_used < 300, "expected early stop, used {}", r.trials_used);
    assert!(b.metrics().trials_saved > 0);
}
