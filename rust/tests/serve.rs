//! Serving-API integration: deployment topologies end-to-end through
//! `serve::plan` (the only way to construct backends), the sharded
//! pipeline's bit-parity contract against `arch::{Floorplan, ShardPlan}`,
//! and the `--topology` grammar.
//!
//! Everything is artifact-free (models are `Weights::random` or trained
//! natively on synthetic digits), so the suite runs on a fresh checkout.

use std::sync::Arc;

use raca::arch::{Floorplan, ShardPlan};
use raca::dataset::synth;
use raca::device::VariationModel;
use raca::engine::{NativeEngine, TrialParams};
use raca::fleet::{Calibrator, Fleet, RoutePolicy};
use raca::nn::{ModelSpec, TrainConfig, Weights};
use raca::serve::{
    build, trial_stream_base, Backend, BackendKind, BuildOptions, DeployPlan, InferRequest,
    Topology,
};

/// Small trained net shared across tests (3 layers, so it shards 2 or 3 ways).
fn trained() -> Weights {
    let ds = synth::generate(160, 0x7A);
    let cfg = TrainConfig { epochs: 3, lr: 0.25, seed: 0x7B, minibatch: 1 };
    raca::nn::train(&ds, ModelSpec::new(vec![784, 20, 12, 10]), &cfg)
}

fn image(i: u64) -> Vec<f32> {
    (0..784).map(|j| ((j as u64 * 7 + i * 131) % 17) as f32 / 17.0).collect()
}

fn topo(spec: &str) -> Topology {
    Topology::parse(spec).unwrap()
}

// ---- the tentpole contract: one trait, any deployment tree ----------------

#[test]
fn every_topology_serves_the_same_workload() {
    let w = trained();
    let seed = 0x5EED5;
    // Leaves, the fused combinator, and a replicas-of-pipelines tree —
    // all through the same compile-and-build path.
    for spec in ["die", "3x(die)", "pipeline:3", "2x(pipeline:2)", "2x(2x(die))@weighted"] {
        let opts = BuildOptions {
            seed,
            variation: if spec == "3x(die)" {
                Some(VariationModel::lognormal(0.05))
            } else {
                None
            },
            ..Default::default()
        };
        let b = build(&topo(spec), &w, &opts).unwrap();
        let tickets: Vec<_> = (0..12u64)
            .map(|i| b.submit(InferRequest::new(i, image(i)).with_budget(6, 0.0)).unwrap())
            .collect();
        for t in tickets {
            let r = b.wait(t).unwrap();
            assert_eq!(r.trials_used, 6, "[{spec}] wrong trial spend");
            assert!((-1..10).contains(&r.prediction), "[{spec}] bad prediction");
            assert_eq!(r.outcome.trials, 6);
        }
        let m = b.metrics();
        assert_eq!(m.requests_completed, 12, "[{spec}] completion count");
        assert!(m.trials_executed >= 72, "[{spec}] trial count {m}");
        b.shutdown();
    }
}

// ---- sharded pipeline vs arch::{Floorplan, ShardPlan} ---------------------

#[test]
fn shard_plan_agrees_with_the_floorplan() {
    let spec = ModelSpec::new(vec![784, 20, 12, 10]);
    let fp = Floorplan::place(spec.clone(), 128, 8);
    for dies in [2usize, 3] {
        let plan = ShardPlan::balanced(&spec, 128, dies).unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.dies(), dies);
        // Every die's tile budget is exactly the floorplan demand of its
        // layers, and the plan covers the whole chip's tile count.
        let mut total = 0usize;
        for (d, r) in plan.ranges.iter().enumerate() {
            let want: usize = r.clone().map(|l| fp.layer_tiles(l).len()).sum();
            assert_eq!(plan.tiles_per_die[d], want, "die {d} tile demand");
            total += want;
        }
        assert_eq!(total, fp.num_tiles());
    }
}

/// The PR-2 acceptance bar, preserved: a 3-layer model split across 2 and
/// 3 dies produces bit-identical votes to the unsharded `NativeEngine` at
/// equal `(seed, trial_idx)`.
#[test]
fn pipelined_votes_are_bit_identical_to_unsharded_native() {
    let w = trained();
    let seed = 0xACA5;
    let p = TrialParams::default();
    let reference = NativeEngine::new(Arc::new(w.clone()), seed);
    for spec in ["pipeline:2", "pipeline:3", "pipeline:3:b1", "pipeline:3:b64"] {
        let b = build(&topo(spec), &w, &BuildOptions { seed, ..Default::default() }).unwrap();
        let tickets: Vec<_> = (0..8u64)
            .map(|i| b.submit(InferRequest::new(i, image(i)).with_budget(24, 0.0)).unwrap())
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let got = b.wait(t).unwrap();
            let want = reference.infer(
                &image(i as u64),
                p,
                24,
                trial_stream_base(seed, i as u64),
            );
            assert_eq!(
                got.outcome.counts, want.counts,
                "[{spec}] diverged from the unsharded engine on request {i}"
            );
            assert_eq!(got.outcome.abstentions, want.abstentions);
            assert_eq!(got.prediction, want.prediction());
        }
        b.shutdown();
    }
}

/// The tentpole parity bar: with `variation: None`, a `2x(pipeline:3)`
/// tree answers with votes bit-identical to the single-chip reference —
/// the unsharded `NativeEngine` evaluated at equal `(seed, trial_idx)`,
/// i.e. `trial_stream_base(seed, request id) + t` — no matter which
/// replica the router picks, because every leaf of the tree shares the
/// deployment seed's trial stream.
#[test]
fn replicated_pipeline_votes_match_the_single_chip_reference() {
    let w = trained();
    let seed = 0x70B0;
    let p = TrialParams::default();
    let reference = NativeEngine::new(Arc::new(w.clone()), seed);
    let t = topo("2x(pipeline:3)");
    assert_eq!(t.dies(), 6);
    let b = build(&t, &w, &BuildOptions { seed, ..Default::default() }).unwrap();
    // More requests than replicas, so both pipelines definitely serve.
    let tickets: Vec<_> = (0..10u64)
        .map(|i| b.submit(InferRequest::new(i, image(i)).with_budget(24, 0.0)).unwrap())
        .collect();
    for (i, t) in tickets.into_iter().enumerate() {
        let got = b.wait(t).unwrap();
        let want = reference.infer(&image(i as u64), p, 24, trial_stream_base(seed, i as u64));
        assert_eq!(
            got.outcome.counts, want.counts,
            "2x(pipeline:3) diverged from the single-chip reference on request {i}"
        );
        assert_eq!(got.prediction, want.prediction());
    }
    assert_eq!(b.metrics().requests_completed, 10);
    b.shutdown();
}

#[test]
fn pipelined_variation_draws_differ_per_die_but_stay_deterministic() {
    // Random weights give near-tied logits, so vote patterns are a
    // sensitive fingerprint of the programmed conductances.
    let w = Weights::random(ModelSpec::new(vec![784, 16, 12, 10]), 3);
    let votes = |seed: u64, variation: Option<VariationModel>| -> Vec<Vec<u64>> {
        let opts = BuildOptions { seed, variation, ..Default::default() };
        let b = build(&topo("pipeline:2"), &w, &opts).unwrap();
        let tickets: Vec<_> = (0..8u64)
            .map(|i| b.submit(InferRequest::new(i, image(i)).with_budget(24, 0.0)).unwrap())
            .collect();
        tickets.into_iter().map(|t| b.wait(t).unwrap().outcome.counts).collect()
    };
    let varied = Some(VariationModel::lognormal(0.08));
    // Same seed reproduces the same programmed pipeline…
    assert_eq!(votes(42, varied.clone()), votes(42, varied.clone()));
    // …a different seed programs different dies…
    assert_ne!(votes(42, varied.clone()), votes(43, varied.clone()));
    // …and a varied pipeline differs from the nominal one.
    assert_ne!(votes(42, varied), votes(42, None));
}

// ---- the --topology grammar ----------------------------------------------

#[test]
fn topology_grammar_round_trips() {
    for spec in [
        "die",
        "die:physical",
        "pipeline:3",
        "pipeline:4:b16",
        "2x(die)",
        "8x(die)@weighted",
        "2x(pipeline:3)",
        "2x(2x(die)@weighted)",
        "remote:10.0.0.7:7433",
        "(remote:a:7433, remote:b:7433)@weighted",
        "(pipeline:3, remote:b:7433)",
    ] {
        let t = topo(spec);
        assert_eq!(t.to_string(), spec, "canonical spelling of '{spec}'");
        assert_eq!(topo(&t.to_string()), t, "round trip of '{spec}'");
    }
    // Case-insensitive spellings normalize to the same trees.
    assert_eq!(topo("2X(PIPELINE:3)"), topo("2x(pipeline:3)"));
    assert_eq!(topo("4x(Die)@Weighted"), topo("4x(die)@weighted"));
    // The legacy BackendKind spellings are sugar over canonical trees.
    assert_eq!(
        BackendKind::parse("Replicated").unwrap().to_topology(4, 2, RoutePolicy::RoundRobin),
        topo("4x(die)")
    );
    assert_eq!(
        BackendKind::parse("pipelined").unwrap().to_topology(4, 3, RoutePolicy::RoundRobin),
        topo("pipeline:3")
    );
}

#[test]
fn topology_compile_allocates_disjoint_chip_ids() {
    let plan = DeployPlan::compile(&topo("2x(pipeline:3)")).unwrap();
    assert_eq!(plan.total_dies, 6);
    let desc = plan.describe(&ModelSpec::new(vec![784, 20, 12, 10]));
    assert!(desc.contains("chips 0..3") && desc.contains("chips 3..6"), "{desc}");
}

// ---- validation: clear errors instead of downstream panics ----------------

#[test]
fn oversharding_and_zero_configs_error_clearly() {
    let w = trained(); // 3 layers
    let err = build(&topo("pipeline:4"), &w, &BuildOptions::default()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("3-layer") && msg.contains("4 dies"), "unhelpful error: {msg}");

    // Zero-sized nodes die at parse/validation time with the spellings
    // named, like the zero-sized fleet checks.
    assert!(Topology::parse("0x(die)").is_err());
    assert!(Topology::parse("pipeline:0").is_err());
    assert!(Topology::parse("pipeline:2:b0").is_err());
    let e = format!("{:#}", Topology::parse("warp:3").unwrap_err());
    assert!(e.contains("die") && e.contains("pipeline"), "unhelpful error: {e}");

    assert!(raca::config::RunConfig::parse(r#"{"fleet": {"chips": 0}}"#).is_err());
    assert!(raca::config::RunConfig::parse(r#"{"serve": {"shards": 0}}"#).is_err());
    assert!(raca::config::RunConfig::parse(r#"{"serve": {"topology": "0x(die)"}}"#).is_err());
    let c = raca::config::RunConfig::parse(
        r#"{"serve": {"backend": "pipelined", "shards": 2}}"#,
    )
    .unwrap();
    assert_eq!(c.serve.backend, BackendKind::Pipelined);
    assert_eq!(c.serve.tree(RoutePolicy::RoundRobin), topo("pipeline:2"));
}

// ---- replicated: router spread, early stop, labeled health ----------------

#[test]
fn replicated_backend_spreads_load_and_tracks_health() {
    let w = trained();
    let batch = synth::generate(30, 0xF00D);
    let cal = synth::generate(12, 0xCA1);
    let opts = BuildOptions {
        seed: 99,
        variation: Some(VariationModel::lognormal(0.05)),
        calibration: Some((cal, Calibrator::quick(3))),
        ..Default::default()
    };
    let b = build(&topo("3x(die)"), &w, &opts).unwrap();
    let tickets: Vec<_> = (0..batch.len())
        .map(|i| {
            b.submit(
                InferRequest::new(i as u64, batch.image(i).to_vec())
                    .with_budget(5, 0.0)
                    .with_label(batch.label(i)),
            )
            .unwrap()
        })
        .collect();
    for t in tickets {
        assert_eq!(b.wait(t).unwrap().trials_used, 5);
    }
    let m = b.metrics();
    assert_eq!(m.requests_completed, 30);
    assert_eq!(m.trials_executed, 150);
    b.shutdown();
}

#[test]
fn replicated_early_stop_saves_trials() {
    // Decisive network: plant a dominant output class (the same
    // construction the coordinator's early-stop test uses).
    let mut w = Weights::random(ModelSpec::new(vec![784, 8, 10]), 1);
    let last = w.mats.len() - 1;
    for row in 0..9 {
        w.mats[last][row * 10 + 3] = 4.0;
    }
    let b = build(
        &topo("2x(die)@least-loaded"),
        &w,
        &BuildOptions { seed: 7, ..Default::default() },
    )
    .unwrap();
    let r = b
        .classify(InferRequest::new(1, vec![0.5; 784]).with_budget(300, 0.95))
        .unwrap();
    assert_eq!(r.prediction, 3);
    assert!(r.trials_used < 300, "expected early stop, used {}", r.trials_used);
    assert!(b.metrics().trials_saved > 0);
    b.shutdown();
}

/// `lift_fleet` is the one externally-programmed path into the topology
/// runtime (`raca fleet` programs + grid-search-calibrates first).
#[test]
fn lifted_fleet_serves_with_snapshots() {
    let w = trained();
    let fleet = Fleet::program_native(
        &w,
        3,
        &VariationModel::lognormal(0.05),
        RoutePolicy::RoundRobin,
        99,
    );
    let b = raca::serve::plan::lift_fleet(
        fleet,
        None,
        raca::serve::ReplicatedOptions::default(),
    );
    let tickets: Vec<_> = (0..9u64)
        .map(|i| b.submit(InferRequest::new(i, image(i)).with_budget(4, 0.0)).unwrap())
        .collect();
    for t in tickets {
        assert_eq!(b.wait(t).unwrap().trials_used, 4);
    }
    let snap = b.snapshot();
    assert_eq!(snap.aggregate().served, 9);
    assert_eq!(snap.load_imbalance(), 0, "round-robin must balance: {snap}");
    assert_eq!(b.healthy().len(), 3);
}

// ---- the wire layer: remote:<addr> as a first-class topology leaf --------

/// The tentpole acceptance bar: `remote:die` over a loopback listener
/// votes **bit-identically** to a local `die` backend at equal
/// `(seed, trial_idx)` with `variation: None`.  Ids and images cross the
/// wire exactly; the listener derives trial streams from its own seed and
/// the unchanged request id — so the socket is invisible to the votes.
#[test]
fn remote_die_votes_bit_identical_to_local_die() {
    let w = trained();
    let seed = 0x11E7;
    let p = TrialParams::default();

    // Host: a single die behind a loopback listener (port 0 = ephemeral).
    let host = build(&topo("die"), &w, &BuildOptions { seed, ..Default::default() }).unwrap();
    let server = raca::serve::net::serve(host, "127.0.0.1:0").unwrap();

    // Client: the same die reached through the remote leaf.  The client's
    // own seed is deliberately different — only the listener's governs.
    let remote_spec = format!("remote:{}", server.addr());
    let t = Topology::parse(&remote_spec).unwrap();
    assert_eq!(t.dies(), 0, "a remote leaf owns no local dies");
    let remote =
        build(&t, &w, &BuildOptions { seed: 0xDEAD, ..Default::default() }).unwrap();

    // Local twin + unsharded reference.
    let local = build(&topo("die"), &w, &BuildOptions { seed, ..Default::default() }).unwrap();
    let reference = NativeEngine::new(Arc::new(w.clone()), seed);

    for i in 0..6u64 {
        let img = image(i);
        let got = remote
            .classify(InferRequest::new(i, img.clone()).with_budget(18, 0.0))
            .unwrap();
        let want_local = local
            .classify(InferRequest::new(i, img.clone()).with_budget(18, 0.0))
            .unwrap();
        let want = reference.infer(&img, p, 18, trial_stream_base(seed, i));
        assert_eq!(
            got.outcome.counts, want.counts,
            "remote:die diverged from the unsharded engine on request {i}"
        );
        assert_eq!(got.outcome.counts, want_local.outcome.counts);
        assert_eq!(got.outcome.abstentions, want.abstentions);
        assert_eq!(got.prediction, want.prediction());
        assert_eq!(got.trials_used, 18);
        assert_eq!(got.id, i);
    }

    // metrics() crosses the wire: the listener answers for its backend.
    let m = remote.metrics();
    assert_eq!(m.requests_completed, 6, "remote metrics snapshot: {m}");
    assert!(m.trials_executed >= 6 * 18);
    assert_eq!(server.sessions_started(), 1);

    remote.shutdown();
    local.shutdown();
    drop(server);
}

/// The `2x(remote:pipeline:2)` shape: two loopback listeners each hosting
/// a `pipeline:2`, routed by a group tree.  Pipeline parity makes the
/// whole thing shape-independent: whichever host serves a request, its
/// votes match the unsharded reference at the *listeners'* shared seed.
#[test]
fn group_of_remote_pipelines_matches_reference_over_two_listeners() {
    let w = trained();
    let seed = 0xD157;
    let p = TrialParams::default();
    let mk_listener = || {
        let b =
            build(&topo("pipeline:2"), &w, &BuildOptions { seed, ..Default::default() })
                .unwrap();
        raca::serve::net::serve(b, "127.0.0.1:0").unwrap()
    };
    let s1 = mk_listener();
    let s2 = mk_listener();

    let spec = format!("(remote:{}, remote:{})", s1.addr(), s2.addr());
    let t = Topology::parse(&spec).unwrap();
    assert_eq!(t.to_string(), spec, "canonical spelling");
    let b = build(&t, &w, &BuildOptions::default()).unwrap();

    let reference = NativeEngine::new(Arc::new(w.clone()), seed);
    // More requests than hosts: both listeners definitely serve.
    let tickets: Vec<_> = (0..10u64)
        .map(|i| b.submit(InferRequest::new(i, image(i)).with_budget(16, 0.0)).unwrap())
        .collect();
    for (i, ticket) in tickets.into_iter().enumerate() {
        let got = b.wait(ticket).unwrap();
        let want = reference.infer(&image(i as u64), p, 16, trial_stream_base(seed, i as u64));
        assert_eq!(
            got.outcome.counts, want.counts,
            "request {i} diverged from the reference (whichever host served it)"
        );
        assert_eq!(got.prediction, want.prediction());
    }
    assert_eq!(b.metrics().requests_completed, 10);
    assert_eq!(s1.sessions_started() + s2.sessions_started(), 2);
    b.shutdown();
}

/// Version mismatches and malformed frames produce an `Error` frame and a
/// closed connection — never a hang, never a crash of the listener.
#[test]
fn listener_rejects_version_mismatch_and_malformed_frames() {
    use raca::serve::net::{WireMsg, PROTOCOL_VERSION};
    use raca::util::json;

    let w = trained();
    let host = build(&topo("die"), &w, &BuildOptions::default()).unwrap();
    let server = raca::serve::net::serve(host, "127.0.0.1:0").unwrap();

    // Peer speaking a future protocol: refused with an error frame.
    {
        let mut s = std::net::TcpStream::connect(server.addr()).unwrap();
        let hello = json::read_frame(&mut s).unwrap().expect("server speaks first");
        let WireMsg::Hello { version, .. } = raca::serve::net::wire::decode(&hello).unwrap()
        else {
            panic!("expected hello")
        };
        assert_eq!(version, PROTOCOL_VERSION);
        json::write_frame(
            &mut s,
            &raca::serve::net::wire::encode(&WireMsg::Hello {
                version: PROTOCOL_VERSION + 9,
                bundles: Vec::new(),
            }),
        )
        .unwrap();
        let err = json::read_frame(&mut s).unwrap().expect("error frame");
        let WireMsg::Error { msg, .. } = raca::serve::net::wire::decode(&err).unwrap() else {
            panic!("expected error frame")
        };
        assert!(msg.contains("version mismatch"), "{msg}");
        // …and the server closes the session.
        assert_eq!(json::read_frame(&mut s).unwrap(), None);
    }

    // Valid handshake, then a garbage frame: per the codec contract the
    // session reports the malformed frame and closes.
    {
        let mut s = std::net::TcpStream::connect(server.addr()).unwrap();
        let _hello = json::read_frame(&mut s).unwrap().expect("server speaks first");
        json::write_frame(
            &mut s,
            &raca::serve::net::wire::encode(&WireMsg::Hello {
                version: PROTOCOL_VERSION,
                bundles: Vec::new(),
            }),
        )
        .unwrap();
        // A frame that parses as JSON but not as a protocol message…
        json::write_frame(&mut s, &raca::util::json::Json::Str("junk".into())).unwrap();
        let err = json::read_frame(&mut s).unwrap().expect("error frame");
        assert!(matches!(
            raca::serve::net::wire::decode(&err).unwrap(),
            WireMsg::Error { .. }
        ));
        assert_eq!(json::read_frame(&mut s).unwrap(), None, "session closed");
    }

    // The listener survived both bad sessions and still serves real ones.
    let remote = raca::serve::RemoteBackend::connect(&server.addr().to_string()).unwrap();
    let r = remote
        .classify(InferRequest::new(1, image(1)).with_budget(4, 0.0))
        .unwrap();
    assert_eq!(r.trials_used, 4);
    Box::new(remote).shutdown();
}

/// Duplicate in-flight ids are a per-request error, not a session or
/// listener failure (the client refuses before the frame is even sent).
#[test]
fn duplicate_in_flight_ids_fail_cleanly_over_the_wire() {
    let w = trained();
    let host = build(&topo("die"), &w, &BuildOptions::default()).unwrap();
    let server = raca::serve::net::serve(host, "127.0.0.1:0").unwrap();
    let remote = raca::serve::RemoteBackend::connect(&server.addr().to_string()).unwrap();
    // A big budget keeps request 7 in flight while we reuse its id.
    let slow = remote
        .submit(InferRequest::new(7, image(0)).with_budget(200, 0.0))
        .unwrap();
    let dup = remote.submit(InferRequest::new(7, image(1)).with_budget(4, 0.0));
    assert!(dup.is_err(), "client-side duplicate detection");
    let r = remote.wait(slow).unwrap();
    assert_eq!(r.trials_used, 200);
    Box::new(remote).shutdown();
}

// ---- telemetry: metrics trees, journals, failure eviction -----------------

/// `metrics_tree()` mirrors the deployment tree: a `2x(pipeline:2)` build
/// yields root → 2 pipelines → 2 stages each (7 nodes), with the router's
/// per-child health notes and caller traffic visible at every level.
#[test]
fn metrics_tree_mirrors_the_replicated_pipeline_topology() {
    let w = trained();
    let b = build(
        &topo("2x(pipeline:2)"),
        &w,
        &BuildOptions { seed: 0x0B5E, ..Default::default() },
    )
    .unwrap();
    let tickets: Vec<_> = (0..12u64)
        .map(|i| b.submit(InferRequest::new(i, image(i)).with_budget(6, 0.0)).unwrap())
        .collect();
    for t in tickets {
        b.wait(t).unwrap();
    }

    let tree = b.metrics_tree();
    assert!(tree.label.starts_with("replicate ×2"), "root label: {}", tree.label);
    assert_eq!(tree.num_nodes(), 7, "tree:\n{}", tree.render());
    assert_eq!(tree.snapshot.requests_completed, 12);
    assert_eq!(tree.children.len(), 2);
    let mut child_completed = 0;
    for pipe in &tree.children {
        assert!(pipe.label.starts_with("pipeline:2"), "child label: {}", pipe.label);
        assert_eq!(pipe.children.len(), 2, "stages under {}", pipe.label);
        for (d, stage) in pipe.children.iter().enumerate() {
            assert!(
                stage.label.starts_with(&format!("stage{d}")),
                "stage label: {}",
                stage.label
            );
        }
        // Router-annotated health notes on every routed child.
        assert_eq!(pipe.notes.evicted, Some(false));
        assert!(pipe.notes.weight.is_some(), "missing routing weight on {}", pipe.label);
        child_completed += pipe.snapshot.requests_completed;
    }
    assert_eq!(child_completed, 12, "round-robin split must cover all requests");
    // The rendering `raca top` prints: one line per node with p50/p99.
    let txt = tree.render();
    assert!(txt.contains("p50") && txt.contains("p99"), "render:\n{txt}");
    assert_eq!(txt.lines().count(), 7, "one line per node:\n{txt}");

    // The shared journal saw the traffic (admissions at the router level).
    let journal = b.journal().expect("built trees share a journal");
    let events = journal.tail(journal.capacity());
    use raca::telemetry::EventKind;
    assert!(events.iter().any(|e| e.kind == EventKind::RequestAdmitted));
    assert!(events.iter().any(|e| e.kind == EventKind::RequestCompleted));
    b.shutdown();
}

/// The acceptance-bar shape for `raca top <addr>`: a listener hosting
/// `2x(pipeline:2)` answers `MetricsReq { tree: true }` with its whole
/// 7-node tree plus recent journal events — over the wire, one exchange.
#[test]
fn metrics_tree_crosses_the_wire_with_journal_events() {
    let w = trained();
    let host = build(
        &topo("2x(pipeline:2)"),
        &w,
        &BuildOptions { seed: 0x70B, ..Default::default() },
    )
    .unwrap();
    let server = raca::serve::net::serve(host, "127.0.0.1:0").unwrap();
    let remote = raca::serve::RemoteBackend::connect(&server.addr().to_string()).unwrap();

    for i in 0..8u64 {
        let r = remote.classify(InferRequest::new(i, image(i)).with_budget(5, 0.0)).unwrap();
        assert_eq!(r.trials_used, 5);
    }

    let (tree, events) = remote.remote_telemetry().expect("live peer answers the tree");
    assert!(tree.label.starts_with("replicate ×2"), "peer root: {}", tree.label);
    assert_eq!(tree.num_nodes(), 7, "peer tree:\n{}", tree.render());
    assert_eq!(tree.snapshot.requests_completed, 8);
    for pipe in &tree.children {
        assert_eq!(pipe.notes.evicted, Some(false), "health notes cross the wire");
    }
    // Journal events ride along with the tree answer.
    use raca::telemetry::EventKind;
    assert!(!events.is_empty(), "hosted deployments journal their traffic");
    assert!(events.iter().any(|e| e.kind == EventKind::RequestCompleted));

    // Flat metrics (the v1 question) still work against the same session.
    let m = remote.metrics();
    assert_eq!(m.requests_completed, 8);
    Box::new(remote).shutdown();
}

/// A mixed `(remote:die, die)` group names both leaves distinctly and
/// grafts the remote peer's subtree under its `remote:<addr>` node.
#[test]
fn metrics_tree_of_a_mixed_group_names_remote_and_local_leaves() {
    let w = trained();
    let seed = 0x31F;
    let host = build(&topo("die"), &w, &BuildOptions { seed, ..Default::default() }).unwrap();
    let server = raca::serve::net::serve(host, "127.0.0.1:0").unwrap();
    let spec = format!("(remote:{}, die)", server.addr());
    let b = build(
        &Topology::parse(&spec).unwrap(),
        &w,
        &BuildOptions { seed, ..Default::default() },
    )
    .unwrap();
    let tickets: Vec<_> = (0..8u64)
        .map(|i| b.submit(InferRequest::new(i, image(i)).with_budget(4, 0.0)).unwrap())
        .collect();
    for t in tickets {
        b.wait(t).unwrap();
    }

    let tree = b.metrics_tree();
    assert!(tree.label.starts_with("group ×2"), "root label: {}", tree.label);
    assert_eq!(tree.children.len(), 2);
    assert_eq!(tree.children[0].label, format!("remote:{}", server.addr()));
    // The remote node carries the peer's whole subtree (its hosted die).
    assert_eq!(tree.children[0].children.len(), 1, "tree:\n{}", tree.render());
    assert_eq!(tree.children[0].children[0].label, "die#0");
    assert_eq!(tree.children[1].label, "die#0");
    // Both group members served under round-robin.
    assert!(tree.children[1].snapshot.requests_completed > 0);
    assert!(tree.children[0].children[0].snapshot.requests_completed > 0);
    b.shutdown();
}

/// Back-compat: a v1 peer (protocol 1 hello, answers only flat `Metrics`)
/// still yields a tree — wrapped as a single `peer` node — and once the
/// session dies, telemetry answers fast from the stale-tagged cache
/// instead of stalling on a wire that will never answer.
#[test]
fn v1_flat_metrics_peer_wraps_into_a_tree_and_goes_stale_on_death() {
    use raca::serve::net::{wire, WireMsg};
    use raca::util::json;

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let fake = std::thread::spawn(move || {
        let (s, _) = listener.accept().unwrap();
        let mut w = s.try_clone().unwrap();
        let mut r = std::io::BufReader::new(s);
        // A v1 listener: old protocol revision in the hello…
        json::write_frame(
            &mut w,
            &wire::encode(&WireMsg::Hello { version: 1, bundles: Vec::new() }),
        )
        .unwrap();
        let _ = json::read_frame(&mut r).unwrap().expect("client hello");
        // …that answers exactly one metrics request with the flat v1
        // shape (a real v1 decoder ignores the unknown `tree` field),
        // then drops the connection — the session death.
        let j = json::read_frame(&mut r).unwrap().expect("metrics request");
        assert!(matches!(wire::decode(&j), Ok(WireMsg::MetricsReq { .. })));
        let m = raca::coordinator::MetricsSnapshot {
            requests_admitted: 5,
            requests_completed: 4,
            trials_executed: 40,
            batches_executed: 6,
            rows_packed: 12,
            trials_saved: 3,
            engine_errors: 0,
            latency_p50_us: 150,
            latency_p99_us: 900,
        };
        json::write_frame(&mut w, &wire::encode(&WireMsg::Metrics(m))).unwrap();
    });

    let remote = raca::serve::RemoteBackend::connect(&addr.to_string()).unwrap();
    let tree = remote.metrics_tree();
    assert_eq!(tree.label, format!("remote:{addr}"));
    assert!(!tree.notes.stale);
    assert_eq!(tree.children.len(), 1, "tree:\n{}", tree.render());
    assert_eq!(tree.children[0].label, "peer", "flat answer wraps as one node");
    assert_eq!(tree.children[0].snapshot.requests_completed, 4);
    assert_eq!(tree.children[0].snapshot.latency_p99_us, 900);
    fake.join().unwrap();

    // The peer hung up; wait for the reader to notice.
    let t0 = std::time::Instant::now();
    while !remote.is_dead() {
        assert!(t0.elapsed() < std::time::Duration::from_secs(5), "reader never died");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }

    // Dead session: telemetry answers immediately (no 10 s wire timeout)
    // from the cached copy, stale-tagged.
    let t1 = std::time::Instant::now();
    let tree = remote.metrics_tree();
    assert!(t1.elapsed() < std::time::Duration::from_secs(5), "must fail fast when dead");
    assert_eq!(tree.children.len(), 1);
    assert!(tree.children[0].notes.stale, "cached peer copy is stale-tagged");
    assert_eq!(tree.children[0].snapshot.requests_completed, 4, "…but still served");

    // And submits fail in-band, immediately.
    let r = remote.classify(InferRequest::new(9, image(9)).with_budget(4, 0.0));
    assert!(r.is_err(), "dead session must refuse work");
    Box::new(remote).shutdown();
}

/// A peer that completes the TCP handshake (the OS backlog does that
/// without any `accept`) but never speaks the protocol hello must fail
/// `connect` in bounded time — not hang the deploying process forever.
#[test]
fn connect_fails_fast_on_a_silent_peer() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let t0 = std::time::Instant::now();
    let r = raca::serve::RemoteBackend::connect(&addr.to_string());
    assert!(r.is_err(), "a silent peer must not yield a session");
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(10),
        "handshake must time out, took {:?}",
        t0.elapsed()
    );
    let msg = format!("{:#}", r.unwrap_err());
    assert!(msg.contains("raca listener"), "unhelpful error: {msg}");
    drop(listener);
}

/// A telemetry ask the peer never answers must give up in bounded time
/// *and* withdraw its waiter: the next ask has to receive the answer
/// written for it, not inherit a reply queued behind a ghost.
#[test]
fn timed_out_telemetry_waiter_does_not_consume_the_next_answer() {
    use raca::serve::net::{wire, WireMsg};
    use raca::util::json;

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let fake = std::thread::spawn(move || {
        let (s, _) = listener.accept().unwrap();
        let mut wr = s.try_clone().unwrap();
        let mut rd = std::io::BufReader::new(s);
        json::write_frame(
            &mut wr,
            &wire::encode(&WireMsg::Hello {
                version: wire::PROTOCOL_VERSION,
                bundles: Vec::new(),
            }),
        )
        .unwrap();
        let _ = json::read_frame(&mut rd).unwrap().expect("client hello");
        // First telemetry ask: swallowed — the client must time out.
        let q1 = json::read_frame(&mut rd).unwrap().expect("first metrics request");
        assert!(matches!(wire::decode(&q1), Ok(WireMsg::MetricsReq { tree: true })));
        // Second ask: answered.  If the timed-out waiter were still
        // queued, it — not the live caller — would receive this.
        let q2 = json::read_frame(&mut rd).unwrap().expect("second metrics request");
        assert!(matches!(wire::decode(&q2), Ok(WireMsg::MetricsReq { tree: true })));
        let m = raca::coordinator::MetricsSnapshot {
            requests_admitted: 77,
            requests_completed: 77,
            trials_executed: 770,
            batches_executed: 9,
            rows_packed: 0,
            trials_saved: 0,
            engine_errors: 0,
            latency_p50_us: 100,
            latency_p99_us: 400,
        };
        let tree = raca::telemetry::MetricsTree::leaf("peer-die", m);
        json::write_frame(
            &mut wr,
            &wire::encode(&WireMsg::MetricsTree { tree, events: Vec::new() }),
        )
        .unwrap();
        // Keep the session open until the client hangs up.
        let _ = json::read_frame(&mut rd);
    });

    let remote = raca::serve::RemoteBackend::connect(&addr.to_string()).unwrap();
    let t0 = std::time::Instant::now();
    assert!(remote.remote_telemetry().is_none(), "unanswered ask must yield None");
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(8),
        "bounded wait, took {:?}",
        t0.elapsed()
    );
    let (tree, _events) =
        remote.remote_telemetry().expect("the second ask owns the answer");
    assert_eq!(tree.label, "peer-die");
    assert_eq!(tree.snapshot.requests_completed, 77, "answer misrouted to a stale waiter?");
    Box::new(remote).shutdown();
    fake.join().unwrap();
}

/// Waiter and ticket hygiene across a reconnect (the PR-7 discipline,
/// extended over session death): every in-flight request completes
/// exactly once on its own channel even when its frames crossed two
/// sessions, the pending map drains, and the telemetry waiter queue
/// comes back aligned — each ask receives the answer written for it,
/// with no ghost waiters left from the killed session.
#[test]
fn reconnect_completes_each_ticket_once_and_leaks_no_waiters() {
    let w = trained();
    let seed = 0x60D;
    const TRIALS: u32 = 12_000;
    let host = build(&topo("die"), &w, &BuildOptions { seed, ..Default::default() }).unwrap();
    let server = raca::serve::net::serve(host, "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();

    let remote = raca::serve::RemoteBackend::connect(&addr).unwrap();
    // One private channel per request, so "exactly once" is per-channel.
    let channels: Vec<_> = (0..4u64)
        .map(|i| {
            let (tx, rx) = std::sync::mpsc::channel();
            remote
                .submit_to(
                    InferRequest::new(i, image(i))
                        .with_budget(TRIALS, 0.0)
                        .with_deadline_ms(60_000),
                    tx,
                )
                .unwrap();
            rx
        })
        .collect();

    server.kill();
    let revived = raca::serve::net::serve(
        build(&topo("die"), &w, &BuildOptions { seed, ..Default::default() }).unwrap(),
        &addr,
    )
    .unwrap();

    for (i, rx) in channels.iter().enumerate() {
        let r = rx
            .recv_timeout(std::time::Duration::from_secs(60))
            .unwrap_or_else(|_| panic!("request {i} hung across the reconnect"));
        assert_eq!(r.id, i as u64);
        assert!(r.error.is_none(), "request {i} failed in-band: {:?}", r.error);
        assert_eq!(r.trials_used, TRIALS);
    }
    // No double-complete: a duplicate answer to a resubmitted frame must
    // be swallowed by the pending-map dedup, not forwarded.
    std::thread::sleep(std::time::Duration::from_millis(200));
    for (i, rx) in channels.iter().enumerate() {
        assert!(rx.try_recv().is_err(), "request {i} completed twice");
    }
    assert_eq!(remote.in_flight(), 0, "pending map must drain after completion");

    // Telemetry waiters did not leak across the session swap: two asks
    // in a row each consume exactly their own answer.
    for ask in 0..2 {
        let (tree, _events) = remote
            .remote_telemetry()
            .unwrap_or_else(|| panic!("telemetry ask {ask} after reconnect went unanswered"));
        assert!(!tree.label.is_empty());
    }
    Box::new(remote).shutdown();
    drop(revived);
}

// ---- the registry: signed bundles behind remote:@ leaves ------------------

/// Publish the given model into a fresh registry under `dir`, signed with
/// `key`; returns the bundle id.
fn publish_into(dir: &std::path::Path, w: &Weights, key: &raca::registry::SigningKey) -> String {
    std::fs::create_dir_all(dir.join("weights")).unwrap();
    let prefix = dir.join("weights").join("fcnn");
    w.save(&prefix).unwrap();
    let calib = dir.join("calib.json");
    std::fs::write(&calib, br#"{"theta":3.0,"sigma_z":1.702}"#).unwrap();
    let store = raca::registry::Store::open(dir);
    let (id, _env) = raca::registry::publish_local(&store, key, &prefix, &calib, None).unwrap();
    id
}

/// The registry acceptance bar: a `remote:@<registry>/<bundle>` leaf —
/// advertised in the listener's hello, manifest fetched and verified
/// under the shared deployment key at build time — votes bit-identically
/// to a local `die` at equal `(seed, trial_idx)`.  The resolution is
/// journaled (`bundle_resolved`) and the bundle id rides the telemetry
/// tree, which is what `raca top` renders on the leaf.
#[test]
fn registry_resolved_remote_die_matches_local_die_bit_for_bit() {
    use raca::registry::{key_path, SigningKey, Store};
    use raca::telemetry::EventKind;

    let w = trained();
    let seed = 0x9E61;
    let p = TrialParams::default();
    let base = std::env::temp_dir().join(format!("raca-reg-parity-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let (host_dir, client_dir) = (base.join("host"), base.join("client"));
    std::fs::create_dir_all(&host_dir).unwrap();
    std::fs::create_dir_all(&client_dir).unwrap();

    // One deployment key, copied to both hosts (the shared-secret model).
    let key = SigningKey::load_or_generate(&host_dir).unwrap();
    key.save(&key_path(&client_dir)).unwrap();
    let bundle = publish_into(&host_dir, &w, &key);

    // Host: a die behind a registry-carrying listener.
    let host = build(&topo("die"), &w, &BuildOptions { seed, ..Default::default() }).unwrap();
    let server = raca::serve::net::serve_registry(
        host,
        "127.0.0.1:0",
        raca::serve::net::RegistryConfig { store: Store::open(&host_dir), key },
    )
    .unwrap();

    // Client: the registry-resolved leaf.  Its own seed is deliberately
    // different — only the listener's governs the trial streams.
    let spec = format!("remote:@{}/{bundle}", server.addr());
    let remote = build(
        &Topology::parse(&spec).unwrap(),
        &w,
        &BuildOptions {
            seed: 0xDEAD,
            artifact_dir: Some(client_dir.clone()),
            ..Default::default()
        },
    )
    .unwrap();

    let local = build(&topo("die"), &w, &BuildOptions { seed, ..Default::default() }).unwrap();
    let reference = NativeEngine::new(Arc::new(w.clone()), seed);
    for i in 0..6u64 {
        let img = image(i);
        let got = remote
            .classify(InferRequest::new(i, img.clone()).with_budget(14, 0.0))
            .unwrap();
        let want = reference.infer(&img, p, 14, trial_stream_base(seed, i));
        let want_local = local
            .classify(InferRequest::new(i, img).with_budget(14, 0.0))
            .unwrap();
        assert_eq!(
            got.outcome.counts, want.counts,
            "remote:@ leaf diverged from the unsharded engine on request {i}"
        );
        assert_eq!(got.outcome.counts, want_local.outcome.counts);
        assert_eq!(got.prediction, want.prediction());
        assert_eq!(got.trials_used, 14);
    }

    let journal = remote.journal().expect("built trees share a journal");
    let events = journal.tail(journal.capacity());
    assert!(
        events
            .iter()
            .any(|e| e.kind == EventKind::BundleResolved && e.detail.contains(&bundle)),
        "no bundle_resolved event; journal:\n{}",
        journal.to_json_lines()
    );
    let tree = remote.metrics_tree();
    assert_eq!(tree.notes.bundle.as_deref(), Some(bundle.as_str()));
    assert!(
        tree.render().contains(&format!("bundle {}", &bundle[..12])),
        "render:\n{}",
        tree.render()
    );

    remote.shutdown();
    local.shutdown();
    drop(server);
    let _ = std::fs::remove_dir_all(&base);
}

/// Rejection paths: a bundle the registry never advertised, a client key
/// that never signed the manifest, and a byte-flipped stored blob.  Every
/// refusal is an error at build time — never a silently-bound leaf — and
/// the listener journals `manifest_rejected` when its own store fails
/// re-verification.
#[test]
fn tampered_blobs_and_foreign_keys_are_refused_with_journal_events() {
    use raca::registry::{key_path, SigningKey, Store};
    use raca::telemetry::EventKind;

    let w = trained();
    let base = std::env::temp_dir().join(format!("raca-reg-tamper-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let (host_dir, good_dir, rogue_dir) =
        (base.join("host"), base.join("good"), base.join("rogue"));
    for d in [&host_dir, &good_dir, &rogue_dir] {
        std::fs::create_dir_all(d).unwrap();
    }
    let key = SigningKey::load_or_generate(&host_dir).unwrap();
    key.save(&key_path(&good_dir)).unwrap();
    SigningKey::generate().save(&key_path(&rogue_dir)).unwrap();
    let bundle = publish_into(&host_dir, &w, &key);

    let host = build(&topo("die"), &w, &BuildOptions::default()).unwrap();
    let host_journal = host.journal().expect("hosted deployments journal");
    let server = raca::serve::net::serve_registry(
        host,
        "127.0.0.1:0",
        raca::serve::net::RegistryConfig { store: Store::open(&host_dir), key },
    )
    .unwrap();
    let spec = format!("remote:@{}/{bundle}", server.addr());

    // An id the listener never advertised is refused before any fetch.
    let absent = "f".repeat(64);
    let e = build(
        &Topology::parse(&format!("remote:@{}/{absent}", server.addr())).unwrap(),
        &w,
        &BuildOptions { artifact_dir: Some(good_dir.clone()), ..Default::default() },
    )
    .unwrap_err();
    assert!(format!("{e:#}").contains("does not advertise"), "unhelpful: {e:#}");

    // A client whose deployment key never signed the manifest rejects the
    // envelope — nothing a registry says is taken on faith.
    let e = build(
        &Topology::parse(&spec).unwrap(),
        &w,
        &BuildOptions { artifact_dir: Some(rogue_dir.clone()), ..Default::default() },
    )
    .unwrap_err();
    assert!(format!("{e:#}").contains("unknown key"), "unhelpful: {e:#}");

    // Byte-flip a stored artifact: the listener refuses to vouch (the
    // fetch re-hashes every referenced blob), journals the rejection, and
    // the good-key client's build fails instead of binding the leaf.
    let env = Store::open(&host_dir).get_manifest(&bundle).unwrap();
    let victim = host_dir.join("registry").join("blobs").join(&env.manifest.weights_bin);
    let mut bytes = std::fs::read(&victim).unwrap();
    bytes[0] ^= 0x01;
    std::fs::write(&victim, &bytes).unwrap();
    let e = build(
        &Topology::parse(&spec).unwrap(),
        &w,
        &BuildOptions { artifact_dir: Some(good_dir.clone()), ..Default::default() },
    )
    .unwrap_err();
    assert!(format!("{e:#}").contains("refused"), "unhelpful: {e:#}");
    let events = host_journal.tail(host_journal.capacity());
    assert!(
        events.iter().any(|e| e.kind == EventKind::ManifestRejected),
        "listener never journaled the rejection:\n{}",
        host_journal.to_json_lines()
    );

    drop(server);
    let _ = std::fs::remove_dir_all(&base);
}

/// The PR's acceptance bar: kill one child of a two-remote group and the
/// health monitor evicts it — a `health_evict` event lands in the shared
/// journal, the tree shows `EVICTED`, and traffic routes away cleanly.
#[test]
fn dead_remote_child_is_evicted_and_routed_around() {
    use raca::serve::net::{wire, WireMsg};
    use raca::telemetry::EventKind;
    use raca::util::json;

    let w = trained();
    let seed = 0xDEAD5;
    // Child A: a real listener hosting a die.
    let host = build(&topo("die"), &w, &BuildOptions { seed, ..Default::default() }).unwrap();
    let alive = raca::serve::net::serve(host, "127.0.0.1:0").unwrap();

    // Child B: a listener killed right after the handshake — the in-test
    // stand-in for a host that died under the router.
    let doomed = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let doomed_addr = doomed.local_addr().unwrap();
    let killer = std::thread::spawn(move || {
        let (s, _) = doomed.accept().unwrap();
        let mut wr = s.try_clone().unwrap();
        let mut rd = std::io::BufReader::new(s);
        json::write_frame(
            &mut wr,
            &wire::encode(&WireMsg::Hello {
                version: wire::PROTOCOL_VERSION,
                bundles: Vec::new(),
            }),
        )
        .unwrap();
        let _ = json::read_frame(&mut rd).unwrap().expect("client hello");
        // connection dropped here — the kill
    });

    let spec = format!("(remote:{doomed_addr}, remote:{})", alive.addr());
    let b = build(
        &Topology::parse(&spec).unwrap(),
        &w,
        &BuildOptions { reweigh_every: 8, ..Default::default() },
    )
    .unwrap();
    killer.join().unwrap();

    // Sequential traffic: round-robin sends every other request into the
    // dead child until its failure streak crosses the eviction bar
    // (min_samples labeled observations, accuracy below the floor).
    let (mut ok, mut failed) = (0usize, 0usize);
    for i in 0..60u64 {
        match b.classify(InferRequest::new(i, image(i)).with_budget(4, 0.0)) {
            Ok(r) => {
                assert_eq!(r.trials_used, 4);
                ok += 1;
            }
            Err(_) => failed += 1,
        }
    }
    assert!(ok > 0 && failed > 0, "both children must have been tried: ok={ok} failed={failed}");

    // The eviction is journaled against the dead child's label…
    let journal = b.journal().expect("router journal");
    let events = journal.tail(journal.capacity());
    let dead_label = format!("remote:{doomed_addr}");
    assert!(
        events
            .iter()
            .any(|e| e.kind == EventKind::HealthEvict && e.node == dead_label),
        "no eviction event for {dead_label}; journal:\n{}",
        journal.to_json_lines()
    );
    assert!(events.iter().any(|e| e.kind == EventKind::RequestFailed && e.node == dead_label));

    // …and visible in the tree: evicted flag, error count, stale leaf.
    let tree = b.metrics_tree();
    let dead = tree.children.iter().find(|c| c.label == dead_label).expect("dead child node");
    assert_eq!(dead.notes.evicted, Some(true), "tree:\n{}", tree.render());
    assert!(dead.notes.errors.unwrap_or(0) > 0);
    let alive_node = tree
        .children
        .iter()
        .find(|c| c.label == format!("remote:{}", alive.addr()))
        .expect("alive child node");
    assert_eq!(alive_node.notes.evicted, Some(false));
    assert!(tree.render().contains("EVICTED"), "render:\n{}", tree.render());

    // Routed away: with the dead child evicted, traffic flows clean.
    for i in 100..110u64 {
        let r = b.classify(InferRequest::new(i, image(i)).with_budget(4, 0.0)).unwrap();
        assert_eq!(r.trials_used, 4);
    }
    b.shutdown();
}
