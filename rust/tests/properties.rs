//! Property-based tests (randomized, seeded, shrink-free) over coordinator
//! and substrate invariants — the proptest-style suite (no proptest crate
//! in the offline vendor set, so generation is explicit xoshiro-driven).
//!
//! Each property runs across many random cases; failures print the case
//! seed so they reproduce exactly.

use raca::coordinator::batcher::Batcher;
use raca::coordinator::{InferRequest, Scheduler, SchedulerConfig};
use raca::crossbar::{CrossbarArray, ReadMode, WeightMapping};
use raca::device::noise::NoiseParams;
use raca::device::variation::VariationModel;
use raca::engine::{NativeEngine, TrialParams};
use raca::neuron::WtaOutcome;
use raca::nn::{forward, ModelSpec, Weights};
use raca::stats::{GaussianSource, Rng};
use raca::util::json::Json;

const CASES: usize = 60;

// ---------------------------------------------------------------------------
// Batcher invariants (DESIGN: routing/batching state)
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_never_overpacks_and_respects_budgets() {
    for case in 0..CASES {
        let mut rng = Rng::new(case as u64);
        let mut b = Batcher::new();
        let n_req = 1 + rng.below(12) as usize;
        let mut budgets = std::collections::HashMap::new();
        for id in 0..n_req as u64 {
            let budget = 1 + rng.below(40) as u32;
            budgets.insert(id, budget);
            b.admit(id, budget);
        }
        let batch_size = 1 + rng.below(48) as usize;
        let p = b.pack(batch_size);
        assert!(p.len() <= batch_size, "case {case}: overpacked");
        let mut per: std::collections::HashMap<u64, u32> = Default::default();
        for &id in &p.rows {
            assert!(budgets.contains_key(&id), "case {case}: unknown request");
            *per.entry(id).or_insert(0) += 1;
        }
        for (id, used) in &per {
            assert!(used <= &budgets[id], "case {case}: budget exceeded for {id}");
        }
        // Fairness: any two requests with remaining budget ≥ their count
        // differ by at most 1 row (until a budget binds).
        let unbound: Vec<u32> = per
            .iter()
            .filter(|(id, &u)| u < budgets[id])
            .map(|(_, &u)| u)
            .collect();
        if unbound.len() >= 2 && p.len() == batch_size {
            let mx = *unbound.iter().max().unwrap();
            let mn = *unbound.iter().min().unwrap();
            assert!(mx - mn <= 1, "case {case}: unfair pack {unbound:?}");
        }
    }
}

#[test]
fn prop_batcher_conservation_under_consume() {
    for case in 0..CASES {
        let mut rng = Rng::new(1000 + case as u64);
        let mut b = Batcher::new();
        let mut remaining: std::collections::HashMap<u64, u32> = Default::default();
        for id in 0..(1 + rng.below(8)) {
            let budget = 1 + rng.below(20) as u32;
            remaining.insert(id, budget);
            b.admit(id, budget);
        }
        // Repeatedly pack + consume until drained; total consumed per
        // request must equal its budget exactly.
        let mut consumed: std::collections::HashMap<u64, u32> = Default::default();
        let mut guard = 0;
        while !b.is_idle() {
            guard += 1;
            assert!(guard < 10_000, "case {case}: batcher never drains");
            let p = b.pack(1 + rng.below(16) as usize);
            let mut per: std::collections::HashMap<u64, u32> = Default::default();
            for &id in &p.rows {
                *per.entry(id).or_insert(0) += 1;
            }
            for (id, used) in per {
                b.consume(id, used);
                *consumed.entry(id).or_insert(0) += used;
            }
        }
        assert_eq!(consumed, remaining, "case {case}");
    }
}

// ---------------------------------------------------------------------------
// Scheduler invariants (vote-state management)
// ---------------------------------------------------------------------------

#[test]
fn prop_scheduler_trials_used_within_budget_and_counts_consistent() {
    let w = std::sync::Arc::new(Weights::random(ModelSpec::new(vec![784, 12, 10]), 9));
    for case in 0..12 {
        let mut rng = Rng::new(2000 + case as u64);
        let mut cfg = SchedulerConfig::default();
        cfg.batch_size = 1 + rng.below(24) as usize;
        cfg.min_trials = 1 + rng.below(6) as u32;
        let engine = NativeEngine::new(w.clone(), case as u64);
        let mut s = Scheduler::new(engine, cfg, raca::coordinator::Metrics::new());
        let n_req = 1 + rng.below(6) as usize;
        let mut budgets = Vec::new();
        for i in 0..n_req {
            let budget = 1 + rng.below(30) as u32;
            let conf = if rng.next_f64() < 0.5 { 0.9 } else { 0.0 };
            budgets.push(budget);
            s.submit(InferRequest::new(i as u64, vec![0.3; 784]).with_budget(budget, conf))
                .unwrap();
        }
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), n_req, "case {case}");
        for r in &done {
            let budget = budgets[r.id as usize];
            assert!(r.trials_used >= 1 && r.trials_used <= budget, "case {case}");
            let counted: u64 = r.outcome.counts.iter().sum::<u64>() + r.outcome.abstentions;
            assert_eq!(counted, r.trials_used as u64, "case {case}");
            assert!((-1..10).contains(&r.prediction), "case {case}");
        }
    }
}

// ---------------------------------------------------------------------------
// Vote-state invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_wta_outcome_merge_is_commutative_and_lossless() {
    for case in 0..CASES {
        let mut rng = Rng::new(3000 + case as u64);
        let gen = |rng: &mut Rng| {
            let mut o = WtaOutcome::new(10);
            for _ in 0..rng.below(200) {
                let w = rng.below(11) as i32 - 1;
                o.record(w);
            }
            o
        };
        let a = gen(&mut rng);
        let b = gen(&mut rng);
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab.counts, ba.counts, "case {case}");
        assert_eq!(ab.trials, a.trials + b.trials);
        assert_eq!(ab.abstentions, a.abstentions + b.abstentions);
        let total: u64 = ab.counts.iter().sum();
        assert_eq!(total + ab.abstentions, ab.trials, "case {case}");
    }
}

// ---------------------------------------------------------------------------
// Physics invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_weight_mapping_is_monotone_and_bounded() {
    let m = WeightMapping::default();
    for case in 0..CASES {
        let mut rng = Rng::new(4000 + case as u64);
        let a = rng.range_f64(-6.0, 6.0);
        let b = rng.range_f64(-6.0, 6.0);
        let (lo, hi) = if a < b { (a, b) } else { (b, a) };
        let gl = m.weight_to_g(lo);
        let gh = m.weight_to_g(hi);
        assert!(gl <= gh + 1e-18, "case {case}: not monotone");
        for g in [gl, gh] {
            assert!((m.g_min..=m.g_max).contains(&g), "case {case}: out of range");
        }
    }
}

#[test]
fn prop_mean_read_is_linear_in_inputs() {
    // Superposition: reading v1+v2 equals read(v1) + read(v2) (mean path).
    for case in 0..10 {
        let mut rng = Rng::new(5000 + case as u64);
        let rows = 2 + rng.below(40) as usize;
        let cols = 1 + rng.below(12) as usize;
        let w: Vec<f32> = (0..rows * cols).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect();
        let mut gauss = GaussianSource::new(case as u64);
        let arr = CrossbarArray::program(
            rows,
            cols,
            &w,
            WeightMapping::default(),
            &VariationModel::default(),
            NoiseParams::thermal_only(1e9),
            &mut gauss,
        );
        let v1: Vec<f64> = (0..rows).map(|_| rng.range_f64(0.0, 0.01)).collect();
        let v2: Vec<f64> = (0..rows).map(|_| rng.range_f64(0.0, 0.01)).collect();
        let vsum: Vec<f64> = v1.iter().zip(&v2).map(|(a, b)| a + b).collect();
        let mut o1 = vec![0.0; cols];
        let mut o2 = vec![0.0; cols];
        let mut os = vec![0.0; cols];
        arr.mean_differential(&v1, &mut o1);
        arr.mean_differential(&v2, &mut o2);
        arr.mean_differential(&vsum, &mut os);
        for j in 0..cols {
            assert!(
                (o1[j] + o2[j] - os[j]).abs() < 1e-12,
                "case {case} col {j}: superposition violated"
            );
        }
    }
}

#[test]
fn prop_softmax_invariances() {
    // softmax(z + c) == softmax(z); output sums to 1; argmax preserved.
    for case in 0..CASES {
        let mut rng = Rng::new(6000 + case as u64);
        let n = 2 + rng.below(12) as usize;
        let z: Vec<f32> = (0..n).map(|_| (rng.range_f64(-8.0, 8.0)) as f32).collect();
        let c = rng.range_f64(-50.0, 50.0) as f32;
        let mut a = z.clone();
        forward::softmax(&mut a);
        let mut b: Vec<f32> = z.iter().map(|&v| v + c).collect();
        forward::softmax(&mut b);
        let sum: f32 = a.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5, "case {case}");
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5, "case {case}: shift invariance");
        }
    }
}

// ---------------------------------------------------------------------------
// Engine determinism / JSON round-trip
// ---------------------------------------------------------------------------

#[test]
fn prop_native_engine_is_pure_in_trial_index() {
    let w = std::sync::Arc::new(Weights::random(ModelSpec::new(vec![16, 8, 6]), 2));
    let e = NativeEngine::new(w, 42);
    for case in 0..CASES {
        let mut rng = Rng::new(7000 + case as u64);
        let x: Vec<f32> = (0..16).map(|_| rng.next_f32()).collect();
        let t = rng.below(1000);
        let p = TrialParams::default();
        let a = e.trial(&x, p, t);
        let b = e.trial(&x, p, t);
        assert_eq!(a, b, "case {case}: trial not deterministic");
    }
}

#[test]
fn prop_json_roundtrip_random_documents() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f64() < 0.5),
            2 => Json::Num((rng.range_f64(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => Json::Str(format!("s{}—\"q\"\n", rng.below(1000))),
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    for case in 0..CASES {
        let mut rng = Rng::new(8000 + case as u64);
        let doc = gen(&mut rng, 0);
        let text = doc.to_string();
        let re = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e} in {text}"));
        assert_eq!(doc, re, "case {case}");
    }
}
