//! Smoke tests: every figure/table harness runs end-to-end at tiny scale
//! and writes its CSV (the `results/` contract used by EXPERIMENTS.md).

use raca::figures;

fn results(name: &str) -> std::path::PathBuf {
    figures::results_dir().join(format!("{name}.csv"))
}

#[test]
fn fig4_all_panels_run() {
    figures::fig4::run("all", 300).expect("fig4");
    for csv in ["fig4_ab", "fig4_c", "fig4_d", "fig4_e", "fig4_f"] {
        assert!(results(csv).exists(), "{csv} missing");
    }
}

#[test]
fn fig5_all_panels_run() {
    figures::fig5::run("all", 500).expect("fig5");
    for csv in ["fig5_a", "fig5_bc", "fig5_d"] {
        assert!(results(csv).exists(), "{csv} missing");
    }
    // Panel (a) CSV must contain 3 completed decisions (winner column).
    let text = std::fs::read_to_string(results("fig5_a")).unwrap();
    let winners = text
        .lines()
        .skip(1)
        .filter(|l| !l.ends_with(',') && !l.is_empty())
        .count();
    assert!(winners >= 3, "expected ≥3 winner rows, got {winners}");
}

#[test]
fn fig6_runs_when_artifacts_exist() {
    let dir = raca::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    figures::fig6::run("all", 60, false).expect("fig6");
    assert!(results("fig6_a").exists());
    assert!(results("fig6_b").exists());
    // Header sanity: 5 SNR curves + ideal + trials column.
    let head = std::fs::read_to_string(results("fig6_a")).unwrap();
    let cols = head.lines().next().unwrap().split(',').count();
    assert_eq!(cols, 7);
}

#[test]
fn table1_and_ablations_run() {
    figures::table1::run().expect("table1");
    figures::table1::ablate_tiles().expect("tiles");
    figures::table1::ablate_low_vr().expect("low_vr");
    for csv in [
        "table1",
        "table1_energy_breakdown",
        "table1_area_breakdown",
        "ablation_tiles",
        "ablation_low_vr",
    ] {
        assert!(results(csv).exists(), "{csv} missing");
    }
    // Table I change column must show the paper's directions.
    let text = std::fs::read_to_string(results("table1")).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert!(lines[1].contains('-'), "energy row should decrease");
    assert!(lines[3].contains('+'), "tops/w row should increase");
}

#[test]
fn variation_ablation_runs_small() {
    let dir = raca::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    figures::ablate::variation_sweep(20, 3).expect("variation");
    assert!(results("ablation_variation").exists());
}
