//! Coordinator integration tests: server lifecycle, fairness, early
//! stopping, backpressure and failure injection.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;
use raca::coordinator::{InferRequest, Scheduler, SchedulerConfig, Server, TrialRunner};
use raca::engine::{NativeEngine, TrialParams};
use raca::nn::{ModelSpec, Weights};

fn native() -> NativeEngine {
    let w = Arc::new(Weights::random(ModelSpec::new(vec![784, 24, 10]), 5));
    NativeEngine::new(w, 17)
}

#[test]
fn server_serves_many_concurrent_clients() {
    let mut cfg = SchedulerConfig::default();
    cfg.batch_size = 16;
    let server = Server::start(native(), cfg);
    let mut joins = Vec::new();
    for t in 0..6 {
        let c = server.client();
        joins.push(std::thread::spawn(move || {
            for i in 0..10 {
                let x = vec![((t * 10 + i) % 7) as f32 / 7.0; 784];
                let r = c.classify(x, 6, 0.0).expect("classify");
                assert_eq!(r.trials_used, 6);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let m = server.metrics().snapshot();
    assert_eq!(m.requests_completed, 60);
    assert_eq!(m.trials_executed, 360);
    assert!(m.fill_ratio(16) > 0.5, "fill {:.2}", m.fill_ratio(16));
}

#[test]
fn early_stopping_saves_trials_on_decisive_inputs() {
    // Decisive network: one class always wins → early stop at min_trials.
    let spec = ModelSpec::new(vec![784, 8, 10]);
    let mut w = Weights::random(spec, 1);
    let last = w.mats.len() - 1;
    for row in 0..9 {
        w.mats[last][row * 10 + 3] = 4.0;
    }
    let engine = NativeEngine::new(Arc::new(w), 2);
    let mut cfg = SchedulerConfig::default();
    cfg.batch_size = 32;
    cfg.min_trials = 5;
    let mut s = Scheduler::new(engine, cfg, raca::coordinator::Metrics::new());
    s.submit(InferRequest::new(1, vec![0.5; 784]).with_budget(100, 0.95)).unwrap();
    let done = s.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].prediction, 3);
    assert!(
        done[0].trials_used < 40,
        "expected early stop, used {}",
        done[0].trials_used
    );
}

#[test]
fn zero_confidence_disables_early_stop() {
    let mut cfg = SchedulerConfig::default();
    cfg.batch_size = 8;
    let mut s = Scheduler::new(native(), cfg, raca::coordinator::Metrics::new());
    s.submit(InferRequest::new(1, vec![0.4; 784]).with_budget(23, 0.0)).unwrap();
    let done = s.run_to_completion().unwrap();
    assert_eq!(done[0].trials_used, 23);
}

/// Engine wrapper that fails the first `fail_n` batches.
#[derive(Clone)]
struct FlakyEngine {
    inner: NativeEngine,
    fails_left: Arc<AtomicU64>,
}

impl TrialRunner for FlakyEngine {
    fn run(&self, x: &[f32], rows: usize, seed: u32, p: TrialParams) -> Result<Vec<i32>> {
        if self.fails_left.load(Ordering::Relaxed) > 0 {
            self.fails_left.fetch_sub(1, Ordering::Relaxed);
            anyhow::bail!("injected engine failure");
        }
        self.inner.run(x, rows, seed, p)
    }

    fn preferred_batch(&self) -> usize {
        8
    }
}

#[test]
fn failure_injection_batches_retry_without_losing_requests() {
    let flaky = FlakyEngine { inner: native(), fails_left: Arc::new(AtomicU64::new(2)) };
    let metrics = raca::coordinator::Metrics::new();
    let mut cfg = SchedulerConfig::default();
    cfg.batch_size = 8;
    let mut s = Scheduler::new(flaky, cfg, metrics.clone());
    for i in 0..3 {
        s.submit(InferRequest::new(i, vec![0.2; 784]).with_budget(7, 0.0)).unwrap();
    }
    let done = s.run_to_completion().unwrap();
    assert_eq!(done.len(), 3);
    for r in &done {
        assert_eq!(r.trials_used, 7, "failed batches must not burn budget");
    }
    assert_eq!(metrics.snapshot().engine_errors, 2);
}

#[test]
fn persistent_engine_failure_surfaces_error() {
    let flaky = FlakyEngine { inner: native(), fails_left: Arc::new(AtomicU64::new(u64::MAX)) };
    let mut cfg = SchedulerConfig::default();
    cfg.batch_size = 8;
    let mut s = Scheduler::new(flaky, cfg, raca::coordinator::Metrics::new());
    s.submit(InferRequest::new(1, vec![0.2; 784]).with_budget(4, 0.0)).unwrap();
    assert!(s.run_to_completion().is_err());
}

#[test]
fn latency_is_recorded() {
    let mut cfg = SchedulerConfig::default();
    cfg.batch_size = 4;
    let server = Server::start(native(), cfg);
    let c = server.client();
    for _ in 0..5 {
        c.classify(vec![0.1; 784], 4, 0.0).unwrap();
    }
    let m = server.metrics().snapshot();
    assert!(m.latency_p50_us > 0);
    assert!(m.latency_p99_us >= m.latency_p50_us);
}

#[test]
fn mixed_budgets_complete_in_any_interleaving() {
    let mut cfg = SchedulerConfig::default();
    cfg.batch_size = 16;
    let mut s = Scheduler::new(native(), cfg, raca::coordinator::Metrics::new());
    let budgets = [1u32, 64, 3, 17, 32, 2];
    for (i, &b) in budgets.iter().enumerate() {
        s.submit(InferRequest::new(i as u64, vec![0.3; 784]).with_budget(b, 0.0)).unwrap();
    }
    let mut done = s.run_to_completion().unwrap();
    done.sort_by_key(|r| r.id);
    assert_eq!(done.len(), budgets.len());
    for (r, &b) in done.iter().zip(&budgets) {
        assert_eq!(r.trials_used, b);
    }
}
