//! Cross-engine parity: the three implementations of the RACA trial
//! (native normalized, physical SI-unit, AOT-compiled XLA) must be
//! statistically interchangeable at the calibrated design point, and the
//! ideal-forward paths must agree numerically.
//!
//! Requires `make artifacts` (skips gracefully if missing so `cargo test`
//! stays runnable on a fresh checkout).

use std::sync::Arc;

use raca::dataset::Dataset;
use raca::engine::{NativeEngine, PhysicalEngine, TrialParams};
use raca::nn::Weights;

#[cfg(feature = "pjrt")]
use raca::engine::XlaEngine;
#[cfg(feature = "pjrt")]
use raca::nn::forward;

fn artifacts_ready() -> Option<std::path::PathBuf> {
    let dir = raca::runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        None
    }
}

fn load_weights(dir: &std::path::Path) -> Weights {
    Weights::load(&dir.join("weights").join("fcnn")).expect("weights load")
}

fn load_test_set(dir: &std::path::Path) -> Dataset {
    Dataset::load(&dir.join("data").join("test")).expect("test set load")
}

fn accuracy(predictions: &[i32], labels: &[i32]) -> f64 {
    let hit = predictions
        .iter()
        .zip(labels)
        .filter(|(p, l)| p == l)
        .count();
    hit as f64 / labels.len() as f64
}

#[cfg(feature = "pjrt")]
#[test]
fn xla_ideal_matches_native_ideal() {
    let Some(dir) = artifacts_ready() else { return };
    let w = load_weights(&dir);
    let ds = load_test_set(&dir).take(16);
    let engine = XlaEngine::start(dir).expect("xla engine");
    let h = engine.handle();

    for i in 0..ds.len() {
        let x = ds.image(i);
        let xla_probs = h.run_ideal(x.to_vec(), 1).expect("ideal run");
        let native_probs = forward::ideal_forward(&w, x);
        for (a, b) in xla_probs.iter().zip(&native_probs) {
            assert!(
                (a - b).abs() < 5e-4,
                "image {i}: xla {a} vs native {b} (probs {xla_probs:?} / {native_probs:?})"
            );
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn xla_trial_winners_valid_and_deterministic() {
    let Some(dir) = artifacts_ready() else { return };
    let ds = load_test_set(&dir).take(4);
    let engine = XlaEngine::start(dir).expect("xla engine");
    let h = engine.handle();
    let p = TrialParams::default();
    let x = ds.image(0).to_vec();

    let a = h.run_trials(x.clone(), 1, 42, p).expect("trial");
    let b = h.run_trials(x.clone(), 1, 42, p).expect("trial");
    assert_eq!(a, b, "same seed must reproduce the same winner");
    assert!((-1..10).contains(&a[0]));

    // Different seeds must eventually vary (stochastic inference).
    let winners: std::collections::HashSet<i32> = (0..24)
        .map(|s| h.run_trials(x.clone(), 1, s, p).unwrap()[0])
        .collect();
    assert!(!winners.is_empty());
}

#[cfg(feature = "pjrt")]
#[test]
fn xla_and_native_vote_accuracy_agree() {
    let Some(dir) = artifacts_ready() else { return };
    let w = Arc::new(load_weights(&dir));
    let ds = load_test_set(&dir).take(64);
    let engine = XlaEngine::start(dir).expect("xla engine");
    let h = engine.handle();
    let p = TrialParams::default();
    let trials = 15usize;

    // --- XLA path: batch 32 rows = 32 images; `trials` passes ------------
    let batch = 32usize;
    let mut xla_pred = Vec::new();
    for chunk in 0..ds.len() / batch {
        let mut counts = vec![[0u32; 10]; batch];
        let xs: Vec<f32> = (0..batch)
            .flat_map(|i| ds.image(chunk * batch + i).to_vec())
            .collect();
        for t in 0..trials {
            let winners = h
                .run_trials(xs.clone(), batch, (chunk * 1000 + t) as u32, p)
                .expect("trial batch");
            for (i, &win) in winners.iter().enumerate() {
                if win >= 0 {
                    counts[i][win as usize] += 1;
                }
            }
        }
        for c in &counts {
            let best = c.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
            xla_pred.push(best as i32);
        }
    }
    let xla_acc = accuracy(&xla_pred, &ds.labels);

    // --- native path: same trial count --------------------------------
    let ne = NativeEngine::new(w, 99);
    let native_pred: Vec<i32> = (0..ds.len())
        .map(|i| ne.infer(ds.image(i), p, trials, (i * 7919) as u64).prediction())
        .collect();
    let native_acc = accuracy(&native_pred, &ds.labels);

    eprintln!("vote accuracy: xla={xla_acc:.3} native={native_acc:.3}");
    assert!(xla_acc > 0.7, "xla vote accuracy too low: {xla_acc}");
    assert!(native_acc > 0.7, "native vote accuracy too low: {native_acc}");
    assert!(
        (xla_acc - native_acc).abs() < 0.12,
        "engines disagree: xla={xla_acc} native={native_acc}"
    );
}

#[test]
fn physical_and_native_agree_statistically() {
    let Some(dir) = artifacts_ready() else { return };
    let w = load_weights(&dir);
    let ds = load_test_set(&dir).take(24);
    let p = TrialParams::default();
    let trials = 9usize;

    let ne = NativeEngine::new(Arc::new(w.clone()), 5);
    let native_pred: Vec<i32> = (0..ds.len())
        .map(|i| ne.infer(ds.image(i), p, trials, (i * 131) as u64).prediction())
        .collect();

    let mut pe = PhysicalEngine::paper_default(&w, 5);
    let phys_pred: Vec<i32> = (0..ds.len())
        .map(|i| pe.infer(ds.image(i), p, trials, (i * 131) as u64).prediction())
        .collect();

    let na = accuracy(&native_pred, &ds.labels);
    let pa = accuracy(&phys_pred, &ds.labels);
    eprintln!("physical={pa:.3} native={na:.3}");
    assert!(pa > 0.6, "physical accuracy too low: {pa}");
    assert!((na - pa).abs() < 0.2, "native {na} vs physical {pa}");
}

#[test]
fn logit_distributions_match_across_native_and_physical() {
    // Distribution-level parity (KS test), not just means: the normalized
    // stochastic logits of the native engine and the (current-scaled)
    // physical engine must be statistically indistinguishable.
    use raca::crossbar::{CrossbarArray, ReadMode, WeightMapping};
    use raca::device::noise::NoiseParams;
    use raca::device::variation::VariationModel;
    use raca::stats::{ks, GaussianSource};

    let n_col = 64;
    let z = 0.8f64;
    let mapping = WeightMapping::default();
    let vr = mapping.calibrate_vr(n_col, 1e9, 1.0);
    let i_unit = vr * mapping.g0();

    // Physical: repeated noisy reads of one column, normalized to z units.
    let mut gauss = GaussianSource::new(21);
    let mut arr = CrossbarArray::program(
        n_col,
        1,
        &vec![(z / n_col as f64) as f32; n_col],
        mapping.clone(),
        &VariationModel::default(),
        NoiseParams::thermal_only(1e9),
        &mut gauss,
    );
    let v = vec![vr; n_col];
    let mut out = [0.0f64];
    let phys: Vec<f64> = (0..4000)
        .map(|_| {
            arr.read_differential(&v, ReadMode::ColumnAggregate, &mut out, &mut gauss);
            out[0] / i_unit
        })
        .collect();

    // Native: z + σ_z·n.
    let mut g2 = GaussianSource::new(22);
    let native: Vec<f64> = (0..4000).map(|_| z + 1.702 * g2.next()).collect();

    assert!(
        ks::same_distribution(&phys, &native, 0.01),
        "normalized physical reads and native logits diverge"
    );
}

#[test]
fn snr_sweep_parity_native_vs_physical_single_column() {
    // Firing probability of one crossbar column must match Φ(s·z/1.702)
    // in BOTH engines for every SNR scale (Fig. 4c ground truth).
    use raca::crossbar::{CrossbarArray, ReadMode, WeightMapping};
    use raca::device::noise::NoiseParams;
    use raca::device::variation::VariationModel;
    use raca::stats::{erf::norm_cdf, GaussianSource};

    let mapping = WeightMapping::default();
    for &snr in &[0.5f64, 1.0, 2.0] {
        let n_col = 32;
        let z = 1.2f64;
        let w_each = (z / n_col as f64) as f32;
        let mut gauss = GaussianSource::new(42);
        let mut arr = CrossbarArray::program(
            n_col,
            1,
            &vec![w_each; n_col],
            mapping.clone(),
            &VariationModel::default(),
            NoiseParams::thermal_only(1e9),
            &mut gauss,
        );
        let vr = mapping.calibrate_vr(n_col, 1e9, snr);
        let v = vec![vr; n_col];
        let mut out = [0.0f64];
        let n = 40_000;
        let mut fired = 0;
        for _ in 0..n {
            arr.read_differential(&v, ReadMode::ColumnAggregate, &mut out[..].as_mut(), &mut gauss);
            if out[0] > 0.0 {
                fired += 1;
            }
        }
        let p_phys = fired as f64 / n as f64;
        let p_analytic = norm_cdf(snr * z / 1.702);
        assert!(
            (p_phys - p_analytic).abs() < 0.02,
            "snr={snr}: physical {p_phys} vs analytic {p_analytic}"
        );
    }
}
