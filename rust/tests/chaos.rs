//! Chaos lane: kill listeners mid-load and hold the fabric to the PR-10
//! availability contract — every admitted request completes or fails
//! in-band before its deadline (nothing hangs), surviving answers stay
//! bit-identical to the unsharded engine, and `remote:@` leaves re-run
//! the full bundle verification before trusting a restarted peer.
//!
//! The kill primitive is [`raca::serve::net::NetServer::kill`]: stop
//! accepting and hard-close every live session socket — the in-process
//! equivalent of SIGKILLing the listener host.  Rebinding the same
//! address afterwards works because the listener socket is bound with
//! `SO_REUSEADDR` (std's default on Unix).
//!
//! Why resubmission is bit-safe: votes are pure functions of
//! `(seed, trial_idx)` and trial indices derive from
//! `trial_stream_base(seed, request id)`, so a request served twice —
//! once by the killed listener, once by its replacement with the same
//! seed — produces the same counts.  Duplicate completions are deduped
//! by ticket id on the client.

use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use raca::dataset::synth;
use raca::engine::{NativeEngine, TrialParams};
use raca::nn::{ModelSpec, TrainConfig, Weights};
use raca::serve::{build, trial_stream_base, Backend, BuildOptions, InferRequest, Topology};
use raca::telemetry::EventKind;

fn trained() -> Weights {
    let ds = synth::generate(160, 0x7A);
    let cfg = TrainConfig { epochs: 3, lr: 0.25, seed: 0x7B, minibatch: 1 };
    raca::nn::train(&ds, ModelSpec::new(vec![784, 20, 12, 10]), &cfg)
}

fn image(i: u64) -> Vec<f32> {
    (0..784).map(|j| ((j as u64 * 7 + i * 131) % 17) as f32 / 17.0).collect()
}

fn topo(spec: &str) -> Topology {
    Topology::parse(spec).unwrap()
}

/// Collect exactly `n` responses off a shared completion channel with a
/// hang detector, and verify ticket-id dedup: each id answers exactly
/// once, and nothing trails after the last expected response.
fn collect(
    rx: &mpsc::Receiver<raca::serve::InferResponse>,
    n: u64,
    per_wait: Duration,
) -> std::collections::HashMap<u64, raca::serve::InferResponse> {
    let mut got = std::collections::HashMap::new();
    for _ in 0..n {
        let r = rx
            .recv_timeout(per_wait)
            .unwrap_or_else(|_| panic!("hung: only {}/{n} responses arrived", got.len()));
        assert!(
            got.insert(r.id, r).is_none(),
            "a request completed twice — resubmission dedup failed"
        );
    }
    // Resubmitted frames may still be answered by a late session; the
    // pending-map dedup must have swallowed every duplicate.
    std::thread::sleep(Duration::from_millis(200));
    assert!(got.len() as u64 == n && rx.try_recv().is_err(), "stray extra response");
    got
}

/// The acceptance bar: kill the only listener while requests are in
/// flight, bring a same-seed replacement up on the same address, and the
/// session reconnects, resubmits, and answers every request bit-identical
/// to the unsharded reference — the kill is invisible to callers.
#[test]
fn killed_listener_mid_load_reconnects_resubmits_and_keeps_bit_parity() {
    let w = trained();
    let seed = 0xC4A05;
    const N: u64 = 6;
    const TRIALS: u32 = 20_000;

    let host = build(&topo("die"), &w, &BuildOptions { seed, ..Default::default() }).unwrap();
    let server = raca::serve::net::serve(host, "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();

    let b = build(&topo(&format!("remote:{addr}")), &w, &BuildOptions::default()).unwrap();
    let (tx, rx) = mpsc::channel();
    for i in 0..N {
        b.submit_to(
            InferRequest::new(i, image(i)).with_budget(TRIALS, 0.0).with_deadline_ms(60_000),
            tx.clone(),
        )
        .unwrap();
    }

    // The kill: hard-close the listener under ~2.4M queued trials, then
    // restart it — same weights, same seed — on the same address.
    server.kill();
    let revived = raca::serve::net::serve(
        build(&topo("die"), &w, &BuildOptions { seed, ..Default::default() }).unwrap(),
        &addr,
    )
    .unwrap();

    let got = collect(&rx, N, Duration::from_secs(60));
    let reference = NativeEngine::new(Arc::new(w.clone()), seed);
    for i in 0..N {
        let r = &got[&i];
        assert!(r.error.is_none(), "request {i} failed: {:?}", r.error);
        let want = reference.infer(
            &image(i),
            TrialParams::default(),
            TRIALS as usize,
            trial_stream_base(seed, i),
        );
        assert_eq!(
            r.outcome.counts, want.counts,
            "request {i} diverged from the unsharded engine after the kill"
        );
        assert_eq!(r.prediction, want.prediction());
    }

    // The journal narrates the recovery: the drop, the reconnect, and
    // the per-request resubmissions, all against the remote leaf's node.
    let j = b.journal().expect("built trees share a journal");
    let evs = j.tail(j.capacity());
    let node = format!("remote:{addr}");
    assert!(
        evs.iter().any(|e| e.kind == EventKind::SessionReconnect && e.node == node),
        "no session_reconnect; journal:\n{}",
        j.to_json_lines()
    );
    assert!(
        evs.iter().any(|e| e.kind == EventKind::Resubmit && e.node == node),
        "nothing was resubmitted — were the requests not in flight at the kill?\n{}",
        j.to_json_lines()
    );

    b.shutdown();
    drop(revived);
}

/// The two-host shape from the issue: `(remote:a, remote:b)@weighted`
/// under load, child A killed mid-run and rebound.  Every admitted
/// request resolves (none hang), nothing completes twice, and every
/// successful answer is bit-identical to the reference — whichever
/// listener, or *pair* of listeners, ended up serving it.
#[test]
fn router_over_two_remotes_survives_a_mid_load_kill() {
    let w = trained();
    let seed = 0x2C4A0;
    const N: u64 = 40;
    const TRIALS: u32 = 3_000;

    let serve_die = |w: &Weights, addr: &str| {
        raca::serve::net::serve(
            build(&topo("die"), w, &BuildOptions { seed, ..Default::default() }).unwrap(),
            addr,
        )
        .unwrap()
    };
    let a = serve_die(&w, "127.0.0.1:0");
    let addr_a = a.addr().to_string();
    let b_srv = serve_die(&w, "127.0.0.1:0");

    let spec = format!("(remote:{addr_a}, remote:{})@weighted", b_srv.addr());
    let b = build(&topo(&spec), &w, &BuildOptions::default()).unwrap();
    let (tx, rx) = mpsc::channel();
    for i in 0..N {
        b.submit_to(
            InferRequest::new(i, image(i)).with_budget(TRIALS, 0.0).with_deadline_ms(30_000),
            tx.clone(),
        )
        .unwrap();
    }

    a.kill();
    let revived = serve_die(&w, &addr_a);

    let got = collect(&rx, N, Duration::from_secs(60));
    let reference = NativeEngine::new(Arc::new(w.clone()), seed);
    let (mut ok, mut failed) = (0u64, 0u64);
    for i in 0..N {
        let r = &got[&i];
        match &r.error {
            None => {
                let want = reference.infer(
                    &image(i),
                    TrialParams::default(),
                    TRIALS as usize,
                    trial_stream_base(seed, i),
                );
                assert_eq!(r.outcome.counts, want.counts, "request {i} lost bit-parity");
                ok += 1;
            }
            // In-band failure is an allowed outcome (never a hang), but
            // it must say why.
            Some(msg) => {
                assert!(!msg.is_empty());
                failed += 1;
            }
        }
    }
    // Everything was dispatched before the kill, so the reconnect path
    // must recover all of child A's share — not shed it.
    assert_eq!(
        (ok, failed),
        (N, 0),
        "in-flight work was lost to the kill instead of resubmitted"
    );

    let j = b.journal().expect("router journal");
    let evs = j.tail(j.capacity());
    assert!(
        evs.iter()
            .any(|e| e.kind == EventKind::SessionReconnect && e.node == format!("remote:{addr_a}")),
        "child A never journaled its reconnect:\n{}",
        j.to_json_lines()
    );

    b.shutdown();
    drop(revived);
    drop(b_srv);
}

/// Satellite 1: reconnect re-runs the *build-time* bundle discipline.
/// A peer that comes back serving different weights (a different
/// registry, a rogue key) is rejected — `manifest_rejected` in the
/// journal, session stays dead — and the redial keeps retrying until the
/// genuine bundle returns, at which point service resumes with parity.
#[test]
fn reconnect_reverifies_the_bundle_and_rejects_a_swapped_peer() {
    use raca::registry::{key_path, SigningKey, Store};
    use raca::serve::net::RegistryConfig;

    let w = trained();
    let seed = 0x5AFE0;
    let base = std::env::temp_dir().join(format!("raca-chaos-reverify-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let (host_dir, client_dir, rogue_dir) =
        (base.join("host"), base.join("client"), base.join("rogue"));
    for d in [&host_dir, &client_dir, &rogue_dir] {
        std::fs::create_dir_all(d).unwrap();
    }

    // Genuine deployment: one key on both hosts, one published bundle.
    let key = SigningKey::load_or_generate(&host_dir).unwrap();
    key.save(&key_path(&client_dir)).unwrap();
    std::fs::create_dir_all(host_dir.join("weights")).unwrap();
    let prefix = host_dir.join("weights").join("fcnn");
    w.save(&prefix).unwrap();
    let calib = host_dir.join("calib.json");
    std::fs::write(&calib, br#"{"theta":3.0,"sigma_z":1.702}"#).unwrap();
    let (bundle, _env) =
        raca::registry::publish_local(&Store::open(&host_dir), &key, &prefix, &calib, None)
            .unwrap();

    let server = raca::serve::net::serve_registry(
        build(&topo("die"), &w, &BuildOptions { seed, ..Default::default() }).unwrap(),
        "127.0.0.1:0",
        RegistryConfig { store: Store::open(&host_dir), key },
    )
    .unwrap();
    let addr = server.addr().to_string();

    let b = build(
        &topo(&format!("remote:@{addr}/{bundle}")),
        &w,
        &BuildOptions { seed: 0xDEAD, artifact_dir: Some(client_dir.clone()), ..Default::default() },
    )
    .unwrap();
    b.classify(InferRequest::new(0, image(0)).with_budget(8, 0.0)).unwrap();

    // Kill, then come back *wrong*: different weights published under a
    // rogue key in a different store, same address.
    server.kill();
    let w2 = Weights::random(ModelSpec::new(vec![784, 20, 12, 10]), 0xBAD);
    let rogue_key = SigningKey::generate();
    std::fs::create_dir_all(rogue_dir.join("weights")).unwrap();
    let rogue_prefix = rogue_dir.join("weights").join("fcnn");
    w2.save(&rogue_prefix).unwrap();
    let rogue_calib = rogue_dir.join("calib.json");
    std::fs::write(&rogue_calib, br#"{"theta":3.0,"sigma_z":1.702}"#).unwrap();
    raca::registry::publish_local(&Store::open(&rogue_dir), &rogue_key, &rogue_prefix, &rogue_calib, None)
        .unwrap();
    let rogue = raca::serve::net::serve_registry(
        build(&topo("die"), &w2, &BuildOptions { seed, ..Default::default() }).unwrap(),
        &addr,
        RegistryConfig { store: Store::open(&rogue_dir), key: rogue_key },
    )
    .unwrap();

    // The supervisor redials, sees a hello without our bundle, and
    // refuses to adopt the session — journaled, retried, never served.
    let j = b.journal().expect("built trees share a journal");
    let t0 = Instant::now();
    while !j
        .tail(j.capacity())
        .iter()
        .any(|e| e.kind == EventKind::ManifestRejected && e.detail.contains("at reconnect"))
    {
        assert!(
            t0.elapsed() < Duration::from_secs(15),
            "swapped peer was never rejected:\n{}",
            j.to_json_lines()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    let r = b.classify(InferRequest::new(1, image(1)).with_budget(8, 0.0));
    assert!(r.is_err(), "a rejected session must refuse work, got {r:?}");

    // Genuine listener returns (key reloaded from disk, same store):
    // the standing redial verifies, adopts, and service resumes.
    rogue.kill();
    let revived = raca::serve::net::serve_registry(
        build(&topo("die"), &w, &BuildOptions { seed, ..Default::default() }).unwrap(),
        &addr,
        RegistryConfig {
            store: Store::open(&host_dir),
            key: SigningKey::load_or_generate(&host_dir).unwrap(),
        },
    )
    .unwrap();

    let reference = NativeEngine::new(Arc::new(w.clone()), seed);
    let t1 = Instant::now();
    let mut id = 100u64;
    let got = loop {
        match b.classify(InferRequest::new(id, image(7)).with_budget(12, 0.0)) {
            Ok(r) => break r,
            Err(_) => {
                assert!(
                    t1.elapsed() < Duration::from_secs(20),
                    "service never resumed after the genuine peer returned:\n{}",
                    j.to_json_lines()
                );
                id += 1;
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    };
    let want = reference.infer(&image(7), TrialParams::default(), 12, trial_stream_base(seed, id));
    assert_eq!(got.outcome.counts, want.counts, "post-recovery answers lost parity");
    assert!(
        j.tail(j.capacity()).iter().any(|e| e.kind == EventKind::SessionReconnect),
        "recovery must be journaled:\n{}",
        j.to_json_lines()
    );

    b.shutdown();
    drop(revived);
    let _ = std::fs::remove_dir_all(&base);
}
