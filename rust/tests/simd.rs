//! SIMD ≡ scalar bit-parity matrix (§Perf iteration 6).
//!
//! Two layers of pinning:
//!
//! 1. **Kernel-level** — every kernel table the current CPU can execute
//!    ([`simd::variants`]: scalar always, SSE2/AVX2/NEON when detected)
//!    is compared against the portable scalar reference bit-for-bit,
//!    across odd/non-multiple-of-lane widths and tail columns.  This
//!    catches a broken SIMD variant even on machines where the
//!    dispatcher would have picked a different table.
//! 2. **Engine-level** — the dispatched path (whatever [`simd::active`]
//!    selected, including the forced scalar table under
//!    `RACA_NO_SIMD=1`) must reproduce `NativeEngine::infer_scalar`
//!    vote-for-vote across block sizes B ∈ {1, 3, 64, 100}.
//!
//! Forced-fallback vs dispatched cannot be compared inside one process —
//! the dispatcher reads the environment once through a `OnceLock` — so
//! CI runs this whole suite twice, once plain and once under
//! `RACA_NO_SIMD=1`; `dispatch_honors_environment` asserts each leg
//! really exercised the table it was meant to.

use std::sync::Arc;

use raca::engine::{NativeEngine, TrialParams};
use raca::nn::{ModelSpec, Weights};
use raca::stats::Rng;
use raca::util::simd::{self, Isa, ZIG_LANES};

/// Deterministic f32s in roughly [-2, 2) off the crate's own xoshiro.
fn f32s(seed: u64, n: usize) -> Vec<f32> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| (r.next_f64() * 4.0 - 2.0) as f32).collect()
}

fn f64s(seed: u64, n: usize) -> Vec<f64> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.next_f64() * 4.0 - 2.0).collect()
}

/// Widths straddling every lane boundary of every ISA (1..=2×AVX2 f32
/// width, plus larger non-multiples with long tails).
const WIDTHS: &[usize] = &[
    1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16, 17, 23, 31, 33, 63, 65, 100, 127, 129,
    257,
];

#[test]
fn dispatch_honors_environment() {
    // The suite runs twice in CI: plain (dispatched ISA) and under
    // RACA_NO_SIMD=1 (forced scalar).  Each leg asserts its own side.
    let forced = std::env::var("RACA_NO_SIMD").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    let isa = simd::active().isa;
    if forced {
        assert_eq!(isa, Isa::Scalar, "RACA_NO_SIMD=1 must force the scalar table");
    } else if cfg!(target_arch = "x86_64") {
        assert!(
            matches!(isa, Isa::Avx2 | Isa::Sse2),
            "x86_64 must dispatch AVX2 or the SSE2 baseline, got {:?}",
            isa
        );
    } else if cfg!(target_arch = "aarch64") {
        assert_eq!(isa, Isa::Neon, "aarch64 must dispatch NEON");
    } else {
        assert_eq!(isa, Isa::Scalar);
    }
    // And the name surfaced in bench reports round-trips.
    assert_eq!(simd::active().name(), isa.name());
}

#[test]
fn add_assign_matches_scalar_on_every_variant() {
    let scalar = simd::variants()[0];
    for &n in WIDTHS {
        let base = f32s(0x5EED ^ n as u64, n);
        let row = f32s(0xABCD ^ n as u64, n);
        let mut want = base.clone();
        (scalar.add_assign_f32)(&mut want, &row);
        for k in simd::variants() {
            let mut got = base.clone();
            (k.add_assign_f32)(&mut got, &row);
            for j in 0..n {
                assert_eq!(
                    got[j].to_bits(),
                    want[j].to_bits(),
                    "{} width {n} col {j}",
                    k.name()
                );
            }
        }
    }
}

#[test]
fn add_assign_accumulation_order_survives_repeated_rows() {
    // The blocked matmul calls the kernel once per set weight row; f32
    // accumulation over many rows must stay bit-stable per column.
    let scalar = simd::variants()[0];
    for &n in &[5usize, 17, 64, 100] {
        let rows: Vec<Vec<f32>> = (0..37).map(|i| f32s(0x60 + i as u64, n)).collect();
        let mut want = vec![0.0f32; n];
        for r in &rows {
            (scalar.add_assign_f32)(&mut want, r);
        }
        for k in simd::variants() {
            let mut got = vec![0.0f32; n];
            for r in &rows {
                (k.add_assign_f32)(&mut got, r);
            }
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{} width {n}",
                k.name()
            );
        }
    }
}

#[test]
fn center_matches_scalar_on_every_variant() {
    let scalar = simd::variants()[0];
    for &n in WIDTHS {
        let z = f32s(0xCE17E4 ^ n as u64, n);
        let mean = z.iter().sum::<f32>() / n as f32;
        let theta = 3.0f64;
        let mut want = vec![0.0f64; n];
        (scalar.center_f32)(&z, mean, theta, &mut want);
        for k in simd::variants() {
            let mut got = vec![0.0f64; n];
            (k.center_f32)(&z, mean, theta, &mut got);
            for j in 0..n {
                assert_eq!(
                    got[j].to_bits(),
                    want[j].to_bits(),
                    "{} width {n} col {j}",
                    k.name()
                );
            }
        }
    }
}

#[test]
fn race_step_matches_scalar_on_every_variant() {
    let scalar = simd::variants()[0];
    for &n in WIDTHS {
        for round in 0..8u64 {
            let c = f64s(0x9ACE ^ n as u64 ^ (round << 32), n);
            let noise = f64s(0x11071 ^ n as u64 ^ (round << 16), n);
            let want = (scalar.race_step)(&c, &noise);
            for k in simd::variants() {
                assert_eq!(
                    (k.race_step)(&c, &noise),
                    want,
                    "{} width {n} round {round}",
                    k.name()
                );
            }
        }
    }
}

#[test]
fn race_step_edge_cases_on_every_variant() {
    for k in simd::variants() {
        // All candidates below threshold → abstain.
        let c = vec![-1.0f64; 10];
        let noise = vec![0.25f64; 10];
        assert_eq!((k.race_step)(&c, &noise), -1, "{} all-negative", k.name());
        // Exactly zero never wins (strict > 0).
        assert_eq!((k.race_step)(&[0.0], &[0.0]), -1, "{} zero", k.name());
        // A tie resolves to the first index attaining the maximum.
        let c = vec![-5.0, 1.5, 0.25, 1.5, 1.5];
        let noise = vec![0.0; 5];
        assert_eq!((k.race_step)(&c, &noise), 1, "{} tie", k.name());
        // A lone positive in the scalar tail region is found.
        let mut c = vec![-3.0f64; 13];
        c[12] = 0.75;
        let noise = vec![0.0; 13];
        assert_eq!((k.race_step)(&c, &noise), 12, "{} tail winner", k.name());
    }
}

#[test]
fn zig_fastpath_matches_scalar_on_every_variant() {
    let scalar = simd::variants()[0];
    // Synthetic layer bounds: the kernel is a pure function of
    // (bits, lo, hi, std), so tables need not come from the ziggurat.
    let mut r = Rng::new(0x216);
    for case in 0..64 {
        let mut bits = [0u64; ZIG_LANES];
        let mut lo = [0.0f64; ZIG_LANES];
        let mut hi = [0.0f64; ZIG_LANES];
        for j in 0..ZIG_LANES {
            bits[j] = r.next_u64();
            lo[j] = 0.5 + r.next_f64() * 3.0;
            // Mix of accepting (hi > lo ≥ u·lo) and rejecting lanes.
            hi[j] = if (case + j) % 5 == 0 { r.next_f64() * 0.3 } else { lo[j] + 1.0 };
        }
        let std = [0.0, 1.0, 1.702][case % 3];
        let mut want = vec![f64::NAN; ZIG_LANES];
        let want_ok = (scalar.zig_fastpath)(&bits, &lo, &hi, std, &mut want);
        for k in simd::variants() {
            let mut got = vec![f64::NAN; ZIG_LANES];
            let ok = (k.zig_fastpath)(&bits, &lo, &hi, std, &mut got);
            assert_eq!(ok, want_ok, "{} case {case} accept/reject", k.name());
            if ok {
                for j in 0..ZIG_LANES {
                    assert_eq!(
                        got[j].to_bits(),
                        want[j].to_bits(),
                        "{} case {case} lane {j}",
                        k.name()
                    );
                }
            }
        }
    }
}

#[test]
fn zig_fastpath_all_accept_applies_signs_exactly() {
    // hi ≫ lo → every lane accepts; outputs must be ±(std · u · lo) with
    // the sign taken from bit 8, bit-for-bit on every variant.
    for k in simd::variants() {
        let bits: [u64; ZIG_LANES] = std::array::from_fn(|j| {
            // Alternate the sign bit, vary the 53-bit payload.
            ((j as u64) << 60 | 0xDEAD_BEEF << 11) | ((j as u64 & 1) << 8) | 7
        });
        let lo = [1.25f64; ZIG_LANES];
        let hi = [10.0f64; ZIG_LANES];
        let mut out = vec![0.0f64; ZIG_LANES];
        assert!((k.zig_fastpath)(&bits, &lo, &hi, 1.702, &mut out), "{}", k.name());
        for j in 0..ZIG_LANES {
            let u = (bits[j] >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
            let v = 1.702 * (u * lo[j]);
            let want = if bits[j] & 0x100 != 0 { v } else { -v };
            assert_eq!(out[j].to_bits(), want.to_bits(), "{} lane {j}", k.name());
        }
    }
}

// ---------------------------------------------------------------------------
// Engine-level parity: the dispatched path vs the scalar reference loop.

fn engine(widths: Vec<usize>, seed: u64) -> NativeEngine {
    NativeEngine::new(Arc::new(Weights::random(ModelSpec::new(widths), seed ^ 0xA5)), seed)
}

#[test]
fn dispatched_blocked_infer_matches_scalar_across_blocks() {
    // The acceptance matrix: odd layer widths (lane tails in every
    // kernel), B ∈ {1, 3, 64, 100}, trial counts straddling block
    // boundaries — all bit-identical to the scalar loop under whichever
    // kernel table this process dispatched.
    let e = engine(vec![9, 23, 17, 10, 5], 41);
    let x: Vec<f32> = (0..9).map(|i| (i % 4) as f32 / 4.0).collect();
    let p = TrialParams::default();
    for block in [1usize, 3, 64, 100] {
        let eb = e.clone().with_trial_block(block);
        for trials in [1usize, 5, 63, 64, 65, 130] {
            let a = eb.infer_scalar(&x, p, trials, 7_000);
            let b = eb.infer(&x, p, trials, 7_000);
            assert_eq!(a.counts, b.counts, "B={block} trials={trials}");
            assert_eq!(a.abstentions, b.abstentions, "B={block} trials={trials}");
        }
    }
}

#[test]
fn dispatched_parallel_shard_path_matches_scalar() {
    // A budget large enough to trip the parallel_map shard path, on a
    // wider model (97/65/33 columns exercise 16-wide, 8-wide and tail
    // loops of the AVX2 add kernel).
    let e = engine(vec![12, 97, 65, 33, 10], 43);
    let x: Vec<f32> = (0..12).map(|i| i as f32 / 13.0).collect();
    let p = TrialParams::default();
    let a = e.infer_scalar(&x, p, 700, 0);
    let b = e.infer(&x, p, 700, 0);
    assert_eq!(a.counts, b.counts);
    assert_eq!(a.abstentions, b.abstentions);
    // And the B=1 fallback through the same parallel entry point.
    let e1 = e.clone().with_trial_block(1);
    let c = e1.infer(&x, p, 700, 0);
    assert_eq!(a.counts, c.counts);
    assert_eq!(a.abstentions, c.abstentions);
}

#[test]
fn b1_fallback_matches_blocked_on_arbitrary_indices() {
    // trials_cached at B=1 routes through the scalar loop; winners must
    // match the blocked kernel at B=64 on the same out-of-order,
    // non-contiguous stream indices.
    let e = engine(vec![8, 33, 12, 6], 47);
    let x: Vec<f32> = (0..8).map(|i| (7 - i) as f32 / 9.0).collect();
    let z1 = e.precompute(&x);
    let p = TrialParams::default();
    let indices: Vec<u64> = vec![3, 999, 0, 12, 12, 7, 1 << 40, 42, 5, 6, 88, 2];
    let a = e.clone().with_trial_block(1).trials_cached(&z1, p, &indices);
    let b = e.clone().with_trial_block(64).trials_cached(&z1, p, &indices);
    assert_eq!(a, b);
}

#[test]
fn b1_fallback_matches_per_trial_in_run_trial_batch() {
    // The HTTP-batcher entry point at B=1 (scalar fallback) vs the
    // per-row reference, including grouped repeated images.
    let e = engine(vec![6, 21, 9, 4], 53).with_trial_block(1);
    let a: Vec<f32> = (0..6).map(|i| i as f32 / 7.0).collect();
    let b: Vec<f32> = (0..6).map(|i| (i * i % 5) as f32 / 5.0).collect();
    let mut x = Vec::new();
    for img in [&a, &b, &a, &a, &b] {
        x.extend_from_slice(img);
    }
    let p = TrialParams::default();
    let batch = e.run_trial_batch(&x, 6, p, 900);
    for (r, &w) in batch.iter().enumerate() {
        assert_eq!(w, e.trial(&x[r * 6..(r + 1) * 6], p, 900 + r as u64), "row {r}");
    }
}
