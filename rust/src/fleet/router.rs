//! Request routing across healthy replicas.
//!
//! The router only *picks* — it never owns chips — and drives the
//! [`crate::serve::ReplicatedFleetBackend`]'s per-request dispatch.  (The
//! scheduler-side [`crate::fleet::FleetRunner`] shards each batch evenly
//! across healthy chips instead; `--policy` does not affect that path.)
//! Policies are deliberately pluggable: round-robin is the
//! throughput-optimal choice for homogeneous trial costs, least-loaded
//! wins once chips drift apart (eviction, recalibration pauses,
//! heterogeneous dies), and weighted follows the health monitor's live
//! traffic weights (slow or abstention-prone dies get fewer requests
//! without being evicted).

use std::sync::atomic::{AtomicUsize, Ordering};

use super::chip::ChipId;

/// Dispatch policy over healthy replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutePolicy {
    #[default]
    RoundRobin,
    LeastLoaded,
    /// Least loaded *per unit of traffic weight*: the health monitor's
    /// [`crate::fleet::HealthMonitor::traffic_weights`] scale how much
    /// in-flight work each die should carry.
    Weighted,
}

impl RoutePolicy {
    /// Accepted spellings, for `parse` error messages.
    pub const SPELLINGS: &'static str = "round-robin|rr, least-loaded|ll, weighted|wt";

    /// Parse a CLI/config/topology spelling (case-insensitive).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => Some(RoutePolicy::RoundRobin),
            "least-loaded" | "ll" => Some(RoutePolicy::LeastLoaded),
            "weighted" | "wt" => Some(RoutePolicy::Weighted),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastLoaded => "least-loaded",
            RoutePolicy::Weighted => "weighted",
        }
    }
}

/// Stateless-per-request picker (the round-robin cursor is the only
/// internal state, and it is lock-free).
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    cursor: AtomicUsize,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Self {
        Self { policy, cursor: AtomicUsize::new(0) }
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Pick a chip from `healthy`.  `load` maps chip id → current load
    /// (in-flight or cumulative served, caller's choice) and is consulted
    /// by [`RoutePolicy::LeastLoaded`] and [`RoutePolicy::Weighted`];
    /// `weights` maps chip id → relative traffic share and is consulted
    /// only by `Weighted` (missing entries count as 1.0).  Ties break
    /// toward the lower id.
    pub fn pick(&self, healthy: &[ChipId], load: &[u64], weights: &[f64]) -> Option<ChipId> {
        if healthy.is_empty() {
            return None;
        }
        match self.policy {
            RoutePolicy::RoundRobin => {
                let k = self.cursor.fetch_add(1, Ordering::Relaxed);
                Some(healthy[k % healthy.len()])
            }
            RoutePolicy::LeastLoaded => healthy
                .iter()
                .copied()
                .min_by_key(|&id| (load.get(id).copied().unwrap_or(0), id)),
            RoutePolicy::Weighted => healthy
                .iter()
                .copied()
                .min_by(|&a, &b| {
                    let cost = |id: ChipId| {
                        let l = load.get(id).copied().unwrap_or(0) as f64 + 1.0;
                        let w = weights.get(id).copied().unwrap_or(1.0).max(1e-6);
                        l / w
                    };
                    cost(a)
                        .partial_cmp(&cost(b))
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.cmp(&b))
                }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spellings() {
        assert_eq!(RoutePolicy::parse("rr"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("least-loaded"), Some(RoutePolicy::LeastLoaded));
        assert_eq!(RoutePolicy::parse("weighted"), Some(RoutePolicy::Weighted));
        // Case-insensitive, like every other CLI/config spelling.
        assert_eq!(RoutePolicy::parse("Round-Robin"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("WEIGHTED"), Some(RoutePolicy::Weighted));
        assert_eq!(RoutePolicy::parse("nope"), None);
        assert_eq!(RoutePolicy::RoundRobin.name(), "round-robin");
        assert_eq!(RoutePolicy::Weighted.name(), "weighted");
    }

    #[test]
    fn round_robin_cycles_over_healthy_only() {
        let r = Router::new(RoutePolicy::RoundRobin);
        let healthy = vec![0usize, 2, 3]; // chip 1 evicted
        let picks: Vec<ChipId> =
            (0..6).map(|_| r.pick(&healthy, &[], &[]).unwrap()).collect();
        assert_eq!(picks, vec![0, 2, 3, 0, 2, 3]);
    }

    #[test]
    fn least_loaded_picks_minimum_then_lower_id() {
        let r = Router::new(RoutePolicy::LeastLoaded);
        let healthy = vec![0usize, 1, 2];
        assert_eq!(r.pick(&healthy, &[5, 2, 9], &[]), Some(1));
        assert_eq!(r.pick(&healthy, &[4, 4, 9], &[]), Some(0)); // tie → lower id
        // Missing load entries count as zero load.
        assert_eq!(r.pick(&[0, 1, 7], &[3, 1, 2], &[]), Some(7));
    }

    #[test]
    fn weighted_prefers_the_heavier_weight_at_equal_load() {
        let r = Router::new(RoutePolicy::Weighted);
        let healthy = vec![0usize, 1, 2];
        // Equal load: chip 2's double weight wins.
        assert_eq!(r.pick(&healthy, &[3, 3, 3], &[1.0, 1.0, 2.0]), Some(2));
        // The heavy chip absorbs proportionally more load before losing.
        assert_eq!(r.pick(&healthy, &[0, 0, 1], &[1.0, 1.0, 2.0]), Some(0));
        // Missing weights default to 1.0; ties break toward the lower id.
        assert_eq!(r.pick(&healthy, &[1, 1, 1], &[]), Some(0));
        // Near-zero weight starves the chip without dividing by zero.
        assert_eq!(r.pick(&[0, 1], &[9, 0], &[1.0, 0.0]), Some(0));
    }

    #[test]
    fn empty_fleet_yields_none() {
        let r = Router::new(RoutePolicy::RoundRobin);
        assert_eq!(r.pick(&[], &[], &[]), None);
        let r = Router::new(RoutePolicy::LeastLoaded);
        assert_eq!(r.pick(&[], &[1, 2], &[]), None);
    }
}
