//! Request routing across healthy replicas.
//!
//! The router only *picks* — it never owns chips — and drives the
//! request-level [`crate::fleet::Fleet::serve`] loop.  (The scheduler-side
//! [`crate::fleet::FleetRunner`] shards each batch evenly across healthy
//! chips instead; `--policy` does not affect that path.)  Policies are
//! deliberately pluggable: round-robin is the throughput-optimal choice
//! for homogeneous trial costs, least-loaded wins once chips drift apart
//! (eviction, recalibration pauses, heterogeneous dies).

use std::sync::atomic::{AtomicUsize, Ordering};

use super::chip::ChipId;

/// Dispatch policy over healthy replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutePolicy {
    #[default]
    RoundRobin,
    LeastLoaded,
}

impl RoutePolicy {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "round-robin" | "rr" => Some(RoutePolicy::RoundRobin),
            "least-loaded" | "ll" => Some(RoutePolicy::LeastLoaded),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastLoaded => "least-loaded",
        }
    }
}

/// Stateless-per-request picker (the round-robin cursor is the only
/// internal state, and it is lock-free).
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    cursor: AtomicUsize,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Self {
        Self { policy, cursor: AtomicUsize::new(0) }
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Pick a chip from `healthy`.  `load` maps chip id → current load
    /// (in-flight or cumulative served, caller's choice); only consulted
    /// by [`RoutePolicy::LeastLoaded`], ties break toward the lower id.
    pub fn pick(&self, healthy: &[ChipId], load: &[u64]) -> Option<ChipId> {
        if healthy.is_empty() {
            return None;
        }
        match self.policy {
            RoutePolicy::RoundRobin => {
                let k = self.cursor.fetch_add(1, Ordering::Relaxed);
                Some(healthy[k % healthy.len()])
            }
            RoutePolicy::LeastLoaded => healthy
                .iter()
                .copied()
                .min_by_key(|&id| (load.get(id).copied().unwrap_or(0), id)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spellings() {
        assert_eq!(RoutePolicy::parse("rr"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("least-loaded"), Some(RoutePolicy::LeastLoaded));
        assert_eq!(RoutePolicy::parse("nope"), None);
        assert_eq!(RoutePolicy::RoundRobin.name(), "round-robin");
    }

    #[test]
    fn round_robin_cycles_over_healthy_only() {
        let r = Router::new(RoutePolicy::RoundRobin);
        let healthy = vec![0usize, 2, 3]; // chip 1 evicted
        let picks: Vec<ChipId> =
            (0..6).map(|_| r.pick(&healthy, &[]).unwrap()).collect();
        assert_eq!(picks, vec![0, 2, 3, 0, 2, 3]);
    }

    #[test]
    fn least_loaded_picks_minimum_then_lower_id() {
        let r = Router::new(RoutePolicy::LeastLoaded);
        let healthy = vec![0usize, 1, 2];
        assert_eq!(r.pick(&healthy, &[5, 2, 9]), Some(1));
        assert_eq!(r.pick(&healthy, &[4, 4, 9]), Some(0)); // tie → lower id
        // Missing load entries count as zero load.
        assert_eq!(r.pick(&[0, 1, 7], &[3, 1, 2]), Some(7));
    }

    #[test]
    fn empty_fleet_yields_none() {
        let r = Router::new(RoutePolicy::RoundRobin);
        assert_eq!(r.pick(&[], &[]), None);
        let r = Router::new(RoutePolicy::LeastLoaded);
        assert_eq!(r.pick(&[], &[1, 2]), None);
    }
}
