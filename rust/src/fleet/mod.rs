//! Fleet layer: program, calibrate and health-model a farm of
//! non-identical RACA chips.
//!
//! One simulated die is never the deployment story — production runs many
//! chips, each with its own programming-variation draw, and compensates at
//! the system level (Marinella et al.'s multiscale co-design argument).
//! This subsystem owns the *chips*; **serving goes through the
//! [`crate::serve::Backend`] trait**, which is the only public entry point
//! ([`crate::serve::ReplicatedFleetBackend`] lifts a programmed `Fleet`
//! onto per-chip worker threads; [`crate::serve::PipelinedFleetBackend`]
//! shards one model's layers across dies):
//!
//! * [`Chip`] — one die: `NativeEngine` (or `PhysicalEngine`) programmed
//!   through the conductance mapping with a private [`VariationModel`]
//!   draw and RNG stream derived from `(fleet_seed, chip_id)`;
//! * [`Calibrator`] — per-chip (θ, σ_z) grid search against a held-out
//!   calibration set; never worse than the nominal point on that set;
//! * [`Router`] — round-robin / least-loaded / health-weighted dispatch
//!   over healthy chips;
//! * [`HealthMonitor`] — rolling per-chip accuracy/latency, drift
//!   flagging (→ recalibrate), eviction (→ drop from routing) and live
//!   traffic reweighting ([`HealthMonitor::traffic_weights`]);
//! * [`FleetRunner`] — a [`crate::coordinator::TrialRunner`] that shards
//!   scheduler batches across the farm, so the whole coordinator stack
//!   (batcher, early-stopper, server) runs unchanged on top of N chips.
//!
//! `raca fleet --chips 8 --sigma 0.10` exercises the full loop:
//! program → calibrate → serve (through the replicated backend) →
//! health report.

pub mod calibrate;
pub mod chip;
pub mod health;
pub mod metrics;
pub mod router;
pub mod runner;

pub use calibrate::{CalibrationReport, Calibrator};
pub use chip::{chip_seed, program_weights, Chip, ChipId};
pub use health::{ChipHealth, HealthConfig, HealthMonitor, SteerReport};
pub use metrics::{ChipStats, FleetSnapshot};
pub use router::{RoutePolicy, Router};
pub use runner::FleetRunner;

use crate::dataset::Dataset;
use crate::device::VariationModel;
use crate::engine::{NativeEngine, TrialEngine};
use crate::nn::Weights;

/// Knobs of a fleet run (`raca fleet` flags / the `"fleet"` config block).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of chips to program.
    pub chips: usize,
    /// Lognormal programming-variation σ per die.
    pub sigma: f64,
    /// Stuck-at-G_min / stuck-at-G_max device probabilities.
    pub stuck_lo: f64,
    pub stuck_hi: f64,
    pub policy: RoutePolicy,
    /// Held-out calibration set size and vote trials per image.
    pub cal_images: usize,
    pub cal_trials: usize,
    /// Served workload size and vote trials per request.
    pub serve_images: usize,
    pub serve_trials: usize,
    pub seed: u64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            chips: 8,
            sigma: 0.10,
            stuck_lo: 0.0,
            stuck_hi: 0.0,
            policy: RoutePolicy::RoundRobin,
            cal_images: 96,
            cal_trials: 7,
            serve_images: 256,
            serve_trials: 9,
            seed: 0xF1EE7,
        }
    }
}

impl FleetConfig {
    pub fn variation(&self) -> VariationModel {
        VariationModel::with_defects(self.sigma, self.stuck_lo, self.stuck_hi)
    }
}

/// A farm of programmed chips plus its router and health state.
///
/// This is chip *ownership*, not a serving loop: hand it to
/// [`crate::serve::ReplicatedFleetBackend::start`] for threaded serving,
/// or [`Fleet::into_runner`] for scheduler-side batch sharding.
pub struct Fleet<E> {
    pub chips: Vec<Chip<E>>,
    pub router: Router,
    pub health: HealthMonitor,
    pub seed: u64,
}

impl Fleet<NativeEngine> {
    /// Program `n_chips` native-engine dies from one set of nominal
    /// weights; every die draws its own variation from the fleet seed.
    pub fn program_native(
        nominal: &Weights,
        n_chips: usize,
        variation: &VariationModel,
        policy: RoutePolicy,
        seed: u64,
    ) -> Self {
        Self::program_native_span(nominal, n_chips, 0, variation, policy, seed)
    }

    /// Program `n_chips` dies whose *global* identities start at
    /// `chip_base` — the topology compiler's fleet-wide die numbering, so
    /// replica groups inside one deployment tree never share a variation
    /// draw.  `chip_base == 0` is exactly [`Fleet::program_native`].
    pub fn program_native_span(
        nominal: &Weights,
        n_chips: usize,
        chip_base: usize,
        variation: &VariationModel,
        policy: RoutePolicy,
        seed: u64,
    ) -> Self {
        assert!(n_chips > 0, "a fleet needs at least one chip");
        let chips = (0..n_chips)
            .map(|id| Chip::program_native_global(id, chip_base + id, nominal, variation, seed))
            .collect();
        Self {
            chips,
            router: Router::new(policy),
            health: HealthMonitor::new(n_chips, HealthConfig::default()),
            seed,
        }
    }
}

impl<E: TrialEngine> Fleet<E> {
    pub fn len(&self) -> usize {
        self.chips.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chips.is_empty()
    }

    /// Calibrate every healthy chip against `cal`; returns one report per
    /// calibrated chip.
    pub fn calibrate(&mut self, cal: &Dataset, calibrator: &Calibrator) -> Vec<CalibrationReport> {
        let mut reports = Vec::new();
        for chip in self.chips.iter_mut() {
            if self.health.chip(chip.id).evicted {
                continue;
            }
            reports.push(calibrator.calibrate_chip(chip, cal));
            self.health.note_recalibrated(chip.id);
        }
        reports
    }

    /// Mean per-chip vote accuracy on `ds` under each chip's *active*
    /// parameters, scored with the calibrator's deterministic protocol.
    /// This is the fleet-level "classifies a batch" number: every healthy
    /// chip classifies the full set, and the fleet average is reported.
    pub fn mean_accuracy(&mut self, ds: &Dataset, calibrator: &Calibrator) -> f64 {
        let mut total = 0.0;
        let mut counted = 0usize;
        for chip in self.chips.iter_mut() {
            if self.health.chip(chip.id).evicted {
                continue;
            }
            total += calibrator.score(&mut chip.engine, chip.params, ds);
            counted += 1;
        }
        if counted == 0 {
            0.0
        } else {
            total / counted as f64
        }
    }

    /// Hand the healthy chips to a scheduler-driven [`FleetRunner`].
    pub fn into_runner(self) -> FleetRunner<E> {
        FleetRunner::new(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ModelSpec;

    fn nominal() -> Weights {
        Weights::random(ModelSpec::new(vec![784, 10, 10]), 4)
    }

    fn labeled_batch(n: usize) -> Dataset {
        let mut images = Vec::new();
        let mut labels = Vec::new();
        for i in 0..n {
            images.extend((0..784).map(|j| ((i * 13 + j) % 17) as f32 / 17.0));
            labels.push((i % 10) as i32);
        }
        Dataset { images, labels }
    }

    #[test]
    fn same_seed_reproduces_the_same_farm() {
        let w = nominal();
        let v = VariationModel::lognormal(0.10);
        let a = Fleet::program_native(&w, 3, &v, RoutePolicy::RoundRobin, 7);
        let b = Fleet::program_native(&w, 3, &v, RoutePolicy::RoundRobin, 7);
        for (ca, cb) in a.chips.iter().zip(&b.chips) {
            assert_eq!(ca.engine.weights.mats, cb.engine.weights.mats);
        }
        let c = Fleet::program_native(&w, 3, &v, RoutePolicy::RoundRobin, 8);
        assert_ne!(
            a.chips[0].engine.weights.mats,
            c.chips[0].engine.weights.mats
        );
    }

    #[test]
    fn calibrate_skips_evicted_and_reports_all_healthy() {
        let w = nominal();
        let mut fleet = Fleet::program_native(
            &w,
            3,
            &VariationModel::lognormal(0.10),
            RoutePolicy::RoundRobin,
            17,
        );
        fleet.health.evict(0);
        let ds = labeled_batch(8);
        let reports = fleet.calibrate(&ds, &Calibrator::quick(3));
        assert_eq!(reports.len(), 2);
        assert!(reports.iter().all(|r| r.chip != 0));
        for r in &reports {
            assert!(r.calibrated_accuracy >= r.baseline_accuracy);
        }
    }
}
