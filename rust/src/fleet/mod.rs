//! Fleet layer: serve inference across a farm of non-identical RACA chips.
//!
//! One simulated die is never the deployment story — production runs many
//! chips, each with its own programming-variation draw, and compensates at
//! the system level (Marinella et al.'s multiscale co-design argument).
//! This subsystem is that level:
//!
//! * [`Chip`] — one die: `NativeEngine` (or `PhysicalEngine`) programmed
//!   through the conductance mapping with a private [`VariationModel`]
//!   draw and RNG stream derived from `(fleet_seed, chip_id)`;
//! * [`Calibrator`] — per-chip (θ, σ_z) grid search against a held-out
//!   calibration set; never worse than the nominal point on that set;
//! * [`Router`] — round-robin / least-loaded dispatch over healthy chips;
//! * [`HealthMonitor`] — rolling per-chip accuracy/latency, drift
//!   flagging (→ recalibrate) and eviction (→ drop from routing);
//! * [`FleetRunner`] — a [`crate::coordinator::TrialRunner`] that shards
//!   scheduler batches across the farm, so the whole coordinator stack
//!   (batcher, early-stopper, server) runs unchanged on top of N chips.
//!
//! `raca fleet --chips 8 --sigma 0.10` exercises the full loop:
//! program → calibrate → serve → health report.

pub mod calibrate;
pub mod chip;
pub mod health;
pub mod metrics;
pub mod router;
pub mod runner;

pub use calibrate::{CalibrationReport, Calibrator};
pub use chip::{chip_seed, program_weights, Chip, ChipId};
pub use health::{ChipHealth, HealthConfig, HealthMonitor};
pub use metrics::{ChipStats, FleetSnapshot};
pub use router::{RoutePolicy, Router};
pub use runner::FleetRunner;

use std::time::{Duration, Instant};

use crate::dataset::Dataset;
use crate::device::VariationModel;
use crate::engine::{NativeEngine, TrialEngine};
use crate::nn::Weights;

/// Knobs of a fleet run (`raca fleet` flags / the `"fleet"` config block).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of chips to program.
    pub chips: usize,
    /// Lognormal programming-variation σ per die.
    pub sigma: f64,
    /// Stuck-at-G_min / stuck-at-G_max device probabilities.
    pub stuck_lo: f64,
    pub stuck_hi: f64,
    pub policy: RoutePolicy,
    /// Held-out calibration set size and vote trials per image.
    pub cal_images: usize,
    pub cal_trials: usize,
    /// Served workload size and vote trials per request.
    pub serve_images: usize,
    pub serve_trials: usize,
    pub seed: u64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            chips: 8,
            sigma: 0.10,
            stuck_lo: 0.0,
            stuck_hi: 0.0,
            policy: RoutePolicy::RoundRobin,
            cal_images: 96,
            cal_trials: 7,
            serve_images: 256,
            serve_trials: 9,
            seed: 0xF1EE7,
        }
    }
}

impl FleetConfig {
    pub fn variation(&self) -> VariationModel {
        VariationModel::with_defects(self.sigma, self.stuck_lo, self.stuck_hi)
    }
}

/// Result of serving a workload through the router.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub served: usize,
    pub labeled: usize,
    pub hits: usize,
    pub abstentions: u64,
    pub wall: Duration,
    pub snapshot: FleetSnapshot,
}

impl ServeReport {
    /// Accuracy over labeled requests (None for unlabeled traffic).
    pub fn accuracy(&self) -> Option<f64> {
        if self.labeled == 0 {
            None
        } else {
            Some(self.hits as f64 / self.labeled as f64)
        }
    }

    /// Served requests per wall-clock second.
    pub fn requests_per_sec(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.served as f64 / self.wall.as_secs_f64()
    }
}

/// A farm of programmed chips plus its router and health state.
pub struct Fleet<E> {
    pub chips: Vec<Chip<E>>,
    pub router: Router,
    pub health: HealthMonitor,
    pub seed: u64,
    stats: Vec<ChipStats>,
}

impl Fleet<NativeEngine> {
    /// Program `n_chips` native-engine dies from one set of nominal
    /// weights; every die draws its own variation from the fleet seed.
    pub fn program_native(
        nominal: &Weights,
        n_chips: usize,
        variation: &VariationModel,
        policy: RoutePolicy,
        seed: u64,
    ) -> Self {
        assert!(n_chips > 0, "a fleet needs at least one chip");
        let chips = (0..n_chips)
            .map(|id| Chip::program_native(id, nominal, variation, seed))
            .collect();
        Self {
            chips,
            router: Router::new(policy),
            health: HealthMonitor::new(n_chips, HealthConfig::default()),
            seed,
            stats: vec![ChipStats::default(); n_chips],
        }
    }
}

impl<E: TrialEngine> Fleet<E> {
    pub fn len(&self) -> usize {
        self.chips.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chips.is_empty()
    }

    /// Calibrate every healthy chip against `cal`; returns one report per
    /// calibrated chip.
    pub fn calibrate(&mut self, cal: &Dataset, calibrator: &Calibrator) -> Vec<CalibrationReport> {
        let mut reports = Vec::new();
        for chip in self.chips.iter_mut() {
            if self.health.chip(chip.id).evicted {
                continue;
            }
            reports.push(calibrator.calibrate_chip(chip, cal));
            self.health.note_recalibrated(chip.id);
        }
        reports
    }

    /// Mean per-chip vote accuracy on `ds` under each chip's *active*
    /// parameters, scored with the calibrator's deterministic protocol.
    /// This is the fleet-level "classifies a batch" number: every healthy
    /// chip classifies the full set, and the fleet average is reported.
    pub fn mean_accuracy(&mut self, ds: &Dataset, calibrator: &Calibrator) -> f64 {
        let mut total = 0.0;
        let mut counted = 0usize;
        for chip in self.chips.iter_mut() {
            if self.health.chip(chip.id).evicted {
                continue;
            }
            total += calibrator.score(&mut chip.engine, chip.params, ds);
            counted += 1;
        }
        if counted == 0 {
            0.0
        } else {
            total / counted as f64
        }
    }

    /// Serve a labeled workload request-by-request through the router,
    /// recording health and per-chip stats.
    pub fn serve(&mut self, ds: &Dataset, trials: usize, seed: u64) -> ServeReport {
        let t0 = Instant::now();
        let mut hits = 0usize;
        let mut abstentions = 0u64;
        let mut served = 0usize;
        // Nothing evicts mid-serve, so the healthy set is loop-invariant;
        // loads change by one element per request and are kept incrementally.
        let healthy = self.health.healthy();
        let mut loads: Vec<u64> = self.stats.iter().map(|s| s.served).collect();
        for i in 0..ds.len() {
            let Some(id) = self.router.pick(&healthy, &loads) else { break };
            loads[id] += 1;
            let req_t0 = Instant::now();
            let pred = self.chips[id].classify(
                ds.image(i),
                trials,
                // 2^32 trial indices per image — streams never overlap for
                // any realistic --trials value.
                seed.wrapping_add((i as u64) << 32),
            );
            let latency_us = req_t0.elapsed().as_micros() as u64;
            let abstained = pred < 0;
            let correct = pred == ds.label(i);
            served += 1;
            if correct {
                hits += 1;
            }
            if abstained {
                abstentions += 1;
            }
            self.health.record(id, Some(correct), abstained, latency_us);
            self.stats[id].record(trials as u64, abstained, Some(correct), latency_us);
        }
        ServeReport {
            served,
            labeled: served,
            hits,
            abstentions,
            wall: t0.elapsed(),
            snapshot: self.snapshot(),
        }
    }

    /// Recalibrate drifting chips and evict chips under the hard floor.
    /// Returns `(recalibrated, evicted)` chip ids.
    pub fn heal(&mut self, cal: &Dataset, calibrator: &Calibrator) -> (Vec<ChipId>, Vec<ChipId>) {
        let evicted = self.health.evictable();
        for &id in &evicted {
            self.health.evict(id);
        }
        let drifting = self.health.drifting();
        for &id in &drifting {
            calibrator.calibrate_chip(&mut self.chips[id], cal);
            self.health.note_recalibrated(id);
        }
        (drifting, evicted)
    }

    /// Point-in-time per-chip stats.
    pub fn snapshot(&self) -> FleetSnapshot {
        FleetSnapshot {
            chips: self
                .chips
                .iter()
                .map(|c| (c.id, self.stats[c.id].clone()))
                .collect(),
        }
    }

    /// Hand the healthy chips to a scheduler-driven [`FleetRunner`].
    pub fn into_runner(self) -> FleetRunner<E> {
        FleetRunner::new(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ModelSpec;

    fn nominal() -> Weights {
        Weights::random(ModelSpec::new(vec![784, 10, 10]), 4)
    }

    fn labeled_batch(n: usize) -> Dataset {
        let mut images = Vec::new();
        let mut labels = Vec::new();
        for i in 0..n {
            images.extend((0..784).map(|j| ((i * 13 + j) % 17) as f32 / 17.0));
            labels.push((i % 10) as i32);
        }
        Dataset { images, labels }
    }

    #[test]
    fn same_seed_reproduces_the_same_farm() {
        let w = nominal();
        let v = VariationModel::lognormal(0.10);
        let a = Fleet::program_native(&w, 3, &v, RoutePolicy::RoundRobin, 7);
        let b = Fleet::program_native(&w, 3, &v, RoutePolicy::RoundRobin, 7);
        for (ca, cb) in a.chips.iter().zip(&b.chips) {
            assert_eq!(ca.engine.weights.mats, cb.engine.weights.mats);
        }
        let c = Fleet::program_native(&w, 3, &v, RoutePolicy::RoundRobin, 8);
        assert_ne!(
            a.chips[0].engine.weights.mats,
            c.chips[0].engine.weights.mats
        );
    }

    #[test]
    fn serve_balances_round_robin() {
        let w = nominal();
        let mut fleet = Fleet::program_native(
            &w,
            4,
            &VariationModel::lognormal(0.05),
            RoutePolicy::RoundRobin,
            11,
        );
        let ds = labeled_batch(40);
        let report = fleet.serve(&ds, 3, 123);
        assert_eq!(report.served, 40);
        assert_eq!(report.snapshot.load_imbalance(), 0);
        let agg = report.snapshot.aggregate();
        assert_eq!(agg.served, 40);
        assert_eq!(agg.trials, 120);
    }

    #[test]
    fn serve_skips_evicted_chips() {
        let w = nominal();
        let mut fleet = Fleet::program_native(
            &w,
            3,
            &VariationModel::default(),
            RoutePolicy::LeastLoaded,
            13,
        );
        fleet.health.evict(1);
        let ds = labeled_batch(12);
        let report = fleet.serve(&ds, 2, 5);
        assert_eq!(report.served, 12);
        assert_eq!(report.snapshot.chips[1].1.served, 0);
        assert_eq!(report.snapshot.chips[0].1.served + report.snapshot.chips[2].1.served, 12);
    }

    #[test]
    fn calibrate_skips_evicted_and_reports_all_healthy() {
        let w = nominal();
        let mut fleet = Fleet::program_native(
            &w,
            3,
            &VariationModel::lognormal(0.10),
            RoutePolicy::RoundRobin,
            17,
        );
        fleet.health.evict(0);
        let ds = labeled_batch(8);
        let reports = fleet.calibrate(&ds, &Calibrator::quick(3));
        assert_eq!(reports.len(), 2);
        assert!(reports.iter().all(|r| r.chip != 0));
        for r in &reports {
            assert!(r.calibrated_accuracy >= r.baseline_accuracy);
        }
    }
}
