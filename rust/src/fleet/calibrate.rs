//! Per-chip calibration: recover accuracy lost to that die's variation.
//!
//! Fig. 6 shows accuracy is a function of the trial parameters (SNR scale,
//! V_th0) — and device variation shifts each die's optimum.  The
//! calibrator grid-searches (θ, σ_z-scale) around the chip's nominal
//! design point against a held-out calibration set and installs the
//! argmax.  The nominal parameters are always candidate 0 and ties break
//! toward the earliest candidate, so on the calibration set the calibrated
//! accuracy is ≥ the uncalibrated accuracy *by construction* — calibration
//! can only help.
//!
//! Scoring is deterministic: trial indices derive from the calibrator seed
//! and the image index only, so every candidate sees the same comparator
//! noise streams and re-scoring reproduces bit-identical accuracies.

use crate::dataset::Dataset;
use crate::engine::{TrialEngine, TrialParams};

use super::chip::{Chip, ChipId};

/// Outcome of calibrating one chip.
#[derive(Debug, Clone)]
pub struct CalibrationReport {
    pub chip: ChipId,
    pub chosen: TrialParams,
    /// Accuracy at the nominal design point (candidate 0).
    pub baseline_accuracy: f64,
    /// Accuracy at the chosen parameters (≥ baseline on the cal set).
    pub calibrated_accuracy: f64,
    pub candidates_tried: usize,
}

/// Grid-search calibrator over (θ, σ_z scale).
#[derive(Debug, Clone)]
pub struct Calibrator {
    /// WTA rest-threshold candidates (normalized z units).
    pub thetas: Vec<f32>,
    /// Multipliers on the nominal σ_z (per-chip read-voltage trim).
    pub sigma_scales: Vec<f32>,
    /// Vote trials per calibration image.
    pub trials: usize,
    /// Base seed of the (shared) scoring trial streams.
    pub seed: u64,
}

impl Default for Calibrator {
    fn default() -> Self {
        Self {
            thetas: vec![2.0, 2.5, 3.0, 3.5, 4.0],
            sigma_scales: vec![0.75, 1.0, 1.25],
            trials: 7,
            seed: 0xCA11_B5EED,
        }
    }
}

impl Calibrator {
    /// Small grid for tests and quick CLI runs.
    pub fn quick(trials: usize) -> Self {
        Self {
            thetas: vec![2.0, 3.0, 4.0],
            sigma_scales: vec![1.0],
            trials,
            ..Default::default()
        }
    }

    /// Candidate parameter sets; the nominal point is always first.
    pub fn candidates(&self, nominal: TrialParams) -> Vec<TrialParams> {
        let mut out = vec![nominal];
        for &theta in &self.thetas {
            for &scale in &self.sigma_scales {
                let cand = TrialParams {
                    sigma_z: nominal.sigma_z * scale,
                    theta,
                    wta_steps: nominal.wta_steps,
                };
                if cand != nominal {
                    out.push(cand);
                }
            }
        }
        out
    }

    /// Deterministic vote accuracy of `engine` at `params` on `cal`.
    pub fn score<E: TrialEngine>(&self, engine: &mut E, params: TrialParams, cal: &Dataset) -> f64 {
        if cal.is_empty() {
            return 0.0;
        }
        let hits = (0..cal.len())
            .filter(|&i| {
                // 2^32 trial indices per image: per-image streams stay
                // disjoint for any realistic trial count.
                let base = self.seed.wrapping_add((i as u64) << 32);
                engine.infer(cal.image(i), params, self.trials, base).prediction()
                    == cal.label(i)
            })
            .count();
        hits as f64 / cal.len() as f64
    }

    /// Grid-search `chip`'s parameters on `cal` and install the argmax.
    pub fn calibrate_chip<E: TrialEngine>(
        &self,
        chip: &mut Chip<E>,
        cal: &Dataset,
    ) -> CalibrationReport {
        let cands = self.candidates(chip.nominal);
        let mut baseline = 0.0;
        let mut best = 0usize;
        let mut best_acc = f64::NEG_INFINITY;
        for (k, &p) in cands.iter().enumerate() {
            let acc = self.score(&mut chip.engine, p, cal);
            if k == 0 {
                baseline = acc;
            }
            if acc > best_acc {
                best_acc = acc;
                best = k;
            }
        }
        chip.params = cands[best];
        chip.calibrated = true;
        CalibrationReport {
            chip: chip.id,
            chosen: cands[best],
            baseline_accuracy: baseline,
            calibrated_accuracy: best_acc,
            candidates_tried: cands.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::VariationModel;
    use crate::nn::{ModelSpec, Weights};

    fn chip(sigma: f64) -> Chip<crate::engine::NativeEngine> {
        let w = Weights::random(ModelSpec::new(vec![784, 8, 4]), 3);
        Chip::program_native(0, &w, &VariationModel::lognormal(sigma), 21)
    }

    fn tiny_set() -> Dataset {
        // 12 deterministic pseudo-images (Dataset rows are 784 pixels)
        // with labels in the 4-class range.
        let mut images = Vec::new();
        let mut labels = Vec::new();
        for i in 0..12usize {
            images.extend((0..784).map(|j| ((i * 7 + j * 3) % 10) as f32 / 10.0));
            labels.push((i % 4) as i32);
        }
        Dataset { images, labels }
    }

    #[test]
    fn nominal_is_first_candidate_and_grid_dedups() {
        let c = Calibrator::default();
        let cands = c.candidates(TrialParams::default());
        assert_eq!(cands[0], TrialParams::default());
        // θ=3.0 × scale=1.0 duplicates the nominal point and is dropped.
        assert_eq!(cands.len(), 1 + 5 * 3 - 1);
        assert!(cands.iter().skip(1).all(|&p| p != cands[0]));
    }

    #[test]
    fn scoring_is_deterministic() {
        let mut ch = chip(0.10);
        let c = Calibrator::quick(5);
        let ds = tiny_set();
        let a = c.score(&mut ch.engine, TrialParams::default(), &ds);
        let b = c.score(&mut ch.engine, TrialParams::default(), &ds);
        assert_eq!(a, b);
    }

    #[test]
    fn calibration_never_hurts_on_the_cal_set() {
        let ds = tiny_set();
        let c = Calibrator::quick(5);
        for sigma in [0.0, 0.05, 0.10, 0.20] {
            let mut ch = chip(sigma);
            let r = c.calibrate_chip(&mut ch, &ds);
            assert!(
                r.calibrated_accuracy >= r.baseline_accuracy,
                "σ={sigma}: {} < {}",
                r.calibrated_accuracy,
                r.baseline_accuracy
            );
            assert_eq!(ch.params, r.chosen);
            // Re-scoring the chosen params reproduces the reported number.
            assert_eq!(c.score(&mut ch.engine, r.chosen, &ds), r.calibrated_accuracy);
        }
    }
}
