//! One fleet chip: a simulated RACA die with its own variation draw.
//!
//! Real deployments never get two identical dies — conductance programming
//! lands lognormally off-target and a few devices stick (Fig. 6 / E-ABL2).
//! A [`Chip`] models one die: the nominal weights are pushed through the
//! weight→conductance mapping, perturbed by the chip's private
//! [`VariationModel`] draw, and read back as the *effective* weights its
//! engine computes with.  Every chip derives its RNG streams from
//! `(fleet_seed, chip_id)`, so a fleet seed reproduces the exact same farm
//! while chips within it stay mutually independent.

use std::sync::Arc;

use crate::crossbar::WeightMapping;
use crate::device::noise::NoiseParams;
use crate::device::{VariationModel, DELTA_F};
use crate::engine::{NativeEngine, PhysicalEngine, TrialEngine, TrialParams};
use crate::nn::Weights;
use crate::stats::GaussianSource;

/// Index of a chip within its fleet.
pub type ChipId = usize;

/// Derive a chip's private seed from the fleet seed (splitmix-style
/// stream separation; id+1 keeps chip 0 distinct from the fleet seed).
pub fn chip_seed(fleet_seed: u64, id: ChipId) -> u64 {
    fleet_seed ^ (id as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)
}

/// Program-and-read-back: map each nominal weight to a conductance
/// (Eq. 7), apply the die's programming variation, and map back.  The
/// returned weights are what the die *actually* computes with.
pub fn program_weights(
    nominal: &Weights,
    variation: &VariationModel,
    gauss: &mut GaussianSource,
) -> Weights {
    let mapping = WeightMapping::default();
    let mut out = nominal.clone();
    for m in out.mats.iter_mut() {
        for w in m.iter_mut() {
            let g = mapping.weight_to_g(*w as f64);
            let gv = variation.apply(g, mapping.g_min, mapping.g_max, gauss);
            *w = mapping.g_to_weight(gv) as f32;
        }
    }
    out
}

/// One simulated die: engine + its active (calibrated) trial parameters.
pub struct Chip<E> {
    pub id: ChipId,
    pub engine: E,
    /// Design-point parameters (calibration searches around these).
    pub nominal: TrialParams,
    /// Active parameters (== `nominal` until calibrated).
    pub params: TrialParams,
    /// Whether a calibrator has validated `params` (even if it chose the
    /// nominal point — that is still a calibrated chip).
    pub calibrated: bool,
    /// This chip's private seed (derived from the fleet seed).
    pub seed: u64,
}

impl<E: TrialEngine> Chip<E> {
    /// Classify one image with the chip's active parameters; returns the
    /// majority-vote prediction.
    pub fn classify(&mut self, x: &[f32], trials: usize, base_trial: u64) -> i32 {
        self.engine.infer(x, self.params, trials, base_trial).prediction()
    }
}

impl Chip<NativeEngine> {
    /// Program a native-engine die from nominal weights.
    pub fn program_native(
        id: ChipId,
        nominal_weights: &Weights,
        variation: &VariationModel,
        fleet_seed: u64,
    ) -> Self {
        Self::program_native_global(id, id, nominal_weights, variation, fleet_seed)
    }

    /// Program a die whose RNG identity derives from `global` — its
    /// fleet-wide chip id under a composed deployment tree
    /// ([`crate::serve::plan`] numbers every physical die once across the
    /// whole topology) — while `id` stays the local index within its
    /// serving group.  `global == id` reproduces a flat fleet exactly.
    pub fn program_native_global(
        id: ChipId,
        global: ChipId,
        nominal_weights: &Weights,
        variation: &VariationModel,
        fleet_seed: u64,
    ) -> Self {
        let seed = chip_seed(fleet_seed, global);
        // Separate stream for programming so trial RNG stays comparable
        // across variation settings.
        let mut gauss = GaussianSource::new(seed ^ 0xD1E_5EED);
        let w = program_weights(nominal_weights, variation, &mut gauss);
        Chip {
            id,
            engine: NativeEngine::new(Arc::new(w), seed),
            nominal: TrialParams::default(),
            params: TrialParams::default(),
            calibrated: false,
            seed,
        }
    }
}

impl Chip<PhysicalEngine> {
    /// Program a full analog-simulation die (validation-grade, slow).
    pub fn program_physical(
        id: ChipId,
        nominal_weights: &Weights,
        variation: &VariationModel,
        tile: usize,
        fleet_seed: u64,
    ) -> Self {
        Self::program_physical_global(id, id, nominal_weights, variation, tile, fleet_seed)
    }

    /// Physical twin of [`Chip::program_native_global`]: the die's RNG
    /// identity comes from `global` (its fleet-wide chip id under a
    /// composed deployment tree), `id` stays the local index within its
    /// serving group.
    pub fn program_physical_global(
        id: ChipId,
        global: ChipId,
        nominal_weights: &Weights,
        variation: &VariationModel,
        tile: usize,
        fleet_seed: u64,
    ) -> Self {
        let seed = chip_seed(fleet_seed, global);
        let engine = PhysicalEngine::program(
            nominal_weights,
            tile,
            variation,
            &NoiseParams::thermal_only(DELTA_F),
            1.0,
            seed,
        );
        Chip {
            id,
            engine,
            nominal: TrialParams::default(),
            params: TrialParams::default(),
            calibrated: false,
            seed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ModelSpec;

    fn nominal() -> Weights {
        Weights::random(ModelSpec::new(vec![12, 8, 4]), 3)
    }

    #[test]
    fn programming_is_reproducible_per_seed_and_chip() {
        let w = nominal();
        let v = VariationModel::lognormal(0.10);
        let a = Chip::program_native(2, &w, &v, 77);
        let b = Chip::program_native(2, &w, &v, 77);
        assert_eq!(a.engine.weights.mats, b.engine.weights.mats);
        assert_eq!(a.seed, b.seed);
    }

    #[test]
    fn global_identity_decides_the_die_not_the_local_index() {
        let w = nominal();
        let v = VariationModel::lognormal(0.10);
        // A replica group's local chip 0 with global id 3 is the *same
        // physical die* as a flat fleet's chip 3 — and not chip 0.
        let flat = Chip::program_native(3, &w, &v, 77);
        let grouped = Chip::program_native_global(0, 3, &w, &v, 77);
        assert_eq!(flat.engine.weights.mats, grouped.engine.weights.mats);
        assert_eq!(flat.seed, grouped.seed);
        assert_eq!(grouped.id, 0);
        let local = Chip::program_native(0, &w, &v, 77);
        assert_ne!(local.engine.weights.mats, grouped.engine.weights.mats);
    }

    #[test]
    fn chips_differ_from_each_other_and_from_nominal() {
        let w = nominal();
        let v = VariationModel::lognormal(0.10);
        let a = Chip::program_native(0, &w, &v, 77);
        let b = Chip::program_native(1, &w, &v, 77);
        assert_ne!(a.engine.weights.mats, b.engine.weights.mats);
        assert_ne!(a.engine.weights.mats, w.mats);
    }

    #[test]
    fn ideal_variation_is_identity_modulo_clip() {
        let w = nominal();
        let chip = Chip::program_native(0, &w, &VariationModel::default(), 5);
        for (a, b) in chip.engine.weights.mats.iter().flatten().zip(w.mats.iter().flatten()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn programmed_weights_stay_in_clip_range() {
        let w = nominal();
        let v = VariationModel::with_defects(0.3, 0.02, 0.01);
        let chip = Chip::program_native(1, &w, &v, 9);
        chip.engine.weights.validate().expect("clip range preserved");
    }

    #[test]
    fn physical_chip_programs_and_decides() {
        let w = nominal();
        let mut chip =
            Chip::program_physical(0, &w, &VariationModel::lognormal(0.05), 8, 13);
        let x = vec![0.4f32; 12];
        let win = chip.classify(&x, 5, 0);
        assert!((-1..4).contains(&win));
    }
}
