//! Per-chip and fleet-aggregate serving statistics.
//!
//! The coordinator's [`crate::coordinator::Metrics`] counts the request
//! loop; these counters describe the *chips* behind it — who served what,
//! how well, and how fast — so operators can see one replica dragging the
//! farm down.  Snapshots are plain data: cheap to clone, merge and print.

use std::fmt;

use super::chip::ChipId;

/// Cumulative serving counters for one chip.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ChipStats {
    /// Requests served.
    pub served: u64,
    /// Stochastic trials executed.
    pub trials: u64,
    /// Requests where every trial abstained.
    pub abstentions: u64,
    /// Served requests that carried a label.
    pub labeled: u64,
    /// Correct predictions among labeled requests.
    pub hits: u64,
    /// Total busy time [µs].
    pub busy_us: u64,
    /// Worst single-request latency [µs].
    pub max_latency_us: u64,
    /// Total queue wait [µs]: end-to-end latency minus on-chip service
    /// time, summed.  Separates "the die is slow" from "the die is
    /// swamped" in the telemetry tree ([`ChipStats::mean_wait_us`]).
    pub wait_us: u64,
}

impl ChipStats {
    pub fn record(&mut self, trials: u64, abstained: bool, correct: Option<bool>, latency_us: u64) {
        self.served += 1;
        self.trials += trials;
        if abstained {
            self.abstentions += 1;
        }
        if let Some(c) = correct {
            self.labeled += 1;
            if c {
                self.hits += 1;
            }
        }
        self.busy_us += latency_us;
        self.max_latency_us = self.max_latency_us.max(latency_us);
    }

    /// Accuracy over labeled traffic (None when unlabeled).
    pub fn accuracy(&self) -> Option<f64> {
        if self.labeled == 0 {
            None
        } else {
            Some(self.hits as f64 / self.labeled as f64)
        }
    }

    /// Mean latency per served request [µs].
    pub fn mean_latency_us(&self) -> f64 {
        if self.served == 0 {
            return 0.0;
        }
        self.busy_us as f64 / self.served as f64
    }

    /// Fold in one request's queue wait (end-to-end minus service time).
    pub fn record_wait(&mut self, wait_us: u64) {
        self.wait_us += wait_us;
    }

    /// Mean queue wait per served request [µs].
    pub fn mean_wait_us(&self) -> f64 {
        if self.served == 0 {
            return 0.0;
        }
        self.wait_us as f64 / self.served as f64
    }

    pub fn merge(&mut self, other: &ChipStats) {
        self.served += other.served;
        self.trials += other.trials;
        self.abstentions += other.abstentions;
        self.labeled += other.labeled;
        self.hits += other.hits;
        self.busy_us += other.busy_us;
        self.max_latency_us = self.max_latency_us.max(other.max_latency_us);
        self.wait_us += other.wait_us;
    }
}

/// Point-in-time copy of every chip's stats.
#[derive(Debug, Clone, Default)]
pub struct FleetSnapshot {
    pub chips: Vec<(ChipId, ChipStats)>,
}

impl FleetSnapshot {
    /// Fleet-wide totals.
    pub fn aggregate(&self) -> ChipStats {
        let mut total = ChipStats::default();
        for (_, s) in &self.chips {
            total.merge(s);
        }
        total
    }

    /// Largest served-count imbalance between two *participating* chips
    /// (router QA).  Chips that served nothing — evicted dies, or farms
    /// larger than the workload — are excluded so eviction doesn't read
    /// as a routing failure.
    pub fn load_imbalance(&self) -> u64 {
        let served: Vec<u64> = self
            .chips
            .iter()
            .map(|(_, s)| s.served)
            .filter(|&n| n > 0)
            .collect();
        match (served.iter().max(), served.iter().min()) {
            (Some(mx), Some(mn)) => mx - mn,
            _ => 0,
        }
    }
}

impl fmt::Display for FleetSnapshot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (id, s) in &self.chips {
            let acc = s
                .accuracy()
                .map(|a| format!("{:.1}%", a * 100.0))
                .unwrap_or_else(|| "n/a".into());
            writeln!(
                f,
                "chip {id:>2}: served {:>6}  trials {:>7}  acc {acc:>6}  abstain {:>4}  mean {:>7.0}µs  max {:>6}µs",
                s.served, s.trials, s.abstentions, s.mean_latency_us(), s.max_latency_us
            )?;
        }
        let t = self.aggregate();
        let acc = t
            .accuracy()
            .map(|a| format!("{:.1}%", a * 100.0))
            .unwrap_or_else(|| "n/a".into());
        write!(
            f,
            "fleet  : served {:>6}  trials {:>7}  acc {acc:>6}  abstain {:>4}  imbalance {}",
            t.served, t.trials, t.abstentions, self.load_imbalance()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_accuracy() {
        let mut s = ChipStats::default();
        s.record(9, false, Some(true), 120);
        s.record(9, false, Some(false), 80);
        s.record(9, true, None, 400);
        assert_eq!(s.served, 3);
        assert_eq!(s.trials, 27);
        assert_eq!(s.abstentions, 1);
        assert_eq!(s.accuracy(), Some(0.5));
        assert_eq!(s.max_latency_us, 400);
        assert!((s.mean_latency_us() - 200.0).abs() < 1e-9);
        s.record_wait(30);
        s.record_wait(60);
        assert!((s.mean_wait_us() - 30.0).abs() < 1e-9);
        let mut other = ChipStats::default();
        other.record_wait(10);
        other.merge(&s);
        assert_eq!(other.wait_us, 100);
    }

    #[test]
    fn aggregate_and_imbalance() {
        let mut a = ChipStats::default();
        let mut b = ChipStats::default();
        for _ in 0..10 {
            a.record(5, false, Some(true), 100);
        }
        for _ in 0..4 {
            b.record(5, false, Some(false), 300);
        }
        let snap = FleetSnapshot { chips: vec![(0, a), (1, b)] };
        let t = snap.aggregate();
        assert_eq!(t.served, 14);
        assert_eq!(t.trials, 70);
        assert_eq!(t.accuracy(), Some(10.0 / 14.0));
        assert_eq!(snap.load_imbalance(), 6);
        // An idle (evicted / never-routed) chip must not inflate imbalance.
        let mut snap2 = snap.clone();
        snap2.chips.push((2, ChipStats::default()));
        assert_eq!(snap2.load_imbalance(), 6);
        let text = format!("{snap}");
        assert!(text.contains("chip  0"));
        assert!(text.contains("fleet"));
    }
}
