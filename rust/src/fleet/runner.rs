//! Fleet-backed [`TrialRunner`]: the coordinator's scheduler drives a whole
//! farm instead of one engine.
//!
//! `run` shards each packed batch across the healthy chips (contiguous
//! row ranges, one scoped thread per chip) and reassembles winners in row
//! order.  Each chip executes with *its own* calibrated parameters —
//! the scheduler's nominal `TrialParams` only applies to chips that were
//! never calibrated — and each row's trial seed depends only on the batch
//! seed and row index, so routing never changes a row's RNG stream.
//!
//! Per-chip [`Metrics`] record batches/rows/latency, and
//! [`FleetRunner::combined_metrics`] folds them with
//! [`MetricsSnapshot::combine`] for the fleet-aggregate view.

use std::sync::Mutex;

use anyhow::Result;

use crate::coordinator::{Metrics, MetricsSnapshot, TrialRunner};
use crate::engine::{TrialEngine, TrialParams};

use super::chip::Chip;
use super::Fleet;

/// `Clone + Send`-free owner of the chips behind a scheduler.
pub struct FleetRunner<E> {
    chips: Vec<Mutex<Chip<E>>>,
    metrics: Vec<std::sync::Arc<Metrics>>,
    /// Preferred rows per scheduler batch (scales with fleet width).
    rows_per_batch: usize,
}

impl<E: TrialEngine> FleetRunner<E> {
    /// Take ownership of a fleet's healthy chips.
    pub fn new(fleet: Fleet<E>) -> Self {
        let healthy = fleet.health.healthy();
        let chips: Vec<Mutex<Chip<E>>> = fleet
            .chips
            .into_iter()
            .filter(|c| healthy.contains(&c.id))
            .map(Mutex::new)
            .collect();
        let n = chips.len().max(1);
        let metrics = (0..chips.len()).map(|_| Metrics::new()).collect();
        Self { chips, metrics, rows_per_batch: 32 * n }
    }

    pub fn num_chips(&self) -> usize {
        self.chips.len()
    }

    /// Per-chip scheduler-side metrics (batches, rows, latency).
    pub fn per_chip_metrics(&self) -> Vec<MetricsSnapshot> {
        self.metrics.iter().map(|m| m.snapshot()).collect()
    }

    /// Fleet-aggregate metrics.
    pub fn combined_metrics(&self) -> MetricsSnapshot {
        self.per_chip_metrics()
            .into_iter()
            .reduce(|a, b| a.combine(&b))
            .unwrap_or_else(|| Metrics::new().snapshot())
    }
}

impl<E: TrialEngine> TrialRunner for FleetRunner<E> {
    fn run(&self, x: &[f32], rows: usize, seed: u32, p: TrialParams) -> Result<Vec<i32>> {
        anyhow::ensure!(!self.chips.is_empty(), "fleet has no healthy chips");
        anyhow::ensure!(rows > 0 && x.len() % rows == 0, "bad trial input shape");
        let features = x.len() / rows;
        let n = self.chips.len().min(rows);
        // Contiguous shards, sizes differing by at most one row.
        let base = rows / n;
        let extra = rows % n;
        let mut shards: Vec<(usize, usize)> = Vec::with_capacity(n); // (start, len)
        let mut start = 0usize;
        for k in 0..n {
            let len = base + usize::from(k < extra);
            shards.push((start, len));
            start += len;
        }
        let mut winners = vec![-1i32; rows];
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(n);
            for (k, &(lo, len)) in shards.iter().enumerate() {
                let chip = &self.chips[k];
                let metrics = &self.metrics[k];
                handles.push(s.spawn(move || {
                    let t0 = std::time::Instant::now();
                    let mut chip = chip.lock().unwrap();
                    // Calibrated chips use their own validated params (even
                    // when calibration chose the nominal point); only chips
                    // never calibrated follow the scheduler.
                    let cp = if chip.calibrated { chip.params } else { p };
                    // Rows repeating one image (k trials of one request in
                    // a packed batch) run as trial blocks — one weight
                    // sweep per block (§Perf iteration 5).  Each row keeps
                    // its `seed + row` stream, so routing and grouping
                    // never change a winner.
                    let shard = &x[lo * features..(lo + len) * features];
                    let mut out = vec![-1i32; len];
                    for g in crate::engine::group_equal_rows(shard, features, len) {
                        let xi = &shard[g[0] * features..(g[0] + 1) * features];
                        let idx: Vec<u64> = g
                            .iter()
                            .map(|&r| (seed as u64).wrapping_add((lo + r) as u64))
                            .collect();
                        let winners_g = chip.engine.trial_indices(xi, cp, &idx);
                        for (&r, &w) in g.iter().zip(&winners_g) {
                            out[r] = w;
                        }
                    }
                    use std::sync::atomic::Ordering::Relaxed;
                    metrics.batches_executed.fetch_add(1, Relaxed);
                    metrics.rows_packed.fetch_add(len as u64, Relaxed);
                    metrics.trials_executed.fetch_add(len as u64, Relaxed);
                    metrics.record_latency(t0.elapsed());
                    out
                }));
            }
            for (h, &(lo, len)) in handles.into_iter().zip(shards.iter()) {
                let part = h.join().expect("fleet shard thread panicked");
                winners[lo..lo + len].copy_from_slice(&part);
            }
        });
        Ok(winners)
    }

    fn preferred_batch(&self) -> usize {
        self.rows_per_batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Scheduler, SchedulerConfig};
    use crate::device::VariationModel;
    use crate::fleet::RoutePolicy;
    use crate::nn::{ModelSpec, Weights};

    fn runner(n_chips: usize) -> FleetRunner<crate::engine::NativeEngine> {
        let w = Weights::random(ModelSpec::new(vec![784, 12, 10]), 5);
        let fleet = Fleet::program_native(
            &w,
            n_chips,
            &VariationModel::lognormal(0.05),
            RoutePolicy::RoundRobin,
            99,
        );
        FleetRunner::new(fleet)
    }

    #[test]
    fn shards_cover_all_rows_in_order() {
        let r = runner(3);
        let rows = 10usize;
        let x: Vec<f32> = (0..rows * 784).map(|i| (i % 11) as f32 / 11.0).collect();
        let w1 = r.run(&x, rows, 42, TrialParams::default()).unwrap();
        assert_eq!(w1.len(), rows);
        assert!(w1.iter().all(|&v| (-1..10).contains(&v)));
        // Deterministic given the same seed.
        let w2 = r.run(&x, rows, 42, TrialParams::default()).unwrap();
        assert_eq!(w1, w2);
        let m = r.combined_metrics();
        assert_eq!(m.rows_packed, 2 * rows as u64);
        assert_eq!(m.batches_executed, 6);
    }

    #[test]
    fn fewer_rows_than_chips_still_works() {
        let r = runner(4);
        let x: Vec<f32> = vec![0.3; 784];
        let w = r.run(&x, 1, 7, TrialParams::default()).unwrap();
        assert_eq!(w.len(), 1);
    }

    #[test]
    fn scheduler_drives_the_fleet_end_to_end() {
        let r = runner(2);
        let mut cfg = SchedulerConfig::default();
        cfg.batch_size = 16;
        let mut sched = Scheduler::new(r, cfg, Metrics::new());
        for i in 0..5u64 {
            sched
                .submit(
                    crate::coordinator::InferRequest::new(i, vec![0.4; 784])
                        .with_budget(8, 0.0),
                )
                .unwrap();
        }
        let done = sched.run_to_completion().unwrap();
        assert_eq!(done.len(), 5);
        for resp in &done {
            assert_eq!(resp.trials_used, 8);
        }
    }

    #[test]
    fn bad_shapes_are_rejected() {
        let r = runner(2);
        assert!(r.run(&[0.0; 100], 3, 1, TrialParams::default()).is_err());
        assert!(r.run(&[], 0, 1, TrialParams::default()).is_err());
    }
}
