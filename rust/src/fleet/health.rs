//! Per-chip health tracking: rolling accuracy/latency windows, drift
//! detection and eviction.
//!
//! ReRAM dies drift (retention loss, read disturb); at fleet level that
//! shows up as one replica's rolling accuracy sagging below its peers.
//! The monitor keeps a bounded window of recent labeled outcomes and
//! latencies per chip, flags chips whose rolling accuracy falls more than
//! `drift_margin` under the fleet median (→ recalibrate), and evicts
//! chips below the hard `evict_floor` (→ drop from routing).

use std::collections::VecDeque;
use std::sync::Arc;

use crate::telemetry::{EventKind, Journal};

use super::chip::ChipId;

/// Monitor thresholds.
#[derive(Debug, Clone)]
pub struct HealthConfig {
    /// Rolling window length (labeled outcomes and latency samples).
    pub window: usize,
    /// Minimum labeled samples before a chip can be flagged.
    pub min_samples: usize,
    /// Flag a chip when rolling accuracy < fleet median − this margin.
    pub drift_margin: f64,
    /// Evict a chip when rolling accuracy < this absolute floor.
    pub evict_floor: f64,
}

impl Default for HealthConfig {
    fn default() -> Self {
        Self { window: 128, min_samples: 24, drift_margin: 0.15, evict_floor: 0.25 }
    }
}

/// Rolling state for one chip.
#[derive(Debug, Default)]
pub struct ChipHealth {
    correct: VecDeque<bool>,
    latency_us: VecDeque<u64>,
    pub served: u64,
    pub abstained: u64,
    pub evicted: bool,
    pub recalibrations: u32,
}

impl ChipHealth {
    /// Rolling accuracy over the labeled window (None until any labels).
    pub fn rolling_accuracy(&self) -> Option<f64> {
        if self.correct.is_empty() {
            return None;
        }
        let hits = self.correct.iter().filter(|&&c| c).count();
        Some(hits as f64 / self.correct.len() as f64)
    }

    /// Labeled samples currently in the window.
    pub fn labeled_samples(&self) -> usize {
        self.correct.len()
    }

    /// Mean latency over the window [µs].
    pub fn mean_latency_us(&self) -> f64 {
        if self.latency_us.is_empty() {
            return 0.0;
        }
        self.latency_us.iter().sum::<u64>() as f64 / self.latency_us.len() as f64
    }

    /// Abstention rate over everything served.
    pub fn abstention_rate(&self) -> f64 {
        if self.served == 0 {
            return 0.0;
        }
        self.abstained as f64 / self.served as f64
    }
}

/// Outcome of one [`HealthMonitor::steer`] pass.
#[derive(Debug, Clone)]
pub struct SteerReport {
    /// Members evicted this pass (floor-breakers, never the last one).
    pub evicted: Vec<ChipId>,
    /// Members sagging under the group median (recalibration candidates —
    /// actionable only where the caller owns calibratable chips).
    pub drifting: Vec<ChipId>,
    /// Refreshed router traffic weights.
    pub weights: Vec<f64>,
}

/// Fleet-wide health state.
#[derive(Debug)]
pub struct HealthMonitor {
    pub cfg: HealthConfig,
    chips: Vec<ChipHealth>,
    /// Event sink + per-member labels (`die#3`, `remote:a:7433`, …):
    /// evictions, reweighs and recalibrations become journal events.
    journal: Option<(Arc<Journal>, Vec<String>)>,
}

impl HealthMonitor {
    pub fn new(n_chips: usize, cfg: HealthConfig) -> Self {
        Self {
            cfg,
            chips: (0..n_chips).map(|_| ChipHealth::default()).collect(),
            journal: None,
        }
    }

    /// Route health events (evict/reweigh/recalibrate) into `journal`,
    /// naming members by `labels[chip]` (falls back to `chip#<id>`).
    pub fn attach_journal(&mut self, journal: Arc<Journal>, labels: Vec<String>) {
        self.journal = Some((journal, labels));
    }

    fn log(&self, kind: EventKind, chip: Option<ChipId>, detail: String) {
        if let Some((journal, labels)) = &self.journal {
            let node = match chip {
                Some(c) => labels.get(c).cloned().unwrap_or_else(|| format!("chip#{c}")),
                None => "health".to_string(),
            };
            journal.record(kind, &node, detail);
        }
    }

    pub fn len(&self) -> usize {
        self.chips.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chips.is_empty()
    }

    pub fn chip(&self, id: ChipId) -> &ChipHealth {
        &self.chips[id]
    }

    /// Record one served request on `chip`.  `correct` is `Some` when the
    /// request carried a label (probe traffic); `abstained` means every
    /// trial timed out.
    pub fn record(&mut self, chip: ChipId, correct: Option<bool>, abstained: bool, latency_us: u64) {
        let h = &mut self.chips[chip];
        h.served += 1;
        if abstained {
            h.abstained += 1;
        }
        if let Some(c) = correct {
            if h.correct.len() >= self.cfg.window {
                h.correct.pop_front();
            }
            h.correct.push_back(c);
        }
        if h.latency_us.len() >= self.cfg.window {
            h.latency_us.pop_front();
        }
        h.latency_us.push_back(latency_us);
    }

    /// Ids still eligible for routing.
    pub fn healthy(&self) -> Vec<ChipId> {
        (0..self.chips.len()).filter(|&i| !self.chips[i].evicted).collect()
    }

    /// Median rolling accuracy over healthy chips with enough samples.
    pub fn median_accuracy(&self) -> Option<f64> {
        let mut accs: Vec<f64> = self
            .chips
            .iter()
            .filter(|h| !h.evicted && h.labeled_samples() >= self.cfg.min_samples)
            .filter_map(|h| h.rolling_accuracy())
            .collect();
        if accs.is_empty() {
            return None;
        }
        accs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(accs[accs.len() / 2])
    }

    /// Chips whose rolling accuracy sags below the fleet median by more
    /// than the drift margin (candidates for recalibration).
    pub fn drifting(&self) -> Vec<ChipId> {
        let Some(median) = self.median_accuracy() else { return Vec::new() };
        (0..self.chips.len())
            .filter(|&i| {
                let h = &self.chips[i];
                !h.evicted
                    && h.labeled_samples() >= self.cfg.min_samples
                    && h.rolling_accuracy().is_some_and(|a| a < median - self.cfg.drift_margin)
            })
            .collect()
    }

    /// Chips below the absolute accuracy floor (candidates for eviction).
    pub fn evictable(&self) -> Vec<ChipId> {
        (0..self.chips.len())
            .filter(|&i| {
                let h = &self.chips[i];
                !h.evicted
                    && h.labeled_samples() >= self.cfg.min_samples
                    && h.rolling_accuracy().is_some_and(|a| a < self.cfg.evict_floor)
            })
            .collect()
    }

    /// Drop a chip from routing.
    pub fn evict(&mut self, chip: ChipId) {
        if !self.chips[chip].evicted {
            let acc = self.chips[chip].rolling_accuracy();
            self.log(
                EventKind::HealthEvict,
                Some(chip),
                match acc {
                    Some(a) => format!("rolling accuracy {a:.2} < floor {:.2}", self.cfg.evict_floor),
                    None => "evicted by caller".to_string(),
                },
            );
        }
        self.chips[chip].evicted = true;
    }

    /// One periodic steering pass, shared by every serving layer that
    /// wraps a monitor (the replicated backend's workers, the topology
    /// router over child backends): evict floor-breakers — but never the
    /// last healthy member, a degraded group that still answers beats a
    /// submit path that hard-errors — and report who is drifting plus the
    /// refreshed traffic weights.
    pub fn steer(&mut self) -> SteerReport {
        let mut evicted = Vec::new();
        for c in self.evictable() {
            if self.healthy().len() > 1 {
                self.evict(c);
                evicted.push(c);
            }
        }
        let report =
            SteerReport { evicted, drifting: self.drifting(), weights: self.traffic_weights() };
        for &c in &report.drifting {
            let acc = self.chips[c].rolling_accuracy().unwrap_or(0.0);
            self.log(
                EventKind::HealthRecalibrate,
                Some(c),
                format!("drifting: rolling accuracy {acc:.2} under fleet median"),
            );
        }
        self.log(
            EventKind::HealthReweigh,
            None,
            format!(
                "weights {:?}",
                report.weights.iter().map(|w| (w * 100.0).round() / 100.0).collect::<Vec<_>>()
            ),
        );
        report
    }

    /// Reset a chip's rolling window after recalibration (old samples no
    /// longer describe its behaviour).
    pub fn note_recalibrated(&mut self, chip: ChipId) {
        self.log(EventKind::HealthRecalibrate, Some(chip), "window reset after recalibration".into());
        let h = &mut self.chips[chip];
        h.recalibrations += 1;
        h.correct.clear();
    }

    /// Live traffic weights for [`super::Router`]'s weighted policy: how
    /// much in-flight work each die should carry *right now*, relative to
    /// its peers.  Evicted dies weigh 0.  A die's weight is its speed
    /// factor (fleet mean latency / its mean latency, clamped to [¼, 4])
    /// discounted by its abstention rate and — when labeled probes are in
    /// the window — its rolling accuracy.  This is the monitor *steering*
    /// traffic continuously, not just the evict/recalibrate cliff edges.
    pub fn traffic_weights(&self) -> Vec<f64> {
        let lats: Vec<f64> = self
            .chips
            .iter()
            .filter(|h| !h.evicted && h.served > 0)
            .map(|h| h.mean_latency_us())
            .filter(|&l| l > 0.0)
            .collect();
        let fleet_mean = if lats.is_empty() {
            0.0
        } else {
            lats.iter().sum::<f64>() / lats.len() as f64
        };
        self.chips
            .iter()
            .map(|h| {
                if h.evicted {
                    return 0.0;
                }
                let speed = match (fleet_mean > 0.0, h.mean_latency_us()) {
                    (true, l) if l > 0.0 => (fleet_mean / l).clamp(0.25, 4.0),
                    _ => 1.0,
                };
                let yield_rate = (1.0 - h.abstention_rate()).max(0.05);
                let acc = h.rolling_accuracy().unwrap_or(1.0).max(0.05);
                speed * yield_rate * acc
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn monitor(n: usize) -> HealthMonitor {
        HealthMonitor::new(
            n,
            HealthConfig { window: 16, min_samples: 8, drift_margin: 0.2, evict_floor: 0.3 },
        )
    }

    fn feed(m: &mut HealthMonitor, chip: ChipId, hits: usize, misses: usize) {
        for _ in 0..hits {
            m.record(chip, Some(true), false, 100);
        }
        for _ in 0..misses {
            m.record(chip, Some(false), false, 100);
        }
    }

    #[test]
    fn rolling_window_bounds_and_accuracy() {
        let mut m = monitor(1);
        feed(&mut m, 0, 16, 16); // window keeps only the last 16 (all misses)
        assert_eq!(m.chip(0).labeled_samples(), 16);
        assert_eq!(m.chip(0).rolling_accuracy(), Some(0.0));
        assert_eq!(m.chip(0).served, 32);
    }

    #[test]
    fn drift_detection_flags_the_sagging_chip() {
        let mut m = monitor(3);
        feed(&mut m, 0, 15, 1);
        feed(&mut m, 1, 14, 2);
        feed(&mut m, 2, 6, 10); // well below median − 0.2
        assert_eq!(m.drifting(), vec![2]);
        assert!(m.evictable().is_empty());
    }

    #[test]
    fn eviction_removes_from_routing_and_median() {
        let mut m = monitor(3);
        feed(&mut m, 0, 16, 0);
        feed(&mut m, 1, 16, 0);
        feed(&mut m, 2, 1, 15);
        assert_eq!(m.evictable(), vec![2]);
        m.evict(2);
        assert_eq!(m.healthy(), vec![0, 1]);
        assert!(m.evictable().is_empty());
        assert_eq!(m.median_accuracy(), Some(1.0));
    }

    #[test]
    fn steer_evicts_floor_breakers_but_never_the_last_member() {
        let mut m = monitor(2);
        feed(&mut m, 0, 16, 0);
        feed(&mut m, 1, 1, 15);
        let r = m.steer();
        assert_eq!(r.evicted, vec![1]);
        assert_eq!(r.weights[1], 0.0);
        assert_eq!(m.healthy(), vec![0]);
        // Now chip 0 collapses too — it stays routable anyway.
        feed(&mut m, 0, 0, 16);
        let r = m.steer();
        assert!(r.evicted.is_empty(), "last member must survive: {r:?}");
        assert_eq!(m.healthy(), vec![0]);
    }

    #[test]
    fn recalibration_resets_the_window() {
        let mut m = monitor(2);
        feed(&mut m, 0, 16, 0);
        feed(&mut m, 1, 2, 14);
        m.note_recalibrated(1);
        assert_eq!(m.chip(1).labeled_samples(), 0);
        assert_eq!(m.chip(1).recalibrations, 1);
        assert!(m.drifting().is_empty()); // not enough fresh samples
    }

    #[test]
    fn traffic_weights_follow_speed_health_and_eviction() {
        let mut m = monitor(3);
        // Chip 0: fast and accurate; chip 1: 4x slower; chip 2: evicted.
        for _ in 0..8 {
            m.record(0, Some(true), false, 100);
            m.record(1, Some(true), false, 400);
            m.record(2, Some(true), false, 100);
        }
        m.evict(2);
        let w = m.traffic_weights();
        assert_eq!(w.len(), 3);
        assert!(w[0] > w[1], "fast chip must outweigh slow chip: {w:?}");
        assert_eq!(w[2], 0.0, "evicted chip must get zero traffic");
        // Abstentions discount the weight further.
        let mut m2 = monitor(2);
        for _ in 0..8 {
            m2.record(0, None, false, 100);
            m2.record(1, None, true, 100); // always abstains
        }
        let w2 = m2.traffic_weights();
        assert!(w2[0] > 5.0 * w2[1], "abstaining chip must be starved: {w2:?}");
    }

    #[test]
    fn journal_records_evictions_and_reweighs() {
        let j = Journal::new(64);
        let mut m = monitor(2);
        m.attach_journal(j.clone(), vec!["die#0".into(), "die#1".into()]);
        feed(&mut m, 0, 16, 0);
        feed(&mut m, 1, 1, 15);
        let r = m.steer();
        assert_eq!(r.evicted, vec![1]);
        let evs = j.tail(64);
        assert!(
            evs.iter().any(|e| e.kind == EventKind::HealthEvict && e.node == "die#1"),
            "eviction must land in the journal: {evs:?}"
        );
        assert!(evs.iter().any(|e| e.kind == EventKind::HealthReweigh), "{evs:?}");
        // Re-evicting an already-evicted chip adds no duplicate event.
        let evictions = evs.iter().filter(|e| e.kind == EventKind::HealthEvict).count();
        m.evict(1);
        let after =
            j.tail(64).iter().filter(|e| e.kind == EventKind::HealthEvict).count();
        assert_eq!(after, evictions);
    }

    #[test]
    fn abstentions_and_latency_tracked() {
        let mut m = monitor(1);
        m.record(0, None, true, 500);
        m.record(0, None, false, 300);
        assert!((m.chip(0).abstention_rate() - 0.5).abs() < 1e-12);
        assert!((m.chip(0).mean_latency_us() - 400.0).abs() < 1e-12);
        assert_eq!(m.chip(0).rolling_accuracy(), None);
    }
}
