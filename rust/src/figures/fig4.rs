//! Fig. 4 — Sigmoid-neuron simulations.
//!
//! (a,b) sampling convergence of two example neurons (P ≈ 0.014 / 0.745);
//! (c)–(f) activation probability P(Z) against the logistic reference
//! while sweeping the four SNR knobs: Vr, G0, Δf, N_col.  The physical
//! samples come from the crossbar array simulator (amperes, aggregate
//! thermal noise); the analytic curves are Φ(κ·Z) (Eq. 13).

use anyhow::Result;

use crate::crossbar::{CrossbarArray, ReadMode, WeightMapping};
use crate::device::noise::NoiseParams;
use crate::device::variation::VariationModel;
use crate::device::DELTA_F;
use crate::stats::erf::{logistic, norm_cdf};
use crate::stats::GaussianSource;
use crate::util::table::Table;

use super::common::{linspace, results_dir};

/// Empirical firing probability of a physical column programmed to mean
/// weight-sum `z`, read `n` times at voltage `vr` with bandwidth `df`.
fn empirical_p(z: f64, n_col: usize, vr: f64, df: f64, n: usize, seed: u64) -> f64 {
    let mapping = WeightMapping::default();
    let w_each = (z / n_col as f64).clamp(-4.0, 4.0) as f32;
    let mut gauss = GaussianSource::new(seed);
    let mut arr = CrossbarArray::program(
        n_col,
        1,
        &vec![w_each; n_col],
        mapping,
        &VariationModel::default(),
        NoiseParams::thermal_only(df),
        &mut gauss,
    );
    let v = vec![vr; n_col];
    let mut out = [0.0f64];
    let mut fired = 0usize;
    for _ in 0..n {
        arr.read_differential(&v, ReadMode::ColumnAggregate, &mut out, &mut gauss);
        if out[0] > 0.0 {
            fired += 1;
        }
    }
    fired as f64 / n as f64
}

/// Panels (a,b): sampling traces of two example activation probabilities.
pub fn panel_ab(samples: usize) -> Result<()> {
    let mapping = WeightMapping::default();
    let n_col = 785;
    let vr = mapping.calibrate_vr(n_col, DELTA_F, 1.0);
    let mut t = Table::new(
        "Fig 4(a,b) — example neurons: cumulative firing frequency",
        &["samples", "P_hat(a)", "P_hat(b)", "target(a)=0.014", "target(b)=0.745"],
    );
    // Choose Z so the *physical* activation probability Φ(Z/1.702) hits
    // the paper's example values (in the deep tail the probit and logit
    // differ — the hardware follows the probit, Eq. 13).
    let targets = [0.014f64, 0.745];
    let zs: Vec<f64> =
        targets.iter().map(|&p| 1.702 * crate::stats::erf::norm_ppf(p)).collect();

    let mut cum = [0usize; 2];
    let mut rows: Vec<(usize, f64, f64)> = Vec::new();
    let mapping2 = WeightMapping::default();
    let mut arrays: Vec<(CrossbarArray, Vec<f64>)> = zs
        .iter()
        .enumerate()
        .map(|(i, &z)| {
            let mut g = GaussianSource::new(100 + i as u64);
            let w_each = (z / n_col as f64) as f32;
            let arr = CrossbarArray::program(
                n_col,
                1,
                &vec![w_each; n_col],
                mapping2.clone(),
                &VariationModel::default(),
                NoiseParams::thermal_only(DELTA_F),
                &mut g,
            );
            (arr, vec![vr; n_col])
        })
        .collect();
    let mut gauss = GaussianSource::new(4242);
    let mut out = [0.0f64];
    let checkpoints: Vec<usize> =
        [100, 300, 1000, 3000, 10_000, 30_000].iter().copied().filter(|&c| c <= samples).collect();
    for s in 1..=samples {
        for (i, (arr, v)) in arrays.iter_mut().enumerate() {
            arr.read_differential(v, ReadMode::ColumnAggregate, &mut out, &mut gauss);
            if out[0] > 0.0 {
                cum[i] += 1;
            }
        }
        if checkpoints.contains(&s) {
            rows.push((s, cum[0] as f64 / s as f64, cum[1] as f64 / s as f64));
        }
    }
    for (s, pa, pb) in &rows {
        t.row(vec![
            s.to_string(),
            format!("{pa:.4}"),
            format!("{pb:.4}"),
            "0.014".into(),
            "0.745".into(),
        ]);
    }
    t.emit(&results_dir(), "fig4_ab")?;
    let (_, pa, pb) = rows.last().copied().unwrap();
    println!(
        "final: P(a)={pa:.4} (target 0.014, |Δ|={:.4})  P(b)={pb:.4} (target 0.745, |Δ|={:.4})\n",
        (pa - 0.014).abs(),
        (pb - 0.745).abs()
    );
    Ok(())
}

/// One sweep panel: P(Z) per sweep setting + logistic reference.
fn sweep_panel(
    name: &str,
    csv: &str,
    sweep_label: &str,
    settings: &[(String, usize, f64, f64)], // (label, n_col, vr, df)
    samples: usize,
) -> Result<()> {
    let zs = linspace(-8.0, 8.0, 17);
    let mut headers: Vec<String> = vec!["Z".into()];
    for (label, ..) in settings {
        headers.push(format!("P[{sweep_label}={label}]"));
        headers.push(format!("analytic[{label}]"));
    }
    headers.push("logistic".into());
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(name, &hdr_refs);
    let mapping = WeightMapping::default();
    for &z in &zs {
        let mut row = vec![format!("{z:.2}")];
        for (si, (_, n_col, vr, df)) in settings.iter().enumerate() {
            let p = empirical_p(z, *n_col, *vr, *df, samples, 7000 + si as u64);
            let kappa = mapping.kappa(*vr, *n_col, *df);
            row.push(format!("{p:.4}"));
            row.push(format!("{:.4}", norm_cdf(kappa * z)));
        }
        row.push(format!("{:.4}", logistic(z)));
        t.row(row);
    }
    t.emit(&results_dir(), csv)?;
    Ok(())
}

/// Panel (c): read-voltage sweep (Vr scales κ linearly).
pub fn panel_c(samples: usize) -> Result<()> {
    let m = WeightMapping::default();
    let n_col = 785;
    let vr1 = m.calibrate_vr(n_col, DELTA_F, 1.0);
    let settings: Vec<(String, usize, f64, f64)> = [0.25, 0.5, 1.0, 2.0, 4.0]
        .iter()
        .map(|&s| (format!("{s}xVr*"), n_col, vr1 * s, DELTA_F))
        .collect();
    sweep_panel("Fig 4(c) — Vr sweep", "fig4_c", "Vr", &settings, samples)
}

/// Panel (d): G0 sweep — realized by scaling the conductance window.
pub fn panel_d(samples: usize) -> Result<()> {
    // G0 scales with (Gmax − Gmin); emulate by scaling Vr·G0 jointly (the
    // product is what sets κ) while keeping the array at default mapping.
    let m = WeightMapping::default();
    let n_col = 785;
    let vr1 = m.calibrate_vr(n_col, DELTA_F, 1.0);
    let settings: Vec<(String, usize, f64, f64)> = [0.25, 0.5, 1.0, 2.0, 4.0]
        .iter()
        .map(|&s| (format!("{s}xG0*"), n_col, vr1 * s, DELTA_F))
        .collect();
    sweep_panel(
        "Fig 4(d) — G0 sweep (κ ∝ Vr·G0; same locus as Vr)",
        "fig4_d",
        "G0",
        &settings,
        samples,
    )
}

/// Panel (e): bandwidth sweep (κ ∝ 1/√Δf).
pub fn panel_e(samples: usize) -> Result<()> {
    let m = WeightMapping::default();
    let n_col = 785;
    let vr1 = m.calibrate_vr(n_col, DELTA_F, 1.0);
    let settings: Vec<(String, usize, f64, f64)> = [0.0625, 0.25, 1.0, 4.0, 16.0]
        .iter()
        .map(|&f| (format!("{f}xΔf*"), n_col, vr1, DELTA_F * f))
        .collect();
    sweep_panel("Fig 4(e) — Δf sweep", "fig4_e", "Δf", &settings, samples)
}

/// Panel (f): column-size sweep (κ ∝ 1/√N_col).
pub fn panel_f(samples: usize) -> Result<()> {
    let m = WeightMapping::default();
    let vr1 = m.calibrate_vr(785, DELTA_F, 1.0);
    let settings: Vec<(String, usize, f64, f64)> = [98usize, 196, 392, 785, 1570]
        .iter()
        .map(|&n| (format!("{n}"), n, vr1, DELTA_F))
        .collect();
    sweep_panel("Fig 4(f) — N_col sweep", "fig4_f", "Ncol", &settings, samples)
}

/// Run requested panels ("ab", "c".."f", or "all").
pub fn run(panel: &str, samples: usize) -> Result<()> {
    match panel {
        "ab" => panel_ab(samples),
        "c" => panel_c(samples),
        "d" => panel_d(samples),
        "e" => panel_e(samples),
        "f" => panel_f(samples),
        "all" => {
            panel_ab(samples)?;
            panel_c(samples)?;
            panel_d(samples)?;
            panel_e(samples)?;
            panel_f(samples)
        }
        other => anyhow::bail!("unknown fig4 panel '{other}' (ab|c|d|e|f|all)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empirical_matches_analytic_at_calibration() {
        let m = WeightMapping::default();
        let n_col = 128;
        let vr = m.calibrate_vr(n_col, DELTA_F, 1.0);
        for z in [-2.0, 0.0, 1.5] {
            let p = empirical_p(z, n_col, vr, DELTA_F, 20_000, 9);
            let want = norm_cdf(z / 1.702);
            assert!((p - want).abs() < 0.02, "z={z}: {p} vs {want}");
        }
    }

    #[test]
    fn snr_steepens_curve() {
        let m = WeightMapping::default();
        let n_col = 128;
        let vr = m.calibrate_vr(n_col, DELTA_F, 1.0);
        let p_lo = empirical_p(1.0, n_col, vr * 0.25, DELTA_F, 15_000, 11);
        let p_hi = empirical_p(1.0, n_col, vr * 4.0, DELTA_F, 15_000, 12);
        // Higher SNR → sharper sigmoid → closer to 1 at z=1.
        assert!(p_hi > p_lo + 0.1, "lo={p_lo} hi={p_hi}");
    }
}
