//! Figure/table regeneration harnesses (DESIGN.md §5 experiment index).
//!
//! One module per paper artifact; each prints the paper's series as an
//! aligned text table and writes a CSV twin under `results/`.  Everything
//! is deterministic given the seed embedded in each harness.

pub mod ablate;
pub mod common;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod table1;

pub use common::results_dir;
