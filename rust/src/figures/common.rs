//! Shared helpers for the figure harnesses.

use std::path::PathBuf;

/// Resolve the results directory ($RACA_RESULTS or ./results).
pub fn results_dir() -> PathBuf {
    if let Ok(d) = std::env::var("RACA_RESULTS") {
        return PathBuf::from(d);
    }
    for cand in ["results", "../results"] {
        let p = PathBuf::from(cand);
        if p.exists() {
            return p;
        }
    }
    let p = PathBuf::from("results");
    let _ = std::fs::create_dir_all(&p);
    p
}

/// Evenly spaced points over [lo, hi] inclusive.
pub fn linspace(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    assert!(n >= 2);
    (0..n)
        .map(|i| lo + (hi - lo) * i as f64 / (n - 1) as f64)
        .collect()
}

/// Parallel map over items using scoped threads (no rayon offline).
pub fn parallel_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send + Default + Clone,
    F: Fn(usize, &T) -> R + Sync,
{
    let n_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(items.len().max(1));
    let mut results = vec![R::default(); items.len()];
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<Option<R>>> =
        (0..items.len()).map(|_| std::sync::Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..n_threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    for (i, slot) in slots.into_iter().enumerate() {
        results[i] = slot.into_inner().unwrap().expect("worker missed a slot");
    }
    results
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linspace_endpoints() {
        let v = linspace(-2.0, 2.0, 5);
        assert_eq!(v, vec![-2.0, -1.0, 0.0, 1.0, 2.0]);
    }

    #[test]
    fn parallel_map_order_preserved() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map(&items, |_, &x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let items: Vec<u64> = vec![];
        let out: Vec<u64> = parallel_map(&items, |_, &x| x);
        assert!(out.is_empty());
    }
}
