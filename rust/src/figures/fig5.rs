//! Fig. 5 — WTA SoftMax-neuron simulations.
//!
//! (a) transient traces of ten neurons vs the adaptive threshold over
//! three consecutive decisions; (b,c) 100 decision experiments — decision
//! times and the winner raster; (d) empirical win frequencies vs the
//! ideal softmax (Eq. 14).

use anyhow::Result;

use crate::circuit::{WtaCircuit, WtaParams};
use crate::neuron::softmax_wta::{softmax64, WtaLayer};
use crate::stats::GaussianSource;
use crate::util::table::Table;

use super::common::results_dir;

/// The ten output logits used across all panels (z units, mean-centered).
/// Chosen to mirror the paper's example: one clear-but-not-degenerate
/// winner with plausible runner-ups.
pub fn example_logits() -> Vec<f64> {
    vec![-1.2, -0.4, 0.3, -0.8, 2.1, 0.9, -1.6, 0.1, -0.3, 0.9]
}

fn layer(vth0: f64, sigma_v: f64) -> (WtaLayer, Vec<f64>) {
    let z = example_logits();
    // Voltage mapping: v = σ_v·z/1.702 (DESIGN.md §6).
    let v: Vec<f64> = z.iter().map(|&zi| zi * sigma_v / 1.702).collect();
    let l = WtaLayer::new(WtaParams {
        sigma_v,
        vth0,
        refractory_steps: 8,
        max_steps: 64,
        ..Default::default()
    });
    (l, v)
}

/// Softmax-matching rest offset: θ_z − z̄ = 1.702² in z units (§6).
fn matched_vth0(v: &[f64], sigma_v: f64) -> f64 {
    let v_mean = v.iter().sum::<f64>() / v.len() as f64;
    let theta_v = (1.702f64 * 1.702) * sigma_v / 1.702; // volts above z̄=0
    theta_v - v_mean // rest = mean + vth0 must sit at θ
}

/// Panel (a): transient traces, three consecutive decisions.
pub fn panel_a() -> Result<()> {
    let sigma_v = 0.02;
    let (l, v) = layer(0.0, sigma_v);
    let vth0 = matched_vth0(&v, sigma_v);
    let circuit = WtaCircuit::new(WtaParams { vth0, sigma_v, ..l.circuit.params.clone() });
    let mut g = GaussianSource::new(55);
    let trace = circuit.run_trace(&v, 3, &mut g);

    let mut headers: Vec<String> = vec!["t_ns".into()];
    headers.extend((0..10).map(|i| format!("V{i}_mV")));
    headers.push("Vth_mV".into());
    headers.push("winner".into());
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Fig 5(a) — WTA transient (3 decisions)", &hdr);
    for step in &trace.steps {
        let mut row = vec![format!("{:.1}", step.t * 1e9)];
        row.extend(step.v.iter().map(|&x| format!("{:.2}", x * 1e3)));
        row.push(format!("{:.2}", step.vth * 1e3));
        row.push(step.winner.map(|w| w.to_string()).unwrap_or_default());
        t.row(row);
    }
    // Print only a summary to stdout (the trace is long); CSV is complete.
    let path = results_dir().join("fig5_a.csv");
    t.write_csv(&path)?;
    println!("== Fig 5(a) — WTA transient ==");
    println!(
        "decisions: winners={:?} over {} steps ({} ns simulated)",
        trace.winners,
        trace.steps.len(),
        trace.steps.len() as f64
    );
    println!("[csv] {}\n", path.display());
    assert_eq!(trace.winners.len(), 3);
    Ok(())
}

/// Panels (b,c): 100 decision experiments — decision time + winner raster.
pub fn panel_bc() -> Result<()> {
    let sigma_v = 0.02;
    let (l, v) = layer(0.0, sigma_v);
    let vth0 = matched_vth0(&v, sigma_v);
    let circuit = WtaCircuit::new(WtaParams { vth0, sigma_v, ..l.circuit.params.clone() });
    let mut g = GaussianSource::new(77);

    let mut t = Table::new(
        "Fig 5(b,c) — 100 decision experiments",
        &["decision", "winner", "steps_to_fire"],
    );
    let mut counts = vec![0u64; 10];
    for d in 0..100 {
        // Count steps until the decision fires.
        let trace = circuit.run_trace(&v, 1, &mut g);
        let steps = trace
            .steps
            .iter()
            .position(|s| s.winner.is_some())
            .map(|p| p + 1)
            .unwrap_or(trace.steps.len());
        let w = trace.winners[0];
        if w >= 0 {
            counts[w as usize] += 1;
        }
        t.row(vec![d.to_string(), w.to_string(), steps.to_string()]);
    }
    t.emit(&results_dir(), "fig5_bc")?;
    println!("winner histogram over 100 decisions: {counts:?}\n");
    Ok(())
}

/// Panel (d): win frequencies (sampled + analytic) vs ideal softmax.
pub fn panel_d(trials: usize) -> Result<()> {
    let sigma_v = 0.02;
    let (l0, v) = layer(0.0, sigma_v);
    let vth0 = matched_vth0(&v, sigma_v);
    let l = WtaLayer::new(WtaParams { vth0, sigma_v, ..l0.circuit.params.clone() });
    let mut g = GaussianSource::new(99);
    let outcome = l.run(&v, trials, &mut g);
    let emp = outcome.frequencies();
    let analytic = l.analytic_win_distribution(&v);
    let soft = softmax64(&example_logits());

    let mut t = Table::new(
        &format!("Fig 5(d) — WTA win distribution vs softmax ({trials} trials)"),
        &["neuron", "empirical", "analytic(Eq14)", "softmax", "|emp-softmax|"],
    );
    let mut max_gap: f64 = 0.0;
    for j in 0..10 {
        let gap = (emp[j] - soft[j]).abs();
        max_gap = max_gap.max(gap);
        t.row(vec![
            j.to_string(),
            format!("{:.4}", emp[j]),
            format!("{:.4}", analytic[j]),
            format!("{:.4}", soft[j]),
            format!("{gap:.4}"),
        ]);
    }
    t.emit(&results_dir(), "fig5_d")?;
    let argmax_emp = emp
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    let argmax_soft = soft
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    println!(
        "max |empirical − softmax| = {max_gap:.4}; argmax agree: {} (emp {argmax_emp}, softmax {argmax_soft}); abstentions {}\n",
        argmax_emp == argmax_soft,
        outcome.abstentions
    );
    Ok(())
}

/// Run requested panels ("a", "bc", "d", "all").
pub fn run(panel: &str, trials: usize) -> Result<()> {
    match panel {
        "a" => panel_a(),
        "bc" => panel_bc(),
        "d" => panel_d(trials),
        "all" => {
            panel_a()?;
            panel_bc()?;
            panel_d(trials)
        }
        other => anyhow::bail!("unknown fig5 panel '{other}' (a|bc|d|all)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wta_distribution_tracks_softmax() {
        let sigma_v = 0.02;
        let (l0, v) = layer(0.0, sigma_v);
        let vth0 = matched_vth0(&v, sigma_v);
        let l = WtaLayer::new(WtaParams { vth0, sigma_v, ..l0.circuit.params.clone() });
        let mut g = GaussianSource::new(1);
        let o = l.run(&v, 20_000, &mut g);
        let emp = o.frequencies();
        let soft = softmax64(&example_logits());
        // Same argmax, coarse value agreement (Fig. 5d claim).
        let am_e = emp.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        let am_s = soft.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert_eq!(am_e, am_s);
        for j in 0..10 {
            assert!((emp[j] - soft[j]).abs() < 0.08, "neuron {j}: {} vs {}", emp[j], soft[j]);
        }
    }
}
