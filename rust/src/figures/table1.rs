//! Table I harness + design-space ablations (E-ABL3/4).

use anyhow::Result;

use crate::hwmodel::table1::Table1Result;
use crate::hwmodel::{Architecture, SystemModel, TechParams};
use crate::nn::ModelSpec;
use crate::util::table::{fmt_g, Table};

use super::common::results_dir;

/// Regenerate Table I with breakdowns.
pub fn run() -> Result<()> {
    let model = SystemModel::paper();
    let r = Table1Result::compute(&model);
    let t = r.to_table();
    t.emit(&results_dir(), "table1")?;

    // Energy breakdown per category (per trial).
    let mut bt = Table::new(
        "Table I breakdown — energy per trial (pJ)",
        &["category", "1-bit ADC", "RACA"],
    );
    let eb = model.energy(Architecture::OneBitAdc);
    let er = model.energy(Architecture::Raca);
    for (name, a, b) in [
        ("array", eb.array, er.array),
        ("readout (ADC / TIA+comp)", eb.readout, er.readout),
        ("drivers + DAC", eb.drivers, er.drivers),
        ("digital (RNG/accum/WTA/ctl)", eb.digital, er.digital),
        ("buffers", eb.buffers, er.buffers),
        ("interconnect", eb.interconnect, er.interconnect),
        ("TOTAL", eb.total(), er.total()),
    ] {
        bt.row(vec![name.into(), fmt_g(a), fmt_g(b)]);
    }
    bt.emit(&results_dir(), "table1_energy_breakdown")?;

    let mut at = Table::new(
        "Table I breakdown — area (mm²)",
        &["category", "1-bit ADC", "RACA"],
    );
    let ab = model.area(Architecture::OneBitAdc);
    let ar = model.area(Architecture::Raca);
    for (name, a, b) in [
        ("array", ab.array, ar.array),
        ("readout", ab.readout, ar.readout),
        ("drivers + DAC", ab.drivers, ar.drivers),
        ("digital", ab.digital, ar.digital),
        ("buffers", ab.buffers, ar.buffers),
        ("interconnect", ab.interconnect, ar.interconnect),
        ("TOTAL", ab.total(), ar.total()),
    ] {
        at.row(vec![name.into(), fmt_g(a), fmt_g(b)]);
    }
    at.emit(&results_dir(), "table1_area_breakdown")?;
    Ok(())
}

/// E-INTRO: the paper's §I premise — converter share of a conventional
/// multi-bit-ADC CiM design ("up to 72% energy / 81% area in DAC+ADC").
pub fn intro_converter_share() -> Result<()> {
    use crate::hwmodel::ConventionalCim;
    let mut t = Table::new(
        "Intro premise — converter (DAC+ADC) share of conventional CiM",
        &["adc bits", "E total pJ", "conv E %", "area mm²", "conv A %", "paper claim"],
    );
    for bits in [4u32, 6, 8] {
        let mut c = ConventionalCim::paper();
        c.adc_bits = bits;
        c.dac_bits = bits;
        t.row(vec![
            bits.to_string(),
            fmt_g(c.energy().total()),
            format!("{:.1}", c.converter_energy_fraction() * 100.0),
            fmt_g(c.area().total()),
            format!("{:.1}", c.converter_area_fraction() * 100.0),
            if bits == 8 { "≤72% E, ≤81% A".into() } else { String::new() },
        ]);
    }
    t.emit(&results_dir(), "intro_converter_share")?;
    Ok(())
}

/// E-ABL3: tile-size ablation.
pub fn ablate_tiles() -> Result<()> {
    let mut t = Table::new(
        "Ablation — tile size vs Table I metrics (RACA)",
        &["tile", "tiles", "energy pJ/trial", "area mm²", "TOPS/W"],
    );
    for tile in [64usize, 128, 256] {
        let mut tech = TechParams::default();
        tech.tile = tile;
        let m = SystemModel::new(ModelSpec::paper(), tech);
        t.row(vec![
            tile.to_string(),
            m.num_tiles().to_string(),
            fmt_g(m.energy(Architecture::Raca).total()),
            fmt_g(m.area(Architecture::Raca).total()),
            fmt_g(m.tops_per_watt(Architecture::Raca)),
        ]);
    }
    t.emit(&results_dir(), "ablation_tiles")?;
    Ok(())
}

/// E-ABL4: the calibrated low-Vr corner the paper motivates.
pub fn ablate_low_vr() -> Result<()> {
    let base = SystemModel::paper();
    let low = SystemModel::new(ModelSpec::paper(), TechParams::default().with_calibrated_vr());
    let mut t = Table::new(
        "Ablation — RACA read-voltage corner",
        &["corner", "Vr (V)", "array pJ/trial", "total pJ/trial", "TOPS/W"],
    );
    for (name, m) in [("conventional swing", &base), ("noise-calibrated", &low)] {
        let e = m.energy(Architecture::Raca);
        t.row(vec![
            name.into(),
            format!("{:.3}", m.tech.v_read_raca),
            fmt_g(e.array),
            fmt_g(e.total()),
            fmt_g(m.tops_per_watt(Architecture::Raca)),
        ]);
    }
    t.emit(&results_dir(), "ablation_low_vr")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_vr_cuts_array_energy() {
        let base = SystemModel::paper();
        let low =
            SystemModel::new(ModelSpec::paper(), TechParams::default().with_calibrated_vr());
        let eb = base.energy(Architecture::Raca);
        let el = low.energy(Architecture::Raca);
        assert!(el.array < eb.array / 50.0);
        assert!(el.total() < eb.total());
    }
}
