//! Fig. 6 — RACA end-to-end accuracy vs number of stochastic trials.
//!
//! (a) sweeping the Sigmoid-layer SNR (κ/κ* ∈ {¼,½,1,2,4});
//! (b) sweeping the WTA rest threshold V_th0 ∈ {0, 0.05 V}
//!     (θ_norm ∈ {0, 3}).
//!
//! Method: for each test image run `max_trials` stochastic trials once and
//! record the winner sequence; the accuracy at k trials is the majority
//! vote over the first k winners (prefix voting) — so one pass yields the
//! whole curve.  Native engine by default (parallel over images); the
//! `--engine xla` path exercises the AOT artifacts instead.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::dataset::Dataset;
use crate::engine::{NativeEngine, TrialParams};
use crate::nn::Weights;
use crate::runtime::default_artifact_dir;
use crate::util::table::Table;

use super::common::{parallel_map, results_dir};

/// Trial counts reported on the x-axis.
pub const TRIAL_POINTS: &[usize] = &[1, 2, 4, 8, 16, 32, 64];

/// Majority vote over the first `k` winners (ties → lower class).
fn prefix_vote(winners: &[i32], k: usize, classes: usize) -> i32 {
    let mut counts = vec![0u32; classes];
    for &w in &winners[..k.min(winners.len())] {
        if w >= 0 {
            counts[w as usize] += 1;
        }
    }
    let (best, &cnt) = counts.iter().enumerate().max_by_key(|&(i, &c)| (c, usize::MAX - i)).unwrap();
    if cnt == 0 {
        -1
    } else {
        best as i32
    }
}

/// Accuracy at each TRIAL_POINTS entry for one winner-matrix.
fn curve(winner_rows: &[Vec<i32>], labels: &[i32]) -> Vec<f64> {
    TRIAL_POINTS
        .iter()
        .map(|&k| {
            let hits = winner_rows
                .iter()
                .zip(labels)
                .filter(|(w, &l)| prefix_vote(w, k, 10) == l)
                .count();
            hits as f64 / labels.len() as f64
        })
        .collect()
}

/// Run `max_trials` native-engine trials per image (parallel over images,
/// trial-blocked bit-packed kernel within each image — §Perf iteration 5;
/// per-trial indices are unchanged, so winner sequences are bit-identical
/// to the old scalar loop).
fn native_winners(
    weights: &Arc<Weights>,
    ds: &Dataset,
    p: TrialParams,
    max_trials: usize,
    seed: u64,
) -> Vec<Vec<i32>> {
    let engine = NativeEngine::new(weights.clone(), seed);
    let idx: Vec<usize> = (0..ds.len()).collect();
    parallel_map(&idx, |_, &i| {
        let z1 = engine.precompute(ds.image(i));
        let indices: Vec<u64> = (0..max_trials).map(|t| (i * 100_003 + t) as u64).collect();
        engine.trials_cached(&z1, p, &indices)
    })
}

/// Run trials through the AOT/PJRT path (batch-packed).
#[cfg(feature = "pjrt")]
fn xla_winners(
    dir: std::path::PathBuf,
    ds: &Dataset,
    p: TrialParams,
    max_trials: usize,
) -> Result<Vec<Vec<i32>>> {
    let engine = crate::engine::XlaEngine::start(dir)?;
    let h = engine.handle();
    let batch = 32usize;
    let mut rows = vec![Vec::with_capacity(max_trials); ds.len()];
    let n_chunks = ds.len().div_ceil(batch);
    for c in 0..n_chunks {
        let lo = c * batch;
        let hi = (lo + batch).min(ds.len());
        let mut xs = Vec::with_capacity(batch * 784);
        for i in lo..hi {
            xs.extend_from_slice(ds.image(i));
        }
        // Pad the final chunk by repeating the last image (discarded).
        for _ in hi - lo..batch {
            xs.extend_from_slice(ds.image(hi - 1));
        }
        for t in 0..max_trials {
            let winners = h.run_trials(xs.clone(), batch, (c * 7919 + t) as u32, p)?;
            for i in lo..hi {
                rows[i].push(winners[i - lo]);
            }
        }
    }
    Ok(rows)
}

/// Non-PJRT builds reject `--engine xla` with a clear error.
#[cfg(not(feature = "pjrt"))]
fn xla_winners(
    _dir: std::path::PathBuf,
    _ds: &Dataset,
    _p: TrialParams,
    _max_trials: usize,
) -> Result<Vec<Vec<i32>>> {
    anyhow::bail!(
        "this build has no PJRT runtime (the `pjrt` cargo feature is off); \
         rebuild with `--features pjrt` or drop `--engine xla`"
    )
}

fn load(dir: &std::path::Path, n_images: usize) -> Result<(Arc<Weights>, Dataset, f64)> {
    let w = Weights::load(&dir.join("weights").join("fcnn")).context("weights")?;
    let acc = w.ideal_test_accuracy;
    let ds = Dataset::load(&dir.join("data").join("test"))?.take(n_images);
    Ok((Arc::new(w), ds, acc))
}

/// Panel (a): SNR sweep.
pub fn panel_a(n_images: usize, use_xla: bool) -> Result<()> {
    let dir = default_artifact_dir();
    let (w, ds, ideal_acc) = load(&dir, n_images)?;
    let snrs = [0.25f32, 0.5, 1.0, 2.0, 4.0];
    let mut headers: Vec<String> = vec!["trials".into()];
    headers.extend(snrs.iter().map(|s| format!("acc[snr={s}x]")));
    headers.push("ideal(software)".into());
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!("Fig 6(a) — accuracy vs trials, SNR sweep ({n_images} images)"),
        &hdr,
    );
    let mut curves = Vec::new();
    for (si, &s) in snrs.iter().enumerate() {
        let p = TrialParams::with_snr_scale(s);
        let rows = if use_xla {
            xla_winners(dir.clone(), &ds, p, *TRIAL_POINTS.last().unwrap())?
        } else {
            native_winners(&w, &ds, p, *TRIAL_POINTS.last().unwrap(), 40 + si as u64)
        };
        curves.push(curve(&rows, &ds.labels));
    }
    for (ti, &k) in TRIAL_POINTS.iter().enumerate() {
        let mut row = vec![k.to_string()];
        for c in &curves {
            row.push(format!("{:.4}", c[ti]));
        }
        row.push(format!("{ideal_acc:.4}"));
        t.row(row);
    }
    t.emit(&results_dir(), "fig6_a")?;
    Ok(())
}

/// Panel (b): V_th0 sweep (θ_norm 0 ↔ 0 V, 3 ↔ 0.05 V).
pub fn panel_b(n_images: usize, use_xla: bool) -> Result<()> {
    let dir = default_artifact_dir();
    let (w, ds, ideal_acc) = load(&dir, n_images)?;
    let thetas: [(f32, &str); 2] = [(0.0, "Vth0=0V"), (3.0, "Vth0=0.05V")];
    let mut headers: Vec<String> = vec!["trials".into()];
    headers.extend(thetas.iter().map(|(_, n)| format!("acc[{n}]")));
    headers.push("ideal(software)".into());
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!("Fig 6(b) — accuracy vs trials, V_th0 sweep ({n_images} images)"),
        &hdr,
    );
    let mut curves = Vec::new();
    for (ti_, &(theta, _)) in thetas.iter().enumerate() {
        let p = TrialParams::default().with_theta(theta);
        let rows = if use_xla {
            xla_winners(dir.clone(), &ds, p, *TRIAL_POINTS.last().unwrap())?
        } else {
            native_winners(&w, &ds, p, *TRIAL_POINTS.last().unwrap(), 70 + ti_ as u64)
        };
        curves.push(curve(&rows, &ds.labels));
    }
    for (ti, &k) in TRIAL_POINTS.iter().enumerate() {
        let mut row = vec![k.to_string()];
        for c in &curves {
            row.push(format!("{:.4}", c[ti]));
        }
        row.push(format!("{ideal_acc:.4}"));
        t.row(row);
    }
    t.emit(&results_dir(), "fig6_b")?;
    let final_005 = curves[1].last().copied().unwrap_or(0.0);
    let final_0 = curves[0].last().copied().unwrap_or(0.0);
    println!(
        "final accuracy: Vth0=0.05V → {:.2}% (paper 96.7%), Vth0=0V → {:.2}% (paper 96.0%), software {:.2}%\n",
        final_005 * 100.0,
        final_0 * 100.0,
        ideal_acc * 100.0
    );
    Ok(())
}

/// Run requested panels ("a", "b", "all").
pub fn run(panel: &str, n_images: usize, use_xla: bool) -> Result<()> {
    match panel {
        "a" => panel_a(n_images, use_xla),
        "b" => panel_b(n_images, use_xla),
        "all" => {
            panel_a(n_images, use_xla)?;
            panel_b(n_images, use_xla)
        }
        other => anyhow::bail!("unknown fig6 panel '{other}' (a|b|all)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_vote_rules() {
        assert_eq!(prefix_vote(&[1, 1, 2], 3, 10), 1);
        assert_eq!(prefix_vote(&[2, 1], 1, 10), 2);
        assert_eq!(prefix_vote(&[-1, -1], 2, 10), -1);
        assert_eq!(prefix_vote(&[3, 5, 5, 3], 4, 10), 3); // tie → lower class
    }

    #[test]
    fn curve_monotone_for_perfect_winner() {
        let rows = vec![vec![7i32; 64], vec![7i32; 64]];
        let labels = vec![7, 7];
        let c = curve(&rows, &labels);
        assert!(c.iter().all(|&a| a == 1.0));
    }
}
