//! Robustness ablations on the physical engine (E-ABL1/2).
//!
//! * noise composition: thermal-only (the paper's model) vs thermal +
//!   shot + RTN + 1/f — does the sigmoid emulation survive real devices?
//! * programming variation: lognormal σ sweep — accuracy degradation.

use anyhow::{Context, Result};

use crate::dataset::Dataset;
use crate::device::noise::NoiseParams;
use crate::device::variation::VariationModel;
use crate::device::DELTA_F;
use crate::engine::{PhysicalEngine, TrialParams};
use crate::nn::Weights;
use crate::runtime::default_artifact_dir;
use crate::util::table::Table;

use super::common::results_dir;

fn load(n_images: usize) -> Result<(Weights, Dataset)> {
    let dir = default_artifact_dir();
    let w = Weights::load(&dir.join("weights").join("fcnn")).context("weights")?;
    let ds = Dataset::load(&dir.join("data").join("test"))?.take(n_images);
    Ok((w, ds))
}

fn accuracy(engine: &mut PhysicalEngine, ds: &Dataset, trials: usize) -> f64 {
    let p = TrialParams::default();
    let hits = (0..ds.len())
        .filter(|&i| {
            engine.infer(ds.image(i), p, trials, (i * 977) as u64).prediction() == ds.label(i)
        })
        .count();
    hits as f64 / ds.len() as f64
}

/// E-ABL1: noise-source composition.
pub fn noise_composition(n_images: usize, trials: usize) -> Result<()> {
    let (w, ds) = load(n_images)?;
    let mut t = Table::new(
        &format!("Ablation — noise composition ({n_images} images × {trials} trials)"),
        &["noise model", "accuracy"],
    );
    let corners: [(&str, NoiseParams); 3] = [
        ("thermal only (paper)", NoiseParams::thermal_only(DELTA_F)),
        ("thermal + shot", {
            let mut n = NoiseParams::thermal_only(DELTA_F);
            n.shot = true;
            n
        }),
        ("thermal+shot+RTN+1/f", NoiseParams::full(DELTA_F)),
    ];
    for (name, noise) in corners {
        let mut e = PhysicalEngine::program(
            &w, 128, &VariationModel::default(), &noise, 1.0, 31,
        );
        let acc = accuracy(&mut e, &ds, trials);
        t.row(vec![name.into(), format!("{:.4}", acc)]);
    }
    t.emit(&results_dir(), "ablation_noise")?;
    Ok(())
}

/// E-ABL2: device programming variation sweep.
pub fn variation_sweep(n_images: usize, trials: usize) -> Result<()> {
    let (w, ds) = load(n_images)?;
    let mut t = Table::new(
        &format!("Ablation — programming variation ({n_images} images × {trials} trials)"),
        &["lognormal σ", "stuck fraction", "accuracy"],
    );
    for (sigma, stuck) in [(0.0, 0.0), (0.02, 0.0), (0.05, 0.0), (0.10, 0.0), (0.05, 0.01)] {
        let v = VariationModel::with_defects(sigma, stuck, stuck / 2.0);
        let mut e = PhysicalEngine::program(
            &w, 128, &v, &NoiseParams::thermal_only(DELTA_F), 1.0, 37,
        );
        let acc = accuracy(&mut e, &ds, trials);
        t.row(vec![
            format!("{sigma:.2}"),
            format!("{stuck:.2}"),
            format!("{acc:.4}"),
        ]);
    }
    t.emit(&results_dir(), "ablation_variation")?;
    Ok(())
}
