//! Loader for `artifacts/data/{train,test}.{img,lbl}.bin`.
//!
//! Format contract with `python/compile/data.py::save_bin`: images are
//! little-endian f32, row-major `[n, 784]`, values in [0, 1]; labels are
//! little-endian i32 in [0, 10).

use std::path::Path;

use anyhow::{ensure, Context, Result};

pub const IMG_PIXELS: usize = 28 * 28;

/// An in-memory image/label set.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Row-major `[n, 784]` pixels in [0, 1].
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
}

impl Dataset {
    /// Load `<prefix>.img.bin` + `<prefix>.lbl.bin`.
    pub fn load(prefix: &Path) -> Result<Self> {
        let img_path = with_suffix(prefix, ".img.bin");
        let lbl_path = with_suffix(prefix, ".lbl.bin");
        let img_bytes = std::fs::read(&img_path)
            .with_context(|| format!("reading {}", img_path.display()))?;
        let lbl_bytes = std::fs::read(&lbl_path)
            .with_context(|| format!("reading {}", lbl_path.display()))?;
        ensure!(img_bytes.len() % (IMG_PIXELS * 4) == 0, "truncated image file");
        ensure!(lbl_bytes.len() % 4 == 0, "truncated label file");
        let n = img_bytes.len() / (IMG_PIXELS * 4);
        ensure!(lbl_bytes.len() / 4 == n, "image/label count mismatch");

        let images: Vec<f32> = img_bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let labels: Vec<i32> = lbl_bytes
            .chunks_exact(4)
            .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let ds = Self { images, labels };
        ds.validate()?;
        Ok(ds)
    }

    /// Write `<prefix>.img.bin` + `<prefix>.lbl.bin` in the python
    /// toolchain's format ([`Dataset::load`] round-trips exactly) — the
    /// `raca train` path that regenerates artifacts natively.
    pub fn save(&self, prefix: &Path) -> Result<()> {
        if let Some(dir) = prefix.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
        let mut img = Vec::with_capacity(self.images.len() * 4);
        for p in &self.images {
            img.extend_from_slice(&p.to_le_bytes());
        }
        let mut lbl = Vec::with_capacity(self.labels.len() * 4);
        for l in &self.labels {
            lbl.extend_from_slice(&l.to_le_bytes());
        }
        let img_path = with_suffix(prefix, ".img.bin");
        std::fs::write(&img_path, img)
            .with_context(|| format!("writing {}", img_path.display()))?;
        let lbl_path = with_suffix(prefix, ".lbl.bin");
        std::fs::write(&lbl_path, lbl)
            .with_context(|| format!("writing {}", lbl_path.display()))?;
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Pixel row of image `i`.
    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * IMG_PIXELS..(i + 1) * IMG_PIXELS]
    }

    pub fn label(&self, i: usize) -> i32 {
        self.labels[i]
    }

    /// First `n` examples as a view-copy (figure harness subsets).
    pub fn take(&self, n: usize) -> Dataset {
        self.slice(0, n)
    }

    /// Examples `[lo, hi)` as a view-copy (disjoint calibration/serving
    /// splits for the fleet harness); bounds are clamped to the set.
    pub fn slice(&self, lo: usize, hi: usize) -> Dataset {
        let hi = hi.min(self.len());
        let lo = lo.min(hi);
        Dataset {
            images: self.images[lo * IMG_PIXELS..hi * IMG_PIXELS].to_vec(),
            labels: self.labels[lo..hi].to_vec(),
        }
    }

    pub fn validate(&self) -> Result<()> {
        for (i, &l) in self.labels.iter().enumerate() {
            ensure!((0..10).contains(&l), "label {l} at index {i} out of range");
        }
        for &p in &self.images {
            ensure!(p.is_finite() && (-0.001..=1.001).contains(&p), "pixel {p} out of range");
        }
        Ok(())
    }
}

fn with_suffix(prefix: &Path, suffix: &str) -> std::path::PathBuf {
    let mut s = prefix.as_os_str().to_os_string();
    s.push(suffix);
    std::path::PathBuf::from(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path, n: usize) {
        let mut img = Vec::new();
        for i in 0..n * IMG_PIXELS {
            img.extend_from_slice(&(((i % 7) as f32) / 7.0).to_le_bytes());
        }
        let mut lbl = Vec::new();
        for i in 0..n {
            lbl.extend_from_slice(&((i % 10) as i32).to_le_bytes());
        }
        std::fs::write(dir.join("d.img.bin"), img).unwrap();
        std::fs::write(dir.join("d.lbl.bin"), lbl).unwrap();
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("raca_ds_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_fixture(&dir, 12);
        let ds = Dataset::load(&dir.join("d")).unwrap();
        assert_eq!(ds.len(), 12);
        assert_eq!(ds.label(11), 1);
        assert_eq!(ds.image(0).len(), IMG_PIXELS);
        let t = ds.take(5);
        assert_eq!(t.len(), 5);
        let s = ds.slice(5, 8);
        assert_eq!(s.len(), 3);
        assert_eq!(s.label(0), ds.label(5));
        assert_eq!(s.image(0), ds.image(5));
        assert_eq!(ds.slice(10, 99).len(), 2);
        assert!(ds.slice(20, 5).is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_load_round_trips_bit_exactly() {
        let dir = std::env::temp_dir().join(format!("raca_dssave_{}", std::process::id()));
        let ds = crate::dataset::synth::generate(9, 0x5A);
        ds.save(&dir.join("data").join("test")).unwrap(); // creates subdirs
        let r = Dataset::load(&dir.join("data").join("test")).unwrap();
        assert_eq!(r.labels, ds.labels);
        assert_eq!(r.images, ds.images, "f32 pixels must survive exactly");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_mismatched_counts() {
        let dir = std::env::temp_dir().join(format!("raca_dsbad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_fixture(&dir, 3);
        // Corrupt: drop one label.
        let lbl = std::fs::read(dir.join("d.lbl.bin")).unwrap();
        std::fs::write(dir.join("d.lbl.bin"), &lbl[..8]).unwrap();
        assert!(Dataset::load(&dir.join("d")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_labels() {
        let dir = std::env::temp_dir().join(format!("raca_dsbad2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let img: Vec<u8> = (0..IMG_PIXELS * 4).map(|_| 0u8).collect();
        std::fs::write(dir.join("d.img.bin"), img).unwrap();
        std::fs::write(dir.join("d.lbl.bin"), 99i32.to_le_bytes()).unwrap();
        assert!(Dataset::load(&dir.join("d")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
