//! Native mirror of `python/compile/data.py`: procedural digit rendering.
//!
//! Same stroke templates and rasterizer; the RNG differs (xoshiro vs
//! numpy PCG64), so samples match the python generator in *distribution*,
//! not bit-for-bit.  Used by artifact-free tests, the `serve_demo`
//! example's request generator, and as a fallback when `artifacts/data`
//! is missing.

use crate::stats::Rng;

pub const IMG: usize = 28;

/// Stroke templates: polylines in the unit square (x right, y down).
/// KEEP IN SYNC with python/compile/data.py::DIGIT_STROKES.
pub fn digit_strokes(digit: usize) -> &'static [&'static [(f64, f64)]] {
    const D0: &[&[(f64, f64)]] = &[&[
        (0.50, 0.08), (0.78, 0.22), (0.82, 0.50), (0.78, 0.78),
        (0.50, 0.92), (0.22, 0.78), (0.18, 0.50), (0.22, 0.22), (0.50, 0.08),
    ]];
    const D1: &[&[(f64, f64)]] = &[
        &[(0.35, 0.25), (0.55, 0.10), (0.55, 0.90)],
        &[(0.35, 0.90), (0.75, 0.90)],
    ];
    const D2: &[&[(f64, f64)]] = &[
        &[(0.22, 0.30), (0.30, 0.12), (0.60, 0.08), (0.78, 0.25),
          (0.72, 0.48), (0.45, 0.65), (0.22, 0.88)],
        &[(0.22, 0.88), (0.80, 0.88)],
    ];
    const D3: &[&[(f64, f64)]] = &[&[
        (0.25, 0.15), (0.60, 0.10), (0.75, 0.28), (0.55, 0.46),
        (0.75, 0.68), (0.60, 0.90), (0.25, 0.85),
    ]];
    const D4: &[&[(f64, f64)]] = &[&[(0.62, 0.90), (0.62, 0.10), (0.20, 0.62), (0.82, 0.62)]];
    const D5: &[&[(f64, f64)]] = &[&[
        (0.75, 0.12), (0.30, 0.12), (0.27, 0.45), (0.60, 0.42),
        (0.78, 0.62), (0.68, 0.86), (0.25, 0.88),
    ]];
    const D6: &[&[(f64, f64)]] = &[&[
        (0.68, 0.10), (0.38, 0.30), (0.25, 0.60), (0.35, 0.85),
        (0.65, 0.88), (0.75, 0.65), (0.55, 0.50), (0.28, 0.58),
    ]];
    const D7: &[&[(f64, f64)]] = &[
        &[(0.20, 0.12), (0.80, 0.12), (0.45, 0.90)],
        &[(0.35, 0.52), (0.68, 0.52)],
    ];
    const D8: &[&[(f64, f64)]] = &[
        &[(0.50, 0.10), (0.72, 0.22), (0.66, 0.44), (0.50, 0.50),
          (0.34, 0.44), (0.28, 0.22), (0.50, 0.10)],
        &[(0.50, 0.50), (0.74, 0.62), (0.68, 0.86), (0.50, 0.92),
          (0.32, 0.86), (0.26, 0.62), (0.50, 0.50)],
    ];
    const D9: &[&[(f64, f64)]] = &[
        &[(0.72, 0.42), (0.45, 0.50), (0.28, 0.35), (0.35, 0.12),
          (0.65, 0.10), (0.72, 0.42)],
        &[(0.72, 0.42), (0.68, 0.70), (0.55, 0.90)],
    ];
    match digit {
        0 => D0, 1 => D1, 2 => D2, 3 => D3, 4 => D4,
        5 => D5, 6 => D6, 7 => D7, 8 => D8, 9 => D9,
        _ => panic!("digit out of range: {digit}"),
    }
}

fn rasterize(strokes: &[Vec<(f64, f64)>], width: f64, soft: f64) -> Vec<f32> {
    let mut img = vec![0.0f32; IMG * IMG];
    for yi in 0..IMG {
        for xi in 0..IMG {
            let px = (xi as f64 + 0.5) / IMG as f64;
            let py = (yi as f64 + 0.5) / IMG as f64;
            let mut dmin = 1e9f64;
            for poly in strokes {
                for k in 0..poly.len() - 1 {
                    let (ax, ay) = poly[k];
                    let (bx, by) = poly[k + 1];
                    let (abx, aby) = (bx - ax, by - ay);
                    let denom = abx * abx + aby * aby + 1e-12;
                    let t = (((px - ax) * abx + (py - ay) * aby) / denom).clamp(0.0, 1.0);
                    let (cx, cy) = (ax + t * abx, ay + t * aby);
                    let d = ((px - cx).powi(2) + (py - cy).powi(2)).sqrt();
                    dmin = dmin.min(d);
                }
            }
            img[yi * IMG + xi] = ((1.0 - (dmin - width) / soft).clamp(0.0, 1.0)) as f32;
        }
    }
    img
}

fn affine(
    poly: &[(f64, f64)],
    rot: f64,
    sx: f64,
    sy: f64,
    shear: f64,
    tx: f64,
    ty: f64,
    wobble: f64,
    rng: &mut Rng,
) -> Vec<(f64, f64)> {
    let (c, s) = (rot.cos(), rot.sin());
    poly.iter()
        .map(|&(px, py)| {
            let (mut px, mut py) = (px, py);
            if wobble > 0.0 {
                // Box–Muller-free wobble: uniform jitter is fine here.
                px += (rng.next_f64() * 2.0 - 1.0) * wobble * 1.5;
                py += (rng.next_f64() * 2.0 - 1.0) * wobble * 1.5;
            }
            let x = (px - 0.5) * sx + (py - 0.5) * shear;
            let y = (py - 0.5) * sy;
            (c * x - s * y + 0.5 + tx, s * x + c * y + 0.5 + ty)
        })
        .collect()
}

/// Render one distorted digit; distortion ranges mirror the python
/// generator (see data.py::render_digit).
pub fn render_digit(digit: usize, rng: &mut Rng) -> Vec<f32> {
    let rot = rng.range_f64(-0.5, 0.5);
    let sx = rng.range_f64(0.70, 1.30);
    let sy = rng.range_f64(0.70, 1.30);
    let shear = rng.range_f64(-0.3, 0.3);
    let tx = rng.range_f64(-0.12, 0.12);
    let ty = rng.range_f64(-0.12, 0.12);
    let width = rng.range_f64(0.022, 0.065);
    let soft = rng.range_f64(0.020, 0.050);
    let wobble = rng.range_f64(0.0, 0.035);

    let strokes: Vec<Vec<(f64, f64)>> = digit_strokes(digit)
        .iter()
        .map(|poly| affine(poly, rot, sx, sy, shear, tx, ty, wobble, rng))
        .collect();
    let mut img = rasterize(&strokes, width, soft);
    let gain = rng.range_f64(0.55, 1.0) as f32;
    for p in img.iter_mut() {
        *p *= gain;
    }
    if rng.next_f64() < 0.3 {
        let ph = 3 + rng.below(5) as usize;
        let pw = 3 + rng.below(5) as usize;
        let y0 = rng.below((IMG - ph) as u64) as usize;
        let x0 = rng.below((IMG - pw) as u64) as usize;
        for y in y0..y0 + ph {
            for x in x0..x0 + pw {
                img[y * IMG + x] = 0.0;
            }
        }
    }
    let mut gauss = crate::stats::GaussianSource::from_rng(rng.fork(0xDA7A));
    for p in img.iter_mut() {
        *p = (*p + 0.10 * gauss.next() as f32).clamp(0.0, 1.0);
    }
    img
}

/// Generate a balanced labeled set (native twin of data.py::generate).
pub fn generate(n: usize, seed: u64) -> crate::dataset::Dataset {
    let mut rng = Rng::new(seed);
    let mut images = Vec::with_capacity(n * IMG * IMG);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let d = i % 10;
        images.extend_from_slice(&render_digit(d, &mut rng));
        labels.push(d as i32);
    }
    // Shuffle consistently (indices, then gather).
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let mut out_img = Vec::with_capacity(images.len());
    let mut out_lbl = Vec::with_capacity(n);
    for &i in &idx {
        out_img.extend_from_slice(&images[i * IMG * IMG..(i + 1) * IMG * IMG]);
        out_lbl.push(labels[i]);
    }
    crate::dataset::Dataset { images: out_img, labels: out_lbl }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(20, 5);
        let b = generate(20, 5);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn all_digits_render_nonempty() {
        let mut rng = Rng::new(1);
        for d in 0..10 {
            let img = render_digit(d, &mut rng);
            let sum: f32 = img.iter().sum();
            assert!(sum > 5.0, "digit {d} rendered empty (sum={sum})");
            assert!(img.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
    }

    #[test]
    fn balanced_and_valid() {
        let ds = generate(100, 2);
        ds.validate().unwrap();
        let mut counts = [0; 10];
        for &l in &ds.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10));
    }

    #[test]
    fn digits_distinguishable_by_mean_image() {
        let ds = generate(400, 3);
        let mut mus = vec![vec![0.0f64; IMG * IMG]; 10];
        let mut counts = [0usize; 10];
        for i in 0..ds.len() {
            let l = ds.label(i) as usize;
            counts[l] += 1;
            for (m, &p) in mus[l].iter_mut().zip(ds.image(i)) {
                *m += p as f64;
            }
        }
        for (m, &c) in mus.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c as f64;
            }
        }
        // Every pair of class means should differ noticeably.
        for a in 0..10 {
            for b in a + 1..10 {
                let d: f64 = mus[a]
                    .iter()
                    .zip(&mus[b])
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum::<f64>()
                    .sqrt();
                assert!(d > 0.5, "digits {a} and {b} too similar: {d}");
            }
        }
    }
}
