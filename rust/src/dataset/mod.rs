//! Dataset layer (DESIGN.md §4.11): loader for the build-time-generated
//! synthetic MNIST binaries + a native generator mirror for tests.

pub mod loader;
pub mod synth;

pub use loader::Dataset;
