//! RACA — ReRAM Analog Computing Accelerator without ADCs.
//!
//! Full-system reproduction of "A Fully Hardware Implemented Accelerator
//! Design in ReRAM Analog Computing without ADCs" (Dang, Li, Wang, 2024).
//!
//! Three-layer architecture:
//! * **L1 (Pallas, build-time python)** — crossbar MAC + stochastic
//!   binarization kernels, lowered with `interpret=True`.
//! * **L2 (JAX, build-time python)** — the RACA forward pass (stochastic
//!   binary sigmoid layers + WTA softmax layer), AOT-lowered to HLO text.
//! * **L3 (this crate)** — the coordinator: analog-circuit simulator,
//!   PJRT runtime, trial scheduler, serving loop, and the NeuroSim-style
//!   hardware cost model that regenerates the paper's Table I.
//!
//! Module map (DESIGN.md §4): `stats` → `device` → `circuit` → `crossbar`
//! → `neuron` → `nn` → `engine` → `runtime` → `coordinator` → `fleet` →
//! `serve`, with `hwmodel` (Table I), `arch` (floorplan/pipeline/shard),
//! `dataset`, `figures` (Fig. 4/5/6), `telemetry` (per-node
//! [`telemetry::MetricsTree`] + event [`telemetry::Journal`]) and `util`
//! on the side.  `fleet`
//! programs, calibrates and health-models a farm of non-identical
//! simulated RACA dies; `serve` is the single public serving entry point —
//! a composable [`serve::Topology`] tree (`die` / `pipeline:<dies>`
//! leaves, `<n>x(…)` replication) compiled by [`serve::plan`] into nested
//! [`serve::Backend`]s: one batched chip (`SingleChipBackend`), a
//! router-dispatched replica farm (`ReplicatedFleetBackend`), a
//! layer-sharded die pipeline (`PipelinedFleetBackend`), a
//! health-reweighted router over arbitrary subtrees
//! (`serve::RouterBackend`) — and, through the [`serve::net`] wire layer
//! (`raca serve --listen`, `remote:<host:port>` leaves), trees that span
//! hosts.  [`registry`] adds signed, content-addressed model
//! distribution on top of that wire: `raca publish` stores a bundle,
//! listeners advertise it, and `remote:@<registry>/<bundle>` leaves
//! verify and bind it at build time.

pub mod arch;
pub mod circuit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod crossbar;
pub mod dataset;
pub mod device;
pub mod engine;
pub mod figures;
pub mod fleet;
pub mod hwmodel;
pub mod neuron;
pub mod nn;
pub mod planner;
pub mod registry;
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod telemetry;
pub mod util;

pub mod version {
    /// Crate version string, for the CLI banner.
    pub const VERSION: &str = env!("CARGO_PKG_VERSION");
}
