//! Tiny CLI argument parser (no clap in the offline vendor set).
//!
//! Supports `raca <subcommand> [--flag value] [--switch]`.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
    /// Bare (non-flag) arguments after the subcommand, in order — e.g.
    /// the target of `raca top <addr|topology>`.
    positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap();
                        out.flags.insert(name.to_string(), v);
                    }
                    _ => out.switches.push(name.to_string()),
                }
            } else {
                // Bare positional — keep the historical switch behavior
                // (so `has` still sees it) and record the order.
                out.switches.push(a.clone());
                out.positionals.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }

    /// The `i`-th bare argument after the subcommand, if any.
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("fig4 --panel c --samples 500 --fast");
        assert_eq!(a.subcommand.as_deref(), Some("fig4"));
        assert_eq!(a.get("panel"), Some("c"));
        assert_eq!(a.get_usize("samples", 0), 500);
        assert!(a.has("fast"));
        assert!(!a.has("slow"));
    }

    #[test]
    fn defaults() {
        let a = parse("fig6");
        assert_eq!(a.get_or("panel", "all"), "all");
        assert_eq!(a.get_usize("images", 200), 200);
        assert_eq!(a.get_f64("snr", 1.0), 1.0);
    }

    #[test]
    fn positionals_keep_order_and_skip_flag_values() {
        let a = parse("top 127.0.0.1:7433 --interval 2 --json");
        assert_eq!(a.subcommand.as_deref(), Some("top"));
        assert_eq!(a.positional(0), Some("127.0.0.1:7433"));
        assert_eq!(a.positional(1), None);
        assert_eq!(a.get("interval"), Some("2"));
        assert!(a.has("json"));
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.has("help"));
    }
}
