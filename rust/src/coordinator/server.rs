//! Serving front-end: a scheduler thread + cloneable submit handles.
//!
//! `Server::start(engine, cfg)` spawns the scheduler loop; [`ServerClient`]
//! (Clone + Send) submits requests and receives an `mpsc::Receiver` to
//! await the response — the thread-based analogue of a oneshot future.
//! Backpressure: when the scheduler is at `max_in_flight`, submissions
//! park in the inbox until capacity frees (bounded by the inbox itself).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::metrics::Metrics;
use super::scheduler::{Scheduler, SchedulerConfig, TrialRunner};
use crate::serve::{InferRequest, InferResponse};

enum Msg {
    Submit(InferRequest, mpsc::Sender<InferResponse>),
    Shutdown,
}

/// Owner of the scheduler thread.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    next_id: Arc<AtomicU64>,
}

/// Cloneable, Send submission handle.
#[derive(Clone)]
pub struct ServerClient {
    tx: mpsc::Sender<Msg>,
    next_id: Arc<AtomicU64>,
}

impl Server {
    /// Spawn the scheduler loop over `engine`.
    pub fn start<E: TrialRunner + Send + 'static>(engine: E, cfg: SchedulerConfig) -> Self {
        let metrics = Metrics::new();
        let m2 = metrics.clone();
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = std::thread::Builder::new()
            .name("raca-scheduler".into())
            .spawn(move || server_loop(engine, cfg, m2, rx))
            .expect("spawning scheduler thread");
        Self { tx, worker: Some(worker), metrics, next_id: Arc::new(AtomicU64::new(1)) }
    }

    pub fn client(&self) -> ServerClient {
        ServerClient { tx: self.tx.clone(), next_id: self.next_id.clone() }
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl ServerClient {
    /// Submit an image; returns a receiver for the response.
    pub fn submit(
        &self,
        image: Vec<f32>,
        max_trials: u32,
        confidence: f64,
    ) -> Result<mpsc::Receiver<InferResponse>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.submit_request(InferRequest::new(id, image).with_budget(max_trials, confidence))
    }

    /// Submit a fully-formed request (the [`crate::serve::Backend`] path;
    /// the caller owns id uniqueness).
    pub fn submit_request(&self, req: InferRequest) -> Result<mpsc::Receiver<InferResponse>> {
        let (reply, rx) = mpsc::channel();
        self.submit_request_to(req, reply)?;
        Ok(rx)
    }

    /// Submit with a caller-owned reply channel — the primitive behind
    /// [`crate::serve::Backend::submit_to`]: many requests may share one
    /// channel, so routers/sessions can multiplex completions.
    pub fn submit_request_to(
        &self,
        req: InferRequest,
        reply: mpsc::Sender<InferResponse>,
    ) -> Result<()> {
        self.tx
            .send(Msg::Submit(req, reply))
            .map_err(|_| anyhow!("server is gone"))
    }

    /// Submit and block for the answer.
    pub fn classify(&self, image: Vec<f32>, max_trials: u32, confidence: f64) -> Result<InferResponse> {
        self.submit(image, max_trials, confidence)?
            .recv()
            .map_err(|_| anyhow!("server dropped the request"))
    }
}

fn server_loop<E: TrialRunner>(
    engine: E,
    cfg: SchedulerConfig,
    metrics: Arc<Metrics>,
    rx: mpsc::Receiver<Msg>,
) {
    let mut sched = Scheduler::new(engine, cfg, metrics);
    let mut replies: std::collections::HashMap<u64, mpsc::Sender<InferResponse>> =
        std::collections::HashMap::new();
    let mut pending: std::collections::VecDeque<(InferRequest, mpsc::Sender<InferResponse>)> =
        std::collections::VecDeque::new();
    let mut shutdown = false;

    loop {
        // Admit new work. Block only when idle (nothing to step).
        if sched.is_idle() && pending.is_empty() {
            if shutdown {
                return;
            }
            match rx.recv() {
                Ok(Msg::Submit(r, tx)) => pending.push_back((r, tx)),
                Ok(Msg::Shutdown) | Err(_) => return,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(Msg::Submit(r, tx)) => pending.push_back((r, tx)),
                Ok(Msg::Shutdown) => shutdown = true,
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => shutdown = true,
            }
            if shutdown {
                break;
            }
        }
        // Move parked submissions into the scheduler while capacity lasts.
        while let Some((r, tx)) = pending.pop_front() {
            let id = r.id;
            if replies.contains_key(&id) {
                // Duplicate in-flight id (e.g. two network sessions that
                // failed to split the id space): reject this request
                // in-band instead of silently orphaning the first one.
                let _ = tx.send(InferResponse::failed(
                    id,
                    format!("request id {id} is already in flight on this scheduler"),
                ));
                continue;
            }
            match sched.submit(r) {
                Ok(()) => {
                    replies.insert(id, tx);
                }
                Err(r) => {
                    pending.push_front((r, tx));
                    break;
                }
            }
        }
        // One scheduling iteration.
        match sched.step() {
            Ok(done) => {
                for resp in done {
                    if let Some(tx) = replies.remove(&resp.id) {
                        let _ = tx.send(resp);
                    }
                }
            }
            Err(e) => {
                log::warn!("engine batch failed (will retry): {e:#}");
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
        }
        if shutdown && sched.is_idle() && pending.is_empty() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;
    use crate::nn::{ModelSpec, Weights};

    fn server() -> Server {
        let w = std::sync::Arc::new(Weights::random(ModelSpec::new(vec![784, 16, 10]), 3));
        let e = NativeEngine::new(w, 7);
        let mut cfg = SchedulerConfig::default();
        cfg.batch_size = 16;
        Server::start(e, cfg)
    }

    #[test]
    fn classify_roundtrip() {
        let s = server();
        let c = s.client();
        let r = c.classify(vec![0.5; 784], 9, 0.0).unwrap();
        assert_eq!(r.trials_used, 9);
        assert!((-1..10).contains(&r.prediction));
    }

    #[test]
    fn concurrent_clients() {
        let s = server();
        let mut handles = Vec::new();
        for t in 0..4 {
            let c = s.client();
            handles.push(std::thread::spawn(move || {
                let mut preds = Vec::new();
                for i in 0..5 {
                    let x = vec![(t as f32 * 5.0 + i as f32) / 20.0; 784];
                    preds.push(c.classify(x, 7, 0.0).unwrap().prediction);
                }
                preds
            }));
        }
        for h in handles {
            let preds = h.join().unwrap();
            assert_eq!(preds.len(), 5);
        }
        let m = s.metrics().snapshot();
        assert_eq!(m.requests_completed, 20);
        assert_eq!(m.trials_executed, 20 * 7);
    }

    #[test]
    fn shutdown_completes_in_flight() {
        let s = server();
        let c = s.client();
        let rx = s.client().submit(vec![0.3; 784], 5, 0.0).unwrap();
        drop(c);
        drop(s); // Drop waits for the worker; in-flight work must finish.
        let r = rx.recv().unwrap();
        assert_eq!(r.trials_used, 5);
    }
}
