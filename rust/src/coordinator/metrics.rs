//! Coordinator metrics: lock-free counters + striped latency reservoir.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Shared metric sink (cheap atomics on the hot path).
///
/// Latency samples go to a **striped** reservoir: an atomic cursor
/// rotates writers over [`SHARDS`] independent locks, so concurrent
/// workers (fleet worker threads, pipeline stages, router relays) never
/// serialize on one `Mutex<Vec>` the way they did pre-PR-6.  Shards are
/// merged (and sorted once) at snapshot time — the cold path.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_admitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub trials_executed: AtomicU64,
    pub batches_executed: AtomicU64,
    /// Σ rows over all batches (fill ratio = rows/(batches·batch_size)).
    pub rows_packed: AtomicU64,
    /// Trials saved by early stopping (budget − used, summed).
    pub trials_saved: AtomicU64,
    pub engine_errors: AtomicU64,
    /// Round-robin shard selector for [`Self::record_latency`].
    cursor: AtomicUsize,
    /// Latency samples in µs (bounded recency-weighted window, striped).
    latencies_us: [Mutex<Vec<u64>>; SHARDS],
}

/// Point-in-time copy for reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub requests_admitted: u64,
    pub requests_completed: u64,
    pub trials_executed: u64,
    pub batches_executed: u64,
    pub rows_packed: u64,
    pub trials_saved: u64,
    pub engine_errors: u64,
    pub latency_p50_us: u64,
    pub latency_p99_us: u64,
}

const RESERVOIR: usize = 65_536;
/// Stripes for the latency window (power of two; index is a mask).
const SHARDS: usize = 8;
const SHARD_CAP: usize = RESERVOIR / SHARDS;

impl Metrics {
    pub fn new() -> std::sync::Arc<Self> {
        std::sync::Arc::new(Self::default())
    }

    pub fn record_latency(&self, d: std::time::Duration) {
        let shard = self.cursor.fetch_add(1, Ordering::Relaxed) & (SHARDS - 1);
        let mut v = self.latencies_us[shard].lock().unwrap();
        if v.len() >= SHARD_CAP {
            // Drop the oldest half.  The pre-PR-6 `step_by(2)` halving
            // kept index 0 (the very first sample) forever while thinning
            // the *newest* half on every overflow — repeated halvings
            // skewed the percentiles toward ancient samples.  Discarding
            // from the old end keeps the window recency-weighted: the
            // newest sample always survives, and what ages out is always
            // the oldest data.
            v.drain(..SHARD_CAP / 2);
        }
        v.push(d.as_micros() as u64);
    }

    /// Samples currently retained across all shards (tests/diagnostics).
    pub fn latency_samples(&self) -> usize {
        self.latencies_us.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut lat: Vec<u64> = Vec::new();
        for shard in &self.latencies_us {
            lat.extend_from_slice(&shard.lock().unwrap());
        }
        lat.sort_unstable();
        let pct = |p: f64| -> u64 {
            if lat.is_empty() {
                0
            } else {
                lat[((lat.len() - 1) as f64 * p).ceil() as usize]
            }
        };
        MetricsSnapshot {
            requests_admitted: self.requests_admitted.load(Ordering::Relaxed),
            requests_completed: self.requests_completed.load(Ordering::Relaxed),
            trials_executed: self.trials_executed.load(Ordering::Relaxed),
            batches_executed: self.batches_executed.load(Ordering::Relaxed),
            rows_packed: self.rows_packed.load(Ordering::Relaxed),
            trials_saved: self.trials_saved.load(Ordering::Relaxed),
            engine_errors: self.engine_errors.load(Ordering::Relaxed),
            latency_p50_us: pct(0.50),
            latency_p99_us: pct(0.99),
        }
    }
}

impl MetricsSnapshot {
    /// Merge two snapshots (fleet aggregation over per-chip metrics):
    /// counters add; latency percentiles take the elementwise max, a
    /// conservative upper bound since the underlying reservoirs are gone.
    pub fn combine(&self, other: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            requests_admitted: self.requests_admitted + other.requests_admitted,
            requests_completed: self.requests_completed + other.requests_completed,
            trials_executed: self.trials_executed + other.trials_executed,
            batches_executed: self.batches_executed + other.batches_executed,
            rows_packed: self.rows_packed + other.rows_packed,
            trials_saved: self.trials_saved + other.trials_saved,
            engine_errors: self.engine_errors + other.engine_errors,
            latency_p50_us: self.latency_p50_us.max(other.latency_p50_us),
            latency_p99_us: self.latency_p99_us.max(other.latency_p99_us),
        }
    }

    /// Mean batch occupancy in [0, 1] given the configured batch size.
    pub fn fill_ratio(&self, batch_size: usize) -> f64 {
        if self.batches_executed == 0 {
            return 0.0;
        }
        self.rows_packed as f64 / (self.batches_executed as f64 * batch_size as f64)
    }

    /// Mean trials per completed request.
    pub fn trials_per_request(&self) -> f64 {
        if self.requests_completed == 0 {
            return 0.0;
        }
        self.trials_executed as f64 / self.requests_completed as f64
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "req {}/{} trials {} (saved {}) batches {} p50 {}µs p99 {}µs errs {}",
            self.requests_completed,
            self.requests_admitted,
            self.trials_executed,
            self.trials_saved,
            self.batches_executed,
            self.latency_p50_us,
            self.latency_p99_us,
            self.engine_errors
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn counters_and_percentiles() {
        let m = Metrics::new();
        m.requests_admitted.fetch_add(3, Ordering::Relaxed);
        m.trials_executed.fetch_add(40, Ordering::Relaxed);
        m.requests_completed.fetch_add(2, Ordering::Relaxed);
        for us in [100u64, 200, 300, 400, 500] {
            m.record_latency(Duration::from_micros(us));
        }
        let s = m.snapshot();
        assert_eq!(s.requests_admitted, 3);
        assert_eq!(s.latency_p50_us, 300);
        assert_eq!(s.latency_p99_us, 500);
        assert!((s.trials_per_request() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn fill_ratio() {
        let m = Metrics::new();
        m.batches_executed.fetch_add(4, Ordering::Relaxed);
        m.rows_packed.fetch_add(100, Ordering::Relaxed);
        assert!((m.snapshot().fill_ratio(32) - 100.0 / 128.0).abs() < 1e-12);
    }

    #[test]
    fn combine_adds_counters_and_maxes_latency() {
        let a = MetricsSnapshot {
            requests_admitted: 3,
            requests_completed: 2,
            trials_executed: 40,
            batches_executed: 4,
            rows_packed: 60,
            trials_saved: 5,
            engine_errors: 1,
            latency_p50_us: 100,
            latency_p99_us: 900,
        };
        let mut b = a.clone();
        b.latency_p50_us = 250;
        b.latency_p99_us = 400;
        let c = a.combine(&b);
        assert_eq!(c.trials_executed, 80);
        assert_eq!(c.requests_completed, 4);
        assert_eq!(c.engine_errors, 2);
        assert_eq!(c.latency_p50_us, 250);
        assert_eq!(c.latency_p99_us, 900);
    }

    #[test]
    fn reservoir_bounded() {
        let m = Metrics::new();
        for i in 0..(RESERVOIR * 2 + 10) {
            m.record_latency(Duration::from_micros(i as u64));
        }
        assert!(m.latency_samples() <= RESERVOIR);
        let s = m.snapshot();
        assert!(s.latency_p99_us > s.latency_p50_us);
    }

    #[test]
    fn overflow_discards_oldest_not_newest() {
        // Fill far past capacity with monotonically increasing samples:
        // a correctly recency-weighted window must retain the *latest*
        // sample and every retained sample must come from the newer half
        // of the stream.  (The old `step_by(2)` halving kept sample #0
        // forever and thinned the newest half on each overflow.)
        let m = Metrics::new();
        let total = RESERVOIR * 4;
        for i in 0..total {
            m.record_latency(Duration::from_micros(i as u64));
        }
        let mut all: Vec<u64> = Vec::new();
        for shard in &m.latencies_us {
            all.extend_from_slice(&shard.lock().unwrap());
        }
        assert!(all.contains(&(total as u64 - 1)), "newest sample must survive overflow");
        let oldest = *all.iter().min().unwrap();
        assert!(
            oldest >= (total / 2) as u64,
            "sample {oldest} predates the newer half of a {total}-long stream"
        );
        // p99 over a 0..total ramp restricted to the recent window.
        assert!(m.snapshot().latency_p99_us > (total as f64 * 0.9) as u64);
    }

    #[test]
    fn striped_writes_merge_at_snapshot() {
        // One sample per shard: the snapshot must see all of them even
        // though no single shard holds more than one.
        let m = Metrics::new();
        for us in [100u64, 200, 300, 400, 500, 600, 700, 800] {
            m.record_latency(Duration::from_micros(us));
        }
        assert_eq!(m.latency_samples(), 8);
        for shard in &m.latencies_us {
            assert!(shard.lock().unwrap().len() <= 1);
        }
        let s = m.snapshot();
        assert_eq!(s.latency_p50_us, 500); // ceil((8-1) * 0.5) = idx 4
        assert_eq!(s.latency_p99_us, 800);
    }
}
