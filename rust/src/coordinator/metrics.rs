//! Coordinator metrics: lock-free counters + latency reservoir.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Shared metric sink (cheap atomics on the hot path).
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_admitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub trials_executed: AtomicU64,
    pub batches_executed: AtomicU64,
    /// Σ rows over all batches (fill ratio = rows/(batches·batch_size)).
    pub rows_packed: AtomicU64,
    /// Trials saved by early stopping (budget − used, summed).
    pub trials_saved: AtomicU64,
    pub engine_errors: AtomicU64,
    /// Latency samples in µs (bounded reservoir).
    latencies_us: Mutex<Vec<u64>>,
}

/// Point-in-time copy for reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub requests_admitted: u64,
    pub requests_completed: u64,
    pub trials_executed: u64,
    pub batches_executed: u64,
    pub rows_packed: u64,
    pub trials_saved: u64,
    pub engine_errors: u64,
    pub latency_p50_us: u64,
    pub latency_p99_us: u64,
}

const RESERVOIR: usize = 65_536;

impl Metrics {
    pub fn new() -> std::sync::Arc<Self> {
        std::sync::Arc::new(Self::default())
    }

    pub fn record_latency(&self, d: std::time::Duration) {
        let mut v = self.latencies_us.lock().unwrap();
        if v.len() >= RESERVOIR {
            // Halve the reservoir (keep every other sample) — bounded
            // memory with a still-representative distribution.
            let kept: Vec<u64> = v.iter().copied().step_by(2).collect();
            *v = kept;
        }
        v.push(d.as_micros() as u64);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut lat = self.latencies_us.lock().unwrap().clone();
        lat.sort_unstable();
        let pct = |p: f64| -> u64 {
            if lat.is_empty() {
                0
            } else {
                lat[((lat.len() - 1) as f64 * p).ceil() as usize]
            }
        };
        MetricsSnapshot {
            requests_admitted: self.requests_admitted.load(Ordering::Relaxed),
            requests_completed: self.requests_completed.load(Ordering::Relaxed),
            trials_executed: self.trials_executed.load(Ordering::Relaxed),
            batches_executed: self.batches_executed.load(Ordering::Relaxed),
            rows_packed: self.rows_packed.load(Ordering::Relaxed),
            trials_saved: self.trials_saved.load(Ordering::Relaxed),
            engine_errors: self.engine_errors.load(Ordering::Relaxed),
            latency_p50_us: pct(0.50),
            latency_p99_us: pct(0.99),
        }
    }
}

impl MetricsSnapshot {
    /// Merge two snapshots (fleet aggregation over per-chip metrics):
    /// counters add; latency percentiles take the elementwise max, a
    /// conservative upper bound since the underlying reservoirs are gone.
    pub fn combine(&self, other: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            requests_admitted: self.requests_admitted + other.requests_admitted,
            requests_completed: self.requests_completed + other.requests_completed,
            trials_executed: self.trials_executed + other.trials_executed,
            batches_executed: self.batches_executed + other.batches_executed,
            rows_packed: self.rows_packed + other.rows_packed,
            trials_saved: self.trials_saved + other.trials_saved,
            engine_errors: self.engine_errors + other.engine_errors,
            latency_p50_us: self.latency_p50_us.max(other.latency_p50_us),
            latency_p99_us: self.latency_p99_us.max(other.latency_p99_us),
        }
    }

    /// Mean batch occupancy in [0, 1] given the configured batch size.
    pub fn fill_ratio(&self, batch_size: usize) -> f64 {
        if self.batches_executed == 0 {
            return 0.0;
        }
        self.rows_packed as f64 / (self.batches_executed as f64 * batch_size as f64)
    }

    /// Mean trials per completed request.
    pub fn trials_per_request(&self) -> f64 {
        if self.requests_completed == 0 {
            return 0.0;
        }
        self.trials_executed as f64 / self.requests_completed as f64
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "req {}/{} trials {} (saved {}) batches {} p50 {}µs p99 {}µs errs {}",
            self.requests_completed,
            self.requests_admitted,
            self.trials_executed,
            self.trials_saved,
            self.batches_executed,
            self.latency_p50_us,
            self.latency_p99_us,
            self.engine_errors
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn counters_and_percentiles() {
        let m = Metrics::new();
        m.requests_admitted.fetch_add(3, Ordering::Relaxed);
        m.trials_executed.fetch_add(40, Ordering::Relaxed);
        m.requests_completed.fetch_add(2, Ordering::Relaxed);
        for us in [100u64, 200, 300, 400, 500] {
            m.record_latency(Duration::from_micros(us));
        }
        let s = m.snapshot();
        assert_eq!(s.requests_admitted, 3);
        assert_eq!(s.latency_p50_us, 300);
        assert_eq!(s.latency_p99_us, 500);
        assert!((s.trials_per_request() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn fill_ratio() {
        let m = Metrics::new();
        m.batches_executed.fetch_add(4, Ordering::Relaxed);
        m.rows_packed.fetch_add(100, Ordering::Relaxed);
        assert!((m.snapshot().fill_ratio(32) - 100.0 / 128.0).abs() < 1e-12);
    }

    #[test]
    fn combine_adds_counters_and_maxes_latency() {
        let a = MetricsSnapshot {
            requests_admitted: 3,
            requests_completed: 2,
            trials_executed: 40,
            batches_executed: 4,
            rows_packed: 60,
            trials_saved: 5,
            engine_errors: 1,
            latency_p50_us: 100,
            latency_p99_us: 900,
        };
        let mut b = a.clone();
        b.latency_p50_us = 250;
        b.latency_p99_us = 400;
        let c = a.combine(&b);
        assert_eq!(c.trials_executed, 80);
        assert_eq!(c.requests_completed, 4);
        assert_eq!(c.engine_errors, 2);
        assert_eq!(c.latency_p50_us, 250);
        assert_eq!(c.latency_p99_us, 900);
    }

    #[test]
    fn reservoir_bounded() {
        let m = Metrics::new();
        for i in 0..(RESERVOIR * 2 + 10) {
            m.record_latency(Duration::from_micros(i as u64));
        }
        let len = m.latencies_us.lock().unwrap().len();
        assert!(len <= RESERVOIR + 1);
        let s = m.snapshot();
        assert!(s.latency_p99_us > s.latency_p50_us);
    }
}
