//! Trial batcher: packs (request, trial) pairs into fixed-size batches.
//!
//! The trial executable processes `B` rows per call; each row is one
//! stochastic trial of one image.  The batcher fills rows round-robin
//! across every in-flight request (fairness: no request starves while the
//! batch is full) and allows the *same* request to occupy multiple rows in
//! one batch when there is spare capacity — each row draws independent
//! noise, so k rows = k trials.
//!
//! Invariants (property-tested in rust/tests/properties.rs):
//! * a packed batch never exceeds `batch_size` rows;
//! * every packed row belongs to a registered, unfinished request;
//! * per-request rows in one batch ≤ its remaining trial budget;
//! * round-robin fairness: row counts of any two eligible requests differ
//!   by at most 1 until a budget binds.

use std::collections::VecDeque;

use crate::serve::RequestId;

/// A request's packing view.
#[derive(Debug, Clone)]
pub struct Slot {
    pub id: RequestId,
    /// Trials still allowed for this request (budget − issued).
    pub remaining: u32,
}

/// The outcome of one packing round.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedBatch {
    /// One entry per row: which request this trial belongs to.
    pub rows: Vec<RequestId>,
}

impl PackedBatch {
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// Round-robin packer over in-flight requests.
#[derive(Debug, Default)]
pub struct Batcher {
    queue: VecDeque<Slot>,
}

impl Batcher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a request with a trial budget.
    pub fn admit(&mut self, id: RequestId, budget: u32) {
        debug_assert!(budget > 0);
        self.queue.push_back(Slot { id, remaining: budget });
    }

    /// Remove a request (completed or early-stopped).
    pub fn remove(&mut self, id: RequestId) {
        self.queue.retain(|s| s.id != id);
    }

    /// Reduce a request's remaining budget after results arrive, removing
    /// it when exhausted.  Returns whether the request is still active.
    pub fn consume(&mut self, id: RequestId, used: u32) -> bool {
        if let Some(s) = self.queue.iter_mut().find(|s| s.id == id) {
            s.remaining = s.remaining.saturating_sub(used);
            if s.remaining == 0 {
                self.remove(id);
                return false;
            }
            return true;
        }
        false
    }

    pub fn in_flight(&self) -> usize {
        self.queue.len()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty()
    }

    /// Pack up to `batch_size` rows, round-robin across the queue.
    ///
    /// Does NOT mutate budgets — the scheduler calls [`Batcher::consume`]
    /// once results return (a failed execute must not burn budget).
    pub fn pack(&mut self, batch_size: usize) -> PackedBatch {
        let mut rows = Vec::with_capacity(batch_size);
        if self.queue.is_empty() || batch_size == 0 {
            return PackedBatch { rows };
        }
        // Per-round virtual budgets.
        let mut remaining: Vec<u32> = self.queue.iter().map(|s| s.remaining).collect();
        let n = self.queue.len();
        let mut i = 0usize;
        let mut exhausted = 0usize;
        while rows.len() < batch_size && exhausted < n {
            if remaining[i] > 0 {
                rows.push(self.queue[i].id);
                remaining[i] -= 1;
                if remaining[i] == 0 {
                    exhausted += 1;
                }
            }
            i = (i + 1) % n;
        }
        // Rotate the queue so the next pack starts from a different head
        // (long-run fairness when batches regularly fill).
        if n > 1 {
            self.queue.rotate_left(1);
        }
        PackedBatch { rows }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packs_round_robin_fairly() {
        let mut b = Batcher::new();
        b.admit(1, 10);
        b.admit(2, 10);
        b.admit(3, 10);
        let p = b.pack(7);
        assert_eq!(p.len(), 7);
        let c1 = p.rows.iter().filter(|&&r| r == 1).count();
        let c2 = p.rows.iter().filter(|&&r| r == 2).count();
        let c3 = p.rows.iter().filter(|&&r| r == 3).count();
        assert_eq!(c1 + c2 + c3, 7);
        assert!(c1.abs_diff(c2) <= 1 && c2.abs_diff(c3) <= 1);
    }

    #[test]
    fn respects_budget() {
        let mut b = Batcher::new();
        b.admit(1, 2);
        b.admit(2, 100);
        let p = b.pack(32);
        assert_eq!(p.rows.iter().filter(|&&r| r == 1).count(), 2);
        assert_eq!(p.rows.iter().filter(|&&r| r == 2).count(), 30);
    }

    #[test]
    fn single_request_fills_batch() {
        let mut b = Batcher::new();
        b.admit(9, 100);
        let p = b.pack(32);
        assert_eq!(p.len(), 32);
        assert!(p.rows.iter().all(|&r| r == 9));
    }

    #[test]
    fn underfull_when_budgets_small() {
        let mut b = Batcher::new();
        b.admit(1, 1);
        b.admit(2, 1);
        let p = b.pack(32);
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn consume_retires_requests() {
        let mut b = Batcher::new();
        b.admit(1, 3);
        assert!(b.consume(1, 2));
        assert!(!b.consume(1, 1));
        assert!(b.is_idle());
        assert!(!b.consume(1, 1)); // unknown id is a no-op
    }

    #[test]
    fn remove_unknown_is_noop() {
        let mut b = Batcher::new();
        b.admit(1, 5);
        b.remove(42);
        assert_eq!(b.in_flight(), 1);
    }

    #[test]
    fn empty_pack() {
        let mut b = Batcher::new();
        assert!(b.pack(8).is_empty());
        b.admit(1, 4);
        assert!(b.pack(0).is_empty());
    }
}
