//! Trial scheduler: the pack → execute → count → early-stop loop.
//!
//! Owns the vote state of every in-flight request.  Each iteration packs a
//! batch (round-robin over active requests), executes it on the engine,
//! distributes winners into per-request [`WtaOutcome`] counters, and
//! completes requests that either exhausted their budget or whose leading
//! class is statistically decided (Wilson lower bound of lead vs runner-up
//! > 0.5 at the request's confidence level — `stats::ci`).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::engine::TrialParams;
use crate::neuron::WtaOutcome;
use crate::stats::ci::lead_is_decided;

use super::batcher::Batcher;
use super::metrics::Metrics;
use crate::serve::{InferRequest, InferResponse, RequestId};

/// Engine abstraction the scheduler drives (`NativeEngine`, the fleet's
/// [`crate::fleet::FleetRunner`], and — under the `pjrt` feature —
/// `XlaEngineHandle` implement it).
pub trait TrialRunner {
    /// Execute `rows.len()/features` trials; one winner per row.
    fn run(&self, x: &[f32], rows: usize, seed: u32, p: TrialParams) -> Result<Vec<i32>>;
    /// Preferred (maximum) rows per execution.
    fn preferred_batch(&self) -> usize;
}

#[cfg(feature = "pjrt")]
impl TrialRunner for crate::engine::XlaEngineHandle {
    fn run(&self, x: &[f32], rows: usize, seed: u32, p: TrialParams) -> Result<Vec<i32>> {
        let features = x.len() / rows;
        self.run_trials_any(x, rows, features, seed, p)
    }

    fn preferred_batch(&self) -> usize {
        32
    }
}

impl TrialRunner for crate::engine::NativeEngine {
    fn run(&self, x: &[f32], rows: usize, seed: u32, p: TrialParams) -> Result<Vec<i32>> {
        let features = x.len() / rows;
        Ok(self.run_trial_batch(x, features, p, seed as u64))
    }

    fn preferred_batch(&self) -> usize {
        32
    }
}

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Rows per trial execution (must match an available artifact batch
    /// for the XLA engine).
    pub batch_size: usize,
    /// Trial physics (σ_z, θ, steps).
    pub params: TrialParams,
    /// Minimum trials before early stopping may trigger.
    pub min_trials: u32,
    /// Base PRNG seed (requests derive unique streams from it).
    pub seed: u64,
    /// Admission cap: maximum in-flight requests (backpressure).
    pub max_in_flight: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            batch_size: 32,
            params: TrialParams::default(),
            min_trials: 5,
            seed: 0x52ACA,
            max_in_flight: 256,
        }
    }
}

struct Active {
    request: InferRequest,
    outcome: WtaOutcome,
    issued: u32,
    submitted: Instant,
}

/// The pack/execute/count loop.  Drive it with [`Scheduler::submit`] +
/// [`Scheduler::step`] (the server wraps this in a thread; figure
/// harnesses call it synchronously).
pub struct Scheduler<E: TrialRunner> {
    pub cfg: SchedulerConfig,
    engine: E,
    batcher: Batcher,
    active: HashMap<RequestId, Active>,
    metrics: Arc<Metrics>,
    seq: u64,
    features: usize,
    classes: usize,
}

impl<E: TrialRunner> Scheduler<E> {
    pub fn new(engine: E, cfg: SchedulerConfig, metrics: Arc<Metrics>) -> Self {
        Self {
            cfg,
            engine,
            batcher: Batcher::new(),
            active: HashMap::new(),
            metrics,
            seq: 0,
            features: 784,
            classes: 10,
        }
    }

    /// Admit a request.  Fails (backpressure) when at capacity.
    pub fn submit(&mut self, req: InferRequest) -> Result<(), InferRequest> {
        if self.active.len() >= self.cfg.max_in_flight {
            return Err(req);
        }
        debug_assert_eq!(req.image.len(), self.features);
        self.metrics
            .requests_admitted
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.batcher.admit(req.id, req.max_trials);
        self.active.insert(
            req.id,
            Active {
                outcome: WtaOutcome::new(self.classes),
                issued: 0,
                submitted: Instant::now(),
                request: req,
            },
        );
        Ok(())
    }

    pub fn in_flight(&self) -> usize {
        self.active.len()
    }

    /// The engine behind this scheduler (fleet harnesses read per-chip
    /// metrics off it after a run).
    pub fn engine(&self) -> &E {
        &self.engine
    }

    pub fn is_idle(&self) -> bool {
        self.active.is_empty()
    }

    /// Run one pack→execute→count iteration; returns completed responses.
    ///
    /// An engine error fails the whole batch but *not* the requests — their
    /// budgets were not consumed, so the next step retries.
    ///
    /// Requests past their deadline budget are shed *before* packing, with
    /// in-band `deadline_exceeded` failures: trials nobody will read are
    /// never executed.  A step that shed anything returns those responses
    /// immediately and defers packing to the next step, so shed results
    /// cannot be lost to an engine error in the same iteration.
    pub fn step(&mut self) -> Result<Vec<InferResponse>> {
        let expired: Vec<RequestId> = self
            .active
            .iter()
            .filter(|(_, a)| a.request.past_deadline(a.submitted.elapsed()))
            .map(|(&id, _)| id)
            .collect();
        if !expired.is_empty() {
            let mut shed = Vec::with_capacity(expired.len());
            for id in expired {
                let a = self.active.remove(&id).unwrap();
                self.batcher.remove(id);
                self.metrics
                    .engine_errors
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                shed.push(InferResponse::failed(
                    id,
                    crate::serve::deadline_exceeded_msg(
                        "scheduler",
                        a.submitted.elapsed(),
                        a.request.deadline_ms.unwrap_or(0),
                    ),
                ));
            }
            return Ok(shed);
        }
        let packed = self.batcher.pack(self.cfg.batch_size);
        if packed.is_empty() {
            return Ok(Vec::new());
        }
        let rows = packed.rows.len();
        let mut x = Vec::with_capacity(rows * self.features);
        for &id in &packed.rows {
            x.extend_from_slice(&self.active[&id].request.image);
        }
        self.seq += 1;
        let seed = (self.cfg.seed ^ self.seq.wrapping_mul(0x9E3779B9)) as u32;

        let winners = match self.engine.run(&x, rows, seed, self.cfg.params) {
            Ok(w) => w,
            Err(e) => {
                self.metrics
                    .engine_errors
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                return Err(e);
            }
        };
        self.metrics
            .batches_executed
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.metrics
            .rows_packed
            .fetch_add(rows as u64, std::sync::atomic::Ordering::Relaxed);
        self.metrics
            .trials_executed
            .fetch_add(rows as u64, std::sync::atomic::Ordering::Relaxed);

        // Distribute winners and account budgets.
        let mut used: HashMap<RequestId, u32> = HashMap::new();
        for (&id, &win) in packed.rows.iter().zip(&winners) {
            let a = self.active.get_mut(&id).expect("row for unknown request");
            a.outcome.record(win);
            a.issued += 1;
            *used.entry(id).or_insert(0) += 1;
        }

        let mut done = Vec::new();
        for (id, used_now) in used {
            let still_budgeted = self.batcher.consume(id, used_now);
            let a = &self.active[&id];
            let decided = if a.request.confidence > 0.0 && a.issued >= self.cfg.min_trials {
                let (lead, runner) = a.outcome.top_two();
                lead_is_decided(lead, runner, a.request.confidence)
            } else {
                false
            };
            if !still_budgeted || decided {
                let a = self.active.remove(&id).unwrap();
                if decided {
                    self.batcher.remove(id);
                    self.metrics.trials_saved.fetch_add(
                        (a.request.max_trials - a.issued) as u64,
                        std::sync::atomic::Ordering::Relaxed,
                    );
                }
                let latency = a.submitted.elapsed();
                self.metrics.record_latency(latency);
                self.metrics
                    .requests_completed
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                done.push(InferResponse {
                    id,
                    prediction: a.outcome.prediction(),
                    trials_used: a.issued,
                    outcome: a.outcome,
                    latency,
                    error: None,
                });
            }
        }
        Ok(done)
    }

    /// Drain: step until every in-flight request completes.
    pub fn run_to_completion(&mut self) -> Result<Vec<InferResponse>> {
        let mut out = Vec::new();
        let mut consecutive_errors = 0u32;
        while !self.is_idle() {
            match self.step() {
                Ok(mut r) => {
                    consecutive_errors = 0;
                    out.append(&mut r);
                }
                Err(e) => {
                    consecutive_errors += 1;
                    if consecutive_errors >= 3 {
                        return Err(e.context("engine failed 3 consecutive batches"));
                    }
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;
    use crate::nn::{ModelSpec, Weights};
    use std::sync::Arc;

    fn sched(conf: f64) -> Scheduler<NativeEngine> {
        let w = Arc::new(Weights::random(ModelSpec::new(vec![784, 16, 10]), 3));
        let e = NativeEngine::new(w, 7);
        let mut cfg = SchedulerConfig::default();
        cfg.batch_size = 16;
        cfg.min_trials = 4;
        let mut s = Scheduler::new(e, cfg, Metrics::new());
        s.features = 784;
        let _ = conf;
        s
    }

    fn req(id: u64, trials: u32, conf: f64) -> InferRequest {
        InferRequest::new(id, vec![0.5; 784]).with_budget(trials, conf)
    }

    #[test]
    fn completes_all_requests() {
        let mut s = sched(0.0);
        for i in 0..5 {
            s.submit(req(i, 9, 0.0)).unwrap();
        }
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 5);
        for r in &done {
            assert_eq!(r.trials_used, 9);
            assert_eq!(r.outcome.trials, 9);
        }
        assert!(s.is_idle());
    }

    #[test]
    fn early_stop_spends_fewer_trials() {
        let mut s = sched(0.95);
        s.submit(req(1, 200, 0.95)).unwrap();
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        // A 784→16→10 random net on a fixed input still has a dominant
        // class often enough; if it stopped early the budget was not spent.
        assert!(done[0].trials_used <= 200);
        if done[0].trials_used < 200 {
            let (lead, runner) = done[0].outcome.top_two();
            assert!(lead_is_decided(lead, runner, 0.95));
        }
    }

    #[test]
    fn backpressure_rejects_over_capacity() {
        let mut s = sched(0.0);
        s.cfg.max_in_flight = 2;
        assert!(s.submit(req(1, 4, 0.0)).is_ok());
        assert!(s.submit(req(2, 4, 0.0)).is_ok());
        assert!(s.submit(req(3, 4, 0.0)).is_err());
        let _ = s.run_to_completion().unwrap();
        assert!(s.submit(req(3, 4, 0.0)).is_ok());
    }

    #[test]
    fn metrics_accumulate() {
        let m = Metrics::new();
        let w = Arc::new(Weights::random(ModelSpec::new(vec![784, 16, 10]), 3));
        let mut cfg = SchedulerConfig::default();
        cfg.batch_size = 8;
        let mut s = Scheduler::new(NativeEngine::new(w, 1), cfg, m.clone());
        for i in 0..3 {
            s.submit(req(i, 8, 0.0)).unwrap();
        }
        let _ = s.run_to_completion().unwrap();
        let snap = m.snapshot();
        assert_eq!(snap.requests_completed, 3);
        assert_eq!(snap.trials_executed, 24);
        assert!(snap.batches_executed >= 3);
        assert!(snap.fill_ratio(8) > 0.9);
    }

    #[test]
    fn seeds_differ_across_batches() {
        // Two identical requests must not receive identical vote patterns
        // (would indicate seed reuse across batches).
        let mut s = sched(0.0);
        s.submit(req(1, 64, 0.0)).unwrap();
        s.submit(req(2, 64, 0.0)).unwrap();
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 2);
        // Not a hard guarantee, but with 64 stochastic trials each the
        // full count vectors colliding means something is broken.
        assert_ne!(done[0].outcome.counts, done[1].outcome.counts);
    }
}
