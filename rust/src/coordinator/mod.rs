//! L3 coordinator (DESIGN.md §4.9) — the batched scheduling machinery
//! behind the serving layer.
//!
//! **Entry point note:** applications should serve through the
//! [`crate::serve::Backend`] trait (`serve::SingleChipBackend` wraps this
//! module's [`Server`]); the pieces here are the building blocks, not the
//! public serving API.
//!
//! Stochastic inference needs *many* trials per request; the coordinator's
//! job is to keep the trial executable's batch full while spending as few
//! trials as possible per request:
//!
//! * [`batcher`] packs (request, trial) pairs from all in-flight requests
//!   into fixed-size rows for the batched trial executable;
//! * [`scheduler`] runs the pack→execute→count loop and applies the
//!   confidence-based early stopper (Wilson interval on the top-two vote
//!   counts) so easy inputs finish in a handful of trials while ambiguous
//!   ones keep voting up to the cap;
//! * [`server`] owns the scheduler thread and exposes a `Clone + Send`
//!   client handle with submit/await semantics;
//! * [`metrics`] counts everything (trials, batches, fill ratio,
//!   early-stop savings, latency percentiles).
//!
//! The request/response vocabulary ([`InferRequest`], [`InferResponse`])
//! lives in [`crate::serve`] and is re-exported here for compatibility.

pub mod batcher;
pub mod metrics;
pub mod scheduler;
pub mod server;

pub use batcher::{Batcher, PackedBatch};
pub use metrics::{Metrics, MetricsSnapshot};
pub use scheduler::{Scheduler, SchedulerConfig, TrialRunner};
pub use server::{Server, ServerClient};

pub use crate::serve::{InferRequest, InferResponse, RequestId};
