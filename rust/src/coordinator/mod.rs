//! L3 coordinator (DESIGN.md §4.9) — the serving layer around the RACA
//! trial engines.
//!
//! Stochastic inference needs *many* trials per request; the coordinator's
//! job is to keep the trial executable's batch full while spending as few
//! trials as possible per request:
//!
//! * [`batcher`] packs (request, trial) pairs from all in-flight requests
//!   into fixed-size rows for the batched trial executable;
//! * [`scheduler`] runs the pack→execute→count loop and applies the
//!   confidence-based early stopper (Wilson interval on the top-two vote
//!   counts) so easy inputs finish in a handful of trials while ambiguous
//!   ones keep voting up to the cap;
//! * [`server`] owns the scheduler thread and exposes a `Clone + Send`
//!   client handle with submit/await semantics;
//! * [`metrics`] counts everything (trials, batches, fill ratio,
//!   early-stop savings, latency percentiles).

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;

pub use batcher::{Batcher, PackedBatch};
pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{InferRequest, InferResponse, RequestId};
pub use scheduler::{Scheduler, SchedulerConfig, TrialRunner};
pub use server::{Server, ServerClient};
