//! `raca` — command-line entrypoint.
//!
//! Subcommands regenerate every paper artifact (DESIGN.md §5) and expose
//! the serving stack:
//!
//! ```text
//! raca info                         # artifact + platform summary
//! raca fig4  --panel all|ab|c|d|e|f [--samples N]
//! raca fig5  --panel all|a|bc|d     [--trials N]
//! raca fig6  --panel all|a|b [--images N] [--engine native|xla] [--fast]
//! raca table1                       # + breakdowns
//! raca ablate --noise|--variation|--tiles|--low-vr [--images N]
//! raca infer --images N [--trials K] [--confidence C]   # coordinator path
//! raca selftest                     # quick end-to-end smoke
//! ```

use anyhow::Result;

use raca::cli::Args;
use raca::coordinator::{SchedulerConfig, Server};
use raca::dataset::Dataset;
use raca::engine::{TrialParams, XlaEngine};
use raca::figures;
use raca::runtime::ArtifactStore;

fn main() -> Result<()> {
    raca::util::logging::init();
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("info") => info(),
        Some("fig4") => {
            let samples = args.get_usize("samples", if args.has("fast") { 800 } else { 4000 });
            figures::fig4::run(args.get_or("panel", "all"), samples)
        }
        Some("fig5") => {
            let trials = args.get_usize("trials", if args.has("fast") { 2000 } else { 10000 });
            figures::fig5::run(args.get_or("panel", "all"), trials)
        }
        Some("fig6") => {
            let images = args.get_usize("images", if args.has("fast") { 200 } else { 1000 });
            let use_xla = args.get_or("engine", "native") == "xla";
            figures::fig6::run(args.get_or("panel", "all"), images, use_xla)
        }
        Some("table1") => {
            figures::table1::run()?;
            figures::table1::intro_converter_share()?;
            figures::table1::ablate_low_vr()
        }
        Some("plan") => plan(&args),
        Some("arch") => arch_report(&args),
        Some("ablate") => {
            let images = args.get_usize("images", 100);
            let trials = args.get_usize("trials", 9);
            let mut ran = false;
            if args.has("noise") {
                figures::ablate::noise_composition(images, trials)?;
                ran = true;
            }
            if args.has("variation") {
                figures::ablate::variation_sweep(images, trials)?;
                ran = true;
            }
            if args.has("tiles") {
                figures::table1::ablate_tiles()?;
                ran = true;
            }
            if args.has("low-vr") {
                figures::table1::ablate_low_vr()?;
                ran = true;
            }
            if !ran {
                figures::ablate::noise_composition(images, trials)?;
                figures::ablate::variation_sweep(images, trials)?;
                figures::table1::ablate_tiles()?;
                figures::table1::ablate_low_vr()?;
            }
            Ok(())
        }
        Some("infer") => infer(&args),
        Some("selftest") => selftest(),
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = r#"raca — ReRAM Analog Computing Accelerator (paper reproduction)

USAGE: raca <subcommand> [flags]

  info        artifact + platform summary
  fig4        sigmoid-neuron panels   --panel all|ab|c|d|e|f  --samples N
  fig5        WTA softmax panels      --panel all|a|bc|d      --trials N
  fig6        accuracy vs trials      --panel all|a|b --images N --engine native|xla
  table1      hardware metrics table + low-Vr ablation
  ablate      robustness ablations    --noise --variation --tiles --low-vr
  infer       serve N test images through the coordinator (XLA engine)
              --images N --trials K --confidence C --batch B
  selftest    quick end-to-end smoke test

Add --fast to fig4/fig5/fig6 for CI-sized runs.
"#;

fn info() -> Result<()> {
    println!("raca {}", raca::version::VERSION);
    let dir = ArtifactStore::default_dir();
    println!("artifacts: {}", dir.display());
    match ArtifactStore::open(&dir) {
        Ok(store) => {
            let m = &store.manifest;
            println!("  layers        : {:?}", m.layers);
            println!("  trial batches : {:?}", m.trial_batches);
            println!("  ideal batches : {:?}", m.ideal_batches);
            println!("  sigma_z       : {:.4}", m.sigma_z);
            println!("  theta (0.05V) : {:.2}", m.theta_norm);
            println!("  ideal accuracy: {:.2}%", m.ideal_test_accuracy * 100.0);
            println!("  Δf            : {:.2e} Hz", m.delta_f);
            println!("  Vr per layer  : {:?}", m.vr_per_layer);
            println!(
                "  PJRT          : {} ({} devices)",
                store.client().platform_name(),
                store.client().device_count()
            );
        }
        Err(e) => println!("  (unavailable: {e:#})"),
    }
    Ok(())
}

fn infer(args: &Args) -> Result<()> {
    let n = args.get_usize("images", 64);
    let trials = args.get_usize("trials", 32) as u32;
    let confidence = args.get_f64("confidence", 0.95);
    let batch = args.get_usize("batch", 32);

    let dir = ArtifactStore::default_dir();
    let ds = Dataset::load(&dir.join("data").join("test"))?.take(n);
    let engine = XlaEngine::start(dir)?;
    let handle = engine.handle();
    handle.warmup(batch)?;

    let mut cfg = SchedulerConfig::default();
    cfg.batch_size = batch;
    cfg.params = TrialParams::default();
    let server = Server::start(handle, cfg);
    let client = server.client();

    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..ds.len())
        .map(|i| client.submit(ds.image(i).to_vec(), trials, confidence).unwrap())
        .collect();
    let mut hits = 0usize;
    let mut trials_used = 0u64;
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv()?;
        if r.prediction == ds.label(i) {
            hits += 1;
        }
        trials_used += r.trials_used as u64;
    }
    let dt = t0.elapsed();
    let m = server.metrics().snapshot();
    println!(
        "classified {} images in {:.2}s — accuracy {:.2}%, {:.1} trials/request (cap {trials}), {:.0} trials/s",
        ds.len(),
        dt.as_secs_f64(),
        hits as f64 / ds.len() as f64 * 100.0,
        trials_used as f64 / ds.len() as f64,
        m.trials_executed as f64 / dt.as_secs_f64()
    );
    println!("coordinator: {m}");
    println!("batch fill ratio: {:.1}%", m.fill_ratio(batch) * 100.0);
    Ok(())
}

/// Chip floorplan + pipeline report (arch module).
fn arch_report(args: &Args) -> Result<()> {
    use raca::arch::{Floorplan, PipelineModel};
    use raca::hwmodel::{Architecture, TechParams};
    use raca::nn::ModelSpec;

    let tile = args.get_usize("tile", 128);
    let mut tech = TechParams::default();
    tech.tile = tile;
    let fp = Floorplan::place(ModelSpec::paper(), tile, 8);
    fp.validate().map_err(|e| anyhow::anyhow!(e))?;
    println!("floorplan: {} tiles of {tile}x{tile} on an 8-wide grid", fp.num_tiles());
    for l in 0..fp.spec.num_layers() {
        let tiles = fp.layer_tiles(l);
        println!(
            "  layer {l}: {:>3} tiles, shape {:?}, hop→next {:.2} pitches",
            tiles.len(),
            fp.spec.layer_shape(l),
            if l + 1 < fp.spec.num_layers() { fp.layer_hop_distance(l) } else { 0.0 }
        );
    }
    println!("  device utilization: {:.1}%", fp.device_utilization() * 100.0);

    for (name, arch) in [("RACA", Architecture::Raca), ("1-bit ADC", Architecture::OneBitAdc)] {
        let mut pm = PipelineModel::new(ModelSpec::paper(), tech.clone(), arch);
        pm.set_wta_expectation_from_theta(3.0, 10);
        let r = pm.report();
        println!(
            "pipeline [{name}]: stages {:?} ns, latency {:.1} ns, II {:.1} ns → {:.1}M trials/s, bottleneck stage {}",
            r.stage_ns.iter().map(|s| (s * 10.0).round() / 10.0).collect::<Vec<_>>(),
            r.latency_ns,
            r.ii_ns,
            r.trials_per_sec / 1e6,
            r.bottleneck
        );
    }
    Ok(())
}

/// Trial-budget planning from measured per-image win statistics.
fn plan(args: &Args) -> Result<()> {
    use raca::engine::NativeEngine;
    use raca::nn::Weights;
    use raca::planner::vote_model_from_probs;

    let n = args.get_usize("images", 100);
    let target = args.get_f64("target", 0.97);
    let probe_trials = args.get_usize("probe-trials", 64);
    let dir = ArtifactStore::default_dir();
    let ds = Dataset::load(&dir.join("data").join("test"))?.take(n);
    let w = std::sync::Arc::new(Weights::load(&dir.join("weights").join("fcnn"))?);
    let engine = NativeEngine::new(w, 77);
    let p = TrialParams::default();

    let mut budgets = Vec::new();
    let mut unplannable = 0usize;
    for i in 0..ds.len() {
        let o = engine.infer(ds.image(i), p, probe_trials, (i * 97) as u64);
        let freqs = o.frequencies();
        let m = vote_model_from_probs(&freqs);
        match m.trials_for_accuracy(target) {
            Some(k) => budgets.push(k),
            None => unplannable += 1,
        }
    }
    budgets.sort_unstable();
    let pct = |p: f64| budgets[((budgets.len() - 1) as f64 * p) as usize];
    println!(
        "plan: target per-image vote accuracy {target} over {n} probed images ({probe_trials} probe trials each)"
    );
    println!(
        "  trials needed: p50={} p90={} p99={} max={}  (unplannable: {unplannable} tied images)",
        pct(0.5),
        pct(0.9),
        pct(0.99),
        budgets.last().copied().unwrap_or(0)
    );
    println!(
        "  → a fixed budget of {} trials covers 99% of inputs; the early-stopper\n    spends ~p50 on typical inputs (see `raca infer --confidence`).",
        pct(0.99)
    );
    Ok(())
}

fn selftest() -> Result<()> {
    println!("[1/3] PJRT smoke (artifacts/smoke.hlo.txt)…");
    let dir = ArtifactStore::default_dir();
    let client = raca::runtime::RtClient::new()?;
    let exe = client.compile_hlo_text(&dir.join("smoke.hlo.txt"))?;
    let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]).reshape(&[2, 2])?;
    let y = xla::Literal::vec1(&[1f32, 1., 1., 1.]).reshape(&[2, 2])?;
    let out = exe.execute::<xla::Literal>(&[x, y])?[0][0]
        .to_literal_sync()?
        .to_tuple1()?
        .to_vec::<f32>()?;
    anyhow::ensure!(out == vec![5., 5., 9., 9.], "smoke HLO wrong: {out:?}");
    println!("      ok: {out:?}");

    println!("[2/3] trial executable (batch 1)…");
    let engine = XlaEngine::start(dir.clone())?;
    let h = engine.handle();
    let ds = Dataset::load(&dir.join("data").join("test"))?.take(8);
    let w = h.run_trials(ds.image(0).to_vec(), 1, 7, TrialParams::default())?;
    anyhow::ensure!((-1..10).contains(&w[0]), "bad winner {w:?}");
    println!("      ok: winner={}", w[0]);

    println!("[3/3] coordinator vote on 8 images…");
    let mut cfg = SchedulerConfig::default();
    cfg.batch_size = 32;
    let server = Server::start(h, cfg);
    let client = server.client();
    let mut hits = 0;
    for i in 0..8 {
        let r = client.classify(ds.image(i).to_vec(), 15, 0.9)?;
        if r.prediction == ds.label(i) {
            hits += 1;
        }
    }
    println!("      ok: {hits}/8 correct");
    println!("selftest PASSED");
    Ok(())
}
