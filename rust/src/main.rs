//! `raca` — command-line entrypoint.
//!
//! Subcommands regenerate every paper artifact (DESIGN.md §5) and expose
//! the serving stack:
//!
//! ```text
//! raca info                         # artifact + platform summary
//! raca fig4  --panel all|ab|c|d|e|f [--samples N]
//! raca fig5  --panel all|a|bc|d     [--trials N]
//! raca fig6  --panel all|a|b [--images N] [--engine native|xla] [--fast]
//! raca table1                       # + breakdowns
//! raca ablate --noise|--variation|--tiles|--low-vr [--images N]
//! raca infer --images N [--trials K] [--confidence C]   # single-chip path
//! raca serve --topology "2x(pipeline:3)"                # deployment tree
//!            [--backend single|replicated|pipelined]    # legacy sugar
//!            [--chips N] [--shards S] [--widths 784,...,10]
//!            [--listen 0.0.0.0:7433]   # host the topology on a socket
//!            [--probe-rate 0.05]       # labeled health probes
//! raca serve --topology "(remote:a:7433, remote:b:7433)"  # multi-host tree
//! raca train [--widths 784,500,300,10] # regenerate weight artifacts
//!                                   # natively (no python toolchain)
//! raca publish artifacts/weights/fcnn calib.json  # sign + store a bundle
//! raca bundles [host:port]          # list local/advertised bundles
//! raca serve --topology "remote:@h:7433/<bundle>" # registry-resolved leaf
//! raca fleet --chips N --sigma S    # multi-chip farm: program,
//!                                   # calibrate, serve, health report
//! raca selftest                     # quick end-to-end smoke
//! ```
//!
//! All serving goes through [`raca::serve::Backend`], built from a
//! [`raca::serve::Topology`] by [`raca::serve::plan`]; the AOT/PJRT paths
//! (`--engine xla`, `infer`/`selftest` over artifacts) need the `pjrt`
//! cargo feature; default builds use the native engine.

use anyhow::Result;

use raca::cli::Args;
use raca::coordinator::SchedulerConfig;
use raca::dataset::{synth, Dataset};
use raca::device::VariationModel;
use raca::engine::{NativeEngine, TrialParams};
use raca::figures;
use raca::fleet::{Calibrator, Fleet, FleetConfig, RoutePolicy};
use raca::nn::{ModelSpec, TrainConfig, Weights};
use raca::runtime::default_artifact_dir;
use raca::serve::{Backend, BackendKind, BuildOptions, DeployPlan, InferRequest, Topology};

#[cfg(feature = "pjrt")]
use raca::engine::XlaEngine;
#[cfg(feature = "pjrt")]
use raca::runtime::ArtifactStore;

fn main() -> Result<()> {
    raca::util::logging::init();
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("info") => info(),
        Some("fig4") => {
            let samples = args.get_usize("samples", if args.has("fast") { 800 } else { 4000 });
            figures::fig4::run(args.get_or("panel", "all"), samples)
        }
        Some("fig5") => {
            let trials = args.get_usize("trials", if args.has("fast") { 2000 } else { 10000 });
            figures::fig5::run(args.get_or("panel", "all"), trials)
        }
        Some("fig6") => {
            let images = args.get_usize("images", if args.has("fast") { 200 } else { 1000 });
            let use_xla = args.get_or("engine", "native") == "xla";
            figures::fig6::run(args.get_or("panel", "all"), images, use_xla)
        }
        Some("table1") => {
            figures::table1::run()?;
            figures::table1::intro_converter_share()?;
            figures::table1::ablate_low_vr()
        }
        Some("plan") => plan(&args),
        Some("arch") => arch_report(&args),
        Some("ablate") => {
            let images = args.get_usize("images", 100);
            let trials = args.get_usize("trials", 9);
            let mut ran = false;
            if args.has("noise") {
                figures::ablate::noise_composition(images, trials)?;
                ran = true;
            }
            if args.has("variation") {
                figures::ablate::variation_sweep(images, trials)?;
                ran = true;
            }
            if args.has("tiles") {
                figures::table1::ablate_tiles()?;
                ran = true;
            }
            if args.has("low-vr") {
                figures::table1::ablate_low_vr()?;
                ran = true;
            }
            if !ran {
                figures::ablate::noise_composition(images, trials)?;
                figures::ablate::variation_sweep(images, trials)?;
                figures::table1::ablate_tiles()?;
                figures::table1::ablate_low_vr()?;
            }
            Ok(())
        }
        Some("infer") => infer(&args),
        Some("serve") => serve(&args),
        Some("top") => top(&args),
        Some("train") => train_cmd(&args),
        Some("publish") => publish_cmd(&args),
        Some("bundles") => bundles_cmd(&args),
        Some("fleet") => fleet(&args),
        Some("selftest") => selftest(),
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = r#"raca — ReRAM Analog Computing Accelerator (paper reproduction)

USAGE: raca <subcommand> [flags]

  info        artifact + platform summary
  fig4        sigmoid-neuron panels   --panel all|ab|c|d|e|f  --samples N
  fig5        WTA softmax panels      --panel all|a|bc|d      --trials N
  fig6        accuracy vs trials      --panel all|a|b --images N --engine native|xla
  table1      hardware metrics table + low-Vr ablation
  ablate      robustness ablations    --noise --variation --tiles --low-vr
  infer       serve N test images through the single-chip backend
              --images N --trials K --confidence C --batch B
  serve       serve through a deployment topology (compiled to backends)
              --topology "2x(pipeline:3)"   die | pipeline:<dies>[:b<batch>]
                                            | remote:<host:port>
                                            | remote:@<host:port>/<bundle>
                                            | <n>x(<node>)[@policy]
                                            | (<node>, <node>, …)[@policy]
              --backend single|replicated|pipelined   (legacy sugar:
                die | <chips>x(die) | pipeline:<shards>)
              --listen <host:port>      host the compiled topology on a
                                        socket (peers reach it as
                                        remote:<host:port>); advertises the
                                        local registry's bundles; blocks
              --artifact-dir DIR        weights/registry location (else
                                        RACA_ARTIFACT_DIR, the config
                                        "artifacts" key, or the default)
              --http <host:port>        host the HTTP/JSON ingress:
                                        POST /v1/infer, GET /metrics,
                                        GET /tree, GET /healthz — with
                                        admission control (429+Retry-After),
                                        X-Raca-Tenant rate limits, and
                                        continuous batching; blocks
                                        (composable with --listen: both
                                        front doors share the backend)
              --probe-rate R            labeled health probes per request
                                        (0..1, from the calibration slice)
              --chips N --shards S --batch B (die-to-die trial block)
              --trial-block B           trials per blocked-kernel pass on
                                        native dies (default 64, ≥ 1)
              --images N --trials K --confidence C --sigma S --seed S
              --widths 784,256,128,10   (train a custom-depth model)
              --config run.json         ({"serve": {"topology": ..., ...}})
  top         render a serving tree's per-node telemetry + recent events
              raca top <host:port>        sample a live listener twice and
                                          show per-node p50/p99, trials/s,
                                          health notes, journal tail
              raca top "<topology>"       build locally, drive a small
                                          labeled workload, then render
              --interval S   seconds between remote samples (default 1)
              --events N     journal events to show (default 12)
              --images N --trials K --probe-rate R   local workload shape
  train       train + save weight/dataset artifacts natively (replaces the
              python toolchain for paper-scale weights)
              --widths 784,500,300,10 --samples N --epochs E --lr F
              --minibatch M --seed S --test-samples N --out DIR --force
  publish     sign + store a model bundle in the artifact registry
              raca publish <weights-prefix> <calibration.json>
              --dataset PATH      hash an evaluation set into the manifest
              --to <host:port>    also push the bundle to a live listener
              --artifact-dir DIR  registry location (see serve)
  bundles     list bundles, id first per line (script-friendly)
              raca bundles                 the local registry store
              raca bundles <host:port>     a live listener's advertisement
  fleet       program + calibrate + serve a farm of non-identical chips
              (replicated backend: worker threads + live health steering)
              --chips N --sigma S --policy round-robin|least-loaded|weighted
              --images N --trials K --cal-images N --cal-trials K
              --seed S --config run.json
  selftest    quick end-to-end smoke test

Add --fast to fig4/fig5/fig6 for CI-sized runs.
XLA/PJRT paths require building with `--features pjrt`.
"#;

/// Parse a `--widths 784,...,10` layer spec and enforce the dataset
/// contract (28×28 inputs, 10 classes) — shared by `serve` and `train`.
fn parse_widths(spec_str: &str) -> Result<Vec<usize>> {
    let widths = spec_str
        .split(',')
        .map(|s| s.trim().parse::<usize>())
        .collect::<std::result::Result<Vec<_>, _>>()
        .map_err(|e| anyhow::anyhow!("bad --widths '{spec_str}': {e}"))?;
    anyhow::ensure!(
        widths.first() == Some(&784) && widths.last() == Some(&10),
        "--widths must start at 784 and end at 10 (dataset contract)"
    );
    Ok(widths)
}

/// Resolve the artifact directory for one invocation: the
/// `--artifact-dir` flag, then `RACA_ARTIFACT_DIR`, then a config file's
/// `"artifacts"` key, then the crate default — shared by every
/// artifact-touching subcommand.
fn artifact_dir(args: &Args, config: Option<&std::path::Path>) -> std::path::PathBuf {
    raca::runtime::resolve_artifact_dir(args.get("artifact-dir").map(std::path::Path::new), config)
}

/// Load the trained artifacts from `dir` if present; otherwise train a
/// small native MLP on synthetic digits so every path works on a fresh
/// checkout.  Returns (weights, labeled evaluation set).
fn load_or_train(dir: &std::path::Path) -> Result<(Weights, Dataset)> {
    let loaded = Weights::load(&dir.join("weights").join("fcnn")).and_then(|w| {
        let ds = Dataset::load(&dir.join("data").join("test"))?;
        Ok((w, ds))
    });
    match loaded {
        Ok((w, ds)) => {
            println!(
                "model: trained artifacts from {} (ideal accuracy {:.1}%)",
                dir.display(),
                w.ideal_test_accuracy * 100.0
            );
            Ok((w, ds))
        }
        Err(e) => {
            println!("model: artifacts unavailable ({e:#})");
            // Three layers so the fallback shards up to `pipeline:3` (and
            // `2x(pipeline:3)`) out of the box; minibatched gradients keep
            // the deeper net's training off the serving critical path.
            println!("model: training a native 784-48-24-10 MLP on synthetic digits instead…");
            let train_set = synth::generate(800, 0x7EA1);
            let cfg = TrainConfig { epochs: 8, lr: 0.2, seed: 0x5EED, minibatch: 8 };
            let w = raca::nn::train(&train_set, ModelSpec::new(vec![784, 48, 24, 10]), &cfg);
            println!("model: trained, ideal train accuracy {:.1}%", w.ideal_test_accuracy * 100.0);
            Ok((w, synth::generate(512, 0x7E57)))
        }
    }
}

#[cfg(feature = "pjrt")]
fn info() -> Result<()> {
    println!("raca {}", raca::version::VERSION);
    let dir = default_artifact_dir();
    println!("artifacts: {}", dir.display());
    match ArtifactStore::open(&dir) {
        Ok(store) => {
            let m = &store.manifest;
            println!("  layers        : {:?}", m.layers);
            println!("  trial batches : {:?}", m.trial_batches);
            println!("  ideal batches : {:?}", m.ideal_batches);
            println!("  sigma_z       : {:.4}", m.sigma_z);
            println!("  theta (0.05V) : {:.2}", m.theta_norm);
            println!("  ideal accuracy: {:.2}%", m.ideal_test_accuracy * 100.0);
            println!("  Δf            : {:.2e} Hz", m.delta_f);
            println!("  Vr per layer  : {:?}", m.vr_per_layer);
            println!(
                "  PJRT          : {} ({} devices)",
                store.client().platform_name(),
                store.client().device_count()
            );
        }
        Err(e) => println!("  (unavailable: {e:#})"),
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn info() -> Result<()> {
    println!("raca {}", raca::version::VERSION);
    let dir = default_artifact_dir();
    println!("artifacts: {}", dir.display());
    match Weights::load(&dir.join("weights").join("fcnn")) {
        Ok(w) => println!(
            "  layers        : {:?} (ideal accuracy {:.2}%)",
            w.spec.widths,
            w.ideal_test_accuracy * 100.0
        ),
        Err(e) => println!("  weights       : unavailable ({e:#})"),
    }
    println!("  PJRT          : disabled (rebuild with --features pjrt)");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn infer(args: &Args) -> Result<()> {
    let n = args.get_usize("images", 64);
    let trials = args.get_usize("trials", 32) as u32;
    let confidence = args.get_f64("confidence", 0.95);
    let batch = args.get_usize("batch", 32);

    let dir = artifact_dir(args, None);
    let ds = Dataset::load(&dir.join("data").join("test"))?.take(n);
    let engine = XlaEngine::start(dir)?;
    let handle = engine.handle();
    handle.warmup(batch)?;

    let mut cfg = SchedulerConfig::default();
    cfg.batch_size = batch;
    cfg.params = TrialParams::default();
    let backend = raca::serve::plan::single_die(handle, cfg);
    serve_and_report(&backend, &ds, trials, confidence, Some(batch))
}

#[cfg(not(feature = "pjrt"))]
fn infer(args: &Args) -> Result<()> {
    let n = args.get_usize("images", 64);
    let trials = args.get_usize("trials", 32) as u32;
    let confidence = args.get_f64("confidence", 0.95);
    let batch = args.get_usize("batch", 32);

    let (w, ds) = load_or_train(&artifact_dir(args, None))?;
    let ds = ds.take(n);
    let engine = NativeEngine::new(std::sync::Arc::new(w), 0x1FE2);
    let mut cfg = SchedulerConfig::default();
    cfg.batch_size = batch;
    cfg.params = TrialParams::default();
    let backend = raca::serve::plan::single_die(engine, cfg);
    serve_and_report(&backend, &ds, trials, confidence, Some(batch))
}

/// Shared serving tail: push a labeled set through any [`Backend`], report
/// accuracy / trial spend / throughput (+ fill ratio for batched backends).
fn serve_and_report(
    backend: &dyn Backend,
    ds: &Dataset,
    trials: u32,
    confidence: f64,
    batch: Option<usize>,
) -> Result<()> {
    let t0 = std::time::Instant::now();
    let tickets = (0..ds.len())
        .map(|i| {
            backend.submit(
                InferRequest::new(i as u64, ds.image(i).to_vec())
                    .with_budget(trials, confidence)
                    .with_label(ds.label(i)),
            )
        })
        .collect::<Result<Vec<_>>>()?;
    let mut hits = 0usize;
    let mut trials_used = 0u64;
    let mut abstentions = 0u64;
    for (i, t) in tickets.into_iter().enumerate() {
        let r = backend.wait(t)?;
        if r.prediction == ds.label(i) {
            hits += 1;
        }
        if r.prediction < 0 {
            abstentions += 1;
        }
        trials_used += r.trials_used as u64;
    }
    let dt = t0.elapsed();
    let m = backend.metrics();
    println!(
        "classified {} images in {:.2}s — accuracy {:.2}%, {:.1} trials/request (cap {trials}), {:.0} trials/s, {abstentions} abstentions",
        ds.len(),
        dt.as_secs_f64(),
        hits as f64 / ds.len().max(1) as f64 * 100.0,
        trials_used as f64 / ds.len().max(1) as f64,
        m.trials_executed as f64 / dt.as_secs_f64().max(1e-9),
    );
    println!("backend: {m}");
    if let Some(b) = batch {
        println!("batch fill ratio: {:.1}%", m.fill_ratio(b) * 100.0);
    }
    Ok(())
}

/// `raca serve` — one workload, any deployment tree: compile the selected
/// [`Topology`] into a [`Backend`] and push the evaluation set through it.
fn serve(args: &Args) -> Result<()> {
    use anyhow::Context as _;

    let cfg = match args.get("config") {
        Some(path) => raca::config::RunConfig::load(std::path::Path::new(path))?,
        None => raca::config::RunConfig::parse("{}").expect("empty config"),
    };
    let mut sc = cfg.serve.clone();
    match (args.get("topology"), args.get("backend")) {
        (Some(_), Some(_)) => {
            anyhow::bail!("pass either --topology or --backend, not both")
        }
        (Some(spec), None) => sc.topology = Some(Topology::parse(spec)?),
        (None, Some(b)) => {
            sc.backend = BackendKind::parse(b).with_context(|| {
                format!(
                    "unknown backend '{b}' (valid: {}; case-insensitive — or use --topology)",
                    BackendKind::SPELLINGS
                )
            })?;
            // An explicit CLI shape beats a config-file tree.
            sc.topology = None;
        }
        (None, None) => {}
    }
    sc.chips = args.get_usize("chips", sc.chips);
    sc.shards = args.get_usize("shards", sc.shards);
    sc.batch = args.get_usize("batch", sc.batch);
    sc.trial_block = args.get_usize("trial-block", sc.trial_block);
    sc.probe_rate = args.get_f64("probe-rate", sc.probe_rate);
    if let Some(l) = args.get("listen") {
        sc.listen = Some(l.to_string());
    }
    if let Some(h) = args.get("http") {
        // Keep queue/budget/rate knobs from a config file's serve.http
        // block when present; the flag only picks the bind address.
        let mut hc = sc.http.take().unwrap_or_else(|| raca::serve::HttpConfig::new(h));
        hc.addr = h.to_string();
        sc.http = Some(hc);
    }
    if let Some(h) = &sc.http {
        anyhow::ensure!(h.addr.contains(':'), "--http must be a <host:port> bind address");
    }
    sc.seed = args.get_usize("seed", sc.seed as usize) as u64;
    anyhow::ensure!(sc.chips > 0, "--chips must be at least 1");
    anyhow::ensure!(sc.shards > 0, "--shards must be at least 1");
    anyhow::ensure!(sc.batch > 0, "--batch must be at least 1");
    anyhow::ensure!(
        sc.trial_block > 0,
        "--trial-block must be at least 1 (trials per blocked-kernel pass)"
    );
    anyhow::ensure!(
        (0.0..=1.0).contains(&sc.probe_rate),
        "--probe-rate must be in [0, 1] (probes per caller request)"
    );
    let n = args.get_usize("images", 256);
    let trials = args.get_usize("trials", 16) as u32;
    let confidence = args.get_f64("confidence", 0.0);
    let sigma = args.get_f64("sigma", 0.0);
    let art = artifact_dir(args, cfg.artifacts.as_deref());

    let topo = sc.tree(cfg.fleet.policy);

    // Model: `--widths 784,256,128,10` trains a custom-depth native model
    // (deep pipelines need ≥ as many layers as shards); default is the
    // artifact (or fallback-trained) network.
    let (w, pool) = match args.get("widths") {
        Some(spec_str) => {
            let widths = parse_widths(spec_str)?;
            println!("model: training a native {widths:?} MLP on synthetic digits…");
            let train_set = synth::generate(800, 0x7EA1);
            // Parallel minibatch gradients: custom-depth training was the
            // wall-time sink of `raca serve --widths` setup.
            let tc = TrainConfig { epochs: 6, lr: 0.2, seed: 0x5EED, minibatch: 8 };
            let w = raca::nn::train(&train_set, ModelSpec::new(widths), &tc);
            (w, synth::generate(n + 64, 0x7E57))
        }
        None => load_or_train(&art)?,
    };
    anyhow::ensure!(!pool.is_empty(), "no evaluation data available");
    // Carve the calibration split FIRST (the fleet subcommand's order), so
    // calibration never tunes on the images it is then scored against.
    let cal = pool.take(48.min(pool.len()));
    let ds = {
        let d = pool.slice(cal.len(), cal.len() + n);
        if d.is_empty() {
            // Degenerate pools (< 49 images) fall back to serving the cal
            // set itself — small-sample demos, not evaluation runs.
            cal.clone()
        } else {
            d
        }
    };

    let plan = DeployPlan::compile(&topo)?;
    println!("serve: topology {topo} ({} dies @ σ={sigma:.2})", plan.total_dies);
    print!("{}", plan.describe(&w.spec));
    let opts = BuildOptions {
        seed: sc.seed,
        trial: cfg.trial,
        scheduler: cfg.scheduler.clone(),
        variation: (sigma > 0.0).then(|| VariationModel::lognormal(sigma)),
        depth: sc.depth,
        batch: sc.batch,
        trial_block: sc.trial_block,
        calibration: Some((cal.clone(), Calibrator::quick(5))),
        probe_rate: sc.probe_rate,
        artifact_dir: Some(art.clone()),
        ..Default::default()
    };
    let backend = raca::serve::plan::build(&topo, &w, &opts)?;

    // Listener modes: host the compiled topology on a socket (framed
    // wire and/or HTTP ingress) instead of pushing a local workload.
    // Wire listeners always carry the local registry, advertising its
    // bundles in the hello and answering publish/fetch traffic.
    let registry = || -> Result<(raca::serve::net::RegistryConfig, usize)> {
        let store = raca::registry::Store::open(&art);
        let advertised = store.list().unwrap_or_default().len();
        let key = raca::registry::SigningKey::load_or_generate(&art)
            .with_context(|| format!("deployment key under {}", art.display()))?;
        Ok((raca::serve::net::RegistryConfig { store, key }, advertised))
    };
    match (&sc.listen, &sc.http) {
        (Some(listen), Some(hc)) => {
            // Both front doors share one backend (one metrics/journal
            // stream) via the SharedBackend adapter.
            let (reg, advertised) = registry()?;
            let shared: std::sync::Arc<dyn raca::serve::Backend> = std::sync::Arc::from(backend);
            let net = raca::serve::net::serve_registry(
                Box::new(raca::serve::SharedBackend(shared.clone())),
                listen,
                reg,
            )?;
            let http =
                raca::serve::serve_http(Box::new(raca::serve::SharedBackend(shared)), hc)?;
            println!(
                "serve: wire listener on {} (protocol v{}, {advertised} bundles advertised, \
                 reach as \"remote:{}\"), HTTP ingress on http://{} — ctrl-c to stop",
                net.addr(),
                raca::serve::net::PROTOCOL_VERSION,
                net.addr(),
                http.addr()
            );
            net.join();
            http.join();
            return Ok(());
        }
        (Some(listen), None) => {
            let (reg, advertised) = registry()?;
            let server = raca::serve::net::serve_registry(backend, listen, reg)?;
            println!(
                "serve: listening on {} (wire protocol v{}, {advertised} bundles advertised) — \
                 reach this topology as \"remote:{}\"; ctrl-c to stop",
                server.addr(),
                raca::serve::net::PROTOCOL_VERSION,
                server.addr()
            );
            server.join();
            return Ok(());
        }
        (None, Some(hc)) => {
            let server = raca::serve::serve_http(backend, hc)?;
            println!(
                "serve: HTTP ingress on http://{} (POST /v1/infer, GET /metrics, \
                 GET /tree, GET /healthz) — ctrl-c to stop",
                server.addr()
            );
            server.join();
            return Ok(());
        }
        (None, None) => {}
    }

    serve_and_report(backend.as_ref(), &ds, trials, confidence, None)?;
    backend.shutdown();
    Ok(())
}

/// `raca top` — observability console for a serving tree.
///
/// `raca top <host:port>` samples a live `raca serve --listen` peer twice
/// over `--interval` seconds and renders its [`raca::telemetry::MetricsTree`]
/// (per-node p50/p99, queue-wait vs. service split, probe accuracy,
/// eviction state) plus the tail of its event journal; `raca top
/// "<topology>"` builds the tree locally, drives a small labeled workload
/// through it, and renders the same report.
fn top(args: &Args) -> Result<()> {
    let Some(target) = args.positional(0) else {
        anyhow::bail!(
            "usage: raca top <host:port | topology>\n  e.g. `raca top 127.0.0.1:7433` \
             or `raca top \"2x(pipeline:2)\"`"
        );
    };
    // A target that parses as a topology is built locally (this covers
    // `remote:<addr>` too — a client-side view of the peer); anything
    // else is treated as a listener address.
    match Topology::parse(target) {
        Ok(topo) => top_local(args, &topo),
        Err(_) => top_remote(args, target),
    }
}

fn top_remote(args: &Args, addr: &str) -> Result<()> {
    use raca::serve::net::RemoteBackend;

    let interval = args.get_f64("interval", 1.0).max(0.1);
    let n_events = args.get_usize("events", 12);
    let remote = RemoteBackend::connect(addr)?;
    let (first, _) = remote
        .remote_telemetry()
        .ok_or_else(|| anyhow::anyhow!("{addr}: no telemetry answer"))?;
    std::thread::sleep(std::time::Duration::from_secs_f64(interval));
    let (tree, events) = remote
        .remote_telemetry()
        .ok_or_else(|| anyhow::anyhow!("{addr}: telemetry stopped mid-sample"))?;
    let dtrials = tree.snapshot.trials_executed.saturating_sub(first.snapshot.trials_executed);
    println!(
        "raca top — {addr} (wire v{}): {} nodes, {:.0} trials/s over the last {interval:.1}s",
        raca::serve::net::PROTOCOL_VERSION,
        tree.num_nodes(),
        dtrials as f64 / interval,
    );
    print!("{}", tree.render());
    print_events(&events, n_events);
    Box::new(remote).shutdown();
    Ok(())
}

fn top_local(args: &Args, topo: &Topology) -> Result<()> {
    let n = args.get_usize("images", 64);
    let trials = args.get_usize("trials", 12) as u32;
    let probe_rate = args.get_f64("probe-rate", 0.1);
    let n_events = args.get_usize("events", 12);

    let art = artifact_dir(args, None);
    let (w, pool) = load_or_train(&art)?;
    anyhow::ensure!(!pool.is_empty(), "no evaluation data available");
    let cal = pool.take(48.min(pool.len()));
    let ds = {
        let d = pool.slice(cal.len(), cal.len() + n);
        if d.is_empty() { cal.clone() } else { d }
    };
    let plan = DeployPlan::compile(topo)?;
    println!("top: topology {topo} ({} dies), {} labeled requests…", plan.total_dies, ds.len());
    let opts = BuildOptions {
        seed: args.get_usize("seed", 0x70B) as u64,
        calibration: Some((cal.clone(), Calibrator::quick(5))),
        probe_rate,
        artifact_dir: Some(art),
        ..Default::default()
    };
    let backend = raca::serve::plan::build(topo, &w, &opts)?;

    let t0 = std::time::Instant::now();
    let tickets = (0..ds.len())
        .map(|i| {
            backend.submit(
                InferRequest::new(i as u64, ds.image(i).to_vec())
                    .with_budget(trials, 0.0)
                    .with_label(ds.label(i)),
            )
        })
        .collect::<Result<Vec<_>>>()?;
    for t in tickets {
        backend.wait(t)?;
    }
    let dt = t0.elapsed().as_secs_f64().max(1e-9);

    let tree = backend.metrics_tree();
    println!(
        "raca top — local: {} nodes, {:.0} trials/s over {:.2}s",
        tree.num_nodes(),
        tree.snapshot.trials_executed as f64 / dt,
        dt
    );
    print!("{}", tree.render());
    if let Some(j) = backend.journal() {
        print_events(&j.tail(n_events), n_events);
    }
    backend.shutdown();
    Ok(())
}

fn print_events(events: &[raca::telemetry::Event], n: usize) {
    if events.is_empty() || n == 0 {
        return;
    }
    println!("recent events:");
    let skip = events.len().saturating_sub(n);
    for e in &events[skip..] {
        println!("  {e}");
    }
}

/// `raca train` — regenerate weight + dataset artifacts natively: the
/// minibatch-parallel [`raca::nn::train`] at any `--widths` (paper scale
/// by default), saved in the python toolchain's on-disk format so every
/// artifact consumer (`raca serve`, `infer`, the figures) loads them —
/// no python required.
fn train_cmd(args: &Args) -> Result<()> {
    let widths = match args.get("widths") {
        Some(spec_str) => parse_widths(spec_str)?,
        None => ModelSpec::paper().widths,
    };
    let samples = args.get_usize("samples", 4000);
    let test_samples = args.get_usize("test-samples", 2000);
    let seed = args.get_usize("seed", 0x7121) as u64;
    let tc = TrainConfig {
        epochs: args.get_usize("epochs", 6),
        lr: args.get_f64("lr", 0.2) as f32,
        seed,
        minibatch: args.get_usize("minibatch", 16).max(1),
    };
    // `--out` keeps its historical meaning; absent, train lands in the
    // same resolved artifact directory every consumer loads from.
    let out = args
        .get("out")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| artifact_dir(args, None));
    let wpath = out.join("weights").join("fcnn");
    anyhow::ensure!(
        args.has("force") || !wpath.with_extension("json").exists(),
        "{} already exists — pass --force to overwrite",
        wpath.with_extension("json").display()
    );

    println!(
        "train: {widths:?} on {samples} synthetic digits ({} epochs, lr {}, minibatch {})…",
        tc.epochs, tc.lr, tc.minibatch
    );
    let train_set = synth::generate(samples, seed ^ 0x7EA1C);
    let t0 = std::time::Instant::now();
    let mut w = raca::nn::train(&train_set, ModelSpec::new(widths), &tc);
    println!(
        "train: done in {:.2?}, train accuracy {:.2}%",
        t0.elapsed(),
        w.ideal_test_accuracy * 100.0
    );
    // Score + record held-out accuracy (the number every consumer prints).
    let test_set = synth::generate(test_samples, seed ^ 0x7E57);
    w.ideal_test_accuracy = raca::nn::train::ideal_accuracy(&w, &test_set);
    println!("train: held-out accuracy {:.2}% on {test_samples} images", w.ideal_test_accuracy * 100.0);

    w.save(&wpath)?;
    test_set.save(&out.join("data").join("test"))?;
    println!(
        "train: artifacts saved under {} (weights/fcnn.{{bin,json}}, data/test.*) — \
         `raca serve`/`infer` will load them from here",
        out.display()
    );
    Ok(())
}

/// `raca publish` — blob, sign and store one deployable bundle in the
/// local registry; optionally push it to a live listener's registry too.
fn publish_cmd(args: &Args) -> Result<()> {
    use anyhow::Context as _;
    use std::path::Path;

    let Some(prefix) = args.positional(0) else {
        anyhow::bail!(
            "usage: raca publish <weights-prefix> <calibration.json> \
             [--dataset PATH] [--to host:port] [--artifact-dir DIR]\n  \
             e.g. `raca publish artifacts/weights/fcnn calib.json`"
        );
    };
    let Some(calib) = args.positional(1) else {
        anyhow::bail!("raca publish: missing the calibration profile path (second argument)");
    };
    let dir = artifact_dir(args, None);
    let store = raca::registry::Store::open(&dir);
    let key = raca::registry::SigningKey::load_or_generate(&dir)
        .with_context(|| format!("deployment key under {}", dir.display()))?;
    let (id, env) = raca::registry::publish_local(
        &store,
        &key,
        Path::new(prefix),
        Path::new(calib),
        args.get("dataset").map(Path::new),
    )?;
    println!(
        "published bundle {id}\n  model : {} {:?}\n  key   : {}\n  store : {}",
        env.manifest.model,
        env.manifest.widths,
        key.key_id,
        store.root().display()
    );
    if let Some(addr) = args.get("to") {
        let blobs = env
            .manifest
            .blob_hashes()
            .iter()
            .map(|&h| Ok((h.to_string(), store.get_blob(h)?)))
            .collect::<Result<Vec<_>>>()?;
        let mut client = raca::registry::RegistryClient::connect(addr)?;
        let pushed = client.publish(&env, &blobs)?;
        client.close();
        println!("pushed to {addr}: bundle {pushed} admitted");
    }
    println!("serve it   : raca serve --listen <host:port> --artifact-dir {}", dir.display());
    println!("resolve it : --topology \"remote:@<host:port>/{id}\"");
    Ok(())
}

/// `raca bundles` — list the local registry store, or a live listener's
/// advertisement.  One line per bundle, id first, so scripts can
/// `awk '{print $1}'`.
fn bundles_cmd(args: &Args) -> Result<()> {
    let describe = |id: &str, env: Result<raca::registry::SignedManifest>| match env {
        Ok(env) => println!("{id} {} {:?}", env.manifest.model, env.manifest.widths),
        Err(e) => println!("{id} (manifest unavailable: {e:#})"),
    };
    match args.positional(0) {
        Some(addr) => {
            let mut client = raca::registry::RegistryClient::connect(addr)?;
            let ids = client.bundles()?;
            for id in &ids {
                let env = client.fetch_manifest(id);
                describe(id, env);
            }
            client.close();
            if ids.is_empty() {
                eprintln!("{addr}: no bundles advertised");
            }
        }
        None => {
            let store = raca::registry::Store::open(artifact_dir(args, None));
            let ids = store.list()?;
            for id in &ids {
                describe(id, store.get_manifest(id));
            }
            if ids.is_empty() {
                eprintln!(
                    "{}: empty registry (create a bundle with `raca publish`)",
                    store.root().display()
                );
            }
        }
    }
    Ok(())
}

/// `raca fleet` — the full multi-chip loop: program N non-identical dies,
/// calibrate each against a held-out set, then serve a workload through
/// the replicated [`Backend`] (per-chip worker threads, router dispatch,
/// live health steering).
fn fleet(args: &Args) -> Result<()> {
    use anyhow::Context as _;

    let (mut fc, art_cfg) = match args.get("config") {
        Some(path) => {
            let c = raca::config::RunConfig::load(std::path::Path::new(path))?;
            (c.fleet, c.artifacts)
        }
        None => (FleetConfig::default(), None),
    };
    fc.chips = args.get_usize("chips", fc.chips);
    fc.sigma = args.get_f64("sigma", fc.sigma);
    if let Some(p) = args.get("policy") {
        fc.policy = RoutePolicy::parse(p).with_context(|| {
            format!("unknown policy '{p}' (valid: {})", RoutePolicy::SPELLINGS)
        })?;
    }
    fc.cal_images = args.get_usize("cal-images", fc.cal_images);
    fc.cal_trials = args.get_usize("cal-trials", fc.cal_trials);
    fc.serve_images = args.get_usize("images", fc.serve_images);
    fc.serve_trials = args.get_usize("trials", fc.serve_trials);
    fc.seed = args.get_usize("seed", fc.seed as usize) as u64;
    anyhow::ensure!(fc.chips > 0, "--chips must be at least 1");

    println!(
        "fleet: {} chips @ programming σ={:.2} (stuck {:.3}/{:.3}), policy {}, seed {:#x}",
        fc.chips, fc.sigma, fc.stuck_lo, fc.stuck_hi, fc.policy.name(), fc.seed
    );

    // ---- model + data splits ---------------------------------------------
    let (weights, pool) = load_or_train(&artifact_dir(args, art_cfg.as_deref()))?;
    anyhow::ensure!(!pool.is_empty(), "no evaluation data available");
    let cal = pool.take(fc.cal_images.min(pool.len()));
    let serve_lo = cal.len().min(pool.len());
    let mut workload = pool.slice(serve_lo, serve_lo + fc.serve_images);
    if workload.is_empty() {
        workload = cal.clone();
    }
    println!(
        "data : {} calibration images, {} serving requests",
        cal.len(),
        workload.len()
    );

    // ---- program the farm -------------------------------------------------
    let t0 = std::time::Instant::now();
    let variation = fc.variation();
    let mut farm = Fleet::program_native(&weights, fc.chips, &variation, fc.policy, fc.seed);
    println!("programmed {} dies in {:.2?}", farm.len(), t0.elapsed());

    // ---- calibrate: per-chip grid search ---------------------------------
    // The reports carry both numbers (scoring is deterministic), so no
    // extra mean_accuracy passes are needed.
    let calibrator = Calibrator { trials: fc.cal_trials, ..Default::default() };
    let t0 = std::time::Instant::now();
    let reports = farm.calibrate(&cal, &calibrator);
    let cal_time = t0.elapsed();
    let n_rep = reports.len().max(1) as f64;
    let uncal_acc = reports.iter().map(|r| r.baseline_accuracy).sum::<f64>() / n_rep;
    let cal_acc = reports.iter().map(|r| r.calibrated_accuracy).sum::<f64>() / n_rep;

    let mut table = raca::util::table::Table::new(
        &format!(
            "Per-chip calibration ({} candidates × {} images × {} trials)",
            reports.first().map(|r| r.candidates_tried).unwrap_or(0),
            cal.len(),
            fc.cal_trials
        ),
        &["chip", "baseline", "calibrated", "θ", "σ_z"],
    );
    for r in &reports {
        table.row(vec![
            r.chip.to_string(),
            format!("{:.4}", r.baseline_accuracy),
            format!("{:.4}", r.calibrated_accuracy),
            format!("{:.2}", r.chosen.theta),
            format!("{:.3}", r.chosen.sigma_z),
        ]);
    }
    table.emit(&figures::results_dir(), "fleet_calibration")?;
    println!(
        "fleet accuracy on calibration set: uncalibrated {:.2}% → calibrated {:.2}% ({} chips, {:.2?})",
        uncal_acc * 100.0,
        cal_acc * 100.0,
        farm.len(),
        cal_time
    );
    debug_assert!(cal_acc >= uncal_acc, "calibration must not hurt on the cal set");

    // ---- serve through the replicated backend -----------------------------
    // The farm moves onto per-chip worker threads behind the Backend
    // trait (`serve::plan::lift_fleet` — the one externally-programmed
    // path into the topology runtime); labeled requests double as health
    // probes, so the monitor steers traffic (reweight/recalibrate/evict)
    // *while* serving.
    let backend = raca::serve::plan::lift_fleet(
        farm,
        Some((cal.clone(), calibrator.clone())),
        raca::serve::ReplicatedOptions { seed: fc.seed ^ 0x5E11E, ..Default::default() },
    );
    serve_and_report(&backend, &workload, fc.serve_trials as u32, 0.0, None)?;
    println!("{}", backend.snapshot());
    let tw: Vec<f64> = backend
        .traffic_weights()
        .iter()
        .map(|w| (w * 100.0).round() / 100.0)
        .collect();
    println!("health: healthy chips {:?}, traffic weights {tw:?}", backend.healthy());
    Box::new(backend).shutdown();
    Ok(())
}

/// Chip floorplan + pipeline report (arch module).
fn arch_report(args: &Args) -> Result<()> {
    use raca::arch::{Floorplan, PipelineModel, ShardPlan};
    use raca::hwmodel::{Architecture, TechParams};

    let tile = args.get_usize("tile", 128);
    let mut tech = TechParams::default();
    tech.tile = tile;
    let fp = Floorplan::place(ModelSpec::paper(), tile, 8);
    fp.validate().map_err(|e| anyhow::anyhow!(e))?;
    println!("floorplan: {} tiles of {tile}x{tile} on an 8-wide grid", fp.num_tiles());
    for l in 0..fp.spec.num_layers() {
        let tiles = fp.layer_tiles(l);
        println!(
            "  layer {l}: {:>3} tiles, shape {:?}, hop→next {:.2} pitches",
            tiles.len(),
            fp.spec.layer_shape(l),
            if l + 1 < fp.spec.num_layers() { fp.layer_hop_distance(l) } else { 0.0 }
        );
    }
    println!("  device utilization: {:.1}%", fp.device_utilization() * 100.0);

    for (name, arch) in [("RACA", Architecture::Raca), ("1-bit ADC", Architecture::OneBitAdc)] {
        let mut pm = PipelineModel::new(ModelSpec::paper(), tech.clone(), arch);
        pm.set_wta_expectation_from_theta(3.0, 10);
        let r = pm.report();
        println!(
            "pipeline [{name}]: stages {:?} ns, latency {:.1} ns, II {:.1} ns → {:.1}M trials/s, bottleneck stage {}",
            r.stage_ns.iter().map(|s| (s * 10.0).round() / 10.0).collect::<Vec<_>>(),
            r.latency_ns,
            r.ii_ns,
            r.trials_per_sec / 1e6,
            r.bottleneck
        );
    }

    // Multi-die shard plans (the pipelined backend executes these).
    for dies in [2usize, 3] {
        match ShardPlan::balanced(&ModelSpec::paper(), tile, dies) {
            Ok(plan) => println!(
                "shard [{dies} dies]: layer ranges {:?}, tiles/die {:?} (max {})",
                plan.ranges,
                plan.tiles_per_die,
                plan.max_tiles()
            ),
            Err(e) => println!("shard [{dies} dies]: {e}"),
        }
    }
    Ok(())
}

/// Trial-budget planning from measured per-image win statistics.
fn plan(args: &Args) -> Result<()> {
    use raca::planner::vote_model_from_probs;

    let n = args.get_usize("images", 100);
    let target = args.get_f64("target", 0.97);
    let probe_trials = args.get_usize("probe-trials", 64);
    let dir = artifact_dir(args, None);
    let ds = Dataset::load(&dir.join("data").join("test"))?.take(n);
    let w = std::sync::Arc::new(Weights::load(&dir.join("weights").join("fcnn"))?);
    let engine = NativeEngine::new(w, 77);
    let p = TrialParams::default();

    let mut budgets = Vec::new();
    let mut unplannable = 0usize;
    for i in 0..ds.len() {
        let o = engine.infer(ds.image(i), p, probe_trials, (i * 97) as u64);
        let freqs = o.frequencies();
        let m = vote_model_from_probs(&freqs);
        match m.trials_for_accuracy(target) {
            Some(k) => budgets.push(k),
            None => unplannable += 1,
        }
    }
    budgets.sort_unstable();
    let pct = |p: f64| budgets[((budgets.len() - 1) as f64 * p) as usize];
    println!(
        "plan: target per-image vote accuracy {target} over {n} probed images ({probe_trials} probe trials each)"
    );
    println!(
        "  trials needed: p50={} p90={} p99={} max={}  (unplannable: {unplannable} tied images)",
        pct(0.5),
        pct(0.9),
        pct(0.99),
        budgets.last().copied().unwrap_or(0)
    );
    println!(
        "  → a fixed budget of {} trials covers 99% of inputs; the early-stopper\n    spends ~p50 on typical inputs (see `raca infer --confidence`).",
        pct(0.99)
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn selftest() -> Result<()> {
    println!("[1/3] PJRT smoke (artifacts/smoke.hlo.txt)…");
    let dir = default_artifact_dir();
    let client = raca::runtime::RtClient::new()?;
    let exe = client.compile_hlo_text(&dir.join("smoke.hlo.txt"))?;
    let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]).reshape(&[2, 2])?;
    let y = xla::Literal::vec1(&[1f32, 1., 1., 1.]).reshape(&[2, 2])?;
    let out = exe.execute::<xla::Literal>(&[x, y])?[0][0]
        .to_literal_sync()?
        .to_tuple1()?
        .to_vec::<f32>()?;
    anyhow::ensure!(out == vec![5., 5., 9., 9.], "smoke HLO wrong: {out:?}");
    println!("      ok: {out:?}");

    println!("[2/3] trial executable (batch 1)…");
    let engine = XlaEngine::start(dir.clone())?;
    let h = engine.handle();
    let ds = Dataset::load(&dir.join("data").join("test"))?.take(8);
    let w = h.run_trials(ds.image(0).to_vec(), 1, 7, TrialParams::default())?;
    anyhow::ensure!((-1..10).contains(&w[0]), "bad winner {w:?}");
    println!("      ok: winner={}", w[0]);

    println!("[3/3] single-chip backend vote on 8 images…");
    let mut cfg = SchedulerConfig::default();
    cfg.batch_size = 32;
    let backend = raca::serve::plan::single_die(h, cfg);
    let mut hits = 0;
    for i in 0..8 {
        let r = backend.classify(
            InferRequest::new(i as u64, ds.image(i).to_vec()).with_budget(15, 0.9),
        )?;
        if r.prediction == ds.label(i) {
            hits += 1;
        }
    }
    println!("      ok: {hits}/8 correct");
    println!("selftest PASSED");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn selftest() -> Result<()> {
    println!("[1/5] native trainer on synthetic digits…");
    let train_set = synth::generate(200, 0xA);
    let cfg = TrainConfig { epochs: 3, lr: 0.25, seed: 0xB, minibatch: 1 };
    let w = raca::nn::train(&train_set, ModelSpec::new(vec![784, 16, 10]), &cfg);
    anyhow::ensure!(
        w.ideal_test_accuracy > 0.3,
        "trainer underperformed: {:.3}",
        w.ideal_test_accuracy
    );
    println!("      ok: train accuracy {:.1}%", w.ideal_test_accuracy * 100.0);

    println!("[2/5] single-die topology vote over the native engine…");
    let backend = raca::serve::plan::build(
        &Topology::parse("die")?,
        &w,
        &BuildOptions { seed: 7, ..Default::default() },
    )?;
    let mut hits = 0usize;
    for i in 0..8 {
        let r = backend.classify(
            InferRequest::new(i as u64, train_set.image(i).to_vec()).with_budget(15, 0.9),
        )?;
        if r.prediction == train_set.label(i) {
            hits += 1;
        }
    }
    println!("      ok: {hits}/8 correct");

    println!("[3/5] two-chip fleet calibration (σ=10%)…");
    let mut farm = Fleet::program_native(
        &w,
        2,
        &VariationModel::lognormal(0.10),
        RoutePolicy::RoundRobin,
        0xC,
    );
    let cal = train_set.take(16);
    let calibrator = Calibrator::quick(5);
    let before = farm.mean_accuracy(&cal, &calibrator);
    farm.calibrate(&cal, &calibrator);
    let after = farm.mean_accuracy(&cal, &calibrator);
    anyhow::ensure!(after >= before, "calibration regressed: {before} → {after}");
    println!("      ok: fleet cal-set accuracy {:.1}% → {:.1}%", before * 100.0, after * 100.0);

    println!("[4/5] 2x(pipeline:2) topology vs unsharded engine…");
    let seed = 0xD1E5;
    let reference = NativeEngine::new(std::sync::Arc::new(w.clone()), seed);
    let pb = raca::serve::plan::build(
        &Topology::parse("2x(pipeline:2)")?,
        &w,
        &BuildOptions { seed, ..Default::default() },
    )?;
    let x = train_set.image(0).to_vec();
    let want = reference.infer(
        &x,
        TrialParams::default(),
        12,
        raca::serve::trial_stream_base(seed, 0),
    );
    let got = pb.classify(InferRequest::new(0, x).with_budget(12, 0.0))?;
    anyhow::ensure!(
        got.outcome.counts == want.counts,
        "replicated-pipeline votes diverged from the unsharded engine"
    );
    println!("      ok: votes match bit-for-bit, either replica of 2 dies");

    println!("[5/5] remote:die over a loopback listener vs the local engine…");
    let seed = 0x11E7;
    let host = raca::serve::plan::build(
        &Topology::parse("die")?,
        &w,
        &BuildOptions { seed, ..Default::default() },
    )?;
    let listener = raca::serve::net::serve(host, "127.0.0.1:0")?;
    let remote = raca::serve::plan::build(
        &Topology::parse(&format!("remote:{}", listener.addr()))?,
        &w,
        &BuildOptions::default(), // the client seed is irrelevant: the listener's governs
    )?;
    let x = train_set.image(1).to_vec();
    let reference = NativeEngine::new(std::sync::Arc::new(w.clone()), seed);
    let want = reference.infer(
        &x,
        TrialParams::default(),
        10,
        raca::serve::trial_stream_base(seed, 5),
    );
    let got = remote.classify(InferRequest::new(5, x).with_budget(10, 0.0))?;
    anyhow::ensure!(
        got.outcome.counts == want.counts,
        "remote:die votes diverged from the local engine across the socket"
    );
    remote.shutdown();
    println!("      ok: votes match bit-for-bit across the wire (protocol v{})",
        raca::serve::net::PROTOCOL_VERSION);
    println!("selftest PASSED");
    Ok(())
}
