//! Physical crossbar array simulation.
//!
//! An array holds the programmed conductances of one weight matrix (or
//! tile) plus the shared reference column.  Reads compute the paper's
//! Eq. 9–12 in amperes:
//!
//!   I_j    = Σ_i V_i·G_ij + noise,    I_ref = Σ_i V_i·Gref + noise
//!
//! Two read modes:
//! * `PerDevice` — one Gaussian per device per read (exact Eq. 9/10; slow,
//!   used by validation tests and the noise-composition ablation),
//! * `ColumnAggregate` — one Gaussian per column with the summed variance
//!   `4kTΔf·Σ(G_ij + Gref)` (exact same statistics for thermal noise,
//!   ~N_col× faster; the default).

use crate::device::noise::{NoiseModel, NoiseParams};
use crate::device::variation::VariationModel;
use crate::stats::GaussianSource;

use super::mapping::WeightMapping;

/// Noise sampling granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadMode {
    PerDevice,
    ColumnAggregate,
}

/// A programmed crossbar of `rows × cols` devices + one reference column.
#[derive(Debug, Clone)]
pub struct CrossbarArray {
    pub rows: usize,
    pub cols: usize,
    /// Row-major programmed conductances [S].
    pub g: Vec<f64>,
    /// Reference column conductances [S] (one per row; ideally all Gref).
    pub g_ref_col: Vec<f64>,
    pub mapping: WeightMapping,
    pub noise: NoiseModel,
    /// Per-column Σ_i(G_ij + Gref_i) — precomputed for aggregate reads.
    g_col_sums: Vec<f64>,
}

impl CrossbarArray {
    /// Program an array from a row-major weight slice.
    pub fn program(
        rows: usize,
        cols: usize,
        weights: &[f32],
        mapping: WeightMapping,
        variation: &VariationModel,
        noise_params: NoiseParams,
        gauss: &mut GaussianSource,
    ) -> Self {
        assert_eq!(weights.len(), rows * cols, "weight slice shape mismatch");
        let mut g = Vec::with_capacity(rows * cols);
        for &w in weights {
            let target = mapping.weight_to_g(w as f64);
            g.push(variation.apply(target, mapping.g_min, mapping.g_max, gauss));
        }
        let g_ref_col: Vec<f64> = (0..rows)
            .map(|_| variation.apply(mapping.g_ref(), mapping.g_min, mapping.g_max, gauss))
            .collect();
        let noise = NoiseModel::new(noise_params, rows * (cols + 1));
        let mut arr = Self { rows, cols, g, g_ref_col, mapping, noise, g_col_sums: vec![] };
        arr.recompute_column_sums();
        arr
    }

    fn recompute_column_sums(&mut self) {
        let gref_sum: f64 = self.g_ref_col.iter().sum();
        self.g_col_sums = (0..self.cols)
            .map(|j| {
                let gj: f64 = (0..self.rows).map(|i| self.g[i * self.cols + j]).sum();
                gj + gref_sum
            })
            .collect();
    }

    /// Mean differential currents (no noise): out[j] = Σ_i V_i·(G_ij − Gref_i).
    pub fn mean_differential(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        out.fill(0.0);
        for i in 0..self.rows {
            let vi = v[i];
            if vi == 0.0 {
                continue;
            }
            let row = &self.g[i * self.cols..(i + 1) * self.cols];
            let gr = self.g_ref_col[i];
            for (o, &gij) in out.iter_mut().zip(row) {
                *o += vi * (gij - gr);
            }
        }
    }

    /// One noisy differential read: out[j] = (I_j + n_j) − (I_ref + n_ref).
    ///
    /// Thermal noise is present on every device regardless of its input
    /// voltage (Johnson noise is an equilibrium phenomenon), so the
    /// variance sums over *all* rows — exactly Eq. 13's denominator.
    pub fn read_differential(
        &mut self,
        v: &[f64],
        mode: ReadMode,
        out: &mut [f64],
        gauss: &mut GaussianSource,
    ) {
        self.mean_differential(v, out);
        match mode {
            ReadMode::ColumnAggregate => {
                for (j, o) in out.iter_mut().enumerate() {
                    let var = self.noise.column_variance(self.g_col_sums[j], 0.0);
                    if var > 0.0 {
                        *o += gauss.next() * var.sqrt();
                    }
                }
            }
            ReadMode::PerDevice => {
                for j in 0..self.cols {
                    let mut n = 0.0;
                    for i in 0..self.rows {
                        let g_ij = self.g[i * self.cols + j];
                        let i_mean = v[i] * g_ij;
                        n += self.noise.sample(i * self.cols + j, g_ij, i_mean, gauss);
                        let g_r = self.g_ref_col[i];
                        let i_ref = v[i] * g_r;
                        n -= self
                            .noise
                            .sample(self.rows * self.cols + i, g_r, i_ref, gauss);
                    }
                    out[j] += n;
                }
            }
        }
    }

    /// Column conductance sum Σ_i(G_ij + Gref_i) (hw model needs it).
    pub fn column_g_sum(&self, j: usize) -> f64 {
        self.g_col_sums[j]
    }

    /// Total array conductance (energy model: static read power).
    pub fn total_g(&self) -> f64 {
        self.g.iter().sum::<f64>() + self.g_ref_col.iter().sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Summary;

    fn make(rows: usize, cols: usize, w: f32, seed: u64) -> (CrossbarArray, GaussianSource) {
        let mut g = GaussianSource::new(seed);
        let arr = CrossbarArray::program(
            rows,
            cols,
            &vec![w; rows * cols],
            WeightMapping::default(),
            &VariationModel::default(),
            NoiseParams::thermal_only(1e9),
            &mut g,
        );
        (arr, g)
    }

    #[test]
    fn mean_differential_matches_eq12() {
        // Eq. 12: Ī_j − Ī_ref = Vr·G0·Σ W_ij·x_i for binary x.
        let (arr, _) = make(8, 3, 0.75, 1);
        let m = WeightMapping::default();
        let vr = 0.01;
        let v = vec![vr; 8];
        let mut out = vec![0.0; 3];
        arr.mean_differential(&v, &mut out);
        let want = vr * m.g0() * 0.75 * 8.0;
        for o in out {
            assert!((o - want).abs() / want < 1e-9);
        }
    }

    #[test]
    fn aggregate_noise_variance_matches_eq13() {
        let (mut arr, mut gauss) = make(16, 1, 0.0, 2);
        let v = vec![0.0; 16]; // zero signal isolates the noise
        let mut out = vec![0.0; 1];
        let mut s = Summary::new();
        for _ in 0..20_000 {
            arr.read_differential(&v, ReadMode::ColumnAggregate, &mut out, &mut gauss);
            s.add(out[0]);
        }
        let want_var = arr.noise.column_variance(arr.column_g_sum(0), 0.0);
        assert!((s.var() - want_var).abs() / want_var < 0.05);
        assert!(s.mean().abs() < 3.0 * want_var.sqrt() / (20_000f64).sqrt() * 3.0);
    }

    #[test]
    fn per_device_and_aggregate_agree_statistically() {
        let (mut arr, mut gauss) = make(12, 2, 0.5, 3);
        let v = vec![0.005; 12];
        let mut out = vec![0.0; 2];
        let mut s_pd = Summary::new();
        let mut s_ca = Summary::new();
        for _ in 0..15_000 {
            arr.read_differential(&v, ReadMode::PerDevice, &mut out, &mut gauss);
            s_pd.add(out[0]);
            arr.read_differential(&v, ReadMode::ColumnAggregate, &mut out, &mut gauss);
            s_ca.add(out[0]);
        }
        assert!((s_pd.mean() - s_ca.mean()).abs() < 4.0 * s_pd.sem().max(s_ca.sem()));
        assert!((s_pd.std() - s_ca.std()).abs() / s_ca.std() < 0.06);
    }

    #[test]
    fn variation_perturbs_conductances() {
        let mut g = GaussianSource::new(4);
        let arr = CrossbarArray::program(
            4,
            4,
            &vec![0.5; 16],
            WeightMapping::default(),
            &VariationModel::lognormal(0.1),
            NoiseParams::thermal_only(1e9),
            &mut g,
        );
        let first = arr.g[0];
        assert!(arr.g.iter().any(|&gv| (gv - first).abs() > 1e-9));
    }

    #[test]
    fn sparse_input_skips_rows() {
        let (arr, _) = make(6, 2, 1.0, 5);
        let mut out_a = vec![0.0; 2];
        let mut out_b = vec![0.0; 2];
        arr.mean_differential(&[0.0, 0.01, 0.0, 0.01, 0.0, 0.0], &mut out_a);
        arr.mean_differential(&[0.0, 0.01, 0.0, 0.01, 0.0, 0.0], &mut out_b);
        assert_eq!(out_a, out_b);
        assert!(out_a[0] != 0.0);
    }
}
