//! Weight ⇄ conductance mapping and SNR calibration (paper Eq. 4–7, 13).
//!
//! Mirrors `python/compile/physics.py` exactly — the parity tests compare
//! numbers across the language boundary.

use crate::device::{DELTA_F, G_MAX, G_MIN, K_B, SIGMOID_PROBIT, TEMPERATURE, W_CLIP};

/// Affine weight→conductance mapping for a weight range [w_min, w_max].
#[derive(Debug, Clone)]
pub struct WeightMapping {
    pub w_min: f64,
    pub w_max: f64,
    pub g_min: f64,
    pub g_max: f64,
}

impl Default for WeightMapping {
    fn default() -> Self {
        Self { w_min: -W_CLIP, w_max: W_CLIP, g_min: G_MIN, g_max: G_MAX }
    }
}

impl WeightMapping {
    /// G0 = (Gmax − Gmin)/(Wmax − Wmin)  (Eq. 4)
    pub fn g0(&self) -> f64 {
        (self.g_max - self.g_min) / (self.w_max - self.w_min)
    }

    /// Gref = (Wmax·Gmin − Wmin·Gmax)/(Wmax − Wmin)  (Eq. 5)
    pub fn g_ref(&self) -> f64 {
        (self.w_max * self.g_min - self.w_min * self.g_max) / (self.w_max - self.w_min)
    }

    /// G_ij = W_ij·G0 + Gref  (Eq. 7), clamped to the physical range.
    pub fn weight_to_g(&self, w: f64) -> f64 {
        (w.clamp(self.w_min, self.w_max) * self.g0() + self.g_ref())
            .clamp(self.g_min, self.g_max)
    }

    /// Inverse mapping (for verification): W = (G − Gref)/G0.
    pub fn g_to_weight(&self, g: f64) -> f64 {
        (g - self.g_ref()) / self.g0()
    }

    /// σ_tot of the differential column noise for `n_col` devices at
    /// bandwidth Δf (idealized column: mean device conductance = Gref).
    pub fn column_noise_sigma(&self, n_col: usize, delta_f: f64) -> f64 {
        let g_sum = n_col as f64 * 2.0 * self.g_ref();
        (4.0 * K_B * TEMPERATURE * delta_f * g_sum).sqrt()
    }

    /// Read voltage placing κ = Vr·G0/σ_tot at `snr_scale`/1.702 (Eq. 13).
    pub fn calibrate_vr(&self, n_col: usize, delta_f: f64, snr_scale: f64) -> f64 {
        snr_scale * self.column_noise_sigma(n_col, delta_f) / (SIGMOID_PROBIT * self.g0())
    }

    /// κ realized by a concrete (Vr, N_col, Δf) design point.
    pub fn kappa(&self, vr: f64, n_col: usize, delta_f: f64) -> f64 {
        vr * self.g0() / self.column_noise_sigma(n_col, delta_f)
    }

    /// Normalized pre-activation noise std: σ_z = 1/κ.
    pub fn sigma_z(&self, snr_scale: f64) -> f64 {
        SIGMOID_PROBIT / snr_scale
    }
}

/// Default calibration used across the repo (mirrors python defaults).
pub fn default_calibration(n_col: usize) -> (f64, f64) {
    let m = WeightMapping::default();
    let vr = m.calibrate_vr(n_col, DELTA_F, 1.0);
    (vr, m.sigma_z(1.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints_map_to_range() {
        let m = WeightMapping::default();
        assert!((m.weight_to_g(-W_CLIP) - G_MIN).abs() < 1e-18);
        assert!((m.weight_to_g(W_CLIP) - G_MAX).abs() < 1e-18);
        assert!((m.weight_to_g(0.0) - m.g_ref()).abs() < 1e-18);
    }

    #[test]
    fn mapping_inverts() {
        let m = WeightMapping::default();
        for w in [-3.7, -1.0, 0.0, 0.5, 3.9] {
            assert!((m.g_to_weight(m.weight_to_g(w)) - w).abs() < 1e-9, "w={w}");
        }
    }

    #[test]
    fn calibration_hits_kappa() {
        let m = WeightMapping::default();
        for n_col in [98, 785, 1570] {
            for df in [1e8, 1e9, 1e10] {
                for s in [0.25, 1.0, 4.0] {
                    let vr = m.calibrate_vr(n_col, df, s);
                    let k = m.kappa(vr, n_col, df);
                    assert!(
                        (k - s / SIGMOID_PROBIT).abs() < 1e-12,
                        "n={n_col} df={df} s={s}"
                    );
                }
            }
        }
    }

    #[test]
    fn matches_python_constants() {
        // Frozen values computed by python/compile/physics.py — guards the
        // cross-language contract (see engine_parity tests).
        let m = WeightMapping::default();
        assert!((m.g0() - 1.2375e-5).abs() < 1e-10);
        assert!((m.g_ref() - 5.05e-5).abs() < 1e-10);
        let sigma = m.column_noise_sigma(785, 1e9);
        let expect = (4.0 * K_B * 300.0 * 1e9 * 785.0 * 2.0 * 5.05e-5_f64).sqrt();
        assert!((sigma - expect).abs() / expect < 1e-12);
    }

    #[test]
    fn vr_is_small_at_ghz_bandwidth() {
        // The paper: read voltage "much smaller than the usual read
        // voltage" — our calibrated Vr should be tens of mV at 1 GHz.
        let m = WeightMapping::default();
        let vr = m.calibrate_vr(785, 1e9, 1.0);
        assert!(vr > 1e-3 && vr < 0.2, "vr={vr}");
    }
}
