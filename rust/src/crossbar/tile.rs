//! Layer tiling: a (fan_in+1) × fan_out weight matrix mapped onto
//! 128×128 crossbar tiles with analog partial-sum recombination.
//!
//! Real arrays are bounded (wire resistance, sneak paths), so a 785×500
//! layer becomes a ⌈785/128⌉×⌈500/128⌉ grid of tiles whose per-column
//! partial currents are summed (in RACA: wired-OR onto a shared TIA per
//! logical column, so the noise of every stacked tile adds — matching the
//! full-column Eq. 13 statistics exactly).

use crate::device::noise::NoiseParams;
use crate::device::variation::VariationModel;
use crate::stats::GaussianSource;

use super::array::{CrossbarArray, ReadMode};
use super::mapping::WeightMapping;

/// One logical layer split into physical tiles.
#[derive(Debug, Clone)]
pub struct TiledLayer {
    /// Logical dimensions (rows includes the bias row).
    pub rows: usize,
    pub cols: usize,
    pub tile: usize,
    /// Row-major tile grid: tiles[ti][tj] covers rows [ti·T, ...) × cols [tj·T, ...).
    pub tiles: Vec<Vec<CrossbarArray>>,
}

impl TiledLayer {
    /// Program a tiled layer from a row-major augmented weight matrix.
    pub fn program(
        rows: usize,
        cols: usize,
        weights: &[f32],
        tile: usize,
        mapping: WeightMapping,
        variation: &VariationModel,
        noise: &NoiseParams,
        gauss: &mut GaussianSource,
    ) -> Self {
        assert_eq!(weights.len(), rows * cols);
        let nti = rows.div_ceil(tile);
        let ntj = cols.div_ceil(tile);
        let mut tiles = Vec::with_capacity(nti);
        for ti in 0..nti {
            let r0 = ti * tile;
            let tr = tile.min(rows - r0);
            let mut row_tiles = Vec::with_capacity(ntj);
            for tj in 0..ntj {
                let c0 = tj * tile;
                let tc = tile.min(cols - c0);
                let mut w = Vec::with_capacity(tr * tc);
                for i in 0..tr {
                    let base = (r0 + i) * cols + c0;
                    w.extend_from_slice(&weights[base..base + tc]);
                }
                // Convert f64 slice back to f32 for program().
                row_tiles.push(CrossbarArray::program(
                    tr,
                    tc,
                    &w,
                    mapping.clone(),
                    variation,
                    noise.clone(),
                    gauss,
                ));
            }
            tiles.push(row_tiles);
        }
        Self { rows, cols, tile, tiles }
    }

    /// Tile-grid shape (row tiles, col tiles).
    pub fn grid(&self) -> (usize, usize) {
        (self.tiles.len(), self.tiles[0].len())
    }

    /// Noisy differential read of the whole logical layer.
    ///
    /// `v` has `rows` entries (the bias row driven at `v_bias`, typically
    /// Vr); per logical column the partial currents of every row-tile sum.
    pub fn read_differential(
        &mut self,
        v: &[f64],
        mode: ReadMode,
        out: &mut [f64],
        gauss: &mut GaussianSource,
    ) {
        assert_eq!(v.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        out.fill(0.0);
        let tile = self.tile;
        let mut buf = vec![0.0f64; tile];
        for (ti, row_tiles) in self.tiles.iter_mut().enumerate() {
            let r0 = ti * tile;
            for (tj, arr) in row_tiles.iter_mut().enumerate() {
                let c0 = tj * tile;
                let vb = &v[r0..r0 + arr.rows];
                let ob = &mut buf[..arr.cols];
                arr.read_differential(vb, mode, ob, gauss);
                for (k, &p) in ob.iter().enumerate() {
                    out[c0 + k] += p;
                }
            }
        }
    }

    /// Mean (noise-free) differential read — reference for tests.
    pub fn mean_differential(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.rows);
        out.fill(0.0);
        let tile = self.tile;
        let mut buf = vec![0.0f64; tile];
        for (ti, row_tiles) in self.tiles.iter().enumerate() {
            let r0 = ti * tile;
            for (tj, arr) in row_tiles.iter().enumerate() {
                let c0 = tj * tile;
                let vb = &v[r0..r0 + arr.rows];
                let ob = &mut buf[..arr.cols];
                arr.mean_differential(vb, ob);
                for (k, &p) in ob.iter().enumerate() {
                    out[c0 + k] += p;
                }
            }
        }
    }

    /// Number of physical tiles (hw model: array count).
    pub fn num_tiles(&self) -> usize {
        self.tiles.iter().map(|r| r.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Summary;

    fn weights(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::stats::Rng::new(seed);
        (0..rows * cols).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect()
    }

    fn program(rows: usize, cols: usize, tile: usize, seed: u64) -> (TiledLayer, GaussianSource) {
        let mut g = GaussianSource::new(seed);
        let w = weights(rows, cols, seed + 100);
        let t = TiledLayer::program(
            rows,
            cols,
            &w,
            tile,
            WeightMapping::default(),
            &VariationModel::default(),
            &NoiseParams::thermal_only(1e9),
            &mut g,
        );
        (t, g)
    }

    #[test]
    fn grid_shape() {
        let (t, _) = program(300, 130, 128, 1);
        assert_eq!(t.grid(), (3, 2));
        assert_eq!(t.num_tiles(), 6);
        assert_eq!(t.tiles[2][1].rows, 300 - 256);
        assert_eq!(t.tiles[2][1].cols, 2);
    }

    #[test]
    fn tiled_mean_equals_monolithic() {
        let rows = 200;
        let cols = 90;
        let w = weights(rows, cols, 7);
        let mut g = GaussianSource::new(8);
        let mono = CrossbarArray::program(
            rows,
            cols,
            &w,
            WeightMapping::default(),
            &VariationModel::default(),
            NoiseParams::thermal_only(1e9),
            &mut g,
        );
        let tiled = TiledLayer::program(
            rows,
            cols,
            &w,
            64,
            WeightMapping::default(),
            &VariationModel::default(),
            &NoiseParams::thermal_only(1e9),
            &mut g,
        );
        let v: Vec<f64> = (0..rows).map(|i| if i % 3 == 0 { 0.01 } else { 0.0 }).collect();
        let mut a = vec![0.0; cols];
        let mut b = vec![0.0; cols];
        mono.mean_differential(&v, &mut a);
        tiled.mean_differential(&v, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-15, "{x} vs {y}");
        }
    }

    #[test]
    fn tiled_noise_variance_equals_monolithic() {
        // Stacking tiles on a shared column must preserve Eq. 13's total
        // variance: Σ over all rows, independent of the tiling.
        let rows = 96;
        let cols = 1;
        let w = weights(rows, cols, 9);
        let mut g = GaussianSource::new(10);
        let mk_mono = CrossbarArray::program(
            rows, cols, &w,
            WeightMapping::default(), &VariationModel::default(),
            NoiseParams::thermal_only(1e9), &mut g,
        );
        let want_var = mk_mono.noise.column_variance(mk_mono.column_g_sum(0), 0.0);

        let mut tiled = TiledLayer::program(
            rows, cols, &w, 32,
            WeightMapping::default(), &VariationModel::default(),
            &NoiseParams::thermal_only(1e9), &mut g,
        );
        let v = vec![0.0; rows];
        let mut out = vec![0.0; 1];
        let mut s = Summary::new();
        for _ in 0..20_000 {
            tiled.read_differential(&v, ReadMode::ColumnAggregate, &mut out, &mut g);
            s.add(out[0]);
        }
        assert!((s.var() - want_var).abs() / want_var < 0.05,
                "var={} want={}", s.var(), want_var);
    }

    #[test]
    fn non_divisible_edges_covered() {
        let (mut t, mut g) = program(101, 37, 32, 11);
        let v = vec![0.01; 101];
        let mut out = vec![0.0; 37];
        t.read_differential(&v, ReadMode::ColumnAggregate, &mut out, &mut g);
        assert!(out.iter().all(|o| o.is_finite()));
        let mut mean = vec![0.0; 37];
        t.mean_differential(&v, &mut mean);
        assert!(mean.iter().any(|&m| m.abs() > 0.0));
    }
}
