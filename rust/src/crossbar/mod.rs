//! Crossbar layer (DESIGN.md §4.4): conductance mapping, the physical
//! array simulation, tiling, and the SNR calibration solver.

pub mod array;
pub mod mapping;
pub mod tile;

pub use array::{CrossbarArray, ReadMode};
pub use mapping::WeightMapping;
pub use tile::TiledLayer;

/// Crossbar tile geometry (rows × cols) used by the paper / hw model.
pub const TILE: usize = 128;
