//! Table I regeneration: 1-bit ADC vs RACA on the FCNN/MNIST workload.

use crate::util::table::{fmt_g, Table};

use super::system::{Architecture, SystemModel};

/// Paper reference values (Table I) for side-by-side reporting.
pub struct PaperTable1 {
    pub energy_adc_pj: f64,
    pub energy_raca_pj: f64,
    pub area_adc_mm2: f64,
    pub area_raca_mm2: f64,
    pub tops_w_adc: f64,
    pub tops_w_raca: f64,
}

pub const PAPER: PaperTable1 = PaperTable1 {
    energy_adc_pj: 8.7e5,
    energy_raca_pj: 3.63e5,
    area_adc_mm2: 8.51,
    area_raca_mm2: 5.24,
    tops_w_adc: 61.3,
    tops_w_raca: 148.58,
};

/// Our model's Table I numbers.
#[derive(Debug, Clone)]
pub struct Table1Result {
    pub energy_adc_pj: f64,
    pub energy_raca_pj: f64,
    pub area_adc_mm2: f64,
    pub area_raca_mm2: f64,
    pub tops_w_adc: f64,
    pub tops_w_raca: f64,
}

impl Table1Result {
    pub fn compute(model: &SystemModel) -> Self {
        Self {
            energy_adc_pj: model.energy_per_classification(Architecture::OneBitAdc),
            energy_raca_pj: model.energy_per_classification(Architecture::Raca),
            area_adc_mm2: model.area(Architecture::OneBitAdc).total(),
            area_raca_mm2: model.area(Architecture::Raca).total(),
            tops_w_adc: model.tops_per_watt(Architecture::OneBitAdc),
            tops_w_raca: model.tops_per_watt(Architecture::Raca),
        }
    }

    pub fn energy_change_pct(&self) -> f64 {
        (self.energy_raca_pj / self.energy_adc_pj - 1.0) * 100.0
    }

    pub fn area_change_pct(&self) -> f64 {
        (self.area_raca_mm2 / self.area_adc_mm2 - 1.0) * 100.0
    }

    pub fn tops_w_change_pct(&self) -> f64 {
        (self.tops_w_raca / self.tops_w_adc - 1.0) * 100.0
    }

    /// Render the paper-format table with a paper-reference column.
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            "Table I — hardware metrics, FCNN [784,500,300,10] (32 nm)",
            &["Metric", "1-bit ADC", "RACA", "Change (%)", "Paper change (%)"],
        );
        t.row(vec![
            "Energy (pJ/classification, 16-trial vote)".into(),
            fmt_g(self.energy_adc_pj),
            fmt_g(self.energy_raca_pj),
            format!("{:+.2}", self.energy_change_pct()),
            format!("{:+.2}", (PAPER.energy_raca_pj / PAPER.energy_adc_pj - 1.0) * 100.0),
        ]);
        t.row(vec![
            "Area (mm^2)".into(),
            fmt_g(self.area_adc_mm2),
            fmt_g(self.area_raca_mm2),
            format!("{:+.2}", self.area_change_pct()),
            format!("{:+.2}", (PAPER.area_raca_mm2 / PAPER.area_adc_mm2 - 1.0) * 100.0),
        ]);
        t.row(vec![
            "Energy Efficiency (TOPS/W)".into(),
            fmt_g(self.tops_w_adc),
            fmt_g(self.tops_w_raca),
            format!("{:+.2}", self.tops_w_change_pct()),
            format!("{:+.2}", (PAPER.tops_w_raca / PAPER.tops_w_adc - 1.0) * 100.0),
        ]);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn directions_match_paper() {
        let r = Table1Result::compute(&SystemModel::paper());
        assert!(r.energy_change_pct() < 0.0);
        assert!(r.area_change_pct() < 0.0);
        assert!(r.tops_w_change_pct() > 0.0);
    }

    #[test]
    fn magnitudes_within_band_of_paper() {
        // Shape requirement (DESIGN.md §5): energy ↓ ~58%, area ↓ ~38%,
        // TOPS/W ↑ ~142%.  Allow a generous modeling band.
        let r = Table1Result::compute(&SystemModel::paper());
        let e = r.energy_change_pct();
        let a = r.area_change_pct();
        let t = r.tops_w_change_pct();
        assert!((-75.0..=-40.0).contains(&e), "energy change {e}%");
        assert!((-55.0..=-22.0).contains(&a), "area change {a}%");
        assert!((65.0..=300.0).contains(&t), "tops/w change {t}%");
    }

    #[test]
    fn table_renders_three_rows() {
        let r = Table1Result::compute(&SystemModel::paper());
        let t = r.to_table();
        assert_eq!(t.rows.len(), 3);
        let s = t.render();
        assert!(s.contains("TOPS/W"));
    }
}
