//! Conventional multi-bit-ADC CiM baseline (paper §I, Fig. 1).
//!
//! The introduction motivates RACA with the classic result that DACs+ADCs
//! consume "up to 72% of total energy and 81% of area" in conventional
//! ReRAM accelerators (ISAAC/PRIME-class designs with multi-bit column
//! ADCs).  This module models that *conventional* architecture — n-bit
//! SAR ADC per column group, multi-bit DACs per row, shift-add
//! recombination — so the repo reproduces the intro's premise (E-INTRO)
//! as well as Table I.

use crate::nn::ModelSpec;

use super::params::TechParams;
use super::system::Breakdown;

/// Conventional CiM configuration.
#[derive(Debug, Clone)]
pub struct ConventionalCim {
    pub spec: ModelSpec,
    pub tech: TechParams,
    /// Column ADC resolution (ISAAC: 8 bit).
    pub adc_bits: u32,
    /// Row DAC resolution.
    pub dac_bits: u32,
}

impl ConventionalCim {
    pub fn paper() -> Self {
        Self {
            spec: ModelSpec::paper(),
            tech: TechParams::default(),
            adc_bits: 8,
            dac_bits: 8,
        }
    }

    /// SAR ADC energy scales ~linearly in bits (one comparison/bit) with a
    /// conversion overhead; area grows with the capacitor DAC (≈2^b units
    /// at small b, clamped by practical layouts).
    pub fn adc_energy_pj(&self) -> f64 {
        self.tech.adc1_energy_pj * (0.4 + 0.6 * self.adc_bits as f64)
    }

    pub fn adc_area_um2(&self) -> f64 {
        // Cap-DAC dominated: ~2× per extra bit up to a layout cap.
        let scale = (1u64 << self.adc_bits.min(8)) as f64 / 2.0;
        (self.tech.adc1_area_um2 * scale).min(12_000.0)
    }

    pub fn dac_energy_pj(&self) -> f64 {
        self.tech.dac8_energy_pj * self.dac_bits as f64 / 8.0
    }

    pub fn dac_area_um2(&self) -> f64 {
        self.tech.dac8_area_um2 * self.dac_bits as f64 / 8.0
    }

    /// Energy per full-precision inference [pJ] with per-category split.
    pub fn energy(&self) -> Breakdown {
        let t = &self.tech;
        let mut b = Breakdown::default();
        for l in 0..self.spec.num_layers() {
            let rows = self.spec.n_col(l);
            let cols = self.spec.widths[l + 1];
            let row_tiles = rows.div_ceil(t.tile);
            let col_tiles = cols.div_ceil(t.tile);
            // Bit-serial input: dac_bits cycles at EVERY layer (activations
            // are multi-bit in the conventional design).
            let cycles = self.dac_bits as usize;
            let col_reads = cols * cycles;
            b.array += col_reads as f64
                * (2 * rows) as f64
                * t.device_read_energy_pj(t.v_read_conv);
            // Every physical column conversion, every cycle, every row tile.
            let conversions = (cols * cycles * row_tiles) as f64;
            b.readout += conversions * (self.adc_energy_pj() + t.tia_energy_pj);
            b.digital += conversions * t.accum_energy_pj * self.adc_bits as f64 / 4.0;
            // Row DACs drive every cycle.
            b.drivers += (rows * col_tiles * cycles) as f64
                * (t.driver_energy_pj + self.dac_energy_pj() / cycles as f64);
            let bits_io = (rows + cols) as f64 * self.dac_bits as f64;
            b.buffers += bits_io * t.buffer_energy_pj_per_bit * col_tiles as f64;
            b.interconnect += bits_io * t.htree_energy_pj_per_bit_mm * t.htree_dist_mm;
        }
        b.digital += t.control_energy_pj;
        b
    }

    /// Area [mm²] with per-category split.
    pub fn area(&self) -> Breakdown {
        let t = &self.tech;
        let um2 = 1e-6;
        let mut b = Breakdown::default();
        for l in 0..self.spec.num_layers() {
            let rows = self.spec.n_col(l);
            let cols = self.spec.widths[l + 1];
            let row_tiles = rows.div_ceil(t.tile);
            let col_tiles = cols.div_ceil(t.tile);
            let tiles = (row_tiles * col_tiles) as f64;
            b.array += tiles * (t.tile * t.tile) as f64 * t.cell_area_um2() * um2;
            // ADCs are shared 8:1 per column group (standard practice).
            let phys_cols = (col_tiles * t.tile * row_tiles) as f64;
            b.readout +=
                phys_cols / 8.0 * self.adc_area_um2() * um2 + phys_cols * t.colmux_area_um2 * um2;
            b.readout += phys_cols * t.tia_area_um2 * um2;
            b.digital += phys_cols * t.accum_area_um2 * um2 * self.adc_bits as f64 / 4.0;
            let phys_rows = (row_tiles * t.tile * col_tiles) as f64;
            b.drivers += phys_rows * (t.driver_area_um2 + self.dac_area_um2()) * um2;
        }
        // The intro's 72%/81% converter-share numbers are *tile-level*
        // (accelerator macro), not whole-chip: only a small slice of the
        // global control/IO overhead is attributable per tile.
        b.digital += t.global_overhead_mm2 * 0.13;
        b.buffers += t.buffer_kb * t.buffer_area_um2_per_kb * um2;
        let partial = b.total();
        b.interconnect += partial * t.htree_area_frac;
        b
    }

    /// Fraction of total energy spent in DAC+ADC (the intro's "72%").
    pub fn converter_energy_fraction(&self) -> f64 {
        let b = self.energy();
        // Converter share: ADC conversions + the DAC part of the drivers.
        let t = &self.tech;
        let mut dac_part = 0.0;
        for l in 0..self.spec.num_layers() {
            let rows = self.spec.n_col(l);
            let cols = self.spec.widths[l + 1];
            let col_tiles = cols.div_ceil(t.tile);
            dac_part += (rows * col_tiles) as f64 * self.dac_energy_pj();
        }
        (b.readout + dac_part) / b.total()
    }

    /// Fraction of total area in DAC+ADC (the intro's "81%").
    pub fn converter_area_fraction(&self) -> f64 {
        let b = self.area();
        let t = &self.tech;
        let um2 = 1e-6;
        let mut conv = 0.0;
        for l in 0..self.spec.num_layers() {
            let rows = self.spec.n_col(l);
            let cols = self.spec.widths[l + 1];
            let row_tiles = rows.div_ceil(t.tile);
            let col_tiles = cols.div_ceil(t.tile);
            let phys_cols = (col_tiles * t.tile * row_tiles) as f64;
            let phys_rows = (row_tiles * t.tile * col_tiles) as f64;
            conv += phys_cols / 8.0 * self.adc_area_um2() * um2;
            conv += phys_rows * self.dac_area_um2() * um2;
        }
        conv / b.total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converters_dominate_conventional_design() {
        // The paper's premise (§I): DAC/ADC ≈ 72% energy, ≈ 81% area in
        // conventional multi-bit CiM.  Accept a generous modeling band.
        let c = ConventionalCim::paper();
        let ef = c.converter_energy_fraction();
        let af = c.converter_area_fraction();
        assert!((0.55..=0.90).contains(&ef), "converter energy fraction {ef}");
        assert!((0.60..=0.92).contains(&af), "converter area fraction {af}");
    }

    #[test]
    fn conventional_costs_exceed_one_bit_baseline() {
        use super::super::system::{Architecture, SystemModel};
        let conv = ConventionalCim::paper();
        let m = SystemModel::paper();
        assert!(conv.energy().total() > m.energy(Architecture::OneBitAdc).total());
        assert!(conv.area().total() > m.area(Architecture::OneBitAdc).total());
    }

    #[test]
    fn adc_scaling_monotone_in_bits() {
        let mut c = ConventionalCim::paper();
        let e8 = c.adc_energy_pj();
        c.adc_bits = 4;
        let e4 = c.adc_energy_pj();
        assert!(e8 > e4);
        assert!(c.adc_area_um2() < ConventionalCim::paper().adc_area_um2());
    }

    #[test]
    fn breakdown_positive() {
        let c = ConventionalCim::paper();
        let e = c.energy();
        let a = c.area();
        for v in [e.array, e.readout, e.drivers, e.digital, e.buffers, e.interconnect] {
            assert!(v > 0.0);
        }
        assert!(a.total() > 0.0);
    }
}
