//! NeuroSim-style hardware cost model (DESIGN.md §4.10, paper Table I).
//!
//! Per-component energy/area/latency at a 32 nm corner, composed over the
//! FCNN workload for two architectures:
//!
//! * **OneBitAdc** — the conventional SBNN readout: per-column 1-bit SAR
//!   ADC (sample/hold + reference + latch), explicit digital activation
//!   (LFSR RNG + comparator) and full-swing reads;
//! * **Raca** — the paper's design: bare comparator on the bitline,
//!   activation *is* the comparator, reads at the calibrated noise-level
//!   voltage, no RNG (intrinsic thermal noise).
//!
//! Component constants come from the CiM literature (ISAAC/PRIME/NeuroSim
//! reports scaled to 32 nm) and are documented per-item in
//! [`params::TechParams`].  Absolute numbers carry the usual factor-2
//! modeling uncertainty; the Table I *structure* (what is removed and what
//! that does to energy/area/efficiency) is the reproduced result.

pub mod conventional;
pub mod params;
pub mod system;
pub mod table1;

pub use conventional::ConventionalCim;
pub use params::TechParams;
pub use system::{Architecture, Breakdown, SystemModel};
