//! System-level aggregation: component counts × unit costs per inference.
//!
//! Workload: one stochastic inference trial of the FCNN (the paper's
//! Table I unit).  Counting rules:
//!
//! * layer `l` has `ceil(n_col/tile) × ceil(n_out/tile)` physical tiles;
//!   a logical column-read touches every row-tile stacked on it;
//! * layer 0 is bit-serial over `input_cycles` (8-bit DAC input, both
//!   designs); hidden activations are 1-bit (single cycle);
//! * the baseline converts every logical column-read with a 1-bit ADC and
//!   runs the RNG+comparator activation in digital; partial sums across
//!   row tiles recombine digitally (accumulator per column-read);
//! * RACA senses each logical column with TIA+comparator (analog partial
//!   sums — wired column, no digital recombination) and spends
//!   `wta_steps` comparator decisions per output column;
//! * both move activations between layers through buffers + H-tree.

use crate::nn::ModelSpec;

use super::params::TechParams;

/// Which readout architecture to cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Architecture {
    /// Conventional SBNN with per-column 1-bit ADC readout.
    OneBitAdc,
    /// The paper's comparator-only, noise-activated design.
    Raca,
}

/// Per-category totals (energy in pJ, area in mm²).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Breakdown {
    pub array: f64,
    pub readout: f64,  // ADC or TIA+comparator
    pub drivers: f64,  // wordline drivers + input DACs
    pub digital: f64,  // RNG/activation, accumulators, WTA, counters
    pub buffers: f64,
    pub interconnect: f64,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.array + self.readout + self.drivers + self.digital + self.buffers + self.interconnect
    }
}

/// The cost model over a network + technology corner.
#[derive(Debug, Clone)]
pub struct SystemModel {
    pub spec: ModelSpec,
    pub tech: TechParams,
}

struct LayerGeom {
    rows: usize,      // logical rows incl. bias
    cols: usize,      // logical output columns
    row_tiles: usize, // stacked tiles per logical column
    col_tiles: usize,
    cycles: usize, // read cycles (bit-serial input or 1)
}

impl SystemModel {
    pub fn new(spec: ModelSpec, tech: TechParams) -> Self {
        Self { spec, tech }
    }

    pub fn paper() -> Self {
        Self::new(ModelSpec::paper(), TechParams::default())
    }

    fn geom(&self, l: usize) -> LayerGeom {
        let rows = self.spec.n_col(l);
        let cols = self.spec.widths[l + 1];
        LayerGeom {
            rows,
            cols,
            row_tiles: rows.div_ceil(self.tech.tile),
            col_tiles: cols.div_ceil(self.tech.tile),
            cycles: if l == 0 { self.tech.input_cycles } else { 1 },
        }
    }

    /// Total physical tiles.
    pub fn num_tiles(&self) -> usize {
        (0..self.spec.num_layers())
            .map(|l| {
                let g = self.geom(l);
                g.row_tiles * g.col_tiles
            })
            .sum()
    }

    // ---------------------------------------------------------------------
    // Energy per inference trial [pJ]
    // ---------------------------------------------------------------------
    pub fn energy(&self, arch: Architecture) -> Breakdown {
        let t = &self.tech;
        let mut b = Breakdown::default();
        let v_read = match arch {
            Architecture::OneBitAdc => t.v_read_conv,
            Architecture::Raca => t.v_read_raca,
        };
        let n_layers = self.spec.num_layers();
        for l in 0..n_layers {
            let g = self.geom(l);
            let last = l == n_layers - 1;
            // Column-read events: every logical column, every cycle.  The
            // RACA output layer re-reads each output column per WTA step.
            let col_reads = if last && arch == Architecture::Raca {
                g.cols * t.wta_steps
            } else {
                g.cols * g.cycles
            };
            // Devices energized per column-read: all stacked rows (+ref).
            let dev_per_col = 2 * g.rows; // column + reference column
            b.array += col_reads as f64 * dev_per_col as f64 * t.device_read_energy_pj(v_read);

            // Drivers: every row of every row-tile switches per cycle; the
            // input layer additionally pays the 8-bit DAC per row.
            let row_events = g.rows
                * g.col_tiles
                * if last && arch == Architecture::Raca { t.wta_steps } else { g.cycles };
            b.drivers += row_events as f64 * t.driver_energy_pj;
            if l == 0 {
                b.drivers += (g.rows * g.col_tiles) as f64 * t.dac8_energy_pj;
            }

            match arch {
                Architecture::OneBitAdc => {
                    // Per-column-read: TIA + 1-bit ADC conversion, then the
                    // digital partial-sum accumulate across row tiles and
                    // the RNG+comparator stochastic activation per logical
                    // column (once per cycle-aggregated result).
                    let conversions = (g.cols * g.cycles * g.row_tiles) as f64;
                    b.readout += conversions * (t.adc1_energy_pj + t.tia_energy_pj);
                    b.digital += conversions * t.accum_energy_pj;
                    b.digital += (g.cols) as f64 * t.rng_energy_pj;
                    if last {
                        b.digital += g.cols as f64 * t.counter_energy_pj;
                    }
                }
                Architecture::Raca => {
                    // Analog partial sums: one TIA+comparator per logical
                    // column-read, regardless of row tiling.
                    b.readout += col_reads as f64 * (t.comparator_energy_pj + t.tia_energy_pj);
                    if last {
                        b.digital += col_reads as f64 * t.wta_energy_pj;
                        b.digital += g.cols as f64 * t.counter_energy_pj;
                    }
                }
            }

            // Buffers + H-tree: activations in (rows·bits_in) and out.
            let bits_in = (g.rows * if l == 0 { 8 } else { 1 }) as f64;
            let bits_out = g.cols as f64 * if last { 4.0 } else { 1.0 };
            b.buffers += (bits_in + bits_out) * t.buffer_energy_pj_per_bit * g.col_tiles as f64;
            b.interconnect +=
                (bits_in + bits_out) * t.htree_energy_pj_per_bit_mm * t.htree_dist_mm;
        }
        // Chip-level control/sequencing/static energy (identical in both
        // designs — NeuroSim's "other" bucket).
        b.digital += t.control_energy_pj;
        b
    }

    /// Energy per *classification* [pJ]: per-trial energy × the majority
    /// vote's trial count (the paper's Table I unit).
    pub fn energy_per_classification(&self, arch: Architecture) -> f64 {
        self.energy(arch).total() * self.tech.trials_per_classification as f64
    }

    // ---------------------------------------------------------------------
    // Area [mm²]
    // ---------------------------------------------------------------------
    pub fn area(&self, arch: Architecture) -> Breakdown {
        let t = &self.tech;
        let um2_to_mm2 = 1e-6;
        let mut b = Breakdown::default();
        let mut logical_cols_total = 0usize;
        for l in 0..self.spec.num_layers() {
            let g = self.geom(l);
            let tiles = (g.row_tiles * g.col_tiles) as f64;
            let cells = tiles * (t.tile * t.tile) as f64;
            b.array += cells * t.cell_area_um2() * um2_to_mm2;

            // Physical columns carry the readout periphery per tile column.
            let phys_cols = (g.col_tiles * t.tile * g.row_tiles) as f64;
            logical_cols_total += g.cols;
            match arch {
                Architecture::OneBitAdc => {
                    b.readout += phys_cols
                        * (t.adc1_area_um2 + t.tia_area_um2 + t.colmux_area_um2)
                        * um2_to_mm2;
                    b.digital += phys_cols * t.accum_area_um2 * um2_to_mm2;
                    b.digital += g.cols as f64 * t.rng_area_um2 * um2_to_mm2;
                }
                Architecture::Raca => {
                    b.readout += phys_cols
                        * (t.comparator_area_um2 + t.tia_area_um2 + t.colmux_area_um2)
                        * um2_to_mm2;
                    b.digital += g.cols as f64 * t.wta_area_um2 * um2_to_mm2;
                }
            }
            // Drivers per physical row; DACs on layer 0 rows.
            let phys_rows = (g.row_tiles * t.tile * g.col_tiles) as f64;
            b.drivers += phys_rows * t.driver_area_um2 * um2_to_mm2;
            if l == 0 {
                b.drivers += g.rows as f64 * t.dac8_area_um2 * um2_to_mm2;
            }
        }
        // Output counters (both designs tally votes/classes).
        b.digital += logical_cols_total as f64 * 0.0; // per-layer handled above
        b.digital += self.spec.output_dim() as f64 * t.counter_area_um2 * um2_to_mm2;
        // Chip-level control / IO / test overhead (identical in both).
        b.digital += t.global_overhead_mm2;
        // Activation/weight staging buffer.
        b.buffers += t.buffer_kb * t.buffer_area_um2_per_kb * um2_to_mm2;
        // H-tree wiring overhead as a fraction of everything else.
        let partial = b.total();
        b.interconnect += partial * t.htree_area_frac;
        b
    }

    /// Latency per trial [ns] — dominated by sequential layer reads.
    pub fn latency_ns(&self, arch: Architecture) -> f64 {
        let t = &self.tech;
        let mut ns = 0.0;
        let n_layers = self.spec.num_layers();
        for l in 0..n_layers {
            let g = self.geom(l);
            let last = l == n_layers - 1;
            let cycles = if last && arch == Architecture::Raca {
                t.wta_steps
            } else {
                g.cycles
            };
            // One analog read + readout per cycle; ADC conversion costs an
            // extra cycle in the baseline.
            let per_cycle = match arch {
                Architecture::OneBitAdc => 2.0 * t.t_read * 1e9,
                Architecture::Raca => t.t_read * 1e9,
            };
            ns += cycles as f64 * per_cycle;
        }
        ns
    }

    /// Energy efficiency [TOPS/W]: (2·MACs per trial) / (energy per trial).
    pub fn tops_per_watt(&self, arch: Architecture) -> f64 {
        let ops = 2.0 * self.spec.macs_per_inference() as f64;
        let joules = self.energy(arch).total() * 1e-12;
        ops / joules / 1e12
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_count_matches_hand_calc() {
        let m = SystemModel::paper();
        // L0: ceil(785/128)·ceil(500/128)=7·4=28; L1: 4·3=12; L2: 3·1=3.
        assert_eq!(m.num_tiles(), 28 + 12 + 3);
    }

    #[test]
    fn raca_beats_baseline_on_everything() {
        let m = SystemModel::paper();
        let eb = m.energy(Architecture::OneBitAdc).total();
        let er = m.energy(Architecture::Raca).total();
        let ab = m.area(Architecture::OneBitAdc).total();
        let ar = m.area(Architecture::Raca).total();
        assert!(er < eb, "energy: raca {er} vs adc {eb}");
        assert!(ar < ab, "area: raca {ar} vs adc {ab}");
        assert!(m.tops_per_watt(Architecture::Raca) > m.tops_per_watt(Architecture::OneBitAdc));
    }

    #[test]
    fn readout_dominates_baseline_energy() {
        // The premise of the paper (72% of energy in DAC/ADC): the ADC
        // readout must be the largest baseline category.
        let m = SystemModel::paper();
        let b = m.energy(Architecture::OneBitAdc);
        assert!(b.readout > b.array);
        assert!(b.readout > b.buffers + b.interconnect);
        assert!(b.readout / b.total() > 0.5);
    }

    #[test]
    fn energy_breakdown_positive_and_consistent() {
        let m = SystemModel::paper();
        for arch in [Architecture::OneBitAdc, Architecture::Raca] {
            let b = m.energy(arch);
            for v in [b.array, b.readout, b.drivers, b.digital, b.buffers, b.interconnect] {
                assert!(v >= 0.0 && v.is_finite());
            }
            assert!(b.total() > 0.0);
        }
    }

    #[test]
    fn bigger_tiles_fewer_tiles() {
        let mut m = SystemModel::paper();
        let n128 = m.num_tiles();
        m.tech.tile = 256;
        assert!(m.num_tiles() < n128);
    }

    #[test]
    fn latency_raca_not_worse_per_hidden_cycle() {
        let m = SystemModel::paper();
        // RACA spends WTA steps at the output but no ADC cycle anywhere.
        let lb = m.latency_ns(Architecture::OneBitAdc);
        let lr = m.latency_ns(Architecture::Raca);
        assert!(lb > 0.0 && lr > 0.0);
    }
}
