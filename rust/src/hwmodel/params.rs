//! Technology parameters @ 32 nm (component energy / area constants).
//!
//! Sources: NeuroSim device-to-system reports, ISAAC (ISCA'16), PRIME
//! (ISCA'16) peripheral tables, SAR-ADC survey data (Murmann), scaled to
//! 32 nm.  Each constant documents what it covers.  The baseline column is
//! calibrated so its per-inference totals land near the paper's Table I
//! "1-bit ADC" column; the RACA column then follows from the structural
//! differences only (no per-column ADC/S&H, no RNG, low-voltage reads).

/// All tunable technology/circuit constants.
#[derive(Debug, Clone)]
pub struct TechParams {
    // ---- array ------------------------------------------------------------
    /// Crossbar tile geometry (rows = cols).
    pub tile: usize,
    /// Feature size [m] (32 nm).
    pub feature: f64,
    /// Cell area in F² (1T1R).
    pub cell_f2: f64,
    /// Mean device conductance during reads [S] (≈ Gref).
    pub g_mean: f64,
    /// Read pulse width [s].
    pub t_read: f64,
    /// Conventional (full-swing) read voltage [V] — baseline arrays.
    pub v_read_conv: f64,
    /// RACA read voltage [V] used in the Table I comparison.  Defaults to
    /// the conventional voltage (the NeuroSim-comparable corner — Table I
    /// in the paper shows only a 2.4× energy gain, which is inconsistent
    /// with also cutting array read power 100×, so their comparison holds
    /// the array corner fixed).  The additional low-Vr benefit the paper
    /// *mentions* is reported separately via
    /// [`TechParams::with_calibrated_vr`] (E-ABL4).
    pub v_read_raca: f64,
    /// Noise-calibrated Vr [V] (DESIGN.md §6; tens of mV at 1 GHz).
    pub v_read_raca_calibrated: f64,

    // ---- per-column periphery ---------------------------------------------
    /// 1-bit SAR ADC (sense amp + S/H + reference ladder + latch):
    /// energy per conversion [pJ] and layout area [µm²].
    pub adc1_energy_pj: f64,
    pub adc1_area_um2: f64,
    /// Bare latched comparator: energy per decision [pJ], area [µm²].
    pub comparator_energy_pj: f64,
    pub comparator_area_um2: f64,
    /// TIA + subtractor pair feeding the comparator (RACA keeps this in
    /// both designs — the ADC baseline also needs current-to-voltage).
    pub tia_energy_pj: f64,
    pub tia_area_um2: f64,
    /// Column mux share per logical column (8:1 mux amortized).
    pub colmux_area_um2: f64,

    // ---- per-row periphery -------------------------------------------------
    /// Wordline driver: energy per row per cycle [pJ], area [µm²].
    pub driver_energy_pj: f64,
    pub driver_area_um2: f64,
    /// 8-bit input DAC (layer 0 only, both designs): energy/convert [pJ],
    /// area [µm²] per row.
    pub dac8_energy_pj: f64,
    pub dac8_area_um2: f64,

    // ---- digital -----------------------------------------------------------
    /// Activation logic of the baseline: LFSR RNG + digital comparator per
    /// column decision [pJ]; area per column [µm²].
    pub rng_energy_pj: f64,
    pub rng_area_um2: f64,
    /// WTA adaptive-threshold block (RACA output layer): per-step energy
    /// [pJ] per column, area per column [µm²].
    pub wta_energy_pj: f64,
    pub wta_area_um2: f64,
    /// Vote counter per class: energy per increment [pJ], area [µm²].
    pub counter_energy_pj: f64,
    pub counter_area_um2: f64,
    /// Partial-sum accumulation / shift-add per column-read [pJ]
    /// (baseline digital recombination across row tiles).
    pub accum_energy_pj: f64,
    pub accum_area_um2: f64,

    // ---- memory & interconnect ----------------------------------------------
    /// Activation buffer access per bit [pJ] and per-bit area [µm²].
    pub buffer_energy_pj_per_bit: f64,
    pub buffer_area_um2_per_kb: f64,
    /// H-tree interconnect energy per bit·mm [pJ] and wiring overhead
    /// fraction of total area.
    pub htree_energy_pj_per_bit_mm: f64,
    pub htree_area_frac: f64,
    /// Mean on-chip transfer distance [mm].
    pub htree_dist_mm: f64,

    // ---- chip-level ----------------------------------------------------------
    /// Control/sequencing/static energy per trial [pJ] (clocking, FSMs,
    /// IO — identical in both designs; NeuroSim's "other" bucket).
    pub control_energy_pj: f64,
    /// Global non-compute area [mm²] (control, IO ring, PLL, test).
    pub global_overhead_mm2: f64,
    /// Activation/weight staging buffer capacity [KB].
    pub buffer_kb: f64,

    // ---- input encoding -----------------------------------------------------
    /// Bit-serial cycles for the 8-bit input layer (both designs keep the
    /// input DAC; hidden layers are 1-bit binary in both).
    pub input_cycles: usize,
    /// WTA time steps per decision (RACA output layer).
    pub wta_steps: usize,
    /// Stochastic trials per classification (majority vote; Fig. 6 shows
    /// accuracy saturating around this count) — scales the per-inference
    /// energy Table I reports.
    pub trials_per_classification: usize,
}

impl Default for TechParams {
    fn default() -> Self {
        Self {
            tile: 128,
            feature: 32e-9,
            cell_f2: 12.0, // 1T1R
            g_mean: 5.05e-5,
            t_read: 1e-9,
            v_read_conv: 0.20,
            v_read_raca: 0.20,
            v_read_raca_calibrated: 0.02,

            adc1_energy_pj: 1.05,
            adc1_area_um2: 530.0,
            comparator_energy_pj: 0.045,
            comparator_area_um2: 45.0,
            tia_energy_pj: 0.09,
            tia_area_um2: 55.0,
            colmux_area_um2: 25.0,

            driver_energy_pj: 0.012,
            driver_area_um2: 18.0,
            dac8_energy_pj: 0.12,
            dac8_area_um2: 160.0,

            rng_energy_pj: 0.35,
            rng_area_um2: 210.0,
            wta_energy_pj: 0.02,
            wta_area_um2: 60.0,
            counter_energy_pj: 0.003,
            counter_area_um2: 35.0,
            accum_energy_pj: 0.06,
            accum_area_um2: 85.0,

            buffer_energy_pj_per_bit: 0.0045,
            buffer_area_um2_per_kb: 1450.0,
            htree_energy_pj_per_bit_mm: 0.06,
            htree_area_frac: 0.12,
            htree_dist_mm: 1.4,

            control_energy_pj: 4200.0,
            global_overhead_mm2: 3.1,
            buffer_kb: 256.0,

            input_cycles: 8,
            wta_steps: 64,
            trials_per_classification: 16,
        }
    }
}

impl TechParams {
    /// The low-read-voltage RACA corner (E-ABL4): Vr at the calibrated
    /// noise level instead of the conventional swing.
    pub fn with_calibrated_vr(mut self) -> Self {
        self.v_read_raca = self.v_read_raca_calibrated;
        self
    }
}

impl TechParams {
    /// Crossbar cell area [µm²].
    pub fn cell_area_um2(&self) -> f64 {
        self.cell_f2 * (self.feature * 1e6).powi(2)
    }

    /// Array read energy per device per cycle [pJ]: V²·G·t.
    pub fn device_read_energy_pj(&self, v_read: f64) -> f64 {
        v_read * v_read * self.g_mean * self.t_read * 1e12
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_area_is_f2_scaled() {
        let p = TechParams::default();
        // 12 F² at 32 nm = 12 · (0.032 µm)² ≈ 0.0123 µm².
        assert!((p.cell_area_um2() - 12.0 * 0.032 * 0.032).abs() < 1e-9);
    }

    #[test]
    fn read_energy_scales_with_v_squared() {
        let p = TechParams::default();
        let e1 = p.device_read_energy_pj(0.1);
        let e2 = p.device_read_energy_pj(0.2);
        assert!((e2 / e1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn calibrated_vr_corner_is_much_lower() {
        let p = TechParams::default().with_calibrated_vr();
        assert!(p.v_read_raca < 0.25 * p.v_read_conv);
        // Array read energy drops quadratically at the calibrated corner.
        let conv = p.device_read_energy_pj(p.v_read_conv);
        let raca = p.device_read_energy_pj(p.v_read_raca);
        assert!(raca < conv / 50.0);
    }

    #[test]
    fn adc_dominates_comparator() {
        // The paper's premise: the ADC is the expensive part.
        let p = TechParams::default();
        assert!(p.adc1_energy_pj > 10.0 * p.comparator_energy_pj);
        assert!(p.adc1_area_um2 > 10.0 * p.comparator_area_um2);
    }
}
