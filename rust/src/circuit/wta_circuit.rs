//! WTA adaptive-threshold circuit — time-stepped transient model.
//!
//! Reproduces the paper's Fig. 5 behaviour (§III-B): the C output neurons'
//! voltages (static value + fresh comparator noise each clock) race
//! against a shared adaptive threshold.  The threshold rests `V_th0`
//! above the static mean; the first neuron to cross fires, the threshold
//! is yanked to `V_dd` (suppressing everyone else — winner-takes-all),
//! holds for a refractory window, then relaxes back for the next decision.

use crate::stats::GaussianSource;

/// Transient-model parameters.
#[derive(Debug, Clone)]
pub struct WtaParams {
    /// Supply voltage the threshold is pulled to on a win [V].
    pub vdd: f64,
    /// Rest threshold offset above the static mean [V] (paper: 0.05 / 0).
    pub vth0: f64,
    /// RMS of the per-step voltage noise on each neuron [V].
    pub sigma_v: f64,
    /// Clock period [s] (trace x-axis only).
    pub dt: f64,
    /// Steps the threshold stays at V_dd after a win.
    pub refractory_steps: usize,
    /// Give-up horizon per decision.
    pub max_steps: usize,
}

impl Default for WtaParams {
    fn default() -> Self {
        Self {
            vdd: 1.0,
            vth0: 0.05,
            sigma_v: 0.05 / 3.0, // θ_norm = 3 at the calibrated point
            dt: 1e-9,
            refractory_steps: 8,
            max_steps: 64,
        }
    }
}

/// One recorded time step of the transient simulation.
#[derive(Debug, Clone)]
pub struct WtaStep {
    pub t: f64,
    /// Instantaneous (noisy) neuron voltages [V].
    pub v: Vec<f64>,
    /// Threshold voltage [V].
    pub vth: f64,
    /// Firing neuron index, if a decision completed at this step.
    pub winner: Option<usize>,
}

/// Full transient trace across one or more decisions (Fig. 5a/b/c).
#[derive(Debug, Clone, Default)]
pub struct WtaTrace {
    pub steps: Vec<WtaStep>,
    /// Winner of each completed decision (−1 = timed out).
    pub winners: Vec<i32>,
}

/// The adaptive-threshold WTA block.
#[derive(Debug, Clone)]
pub struct WtaCircuit {
    pub params: WtaParams,
}

impl WtaCircuit {
    pub fn new(params: WtaParams) -> Self {
        Self { params }
    }

    /// Rest threshold for static outputs `v_static`: mean + V_th0.
    pub fn rest_threshold(&self, v_static: &[f64]) -> f64 {
        let mean = v_static.iter().sum::<f64>() / v_static.len() as f64;
        mean + self.params.vth0
    }

    /// Run one decision; returns the winner (−1 on timeout) without
    /// recording a trace (hot path for the native engine).
    pub fn decide(&self, v_static: &[f64], gauss: &mut GaussianSource) -> i32 {
        let vth = self.rest_threshold(v_static);
        for _ in 0..self.params.max_steps {
            let mut best: Option<(usize, f64)> = None;
            for (j, &v) in v_static.iter().enumerate() {
                let inst = v + self.params.sigma_v * gauss.next();
                if inst > vth {
                    // Ties within a step break toward the largest voltage
                    // (matches the L1 kernel / jnp oracle exactly).
                    if best.map_or(true, |(_, bv)| inst > bv) {
                        best = Some((j, inst));
                    }
                }
            }
            if let Some((j, _)) = best {
                return j as i32;
            }
        }
        -1
    }

    /// Run `decisions` consecutive decisions, recording the full transient
    /// (threshold pull-up + refractory) for figure generation.
    pub fn run_trace(
        &self,
        v_static: &[f64],
        decisions: usize,
        gauss: &mut GaussianSource,
    ) -> WtaTrace {
        let p = &self.params;
        let rest = self.rest_threshold(v_static);
        let mut trace = WtaTrace::default();
        let mut t = 0.0;
        for _ in 0..decisions {
            let mut decided = false;
            for _ in 0..p.max_steps {
                let v: Vec<f64> =
                    v_static.iter().map(|&s| s + p.sigma_v * gauss.next()).collect();
                let mut winner: Option<usize> = None;
                let mut best = f64::NEG_INFINITY;
                for (j, &vi) in v.iter().enumerate() {
                    if vi > rest && vi > best {
                        best = vi;
                        winner = Some(j);
                    }
                }
                trace.steps.push(WtaStep { t, v, vth: rest, winner });
                t += p.dt;
                if let Some(w) = winner {
                    trace.winners.push(w as i32);
                    decided = true;
                    // Refractory: threshold at V_dd, nobody can fire.
                    for _ in 0..p.refractory_steps {
                        let v: Vec<f64> = v_static
                            .iter()
                            .map(|&s| s + p.sigma_v * gauss.next())
                            .collect();
                        trace.steps.push(WtaStep { t, v, vth: p.vdd, winner: None });
                        t += p.dt;
                    }
                    break;
                }
            }
            if !decided {
                trace.winners.push(-1);
            }
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> WtaParams {
        WtaParams { sigma_v: 0.02, vth0: 0.05, ..Default::default() }
    }

    #[test]
    fn dominant_neuron_wins() {
        let c = WtaCircuit::new(params());
        let mut g = GaussianSource::new(1);
        let mut v = vec![0.0; 10];
        v[4] = 0.5;
        for _ in 0..50 {
            assert_eq!(c.decide(&v, &mut g), 4);
        }
    }

    #[test]
    fn timeout_returns_minus_one() {
        let c = WtaCircuit::new(WtaParams { sigma_v: 1e-6, ..params() });
        let mut g = GaussianSource::new(2);
        let v = vec![0.0; 10]; // rest threshold 0.05 ≫ 6σ
        assert_eq!(c.decide(&v, &mut g), -1);
    }

    #[test]
    fn exactly_one_winner_per_decision() {
        let c = WtaCircuit::new(params());
        let mut g = GaussianSource::new(3);
        let v: Vec<f64> = (0..10).map(|i| 0.01 * i as f64).collect();
        let trace = c.run_trace(&v, 20, &mut g);
        assert_eq!(trace.winners.len(), 20);
        let fired = trace.steps.iter().filter(|s| s.winner.is_some()).count();
        let completed = trace.winners.iter().filter(|&&w| w >= 0).count();
        assert_eq!(fired, completed);
    }

    #[test]
    fn threshold_pulled_to_vdd_after_win() {
        let c = WtaCircuit::new(params());
        let mut g = GaussianSource::new(4);
        let mut v = vec![0.0; 4];
        v[0] = 0.5;
        let trace = c.run_trace(&v, 1, &mut g);
        let fire_idx = trace.steps.iter().position(|s| s.winner.is_some()).unwrap();
        assert!(trace.steps[fire_idx + 1].vth == c.params.vdd);
    }

    #[test]
    fn higher_vth0_slows_decisions() {
        let mut g = GaussianSource::new(5);
        let steps_for = |vth0: f64, g: &mut GaussianSource| {
            let c = WtaCircuit::new(WtaParams {
                vth0,
                sigma_v: 0.02,
                max_steps: 100_000,
                ..Default::default()
            });
            let v = vec![0.0; 10];
            let tr = c.run_trace(&v, 5, g);
            tr.steps.len()
        };
        // 0.02 V rest offset (1σ) decides much faster than 0.08 V (4σ).
        assert!(steps_for(0.08, &mut g) > 2 * steps_for(0.02, &mut g));
    }

    #[test]
    fn win_frequency_tracks_static_voltage() {
        let c = WtaCircuit::new(params());
        let mut g = GaussianSource::new(6);
        let v = vec![0.00, 0.02, 0.04];
        let mut counts = [0usize; 3];
        for _ in 0..2000 {
            let w = c.decide(&v, &mut g);
            if w >= 0 {
                counts[w as usize] += 1;
            }
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0], "{counts:?}");
    }
}
