//! Peripheral circuit layer (DESIGN.md §4.3).
//!
//! Behavioural models of everything around the crossbar: TIA, latched
//! comparator, subtraction stage, wordline driver (the only "DAC" RACA
//! keeps, at the input layer), the baseline n-bit SAR ADC (for the Table I
//! comparison architecture), and the WTA adaptive-threshold block whose
//! transient traces reproduce Fig. 5(a).

pub mod adc;
pub mod comparator;
pub mod dac;
pub mod tia;
pub mod wta_circuit;

pub use adc::SarAdc;
pub use comparator::Comparator;
pub use dac::WordlineDriver;
pub use tia::Tia;
pub use wta_circuit::{WtaCircuit, WtaParams, WtaTrace};
