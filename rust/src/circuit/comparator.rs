//! Latched voltage comparator — the *entire* activation circuit of a RACA
//! Sigmoid neuron (paper Fig. 2: comparator replaces ADC + digital logic).

use crate::stats::GaussianSource;

/// Clocked comparator with offset and input-referred noise.
#[derive(Debug, Clone)]
pub struct Comparator {
    /// Static input offset [V] (mismatch; trimmed to ~0 in the paper).
    pub offset: f64,
    /// Input-referred RMS noise of the comparator itself [V].  The paper's
    /// design *wants* noise, but it comes from the array; the comparator's
    /// own noise just adds (in quadrature) to the useful noise.
    pub input_noise_rms: f64,
    /// Hysteresis half-width [V] (0 = ideal latch).
    pub hysteresis: f64,
    /// Previous decision (for hysteresis).
    last: bool,
}

impl Comparator {
    pub fn ideal() -> Self {
        Self { offset: 0.0, input_noise_rms: 0.0, hysteresis: 0.0, last: false }
    }

    pub fn new(offset: f64, input_noise_rms: f64) -> Self {
        Self { offset, input_noise_rms, hysteresis: 0.0, last: false }
    }

    pub fn with_hysteresis(mut self, h: f64) -> Self {
        self.hysteresis = h;
        self
    }

    /// One clocked decision: is `v_plus > v_minus`?
    #[inline]
    pub fn decide(&mut self, v_plus: f64, v_minus: f64, gauss: &mut GaussianSource) -> bool {
        let mut d = v_plus - v_minus + self.offset;
        if self.input_noise_rms > 0.0 {
            d += gauss.next() * self.input_noise_rms;
        }
        if self.hysteresis > 0.0 {
            let th = if self.last { -self.hysteresis } else { self.hysteresis };
            self.last = d > th;
        } else {
            self.last = d > 0.0;
        }
        self.last
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_threshold() {
        let mut c = Comparator::ideal();
        let mut g = GaussianSource::new(1);
        assert!(c.decide(0.1, 0.0, &mut g));
        assert!(!c.decide(-0.1, 0.0, &mut g));
        assert!(!c.decide(0.0, 0.0, &mut g)); // strict >
    }

    #[test]
    fn offset_biases_decision() {
        let mut c = Comparator::new(0.05, 0.0);
        let mut g = GaussianSource::new(1);
        assert!(c.decide(0.0, 0.0, &mut g)); // offset pushes it over
    }

    #[test]
    fn own_noise_randomizes_marginal_inputs() {
        let mut c = Comparator::new(0.0, 0.01);
        let mut g = GaussianSource::new(2);
        let fires = (0..10_000).filter(|_| c.decide(0.0, 0.0, &mut g)).count();
        let f = fires as f64 / 10_000.0;
        assert!((f - 0.5).abs() < 0.02, "f={f}");
    }

    #[test]
    fn hysteresis_sticks() {
        let mut c = Comparator::ideal().with_hysteresis(0.1);
        let mut g = GaussianSource::new(3);
        assert!(!c.decide(0.05, 0.0, &mut g)); // below +hys from low state
        assert!(c.decide(0.15, 0.0, &mut g)); // crosses
        assert!(c.decide(-0.05, 0.0, &mut g)); // stays high above −hys
        assert!(!c.decide(-0.15, 0.0, &mut g)); // releases
    }
}
