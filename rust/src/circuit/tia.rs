//! Trans-impedance amplifier: differential current → voltage.
//!
//! V_out = R_f·(I_col − I_ref), with optional input-referred offset and
//! saturation at the rails — the two non-idealities that matter for the
//! comparator decision statistics.

/// TIA + subtraction stage (paper Fig. 2: TIA pair feeding a subtractor).
#[derive(Debug, Clone)]
pub struct Tia {
    /// Feedback resistance [Ω].
    pub r_feedback: f64,
    /// Input-referred offset current [A] (mismatch).
    pub offset_current: f64,
    /// Supply rails [V]; output clamps to ±v_rail.
    pub v_rail: f64,
}

impl Tia {
    pub fn new(r_feedback: f64) -> Self {
        Self { r_feedback, offset_current: 0.0, v_rail: 1.0 }
    }

    pub fn with_offset(mut self, offset: f64) -> Self {
        self.offset_current = offset;
        self
    }

    pub fn with_rail(mut self, v_rail: f64) -> Self {
        self.v_rail = v_rail;
        self
    }

    /// Convert a differential current to the output voltage.
    #[inline]
    pub fn transfer(&self, i_diff: f64) -> f64 {
        ((i_diff + self.offset_current) * self.r_feedback).clamp(-self.v_rail, self.v_rail)
    }

    /// Largest |I_diff| before the output saturates.
    pub fn linear_range(&self) -> f64 {
        self.v_rail / self.r_feedback
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_gain() {
        let t = Tia::new(1e5);
        assert!((t.transfer(1e-6) - 0.1).abs() < 1e-12);
        assert!((t.transfer(-2e-6) + 0.2).abs() < 1e-12);
    }

    #[test]
    fn saturates_at_rails() {
        let t = Tia::new(1e6).with_rail(0.8);
        assert_eq!(t.transfer(1e-3), 0.8);
        assert_eq!(t.transfer(-1e-3), -0.8);
    }

    #[test]
    fn offset_shifts_zero() {
        let t = Tia::new(1e5).with_offset(1e-7);
        assert!((t.transfer(0.0) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn linear_range_consistent() {
        let t = Tia::new(2e5).with_rail(1.0);
        let i = t.linear_range();
        assert!((t.transfer(i * 0.999)).abs() < 1.0);
        assert_eq!(t.transfer(i * 1.5), 1.0);
    }
}
