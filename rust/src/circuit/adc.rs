//! SAR ADC model — the component RACA *removes*.
//!
//! Needed for the Table I baseline ("1-bit ADC" architecture) and for the
//! conventional-CiM ablations: an n-bit successive-approximation converter
//! with full-scale range, plus energy/area figures consumed by `hwmodel`.
//! A 1-bit SAR degenerates to a clocked comparator with sampling front-end
//! — which is why the paper's comparator-only readout is strictly cheaper.

/// n-bit SAR ADC over [−full_scale, +full_scale].
#[derive(Debug, Clone)]
pub struct SarAdc {
    pub bits: u32,
    pub full_scale: f64,
}

impl SarAdc {
    pub fn new(bits: u32, full_scale: f64) -> Self {
        assert!(bits >= 1 && bits <= 14);
        Self { bits, full_scale }
    }

    /// Convert a voltage to a signed code in [−2^(n−1), 2^(n−1)−1].
    ///
    /// Mid-rise quantizer (floor): the decision threshold between codes
    /// −1 and 0 sits exactly at 0 V, so the 1-bit case degenerates to a
    /// sign comparator — the component RACA keeps.
    #[inline]
    pub fn convert(&self, v: f64) -> i32 {
        let half = (1i64 << (self.bits - 1)) as f64;
        let code = (v / self.full_scale * half).floor();
        code.clamp(-half, half - 1.0) as i32
    }

    /// Reconstruct the analog value of a code (mid-rise: cell center).
    pub fn reconstruct(&self, code: i32) -> f64 {
        let half = (1i64 << (self.bits - 1)) as f64;
        (code as f64 + 0.5) / half * self.full_scale
    }

    /// LSB size in volts.
    pub fn lsb(&self) -> f64 {
        self.full_scale / (1i64 << (self.bits - 1)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_bit_is_sign() {
        let a = SarAdc::new(1, 1.0);
        assert_eq!(a.convert(0.3), 0); // codes {−1, 0}
        assert_eq!(a.convert(-0.3), -1);
        assert_eq!(a.convert(0.0), 0); // threshold exactly at 0 V
    }

    #[test]
    fn roundtrip_error_below_lsb() {
        let a = SarAdc::new(8, 1.0);
        for i in -100..100 {
            let v = i as f64 / 100.0 * 0.99;
            let err = (a.reconstruct(a.convert(v)) - v).abs();
            assert!(err <= a.lsb(), "v={v} err={err}");
        }
    }

    #[test]
    fn clamps_over_range() {
        let a = SarAdc::new(4, 1.0);
        assert_eq!(a.convert(10.0), 7);
        assert_eq!(a.convert(-10.0), -8);
    }

    #[test]
    fn monotonic() {
        let a = SarAdc::new(6, 2.0);
        let mut last = i32::MIN;
        for i in -200..200 {
            let c = a.convert(i as f64 / 100.0);
            assert!(c >= last);
            last = c;
        }
    }
}
