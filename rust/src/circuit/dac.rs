//! Wordline driver / input DAC.
//!
//! RACA keeps a DAC only at the *input* layer (paper §III-C) to preserve
//! input feature integrity; hidden layers receive binary activations that
//! need only a two-level driver.  The model quantizes a normalized input
//! in [0,1] to `bits` levels and scales by the read voltage Vr.

/// N-bit input driver: x ∈ [0,1] → quantized voltage in [0, Vr].
#[derive(Debug, Clone)]
pub struct WordlineDriver {
    pub bits: u32,
    pub v_read: f64,
}

impl WordlineDriver {
    pub fn new(bits: u32, v_read: f64) -> Self {
        assert!(bits >= 1 && bits <= 16);
        Self { bits, v_read }
    }

    /// Binary driver (hidden layers: activation is already 0/1).
    pub fn binary(v_read: f64) -> Self {
        Self { bits: 1, v_read }
    }

    /// Quantize-and-drive. Input is clamped to [0, 1].
    #[inline]
    pub fn drive(&self, x: f64) -> f64 {
        let x = x.clamp(0.0, 1.0);
        let levels = (1u32 << self.bits) - 1;
        let q = (x * levels as f64).round() / levels as f64;
        q * self.v_read
    }

    /// Quantization step in volts.
    pub fn lsb(&self) -> f64 {
        self.v_read / ((1u32 << self.bits) - 1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_driver_is_two_level() {
        let d = WordlineDriver::binary(0.2);
        assert_eq!(d.drive(0.0), 0.0);
        assert_eq!(d.drive(1.0), 0.2);
        assert_eq!(d.drive(0.6), 0.2);
        assert_eq!(d.drive(0.4), 0.0);
    }

    #[test]
    fn eight_bit_resolution() {
        let d = WordlineDriver::new(8, 1.0);
        assert!((d.drive(0.5) - 0.5).abs() < d.lsb());
        assert_eq!(d.drive(-1.0), 0.0);
        assert_eq!(d.drive(2.0), 1.0);
    }

    #[test]
    fn quantization_error_bounded() {
        let d = WordlineDriver::new(4, 1.0);
        for i in 0..100 {
            let x = i as f64 / 99.0;
            assert!((d.drive(x) - x).abs() <= 0.5 * d.lsb() + 1e-12);
        }
    }
}
