//! Native MLP trainer: SGD + backprop over the synthetic digit set.
//!
//! The build-time python pipeline (`python/compile/train.py`) produces the
//! paper-scale trained weights; this module is its small native twin so
//! artifact-free builds still get a *classifying* network — the fleet
//! subsystem, its tests and `raca fleet` train one on
//! [`crate::dataset::synth`] digits in a few seconds instead of requiring
//! `make artifacts`.
//!
//! The trained net transfers to the stochastic engines by construction:
//! hidden sigmoids are exactly what the stochastic binary neuron emulates
//! in expectation (firing frequency ≈ Φ(z/1.702) ≈ sigmoid(z), Fig. 4),
//! and weights are clipped to ±W_CLIP so they stay inside the
//! conductance-mappable range.

use crate::dataset::Dataset;
use crate::device::W_CLIP;
use crate::figures::common::parallel_map;
use crate::stats::Rng;

use super::forward::{affine_aug, sigmoid, softmax};
use super::model::ModelSpec;
use super::weights::Weights;

/// SGD hyper-parameters.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub epochs: usize,
    pub lr: f32,
    pub seed: u64,
    /// Samples per SGD step.  `1` reproduces the classic sequential loop
    /// bit-for-bit; larger values compute per-sample gradients in parallel
    /// ([`parallel_map`] over scoped threads) against the step's frozen
    /// weights and apply them in sample order — deterministic for a given
    /// seed, and the setup-dominating path for `raca serve --widths`.
    pub minibatch: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self { epochs: 10, lr: 0.2, seed: 0x7121, minibatch: 1 }
    }
}

/// He-style uniform init in ±sqrt(3/fan_in) (bias row zero).
fn init_mats(spec: &ModelSpec, rng: &mut Rng) -> Vec<Vec<f32>> {
    (0..spec.num_layers())
        .map(|l| {
            let (rows, cols) = spec.layer_shape(l);
            let bound = (3.0 / (rows - 1) as f64).sqrt();
            let mut m = vec![0.0f32; rows * cols];
            for r in 0..rows - 1 {
                for c in 0..cols {
                    m[r * cols + c] = (rng.range_f64(-bound, bound)) as f32;
                }
            }
            m
        })
        .collect()
}

/// Train an MLP (sigmoid hiddens, softmax output, cross-entropy loss) on
/// `ds` and return paper-format [`Weights`] with `ideal_test_accuracy` set
/// to the final training accuracy.
pub fn train(ds: &Dataset, spec: ModelSpec, cfg: &TrainConfig) -> Weights {
    assert!(!ds.is_empty(), "cannot train on an empty dataset");
    assert_eq!(spec.input_dim(), crate::dataset::loader::IMG_PIXELS);
    if cfg.minibatch > 1 {
        return train_minibatched(ds, spec, cfg);
    }
    let classes = spec.output_dim();
    let n_layers = spec.num_layers();
    let mut rng = Rng::new(cfg.seed);
    let mut mats = init_mats(&spec, &mut rng);

    // Per-layer activation / delta buffers (activations[0] = input copy).
    let mut activations: Vec<Vec<f32>> =
        spec.widths.iter().map(|&w| vec![0.0f32; w]).collect();
    let mut deltas: Vec<Vec<f32>> =
        spec.widths[1..].iter().map(|&w| vec![0.0f32; w]).collect();

    let mut order: Vec<usize> = (0..ds.len()).collect();
    for _epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        for &i in &order {
            activations[0].copy_from_slice(ds.image(i));
            // Forward.
            for l in 0..n_layers {
                let (rows, cols, _) = layer_shape_of(&spec, &mats, l);
                let (head, tail) = activations.split_at_mut(l + 1);
                affine_aug(&head[l], rows, cols, &mats[l], &mut tail[0]);
                if l + 1 < n_layers {
                    for v in tail[0].iter_mut() {
                        *v = sigmoid(*v);
                    }
                }
            }
            softmax(&mut activations[n_layers]);
            // Output delta: p − onehot(label).
            let label = ds.label(i) as usize;
            for (j, d) in deltas[n_layers - 1].iter_mut().enumerate() {
                *d = activations[n_layers][j] - if j == label { 1.0 } else { 0.0 };
            }
            debug_assert_eq!(deltas[n_layers - 1].len(), classes);
            // Backward + update.
            for l in (0..n_layers).rev() {
                let (rows, cols, _) = layer_shape_of(&spec, &mats, l);
                // Hidden delta for layer l-1 inputs (before overwriting W_l).
                if l > 0 {
                    let (dl, dprev) = {
                        let (a, b) = deltas.split_at_mut(l);
                        (&b[0], &mut a[l - 1])
                    };
                    let w = &mats[l];
                    let act = &activations[l];
                    for i_in in 0..rows - 1 {
                        let mut s = 0.0f32;
                        let row = &w[i_in * cols..(i_in + 1) * cols];
                        for (wv, d) in row.iter().zip(dl.iter()) {
                            s += wv * d;
                        }
                        dprev[i_in] = s * act[i_in] * (1.0 - act[i_in]);
                    }
                }
                // SGD update: W -= lr · a_aug ⊗ delta, clipped to ±W_CLIP.
                let w = &mut mats[l];
                let dl = &deltas[l];
                let act = &activations[l];
                let clip = W_CLIP as f32;
                for i_in in 0..rows {
                    let a = if i_in + 1 == rows { 1.0 } else { act[i_in] };
                    if a == 0.0 {
                        continue;
                    }
                    let row = &mut w[i_in * cols..(i_in + 1) * cols];
                    for (wv, d) in row.iter_mut().zip(dl.iter()) {
                        *wv = (*wv - cfg.lr * a * d).clamp(-clip, clip);
                    }
                }
            }
        }
    }

    let mut w = Weights { spec, mats, ideal_test_accuracy: -1.0 };
    w.ideal_test_accuracy = ideal_accuracy(&w, ds);
    w
}

/// Minibatched twin of the sequential loop: per-sample gradients of one
/// step are computed concurrently against the step's frozen weights
/// (classic data-parallel SGD), then applied in sample order with the same
/// per-sample learning rate and clip.  For the small minibatches used here
/// this tracks sequential SGD closely — the only difference is intra-step
/// gradient staleness — while the forward/backward passes (the wall-time
/// sink when `raca serve --widths` trains deep custom models) spread over
/// every core.
fn train_minibatched(ds: &Dataset, spec: ModelSpec, cfg: &TrainConfig) -> Weights {
    let n_layers = spec.num_layers();
    let clip = W_CLIP as f32;
    let mut rng = Rng::new(cfg.seed);
    let mut mats = init_mats(&spec, &mut rng);
    let mut order: Vec<usize> = (0..ds.len()).collect();
    for _epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        for chunk in order.chunks(cfg.minibatch) {
            let grads =
                parallel_map(chunk, |_, &i| sample_grad(&spec, &mats, ds.image(i), ds.label(i)));
            // In-order application keeps the result bit-deterministic for
            // a given seed regardless of worker scheduling.
            for g in &grads {
                for l in 0..n_layers {
                    for (wv, gv) in mats[l].iter_mut().zip(&g[l]) {
                        if *gv != 0.0 {
                            *wv = (*wv - cfg.lr * gv).clamp(-clip, clip);
                        }
                    }
                }
            }
        }
    }
    let mut w = Weights { spec, mats, ideal_test_accuracy: -1.0 };
    w.ideal_test_accuracy = ideal_accuracy(&w, ds);
    w
}

/// Forward + backward for one sample against frozen weights; returns the
/// per-layer gradient `a_aug ⊗ delta` (what the sequential loop applies
/// in place).
fn sample_grad(spec: &ModelSpec, mats: &[Vec<f32>], x: &[f32], label: i32) -> Vec<Vec<f32>> {
    let n_layers = spec.num_layers();
    let mut activations: Vec<Vec<f32>> =
        spec.widths.iter().map(|&w| vec![0.0f32; w]).collect();
    activations[0].copy_from_slice(x);
    for l in 0..n_layers {
        let (rows, cols) = spec.layer_shape(l);
        debug_assert_eq!(mats[l].len(), rows * cols);
        let (head, tail) = activations.split_at_mut(l + 1);
        affine_aug(&head[l], rows, cols, &mats[l], &mut tail[0]);
        if l + 1 < n_layers {
            for v in tail[0].iter_mut() {
                *v = sigmoid(*v);
            }
        }
    }
    softmax(&mut activations[n_layers]);
    let mut deltas: Vec<Vec<f32>> =
        spec.widths[1..].iter().map(|&w| vec![0.0f32; w]).collect();
    let label = label as usize;
    for (j, d) in deltas[n_layers - 1].iter_mut().enumerate() {
        *d = activations[n_layers][j] - if j == label { 1.0 } else { 0.0 };
    }
    let mut grads: Vec<Vec<f32>> = (0..n_layers)
        .map(|l| {
            let (rows, cols) = spec.layer_shape(l);
            vec![0.0f32; rows * cols]
        })
        .collect();
    for l in (0..n_layers).rev() {
        let (rows, cols) = spec.layer_shape(l);
        if l > 0 {
            let (dl, dprev) = {
                let (a, b) = deltas.split_at_mut(l);
                (&b[0], &mut a[l - 1])
            };
            let w = &mats[l];
            let act = &activations[l];
            for i_in in 0..rows - 1 {
                let mut s = 0.0f32;
                let row = &w[i_in * cols..(i_in + 1) * cols];
                for (wv, d) in row.iter().zip(dl.iter()) {
                    s += wv * d;
                }
                dprev[i_in] = s * act[i_in] * (1.0 - act[i_in]);
            }
        }
        let g = &mut grads[l];
        let dl = &deltas[l];
        let act = &activations[l];
        for i_in in 0..rows {
            let a = if i_in + 1 == rows { 1.0 } else { act[i_in] };
            if a == 0.0 {
                continue;
            }
            let row = &mut g[i_in * cols..(i_in + 1) * cols];
            for (gv, d) in row.iter_mut().zip(dl.iter()) {
                *gv = a * d;
            }
        }
    }
    grads
}

fn layer_shape_of(spec: &ModelSpec, mats: &[Vec<f32>], l: usize) -> (usize, usize, usize) {
    let (rows, cols) = spec.layer_shape(l);
    debug_assert_eq!(mats[l].len(), rows * cols);
    (rows, cols, rows * cols)
}

/// Ideal (float softmax) accuracy of `w` on `ds`.
pub fn ideal_accuracy(w: &Weights, ds: &Dataset) -> f64 {
    if ds.is_empty() {
        return 0.0;
    }
    let hits = (0..ds.len())
        .filter(|&i| {
            let p = super::forward::ideal_forward(w, ds.image(i));
            argmax(&p) == ds.label(i)
        })
        .count();
    hits as f64 / ds.len() as f64
}

fn argmax(p: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in p.iter().enumerate() {
        if v > p[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    #[test]
    fn training_beats_chance_and_weights_validate() {
        let ds = synth::generate(120, 11);
        let cfg = TrainConfig { epochs: 3, lr: 0.25, seed: 5, minibatch: 1 };
        let w = train(&ds, ModelSpec::new(vec![784, 12, 10]), &cfg);
        w.validate().expect("trained weights inside clip range");
        let acc = ideal_accuracy(&w, &ds);
        assert!(acc > 0.3, "3-epoch training accuracy too low: {acc}");
        assert!((w.ideal_test_accuracy - acc).abs() < 1e-9);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = synth::generate(40, 3);
        let cfg = TrainConfig { epochs: 1, lr: 0.2, seed: 9, minibatch: 1 };
        let a = train(&ds, ModelSpec::new(vec![784, 8, 10]), &cfg);
        let b = train(&ds, ModelSpec::new(vec![784, 8, 10]), &cfg);
        assert_eq!(a.mats, b.mats);
    }

    #[test]
    fn minibatched_training_is_deterministic_and_learns() {
        let ds = synth::generate(120, 11);
        let cfg = TrainConfig { epochs: 3, lr: 0.25, seed: 5, minibatch: 8 };
        let a = train(&ds, ModelSpec::new(vec![784, 12, 10]), &cfg);
        // Parallel gradient workers must not leak scheduling into the
        // result: same seed, same weights, run to run.
        let b = train(&ds, ModelSpec::new(vec![784, 12, 10]), &cfg);
        assert_eq!(a.mats, b.mats);
        a.validate().expect("trained weights inside clip range");
        assert!(
            a.ideal_test_accuracy > 0.3,
            "minibatched training accuracy too low: {}",
            a.ideal_test_accuracy
        );
    }

    #[test]
    fn minibatch_gate_actually_switches_paths() {
        // The default stays the classic sequential loop…
        assert_eq!(TrainConfig::default().minibatch, 1);
        // …and a minibatch > 1 must genuinely take the data-parallel path:
        // if the gate silently fell back to sequential, the intra-step
        // frozen-weight gradients could not produce different mats.
        let ds = synth::generate(40, 3);
        let seq = TrainConfig { epochs: 2, lr: 0.2, seed: 9, minibatch: 1 };
        let par = TrainConfig { minibatch: 8, ..seq.clone() };
        let a = train(&ds, ModelSpec::new(vec![784, 8, 10]), &seq);
        let b = train(&ds, ModelSpec::new(vec![784, 8, 10]), &par);
        assert_ne!(a.mats, b.mats, "minibatch: 8 must not be the sequential loop");
    }
}
