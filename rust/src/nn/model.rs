//! Model topology description (the paper's FCNN [784, 500, 300, 10]).

/// Fully-connected network specification.
///
/// Layer `l` maps `widths[l]` features to `widths[l+1]` through an
/// augmented weight matrix of shape `(widths[l] + 1, widths[l+1])` — the
/// `+1` is the bias row, realized on hardware as one extra crossbar row
/// driven by a constant-1 input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    pub widths: Vec<usize>,
}

impl ModelSpec {
    pub fn new(widths: Vec<usize>) -> Self {
        assert!(widths.len() >= 2, "need at least input and output widths");
        Self { widths }
    }

    /// The paper's evaluation network.
    pub fn paper() -> Self {
        Self::new(vec![784, 500, 300, 10])
    }

    pub fn num_layers(&self) -> usize {
        self.widths.len() - 1
    }

    pub fn input_dim(&self) -> usize {
        self.widths[0]
    }

    pub fn output_dim(&self) -> usize {
        *self.widths.last().unwrap()
    }

    /// Augmented weight-matrix shape of layer `l`: (fan_in + 1, fan_out).
    pub fn layer_shape(&self, l: usize) -> (usize, usize) {
        (self.widths[l] + 1, self.widths[l + 1])
    }

    /// Crossbar rows (devices per column) of layer `l` — the paper's N_col.
    pub fn n_col(&self, l: usize) -> usize {
        self.widths[l] + 1
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        (0..self.num_layers())
            .map(|l| {
                let (r, c) = self.layer_shape(l);
                r * c
            })
            .sum()
    }

    /// Total MAC operations for one inference (for TOPS accounting; one
    /// MAC = 2 ops by the usual convention).
    pub fn macs_per_inference(&self) -> usize {
        self.num_params()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_network() {
        let m = ModelSpec::paper();
        assert_eq!(m.num_layers(), 3);
        assert_eq!(m.layer_shape(0), (785, 500));
        assert_eq!(m.layer_shape(1), (501, 300));
        assert_eq!(m.layer_shape(2), (301, 10));
        assert_eq!(m.num_params(), 785 * 500 + 501 * 300 + 301 * 10);
        assert_eq!(m.n_col(2), 301);
    }

    #[test]
    #[should_panic]
    fn too_few_widths() {
        ModelSpec::new(vec![10]);
    }
}
