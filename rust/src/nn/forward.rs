//! Native forward passes over loaded weights.
//!
//! Three flavours:
//! * [`ideal_forward`]/[`ideal_logits`] — float sigmoid/softmax, the
//!   software reference the analog system emulates;
//! * [`stochastic_logits`] — the *normalized-unit* stochastic forward
//!   (binary hidden activations via z + σ_z·n > 0), statistically
//!   identical to the physical crossbar simulation at the calibrated
//!   point and to the L1/L2 HLO path (parity-tested in
//!   rust/tests/engine_parity.rs);
//! * [`stochastic_logits_block`] — the same forward for a whole *block*
//!   of trials at once (§Perf iteration 5): binary hidden vectors live
//!   bit-packed in a [`BitBlock`] and the matmul loop is inverted so each
//!   f32 weight row is read **once per block** and accumulated into the
//!   trials whose bit is set, with [`GaussianSource::fill`] batching the
//!   noise draws.  Each trial keeps its own noise stream consuming draws
//!   in the scalar order, so the blocked path is **bit-identical** to
//!   [`stochastic_logits_into`] per trial at equal streams
//!   (rust/tests/blocked.rs holds the whole matrix of widths × block
//!   sizes × tail shapes to that).
//!
//! §Perf iteration 5 (trial-blocked bit-packed kernel): the scalar hot
//! loop streamed the full f32 weight matrix per trial — the binary
//! structure the paper exploits in hardware was thrown away in software.
//! Blocking B trials per pass amortizes weight traffic B×; the per-trial
//! FLOP count is unchanged (the scalar path already skipped silent
//! neurons), so the win is pure memory-hierarchy behaviour plus branchless
//! mask iteration.
//!
//! §Perf iteration 6 (explicit SIMD): the blocked matmul's inner
//! column-add and the batched noise fill now run through the
//! runtime-dispatched kernels of [`crate::util::simd`] (AVX2/SSE2 on
//! x86_64, NEON on aarch64, unrolled scalar elsewhere or under
//! `RACA_NO_SIMD=1`).  The parity contract is preserved because every
//! kernel vectorizes across the **columns** dimension only — each output
//! element keeps its exact scalar accumulation order over weight rows,
//! and IEEE f32/f64 arithmetic is deterministic per element, so the
//! dispatched path stays bit-identical to the scalar reference (see the
//! `util::simd` module docs for the per-kernel argument, and
//! rust/tests/simd.rs for the pinning matrix).

use super::bitvec::BitBlock;
use super::weights::Weights;
use crate::stats::GaussianSource;

/// y[j] = Σ_i x_aug[i]·W[i,j] with the implicit bias row (x_aug = [x; 1]).
pub fn affine_aug(x: &[f32], rows: usize, cols: usize, w: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len() + 1, rows);
    debug_assert_eq!(out.len(), cols);
    // Bias row first (last row of W).
    let bias = &w[(rows - 1) * cols..rows * cols];
    out.copy_from_slice(bias);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue; // binary activations are sparse — skip zero rows
        }
        let row = &w[i * cols..(i + 1) * cols];
        if xi == 1.0 {
            for (o, &wv) in out.iter_mut().zip(row) {
                *o += wv;
            }
        } else {
            for (o, &wv) in out.iter_mut().zip(row) {
                *o += xi * wv;
            }
        }
    }
}

#[inline]
pub fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// In-place numerically-stable softmax.
pub fn softmax(z: &mut [f32]) {
    let m = z.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in z.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    for v in z.iter_mut() {
        *v /= sum;
    }
}

/// Ideal float logits: sigmoid hidden layers, raw output affine.
pub fn ideal_logits(w: &Weights, x: &[f32]) -> Vec<f32> {
    let mut h: Vec<f32> = x.to_vec();
    for l in 0..w.spec.num_layers() - 1 {
        let (rows, cols, m) = w.layer(l);
        let mut z = vec![0.0f32; cols];
        affine_aug(&h, rows, cols, m, &mut z);
        for v in z.iter_mut() {
            *v = sigmoid(*v);
        }
        h = z;
    }
    let l = w.spec.num_layers() - 1;
    let (rows, cols, m) = w.layer(l);
    let mut z = vec![0.0f32; cols];
    affine_aug(&h, rows, cols, m, &mut z);
    z
}

/// Ideal float class probabilities.
pub fn ideal_forward(w: &Weights, x: &[f32]) -> Vec<f32> {
    let mut z = ideal_logits(w, x);
    softmax(&mut z);
    z
}

/// One stochastic pass through the hidden layers (normalized units):
/// h = 1[z + σ_z·n > 0]; returns the output-layer logits.
pub fn stochastic_logits(
    w: &Weights,
    x: &[f32],
    sigma_z: f64,
    gauss: &mut GaussianSource,
) -> Vec<f32> {
    let z1 = layer0_preactivation(w, x);
    stochastic_logits_from_z1(w, &z1, sigma_z, gauss)
}

/// Deterministic layer-0 pre-activation z1 = [x;1]·W1.
///
/// Hot-path optimization (EXPERIMENTS.md §Perf iteration 1): the mean
/// column current of the first crossbar is *fixed per image* — only the
/// comparator noise resamples between trials.  Computing z1 once per
/// request removes the largest matmul (72% of the network's MACs) from
/// the per-trial path.
pub fn layer0_preactivation(w: &Weights, x: &[f32]) -> Vec<f32> {
    let (rows, cols, m) = w.layer(0);
    let mut z = vec![0.0f32; cols];
    affine_aug(x, rows, cols, m, &mut z);
    z
}

/// Reusable per-thread buffers for the stochastic forward (§Perf
/// iteration 3: a trial is ~20 µs — two Vec allocations per layer were
/// ~11% of the profile).
#[derive(Debug, Default, Clone)]
pub struct TrialScratch {
    h: Vec<f32>,
    z: Vec<f32>,
    /// Output logits (valid after `stochastic_logits_into`).
    pub logits: Vec<f32>,
    /// WTA centering buffer (`engine::wta_race_centered` reuses it so the
    /// per-trial race stays allocation-free).
    pub centered: Vec<f64>,
}

/// Stochastic pass given the precomputed layer-0 pre-activation.
pub fn stochastic_logits_from_z1(
    w: &Weights,
    z1_mean: &[f32],
    sigma_z: f64,
    gauss: &mut GaussianSource,
) -> Vec<f32> {
    let mut scratch = TrialScratch::default();
    stochastic_logits_into(w, z1_mean, sigma_z, gauss, &mut scratch);
    scratch.logits
}

/// Allocation-free variant over caller-owned scratch buffers.
pub fn stochastic_logits_into(
    w: &Weights,
    z1_mean: &[f32],
    sigma_z: f64,
    gauss: &mut GaussianSource,
    s: &mut TrialScratch,
) {
    // (§Perf iteration 4 — a 6σ saturation shortcut skipping the noise
    // draw for decided neurons — was tried and REVERTED: <1% measured
    // gain; saturated units beyond 6σ_z = 10.2 z-units are rare.)
    // Layer 0: binarize the cached mean with fresh noise.
    s.h.clear();
    s.h.extend(z1_mean.iter().map(|&z| {
        if (z as f64) + sigma_z * gauss.next() > 0.0 {
            1.0f32
        } else {
            0.0
        }
    }));
    // Remaining hidden layers depend on the stochastic h — full recompute.
    for l in 1..w.spec.num_layers() - 1 {
        let (rows, cols, m) = w.layer(l);
        s.z.resize(cols, 0.0);
        affine_aug(&s.h, rows, cols, m, &mut s.z);
        for v in s.z.iter_mut() {
            let fired = (*v as f64) + sigma_z * gauss.next() > 0.0;
            *v = if fired { 1.0 } else { 0.0 };
        }
        std::mem::swap(&mut s.h, &mut s.z);
    }
    let l = w.spec.num_layers() - 1;
    let (rows, cols, m) = w.layer(l);
    s.logits.resize(cols, 0.0);
    affine_aug(&s.h, rows, cols, m, &mut s.logits);
}

/// Default trials per blocked-kernel pass: one full `u64` lane, so every
/// neuron's trial mask is a single word in the hot loop.
pub const DEFAULT_TRIAL_BLOCK: usize = 64;

/// Reusable buffers of the trial-blocked bit-packed forward (§Perf
/// iteration 5).  One scratch serves any block size; buffers grow to the
/// largest block/layer seen and stay allocated.
#[derive(Debug, Default, Clone)]
pub struct BlockScratch {
    /// One noise stream per trial in the block.  The caller positions
    /// these (engine: `trial_rng(seed, idx)`; pipeline die: same plus the
    /// upstream `noise_skip`) before running the layer primitives.
    pub gauss: Vec<GaussianSource>,
    /// Bit-packed binary activations of the current layer.
    bits: BitBlock,
    /// Per-trial affine accumulators (`trials × cols`, trial-major).
    acc: Vec<f32>,
    /// Batched noise draws of one trial (`cols` f64s).
    noise: Vec<f64>,
    /// Output logits, `trials × output_dim` (valid after
    /// [`stochastic_logits_block`] / [`output_layer_block`]).
    pub logits: Vec<f32>,
}

impl BlockScratch {
    /// Trials in the current block (the noise streams define it).
    pub fn trials(&self) -> usize {
        self.gauss.len()
    }
}

/// Layer 0 of a block: binarize the *shared* cached pre-activation with
/// fresh per-trial noise.  Per trial this draws exactly what the scalar
/// path draws, in the same order — `σ_z·n` via the batched
/// [`GaussianSource::fill`], then the same f64 add/compare.
pub fn binarize_shared_block(z_mean: &[f32], sigma_z: f64, s: &mut BlockScratch) {
    let n = s.gauss.len();
    let cols = z_mean.len();
    s.bits.reset(n, cols);
    s.noise.resize(cols, 0.0);
    for t in 0..n {
        s.gauss[t].fill(&mut s.noise, sigma_z);
        for (j, (&z, &nz)) in z_mean.iter().zip(s.noise.iter()).enumerate() {
            if (z as f64) + nz > 0.0 {
                s.bits.set(t, j);
            }
        }
    }
}

/// Pack `n` binary activation rows (0.0/1.0 f32, trial-major — the
/// pipelined backend's die-to-die slab format) into the block's bits.
/// Draws no noise.
pub fn pack_rows_block(rows: &[f32], width: usize, n: usize, s: &mut BlockScratch) {
    debug_assert_eq!(rows.len(), n * width);
    s.bits.reset(n, width);
    for t in 0..n {
        for (j, &v) in rows[t * width..(t + 1) * width].iter().enumerate() {
            if v != 0.0 {
                s.bits.set(t, j);
            }
        }
    }
}

/// The inverted matmul: `out[t] = [h_t; 1]·W` for every trial of the
/// block, reading each f32 weight row once.  Per trial the additions
/// happen in ascending row order — exactly [`affine_aug`]'s order over a
/// binary `h` — so the accumulators are bit-identical f32s.
///
/// The inner column-add runs through the dispatched SIMD kernel
/// (`util::simd::active().add_assign_f32` — §Perf iteration 6).  Lanes
/// span *columns*, never rows: each `out[t*cols + j]` still receives its
/// additions one weight row at a time in ascending row order, so the
/// f32 accumulation sequence per output element is unchanged and the
/// blocked ≡ scalar bit-parity contract survives vectorization.
fn affine_bits_block(rows: usize, cols: usize, m: &[f32], bits: &BitBlock, out: &mut Vec<f32>) {
    let n = bits.trials();
    debug_assert_eq!(bits.neurons() + 1, rows);
    let k = crate::util::simd::active();
    out.clear();
    out.reserve(n * cols);
    let bias = &m[(rows - 1) * cols..rows * cols];
    for _ in 0..n {
        out.extend_from_slice(bias);
    }
    for i in 0..rows - 1 {
        let row = &m[i * cols..(i + 1) * cols];
        for (lane, &mask) in bits.neuron_masks(i).iter().enumerate() {
            let mut mk = mask;
            while mk != 0 {
                let t = (lane << 6) + mk.trailing_zeros() as usize;
                (k.add_assign_f32)(&mut out[t * cols..(t + 1) * cols], row);
                mk &= mk - 1;
            }
        }
    }
}

/// One hidden layer of a block: inverted affine over the packed bits,
/// then per-trial binarization with fresh batched noise.
pub fn hidden_layer_block(rows: usize, cols: usize, m: &[f32], sigma_z: f64, s: &mut BlockScratch) {
    let n = s.gauss.len();
    affine_bits_block(rows, cols, m, &s.bits, &mut s.acc);
    s.bits.reset(n, cols);
    s.noise.resize(cols, 0.0);
    for t in 0..n {
        s.gauss[t].fill(&mut s.noise, sigma_z);
        let z = &s.acc[t * cols..(t + 1) * cols];
        for (j, (&zj, &nz)) in z.iter().zip(s.noise.iter()).enumerate() {
            if (zj as f64) + nz > 0.0 {
                s.bits.set(t, j);
            }
        }
    }
}

/// The output layer of a block: inverted affine straight into
/// `s.logits` (`trials × cols`).  Draws no noise — the WTA race owns the
/// output-side draws.
pub fn output_layer_block(rows: usize, cols: usize, m: &[f32], s: &mut BlockScratch) {
    affine_bits_block(rows, cols, m, &s.bits, &mut s.logits);
}

/// Unpack the block's current binary activations to trial-major 0.0/1.0
/// rows (a pipeline die's outgoing slab).
pub fn unpack_block_rows(s: &BlockScratch, out: &mut Vec<f32>) {
    for t in 0..s.bits.trials() {
        s.bits.append_trial_row(t, out);
    }
}

/// Blocked stochastic forward from the cached layer-0 pre-activation:
/// the trial-block twin of [`stochastic_logits_into`].  Caller seeds
/// `s.gauss` (one positioned stream per trial); logits land in
/// `s.logits`, trial-major.
pub fn stochastic_logits_block(w: &Weights, z1_mean: &[f32], sigma_z: f64, s: &mut BlockScratch) {
    binarize_shared_block(z1_mean, sigma_z, s);
    for l in 1..w.spec.num_layers() - 1 {
        let (rows, cols, m) = w.layer(l);
        hidden_layer_block(rows, cols, m, sigma_z, s);
    }
    let l = w.spec.num_layers() - 1;
    let (rows, cols, m) = w.layer(l);
    output_layer_block(rows, cols, m, s);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::ModelSpec;

    fn tiny_weights() -> Weights {
        Weights::random(ModelSpec::new(vec![6, 5, 4, 3]), 7)
    }

    #[test]
    fn affine_matches_naive() {
        let w = tiny_weights();
        let (rows, cols, m) = w.layer(0);
        let x: Vec<f32> = (0..6).map(|i| i as f32 * 0.3).collect();
        let mut out = vec![0.0; cols];
        affine_aug(&x, rows, cols, m, &mut out);
        for j in 0..cols {
            let mut want = 0.0f32;
            for i in 0..rows - 1 {
                want += x[i] * m[i * cols + j];
            }
            want += m[(rows - 1) * cols + j]; // bias
            assert!((out[j] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_is_distribution() {
        let mut z = vec![1.0f32, 2.0, 3.0, 1000.0];
        softmax(&mut z);
        let s: f32 = z.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(z[3] > 0.99); // stability at large logits
    }

    #[test]
    fn ideal_forward_shapes_and_simplex() {
        let w = tiny_weights();
        let x = vec![0.5f32; 6];
        let p = ideal_forward(&w, &x);
        assert_eq!(p.len(), 3);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn stochastic_expectation_matches_sigmoid() {
        // One layer, one neuron: firing frequency ≈ Φ(z/σ_z) ≈ sigmoid(z).
        let spec = ModelSpec::new(vec![1, 1]);
        let mut w = Weights::random(spec, 1);
        w.mats[0] = vec![1.5, 0.0]; // weight 1.5, bias 0
        let mut g = GaussianSource::new(2);
        // Single-layer net: stochastic_logits has no hidden layer; use the
        // raw affine + manual binarization loop instead.
        let n = 40_000;
        let mut fired = 0;
        for _ in 0..n {
            let z = 1.5f64; // x = 1 → z = 1.5
            if z + 1.702 * g.next() > 0.0 {
                fired += 1;
            }
        }
        let p = fired as f64 / n as f64;
        let want = 1.0 / (1.0 + (-1.5f64).exp());
        assert!((p - want).abs() < 0.015, "p={p} want={want}");
    }

    #[test]
    fn stochastic_logits_binary_hiddens_affect_output_range() {
        let w = tiny_weights();
        let mut g = GaussianSource::new(3);
        let x = vec![0.5f32; 6];
        let z = stochastic_logits(&w, &x, 1.702, &mut g);
        assert_eq!(z.len(), 3);
        assert!(z.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn blocked_forward_is_bit_identical_per_trial() {
        // The §Perf iteration-5 contract at the forward level: every
        // trial of a block reproduces the scalar pass bit-for-bit and
        // leaves its noise stream at the same position.
        let w = tiny_weights();
        let x: Vec<f32> = (0..6).map(|i| i as f32 / 7.0).collect();
        let z1 = layer0_preactivation(&w, &x);
        let sigma = 1.702f64;
        let n = 7; // partial lane on purpose
        let mut s = BlockScratch::default();
        s.gauss = (0..n).map(|t| GaussianSource::new(100 + t as u64)).collect();
        stochastic_logits_block(&w, &z1, sigma, &mut s);
        for t in 0..n {
            let mut g = GaussianSource::new(100 + t as u64);
            let mut scratch = TrialScratch::default();
            stochastic_logits_into(&w, &z1, sigma, &mut g, &mut scratch);
            assert_eq!(&s.logits[t * 3..(t + 1) * 3], &scratch.logits[..], "trial {t}");
            assert_eq!(s.gauss[t].next(), g.next(), "stream {t} misaligned");
        }
    }

    #[test]
    fn pack_unpack_rows_roundtrip() {
        let rows: Vec<f32> = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 1.0];
        let mut s = BlockScratch::default();
        s.gauss = (0..3).map(|t| GaussianSource::new(t)).collect();
        pack_rows_block(&rows, 4, 3, &mut s);
        let mut out = Vec::new();
        unpack_block_rows(&s, &mut out);
        assert_eq!(out, rows);
    }

    #[test]
    fn zero_noise_stochastic_is_deterministic() {
        let w = tiny_weights();
        let mut g1 = GaussianSource::new(4);
        let mut g2 = GaussianSource::new(5);
        let x = vec![0.3f32; 6];
        let a = stochastic_logits(&w, &x, 0.0, &mut g1);
        let b = stochastic_logits(&w, &x, 0.0, &mut g2);
        assert_eq!(a, b);
    }
}
