//! Native forward passes over loaded weights.
//!
//! Two flavours:
//! * [`ideal_forward`]/[`ideal_logits`] — float sigmoid/softmax, the
//!   software reference the analog system emulates;
//! * [`stochastic_logits`] — the *normalized-unit* stochastic forward
//!   (binary hidden activations via z + σ_z·n > 0), statistically
//!   identical to the physical crossbar simulation at the calibrated
//!   point and to the L1/L2 HLO path (parity-tested in
//!   rust/tests/engine_parity.rs).

use super::weights::Weights;
use crate::stats::GaussianSource;

/// y[j] = Σ_i x_aug[i]·W[i,j] with the implicit bias row (x_aug = [x; 1]).
pub fn affine_aug(x: &[f32], rows: usize, cols: usize, w: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len() + 1, rows);
    debug_assert_eq!(out.len(), cols);
    // Bias row first (last row of W).
    let bias = &w[(rows - 1) * cols..rows * cols];
    out.copy_from_slice(bias);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue; // binary activations are sparse — skip zero rows
        }
        let row = &w[i * cols..(i + 1) * cols];
        if xi == 1.0 {
            for (o, &wv) in out.iter_mut().zip(row) {
                *o += wv;
            }
        } else {
            for (o, &wv) in out.iter_mut().zip(row) {
                *o += xi * wv;
            }
        }
    }
}

#[inline]
pub fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// In-place numerically-stable softmax.
pub fn softmax(z: &mut [f32]) {
    let m = z.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in z.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    for v in z.iter_mut() {
        *v /= sum;
    }
}

/// Ideal float logits: sigmoid hidden layers, raw output affine.
pub fn ideal_logits(w: &Weights, x: &[f32]) -> Vec<f32> {
    let mut h: Vec<f32> = x.to_vec();
    for l in 0..w.spec.num_layers() - 1 {
        let (rows, cols, m) = w.layer(l);
        let mut z = vec![0.0f32; cols];
        affine_aug(&h, rows, cols, m, &mut z);
        for v in z.iter_mut() {
            *v = sigmoid(*v);
        }
        h = z;
    }
    let l = w.spec.num_layers() - 1;
    let (rows, cols, m) = w.layer(l);
    let mut z = vec![0.0f32; cols];
    affine_aug(&h, rows, cols, m, &mut z);
    z
}

/// Ideal float class probabilities.
pub fn ideal_forward(w: &Weights, x: &[f32]) -> Vec<f32> {
    let mut z = ideal_logits(w, x);
    softmax(&mut z);
    z
}

/// One stochastic pass through the hidden layers (normalized units):
/// h = 1[z + σ_z·n > 0]; returns the output-layer logits.
pub fn stochastic_logits(
    w: &Weights,
    x: &[f32],
    sigma_z: f64,
    gauss: &mut GaussianSource,
) -> Vec<f32> {
    let z1 = layer0_preactivation(w, x);
    stochastic_logits_from_z1(w, &z1, sigma_z, gauss)
}

/// Deterministic layer-0 pre-activation z1 = [x;1]·W1.
///
/// Hot-path optimization (EXPERIMENTS.md §Perf iteration 1): the mean
/// column current of the first crossbar is *fixed per image* — only the
/// comparator noise resamples between trials.  Computing z1 once per
/// request removes the largest matmul (72% of the network's MACs) from
/// the per-trial path.
pub fn layer0_preactivation(w: &Weights, x: &[f32]) -> Vec<f32> {
    let (rows, cols, m) = w.layer(0);
    let mut z = vec![0.0f32; cols];
    affine_aug(x, rows, cols, m, &mut z);
    z
}

/// Reusable per-thread buffers for the stochastic forward (§Perf
/// iteration 3: a trial is ~20 µs — two Vec allocations per layer were
/// ~11% of the profile).
#[derive(Debug, Default, Clone)]
pub struct TrialScratch {
    h: Vec<f32>,
    z: Vec<f32>,
    /// Output logits (valid after `stochastic_logits_into`).
    pub logits: Vec<f32>,
}

/// Stochastic pass given the precomputed layer-0 pre-activation.
pub fn stochastic_logits_from_z1(
    w: &Weights,
    z1_mean: &[f32],
    sigma_z: f64,
    gauss: &mut GaussianSource,
) -> Vec<f32> {
    let mut scratch = TrialScratch::default();
    stochastic_logits_into(w, z1_mean, sigma_z, gauss, &mut scratch);
    scratch.logits
}

/// Allocation-free variant over caller-owned scratch buffers.
pub fn stochastic_logits_into(
    w: &Weights,
    z1_mean: &[f32],
    sigma_z: f64,
    gauss: &mut GaussianSource,
    s: &mut TrialScratch,
) {
    // (§Perf iteration 4 — a 6σ saturation shortcut skipping the noise
    // draw for decided neurons — was tried and REVERTED: <1% measured
    // gain; saturated units beyond 6σ_z = 10.2 z-units are rare.)
    // Layer 0: binarize the cached mean with fresh noise.
    s.h.clear();
    s.h.extend(z1_mean.iter().map(|&z| {
        if (z as f64) + sigma_z * gauss.next() > 0.0 {
            1.0f32
        } else {
            0.0
        }
    }));
    // Remaining hidden layers depend on the stochastic h — full recompute.
    for l in 1..w.spec.num_layers() - 1 {
        let (rows, cols, m) = w.layer(l);
        s.z.resize(cols, 0.0);
        affine_aug(&s.h, rows, cols, m, &mut s.z);
        for v in s.z.iter_mut() {
            let fired = (*v as f64) + sigma_z * gauss.next() > 0.0;
            *v = if fired { 1.0 } else { 0.0 };
        }
        std::mem::swap(&mut s.h, &mut s.z);
    }
    let l = w.spec.num_layers() - 1;
    let (rows, cols, m) = w.layer(l);
    s.logits.resize(cols, 0.0);
    affine_aug(&s.h, rows, cols, m, &mut s.logits);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::ModelSpec;

    fn tiny_weights() -> Weights {
        Weights::random(ModelSpec::new(vec![6, 5, 4, 3]), 7)
    }

    #[test]
    fn affine_matches_naive() {
        let w = tiny_weights();
        let (rows, cols, m) = w.layer(0);
        let x: Vec<f32> = (0..6).map(|i| i as f32 * 0.3).collect();
        let mut out = vec![0.0; cols];
        affine_aug(&x, rows, cols, m, &mut out);
        for j in 0..cols {
            let mut want = 0.0f32;
            for i in 0..rows - 1 {
                want += x[i] * m[i * cols + j];
            }
            want += m[(rows - 1) * cols + j]; // bias
            assert!((out[j] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_is_distribution() {
        let mut z = vec![1.0f32, 2.0, 3.0, 1000.0];
        softmax(&mut z);
        let s: f32 = z.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(z[3] > 0.99); // stability at large logits
    }

    #[test]
    fn ideal_forward_shapes_and_simplex() {
        let w = tiny_weights();
        let x = vec![0.5f32; 6];
        let p = ideal_forward(&w, &x);
        assert_eq!(p.len(), 3);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn stochastic_expectation_matches_sigmoid() {
        // One layer, one neuron: firing frequency ≈ Φ(z/σ_z) ≈ sigmoid(z).
        let spec = ModelSpec::new(vec![1, 1]);
        let mut w = Weights::random(spec, 1);
        w.mats[0] = vec![1.5, 0.0]; // weight 1.5, bias 0
        let mut g = GaussianSource::new(2);
        // Single-layer net: stochastic_logits has no hidden layer; use the
        // raw affine + manual binarization loop instead.
        let n = 40_000;
        let mut fired = 0;
        for _ in 0..n {
            let z = 1.5f64; // x = 1 → z = 1.5
            if z + 1.702 * g.next() > 0.0 {
                fired += 1;
            }
        }
        let p = fired as f64 / n as f64;
        let want = 1.0 / (1.0 + (-1.5f64).exp());
        assert!((p - want).abs() < 0.015, "p={p} want={want}");
    }

    #[test]
    fn stochastic_logits_binary_hiddens_affect_output_range() {
        let w = tiny_weights();
        let mut g = GaussianSource::new(3);
        let x = vec![0.5f32; 6];
        let z = stochastic_logits(&w, &x, 1.702, &mut g);
        assert_eq!(z.len(), 3);
        assert!(z.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn zero_noise_stochastic_is_deterministic() {
        let w = tiny_weights();
        let mut g1 = GaussianSource::new(4);
        let mut g2 = GaussianSource::new(5);
        let x = vec![0.3f32; 6];
        let a = stochastic_logits(&w, &x, 0.0, &mut g1);
        let b = stochastic_logits(&w, &x, 0.0, &mut g2);
        assert_eq!(a, b);
    }
}
