//! Trained-weight loading (`artifacts/weights/fcnn.{bin,json}`).
//!
//! Format contract with `python/compile/train.py::save_weights`: the .bin
//! is the little-endian f32 concatenation of each augmented weight matrix
//! in row-major order; the .json carries `layers` and `shapes`.

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::model::ModelSpec;
use crate::util::json::Json;

/// Loaded network parameters.
#[derive(Debug, Clone)]
pub struct Weights {
    pub spec: ModelSpec,
    /// Row-major augmented matrices, one per layer: shape (fan_in+1, fan_out).
    pub mats: Vec<Vec<f32>>,
    /// Ideal test accuracy recorded at training time (−1 if unknown).
    pub ideal_test_accuracy: f64,
}

impl Weights {
    /// Load from `<prefix>.bin` + `<prefix>.json`.
    pub fn load(prefix: &Path) -> Result<Self> {
        let json_path = prefix.with_extension("json");
        let bin_path = prefix.with_extension("bin");
        let meta = Json::parse(
            &std::fs::read_to_string(&json_path)
                .with_context(|| format!("reading {}", json_path.display()))?,
        )?;
        let layers: Vec<usize> = meta
            .get("layers")
            .and_then(Json::as_arr)
            .context("weights meta: layers")?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let shapes: Vec<(usize, usize)> = meta
            .get("shapes")
            .and_then(Json::as_arr)
            .context("weights meta: shapes")?
            .iter()
            .map(|s| {
                let r = s.idx(0).and_then(Json::as_usize).context("shape row")?;
                let c = s.idx(1).and_then(Json::as_usize).context("shape col")?;
                Ok((r, c))
            })
            .collect::<Result<_>>()?;
        let spec = ModelSpec::new(layers);
        ensure!(shapes.len() == spec.num_layers(), "shape count mismatch");
        for (l, &(r, c)) in shapes.iter().enumerate() {
            ensure!(
                (r, c) == spec.layer_shape(l),
                "layer {l} shape {:?} != spec {:?}",
                (r, c),
                spec.layer_shape(l)
            );
        }

        let bytes = std::fs::read(&bin_path)
            .with_context(|| format!("reading {}", bin_path.display()))?;
        let expected = spec.num_params() * 4;
        ensure!(
            bytes.len() == expected,
            "weights bin is {} bytes, expected {expected}",
            bytes.len()
        );
        let mut mats = Vec::with_capacity(shapes.len());
        let mut off = 0usize;
        for &(r, c) in &shapes {
            let n = r * c;
            let mut m = Vec::with_capacity(n);
            for i in 0..n {
                let b = &bytes[(off + i) * 4..(off + i) * 4 + 4];
                m.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            off += n;
            mats.push(m);
        }
        let acc = meta
            .get("ideal_test_accuracy")
            .and_then(Json::as_f64)
            .unwrap_or(-1.0);
        let w = Self { spec, mats, ideal_test_accuracy: acc };
        w.validate()?;
        Ok(w)
    }

    /// Write `<prefix>.bin` + `<prefix>.json` in the python toolchain's
    /// format, so natively trained weights (`raca train`) are loadable by
    /// every artifact consumer ([`Weights::load`] round-trips exactly).
    pub fn save(&self, prefix: &Path) -> Result<()> {
        use crate::util::json::{obj, Json};
        if let Some(dir) = prefix.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
        let mut bytes = Vec::with_capacity(self.spec.num_params() * 4);
        for m in &self.mats {
            for v in m {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        let bin_path = prefix.with_extension("bin");
        std::fs::write(&bin_path, &bytes)
            .with_context(|| format!("writing {}", bin_path.display()))?;
        let layers =
            Json::Arr(self.spec.widths.iter().map(|&w| Json::Num(w as f64)).collect());
        let shapes = Json::Arr(
            (0..self.spec.num_layers())
                .map(|l| {
                    let (r, c) = self.spec.layer_shape(l);
                    Json::Arr(vec![Json::Num(r as f64), Json::Num(c as f64)])
                })
                .collect(),
        );
        let meta = obj(vec![
            ("layers", layers),
            ("shapes", shapes),
            ("ideal_test_accuracy", Json::Num(self.ideal_test_accuracy)),
        ]);
        let json_path = prefix.with_extension("json");
        std::fs::write(&json_path, meta.to_string())
            .with_context(|| format!("writing {}", json_path.display()))?;
        Ok(())
    }

    /// Sanity-check invariants (finite, inside the conductance clip range).
    pub fn validate(&self) -> Result<()> {
        for (l, m) in self.mats.iter().enumerate() {
            for &v in m {
                if !v.is_finite() {
                    bail!("layer {l}: non-finite weight {v}");
                }
                if v.abs() > crate::device::W_CLIP as f32 + 1e-4 {
                    bail!("layer {l}: weight {v} outside clip range");
                }
            }
        }
        Ok(())
    }

    /// Weight matrix of layer `l` as (rows, cols, data).
    pub fn layer(&self, l: usize) -> (usize, usize, &[f32]) {
        let (r, c) = self.spec.layer_shape(l);
        (r, c, &self.mats[l])
    }

    /// Synthetic random weights for tests (uniform in [−1, 1]).
    pub fn random(spec: ModelSpec, seed: u64) -> Self {
        let mut rng = crate::stats::Rng::new(seed);
        let mats = (0..spec.num_layers())
            .map(|l| {
                let (r, c) = spec.layer_shape(l);
                (0..r * c)
                    .map(|_| (rng.next_f64() * 2.0 - 1.0) as f32)
                    .collect()
            })
            .collect();
        Self { spec, mats, ideal_test_accuracy: -1.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path, shapes: &[(usize, usize)], layers: &[usize]) {
        let mut flat: Vec<u8> = Vec::new();
        let mut v = 0.0f32;
        for &(r, c) in shapes {
            for _ in 0..r * c {
                flat.extend_from_slice(&v.to_le_bytes());
                v = (v + 0.125) % 2.0;
            }
        }
        std::fs::write(dir.join("w.bin"), &flat).unwrap();
        let shapes_json: Vec<String> =
            shapes.iter().map(|(r, c)| format!("[{r},{c}]")).collect();
        let layers_json: Vec<String> = layers.iter().map(|l| l.to_string()).collect();
        std::fs::write(
            dir.join("w.json"),
            format!(
                r#"{{"layers": [{}], "shapes": [{}], "ideal_test_accuracy": 0.9}}"#,
                layers_json.join(","),
                shapes_json.join(",")
            ),
        )
        .unwrap();
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("raca_w_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_fixture(&dir, &[(5, 3), (4, 2)], &[4, 3, 2]);
        let w = Weights::load(&dir.join("w")).unwrap();
        assert_eq!(w.spec.widths, vec![4, 3, 2]);
        assert_eq!(w.mats[0].len(), 15);
        assert_eq!(w.mats[1].len(), 8);
        assert!((w.ideal_test_accuracy - 0.9).abs() < 1e-12);
        assert_eq!(w.mats[0][1], 0.125);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shape_mismatch_rejected() {
        let dir = std::env::temp_dir().join(format!("raca_wbad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // shapes say (5,3) but layers say [4,3] → expects (5,3)... make them disagree:
        write_fixture(&dir, &[(9, 3)], &[4, 3]);
        assert!(Weights::load(&dir.join("w")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_load_round_trips_bit_exactly() {
        let dir = std::env::temp_dir().join(format!("raca_wsave_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut w = Weights::random(ModelSpec::new(vec![784, 6, 10]), 9);
        w.ideal_test_accuracy = 0.625;
        w.save(&dir.join("weights").join("fcnn")).unwrap(); // creates subdir
        let r = Weights::load(&dir.join("weights").join("fcnn")).unwrap();
        assert_eq!(r.spec.widths, w.spec.widths);
        assert_eq!(r.mats, w.mats, "f32 payload must survive exactly");
        assert!((r.ideal_test_accuracy - 0.625).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn random_weights_validate() {
        let w = Weights::random(ModelSpec::new(vec![6, 4, 2]), 1);
        w.validate().unwrap();
        assert_eq!(w.mats.len(), 2);
        assert_eq!(w.mats[0].len(), 7 * 4);
    }
}
