//! Neural-network layer (DESIGN.md §4.6): model definition, trained-weight
//! loading, the two native forward passes (ideal float & stochastic) plus
//! their trial-blocked bit-packed variant ([`forward::BlockScratch`] over
//! [`bitvec::BitBlock`]), and a native SGD trainer for artifact-free
//! builds.

pub mod bitvec;
pub mod forward;
pub mod model;
pub mod train;
pub mod weights;

pub use bitvec::BitBlock;
pub use forward::{ideal_forward, ideal_logits, stochastic_logits};
pub use model::ModelSpec;
pub use train::{train, TrainConfig};
pub use weights::Weights;
