//! Neural-network layer (DESIGN.md §4.6): model definition, trained-weight
//! loading, and the two native forward passes (ideal float & stochastic).

pub mod forward;
pub mod model;
pub mod weights;

pub use forward::{ideal_forward, ideal_logits, stochastic_logits};
pub use model::ModelSpec;
pub use weights::Weights;
