//! Bit-packed binary activations for the trial-blocked forward kernel.
//!
//! The RACA hidden state is a *binary* spike vector (h = 1[z + σ_z·n > 0],
//! paper §III-A) — one bit of information per neuron that the scalar
//! forward nevertheless stores as an f32 and re-multiplies against the
//! full weight matrix once per trial.  [`BitBlock`] packs the hidden
//! vectors of a whole block of trials into `u64` words so the matmul loop
//! can be inverted: each f32 weight row is read **once per block** and
//! accumulated into exactly the trials whose bit is set (§Perf iteration
//! 5, `nn::forward::hidden_layer_block`).
//!
//! Layout is **neuron-major**: for each neuron the block stores
//! `lanes = ceil(trials/64)` words whose bit *t* says "trial *t* fired".
//! That orientation is what makes the inverted loop a straight
//! `trailing_zeros` walk per weight row — the per-trial view only matters
//! at the block boundary (packing a pipeline's activation slab in,
//! unpacking one out), where [`BitBlock::append_trial_row`] and
//! [`nn::forward::pack_rows_block`] convert.
//!
//! [`nn::forward::pack_rows_block`]: crate::nn::forward::pack_rows_block

/// Binary activations of one trial block: `trials × neurons` bits,
/// neuron-major (`lanes` words of trial mask per neuron).
#[derive(Debug, Default, Clone)]
pub struct BitBlock {
    /// `neurons * lanes` words; neuron `i`'s trial masks start at
    /// `i * lanes`.
    words: Vec<u64>,
    lanes: usize,
    trials: usize,
    neurons: usize,
}

impl BitBlock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Clear and resize to `trials × neurons` (all bits zero).
    pub fn reset(&mut self, trials: usize, neurons: usize) {
        self.lanes = trials.div_ceil(64).max(1);
        self.trials = trials;
        self.neurons = neurons;
        self.words.clear();
        self.words.resize(neurons * self.lanes, 0);
    }

    pub fn trials(&self) -> usize {
        self.trials
    }

    pub fn neurons(&self) -> usize {
        self.neurons
    }

    /// Words per neuron (`ceil(trials/64)`).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Mark trial `t`'s activation of neuron `i` as fired.
    #[inline]
    pub fn set(&mut self, t: usize, i: usize) {
        debug_assert!(t < self.trials && i < self.neurons);
        self.words[i * self.lanes + (t >> 6)] |= 1u64 << (t & 63);
    }

    /// Whether trial `t` fired neuron `i`.
    #[inline]
    pub fn get(&self, t: usize, i: usize) -> bool {
        self.words[i * self.lanes + (t >> 6)] & (1u64 << (t & 63)) != 0
    }

    /// Neuron `i`'s trial masks (`lanes` words) — the unit the inverted
    /// matmul loop walks with `trailing_zeros`.
    #[inline]
    pub fn neuron_masks(&self, i: usize) -> &[u64] {
        &self.words[i * self.lanes..(i + 1) * self.lanes]
    }

    /// Append trial `t`'s activation row as 0.0/1.0 f32 (the die-to-die
    /// slab format of the pipelined backend).
    pub fn append_trial_row(&self, t: usize, out: &mut Vec<f32>) {
        out.reserve(self.neurons);
        for i in 0..self.neurons {
            out.push(if self.get(t, i) { 1.0 } else { 0.0 });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip_across_lanes() {
        let mut b = BitBlock::new();
        b.reset(130, 5); // 3 lanes
        assert_eq!(b.lanes(), 3);
        let fired = [(0usize, 0usize), (63, 1), (64, 1), (129, 4), (65, 0)];
        for &(t, i) in &fired {
            b.set(t, i);
        }
        for t in 0..130 {
            for i in 0..5 {
                assert_eq!(b.get(t, i), fired.contains(&(t, i)), "bit ({t},{i})");
            }
        }
    }

    #[test]
    fn neuron_masks_walk_matches_get() {
        let mut b = BitBlock::new();
        b.reset(70, 3);
        for t in (0..70).step_by(7) {
            b.set(t, 1);
        }
        let mut seen = Vec::new();
        for (lane, &mask) in b.neuron_masks(1).iter().enumerate() {
            let mut m = mask;
            while m != 0 {
                seen.push((lane << 6) + m.trailing_zeros() as usize);
                m &= m - 1;
            }
        }
        assert_eq!(seen, (0..70).step_by(7).collect::<Vec<_>>());
        assert!(b.neuron_masks(0).iter().all(|&m| m == 0));
    }

    #[test]
    fn reset_clears_previous_contents() {
        let mut b = BitBlock::new();
        b.reset(10, 4);
        b.set(3, 2);
        b.reset(10, 4);
        assert!(!b.get(3, 2));
        b.reset(0, 0); // degenerate sizes stay well-formed
        assert_eq!(b.lanes(), 1);
    }

    #[test]
    fn append_trial_row_unpacks_binary_f32() {
        let mut b = BitBlock::new();
        b.reset(2, 4);
        b.set(0, 1);
        b.set(0, 3);
        b.set(1, 0);
        let mut out = Vec::new();
        b.append_trial_row(0, &mut out);
        b.append_trial_row(1, &mut out);
        assert_eq!(out, vec![0.0, 1.0, 0.0, 1.0, 1.0, 0.0, 0.0, 0.0]);
    }
}
