//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin).  The interchange format
//! is HLO *text* — see DESIGN.md §7 and /opt/xla-example/README.md for why
//! serialized protos from jax ≥ 0.5 are rejected by xla_extension 0.5.1.

pub mod artifact;
pub mod client;
pub mod executor;

pub use artifact::{ArtifactStore, Manifest};
pub use client::RtClient;
pub use executor::{Executor, TrialExecutor, IdealExecutor};
