//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin).  The interchange format
//! is HLO *text* — see DESIGN.md §7 and /opt/xla-example/README.md for why
//! serialized protos from jax ≥ 0.5 are rejected by xla_extension 0.5.1.
//!
//! The whole PJRT surface is gated behind the `pjrt` cargo feature
//! (default off): the offline build links an API-shaped stub, so the
//! AOT/XLA engine only exists when a real plugin is available.  Artifact
//! *location* ([`default_artifact_dir`]) stays available in every build —
//! the native engine and figure harnesses load weights/datasets from the
//! same directory without touching PJRT.

#[cfg(feature = "pjrt")]
pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod executor;

#[cfg(feature = "pjrt")]
pub use artifact::{ArtifactStore, Manifest};
#[cfg(feature = "pjrt")]
pub use client::RtClient;
#[cfg(feature = "pjrt")]
pub use executor::{Executor, IdealExecutor, TrialExecutor};

/// Resolve the default artifact directory: `$RACA_ARTIFACTS`, then
/// `./artifacts` walking up, then the crate-root `artifacts/` (tests run
/// from `target/`).
pub fn default_artifact_dir() -> std::path::PathBuf {
    if let Ok(d) = std::env::var("RACA_ARTIFACTS") {
        return std::path::PathBuf::from(d);
    }
    for cand in ["artifacts", "../artifacts", "../../artifacts"] {
        let p = std::path::PathBuf::from(cand);
        if p.join("manifest.json").exists() {
            return p;
        }
    }
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Resolve the artifact directory for one command, in precedence order:
/// the `--artifact-dir` flag, the `RACA_ARTIFACT_DIR` environment
/// variable, the `"artifacts"` config key (validated to exist at config
/// parse — see [`crate::config::RunConfig`]), then
/// [`default_artifact_dir`] (which itself honors the older
/// `RACA_ARTIFACTS` variable for compatibility).
pub fn resolve_artifact_dir(
    flag: Option<&std::path::Path>,
    config: Option<&std::path::Path>,
) -> std::path::PathBuf {
    if let Some(p) = flag {
        return p.to_path_buf();
    }
    if let Ok(d) = std::env::var("RACA_ARTIFACT_DIR") {
        if !d.is_empty() {
            return std::path::PathBuf::from(d);
        }
    }
    if let Some(p) = config {
        return p.to_path_buf();
    }
    default_artifact_dir()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn artifact_dir_precedence_is_flag_config_default() {
        // CI never sets RACA_ARTIFACT_DIR, but guard the assertions so a
        // developer shell with it exported doesn't see spurious failures
        // (env mutation in-process would race parallel tests).
        if std::env::var("RACA_ARTIFACT_DIR").is_ok() {
            return;
        }
        let flag = Path::new("/from/flag");
        let conf = Path::new("/from/config");
        assert_eq!(resolve_artifact_dir(Some(flag), Some(conf)), flag);
        assert_eq!(resolve_artifact_dir(Some(flag), None), flag);
        assert_eq!(resolve_artifact_dir(None, Some(conf)), conf);
        assert_eq!(resolve_artifact_dir(None, None), default_artifact_dir());
    }
}
