//! Typed wrappers around the compiled RACA executables.
//!
//! Artifact contract (DESIGN.md §7):
//!
//! * `trial_fwd_b{B}`: `(x f32[B,784], w1, w2, w3, seed u32, σ_z f32,
//!   θ f32) → (winner i32[B],)` — one stochastic inference trial.
//! * `ideal_fwd_b{B}`: `(x f32[B,784], w1, w2, w3) → (probs f32[B,10],)`.
//!
//! Weights are **runtime parameters** (HLO text elides big constants, so
//! they cannot be baked).  They are uploaded once as device-resident PJRT
//! buffers and shared across executors via [`WeightBuffers`]; the hot path
//! only uploads the per-call `x`/`seed`/`σ_z`/`θ` and uses `execute_b`.
//!
//! Outputs are 1-tuples (jax lowered with `return_tuple=True`), hence the
//! `to_tuple1` unwrap.

use std::rc::Rc;

use anyhow::{ensure, Context, Result};

use crate::nn::Weights;

/// Device-resident weight buffers (one per layer), shared by executors.
pub struct WeightBuffers {
    bufs: Vec<xla::PjRtBuffer>,
}

impl WeightBuffers {
    /// Upload all layers of `w` to the device owned by `client`.
    pub fn upload(client: &xla::PjRtClient, w: &Weights) -> Result<Rc<Self>> {
        let mut bufs = Vec::with_capacity(w.spec.num_layers());
        for l in 0..w.spec.num_layers() {
            let (rows, cols, data) = w.layer(l);
            let buf = client
                .buffer_from_host_buffer::<f32>(data, &[rows, cols], None)
                .with_context(|| format!("uploading layer {l} weights"))?;
            bufs.push(buf);
        }
        Ok(Rc::new(Self { bufs }))
    }

    pub fn layers(&self) -> &[xla::PjRtBuffer] {
        &self.bufs
    }
}

/// Generic compiled-executable handle.
pub struct Executor {
    exe: xla::PjRtLoadedExecutable,
    /// Human-readable identifier (artifact file stem) for error messages.
    pub name: String,
}

impl Executor {
    pub fn new(exe: xla::PjRtLoadedExecutable, name: impl Into<String>) -> Self {
        Self { exe, name: name.into() }
    }

    /// Execute with device buffers, returning the unwrapped 1-tuple.
    pub fn run1_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<xla::Literal> {
        let outs = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of {}", self.name))?;
        lit.to_tuple1()
            .with_context(|| format!("unwrapping 1-tuple output of {}", self.name))
    }

    /// Execute with literal arguments (smoke tests / tools).
    pub fn run1(&self, args: &[xla::Literal]) -> Result<xla::Literal> {
        let outs = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of {}", self.name))?;
        lit.to_tuple1()
            .with_context(|| format!("unwrapping 1-tuple output of {}", self.name))
    }
}

/// One stochastic inference trial over a fixed batch size.
pub struct TrialExecutor {
    inner: Executor,
    client: xla::PjRtClient,
    weights: Rc<WeightBuffers>,
    /// Rows per execution (images × trials packed by the batcher).
    pub batch: usize,
    /// Input features per row (784).
    pub features: usize,
}

impl TrialExecutor {
    pub fn new(
        exe: xla::PjRtLoadedExecutable,
        client: xla::PjRtClient,
        weights: Rc<WeightBuffers>,
        batch: usize,
        features: usize,
    ) -> Self {
        Self {
            inner: Executor::new(exe, format!("trial_fwd_b{batch}")),
            client,
            weights,
            batch,
            features,
        }
    }

    /// Run one trial batch.
    ///
    /// `x` is row-major `[batch, features]`; `sigma_z` is the normalized
    /// comparator noise std (1.702/snr_scale); `theta` the normalized WTA
    /// rest threshold.  Returns one winner index per row (−1 = abstain).
    pub fn run(&self, x: &[f32], seed: u32, sigma_z: f32, theta: f32) -> Result<Vec<i32>> {
        ensure!(
            x.len() == self.batch * self.features,
            "trial batch expects {}x{} inputs, got {}",
            self.batch,
            self.features,
            x.len()
        );
        let xb = self
            .client
            .buffer_from_host_buffer::<f32>(x, &[self.batch, self.features], None)?;
        let seed_b = self.client.buffer_from_host_buffer::<u32>(&[seed], &[], None)?;
        let sig_b = self.client.buffer_from_host_buffer::<f32>(&[sigma_z], &[], None)?;
        let th_b = self.client.buffer_from_host_buffer::<f32>(&[theta], &[], None)?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&xb];
        args.extend(self.weights.layers().iter());
        args.push(&seed_b);
        args.push(&sig_b);
        args.push(&th_b);
        let out = self.inner.run1_buffers(&args)?;
        let winners = out.to_vec::<i32>()?;
        ensure!(winners.len() == self.batch, "winner count mismatch");
        Ok(winners)
    }
}

/// Float software forward (`ideal_fwd`): batch of images → class probs.
pub struct IdealExecutor {
    inner: Executor,
    client: xla::PjRtClient,
    weights: Rc<WeightBuffers>,
    pub batch: usize,
    pub features: usize,
    pub classes: usize,
}

impl IdealExecutor {
    pub fn new(
        exe: xla::PjRtLoadedExecutable,
        client: xla::PjRtClient,
        weights: Rc<WeightBuffers>,
        batch: usize,
        features: usize,
        classes: usize,
    ) -> Self {
        Self {
            inner: Executor::new(exe, format!("ideal_fwd_b{batch}")),
            client,
            weights,
            batch,
            features,
            classes,
        }
    }

    /// Returns row-major `[batch, classes]` probabilities.
    pub fn run(&self, x: &[f32]) -> Result<Vec<f32>> {
        ensure!(
            x.len() == self.batch * self.features,
            "ideal batch expects {}x{} inputs, got {}",
            self.batch,
            self.features,
            x.len()
        );
        let xb = self
            .client
            .buffer_from_host_buffer::<f32>(x, &[self.batch, self.features], None)?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&xb];
        args.extend(self.weights.layers().iter());
        let out = self.inner.run1_buffers(&args)?;
        let probs = out.to_vec::<f32>()?;
        ensure!(probs.len() == self.batch * self.classes, "prob count mismatch");
        Ok(probs)
    }
}
