//! PJRT CPU client wrapper.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (neither `Send` nor
//! `Sync`), so all PJRT state lives on whichever thread created it.  The
//! serving architecture therefore gives each engine worker a dedicated OS
//! thread that owns its own `RtClient` + compiled executables and speaks
//! to the coordinator over channels (see `engine::xla`).

use anyhow::{Context, Result};

/// Thin wrapper over the PJRT CPU client (thread-local by construction).
pub struct RtClient {
    inner: xla::PjRtClient,
}

impl RtClient {
    /// Create a client on the current thread.
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { inner: client })
    }

    /// Backwards-compatible alias used by single-threaded tools.
    pub fn global() -> Result<Self> {
        Self::new()
    }

    pub fn platform_name(&self) -> String {
        self.inner.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.inner.device_count()
    }

    /// Load an HLO-text file and compile it to a PJRT executable.
    pub fn compile_hlo_text(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.inner
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    pub fn raw(&self) -> &xla::PjRtClient {
        &self.inner
    }
}
