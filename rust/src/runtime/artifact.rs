//! Artifact store: locate, validate and lazily compile `artifacts/*`.
//!
//! `make artifacts` (python, build-time) writes the manifest; this module
//! is the only place that knows the directory layout.  Executables are
//! compiled and weights uploaded on first use, then cached for the store's
//! lifetime — HLO→machine code happens once, never on the request path.
//!
//! The store (like everything PJRT in the `xla` crate) is **not Send**:
//! it lives on the engine worker thread that created it (see
//! `engine::xla`).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::client::RtClient;
use super::executor::{IdealExecutor, TrialExecutor, WeightBuffers};
use crate::nn::Weights;
use crate::util::json::Json;

pub const FEATURES: usize = 784;
pub const CLASSES: usize = 10;

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Batched-trial executables available (rows per execute).
    pub trial_batches: Vec<usize>,
    pub ideal_batches: Vec<usize>,
    /// Normalized comparator noise std at the calibrated point (1.702).
    pub sigma_z: f64,
    /// Normalized WTA rest threshold corresponding to V_th0 = 0.05 V.
    pub theta_norm: f64,
    /// Ideal (software) test accuracy recorded by the trainer.
    pub ideal_test_accuracy: f64,
    /// Layer widths, e.g. [784, 500, 300, 10].
    pub layers: Vec<usize>,
    /// Per-layer calibrated read voltages [V] (for the hw cost model).
    pub vr_per_layer: Vec<f64>,
    /// Readout bandwidth Δf [Hz].
    pub delta_f: f64,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let dp = j.get("design_point").context("manifest: design_point missing")?;
        let usize_arr = |v: &Json| -> Vec<usize> {
            v.as_arr()
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default()
        };
        let f64_arr = |v: &Json| -> Vec<f64> {
            v.as_arr()
                .map(|a| a.iter().filter_map(Json::as_f64).collect())
                .unwrap_or_default()
        };
        Ok(Self {
            trial_batches: j.get("trial_batches").map(usize_arr).unwrap_or_default(),
            ideal_batches: j.get("ideal_batches").map(usize_arr).unwrap_or_default(),
            sigma_z: dp
                .get("sigma_z")
                .and_then(Json::as_f64)
                .context("manifest: sigma_z")?,
            theta_norm: dp
                .get("theta_norm")
                .and_then(Json::as_f64)
                .context("manifest: theta_norm")?,
            ideal_test_accuracy: j
                .get("ideal_test_accuracy")
                .and_then(Json::as_f64)
                .unwrap_or(-1.0),
            layers: dp.get("layers").map(usize_arr).context("manifest: layers")?,
            vr_per_layer: dp.get("vr_per_layer").map(f64_arr).unwrap_or_default(),
            delta_f: dp.get("delta_f").and_then(Json::as_f64).unwrap_or(1e9),
        })
    }
}

/// Compiled-executable + uploaded-weight cache over an artifact directory.
pub struct ArtifactStore {
    pub dir: PathBuf,
    pub manifest: Manifest,
    pub weights: Weights,
    client: RtClient,
    weight_bufs: RefCell<Option<Rc<WeightBuffers>>>,
    trials: RefCell<HashMap<usize, Rc<TrialExecutor>>>,
    ideals: RefCell<HashMap<usize, Rc<IdealExecutor>>>,
}

impl ArtifactStore {
    /// Open an artifact directory (default resolution: $RACA_ARTIFACTS,
    /// then ./artifacts walking up, then the crate root).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let manifest = Manifest::parse(&text)?;
        let weights = Weights::load(&dir.join("weights").join("fcnn"))?;
        if weights.spec.widths != manifest.layers {
            bail!(
                "weights topology {:?} disagrees with manifest {:?}",
                weights.spec.widths,
                manifest.layers
            );
        }
        Ok(Self {
            dir,
            manifest,
            weights,
            client: RtClient::new()?,
            weight_bufs: RefCell::new(None),
            trials: RefCell::new(HashMap::new()),
            ideals: RefCell::new(HashMap::new()),
        })
    }

    /// Resolve the default artifact directory (see
    /// [`super::default_artifact_dir`], which is feature-independent).
    pub fn default_dir() -> PathBuf {
        super::default_artifact_dir()
    }

    pub fn open_default() -> Result<Self> {
        Self::open(Self::default_dir())
    }

    pub fn client(&self) -> &RtClient {
        &self.client
    }

    /// Path to a dataset prefix inside the artifact dir.
    pub fn data_prefix(&self, split: &str) -> PathBuf {
        self.dir.join("data").join(split)
    }

    fn weight_buffers(&self) -> Result<Rc<WeightBuffers>> {
        if let Some(b) = self.weight_bufs.borrow().as_ref() {
            return Ok(b.clone());
        }
        let bufs = WeightBuffers::upload(self.client.raw(), &self.weights)?;
        *self.weight_bufs.borrow_mut() = Some(bufs.clone());
        Ok(bufs)
    }

    /// Trial executable for an exact batch size (compiled once, cached).
    pub fn trial(&self, batch: usize) -> Result<Rc<TrialExecutor>> {
        if let Some(e) = self.trials.borrow().get(&batch) {
            return Ok(e.clone());
        }
        if !self.manifest.trial_batches.contains(&batch) {
            bail!(
                "no trial artifact for batch {batch}; available: {:?}",
                self.manifest.trial_batches
            );
        }
        let path = self.dir.join(format!("trial_fwd_b{batch}.hlo.txt"));
        log::info!("compiling {}", path.display());
        let exe = self.client.compile_hlo_text(&path)?;
        let ex = Rc::new(TrialExecutor::new(
            exe,
            self.client.raw().clone(),
            self.weight_buffers()?,
            batch,
            FEATURES,
        ));
        self.trials.borrow_mut().insert(batch, ex.clone());
        Ok(ex)
    }

    /// Ideal (float software) executable for an exact batch size.
    pub fn ideal(&self, batch: usize) -> Result<Rc<IdealExecutor>> {
        if let Some(e) = self.ideals.borrow().get(&batch) {
            return Ok(e.clone());
        }
        if !self.manifest.ideal_batches.contains(&batch) {
            bail!(
                "no ideal artifact for batch {batch}; available: {:?}",
                self.manifest.ideal_batches
            );
        }
        let path = self.dir.join(format!("ideal_fwd_b{batch}.hlo.txt"));
        log::info!("compiling {}", path.display());
        let exe = self.client.compile_hlo_text(&path)?;
        let ex = Rc::new(IdealExecutor::new(
            exe,
            self.client.raw().clone(),
            self.weight_buffers()?,
            batch,
            FEATURES,
            CLASSES,
        ));
        self.ideals.borrow_mut().insert(batch, ex.clone());
        Ok(ex)
    }

    /// Largest available trial batch ≤ `cap` (the batcher's packing size).
    pub fn best_trial_batch(&self, cap: usize) -> Option<usize> {
        self.manifest
            .trial_batches
            .iter()
            .copied()
            .filter(|&b| b <= cap.max(1))
            .max()
            .or_else(|| self.manifest.trial_batches.iter().copied().min())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
      "design_point": {"layers": [784,500,300,10], "sigma_z": 1.702,
                        "theta_norm": 3.0, "delta_f": 1e9,
                        "vr_per_layer": [0.01, 0.012, 0.015]},
      "trial_batches": [1, 32], "ideal_batches": [1, 256],
      "ideal_test_accuracy": 0.97}"#;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(MANIFEST).unwrap();
        assert_eq!(m.layers, vec![784, 500, 300, 10]);
        assert_eq!(m.trial_batches, vec![1, 32]);
        assert!((m.sigma_z - 1.702).abs() < 1e-12);
        assert_eq!(m.vr_per_layer.len(), 3);
    }

    #[test]
    fn manifest_missing_fields_rejected() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"design_point": {}}"#).is_err());
    }
}
