//! Run configuration: JSON file → typed config for every subsystem.
//!
//! One file configures the whole stack (`raca --config run.json <cmd>`),
//! so experiments are reproducible artifacts rather than flag soup:
//!
//! ```json
//! {
//!   "trial": {"snr_scale": 1.0, "theta": 3.0, "wta_steps": 64},
//!   "scheduler": {"batch_size": 32, "min_trials": 5,
//!                  "max_in_flight": 256, "confidence": 0.95},
//!   "engine": "xla",
//!   "tech": {"tile": 128, "adc1_energy_pj": 1.05}
//! }
//! ```
//!
//! Unknown keys are rejected (catch typos); missing keys take defaults.

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::SchedulerConfig;
use crate::engine::TrialParams;
use crate::fleet::{FleetConfig, RoutePolicy};
use crate::hwmodel::TechParams;
use crate::serve::{BackendKind, HttpConfig, ServeConfig, Topology};
use crate::util::json::Json;

/// Which engine backs the scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    #[default]
    Xla,
    Native,
    Physical,
}

/// Fully parsed run configuration.
#[derive(Debug, Clone, Default)]
pub struct RunConfig {
    pub trial: TrialParams,
    pub scheduler: SchedulerConfig,
    pub engine: EngineKind,
    pub tech: TechParams,
    /// Fleet-serving knobs (`raca fleet`).
    pub fleet: FleetConfig,
    /// Backend selection for `raca serve` (single/replicated/pipelined).
    pub serve: ServeConfig,
    /// Default per-request vote confidence.
    pub confidence: f64,
    /// Artifact directory (weights, compiled executables, registry store).
    /// Precedence at the CLI: `--artifact-dir` flag > `RACA_ARTIFACT_DIR`
    /// env > this key > [`crate::runtime::default_artifact_dir`].
    pub artifacts: Option<std::path::PathBuf>,
}

fn check_keys(obj: &Json, allowed: &[&str], section: &str) -> Result<()> {
    if let Some(map) = obj.as_obj() {
        for k in map.keys() {
            if !allowed.contains(&k.as_str()) {
                bail!("config: unknown key '{k}' in {section} (allowed: {allowed:?})");
            }
        }
    }
    Ok(())
}

impl RunConfig {
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("parsing run config")?;
        check_keys(
            &j,
            &["trial", "scheduler", "engine", "tech", "fleet", "serve", "confidence", "artifacts"],
            "root",
        )?;
        let mut cfg = RunConfig { confidence: 0.95, ..Default::default() };

        if let Some(t) = j.get("trial") {
            check_keys(t, &["snr_scale", "sigma_z", "theta", "wta_steps"], "trial")?;
            if let Some(s) = t.get("snr_scale").and_then(Json::as_f64) {
                cfg.trial = TrialParams::with_snr_scale(s as f32);
            }
            if let Some(s) = t.get("sigma_z").and_then(Json::as_f64) {
                cfg.trial.sigma_z = s as f32;
            }
            if let Some(th) = t.get("theta").and_then(Json::as_f64) {
                cfg.trial.theta = th as f32;
            }
            if let Some(w) = t.get("wta_steps").and_then(Json::as_usize) {
                cfg.trial.wta_steps = w;
            }
        }
        if let Some(s) = j.get("scheduler") {
            check_keys(
                s,
                &["batch_size", "min_trials", "max_in_flight", "seed", "confidence"],
                "scheduler",
            )?;
            if let Some(v) = s.get("batch_size").and_then(Json::as_usize) {
                cfg.scheduler.batch_size = v;
            }
            if let Some(v) = s.get("min_trials").and_then(Json::as_usize) {
                cfg.scheduler.min_trials = v as u32;
            }
            if let Some(v) = s.get("max_in_flight").and_then(Json::as_usize) {
                cfg.scheduler.max_in_flight = v;
            }
            if let Some(v) = s.get("seed").and_then(Json::as_f64) {
                cfg.scheduler.seed = v as u64;
            }
            if let Some(v) = s.get("confidence").and_then(Json::as_f64) {
                cfg.confidence = v;
            }
        }
        if let Some(a) = j.get("artifacts") {
            let dir = a.as_str().map(std::path::PathBuf::from).ok_or_else(|| {
                anyhow::anyhow!("config: artifacts must be a directory path string")
            })?;
            // Catch a mistyped path at parse time, not at first artifact
            // write deep inside a train/publish run.
            ensure!(
                dir.is_dir(),
                "config: artifacts directory {} does not exist",
                dir.display()
            );
            cfg.artifacts = Some(dir);
        }
        if let Some(e) = j.get("engine").and_then(Json::as_str) {
            cfg.engine = match e {
                "xla" => EngineKind::Xla,
                "native" => EngineKind::Native,
                "physical" => EngineKind::Physical,
                other => bail!("config: unknown engine '{other}'"),
            };
        }
        if let Some(t) = j.get("tech") {
            check_keys(
                t,
                &[
                    "tile", "adc1_energy_pj", "adc1_area_um2", "comparator_energy_pj",
                    "comparator_area_um2", "v_read_conv", "v_read_raca", "delta_f",
                    "trials_per_classification", "wta_steps", "input_cycles",
                ],
                "tech",
            )?;
            if let Some(v) = t.get("tile").and_then(Json::as_usize) {
                cfg.tech.tile = v;
            }
            let set = |key: &str, field: &mut f64| {
                if let Some(v) = t.get(key).and_then(Json::as_f64) {
                    *field = v;
                }
            };
            set("adc1_energy_pj", &mut cfg.tech.adc1_energy_pj);
            set("adc1_area_um2", &mut cfg.tech.adc1_area_um2);
            set("comparator_energy_pj", &mut cfg.tech.comparator_energy_pj);
            set("comparator_area_um2", &mut cfg.tech.comparator_area_um2);
            set("v_read_conv", &mut cfg.tech.v_read_conv);
            set("v_read_raca", &mut cfg.tech.v_read_raca);
            if let Some(v) = t.get("trials_per_classification").and_then(Json::as_usize) {
                cfg.tech.trials_per_classification = v;
            }
            if let Some(v) = t.get("wta_steps").and_then(Json::as_usize) {
                cfg.tech.wta_steps = v;
            }
            if let Some(v) = t.get("input_cycles").and_then(Json::as_usize) {
                cfg.tech.input_cycles = v;
            }
        }
        if let Some(fl) = j.get("fleet") {
            check_keys(
                fl,
                &[
                    "chips", "sigma", "stuck_lo", "stuck_hi", "policy", "cal_images",
                    "cal_trials", "serve_images", "serve_trials", "seed",
                ],
                "fleet",
            )?;
            if let Some(v) = fl.get("chips").and_then(Json::as_usize) {
                cfg.fleet.chips = v;
            }
            if let Some(v) = fl.get("sigma").and_then(Json::as_f64) {
                cfg.fleet.sigma = v;
            }
            if let Some(v) = fl.get("stuck_lo").and_then(Json::as_f64) {
                cfg.fleet.stuck_lo = v;
            }
            if let Some(v) = fl.get("stuck_hi").and_then(Json::as_f64) {
                cfg.fleet.stuck_hi = v;
            }
            if let Some(p) = fl.get("policy").and_then(Json::as_str) {
                cfg.fleet.policy = RoutePolicy::parse(p).with_context(|| {
                    format!(
                        "config: unknown fleet policy '{p}' (valid: {})",
                        RoutePolicy::SPELLINGS
                    )
                })?;
            }
            if let Some(v) = fl.get("cal_images").and_then(Json::as_usize) {
                cfg.fleet.cal_images = v;
            }
            if let Some(v) = fl.get("cal_trials").and_then(Json::as_usize) {
                cfg.fleet.cal_trials = v;
            }
            if let Some(v) = fl.get("serve_images").and_then(Json::as_usize) {
                cfg.fleet.serve_images = v;
            }
            if let Some(v) = fl.get("serve_trials").and_then(Json::as_usize) {
                cfg.fleet.serve_trials = v;
            }
            // JSON numbers are f64, so config seeds are exact only up to
            // 2^53; pass --seed on the CLI for full-width u64 seeds.
            if let Some(v) = fl.get("seed").and_then(Json::as_usize) {
                cfg.fleet.seed = v as u64;
            }
        }
        if let Some(s) = j.get("serve") {
            check_keys(
                s,
                &[
                    "backend", "topology", "chips", "shards", "depth", "batch",
                    "trial_block", "probe_rate", "listen", "http", "seed",
                ],
                "serve",
            )?;
            if let Some(b) = s.get("backend").and_then(Json::as_str) {
                cfg.serve.backend = BackendKind::parse(b).with_context(|| {
                    format!(
                        "config: unknown serve backend '{b}' (valid: {}; case-insensitive — \
                         or use \"topology\")",
                        BackendKind::SPELLINGS
                    )
                })?;
            }
            if let Some(t) = s.get("topology").and_then(Json::as_str) {
                // `Topology::parse` validates the tree, rejecting 0-sized
                // replicas/pipelines like the fleet checks below.
                cfg.serve.topology =
                    Some(Topology::parse(t).context("config: serve.topology")?);
            }
            if let Some(v) = s.get("chips").and_then(Json::as_usize) {
                cfg.serve.chips = v;
            }
            if let Some(v) = s.get("shards").and_then(Json::as_usize) {
                cfg.serve.shards = v;
            }
            if let Some(v) = s.get("depth").and_then(Json::as_usize) {
                cfg.serve.depth = v;
            }
            if let Some(v) = s.get("batch").and_then(Json::as_usize) {
                cfg.serve.batch = v;
            }
            if let Some(v) = s.get("trial_block").and_then(Json::as_usize) {
                cfg.serve.trial_block = v;
            }
            if let Some(v) = s.get("probe_rate").and_then(Json::as_f64) {
                cfg.serve.probe_rate = v;
            }
            if let Some(v) = s.get("listen").and_then(Json::as_str) {
                cfg.serve.listen = Some(v.to_string());
            }
            if let Some(h) = s.get("http") {
                check_keys(
                    h,
                    &["addr", "queue_depth", "in_flight", "tenant_rate", "tenant_burst"],
                    "serve.http",
                )?;
                let addr = match h.get("addr").and_then(Json::as_str) {
                    Some(a) => a,
                    None => bail!(
                        "config: serve.http requires an \"addr\" (<host:port> bind address)"
                    ),
                };
                let mut hc = HttpConfig::new(addr);
                if let Some(v) = h.get("queue_depth").and_then(Json::as_usize) {
                    hc.queue_depth = v;
                }
                if let Some(v) = h.get("in_flight").and_then(Json::as_usize) {
                    hc.in_flight = v;
                }
                if let Some(v) = h.get("tenant_rate").and_then(Json::as_f64) {
                    hc.tenant_rate = v;
                }
                if let Some(v) = h.get("tenant_burst").and_then(Json::as_f64) {
                    hc.tenant_burst = v;
                }
                cfg.serve.http = Some(hc);
            }
            if let Some(v) = s.get("seed").and_then(Json::as_usize) {
                cfg.serve.seed = v as u64;
            }
        }
        // Zero-sized farms/pipelines panic deep in the stack; reject them
        // here with a clear error instead.  (Shard count vs. layer count is
        // checked against the actual model when the shard plan is built;
        // explicit topology trees were validated at parse time above.)
        ensure!(cfg.fleet.chips > 0, "config: fleet.chips must be at least 1");
        ensure!(cfg.serve.chips > 0, "config: serve.chips must be at least 1");
        ensure!(
            cfg.serve.shards > 0,
            "config: serve.shards must be at least 1 (and at most the model's layer count)"
        );
        ensure!(cfg.serve.batch > 0, "config: serve.batch must be at least 1");
        ensure!(
            cfg.serve.trial_block > 0,
            "config: serve.trial_block must be at least 1 (trials per blocked-kernel pass)"
        );
        ensure!(
            (0.0..=1.0).contains(&cfg.serve.probe_rate),
            "config: serve.probe_rate must be in [0, 1] (probes per caller request)"
        );
        if let Some(l) = &cfg.serve.listen {
            ensure!(
                l.contains(':'),
                "config: serve.listen must be a <host:port> bind address"
            );
        }
        if let Some(h) = &cfg.serve.http {
            ensure!(
                h.addr.contains(':'),
                "config: serve.http.addr must be a <host:port> bind address"
            );
            ensure!(
                h.queue_depth > 0,
                "config: serve.http.queue_depth must be at least 1 (bounded ingress queue)"
            );
            ensure!(
                h.in_flight > 0,
                "config: serve.http.in_flight must be at least 1 (admitted-request budget)"
            );
            ensure!(
                h.tenant_rate >= 0.0 && h.tenant_rate.is_finite(),
                "config: serve.http.tenant_rate must be ≥ 0 requests/s per tenant (0 disables)"
            );
            ensure!(
                h.tenant_burst >= 1.0 && h.tenant_burst.is_finite(),
                "config: serve.http.tenant_burst must be at least 1 (token-bucket capacity)"
            );
        }
        cfg.scheduler.params = cfg.trial;
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_from_empty() {
        let c = RunConfig::parse("{}").unwrap();
        assert_eq!(c.engine, EngineKind::Xla);
        assert_eq!(c.scheduler.batch_size, 32);
        assert!((c.trial.sigma_z - 1.702).abs() < 1e-6);
    }

    #[test]
    fn full_roundtrip() {
        let c = RunConfig::parse(
            r#"{"trial": {"snr_scale": 2.0, "theta": 0.0, "wta_steps": 16},
                "scheduler": {"batch_size": 8, "min_trials": 2, "confidence": 0.9},
                "engine": "native",
                "tech": {"tile": 64, "adc1_energy_pj": 2.5}}"#,
        )
        .unwrap();
        assert_eq!(c.engine, EngineKind::Native);
        assert!((c.trial.sigma_z - 0.851).abs() < 1e-4);
        assert_eq!(c.trial.theta, 0.0);
        assert_eq!(c.trial.wta_steps, 16);
        assert_eq!(c.scheduler.batch_size, 8);
        assert_eq!(c.scheduler.params.wta_steps, 16);
        assert_eq!(c.tech.tile, 64);
        assert!((c.tech.adc1_energy_pj - 2.5).abs() < 1e-12);
        assert!((c.confidence - 0.9).abs() < 1e-12);
    }

    #[test]
    fn unknown_keys_rejected() {
        assert!(RunConfig::parse(r#"{"trail": {}}"#).is_err());
        assert!(RunConfig::parse(r#"{"trial": {"sigma": 1}}"#).is_err());
        assert!(RunConfig::parse(r#"{"engine": "gpu"}"#).is_err());
        assert!(RunConfig::parse(r#"{"fleet": {"dies": 4}}"#).is_err());
        assert!(RunConfig::parse(r#"{"fleet": {"policy": "random"}}"#).is_err());
        assert!(RunConfig::parse(r#"{"serve": {"backend": "sharded"}}"#).is_err());
        assert!(RunConfig::parse(r#"{"serve": {"dies": 2}}"#).is_err());
    }

    #[test]
    fn serve_section_parses() {
        let c = RunConfig::parse(
            r#"{"serve": {"backend": "pipelined", "shards": 3, "chips": 6,
                          "depth": 64, "batch": 4, "trial_block": 32,
                          "probe_rate": 0.1,
                          "listen": "0.0.0.0:7433", "seed": 12}}"#,
        )
        .unwrap();
        assert_eq!(c.serve.backend, crate::serve::BackendKind::Pipelined);
        assert_eq!(c.serve.shards, 3);
        assert_eq!(c.serve.chips, 6);
        assert_eq!(c.serve.depth, 64);
        assert_eq!(c.serve.batch, 4);
        assert_eq!(c.serve.trial_block, 32);
        assert!((c.serve.probe_rate - 0.1).abs() < 1e-12);
        assert_eq!(c.serve.listen.as_deref(), Some("0.0.0.0:7433"));
        assert_eq!(c.serve.seed, 12);
        // Untouched keys keep their defaults.
        let d = RunConfig::parse(r#"{"serve": {"backend": "replicated"}}"#).unwrap();
        assert_eq!(d.serve.chips, 4);
        assert_eq!(d.serve.shards, 2);
        assert_eq!(d.serve.topology, None);
        assert_eq!(d.serve.probe_rate, 0.0);
        assert_eq!(d.serve.listen, None);
        assert_eq!(d.serve.trial_block, 64, "default = one u64 lane");
        // Remote leaves parse like any other topology node.
        let r = RunConfig::parse(
            r#"{"serve": {"topology": "(remote:a:7433, remote:b:7433)@weighted"}}"#,
        )
        .unwrap();
        assert_eq!(
            r.serve.topology.unwrap().to_string(),
            "(remote:a:7433, remote:b:7433)@weighted"
        );
        // Out-of-range knobs are rejected with the key named.
        let e = RunConfig::parse(r#"{"serve": {"probe_rate": 1.5}}"#).unwrap_err();
        assert!(format!("{e}").contains("probe_rate"), "{e}");
        let e = RunConfig::parse(r#"{"serve": {"probe_rate": -0.1}}"#).unwrap_err();
        assert!(format!("{e}").contains("probe_rate"), "{e}");
        let e = RunConfig::parse(r#"{"serve": {"listen": "no-port"}}"#).unwrap_err();
        assert!(format!("{e}").contains("listen"), "{e}");
    }

    #[test]
    fn serve_http_block_parses_and_validates() {
        let c = RunConfig::parse(
            r#"{"serve": {"http": {"addr": "0.0.0.0:8080", "queue_depth": 32,
                                   "in_flight": 64, "tenant_rate": 10.5,
                                   "tenant_burst": 4}}}"#,
        )
        .unwrap();
        let h = c.serve.http.unwrap();
        assert_eq!(h.addr, "0.0.0.0:8080");
        assert_eq!(h.queue_depth, 32);
        assert_eq!(h.in_flight, 64);
        assert!((h.tenant_rate - 10.5).abs() < 1e-12);
        assert!((h.tenant_burst - 4.0).abs() < 1e-12);
        // Omitted knobs keep HttpConfig defaults; omitted block stays None.
        let d = RunConfig::parse(r#"{"serve": {"http": {"addr": "127.0.0.1:0"}}}"#).unwrap();
        let h = d.serve.http.unwrap();
        assert_eq!((h.queue_depth, h.in_flight), (256, 512));
        assert_eq!(h.tenant_rate, 0.0, "rate limiting off by default");
        assert_eq!(RunConfig::parse("{}").unwrap().serve.http, None);
        // Rejections name the offending key.
        let e = RunConfig::parse(r#"{"serve": {"http": {}}}"#).unwrap_err();
        assert!(format!("{e}").contains("addr"), "{e}");
        let e = RunConfig::parse(r#"{"serve": {"http": {"addr": "no-port"}}}"#).unwrap_err();
        assert!(format!("{e}").contains("serve.http.addr"), "{e}");
        let e = RunConfig::parse(
            r#"{"serve": {"http": {"addr": "h:1", "queue_depth": 0}}}"#,
        )
        .unwrap_err();
        assert!(format!("{e}").contains("serve.http.queue_depth"), "{e}");
        let e = RunConfig::parse(
            r#"{"serve": {"http": {"addr": "h:1", "in_flight": 0}}}"#,
        )
        .unwrap_err();
        assert!(format!("{e}").contains("serve.http.in_flight"), "{e}");
        let e = RunConfig::parse(
            r#"{"serve": {"http": {"addr": "h:1", "tenant_rate": -1}}}"#,
        )
        .unwrap_err();
        assert!(format!("{e}").contains("serve.http.tenant_rate"), "{e}");
        let e = RunConfig::parse(
            r#"{"serve": {"http": {"addr": "h:1", "tenant_burst": 0.5}}}"#,
        )
        .unwrap_err();
        assert!(format!("{e}").contains("serve.http.tenant_burst"), "{e}");
        // Unknown keys inside the block are typo-checked like any other.
        let e = RunConfig::parse(
            r#"{"serve": {"http": {"addr": "h:1", "que_depth": 9}}}"#,
        )
        .unwrap_err();
        assert!(format!("{e}").contains("serve.http"), "{e}");
    }

    #[test]
    fn serve_topology_parses_and_wins_over_backend() {
        let c = RunConfig::parse(
            r#"{"serve": {"backend": "single", "topology": "2x(pipeline:3)"}}"#,
        )
        .unwrap();
        let t = c.serve.topology.clone().unwrap();
        assert_eq!(t.to_string(), "2x(pipeline:3)");
        assert_eq!(t.dies(), 6);
        assert_eq!(
            c.serve.tree(crate::fleet::RoutePolicy::RoundRobin).to_string(),
            "2x(pipeline:3)"
        );
        // Spellings are case-insensitive across backend and topology.
        let c = RunConfig::parse(
            r#"{"serve": {"backend": "Replicated", "topology": "4X(DIE)@Weighted"}}"#,
        )
        .unwrap();
        assert_eq!(c.serve.backend, crate::serve::BackendKind::Replicated);
        assert_eq!(c.serve.topology.unwrap().to_string(), "4x(die)@weighted");
    }

    #[test]
    fn zero_sized_farms_rejected_with_clear_errors() {
        let e = RunConfig::parse(r#"{"fleet": {"chips": 0}}"#).unwrap_err();
        assert!(format!("{e}").contains("fleet.chips"), "{e}");
        let e = RunConfig::parse(r#"{"serve": {"chips": 0}}"#).unwrap_err();
        assert!(format!("{e}").contains("serve.chips"), "{e}");
        let e = RunConfig::parse(r#"{"serve": {"shards": 0}}"#).unwrap_err();
        assert!(format!("{e}").contains("serve.shards"), "{e}");
        let e = RunConfig::parse(r#"{"serve": {"batch": 0}}"#).unwrap_err();
        assert!(format!("{e}").contains("serve.batch"), "{e}");
        let e = RunConfig::parse(r#"{"serve": {"trial_block": 0}}"#).unwrap_err();
        assert!(format!("{e}").contains("serve.trial_block"), "{e}");
        // Zero-sized topology nodes are rejected at parse, like the above.
        let e = RunConfig::parse(r#"{"serve": {"topology": "0x(die)"}}"#).unwrap_err();
        assert!(format!("{e:#}").contains("at least 1"), "{e:#}");
        let e = RunConfig::parse(r#"{"serve": {"topology": "pipeline:0"}}"#).unwrap_err();
        assert!(format!("{e:#}").contains("at least one die"), "{e:#}");
        // Unknown spellings list the valid ones.
        let e = RunConfig::parse(r#"{"serve": {"backend": "sharded"}}"#).unwrap_err();
        assert!(format!("{e:#}").contains("single, replicated, pipelined"), "{e:#}");
    }

    #[test]
    fn artifacts_key_requires_an_existing_directory() {
        // Any directory that certainly exists works as the value.
        let dir = std::env::temp_dir();
        let c = RunConfig::parse(&format!(r#"{{"artifacts": "{}"}}"#, dir.display())).unwrap();
        assert_eq!(c.artifacts.as_deref(), Some(dir.as_path()));
        assert_eq!(RunConfig::parse("{}").unwrap().artifacts, None);
        // A missing directory or a non-string value is rejected at parse.
        let e = RunConfig::parse(r#"{"artifacts": "/no/such/raca/dir"}"#).unwrap_err();
        assert!(format!("{e}").contains("does not exist"), "{e}");
        let e = RunConfig::parse(r#"{"artifacts": 7}"#).unwrap_err();
        assert!(format!("{e}").contains("directory path"), "{e}");
    }

    #[test]
    fn fleet_section_parses() {
        let c = RunConfig::parse(
            r#"{"fleet": {"chips": 4, "sigma": 0.05, "policy": "least-loaded",
                          "cal_images": 32, "serve_trials": 5, "seed": 99}}"#,
        )
        .unwrap();
        assert_eq!(c.fleet.chips, 4);
        assert!((c.fleet.sigma - 0.05).abs() < 1e-12);
        assert_eq!(c.fleet.policy, crate::fleet::RoutePolicy::LeastLoaded);
        assert_eq!(c.fleet.cal_images, 32);
        assert_eq!(c.fleet.serve_trials, 5);
        assert_eq!(c.fleet.seed, 99);
        // Untouched keys keep their defaults.
        assert_eq!(c.fleet.cal_trials, FleetConfig::default().cal_trials);
    }
}
