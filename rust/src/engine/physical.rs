//! Physical (SI-unit) engine — the full analog simulation.
//!
//! Every layer is a [`TiledLayer`] of programmed 128×128 crossbars; reads
//! return amperes with Johnson–Nyquist (and optionally shot/RTN/1-f)
//! noise; TIAs convert to volts; comparators binarize; the output layer
//! runs the transient WTA race where *each time step is a fresh analog
//! read* of the output crossbar.
//!
//! At the calibrated design point this engine is statistically identical
//! to [`super::NativeEngine`] (engine_parity tests); its purpose is the
//! non-ideality ablations (device variation, extra noise sources, tile
//! size) that the normalized model cannot express.

use crate::crossbar::{ReadMode, TiledLayer, WeightMapping, TILE};
use crate::device::noise::NoiseParams;
use crate::device::variation::VariationModel;
use crate::neuron::WtaOutcome;
use crate::nn::Weights;
use crate::stats::{GaussianSource, Rng};

use super::{TrialEngine, TrialParams};

/// Per-layer physical configuration derived from calibration.
#[derive(Debug, Clone)]
pub struct LayerPhys {
    /// Calibrated read voltage [V].
    pub vr: f64,
    /// Column noise RMS [A] at the idealized design point (diagnostics).
    pub sigma_i: f64,
}

/// Full analog-simulation engine.
pub struct PhysicalEngine {
    pub spec: crate::nn::ModelSpec,
    layers: Vec<TiledLayer>,
    phys: Vec<LayerPhys>,
    pub mapping: WeightMapping,
    pub read_mode: ReadMode,
    pub delta_f: f64,
    pub seed: u64,
}

impl PhysicalEngine {
    /// Program all layers from trained weights.
    ///
    /// `variation`/`noise` select the non-ideality corner; `snr_scale`
    /// scales the read voltage away from the calibrated point (Fig. 6a).
    pub fn program(
        weights: &Weights,
        tile: usize,
        variation: &VariationModel,
        noise: &NoiseParams,
        snr_scale: f64,
        seed: u64,
    ) -> Self {
        let mapping = WeightMapping::default();
        let mut gauss = GaussianSource::new(seed ^ 0xA11A);
        let mut layers = Vec::new();
        let mut phys = Vec::new();
        for l in 0..weights.spec.num_layers() {
            let (rows, cols, w) = weights.layer(l);
            layers.push(TiledLayer::program(
                rows, cols, w, tile, mapping.clone(), variation, noise, &mut gauss,
            ));
            let vr = mapping.calibrate_vr(rows, noise.delta_f, snr_scale);
            let sigma_i = mapping.column_noise_sigma(rows, noise.delta_f);
            phys.push(LayerPhys { vr, sigma_i });
        }
        Self {
            spec: weights.spec.clone(),
            layers,
            phys,
            mapping,
            read_mode: ReadMode::ColumnAggregate,
            delta_f: noise.delta_f,
            seed,
        }
    }

    /// Default paper configuration: 128×128 tiles, thermal-only noise,
    /// ideal programming, calibrated SNR.
    pub fn paper_default(weights: &Weights, seed: u64) -> Self {
        Self::program(
            weights,
            TILE,
            &VariationModel::default(),
            &NoiseParams::thermal_only(crate::device::DELTA_F),
            1.0,
            seed,
        )
    }

    /// One decision trial on one image (SI-unit simulation end to end).
    pub fn trial(&mut self, x: &[f32], p: TrialParams, trial_idx: u64) -> i32 {
        let mut gauss = GaussianSource::from_rng(Rng::new(
            self.seed ^ trial_idx.wrapping_mul(0x9E3779B97F4A7C15),
        ));
        self.trial_with(x, p, &mut gauss)
    }

    /// Trial with an explicit noise source.
    pub fn trial_with(&mut self, x: &[f32], p: TrialParams, gauss: &mut GaussianSource) -> i32 {
        let n_layers = self.spec.num_layers();
        // --- hidden layers: drive, read, compare ---------------------------
        let mut h: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        for l in 0..n_layers - 1 {
            let vr = self.phys[l].vr;
            let rows = self.spec.n_col(l);
            let cols = self.spec.widths[l + 1];
            // Input drive: activations (or pixels, layer 0) scaled to Vr;
            // bias row driven at full Vr.
            let mut v = Vec::with_capacity(rows);
            v.extend(h.iter().map(|&a| a * vr));
            v.push(vr);
            let mut i_diff = vec![0.0f64; cols];
            self.layers[l].read_differential(&v, self.read_mode, &mut i_diff, gauss);
            // Comparator on each column: fire iff I_diff > 0 (the TIA gain
            // is positive and offset-free, so voltage/current sign agree).
            h = i_diff.iter().map(|&i| if i > 0.0 { 1.0 } else { 0.0 }).collect();
        }
        // --- output layer: transient WTA, fresh read per step -------------
        let l = n_layers - 1;
        let vr = self.phys[l].vr;
        let rows = self.spec.n_col(l);
        let cols = self.spec.output_dim();
        let mut v = Vec::with_capacity(rows);
        v.extend(h.iter().map(|&a| a * vr));
        v.push(vr);
        // Normalized threshold θ (z units) → current units.  One z unit of
        // differential current is Vr·G0 (Eq. 12), so θ_I = θ·Vr·G0.  The
        // threshold is derived from a replica column driven at the same
        // Vr, so it co-scales with the read voltage and θ stays fixed in z
        // units across SNR sweeps — matching `NativeEngine` for every
        // snr_scale (engine_parity holds the two to this).
        let i_unit = vr * self.mapping.g0();
        let theta_i = p.theta as f64 * i_unit;
        let mut i_diff = vec![0.0f64; cols];
        let mut mean_i = vec![0.0f64; cols];
        self.layers[l].mean_differential(&v, &mut mean_i);
        let mean = mean_i.iter().sum::<f64>() / cols as f64;
        for _ in 0..p.wta_steps {
            self.layers[l].read_differential(&v, self.read_mode, &mut i_diff, gauss);
            let mut winner = -1i32;
            let mut best = f64::NEG_INFINITY;
            for (j, &ij) in i_diff.iter().enumerate() {
                let d = ij - mean - theta_i;
                if d > 0.0 && d > best {
                    best = d;
                    winner = j as i32;
                }
            }
            if winner >= 0 {
                return winner;
            }
        }
        -1
    }

    /// Repeated decisions with cumulative counting.
    pub fn infer(&mut self, x: &[f32], p: TrialParams, trials: usize, base: u64) -> WtaOutcome {
        let mut out = WtaOutcome::new(self.spec.output_dim());
        for t in 0..trials {
            out.record(self.trial(x, p, base.wrapping_add(t as u64)));
        }
        out
    }

    /// Total programmed conductance (hw-model energy input).
    pub fn total_conductance(&self) -> f64 {
        self.layers
            .iter()
            .map(|t| t.tiles.iter().flatten().map(|a| a.total_g()).sum::<f64>())
            .sum()
    }

    /// Physical tile count per layer (hw model / DESIGN §5 E-ABL3).
    pub fn tile_counts(&self) -> Vec<usize> {
        self.layers.iter().map(|t| t.num_tiles()).collect()
    }

    /// Per-layer calibration record: (read voltage [V], column σ_I [A]).
    pub fn calibration(&self) -> Vec<(f64, f64)> {
        self.phys.iter().map(|p| (p.vr, p.sigma_i)).collect()
    }
}

impl TrialEngine for PhysicalEngine {
    fn output_dim(&self) -> usize {
        self.spec.output_dim()
    }

    fn trial(&mut self, x: &[f32], p: TrialParams, trial_idx: u64) -> i32 {
        PhysicalEngine::trial(self, x, p, trial_idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ModelSpec;

    fn tiny() -> PhysicalEngine {
        let w = Weights::random(ModelSpec::new(vec![12, 8, 6, 4]), 5);
        PhysicalEngine::program(
            &w,
            8,
            &VariationModel::default(),
            &NoiseParams::thermal_only(1e9),
            1.0,
            11,
        )
    }

    #[test]
    fn trial_returns_valid_class() {
        let mut e = tiny();
        let x = vec![0.5f32; 12];
        for t in 0..20 {
            let w = e.trial(&x, TrialParams::default(), t);
            assert!((-1..4).contains(&w));
        }
    }

    #[test]
    fn deterministic_per_trial_index() {
        let mut e = tiny();
        let x = vec![0.3f32; 12];
        let a = e.trial(&x, TrialParams::default(), 3);
        let b = e.trial(&x, TrialParams::default(), 3);
        assert_eq!(a, b);
    }

    #[test]
    fn tile_counts_match_geometry() {
        let e = tiny();
        // layers: (13,8), (9,6), (7,4) with tile=8:
        assert_eq!(e.tile_counts(), vec![2, 2, 1]);
    }

    #[test]
    fn sigmoid_statistics_match_analytic() {
        // Single-column physical layer: firing frequency ≈ Φ(κ·z).
        let spec = ModelSpec::new(vec![4, 1]);
        let mut w = Weights::random(spec, 1);
        w.mats[0] = vec![0.8, 0.8, 0.8, 0.8, 0.0]; // z = Σ x·0.8, bias 0
        let mut e = PhysicalEngine::program(
            &w,
            8,
            &VariationModel::default(),
            &NoiseParams::thermal_only(1e9),
            1.0,
            3,
        );
        // Drive all inputs at 1 → z = 3.2... but the single (output) layer
        // in this net is the WTA layer; instead probe via raw reads:
        let vr = e.phys[0].vr;
        let v = vec![vr, vr, vr, vr, vr];
        let mut out = vec![0.0f64];
        let mut gauss = GaussianSource::new(9);
        let mut fired = 0usize;
        let n = 30_000;
        for _ in 0..n {
            e.layers[0].read_differential(&v, ReadMode::ColumnAggregate, &mut out, &mut gauss);
            if out[0] > 0.0 {
                fired += 1;
            }
        }
        let p_hat = fired as f64 / n as f64;
        let kappa = e.mapping.kappa(vr, 5, 1e9);
        let z = 0.8 * 4.0;
        let want = crate::stats::erf::norm_cdf(kappa * z);
        assert!((p_hat - want).abs() < 0.015, "p={p_hat} want={want}");
    }

    #[test]
    fn variation_changes_decisions() {
        let w = Weights::random(ModelSpec::new(vec![12, 8, 6, 4]), 5);
        let mut ideal = PhysicalEngine::paper_default(&w, 1);
        let mut varied = PhysicalEngine::program(
            &w,
            TILE,
            &VariationModel::lognormal(0.3),
            &NoiseParams::thermal_only(1e9),
            1.0,
            1,
        );
        let x = vec![0.5f32; 12];
        let p = TrialParams::default();
        let a: Vec<i32> = (0..100).map(|t| ideal.trial(&x, p, t)).collect();
        let b: Vec<i32> = (0..100).map(|t| varied.trial(&x, p, t)).collect();
        assert_ne!(a, b, "30% variation should perturb at least one decision");
    }
}
