//! Inference engines (DESIGN.md §4.7).
//!
//! Three implementations of the same trial semantics:
//!
//! * [`native::NativeEngine`] — normalized-unit stochastic forward in pure
//!   rust (fast, Send, used by the coordinator's worker pool and the
//!   Fig. 4/6 sweeps),
//! * [`physical::PhysicalEngine`] — full analog simulation in SI units
//!   (tiled crossbars, TIA, comparator, transient WTA; used for
//!   validation and the non-ideality ablations),
//! * `xla::XlaEngine` (feature `pjrt`) — the AOT-compiled L1/L2 HLO
//!   running on PJRT (a dedicated worker thread owns the non-Send PJRT
//!   state and serves requests over channels).
//!
//! All three are statistically interchangeable at the calibrated design
//! point — `rust/tests/engine_parity.rs` holds them to that.
//!
//! [`TrialEngine`] abstracts over the in-process engines so higher layers
//! (notably the [`crate::fleet`] subsystem) are generic over native vs
//! physical chips.

pub mod native;
pub mod physical;
#[cfg(feature = "pjrt")]
pub mod xla;

pub use native::{
    trial_rng, wta_race, wta_race_block, wta_race_centered, NativeEngine, DEFAULT_TRIAL_BLOCK,
};
pub use physical::PhysicalEngine;
#[cfg(feature = "pjrt")]
pub use xla::{XlaEngine, XlaEngineHandle};

use crate::neuron::WtaOutcome;

/// Parameters of one stochastic trial batch (normalized units).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrialParams {
    /// Comparator noise std in z units: 1.702/snr_scale.
    pub sigma_z: f32,
    /// Normalized WTA rest threshold (mean-relative).
    pub theta: f32,
    /// Time steps per WTA decision.
    pub wta_steps: usize,
}

impl Default for TrialParams {
    fn default() -> Self {
        Self { sigma_z: 1.702, theta: 3.0, wta_steps: 64 }
    }
}

impl TrialParams {
    /// Design point at a given SNR scale (Fig. 6a sweeps this).  Takes
    /// `f32` like every other trial knob — `sigma_z` is f32, so a f64
    /// scale only added a silent precision-laundering cast.
    pub fn with_snr_scale(snr_scale: f32) -> Self {
        Self { sigma_z: 1.702 / snr_scale, ..Default::default() }
    }

    /// Paper's V_th0 = 0 ablation (threshold at the static mean).
    pub fn with_theta(mut self, theta: f32) -> Self {
        self.theta = theta;
        self
    }

    /// Scaled comparator noise (per-chip SNR calibration knob).
    pub fn with_sigma_scale(mut self, scale: f32) -> Self {
        self.sigma_z *= scale;
        self
    }
}

/// One in-process RACA trial engine: repeated stochastic WTA decisions on
/// single images.
///
/// `&mut self` because the physical engine mutates per-read noise state;
/// the native engine implements it by delegating to its `&self` methods.
/// Fleet chips ([`crate::fleet::Chip`]) are generic over this trait.
pub trait TrialEngine: Send {
    /// Number of output classes.
    fn output_dim(&self) -> usize;

    /// One decision trial on one image; `trial_idx` selects the RNG
    /// stream, so equal indices reproduce bit-identical decisions.
    fn trial(&mut self, x: &[f32], p: TrialParams, trial_idx: u64) -> i32;

    /// `trials` repeated decisions accumulated into vote counts.
    fn infer(&mut self, x: &[f32], p: TrialParams, trials: usize, base_trial: u64) -> WtaOutcome {
        let mut out = WtaOutcome::new(self.output_dim());
        for t in 0..trials {
            out.record(self.trial(x, p, base_trial.wrapping_add(t as u64)));
        }
        out
    }

    /// Winners for explicit per-trial stream indices on one image, in
    /// index order.  The default loops [`TrialEngine::trial`]; engines
    /// with a trial-blocked kernel (the native engine) override it so
    /// batch shards ([`crate::fleet::FleetRunner`]) amortize weight
    /// traffic across every trial of an image.
    fn trial_indices(&mut self, x: &[f32], p: TrialParams, indices: &[u64]) -> Vec<i32> {
        indices.iter().map(|&t| self.trial(x, p, t)).collect()
    }
}

/// Group row indices of a packed `rows × features` batch whose feature
/// slices are bit-identical — i.e. trials of the same image.  The blocked
/// kernel shares one cached layer-0 pre-activation (and one weight sweep
/// per block) within each group; each row keeps its own trial stream, so
/// grouping never changes a winner.  Grouping order is first occurrence,
/// so results are deterministic.
pub fn group_equal_rows(x: &[f32], features: usize, rows: usize) -> Vec<Vec<usize>> {
    let mut groups: Vec<Vec<usize>> = Vec::new();
    let mut hashes: Vec<u64> = Vec::new();
    for r in 0..rows {
        let row = &x[r * features..(r + 1) * features];
        // FNV-1a over the raw f32 bit patterns (cheap prefilter; equality
        // is verified against the group representative before joining).
        let mut h = 0xcbf29ce484222325u64;
        for &v in row {
            h ^= v.to_bits() as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        let mut placed = false;
        for (gi, g) in groups.iter_mut().enumerate() {
            if hashes[gi] == h && &x[g[0] * features..(g[0] + 1) * features] == row {
                g.push(r);
                placed = true;
                break;
            }
        }
        if !placed {
            groups.push(vec![r]);
            hashes.push(h);
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{ModelSpec, Weights};
    use std::sync::Arc;

    fn engines() -> (NativeEngine, PhysicalEngine) {
        let w = Weights::random(ModelSpec::new(vec![8, 6, 4]), 3);
        let native = NativeEngine::new(Arc::new(w.clone()), 7);
        let physical = PhysicalEngine::paper_default(&w, 7);
        (native, physical)
    }

    #[test]
    fn trait_objects_cover_native_and_physical() {
        let (native, physical) = engines();
        let mut dyn_engines: Vec<Box<dyn TrialEngine>> =
            vec![Box::new(native), Box::new(physical)];
        let x = vec![0.4f32; 8];
        for e in dyn_engines.iter_mut() {
            assert_eq!(e.output_dim(), 4);
            let o = e.infer(&x, TrialParams::default(), 20, 0);
            assert_eq!(o.trials, 20);
            let again = e.trial(&x, TrialParams::default(), 5);
            assert_eq!(again, e.trial(&x, TrialParams::default(), 5));
        }
    }

    #[test]
    fn sigma_scale_multiplies() {
        let p = TrialParams::default().with_sigma_scale(0.5);
        assert!((p.sigma_z - 0.851).abs() < 1e-4);
    }

    #[test]
    fn group_equal_rows_groups_repeated_images() {
        let a = [0.1f32, 0.2, 0.3];
        let b = [0.4f32, 0.5, 0.6];
        let mut x = Vec::new();
        for r in [&a, &b, &a, &a, &b] {
            x.extend_from_slice(r);
        }
        let g = group_equal_rows(&x, 3, 5);
        assert_eq!(g, vec![vec![0, 2, 3], vec![1, 4]]);
        // All-distinct batches degrade to singleton groups, in row order.
        let x: Vec<f32> = (0..9).map(|i| i as f32).collect();
        assert_eq!(group_equal_rows(&x, 3, 3), vec![vec![0], vec![1], vec![2]]);
    }

    #[test]
    fn trial_indices_default_matches_trial_loop() {
        let (_, physical) = engines();
        let mut e: Box<dyn TrialEngine> = Box::new(physical);
        let x = vec![0.4f32; 8];
        let p = TrialParams::default();
        let idx = [3u64, 9, 3, 40];
        let got = e.trial_indices(&x, p, &idx);
        let want: Vec<i32> = idx.iter().map(|&t| e.trial(&x, p, t)).collect();
        assert_eq!(got, want);
    }
}
