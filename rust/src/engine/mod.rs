//! Inference engines (DESIGN.md §4.7).
//!
//! Three implementations of the same trial semantics:
//!
//! * [`native::NativeEngine`] — normalized-unit stochastic forward in pure
//!   rust (fast, Send, used by the coordinator's worker pool and the
//!   Fig. 4/6 sweeps),
//! * [`physical::PhysicalEngine`] — full analog simulation in SI units
//!   (tiled crossbars, TIA, comparator, transient WTA; used for
//!   validation and the non-ideality ablations),
//! * [`xla::XlaEngine`] — the AOT-compiled L1/L2 HLO running on PJRT (the
//!   production path; a dedicated worker thread owns the non-Send PJRT
//!   state and serves requests over channels).
//!
//! All three are statistically interchangeable at the calibrated design
//! point — `rust/tests/engine_parity.rs` holds them to that.

pub mod native;
pub mod physical;
pub mod xla;

pub use native::NativeEngine;
pub use physical::PhysicalEngine;
pub use xla::{XlaEngine, XlaEngineHandle};

/// Parameters of one stochastic trial batch (normalized units).
#[derive(Debug, Clone, Copy)]
pub struct TrialParams {
    /// Comparator noise std in z units: 1.702/snr_scale.
    pub sigma_z: f32,
    /// Normalized WTA rest threshold (mean-relative).
    pub theta: f32,
    /// Time steps per WTA decision.
    pub wta_steps: usize,
}

impl Default for TrialParams {
    fn default() -> Self {
        Self { sigma_z: 1.702, theta: 3.0, wta_steps: 64 }
    }
}

impl TrialParams {
    /// Design point at a given SNR scale (Fig. 6a sweeps this).
    pub fn with_snr_scale(snr_scale: f64) -> Self {
        Self { sigma_z: (1.702 / snr_scale) as f32, ..Default::default() }
    }

    /// Paper's V_th0 = 0 ablation (threshold at the static mean).
    pub fn with_theta(mut self, theta: f32) -> Self {
        self.theta = theta;
        self
    }
}
