//! XLA engine: the AOT-compiled L1/L2 HLO served from a dedicated thread.
//!
//! PJRT state in the `xla` crate is `Rc`-based (not `Send`), so one OS
//! thread owns the [`ArtifactStore`] (client, compiled executables,
//! device-resident weight buffers) and serves trial/ideal requests over an
//! mpsc channel.  [`XlaEngineHandle`] is the cheap, `Clone + Send` side
//! the coordinator and figure harnesses hold.
//!
//! Request path: handle.run_trials(x, …) → channel → worker executes the
//! `trial_fwd_b{B}` executable → winners back over a rendezvous channel.
//! Compile happens lazily on first use of each batch size and never again.

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use crate::runtime::ArtifactStore;

use super::TrialParams;

enum Request {
    Trial {
        x: Vec<f32>,
        batch: usize,
        seed: u32,
        sigma_z: f32,
        theta: f32,
        reply: mpsc::Sender<Result<Vec<i32>>>,
    },
    Ideal {
        x: Vec<f32>,
        batch: usize,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Manifest {
        reply: mpsc::Sender<crate::runtime::Manifest>,
    },
    Warmup {
        batch: usize,
        reply: mpsc::Sender<Result<()>>,
    },
    Shutdown,
}

/// Owner of the worker thread; dropping it shuts the worker down.
pub struct XlaEngine {
    tx: mpsc::Sender<Request>,
    worker: Option<JoinHandle<()>>,
}

/// Cloneable, Send handle used by coordinator workers.
#[derive(Clone)]
pub struct XlaEngineHandle {
    tx: mpsc::Sender<Request>,
    /// Available trial batch sizes (sorted ascending), from the manifest.
    trial_batches: Vec<usize>,
}

impl XlaEngine {
    /// Spawn the worker over the given artifact directory.
    pub fn start(artifact_dir: std::path::PathBuf) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::Builder::new()
            .name("xla-engine".into())
            .spawn(move || worker_main(artifact_dir, rx, ready_tx))
            .context("spawning xla-engine thread")?;
        ready_rx
            .recv()
            .context("xla-engine thread died during startup")??;
        Ok(Self { tx, worker: Some(worker) })
    }

    fn manifest_batches(tx: &mpsc::Sender<Request>) -> Vec<usize> {
        let (reply, rx) = mpsc::channel();
        if tx.send(Request::Manifest { reply }).is_err() {
            return vec![];
        }
        let mut b = rx.recv().map(|m| m.trial_batches).unwrap_or_default();
        b.sort_unstable();
        b
    }

    /// Start over the default artifact directory.
    pub fn start_default() -> Result<Self> {
        Self::start(ArtifactStore::default_dir())
    }

    pub fn handle(&self) -> XlaEngineHandle {
        XlaEngineHandle {
            tx: self.tx.clone(),
            trial_batches: Self::manifest_batches(&self.tx),
        }
    }
}

impl Drop for XlaEngine {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl XlaEngineHandle {
    /// Execute one trial batch: `x` is `[batch, 784]` row-major; returns
    /// one winner per row.  `batch` must be an available artifact size —
    /// use [`XlaEngineHandle::run_trials_any`] for arbitrary row counts.
    pub fn run_trials(
        &self,
        x: Vec<f32>,
        batch: usize,
        seed: u32,
        p: TrialParams,
    ) -> Result<Vec<i32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Trial { x, batch, seed, sigma_z: p.sigma_z, theta: p.theta, reply })
            .map_err(|_| anyhow!("xla engine is gone"))?;
        rx.recv().map_err(|_| anyhow!("xla engine dropped the request"))?
    }

    /// Execute an arbitrary number of rows by padding up to the smallest
    /// available artifact batch (padding rows repeat row 0 and are
    /// discarded) and chunking when rows exceed the largest batch.
    pub fn run_trials_any(
        &self,
        x: &[f32],
        rows: usize,
        features: usize,
        seed: u32,
        p: TrialParams,
    ) -> Result<Vec<i32>> {
        anyhow::ensure!(rows > 0 && x.len() == rows * features, "bad trial input shape");
        if self.trial_batches.contains(&rows) {
            return self.run_trials(x.to_vec(), rows, seed, p);
        }
        let max_b = *self
            .trial_batches
            .last()
            .ok_or_else(|| anyhow!("manifest lists no trial batches"))?;
        if rows > max_b {
            // Chunk recursively.
            let mut out = Vec::with_capacity(rows);
            let mut off = 0usize;
            let mut chunk_idx = 0u32;
            while off < rows {
                let take = max_b.min(rows - off);
                let part = self.run_trials_any(
                    &x[off * features..(off + take) * features],
                    take,
                    features,
                    seed.wrapping_add(chunk_idx.wrapping_mul(0x9E37)),
                    p,
                )?;
                out.extend(part);
                off += take;
                chunk_idx += 1;
            }
            return Ok(out);
        }
        let batch = *self
            .trial_batches
            .iter()
            .find(|&&b| b >= rows)
            .expect("max_b >= rows guaranteed above");
        let mut xp = Vec::with_capacity(batch * features);
        xp.extend_from_slice(x);
        for _ in rows..batch {
            xp.extend_from_slice(&x[..features]);
        }
        let mut winners = self.run_trials(xp, batch, seed, p)?;
        winners.truncate(rows);
        Ok(winners)
    }

    /// Float software forward: `[batch, 784]` → `[batch, 10]` probs.
    pub fn run_ideal(&self, x: Vec<f32>, batch: usize) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Ideal { x, batch, reply })
            .map_err(|_| anyhow!("xla engine is gone"))?;
        rx.recv().map_err(|_| anyhow!("xla engine dropped the request"))?
    }

    /// Fetch the artifact manifest (batch sizes, calibration record).
    pub fn manifest(&self) -> Result<crate::runtime::Manifest> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Manifest { reply })
            .map_err(|_| anyhow!("xla engine is gone"))?;
        rx.recv().map_err(|_| anyhow!("xla engine dropped the request"))
    }

    /// Pre-compile the trial executable for `batch` (off the hot path).
    pub fn warmup(&self, batch: usize) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Warmup { batch, reply })
            .map_err(|_| anyhow!("xla engine is gone"))?;
        rx.recv().map_err(|_| anyhow!("xla engine dropped the request"))?
    }
}

fn worker_main(
    dir: std::path::PathBuf,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<Result<()>>,
) {
    let store = match ArtifactStore::open(&dir) {
        Ok(s) => {
            let _ = ready.send(Ok(()));
            s
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(req) = rx.recv() {
        match req {
            Request::Trial { x, batch, seed, sigma_z, theta, reply } => {
                let res = store
                    .trial(batch)
                    .and_then(|exe| exe.run(&x, seed, sigma_z, theta));
                let _ = reply.send(res);
            }
            Request::Ideal { x, batch, reply } => {
                let res = store.ideal(batch).and_then(|exe| exe.run(&x));
                let _ = reply.send(res);
            }
            Request::Manifest { reply } => {
                let _ = reply.send(store.manifest.clone());
            }
            Request::Warmup { batch, reply } => {
                let _ = reply.send(store.trial(batch).map(|_| ()));
            }
            Request::Shutdown => break,
        }
    }
}
