//! Normalized-unit native engine — the fast pure-rust twin of the L2 HLO.
//!
//! Semantics mirror `python/compile/model.py::raca_trial` exactly:
//! 1. hidden layers: h = 1[z + σ_z·n > 0] per neuron, fresh n per trial;
//! 2. output layer: z_out centered per row (adaptive threshold tracks the
//!    static mean), then a T-step first-crossing WTA race with fresh noise
//!    per step, ties toward the largest instantaneous value, −1 on
//!    timeout.
//!
//! The per-trial RNG is seeded from (engine seed, trial index) so trials
//! are reproducible and embarrassingly parallel — which §Perf iteration 5
//! finally cashes in: [`NativeEngine::infer`] runs the **trial-blocked
//! bit-packed kernel** ([`crate::nn::forward::stochastic_logits_block`]),
//! processing [`NativeEngine::block`] trials per pass (each f32 weight row
//! read once per block instead of once per trial) and sharding blocks
//! across threads via [`crate::figures::common::parallel_map`] with a
//! deterministic merge.  Every trial keeps its private
//! [`trial_rng`]`(seed, base + t)` stream consuming draws in the scalar
//! order, so the blocked path is bit-identical to
//! [`NativeEngine::trial_scratch`] — the same parity contract the
//! pipelined serving backend pins (rust/tests/blocked.rs).
//! [`NativeEngine::infer_scalar`] keeps the one-trial-at-a-time loop as
//! the parity/bench reference.
//!
//! §Perf iteration 6 (explicit SIMD + B=1 fallback): the WTA race runs
//! on the runtime-dispatched kernels of [`crate::util::simd`] — the
//! static `(z_j − mean) − θ` centering is a vector prepass
//! (`center_f32`), hoisted across the whole block in [`wta_race_block`]
//! so one centered buffer serves every trial, and each race step is one
//! batched noise fill plus one `race_step` kernel call.  Both vectorize
//! across the **candidates** (columns) dimension only: the f64 sums
//! `centered[j] + noise[j]` are elementwise, and the kernel returns the
//! first index attaining the step maximum when it clears zero — exactly
//! the scalar ascending scan's strict-`>` winner — so decisions stay
//! bit-identical on every ISA (and under `RACA_NO_SIMD=1`).  Separately,
//! a 1-trial "block" pays bit-pack/unpack overhead for zero weight-reuse
//! amortization, so `block == 1` now routes [`NativeEngine::trials_cached`],
//! [`NativeEngine::infer_cached`] and [`NativeEngine::run_trial_batch`]
//! through the scalar [`NativeEngine::trial_scratch`] loop (bit-identical
//! by the §Perf-5 parity contract, just faster).

use crate::neuron::WtaOutcome;
use crate::nn::{forward, Weights};
use crate::stats::{GaussianSource, Rng};

use super::{group_equal_rows, TrialEngine, TrialParams};

/// Re-export of the kernel's default block size (one `u64` lane).
pub use crate::nn::forward::DEFAULT_TRIAL_BLOCK;

/// Blocks per [`NativeEngine::infer`] call before trial-level threading
/// kicks in (below this, scoped-thread spawn overhead beats the win;
/// figure sweeps already parallelize across images one level up).
const PARALLEL_MIN_BLOCKS: usize = 4;
/// …and never thread a budget this small, whatever the block size.
const PARALLEL_MIN_TRIALS: usize = 256;

/// Pure-rust stochastic inference engine (Send + Sync; clone per worker).
#[derive(Clone)]
pub struct NativeEngine {
    pub weights: std::sync::Arc<Weights>,
    pub seed: u64,
    /// Trials per blocked-kernel pass (≥ 1; the `serve.trial_block`
    /// knob).  Purely a performance parameter: votes are bit-identical at
    /// any value.
    pub block: usize,
}

/// Per-trial RNG stream: one deterministic identity per `(seed, trial
/// index)` pair.  Every execution path that claims bit-parity with this
/// engine — notably each die of the pipelined serving backend — must
/// derive its stream through this function; the mixing constant is
/// load-bearing for those contracts.
pub fn trial_rng(seed: u64, trial_idx: u64) -> Rng {
    Rng::new(seed ^ trial_idx.wrapping_mul(0x9E3779B97F4A7C15))
}

impl NativeEngine {
    pub fn new(weights: std::sync::Arc<Weights>, seed: u64) -> Self {
        Self { weights, seed, block: DEFAULT_TRIAL_BLOCK }
    }

    /// Pin the blocked kernel's trials-per-pass (clamped to ≥ 1).
    pub fn with_trial_block(mut self, block: usize) -> Self {
        self.block = block.max(1);
        self
    }

    /// One decision trial on one image; `trial_idx` selects the RNG stream.
    pub fn trial(&self, x: &[f32], p: TrialParams, trial_idx: u64) -> i32 {
        let mut gauss = GaussianSource::from_rng(trial_rng(self.seed, trial_idx));
        self.trial_with(x, p, &mut gauss)
    }

    /// Precompute the per-image deterministic layer-0 pre-activation
    /// (reused across every trial of that image — §Perf iteration 1).
    pub fn precompute(&self, x: &[f32]) -> Vec<f32> {
        forward::layer0_preactivation(&self.weights, x)
    }

    /// One trial from a cached pre-activation (hot path).
    pub fn trial_cached(&self, z1: &[f32], p: TrialParams, trial_idx: u64) -> i32 {
        let mut scratch = forward::TrialScratch::default();
        self.trial_scratch(z1, p, trial_idx, &mut scratch)
    }

    /// Allocation-free trial over caller-owned scratch (innermost loop).
    pub fn trial_scratch(
        &self,
        z1: &[f32],
        p: TrialParams,
        trial_idx: u64,
        scratch: &mut forward::TrialScratch,
    ) -> i32 {
        let mut gauss = GaussianSource::from_rng(trial_rng(self.seed, trial_idx));
        forward::stochastic_logits_into(&self.weights, z1, p.sigma_z as f64, &mut gauss,
                                        scratch);
        let logits = std::mem::take(&mut scratch.logits);
        let mut centered = std::mem::take(&mut scratch.centered);
        let w = wta_race_centered(&logits, p, &mut gauss, &mut centered);
        scratch.centered = centered;
        scratch.logits = logits;
        w
    }

    /// Trial with an explicit noise source (tests / shared streams).
    pub fn trial_with(&self, x: &[f32], p: TrialParams, gauss: &mut GaussianSource) -> i32 {
        let z = forward::stochastic_logits(&self.weights, x, p.sigma_z as f64, gauss);
        wta_race(&z, p, gauss)
    }

    /// Winners of one trial block (any length) on a cached
    /// pre-activation: seeds one noise stream per index, runs the
    /// bit-packed blocked forward, then races each trial's WTA.  Appends
    /// winners to `out` in index order.  Bit-identical to calling
    /// [`NativeEngine::trial_scratch`] per index.
    pub fn trial_block(
        &self,
        z1: &[f32],
        p: TrialParams,
        indices: &[u64],
        s: &mut forward::BlockScratch,
        out: &mut Vec<i32>,
    ) {
        s.gauss.clear();
        s.gauss.extend(
            indices
                .iter()
                .map(|&t| GaussianSource::from_rng(trial_rng(self.seed, t))),
        );
        forward::stochastic_logits_block(&self.weights, z1, p.sigma_z as f64, s);
        let classes = self.weights.spec.output_dim();
        wta_race_block(&s.logits, classes, p, &mut s.gauss, out);
    }

    /// Winners for arbitrary per-trial stream indices on one cached
    /// pre-activation, processed in blocks of [`NativeEngine::block`].
    /// At `block == 1` the blocked kernel pays bit-pack/unpack overhead
    /// for zero weight-reuse, so the scalar loop runs instead (same
    /// winners — the parity contract makes the paths interchangeable).
    pub fn trials_cached(&self, z1: &[f32], p: TrialParams, indices: &[u64]) -> Vec<i32> {
        let mut out = Vec::with_capacity(indices.len());
        if self.block <= 1 {
            let mut scratch = forward::TrialScratch::default();
            out.extend(indices.iter().map(|&t| self.trial_scratch(z1, p, t, &mut scratch)));
            return out;
        }
        let mut s = forward::BlockScratch::default();
        for chunk in indices.chunks(self.block) {
            self.trial_block(z1, p, chunk, &mut s, &mut out);
        }
        out
    }

    /// `trials` repeated decisions on one image, accumulated into counts.
    /// Runs the trial-blocked kernel over the cached layer-0
    /// pre-activation; large budgets shard whole blocks across threads
    /// (deterministic merge — votes are independent of the thread count).
    pub fn infer(&self, x: &[f32], p: TrialParams, trials: usize, base_trial: u64) -> WtaOutcome {
        let z1 = self.precompute(x);
        self.infer_cached(&z1, p, trials, base_trial)
    }

    /// [`NativeEngine::infer`] over an already-cached pre-activation.
    pub fn infer_cached(
        &self,
        z1: &[f32],
        p: TrialParams,
        trials: usize,
        base_trial: u64,
    ) -> WtaOutcome {
        let mut out = WtaOutcome::new(self.weights.spec.output_dim());
        if trials == 0 {
            return out;
        }
        let block = self.block.max(1);
        // A 1-trial block degenerates to the scalar path (see
        // `trials_cached`); shard threads at the default block size so the
        // fallback still parallelizes in useful grains.
        let shard = if block == 1 { DEFAULT_TRIAL_BLOCK } else { block };
        let n_blocks = trials.div_ceil(shard);
        if n_blocks >= PARALLEL_MIN_BLOCKS && trials >= PARALLEL_MIN_TRIALS {
            // (start index, length) per block; merged in block order.
            let ranges: Vec<(u64, usize)> = (0..n_blocks)
                .map(|b| {
                    (
                        base_trial.wrapping_add((b * shard) as u64),
                        shard.min(trials - b * shard),
                    )
                })
                .collect();
            let winner_blocks =
                crate::figures::common::parallel_map(&ranges, |_, &(lo, len)| {
                    let idx: Vec<u64> = (0..len as u64).map(|k| lo.wrapping_add(k)).collect();
                    self.trials_cached(z1, p, &idx)
                });
            for wb in &winner_blocks {
                for &w in wb {
                    out.record(w);
                }
            }
        } else if block == 1 {
            let mut scratch = forward::TrialScratch::default();
            for t in 0..trials {
                out.record(self.trial_scratch(
                    z1,
                    p,
                    base_trial.wrapping_add(t as u64),
                    &mut scratch,
                ));
            }
        } else {
            let mut s = forward::BlockScratch::default();
            let mut winners = Vec::with_capacity(block);
            let mut idx = Vec::with_capacity(block);
            let mut done = 0usize;
            while done < trials {
                let take = block.min(trials - done);
                idx.clear();
                idx.extend((0..take as u64).map(|k| base_trial.wrapping_add(done as u64 + k)));
                winners.clear();
                self.trial_block(z1, p, &idx, &mut s, &mut winners);
                for &w in &winners {
                    out.record(w);
                }
                done += take;
            }
        }
        out
    }

    /// The pre-iteration-5 one-trial-at-a-time loop: the bit-parity
    /// reference the blocked kernel is held to (rust/tests/blocked.rs),
    /// and the baseline lane of `bench_fleet`'s kernel comparison.
    pub fn infer_scalar(
        &self,
        x: &[f32],
        p: TrialParams,
        trials: usize,
        base_trial: u64,
    ) -> WtaOutcome {
        let z1 = self.precompute(x);
        let mut scratch = forward::TrialScratch::default();
        let mut out = WtaOutcome::new(self.weights.spec.output_dim());
        for t in 0..trials {
            out.record(self.trial_scratch(&z1, p, base_trial.wrapping_add(t as u64), &mut scratch));
        }
        out
    }

    /// Batched API mirroring the XLA trial executable: one trial per row.
    /// Rows carrying the *same image* (the batcher interleaves trials of
    /// in-flight requests round-robin, so a batch usually holds several
    /// trials of each) are grouped and run through the blocked kernel —
    /// each row keeps its own `seed + row` stream, so winners are
    /// bit-identical to the scalar per-row loop.
    pub fn run_trial_batch(&self, x: &[f32], features: usize, p: TrialParams,
                           seed: u64) -> Vec<i32> {
        assert_eq!(x.len() % features, 0);
        let rows = x.len() / features;
        let mut winners = vec![-1i32; rows];
        let mut s = forward::BlockScratch::default();
        let mut scratch = forward::TrialScratch::default();
        let mut group_winners: Vec<i32> = Vec::new();
        for g in group_equal_rows(x, features, rows) {
            let z1 = self.precompute(&x[g[0] * features..(g[0] + 1) * features]);
            if self.block <= 1 {
                // B=1: the scalar loop wins (see `trials_cached`).
                for &r in &g {
                    winners[r] =
                        self.trial_scratch(&z1, p, seed.wrapping_add(r as u64), &mut scratch);
                }
                continue;
            }
            group_winners.clear();
            for chunk in g.chunks(self.block) {
                let idx: Vec<u64> =
                    chunk.iter().map(|&r| seed.wrapping_add(r as u64)).collect();
                self.trial_block(&z1, p, &idx, &mut s, &mut group_winners);
            }
            for (&r, &w) in g.iter().zip(&group_winners) {
                winners[r] = w;
            }
        }
        winners
    }
}

/// The T-step first-crossing WTA race over output logits: threshold at
/// the static row mean plus θ, fresh comparator noise per step, ties
/// toward the largest instantaneous value, −1 on timeout.  Shared by
/// [`NativeEngine`] and the sharded output die of
/// [`crate::serve::PipelinedFleetBackend`] — bit-identical decisions
/// whichever die runs the race.
pub fn wta_race(z: &[f32], p: TrialParams, gauss: &mut GaussianSource) -> i32 {
    let mut centered = Vec::with_capacity(z.len());
    wta_race_centered(z, p, gauss, &mut centered)
}

/// [`wta_race`] over a caller-owned centering buffer.  §Perf iteration 5
/// micro-fix: the per-candidate `(z_j − mean) − θ` term is static across
/// the whole race, yet the old loop recomputed it every step for every
/// candidate — it is now hoisted into `centered`, leaving one
/// multiply-add per candidate per step in the T-step loop.  §Perf
/// iteration 6 runs both the centering prepass and each race step
/// through the dispatched SIMD kernels (the buffer holds the centered
/// row in its first half and the step's batched noise in its second).
pub fn wta_race_centered(
    z: &[f32],
    p: TrialParams,
    gauss: &mut GaussianSource,
    centered: &mut Vec<f64>,
) -> i32 {
    let k = crate::util::simd::active();
    let n = z.len();
    let mean = z.iter().sum::<f32>() / n as f32;
    centered.resize(2 * n, 0.0);
    let (c, noise) = centered.split_at_mut(n);
    (k.center_f32)(z, mean, p.theta as f64, c);
    race_from_centered(c, p, gauss, noise, k)
}

/// The T-step loop over an already-centered candidate row: one batched
/// noise fill plus one `race_step` kernel call per step.  The fill
/// consumes exactly the draws the scalar per-candidate loop would (the
/// `fill ≡ next` pin in `stats::gauss`), and `race_step` returns the
/// scalar scan's winner (first index attaining a `> 0` maximum), so the
/// race is bit-identical to the pre-SIMD loop.
fn race_from_centered(
    centered: &[f64],
    p: TrialParams,
    gauss: &mut GaussianSource,
    noise: &mut [f64],
    k: &crate::util::simd::Kernels,
) -> i32 {
    let sigma = p.sigma_z as f64;
    for _ in 0..p.wta_steps {
        gauss.fill(noise, sigma);
        let winner = (k.race_step)(centered, noise);
        if winner >= 0 {
            return winner;
        }
    }
    -1
}

/// Race every trial of a block: `logits` holds `gauss.len()` trial-major
/// rows of `classes` logits; each trial races with its own noise stream
/// (draw-compatible with per-trial [`wta_race`]).  The per-trial
/// mean/centering is hoisted into one SIMD prepass over the whole block
/// — a single centered buffer (`trials × classes`) plus one shared noise
/// row serve every race.  Winners append to `out` in trial order.
pub fn wta_race_block(
    logits: &[f32],
    classes: usize,
    p: TrialParams,
    gauss: &mut [GaussianSource],
    out: &mut Vec<i32>,
) {
    debug_assert_eq!(logits.len(), classes * gauss.len());
    let k = crate::util::simd::active();
    let n = gauss.len();
    let theta = p.theta as f64;
    let mut centered = vec![0.0f64; n * classes];
    for t in 0..n {
        let z = &logits[t * classes..(t + 1) * classes];
        let mean = z.iter().sum::<f32>() / classes as f32;
        (k.center_f32)(z, mean, theta, &mut centered[t * classes..(t + 1) * classes]);
    }
    let mut noise = vec![0.0f64; classes];
    out.reserve(n);
    for (t, g) in gauss.iter_mut().enumerate() {
        out.push(race_from_centered(
            &centered[t * classes..(t + 1) * classes],
            p,
            g,
            &mut noise,
            k,
        ));
    }
}

impl TrialEngine for NativeEngine {
    fn output_dim(&self) -> usize {
        self.weights.spec.output_dim()
    }

    fn trial(&mut self, x: &[f32], p: TrialParams, trial_idx: u64) -> i32 {
        NativeEngine::trial(self, x, p, trial_idx)
    }

    fn infer(&mut self, x: &[f32], p: TrialParams, trials: usize, base_trial: u64) -> WtaOutcome {
        // Delegate to the inherent fast path (blocked kernel over the
        // cached layer-0 pre-activation).
        NativeEngine::infer(self, x, p, trials, base_trial)
    }

    fn trial_indices(&mut self, x: &[f32], p: TrialParams, indices: &[u64]) -> Vec<i32> {
        let z1 = self.precompute(x);
        self.trials_cached(&z1, p, indices)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ModelSpec;
    use std::sync::Arc;

    fn engine() -> NativeEngine {
        NativeEngine::new(Arc::new(Weights::random(ModelSpec::new(vec![8, 6, 5, 4]), 3)), 7)
    }

    #[test]
    fn deterministic_per_trial_index() {
        let e = engine();
        let x = vec![0.4f32; 8];
        let p = TrialParams::default();
        assert_eq!(e.trial(&x, p, 5), e.trial(&x, p, 5));
    }

    #[test]
    fn trials_vary_across_indices() {
        let e = engine();
        let x = vec![0.4f32; 8];
        let p = TrialParams::default();
        let winners: std::collections::HashSet<i32> =
            (0..200).map(|t| e.trial(&x, p, t)).collect();
        assert!(winners.len() > 1, "stochastic trials all identical");
    }

    #[test]
    fn infer_counts_sum_to_trials() {
        let e = engine();
        let x = vec![0.2f32; 8];
        let o = e.infer(&x, TrialParams::default(), 100, 0);
        let c: u64 = o.counts.iter().sum();
        assert_eq!(c + o.abstentions, 100);
    }

    #[test]
    fn huge_theta_always_abstains() {
        let e = engine();
        let x = vec![0.2f32; 8];
        let p = TrialParams::default().with_theta(1e6);
        let o = e.infer(&x, p, 50, 0);
        assert_eq!(o.abstentions, 50);
        assert_eq!(o.prediction(), -1);
    }

    #[test]
    fn cached_path_matches_uncached_bitexact() {
        // precompute + trial_cached must consume the identical RNG stream
        // as trial() — the §Perf iteration-1 optimization is semantics-
        // preserving by construction.
        let e = engine();
        let x: Vec<f32> = (0..8).map(|i| i as f32 / 9.0).collect();
        let p = TrialParams::default();
        let z1 = e.precompute(&x);
        for t in 0..200 {
            assert_eq!(e.trial(&x, p, t), e.trial_cached(&z1, p, t), "trial {t}");
        }
    }

    #[test]
    fn batch_matches_singles() {
        let e = engine();
        let x: Vec<f32> = (0..24).map(|i| (i % 5) as f32 / 5.0).collect();
        let p = TrialParams::default();
        let batch = e.run_trial_batch(&x, 8, p, 100);
        for (r, &w) in batch.iter().enumerate() {
            assert_eq!(w, e.trial(&x[r * 8..(r + 1) * 8], p, 100 + r as u64));
        }
    }

    #[test]
    fn batch_groups_interleaved_repeats_bitexactly() {
        // The batcher interleaves requests round-robin, so repeated images
        // land on non-adjacent rows; grouping must keep every row's own
        // trial stream (`seed + row`).
        let e = engine();
        let a: Vec<f32> = (0..8).map(|i| i as f32 / 9.0).collect();
        let b: Vec<f32> = (0..8).map(|i| (7 - i) as f32 / 9.0).collect();
        let mut x = Vec::new();
        for img in [&a, &b, &a, &b, &a] {
            x.extend_from_slice(img);
        }
        let p = TrialParams::default();
        let batch = e.run_trial_batch(&x, 8, p, 31);
        for (r, &w) in batch.iter().enumerate() {
            assert_eq!(w, e.trial(&x[r * 8..(r + 1) * 8], p, 31 + r as u64), "row {r}");
        }
    }

    #[test]
    fn blocked_infer_matches_scalar_reference() {
        let e = engine();
        let x: Vec<f32> = (0..8).map(|i| (i % 3) as f32 / 3.0).collect();
        let p = TrialParams::default();
        for block in [1usize, 7, 64] {
            let eb = e.clone().with_trial_block(block);
            for trials in [1usize, 63, 64, 65, 200] {
                let a = eb.infer_scalar(&x, p, trials, 900);
                let b = eb.infer(&x, p, trials, 900);
                assert_eq!(a.counts, b.counts, "block {block}, {trials} trials");
                assert_eq!(a.abstentions, b.abstentions);
            }
        }
        // Large budget → the parallel_map shard path, still bit-identical.
        let a = e.infer_scalar(&x, p, 700, 0);
        let b = e.infer(&x, p, 700, 0);
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.abstentions, b.abstentions);
    }

    #[test]
    fn wta_race_block_matches_per_trial_race() {
        let p = TrialParams::default();
        let classes = 5usize;
        let logits: Vec<f32> = (0..3 * classes).map(|i| ((i * 7) % 11) as f32 - 5.0).collect();
        let mut block: Vec<GaussianSource> =
            (0..3).map(|t| GaussianSource::new(50 + t)).collect();
        let mut out = Vec::new();
        wta_race_block(&logits, classes, p, &mut block, &mut out);
        for t in 0..3usize {
            let mut g = GaussianSource::new(50 + t as u64);
            let want = wta_race(&logits[t * classes..(t + 1) * classes], p, &mut g);
            assert_eq!(out[t], want, "trial {t}");
        }
    }

    #[test]
    fn voting_concentrates() {
        // Majority voting is consistent: two independent 400-trial votes
        // agree on the winner (an untrained random net's stochastic
        // majority class need not equal the *ideal* argmax — that
        // correspondence is only expected for trained, high-margin nets
        // and is checked end-to-end in the integration tests).
        // Plant a dominant output class so the vote has a margin to find
        // (a random net's win distribution can be near-uniform).
        let mut w = Weights::random(ModelSpec::new(vec![8, 6, 5, 4]), 3);
        let last = w.mats.len() - 1;
        let cols = 4;
        for row in 0..6 {
            w.mats[last][row * cols + 2] = 3.0; // boost class 2
        }
        let e = NativeEngine::new(Arc::new(w), 7);
        let x = vec![0.9f32; 8];
        let p = TrialParams::default();
        let a = e.infer(&x, p, 400, 0);
        let b = e.infer(&x, p, 400, 10_000);
        assert_eq!(a.prediction(), b.prediction());
        assert_eq!(a.prediction(), 2);
        // And the winner's lead over runner-up grows with trial count.
        let small = e.infer(&x, p, 40, 20_000);
        let (f1, f2) = a.top_two();
        let (s1, s2) = small.top_two();
        assert!((f1 - f2) as f64 / 400.0 >= (s1 as f64 - s2 as f64) / 40.0 - 0.1);
    }
}
