//! Normalized-unit native engine — the fast pure-rust twin of the L2 HLO.
//!
//! Semantics mirror `python/compile/model.py::raca_trial` exactly:
//! 1. hidden layers: h = 1[z + σ_z·n > 0] per neuron, fresh n per trial;
//! 2. output layer: z_out centered per row (adaptive threshold tracks the
//!    static mean), then a T-step first-crossing WTA race with fresh noise
//!    per step, ties toward the largest instantaneous value, −1 on
//!    timeout.
//!
//! The per-trial RNG is seeded from (engine seed, trial index) so trials
//! are reproducible and embarrassingly parallel.

use crate::neuron::WtaOutcome;
use crate::nn::{forward, Weights};
use crate::stats::{GaussianSource, Rng};

use super::{TrialEngine, TrialParams};

/// Pure-rust stochastic inference engine (Send + Sync; clone per worker).
#[derive(Clone)]
pub struct NativeEngine {
    pub weights: std::sync::Arc<Weights>,
    pub seed: u64,
}

/// Per-trial RNG stream: one deterministic identity per `(seed, trial
/// index)` pair.  Every execution path that claims bit-parity with this
/// engine — notably each die of the pipelined serving backend — must
/// derive its stream through this function; the mixing constant is
/// load-bearing for those contracts.
pub fn trial_rng(seed: u64, trial_idx: u64) -> Rng {
    Rng::new(seed ^ trial_idx.wrapping_mul(0x9E3779B97F4A7C15))
}

impl NativeEngine {
    pub fn new(weights: std::sync::Arc<Weights>, seed: u64) -> Self {
        Self { weights, seed }
    }

    /// One decision trial on one image; `trial_idx` selects the RNG stream.
    pub fn trial(&self, x: &[f32], p: TrialParams, trial_idx: u64) -> i32 {
        let mut gauss = GaussianSource::from_rng(trial_rng(self.seed, trial_idx));
        self.trial_with(x, p, &mut gauss)
    }

    /// Precompute the per-image deterministic layer-0 pre-activation
    /// (reused across every trial of that image — §Perf iteration 1).
    pub fn precompute(&self, x: &[f32]) -> Vec<f32> {
        forward::layer0_preactivation(&self.weights, x)
    }

    /// One trial from a cached pre-activation (hot path).
    pub fn trial_cached(&self, z1: &[f32], p: TrialParams, trial_idx: u64) -> i32 {
        let mut scratch = forward::TrialScratch::default();
        self.trial_scratch(z1, p, trial_idx, &mut scratch)
    }

    /// Allocation-free trial over caller-owned scratch (innermost loop).
    pub fn trial_scratch(
        &self,
        z1: &[f32],
        p: TrialParams,
        trial_idx: u64,
        scratch: &mut forward::TrialScratch,
    ) -> i32 {
        let mut gauss = GaussianSource::from_rng(trial_rng(self.seed, trial_idx));
        forward::stochastic_logits_into(&self.weights, z1, p.sigma_z as f64, &mut gauss,
                                        scratch);
        let logits = std::mem::take(&mut scratch.logits);
        let w = wta_race(&logits, p, &mut gauss);
        scratch.logits = logits;
        w
    }

    /// Trial with an explicit noise source (tests / shared streams).
    pub fn trial_with(&self, x: &[f32], p: TrialParams, gauss: &mut GaussianSource) -> i32 {
        let z = forward::stochastic_logits(&self.weights, x, p.sigma_z as f64, gauss);
        wta_race(&z, p, gauss)
    }

    /// `trials` repeated decisions on one image, accumulated into counts.
    /// Uses the cached layer-0 pre-activation across trials.
    pub fn infer(&self, x: &[f32], p: TrialParams, trials: usize, base_trial: u64) -> WtaOutcome {
        let z1 = self.precompute(x);
        let mut scratch = forward::TrialScratch::default();
        let mut out = WtaOutcome::new(self.weights.spec.output_dim());
        for t in 0..trials {
            out.record(self.trial_scratch(&z1, p, base_trial.wrapping_add(t as u64), &mut scratch));
        }
        out
    }

    /// Batched API mirroring the XLA trial executable: one trial per row.
    pub fn run_trial_batch(&self, x: &[f32], features: usize, p: TrialParams,
                           seed: u64) -> Vec<i32> {
        assert_eq!(x.len() % features, 0);
        let rows = x.len() / features;
        (0..rows)
            .map(|r| self.trial(&x[r * features..(r + 1) * features], p,
                                seed.wrapping_add(r as u64)))
            .collect()
    }
}

/// The T-step first-crossing WTA race over output logits: threshold at
/// the static row mean plus θ, fresh comparator noise per step, ties
/// toward the largest instantaneous value, −1 on timeout.  Shared by
/// [`NativeEngine`] and the sharded output die of
/// [`crate::serve::PipelinedFleetBackend`] — bit-identical decisions
/// whichever die runs the race.
pub fn wta_race(z: &[f32], p: TrialParams, gauss: &mut GaussianSource) -> i32 {
    let mean = z.iter().sum::<f32>() / z.len() as f32;
    let sigma = p.sigma_z as f64;
    let theta = p.theta as f64;
    for _ in 0..p.wta_steps {
        let mut winner = -1i32;
        let mut best = f64::NEG_INFINITY;
        for (j, &zj) in z.iter().enumerate() {
            let v = (zj - mean) as f64 + sigma * gauss.next() - theta;
            if v > 0.0 && v > best {
                best = v;
                winner = j as i32;
            }
        }
        if winner >= 0 {
            return winner;
        }
    }
    -1
}

impl TrialEngine for NativeEngine {
    fn output_dim(&self) -> usize {
        self.weights.spec.output_dim()
    }

    fn trial(&mut self, x: &[f32], p: TrialParams, trial_idx: u64) -> i32 {
        NativeEngine::trial(self, x, p, trial_idx)
    }

    fn infer(&mut self, x: &[f32], p: TrialParams, trials: usize, base_trial: u64) -> WtaOutcome {
        // Delegate to the inherent fast path (cached layer-0 pre-activation).
        NativeEngine::infer(self, x, p, trials, base_trial)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ModelSpec;
    use std::sync::Arc;

    fn engine() -> NativeEngine {
        NativeEngine::new(Arc::new(Weights::random(ModelSpec::new(vec![8, 6, 5, 4]), 3)), 7)
    }

    #[test]
    fn deterministic_per_trial_index() {
        let e = engine();
        let x = vec![0.4f32; 8];
        let p = TrialParams::default();
        assert_eq!(e.trial(&x, p, 5), e.trial(&x, p, 5));
    }

    #[test]
    fn trials_vary_across_indices() {
        let e = engine();
        let x = vec![0.4f32; 8];
        let p = TrialParams::default();
        let winners: std::collections::HashSet<i32> =
            (0..200).map(|t| e.trial(&x, p, t)).collect();
        assert!(winners.len() > 1, "stochastic trials all identical");
    }

    #[test]
    fn infer_counts_sum_to_trials() {
        let e = engine();
        let x = vec![0.2f32; 8];
        let o = e.infer(&x, TrialParams::default(), 100, 0);
        let c: u64 = o.counts.iter().sum();
        assert_eq!(c + o.abstentions, 100);
    }

    #[test]
    fn huge_theta_always_abstains() {
        let e = engine();
        let x = vec![0.2f32; 8];
        let p = TrialParams::default().with_theta(1e6);
        let o = e.infer(&x, p, 50, 0);
        assert_eq!(o.abstentions, 50);
        assert_eq!(o.prediction(), -1);
    }

    #[test]
    fn cached_path_matches_uncached_bitexact() {
        // precompute + trial_cached must consume the identical RNG stream
        // as trial() — the §Perf iteration-1 optimization is semantics-
        // preserving by construction.
        let e = engine();
        let x: Vec<f32> = (0..8).map(|i| i as f32 / 9.0).collect();
        let p = TrialParams::default();
        let z1 = e.precompute(&x);
        for t in 0..200 {
            assert_eq!(e.trial(&x, p, t), e.trial_cached(&z1, p, t), "trial {t}");
        }
    }

    #[test]
    fn batch_matches_singles() {
        let e = engine();
        let x: Vec<f32> = (0..24).map(|i| (i % 5) as f32 / 5.0).collect();
        let p = TrialParams::default();
        let batch = e.run_trial_batch(&x, 8, p, 100);
        for (r, &w) in batch.iter().enumerate() {
            assert_eq!(w, e.trial(&x[r * 8..(r + 1) * 8], p, 100 + r as u64));
        }
    }

    #[test]
    fn voting_concentrates() {
        // Majority voting is consistent: two independent 400-trial votes
        // agree on the winner (an untrained random net's stochastic
        // majority class need not equal the *ideal* argmax — that
        // correspondence is only expected for trained, high-margin nets
        // and is checked end-to-end in the integration tests).
        // Plant a dominant output class so the vote has a margin to find
        // (a random net's win distribution can be near-uniform).
        let mut w = Weights::random(ModelSpec::new(vec![8, 6, 5, 4]), 3);
        let last = w.mats.len() - 1;
        let cols = 4;
        for row in 0..6 {
            w.mats[last][row * cols + 2] = 3.0; // boost class 2
        }
        let e = NativeEngine::new(Arc::new(w), 7);
        let x = vec![0.9f32; 8];
        let p = TrialParams::default();
        let a = e.infer(&x, p, 400, 0);
        let b = e.infer(&x, p, 400, 10_000);
        assert_eq!(a.prediction(), b.prediction());
        assert_eq!(a.prediction(), 2);
        // And the winner's lead over runner-up grows with trial count.
        let small = e.infer(&x, p, 40, 20_000);
        let (f1, f2) = a.top_two();
        let (s1, s2) = small.top_two();
        assert!((f1 - f2) as f64 / 400.0 >= (s1 as f64 - s2 as f64) / 40.0 - 0.1);
    }
}
