//! Recursive per-node metrics: a topology-shaped tree of snapshots.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::coordinator::MetricsSnapshot;
use crate::util::json::{self, Json};

/// Per-node annotations a parent attaches to a child subtree: the facts
/// only the *router* above a node can know (queue wait, traffic weight,
/// eviction verdicts) plus liveness facts only the node itself can know
/// (`stale`).  Every field is optional so a bare leaf stays cheap.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NodeNotes {
    /// Mean on-chip service time per request, µs (excludes queue wait).
    pub service_us: Option<f64>,
    /// Mean queue wait per request, µs (dispatch → start of service).
    pub queue_wait_us: Option<f64>,
    /// Rolling accuracy on labeled probe traffic, [0, 1].
    pub probe_accuracy: Option<f64>,
    /// Health monitor evicted this child from the rotation.
    pub evicted: Option<bool>,
    /// In-band `InferResponse::failed` responses relayed from this child.
    pub errors: Option<u64>,
    /// Current traffic weight under the router's steering policy.
    pub weight: Option<f64>,
    /// Registry bundle id this leaf was resolved from
    /// (`remote:@<registry>/<bundle>` topology leaves).
    pub bundle: Option<String>,
    /// Snapshot is a cached copy — the live source (a remote session)
    /// is gone and these numbers stopped advancing at disconnect.
    pub stale: bool,
    /// A remote session dropped and its supervisor is mid-redial:
    /// in-flight requests are retained for resubmission, new submits
    /// fail fast, and the leaf may come back on its own.
    pub reconnecting: bool,
}

impl NodeNotes {
    pub fn is_empty(&self) -> bool {
        *self == NodeNotes::default()
    }
}

/// A node's own [`MetricsSnapshot`] plus labeled child subtrees — the
/// recursive replacement for the flat fleet report.  Shape mirrors the
/// deployment [`crate::serve::Topology`]: routers list one child per
/// replica, pipelines one per stage, remote leaves forward the peer's
/// whole subtree.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsTree {
    /// Short node name: `die#3`, `stage1 [layers 1..3]`,
    /// `remote:host:port`, `replicate ×2 (round-robin)`, …
    pub label: String,
    /// This node's own (already child-aggregated) counters.
    pub snapshot: MetricsSnapshot,
    /// Parent- and self-reported annotations.
    pub notes: NodeNotes,
    pub children: Vec<MetricsTree>,
}

impl MetricsTree {
    pub fn leaf(label: impl Into<String>, snapshot: MetricsSnapshot) -> Self {
        Self { label: label.into(), snapshot, notes: NodeNotes::default(), children: Vec::new() }
    }

    pub fn with_children(mut self, children: Vec<MetricsTree>) -> Self {
        self.children = children;
        self
    }

    /// Number of nodes in the subtree (including self).
    pub fn num_nodes(&self) -> usize {
        1 + self.children.iter().map(|c| c.num_nodes()).sum::<usize>()
    }

    /// Depth-first `(path, node)` walk; paths join labels with `/`
    /// (`replicate ×2/pipeline:2/stage0`).
    pub fn flatten(&self) -> Vec<(String, &MetricsTree)> {
        let mut out = Vec::new();
        self.walk("", &mut out);
        out
    }

    fn walk<'a>(&'a self, prefix: &str, out: &mut Vec<(String, &'a MetricsTree)>) {
        let path = if prefix.is_empty() {
            self.label.clone()
        } else {
            format!("{prefix}/{}", self.label)
        };
        out.push((path.clone(), self));
        for c in &self.children {
            c.walk(&path, out);
        }
    }

    /// First node (depth-first) whose label contains `needle`.
    pub fn find(&self, needle: &str) -> Option<&MetricsTree> {
        self.flatten().into_iter().map(|(_, n)| n).find(|n| n.label.contains(needle))
    }

    /// Tag the root `stale` (cached copy of a dead source).
    pub fn tagged_stale(mut self) -> Self {
        self.notes.stale = true;
        self
    }

    // ---- JSON (wire + bench baseline format) -----------------------------

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("label", Json::Str(self.label.clone())),
            ("m", snapshot_to_json(&self.snapshot)),
        ];
        if !self.notes.is_empty() {
            pairs.push(("notes", notes_to_json(&self.notes)));
        }
        if !self.children.is_empty() {
            pairs.push((
                "children",
                Json::Arr(self.children.iter().map(|c| c.to_json()).collect()),
            ));
        }
        json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let label = j
            .get("label")
            .and_then(|l| l.as_str())
            .ok_or_else(|| anyhow!("metrics tree node without a label"))?
            .to_string();
        let snapshot = snapshot_from_json(
            j.get("m").ok_or_else(|| anyhow!("metrics tree node '{label}' without 'm'"))?,
        )?;
        let notes = match j.get("notes") {
            Some(n) => notes_from_json(n),
            None => NodeNotes::default(),
        };
        let mut children = Vec::new();
        if let Some(arr) = j.get("children").and_then(|c| c.as_arr()) {
            for c in arr {
                children.push(MetricsTree::from_json(c)?);
            }
        }
        Ok(Self { label, snapshot, notes, children })
    }

    /// Indented multi-line rendering (the `raca top` body).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out, "", true, true);
        out
    }

    fn render_into(&self, out: &mut String, prefix: &str, last: bool, root: bool) {
        let (branch, next_prefix) = if root {
            (String::new(), String::new())
        } else if last {
            (format!("{prefix}└─ "), format!("{prefix}   "))
        } else {
            (format!("{prefix}├─ "), format!("{prefix}│  "))
        };
        let m = &self.snapshot;
        let mut line = format!(
            "{branch}{:<24} req {}/{} trials {} p50 {}µs p99 {}µs",
            self.label,
            m.requests_completed,
            m.requests_admitted,
            m.trials_executed,
            m.latency_p50_us,
            m.latency_p99_us
        );
        if m.engine_errors > 0 {
            line.push_str(&format!(" errs {}", m.engine_errors));
        }
        line.push_str(&render_notes(&self.notes));
        out.push_str(&line);
        out.push('\n');
        for (i, c) in self.children.iter().enumerate() {
            c.render_into(out, &next_prefix, i + 1 == self.children.len(), false);
        }
    }
}

fn render_notes(n: &NodeNotes) -> String {
    let mut s = String::new();
    if let Some(v) = n.service_us {
        s.push_str(&format!(" svc {:.0}µs", v));
    }
    if let Some(v) = n.queue_wait_us {
        s.push_str(&format!(" wait {:.0}µs", v));
    }
    if let Some(v) = n.probe_accuracy {
        s.push_str(&format!(" acc {:.2}", v));
    }
    if let Some(v) = n.weight {
        s.push_str(&format!(" w {:.2}", v));
    }
    if let Some(e) = n.errors {
        if e > 0 {
            s.push_str(&format!(" fails {e}"));
        }
    }
    if let Some(b) = &n.bundle {
        // Bundle ids are 64 hex chars; the first 12 identify one in any
        // realistic store, like short git hashes.
        s.push_str(&format!(" bundle {}", &b[..b.len().min(12)]));
    }
    if n.evicted == Some(true) {
        s.push_str(" EVICTED");
    }
    if n.stale {
        s.push_str(" STALE");
    }
    if n.reconnecting {
        s.push_str(" RECONNECTING");
    }
    s
}

impl std::fmt::Display for MetricsTree {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.render().trim_end())
    }
}

/// Flat snapshot → JSON object (no `"t"` tag — the wire layer adds one).
pub fn snapshot_to_json(m: &MetricsSnapshot) -> Json {
    json::obj(vec![
        ("requests_admitted", json::num(m.requests_admitted as f64)),
        ("requests_completed", json::num(m.requests_completed as f64)),
        ("trials_executed", json::num(m.trials_executed as f64)),
        ("batches_executed", json::num(m.batches_executed as f64)),
        ("rows_packed", json::num(m.rows_packed as f64)),
        ("trials_saved", json::num(m.trials_saved as f64)),
        ("engine_errors", json::num(m.engine_errors as f64)),
        ("latency_p50_us", json::num(m.latency_p50_us as f64)),
        ("latency_p99_us", json::num(m.latency_p99_us as f64)),
    ])
}

pub fn snapshot_from_json(j: &Json) -> Result<MetricsSnapshot> {
    let f = |k: &str| -> u64 { j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64 };
    if j.as_obj().is_none() {
        return Err(anyhow!("metrics snapshot is not an object"));
    }
    Ok(MetricsSnapshot {
        requests_admitted: f("requests_admitted"),
        requests_completed: f("requests_completed"),
        trials_executed: f("trials_executed"),
        batches_executed: f("batches_executed"),
        rows_packed: f("rows_packed"),
        trials_saved: f("trials_saved"),
        engine_errors: f("engine_errors"),
        latency_p50_us: f("latency_p50_us"),
        latency_p99_us: f("latency_p99_us"),
    })
}

fn notes_to_json(n: &NodeNotes) -> Json {
    let mut m = BTreeMap::new();
    if let Some(v) = n.service_us {
        m.insert("service_us".to_string(), json::num(v));
    }
    if let Some(v) = n.queue_wait_us {
        m.insert("queue_wait_us".to_string(), json::num(v));
    }
    if let Some(v) = n.probe_accuracy {
        m.insert("probe_accuracy".to_string(), json::num(v));
    }
    if let Some(v) = n.evicted {
        m.insert("evicted".to_string(), Json::Bool(v));
    }
    if let Some(v) = n.errors {
        m.insert("errors".to_string(), json::num(v as f64));
    }
    if let Some(v) = n.weight {
        m.insert("weight".to_string(), json::num(v));
    }
    if let Some(v) = &n.bundle {
        m.insert("bundle".to_string(), Json::Str(v.clone()));
    }
    if n.stale {
        m.insert("stale".to_string(), Json::Bool(true));
    }
    if n.reconnecting {
        m.insert("reconnecting".to_string(), Json::Bool(true));
    }
    Json::Obj(m)
}

fn notes_from_json(j: &Json) -> NodeNotes {
    NodeNotes {
        service_us: j.get("service_us").and_then(|v| v.as_f64()),
        queue_wait_us: j.get("queue_wait_us").and_then(|v| v.as_f64()),
        probe_accuracy: j.get("probe_accuracy").and_then(|v| v.as_f64()),
        evicted: j.get("evicted").and_then(|v| v.as_bool()),
        errors: j.get("errors").and_then(|v| v.as_f64()).map(|e| e as u64),
        weight: j.get("weight").and_then(|v| v.as_f64()),
        bundle: j.get("bundle").and_then(|v| v.as_str()).map(str::to_string),
        stale: j.get("stale").and_then(|v| v.as_bool()).unwrap_or(false),
        reconnecting: j.get("reconnecting").and_then(|v| v.as_bool()).unwrap_or(false),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(completed: u64) -> MetricsSnapshot {
        MetricsSnapshot {
            requests_admitted: completed + 1,
            requests_completed: completed,
            trials_executed: completed * 10,
            batches_executed: 3,
            rows_packed: 17,
            trials_saved: 2,
            engine_errors: 0,
            latency_p50_us: 120,
            latency_p99_us: 480,
        }
    }

    fn sample() -> MetricsTree {
        let mut die0 = MetricsTree::leaf("die#0", snap(4));
        die0.notes.service_us = Some(110.0);
        die0.notes.queue_wait_us = Some(12.5);
        die0.notes.probe_accuracy = Some(0.97);
        die0.notes.weight = Some(0.5);
        let mut die1 = MetricsTree::leaf("die#1", snap(3));
        die1.notes.evicted = Some(true);
        die1.notes.errors = Some(2);
        let mut remote = MetricsTree::leaf("remote:127.0.0.1:7433", snap(7));
        remote.notes.stale = true;
        remote.notes.reconnecting = true;
        remote.notes.bundle = Some("deadbeef".repeat(8));
        MetricsTree::leaf("replicate ×3 (round-robin)", snap(14))
            .with_children(vec![die0, die1, remote])
    }

    #[test]
    fn json_round_trip_preserves_shape_and_notes() {
        let t = sample();
        let encoded = t.to_json().to_string();
        let back = MetricsTree::from_json(&Json::parse(&encoded).unwrap()).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.num_nodes(), 4);
        assert_eq!(back.children[1].notes.errors, Some(2));
        assert!(back.children[2].notes.stale);
        assert!(back.children[2].notes.reconnecting);
    }

    #[test]
    fn flatten_paths_join_labels() {
        let t = sample();
        let paths: Vec<String> = t.flatten().into_iter().map(|(p, _)| p).collect();
        assert_eq!(paths[0], "replicate ×3 (round-robin)");
        assert_eq!(paths[1], "replicate ×3 (round-robin)/die#0");
        assert!(paths[3].ends_with("remote:127.0.0.1:7433"));
    }

    #[test]
    fn render_marks_eviction_and_staleness() {
        let r = sample().render();
        assert!(r.contains("EVICTED"), "{r}");
        assert!(r.contains("STALE"), "{r}");
        assert!(r.contains("RECONNECTING"), "{r}");
        assert!(r.contains("└─ "), "{r}");
        assert!(r.contains("acc 0.97"), "{r}");
        // Bundle ids render truncated to 12 chars.
        assert!(r.contains(" bundle deadbeefdead"), "{r}");
        assert!(!r.contains("deadbeefdeadb"), "{r}");
    }

    #[test]
    fn from_json_rejects_unlabeled_nodes() {
        let j = Json::parse(r#"{"m": {}}"#).unwrap();
        assert!(MetricsTree::from_json(&j).is_err());
    }
}
