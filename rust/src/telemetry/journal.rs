//! Bounded event journal: a ring of timestamped structured events.
//!
//! Writers never contend on a global lock: an atomic cursor assigns each
//! event a sequence number (and thereby a slot); only writers that land
//! on the *same* slot a full lap apart touch the same per-slot lock, so
//! the hot path is one `fetch_add` plus an uncontended mutex.  Readers
//! ([`Journal::tail`]) reconstruct order from the sequence numbers, not
//! from slot positions, so wraparound never reorders what remains.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::util::json::{self, Json};

/// Event taxonomy (see README "Observability" for the full reading).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A request entered a backend's queue.
    RequestAdmitted,
    /// A request completed with a vote.
    RequestCompleted,
    /// A request failed in-band (dead peer, duplicate id, …).
    RequestFailed,
    /// A labeled health probe came back (detail says hit/miss).
    ProbeVerdict,
    /// The health monitor recomputed traffic weights.
    HealthReweigh,
    /// A child was evicted from the routing rotation.
    HealthEvict,
    /// A child was flagged for threshold recalibration.
    HealthRecalibrate,
    /// A wire session was accepted (listener side).
    SessionConnect,
    /// A wire session ended (either side; detail says why).
    SessionDrop,
    /// The HTTP ingress refused a request (detail says which limit:
    /// queue, in-flight budget, or tenant rate).
    IngressShed,
    /// The HTTP continuous batcher flushed a merged batch to the
    /// backend (detail says how many requests formed how many groups).
    BatchFormed,
    /// A signed bundle landed in a registry store (publish path).
    BundlePublished,
    /// A `remote:@<registry>/<bundle>` leaf verified and bound a bundle
    /// at deployment-build time.
    BundleResolved,
    /// A manifest failed verification — bad signature, foreign key,
    /// tampered blob, or an id the peer does not advertise (detail says
    /// which).
    ManifestRejected,
    /// A dropped wire session was redialed and restored (detail says
    /// attempts, downtime, and how many requests were resubmitted).
    SessionReconnect,
    /// An in-flight request was resubmitted on a restored session.
    Resubmit,
    /// A request was shed because its deadline budget ran out before the
    /// work would have produced anything a caller could still read.
    DeadlineExceeded,
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::RequestAdmitted => "request_admitted",
            EventKind::RequestCompleted => "request_completed",
            EventKind::RequestFailed => "request_failed",
            EventKind::ProbeVerdict => "probe_verdict",
            EventKind::HealthReweigh => "health_reweigh",
            EventKind::HealthEvict => "health_evict",
            EventKind::HealthRecalibrate => "health_recalibrate",
            EventKind::SessionConnect => "session_connect",
            EventKind::SessionDrop => "session_drop",
            EventKind::IngressShed => "ingress_shed",
            EventKind::BatchFormed => "batch_formed",
            EventKind::BundlePublished => "bundle_published",
            EventKind::BundleResolved => "bundle_resolved",
            EventKind::ManifestRejected => "manifest_rejected",
            EventKind::SessionReconnect => "session_reconnect",
            EventKind::Resubmit => "resubmit",
            EventKind::DeadlineExceeded => "deadline_exceeded",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "request_admitted" => EventKind::RequestAdmitted,
            "request_completed" => EventKind::RequestCompleted,
            "request_failed" => EventKind::RequestFailed,
            "probe_verdict" => EventKind::ProbeVerdict,
            "health_reweigh" => EventKind::HealthReweigh,
            "health_evict" => EventKind::HealthEvict,
            "health_recalibrate" => EventKind::HealthRecalibrate,
            "session_connect" => EventKind::SessionConnect,
            "session_drop" => EventKind::SessionDrop,
            "ingress_shed" => EventKind::IngressShed,
            "batch_formed" => EventKind::BatchFormed,
            "bundle_published" => EventKind::BundlePublished,
            "bundle_resolved" => EventKind::BundleResolved,
            "manifest_rejected" => EventKind::ManifestRejected,
            "session_reconnect" => EventKind::SessionReconnect,
            "resubmit" => EventKind::Resubmit,
            "deadline_exceeded" => EventKind::DeadlineExceeded,
            _ => return None,
        })
    }
}

/// One journal entry.  `seq` is globally ordered per journal; `t_us` is
/// microseconds since the journal was created (wall-clock-free, so two
/// events compare even across an export/import).
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub seq: u64,
    pub t_us: u64,
    pub kind: EventKind,
    /// Emitting node's label (`die#3`, `router`, `remote:host:port`, …).
    pub node: String,
    pub detail: String,
}

impl Event {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("seq", json::num(self.seq as f64)),
            ("t_us", json::num(self.t_us as f64)),
            ("kind", Json::Str(self.kind.name().to_string())),
            ("node", Json::Str(self.node.clone())),
            ("detail", Json::Str(self.detail.clone())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let kind_s = j
            .get("kind")
            .and_then(|k| k.as_str())
            .ok_or_else(|| anyhow!("journal event without a kind"))?;
        let kind = EventKind::parse(kind_s)
            .ok_or_else(|| anyhow!("unknown journal event kind '{kind_s}'"))?;
        Ok(Self {
            seq: j.get("seq").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
            t_us: j.get("t_us").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
            kind,
            node: j.get("node").and_then(|v| v.as_str()).unwrap_or("").to_string(),
            detail: j.get("detail").and_then(|v| v.as_str()).unwrap_or("").to_string(),
        })
    }
}

impl std::fmt::Display for Event {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "[+{:>9.3}s] {:<18} {} {}",
            self.t_us as f64 / 1e6,
            self.kind.name(),
            self.node,
            self.detail
        )
    }
}

/// Bounded ring of [`Event`]s shared by every node of one deployment
/// tree (plumbed through `serve::BuildOptions`).
#[derive(Debug)]
pub struct Journal {
    slots: Vec<Mutex<Option<Event>>>,
    /// Next sequence number; `seq & (capacity-1)` is the slot.
    head: AtomicU64,
    origin: Instant,
}

/// Default ring capacity (events). Power of two, see [`Journal::new`].
pub const DEFAULT_CAPACITY: usize = 1024;

impl Journal {
    /// `capacity` is rounded up to a power of two (≥ 8) so the slot
    /// index is a mask, not a division.
    pub fn new(capacity: usize) -> Arc<Self> {
        let cap = capacity.max(8).next_power_of_two();
        Arc::new(Self {
            slots: (0..cap).map(|_| Mutex::new(None)).collect(),
            head: AtomicU64::new(0),
            origin: Instant::now(),
        })
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total events ever recorded (≥ retained count once wrapped).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Append an event; oldest entry in the slot's lap is overwritten.
    pub fn record(&self, kind: EventKind, node: &str, detail: impl Into<String>) {
        let seq = self.head.fetch_add(1, Ordering::Relaxed);
        let t_us = self.origin.elapsed().as_micros() as u64;
        let ev = Event { seq, t_us, kind, node: node.to_string(), detail: detail.into() };
        let slot = (seq as usize) & (self.slots.len() - 1);
        *self.slots[slot].lock().unwrap() = Some(ev);
    }

    /// The most recent `n` retained events, oldest first.
    pub fn tail(&self, n: usize) -> Vec<Event> {
        let mut evs: Vec<Event> = self
            .slots
            .iter()
            .filter_map(|s| s.lock().unwrap().clone())
            .collect();
        evs.sort_unstable_by_key(|e| e.seq);
        if evs.len() > n {
            evs.drain(..evs.len() - n);
        }
        evs
    }

    /// Whole retained window as JSON lines (one event object per line).
    pub fn to_json_lines(&self) -> String {
        let mut out = String::new();
        for e in self.tail(usize::MAX) {
            out.push_str(&e.to_json().to_string());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_in_order_and_round_trips_json() {
        let j = Journal::new(64);
        j.record(EventKind::SessionConnect, "listener:7433", "peer 127.0.0.1:5000");
        j.record(EventKind::RequestAdmitted, "router", "id 1");
        j.record(EventKind::RequestFailed, "die#1", "id 1: engine fault");
        let t = j.tail(10);
        assert_eq!(t.len(), 3);
        assert_eq!(t[0].kind, EventKind::SessionConnect);
        assert_eq!(t[2].node, "die#1");
        assert!(t.windows(2).all(|w| w[0].seq < w[1].seq));
        assert!(t.windows(2).all(|w| w[0].t_us <= w[1].t_us));

        let lines = j.to_json_lines();
        let back: Vec<Event> = lines
            .lines()
            .map(|l| Event::from_json(&Json::parse(l).unwrap()).unwrap())
            .collect();
        assert_eq!(back, t);
    }

    #[test]
    fn wraparound_keeps_newest_capacity_events() {
        let j = Journal::new(16); // already a power of two
        assert_eq!(j.capacity(), 16);
        for i in 0..50u64 {
            j.record(EventKind::RequestCompleted, "die#0", format!("id {i}"));
        }
        assert_eq!(j.recorded(), 50);
        let t = j.tail(usize::MAX);
        assert_eq!(t.len(), 16, "ring retains exactly `capacity` events");
        // The retained window is the newest 16, in order.
        assert_eq!(t.first().unwrap().seq, 34);
        assert_eq!(t.last().unwrap().seq, 49);
        assert!(t.windows(2).all(|w| w[0].seq + 1 == w[1].seq));
        // tail(n) trims from the old end.
        let last4 = j.tail(4);
        assert_eq!(last4.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![46, 47, 48, 49]);
    }

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        assert_eq!(Journal::new(1000).capacity(), 1024);
        assert_eq!(Journal::new(0).capacity(), 8);
    }

    #[test]
    fn concurrent_writers_never_lose_sequence_numbers() {
        let j = Journal::new(256);
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let j = j.clone();
                std::thread::spawn(move || {
                    for i in 0..100 {
                        j.record(EventKind::RequestCompleted, &format!("die#{t}"), format!("id {i}"));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(j.recorded(), 400);
        let tail = j.tail(usize::MAX);
        assert_eq!(tail.len(), 256);
        // Sequence numbers are unique and strictly increasing in the tail.
        assert!(tail.windows(2).all(|w| w[0].seq < w[1].seq));
    }
}
