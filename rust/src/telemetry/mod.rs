//! Fabric-wide observability: recursive per-node metrics + event journal.
//!
//! Since PR-3 every deployment is a recursive [`crate::serve::Topology`]
//! tree, but a flat [`crate::coordinator::MetricsSnapshot`] cannot say
//! *where* inside a `2x(pipeline:3)` the time, trials, or failures went.
//! This module is the missing layer:
//!
//! - [`MetricsTree`] — a node's own snapshot plus labeled children
//!   (`die#3`, `stage1`, `remote:host:port`), annotated with per-child
//!   service-time vs. queue-wait, probe accuracy, eviction state and
//!   in-band error counts ([`NodeNotes`]).  Produced by
//!   `Backend::metrics_tree()`, carried over the wire as a versioned
//!   `metrics_tree` frame (see [`crate::serve::net::wire`]), rendered by
//!   `raca top`.
//! - [`Journal`] — a bounded ring of timestamped structured [`Event`]s
//!   (request admitted/completed/failed, probe verdicts, health
//!   reweigh/evict/recalibrate, session connect/drop) written by every
//!   backend and the fleet `HealthMonitor`, exportable as JSON lines.
//!
//! Both types serialize through [`crate::util::json`] (the crate's only
//! JSON layer — no external deps).

pub mod journal;
pub mod tree;

pub use journal::{Event, EventKind, Journal};
pub use tree::{MetricsTree, NodeNotes};
