//! Content-addressed artifact store under `<artifact-dir>/registry/`.
//!
//! Layout:
//!
//! ```text
//! <artifact-dir>/registry/
//!   blobs/<hex-sha256>          # raw artifact bytes, named by digest
//!   manifests/<bundle-id>.json  # SignedManifest envelopes
//!   keys/key.json               # the deployment signing key (sign.rs)
//! ```
//!
//! Invariants:
//!
//! * **Atomic writes** — every file lands via write-to-temp + rename, so
//!   a crashed publish never leaves a half-written blob behind for a
//!   reader to hash.
//! * **Garbage-safe reads** — [`Store::get_blob`] re-hashes what it read
//!   and refuses a mismatch; [`Store::get_manifest`] re-derives the
//!   bundle id from the envelope's canonical bytes and compares it to
//!   the file name.  On-disk corruption (bit rot, hand editing, a
//!   tampering peer with filesystem access) is detected at read time,
//!   never served.
//! * **No silent overwrites** — the put path treats an existing path
//!   with *different* bytes as a hard error instead of replacing it.
//!   Identical bytes are a dedup no-op.  This is what makes `raca train
//!   --force` + publish safe: retrained weights are different bytes,
//!   hence a different digest, hence new blobs and a **new bundle id** —
//!   the old bundle's blobs are never touched.

use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use super::manifest::SignedManifest;
use super::sign::{is_digest, sha256_hex};
use crate::util::json::Json;

/// Atomic file write: temp file in the target directory, then rename.
pub(crate) fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let dir = path.parent().context("atomic write target has no parent directory")?;
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating directory {}", dir.display()))?;
    let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("artifact");
    let tmp = dir.join(format!(".{name}.tmp.{}", std::process::id()));
    std::fs::write(&tmp, bytes)
        .with_context(|| format!("writing temp file {}", tmp.display()))?;
    std::fs::rename(&tmp, path).with_context(|| {
        let _ = std::fs::remove_file(&tmp);
        format!("renaming {} into place", path.display())
    })
}

/// Handle on one artifact directory's registry tree.  Cheap to clone —
/// all state lives on disk.
#[derive(Debug, Clone)]
pub struct Store {
    root: PathBuf,
}

impl Store {
    /// Open (lazily — directories are created on first put) the registry
    /// under `artifact_dir`.
    pub fn open(artifact_dir: impl AsRef<Path>) -> Self {
        Store { root: artifact_dir.as_ref().join("registry") }
    }

    /// The `registry/` root this store reads and writes.
    pub fn root(&self) -> &Path {
        &self.root
    }

    fn blob_path(&self, hash: &str) -> PathBuf {
        self.root.join("blobs").join(hash)
    }

    fn manifest_path(&self, id: &str) -> PathBuf {
        self.root.join("manifests").join(format!("{id}.json"))
    }

    /// Store `bytes` under their digest; returns the blob hash.
    /// Identical existing content is a dedup no-op; differing existing
    /// content is a collision error (see the module invariants).
    pub fn put_blob(&self, bytes: &[u8]) -> Result<String> {
        let hash = sha256_hex(bytes);
        let path = self.blob_path(&hash);
        if path.exists() {
            let existing = std::fs::read(&path)
                .with_context(|| format!("reading existing blob {}", path.display()))?;
            if existing == bytes {
                return Ok(hash); // content-addressed dedup
            }
            bail!(
                "blob {hash} already exists with different bytes ({} vs {} on disk) — \
                 refusing to overwrite; the store is corrupt",
                bytes.len(),
                existing.len()
            );
        }
        atomic_write(&path, bytes)?;
        Ok(hash)
    }

    /// Read a blob and verify its bytes still hash to its name.
    pub fn get_blob(&self, hash: &str) -> Result<Vec<u8>> {
        ensure!(is_digest(hash), "'{hash}' is not a blob hash");
        let path = self.blob_path(hash);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading blob {}", path.display()))?;
        let actual = sha256_hex(&bytes);
        ensure!(
            actual == hash,
            "blob {hash} is corrupt: stored bytes hash to {actual}"
        );
        Ok(bytes)
    }

    /// Whether a blob with this hash is present (no integrity check).
    pub fn has_blob(&self, hash: &str) -> bool {
        is_digest(hash) && self.blob_path(hash).exists()
    }

    /// Store a signed manifest under its content-derived bundle id.
    /// Same no-overwrite rule as blobs.
    pub fn put_manifest(&self, env: &SignedManifest) -> Result<String> {
        let id = env.bundle_id();
        let bytes = format!("{}\n", env.to_json()).into_bytes();
        let path = self.manifest_path(&id);
        if path.exists() {
            let existing = std::fs::read(&path)
                .with_context(|| format!("reading existing manifest {}", path.display()))?;
            if existing == bytes {
                return Ok(id);
            }
            bail!(
                "bundle {id} already exists with a different envelope — refusing to \
                 overwrite (same content re-signed under another key?)"
            );
        }
        atomic_write(&path, &bytes)?;
        Ok(id)
    }

    /// Load a signed manifest and verify the envelope still matches its
    /// bundle id.  Signature checking is the caller's job (it needs the
    /// deployment key); this guards the *content addressing* invariant.
    pub fn get_manifest(&self, id: &str) -> Result<SignedManifest> {
        ensure!(is_digest(id), "'{id}' is not a bundle id");
        let path = self.manifest_path(id);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("manifest {}: {e}", path.display()))?;
        let env = SignedManifest::from_json(&j)
            .with_context(|| format!("manifest {}", path.display()))?;
        let actual = env.bundle_id();
        ensure!(
            actual == id,
            "manifest {id} is corrupt: stored content hashes to bundle id {actual}"
        );
        Ok(env)
    }

    /// All bundle ids in the store, sorted.
    pub fn list(&self) -> Result<Vec<String>> {
        let dir = self.root.join("manifests");
        if !dir.exists() {
            return Ok(Vec::new());
        }
        let mut ids = Vec::new();
        for entry in std::fs::read_dir(&dir)
            .with_context(|| format!("listing manifests in {}", dir.display()))?
        {
            let entry = entry.context("reading manifest directory entry")?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(id) = name.strip_suffix(".json") {
                if is_digest(id) {
                    ids.push(id.to_string());
                }
            }
        }
        ids.sort_unstable();
        Ok(ids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::registry::manifest::Manifest;
    use crate::registry::sign::SigningKey;

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("raca-store-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample_manifest(store: &Store) -> Manifest {
        Manifest {
            model: "fcnn".into(),
            widths: vec![784, 16, 10],
            weights_json: store.put_blob(b"{\"layers\":3}").unwrap(),
            weights_bin: store.put_blob(&[1, 2, 3, 4]).unwrap(),
            calibration: store.put_blob(b"{\"theta\":3.0}").unwrap(),
            dataset_sha256: String::new(),
        }
    }

    #[test]
    fn blobs_round_trip_and_dedup() {
        let dir = scratch("blob");
        let store = Store::open(&dir);
        let h = store.put_blob(b"hello blobs").unwrap();
        assert!(store.has_blob(&h));
        assert_eq!(store.get_blob(&h).unwrap(), b"hello blobs");
        // Re-putting identical bytes is a no-op, not an error.
        assert_eq!(store.put_blob(b"hello blobs").unwrap(), h);
        // Unknown and malformed hashes are errors, not panics.
        assert!(store.get_blob(&"0".repeat(64)).is_err());
        assert!(store.get_blob("../../etc/passwd").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tampered_blob_is_refused_at_read_time() {
        let dir = scratch("tamper");
        let store = Store::open(&dir);
        let h = store.put_blob(b"pristine weights").unwrap();
        // Byte-flip the stored artifact behind the store's back.
        let path = dir.join("registry").join("blobs").join(&h);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = store.get_blob(&h).unwrap_err();
        assert!(format!("{err:#}").contains("corrupt"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn collision_check_guards_the_put_path() {
        let dir = scratch("collide");
        let store = Store::open(&dir);
        let h = store.put_blob(b"original").unwrap();
        // Simulate a corrupt store: different bytes already sitting at
        // this hash's path (a real sha256 collision being unavailable).
        std::fs::write(dir.join("registry").join("blobs").join(&h), b"imposter").unwrap();
        let err = store.put_blob(b"original").unwrap_err();
        assert!(format!("{err:#}").contains("refusing to overwrite"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifests_round_trip_and_list() {
        let dir = scratch("manifest");
        let store = Store::open(&dir);
        let key = SigningKey::from_secret(vec![3; 32]);
        let env = SignedManifest::sign(sample_manifest(&store), &key);
        let id = store.put_manifest(&env).unwrap();
        assert_eq!(store.get_manifest(&id).unwrap(), env);
        assert_eq!(store.list().unwrap(), vec![id.clone()]);
        // Idempotent re-put.
        assert_eq!(store.put_manifest(&env).unwrap(), id);
        // Same manifest signed under another key: same bundle id,
        // different envelope bytes — refused, not replaced.
        let other = SigningKey::from_secret(vec![4; 32]);
        let resigned = SignedManifest::sign(env.manifest.clone(), &other);
        assert!(store.put_manifest(&resigned).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn retrain_produces_a_new_bundle_id_not_an_overwrite() {
        let dir = scratch("retrain");
        let store = Store::open(&dir);
        let key = SigningKey::from_secret(vec![5; 32]);
        let first = SignedManifest::sign(sample_manifest(&store), &key);
        let first_id = store.put_manifest(&first).unwrap();

        // `raca train --force` writes new weight bytes; publishing again
        // stores new blobs and a new manifest, leaving the old bundle
        // fully intact.
        let mut retrained = first.manifest.clone();
        retrained.weights_bin = store.put_blob(&[9, 9, 9, 9]).unwrap();
        let second = SignedManifest::sign(retrained, &key);
        let second_id = store.put_manifest(&second).unwrap();

        assert_ne!(first_id, second_id);
        let mut want = vec![first_id.clone(), second_id.clone()];
        want.sort_unstable();
        assert_eq!(store.list().unwrap(), want);
        assert_eq!(store.get_manifest(&first_id).unwrap(), first);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
