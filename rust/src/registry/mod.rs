//! `raca::registry` — signed, content-addressed artifact distribution.
//!
//! A fleet of RACA hosts needs to agree on *what model a node serves*
//! before the bit-parity contract (`trial_stream_base(seed, id)` over
//! identical weights) means anything.  This subsystem makes that
//! agreement explicit: a **bundle** is the content-addressed closure of
//! one deployable model — weight metadata, packed matrices, calibration
//! profile, dataset digest, layer widths — named by the SHA-256 of its
//! canonical manifest bytes and signed by the deployment key.  The
//! discovery flow is then:
//!
//! ```text
//!  raca publish w calib ──► store (blobs + signed manifest)
//!                              │
//!  raca serve --listen ────────┘  hello advertises bundle ids (wire v4)
//!                              ▲
//!  --topology "remote:@host:port/<bundle>" ── resolve: fetch manifest,
//!        verify signature + id, bind the leaf, journal bundle_resolved
//! ```
//!
//! # Signing scheme
//!
//! Signatures are **HMAC-SHA256 under a shared deployment key** (a
//! symmetric secret, generated once per artifact directory and copied
//! to every host of the deployment — see [`sign::SigningKey`]).  Both
//! primitives are implemented in [`sign`] from the FIPS 180-4 / RFC
//! 2104 specifications; the repo's no-external-deps posture rules out
//! an asymmetric-crypto crate, and within one administrative domain a
//! shared secret gives the property that matters here: a peer that
//! never held the key cannot mint or alter a manifest that verifies.
//! It does *not* distinguish publishers from verifiers — any key holder
//! can sign.  If that distinction ever matters, swap [`sign`] for a
//! public-key scheme behind the same [`sign::SigningKey`] surface and
//! bump the key file's shape; manifests and wire frames are unaffected
//! (they carry opaque `key_id`/`sig` strings).
//!
//! Verification is end-to-end and repeated at every hop: the store
//! re-hashes blobs on read, the listener re-verifies before vouching,
//! and the resolving client verifies again under its own key — a
//! registry peer can deny service but never substitute content.
//!
//! # Wire coupling and bump rules
//!
//! The registry vocabulary rode in with wire **v4** (see
//! [`crate::serve::net::wire`]): `hello.bundles`, `bundles_req`/
//! `bundles`, `manifest_fetch`/`manifest`, `blob_fetch`/`blob`,
//! `publish`/`publish_ok` — all additive, so the v1 floor stands and a
//! pre-v4 listener simply answers registry frames with its generic
//! `error`.  Rules for growing this surface: new *fields* inside the
//! manifest change the canonical bytes and therefore mint new bundle
//! ids — old bundles stay valid, so that is additive; a new *frame* or
//! optional field bumps `PROTOCOL_VERSION` per the wire module's rules;
//! changing the signing scheme or hash function is **breaking** — raise
//! `MIN_PROTOCOL_VERSION` so pre-break peers are refused rather than
//! fed envelopes they would mis-verify.

pub mod client;
pub mod manifest;
pub mod sign;
pub mod store;

pub use client::{resolve, RegistryClient};
pub use manifest::{Manifest, SignedManifest};
pub use sign::{key_path, sha256_hex, SigningKey};
pub use store::Store;

use std::path::Path;

use anyhow::{Context, Result};

/// Publish a trained model from disk into a local store: read
/// `<weights_prefix>.{json,bin}` and the calibration profile, blob each,
/// build + sign the manifest, and store the envelope.  `dataset`, when
/// given, is hashed into the manifest so resolvers can pin the exact
/// evaluation set.  Returns the bundle id and the signed envelope
/// (which [`RegistryClient::publish`] can forward to a remote listener).
pub fn publish_local(
    store: &Store,
    key: &SigningKey,
    weights_prefix: &Path,
    calibration: &Path,
    dataset: Option<&Path>,
) -> Result<(String, SignedManifest)> {
    let json_path = weights_prefix.with_extension("json");
    let bin_path = weights_prefix.with_extension("bin");
    let meta_bytes = std::fs::read(&json_path)
        .with_context(|| format!("reading {}", json_path.display()))?;
    let bin_bytes =
        std::fs::read(&bin_path).with_context(|| format!("reading {}", bin_path.display()))?;
    let calib_bytes = std::fs::read(calibration)
        .with_context(|| format!("reading {}", calibration.display()))?;

    // Widths come from the weights metadata itself, so the manifest can
    // never disagree with the blobs it names.
    let meta = crate::util::json::Json::parse(
        std::str::from_utf8(&meta_bytes).context("weights metadata is not UTF-8")?,
    )
    .with_context(|| format!("parsing {}", json_path.display()))?;
    let widths: Vec<usize> = meta
        .get("layers")
        .and_then(crate::util::json::Json::as_arr)
        .with_context(|| format!("{}: missing 'layers'", json_path.display()))?
        .iter()
        .filter_map(crate::util::json::Json::as_usize)
        .collect();

    let dataset_sha256 = match dataset {
        Some(p) => {
            let bytes =
                std::fs::read(p).with_context(|| format!("reading {}", p.display()))?;
            sha256_hex(&bytes)
        }
        None => String::new(),
    };

    let manifest = Manifest {
        model: "fcnn".to_string(),
        widths,
        weights_json: store.put_blob(&meta_bytes)?,
        weights_bin: store.put_blob(&bin_bytes)?,
        calibration: store.put_blob(&calib_bytes)?,
        dataset_sha256,
    };
    let env = SignedManifest::sign(manifest, key);
    let id = store.put_manifest(&env)?;
    Ok((id, env))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_local_builds_a_resolvable_bundle() {
        let dir = std::env::temp_dir()
            .join(format!("raca-registry-pub-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(dir.join("weights")).unwrap();
        // A tiny but well-formed weights pair + calibration profile.
        let spec = crate::nn::ModelSpec::new(vec![784, 4, 10]);
        let mats = (0..spec.num_layers())
            .map(|l| {
                let (r, c) = spec.layer_shape(l);
                vec![0.01f32; r * c]
            })
            .collect();
        let w = crate::nn::Weights { spec, mats, ideal_test_accuracy: 0.5 };
        let prefix = dir.join("weights").join("fcnn");
        w.save(&prefix).unwrap();
        let calib = dir.join("calib.json");
        std::fs::write(&calib, br#"{"theta":3.0}"#).unwrap();

        let store = Store::open(&dir);
        let key = SigningKey::from_secret(vec![7; 32]);
        let (id, env) = publish_local(&store, &key, &prefix, &calib, None).unwrap();
        assert_eq!(env.bundle_id(), id);
        assert_eq!(env.manifest.widths, vec![784, 4, 10]);
        assert_eq!(env.verify(&key).unwrap(), id);
        assert_eq!(store.list().unwrap(), vec![id.clone()]);
        // Every referenced blob landed and round-trips.
        for h in env.manifest.blob_hashes() {
            assert!(store.has_blob(h));
            store.get_blob(h).unwrap();
        }
        // Publishing the identical artifacts again is idempotent.
        let (id2, _) = publish_local(&store, &key, &prefix, &calib, None).unwrap();
        assert_eq!(id2, id);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
