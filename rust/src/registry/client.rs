//! Registry client: synchronous RPC against a `raca serve --listen`
//! peer's registry vocabulary.
//!
//! Unlike [`crate::serve::net::RemoteBackend`] — a long-lived
//! multiplexed serving session — registry traffic is rare, sequential
//! control-plane work (a publish at deploy time, one resolve per
//! `remote:@` leaf at build time).  So this client is deliberately
//! simple: one frame out, one frame in, every call bounded by a read
//! timeout, no reader thread.
//!
//! The trust model matches the store's: nothing the peer says is taken
//! on faith.  [`resolve`] checks the advertised bundle list, verifies
//! the fetched envelope's signature under the *local* deployment key,
//! and re-derives the bundle id from the manifest's canonical bytes;
//! [`RegistryClient::fetch_blob`] re-hashes what arrived.  A registry
//! peer can therefore deny service, but cannot substitute content.

use std::io::BufReader;
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::serve::net::wire::{self, WireMsg, PROTOCOL_VERSION};
use crate::util::json;

use super::manifest::SignedManifest;
use super::sign::{self, SigningKey};

/// TCP connect budget.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// Per-RPC read budget.  Registry calls are synchronous; a wedged peer
/// must fail the call, not hang a deployment build.
const RPC_TIMEOUT: Duration = Duration::from_secs(10);

/// One registry session against a listener.
pub struct RegistryClient {
    addr: String,
    read: BufReader<TcpStream>,
    write: TcpStream,
    /// Bundle ids the listener's hello advertised.
    advertised: Vec<String>,
}

impl RegistryClient {
    /// Dial `addr` and complete the protocol handshake, capturing the
    /// listener's advertised bundle ids.
    pub fn connect(addr: &str) -> Result<Self> {
        let resolved: Vec<_> = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving registry address {addr}"))?
            .collect();
        ensure!(!resolved.is_empty(), "registry address {addr} resolved to nothing");
        let mut stream = None;
        let mut last_err = None;
        for sa in &resolved {
            match TcpStream::connect_timeout(sa, CONNECT_TIMEOUT) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) => last_err = Some(e),
            }
        }
        let stream = match stream {
            Some(s) => s,
            None => {
                return Err(last_err.expect("resolved is non-empty"))
                    .with_context(|| format!("connecting to registry {addr}"))
            }
        };
        stream.set_nodelay(true).context("setting TCP_NODELAY")?;
        // Every read on this session is one bounded RPC answer.
        stream.set_read_timeout(Some(RPC_TIMEOUT)).context("setting registry read timeout")?;
        stream.set_write_timeout(Some(RPC_TIMEOUT)).context("setting registry write timeout")?;
        let mut read = BufReader::new(stream.try_clone().context("cloning stream")?);
        let mut write = stream;

        let j = json::read_frame(&mut read)
            .with_context(|| format!("reading hello from {addr} (is it a raca listener?)"))?
            .ok_or_else(|| anyhow!("{addr} closed the connection during the handshake"))?;
        let advertised = match wire::decode(&j).with_context(|| format!("bad hello from {addr}"))? {
            WireMsg::Hello { version, bundles } => {
                wire::check_version(version).with_context(|| format!("peer {addr}"))?;
                bundles
            }
            WireMsg::Error { msg, .. } => bail!("{addr} refused the session: {msg}"),
            other => bail!("{addr} opened with {other:?} instead of hello"),
        };
        json::write_frame(
            &mut write,
            &wire::encode(&WireMsg::Hello { version: PROTOCOL_VERSION, bundles: Vec::new() }),
        )
        .with_context(|| format!("answering hello to {addr}"))?;
        Ok(Self { addr: addr.to_string(), read, write, advertised })
    }

    /// Bundle ids the listener's hello advertised (a snapshot from
    /// connect time; [`Self::bundles`] re-asks).
    pub fn advertised(&self) -> &[String] {
        &self.advertised
    }

    /// One request/answer exchange.  An `Error` frame becomes an `Err`
    /// carrying the peer's message.
    fn rpc(&mut self, req: &WireMsg) -> Result<WireMsg> {
        json::write_frame(&mut self.write, &wire::encode(req))
            .with_context(|| format!("writing to registry {}", self.addr))?;
        let j = json::read_frame(&mut self.read)
            .with_context(|| format!("reading registry answer from {}", self.addr))?
            .ok_or_else(|| anyhow!("registry {} closed mid-exchange", self.addr))?;
        match wire::decode(&j).with_context(|| format!("bad frame from {}", self.addr))? {
            WireMsg::Error { msg, .. } => bail!("registry {}: {msg}", self.addr),
            other => Ok(other),
        }
    }

    /// Ask the listener for its current bundle list.
    pub fn bundles(&mut self) -> Result<Vec<String>> {
        match self.rpc(&WireMsg::BundlesReq)? {
            WireMsg::Bundles { ids } => Ok(ids),
            other => bail!("registry {} answered bundles_req with {other:?}", self.addr),
        }
    }

    /// Fetch one signed manifest.  Verifies nothing — callers hold the
    /// deployment key and must [`SignedManifest::verify`] (see
    /// [`resolve`] for the full discipline).
    pub fn fetch_manifest(&mut self, bundle: &str) -> Result<SignedManifest> {
        match self.rpc(&WireMsg::ManifestFetch { bundle: bundle.to_string() })? {
            WireMsg::Manifest { envelope } => SignedManifest::from_json(&envelope)
                .with_context(|| format!("envelope for bundle {bundle}")),
            other => bail!("registry {} answered manifest_fetch with {other:?}", self.addr),
        }
    }

    /// Fetch one blob and verify the bytes hash to `hash`.
    pub fn fetch_blob(&mut self, hash: &str) -> Result<Vec<u8>> {
        match self.rpc(&WireMsg::BlobFetch { hash: hash.to_string() })? {
            WireMsg::Blob { hash: got, data } => {
                ensure!(got == hash, "registry answered blob {got} for requested {hash}");
                let bytes = sign::unhex(&data).context("blob payload is not hex")?;
                ensure!(
                    sign::sha256_hex(&bytes) == hash,
                    "blob from {} does not hash to {hash}",
                    self.addr
                );
                Ok(bytes)
            }
            other => bail!("registry {} answered blob_fetch with {other:?}", self.addr),
        }
    }

    /// Publish a signed bundle: the envelope plus every referenced blob's
    /// bytes.  Returns the bundle id the listener admitted.
    pub fn publish(&mut self, env: &SignedManifest, blobs: &[(String, Vec<u8>)]) -> Result<String> {
        let frame = WireMsg::Publish {
            envelope: env.to_json(),
            blobs: blobs.iter().map(|(h, b)| (h.clone(), sign::hex(b))).collect(),
        };
        match self.rpc(&frame)? {
            WireMsg::PublishOk { bundle } => {
                ensure!(
                    bundle == env.bundle_id(),
                    "registry {} admitted bundle {bundle}, expected {}",
                    self.addr,
                    env.bundle_id()
                );
                Ok(bundle)
            }
            other => bail!("registry {} answered publish with {other:?}", self.addr),
        }
    }

    /// Polite session end.
    pub fn close(mut self) {
        let _ = json::write_frame(&mut self.write, &wire::encode(&WireMsg::Goodbye));
        let _ = self.write.shutdown(Shutdown::Both);
    }
}

/// The `remote:@<registry>/<bundle>` build-time discipline in one call:
/// dial the registry, require the bundle to be advertised, fetch its
/// envelope, verify the signature under the **local** deployment key,
/// and re-derive the bundle id from the canonical bytes.  Returns the
/// verified envelope; any failure is grounds for a `manifest_rejected`
/// journal event at the caller.
pub fn resolve(addr: &str, bundle: &str, key: &SigningKey) -> Result<SignedManifest> {
    ensure!(sign::is_digest(bundle), "'{bundle}' is not a bundle id");
    let mut client =
        RegistryClient::connect(addr).with_context(|| format!("dialing registry {addr}"))?;
    let out = (|| -> Result<SignedManifest> {
        ensure!(
            client.advertised().iter().any(|b| b == bundle),
            "registry {addr} does not advertise bundle {bundle} (serves {} bundles)",
            client.advertised().len()
        );
        let env = client.fetch_manifest(bundle)?;
        let id = env.verify(key).with_context(|| format!("bundle {bundle} from {addr}"))?;
        ensure!(
            id == bundle,
            "envelope from {addr} verifies but is bundle {id}, not the requested {bundle}"
        );
        Ok(env)
    })();
    client.close();
    out
}
