//! Hashing and signing for the artifact registry — self-contained, no
//! new dependencies.
//!
//! * **Content addressing** uses SHA-256 (FIPS 180-4), implemented here
//!   in ~100 lines and pinned to the standard test vectors below.  Blob
//!   names and bundle ids are lowercase hex digests.
//! * **Signing** is HMAC-SHA256 (RFC 2104) under a *deployment key*: one
//!   `(key_id, secret)` pair shared by every publisher and resolver of a
//!   deployment, stored at `<artifact-dir>/registry/keys/key.json`.  A
//!   symmetric scheme is deliberate: the crate vendors no bignum or
//!   curve arithmetic, and the threat model is "only holders of the
//!   deployment secret may publish or vouch for bundles" — which HMAC
//!   delivers exactly.  The seam is narrow (`sign`/`verify` on canonical
//!   manifest bytes), so swapping in ed25519 later changes this file
//!   only.
//! * Key generation has no OS RNG to lean on either; entropy is distilled
//!   by hashing several independently seeded `RandomState` hashers (each
//!   draws fresh process randomness) together with the wall clock and
//!   pid.  Good enough for a deployment secret; not a general CSPRNG.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::util::json::{obj, Json};

// ---------------------------------------------------------------------------
// SHA-256 (FIPS 180-4)
// ---------------------------------------------------------------------------

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4,
    0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe,
    0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f,
    0x4a7484aa, 0x5cb0a9dc, 0x76f988da, 0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7,
    0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc,
    0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
    0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070, 0x19a4c116,
    0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7,
    0xc67178f2,
];

const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab,
    0x5be0cd19,
];

/// Streaming SHA-256 state.
pub struct Sha256 {
    h: [u32; 8],
    buf: [u8; 64],
    buflen: usize,
    total: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    pub fn new() -> Self {
        Sha256 { h: H0, buf: [0; 64], buflen: 0, total: 0 }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        self.total = self.total.wrapping_add(data.len() as u64);
        if self.buflen > 0 {
            let take = (64 - self.buflen).min(data.len());
            self.buf[self.buflen..self.buflen + take].copy_from_slice(&data[..take]);
            self.buflen += take;
            data = &data[take..];
            if self.buflen == 64 {
                let block = self.buf;
                compress(&mut self.h, &block);
                self.buflen = 0;
            }
        }
        while data.len() >= 64 {
            compress(&mut self.h, data[..64].try_into().expect("64-byte block"));
            data = &data[64..];
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buflen = data.len();
        }
    }

    pub fn finish(mut self) -> [u8; 32] {
        let bits = self.total.wrapping_mul(8);
        self.update(&[0x80]);
        while self.buflen != 56 {
            self.update(&[0]);
        }
        self.update(&bits.to_be_bytes());
        debug_assert_eq!(self.buflen, 0);
        let mut out = [0u8; 32];
        for (i, word) in self.h.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&word.to_be_bytes());
        }
        out
    }
}

fn compress(h: &mut [u32; 8], block: &[u8; 64]) {
    let mut w = [0u32; 64];
    for (i, word) in w.iter_mut().take(16).enumerate() {
        *word = u32::from_be_bytes(block[4 * i..4 * i + 4].try_into().expect("4-byte word"));
    }
    for i in 16..64 {
        let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
        let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
        w[i] = w[i - 16].wrapping_add(s0).wrapping_add(w[i - 7]).wrapping_add(s1);
    }
    let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh] = *h;
    for i in 0..64 {
        let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
        let ch = (e & f) ^ (!e & g);
        let t1 = hh.wrapping_add(s1).wrapping_add(ch).wrapping_add(K[i]).wrapping_add(w[i]);
        let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
        let maj = (a & b) ^ (a & c) ^ (b & c);
        let t2 = s0.wrapping_add(maj);
        hh = g;
        g = f;
        f = e;
        e = d.wrapping_add(t1);
        d = c;
        c = b;
        b = a;
        a = t1.wrapping_add(t2);
    }
    for (slot, v) in h.iter_mut().zip([a, b, c, d, e, f, g, hh]) {
        *slot = slot.wrapping_add(v);
    }
}

/// One-shot SHA-256.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finish()
}

/// One-shot SHA-256 as the registry's canonical lowercase-hex digest.
pub fn sha256_hex(data: &[u8]) -> String {
    hex(&sha256(data))
}

/// HMAC-SHA256 (RFC 2104).
pub fn hmac_sha256(key: &[u8], msg: &[u8]) -> [u8; 32] {
    let mut k = [0u8; 64];
    if key.len() > 64 {
        k[..32].copy_from_slice(&sha256(key));
    } else {
        k[..key.len()].copy_from_slice(key);
    }
    let mut inner = Sha256::new();
    let ipad: Vec<u8> = k.iter().map(|b| b ^ 0x36).collect();
    inner.update(&ipad);
    inner.update(msg);
    let inner_digest = inner.finish();
    let mut outer = Sha256::new();
    let opad: Vec<u8> = k.iter().map(|b| b ^ 0x5c).collect();
    outer.update(&opad);
    outer.update(&inner_digest);
    outer.finish()
}

/// Lowercase hex encoding.
pub fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

/// Inverse of [`hex`]; rejects odd lengths and non-hex characters.
pub fn unhex(s: &str) -> Result<Vec<u8>> {
    ensure!(s.is_ascii(), "hex string contains non-ASCII characters");
    ensure!(s.len() % 2 == 0, "odd-length hex string ({} chars)", s.len());
    (0..s.len())
        .step_by(2)
        .map(|i| {
            u8::from_str_radix(&s[i..i + 2], 16)
                .map_err(|_| anyhow!("bad hex byte '{}'", &s[i..i + 2]))
        })
        .collect()
}

/// A hex string shaped like a SHA-256 digest (64 lowercase hex chars) —
/// the validity gate for blob hashes and bundle ids before they are used
/// as file names or wire fields.
pub fn is_digest(s: &str) -> bool {
    s.len() == 64 && s.bytes().all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(&b))
}

// ---------------------------------------------------------------------------
// Deployment signing key
// ---------------------------------------------------------------------------

/// The shared deployment key: `key_id` names it on the wire and in signed
/// envelopes; `secret` never leaves `key.json`.
#[derive(Clone, PartialEq, Eq)]
pub struct SigningKey {
    pub key_id: String,
    secret: Vec<u8>,
}

impl std::fmt::Debug for SigningKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Never print the secret — key material ends up in logs otherwise.
        write!(f, "SigningKey({})", self.key_id)
    }
}

/// Where a deployment's key lives under its artifact directory.
pub fn key_path(artifact_dir: &Path) -> PathBuf {
    artifact_dir.join("registry").join("keys").join("key.json")
}

impl SigningKey {
    /// Derive a key from explicit secret bytes; `key_id` is the first 8
    /// hex chars of the secret's digest (safe to share — it only *names*
    /// the key).
    pub fn from_secret(secret: Vec<u8>) -> Self {
        let key_id = sha256_hex(&secret)[..8].to_string();
        SigningKey { key_id, secret }
    }

    /// Generate a fresh 32-byte deployment secret (see the module docs
    /// for the entropy story).
    pub fn generate() -> Self {
        use std::collections::hash_map::RandomState;
        use std::hash::{BuildHasher, Hasher};
        let mut h = Sha256::new();
        for i in 0u64..4 {
            let mut hs = RandomState::new().build_hasher();
            hs.write_u64(i);
            h.update(&hs.finish().to_le_bytes());
        }
        let now = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap_or_default();
        h.update(&now.as_nanos().to_le_bytes());
        h.update(&std::process::id().to_le_bytes());
        Self::from_secret(h.finish().to_vec())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading signing key {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("signing key {}: {e}", path.display()))?;
        let secret_hex = j
            .get("secret")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("signing key {}: missing 'secret'", path.display()))?;
        let key = Self::from_secret(unhex(secret_hex)?);
        // The stored key_id is advisory (always re-derived from the
        // secret), but a mismatch means the file was hand-edited.
        if let Some(stored) = j.get("key_id").and_then(Json::as_str) {
            ensure!(
                stored == key.key_id,
                "signing key {}: key_id '{stored}' does not match the secret (expected '{}')",
                path.display(),
                key.key_id
            );
        }
        Ok(key)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating key directory {}", dir.display()))?;
        }
        let j = obj(vec![
            ("key_id", Json::Str(self.key_id.clone())),
            ("secret", Json::Str(hex(&self.secret))),
        ]);
        super::store::atomic_write(path, format!("{j}\n").as_bytes())
            .with_context(|| format!("writing signing key {}", path.display()))
    }

    /// Load the deployment key under `artifact_dir`, generating and
    /// persisting one on first use.
    pub fn load_or_generate(artifact_dir: &Path) -> Result<Self> {
        let path = key_path(artifact_dir);
        if path.exists() {
            return Self::load(&path);
        }
        let key = Self::generate();
        key.save(&path)?;
        log::info!("generated deployment signing key {} at {}", key.key_id, path.display());
        Ok(key)
    }

    /// Hex HMAC-SHA256 signature over `msg`.
    pub fn sign(&self, msg: &[u8]) -> String {
        hex(&hmac_sha256(&self.secret, msg))
    }

    /// Verify a hex signature over `msg` (constant-time comparison).
    pub fn verify(&self, msg: &[u8], sig_hex: &str) -> bool {
        let Ok(got) = unhex(sig_hex) else { return false };
        let want = hmac_sha256(&self.secret, msg);
        if got.len() != want.len() {
            return false;
        }
        got.iter().zip(want.iter()).fold(0u8, |acc, (a, b)| acc | (a ^ b)) == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sha256_matches_fips_vectors() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
        // A million 'a's, streamed in awkward chunk sizes: exercises the
        // buffering path across block boundaries.
        let mut h = Sha256::new();
        let chunk = [b'a'; 997];
        let mut left = 1_000_000usize;
        while left > 0 {
            let take = left.min(chunk.len());
            h.update(&chunk[..take]);
            left -= take;
        }
        assert_eq!(
            hex(&h.finish()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn hmac_matches_rfc4231_vectors() {
        // RFC 4231 test case 2.
        assert_eq!(
            hex(&hmac_sha256(b"Jefe", b"what do ya want for nothing?")),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
        // Test case 1: 20 bytes of 0x0b, "Hi There".
        assert_eq!(
            hex(&hmac_sha256(&[0x0b; 20], b"Hi There")),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
        // Test case 6: a key longer than one block goes through the
        // hash-the-key path.
        assert_eq!(
            hex(&hmac_sha256(
                &[0xaa; 131],
                b"Test Using Larger Than Block-Size Key - Hash Key First"
            )),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
        );
    }

    #[test]
    fn hex_round_trips_and_rejects_garbage() {
        let bytes: Vec<u8> = (0..=255).collect();
        assert_eq!(unhex(&hex(&bytes)).unwrap(), bytes);
        assert!(unhex("abc").is_err()); // odd length
        assert!(unhex("zz").is_err()); // not hex
        assert!(is_digest(&sha256_hex(b"x")));
        assert!(!is_digest("abc"));
        assert!(!is_digest(&"A".repeat(64))); // uppercase is not canonical
        assert!(!is_digest(&"../".repeat(21)));
    }

    #[test]
    fn keys_sign_and_verify() {
        let key = SigningKey::from_secret(vec![7; 32]);
        let sig = key.sign(b"canonical bytes");
        assert!(key.verify(b"canonical bytes", &sig));
        assert!(!key.verify(b"tampered bytes", &sig));
        assert!(!key.verify(b"canonical bytes", "feed"));
        assert!(!key.verify(b"canonical bytes", "not hex!"));
        // A different deployment key refuses the signature.
        let other = SigningKey::from_secret(vec![8; 32]);
        assert!(!other.verify(b"canonical bytes", &sig));
        assert_ne!(key.key_id, other.key_id);
        // Debug never leaks the secret.
        assert!(!format!("{key:?}").contains(&hex(&[7u8; 32])));
    }

    #[test]
    fn key_persists_through_save_and_load() {
        let dir = std::env::temp_dir().join(format!("raca-sign-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = key_path(&dir);
        let key = SigningKey::generate();
        key.save(&path).unwrap();
        let back = SigningKey::load(&path).unwrap();
        assert_eq!(key, back);
        // load_or_generate finds the existing key instead of minting one.
        let again = SigningKey::load_or_generate(&dir).unwrap();
        assert_eq!(again.key_id, key.key_id);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
