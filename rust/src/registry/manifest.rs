//! Bundle manifests: what a deployable model *is*, as canonical bytes.
//!
//! A bundle names everything a die needs to serve a model: the two
//! weight blobs (`fcnn.json` metadata + `fcnn.bin` row-major matrices),
//! a calibration profile blob, the digest of the evaluation dataset it
//! was scored against, and the model's layer widths.  The manifest is
//! serialized with [`crate::util::json`], whose `Display` prints objects
//! with **sorted keys and no whitespace** — so `to_json().to_string()`
//! *is* the canonical byte encoding, no separate canonicalization pass:
//!
//! * `bundle_id = sha256(canonical bytes)` — identical content always
//!   maps to the same id, and any content change (retrained weights, new
//!   calibration) yields a new id;
//! * the HMAC signature ([`super::sign`]) is computed over those same
//!   canonical bytes, so a manifest re-serialized anywhere along the
//!   publish → advertise → resolve path verifies unchanged.

use anyhow::{anyhow, ensure, Result};

use crate::util::json::{obj, Json};

use super::sign::{is_digest, sha256_hex, SigningKey};

/// The content description of one deployable bundle.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    /// Model family name (today always `"fcnn"`).
    pub model: String,
    /// Layer widths, input to output (e.g. `[784, 500, 300, 10]`).
    pub widths: Vec<usize>,
    /// Blob hash of the weights metadata file (`fcnn.json`).
    pub weights_json: String,
    /// Blob hash of the packed weight matrices (`fcnn.bin`).
    pub weights_bin: String,
    /// Blob hash of the calibration profile.
    pub calibration: String,
    /// Digest of the evaluation dataset the bundle was scored against
    /// (empty when the publisher had none on disk).
    pub dataset_sha256: String,
}

impl Manifest {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("calibration", Json::Str(self.calibration.clone())),
            ("dataset_sha256", Json::Str(self.dataset_sha256.clone())),
            ("model", Json::Str(self.model.clone())),
            ("weights_bin", Json::Str(self.weights_bin.clone())),
            ("weights_json", Json::Str(self.weights_json.clone())),
            (
                "widths",
                Json::Arr(self.widths.iter().map(|&w| Json::Num(w as f64)).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let field = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("manifest: missing or non-string field '{k}'"))
        };
        let widths = j
            .get("widths")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing 'widths' array"))?
            .iter()
            .map(|w| w.as_usize().ok_or_else(|| anyhow!("manifest: non-integer width")))
            .collect::<Result<Vec<_>>>()?;
        let m = Manifest {
            model: field("model")?,
            widths,
            weights_json: field("weights_json")?,
            weights_bin: field("weights_bin")?,
            calibration: field("calibration")?,
            dataset_sha256: field("dataset_sha256")?,
        };
        for (name, h) in
            [("weights_json", &m.weights_json), ("weights_bin", &m.weights_bin), ("calibration", &m.calibration)]
        {
            ensure!(is_digest(h), "manifest: '{name}' is not a sha256 digest: '{h}'");
        }
        ensure!(
            m.dataset_sha256.is_empty() || is_digest(&m.dataset_sha256),
            "manifest: 'dataset_sha256' is neither empty nor a sha256 digest"
        );
        Ok(m)
    }

    /// The canonical byte encoding (sorted-key compact JSON) that both
    /// the bundle id and the signature are computed over.
    pub fn canonical_bytes(&self) -> Vec<u8> {
        self.to_json().to_string().into_bytes()
    }

    /// Content-derived bundle id: hex SHA-256 of the canonical bytes.
    pub fn bundle_id(&self) -> String {
        sha256_hex(&self.canonical_bytes())
    }

    /// Every blob hash this manifest references, in store order.
    pub fn blob_hashes(&self) -> [&str; 3] {
        [&self.weights_json, &self.weights_bin, &self.calibration]
    }
}

/// A manifest plus its deployment-key signature — the unit that travels
/// the wire and sits under `registry/manifests/<bundle_id>.json`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SignedManifest {
    pub manifest: Manifest,
    /// Names the deployment key that signed (never the secret itself).
    pub key_id: String,
    /// Hex HMAC-SHA256 over the manifest's canonical bytes.
    pub sig: String,
}

impl SignedManifest {
    /// Sign `manifest` with the deployment key.
    pub fn sign(manifest: Manifest, key: &SigningKey) -> Self {
        let sig = key.sign(&manifest.canonical_bytes());
        SignedManifest { manifest, key_id: key.key_id.clone(), sig }
    }

    /// Verify against the local deployment key; returns the bundle id on
    /// success.  Rejects foreign key ids outright — a correct signature
    /// under a key we do not hold is indistinguishable from garbage.
    pub fn verify(&self, key: &SigningKey) -> Result<String> {
        ensure!(
            self.key_id == key.key_id,
            "manifest signed by unknown key '{}' (deployment key is '{}')",
            self.key_id,
            key.key_id
        );
        let bytes = self.manifest.canonical_bytes();
        ensure!(
            key.verify(&bytes, &self.sig),
            "manifest signature does not verify under deployment key '{}'",
            key.key_id
        );
        Ok(sha256_hex(&bytes))
    }

    pub fn bundle_id(&self) -> String {
        self.manifest.bundle_id()
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("key_id", Json::Str(self.key_id.clone())),
            ("manifest", self.manifest.to_json()),
            ("sig", Json::Str(self.sig.clone())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let manifest = Manifest::from_json(
            j.get("manifest").ok_or_else(|| anyhow!("signed manifest: missing 'manifest'"))?,
        )?;
        let key_id = j
            .get("key_id")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("signed manifest: missing 'key_id'"))?
            .to_string();
        let sig = j
            .get("sig")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("signed manifest: missing 'sig'"))?
            .to_string();
        Ok(SignedManifest { manifest, key_id, sig })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        Manifest {
            model: "fcnn".into(),
            widths: vec![784, 48, 24, 10],
            weights_json: sha256_hex(b"weights json"),
            weights_bin: sha256_hex(b"weights bin"),
            calibration: sha256_hex(b"calibration"),
            dataset_sha256: sha256_hex(b"dataset"),
        }
    }

    #[test]
    fn canonical_bytes_round_trip() {
        // Serialize → parse → re-serialize must be byte-identical: the
        // signature and the bundle id both hang on this.
        let m = sample();
        let bytes = m.canonical_bytes();
        let back =
            Manifest::from_json(&Json::parse(std::str::from_utf8(&bytes).unwrap()).unwrap())
                .unwrap();
        assert_eq!(back, m);
        assert_eq!(back.canonical_bytes(), bytes);
        assert_eq!(back.bundle_id(), m.bundle_id());
        assert!(is_digest(&m.bundle_id()));
    }

    #[test]
    fn any_content_change_moves_the_bundle_id() {
        let m = sample();
        let mut retrained = m.clone();
        retrained.weights_bin = sha256_hex(b"weights bin after --force retrain");
        assert_ne!(m.bundle_id(), retrained.bundle_id());
        let mut recalibrated = m.clone();
        recalibrated.calibration = sha256_hex(b"new profile");
        assert_ne!(m.bundle_id(), recalibrated.bundle_id());
    }

    #[test]
    fn signatures_verify_and_reject() {
        let key = SigningKey::from_secret(vec![1; 32]);
        let env = SignedManifest::sign(sample(), &key);
        assert_eq!(env.verify(&key).unwrap(), env.bundle_id());

        // Round trip through JSON keeps the signature valid.
        let back = SignedManifest::from_json(&Json::parse(&env.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(back, env);
        assert_eq!(back.verify(&key).unwrap(), env.bundle_id());

        // Tampered content: same signature over different canonical bytes.
        let mut tampered = env.clone();
        tampered.manifest.widths = vec![784, 10];
        let err = tampered.verify(&key).unwrap_err();
        assert!(format!("{err:#}").contains("signature"), "{err:#}");

        // Foreign deployment key: refused by key id before any math.
        let other = SigningKey::from_secret(vec![2; 32]);
        let err = env.verify(&other).unwrap_err();
        assert!(format!("{err:#}").contains("unknown key"), "{err:#}");
    }

    #[test]
    fn malformed_manifests_name_the_field() {
        let err = Manifest::from_json(&Json::parse(r#"{"model":"fcnn"}"#).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("widths"), "{err:#}");
        let j = Json::parse(
            r#"{"calibration":"nope","dataset_sha256":"","model":"fcnn",
                "weights_bin":"x","weights_json":"y","widths":[784,10]}"#,
        )
        .unwrap();
        let err = Manifest::from_json(&j).unwrap_err();
        assert!(format!("{err:#}").contains("sha256 digest"), "{err:#}");
    }
}
