//! Trial-budget planner: how many WTA decisions does a target accuracy
//! need?  (The quantitative version of Fig. 6's "repeating the stochastic
//! inference … could quickly improve the overall recognition accuracy".)
//!
//! Model: per trial the correct class wins with probability `p1` and the
//! strongest confuser with `p2` (estimable from the ideal softmax or from
//! measured win frequencies).  The majority vote errs when the confuser
//! out-votes the truth; for k trials the normal approximation to the
//! difference of the two counts gives
//!
//!   P(err) ≈ Φ(−√k · (p1 − p2) / √(p1 + p2 − (p1 − p2)²))
//!
//! which the planner inverts for k.  Also exposed: the coordinator's
//! expected early-stop trial count under the same model.

use crate::stats::erf::{norm_cdf, norm_ppf};

/// Per-image vote statistics.
#[derive(Debug, Clone, Copy)]
pub struct VoteModel {
    /// Win probability of the true class per trial.
    pub p_top: f64,
    /// Win probability of the strongest runner-up.
    pub p_second: f64,
}

impl VoteModel {
    pub fn new(p_top: f64, p_second: f64) -> Self {
        assert!(p_top > 0.0 && p_second >= 0.0 && p_top + p_second <= 1.0 + 1e-9);
        Self { p_top, p_second }
    }

    /// Probability the k-trial majority vote picks the true class.
    pub fn vote_accuracy(&self, k: usize) -> f64 {
        if self.p_top <= self.p_second {
            return 0.5; // degenerate: voting cannot separate them
        }
        let d = self.p_top - self.p_second;
        let var = self.p_top + self.p_second - d * d;
        if var <= 0.0 {
            return 1.0;
        }
        norm_cdf((k as f64).sqrt() * d / var.sqrt())
    }

    /// Minimal trials for `target` vote accuracy (∞-safe cap at 10⁶).
    pub fn trials_for_accuracy(&self, target: f64) -> Option<usize> {
        assert!((0.5..1.0).contains(&target));
        if self.p_top <= self.p_second {
            return None;
        }
        let d = self.p_top - self.p_second;
        let var = self.p_top + self.p_second - d * d;
        let z = norm_ppf(target);
        let k = (z * z * var / (d * d)).ceil() as usize;
        Some(k.clamp(1, 1_000_000))
    }

    /// Expected trials until the Wilson early stopper (confidence c)
    /// separates top from runner-up — approximated by solving the same
    /// normal bound at confidence c.
    pub fn expected_early_stop_trials(&self, confidence: f64, min_trials: u32) -> f64 {
        match self.trials_for_accuracy(confidence.clamp(0.51, 0.9999)) {
            Some(k) => (k as f64).max(min_trials as f64),
            None => f64::INFINITY,
        }
    }
}

/// Derive a [`VoteModel`] from softmax probabilities (top two entries).
pub fn vote_model_from_probs(probs: &[f64]) -> VoteModel {
    let mut top = 0.0f64;
    let mut second = 0.0f64;
    for &p in probs {
        if p > top {
            second = top;
            top = p;
        } else if p > second {
            second = p;
        }
    }
    VoteModel::new(top, second)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn more_trials_more_accuracy() {
        let m = VoteModel::new(0.4, 0.3);
        assert!(m.vote_accuracy(64) > m.vote_accuracy(4));
        assert!(m.vote_accuracy(1000) > 0.99);
    }

    #[test]
    fn planner_inverts_accuracy() {
        let m = VoteModel::new(0.45, 0.25);
        for target in [0.9, 0.99, 0.999] {
            let k = m.trials_for_accuracy(target).unwrap();
            assert!(m.vote_accuracy(k) >= target - 0.01, "target {target} k {k}");
            if k > 2 {
                assert!(m.vote_accuracy(k / 4) < target, "k {k} not minimal-ish");
            }
        }
    }

    #[test]
    fn easy_inputs_need_one_trial() {
        let m = VoteModel::new(0.95, 0.02);
        assert_eq!(m.trials_for_accuracy(0.9).unwrap(), 1);
    }

    #[test]
    fn tied_inputs_unplannable() {
        let m = VoteModel::new(0.3, 0.3);
        assert!(m.trials_for_accuracy(0.9).is_none());
        assert!(m.expected_early_stop_trials(0.95, 5).is_infinite());
    }

    #[test]
    fn from_probs_picks_top_two() {
        let m = vote_model_from_probs(&[0.1, 0.5, 0.2, 0.2]);
        assert!((m.p_top - 0.5).abs() < 1e-12);
        assert!((m.p_second - 0.2).abs() < 1e-12);
    }
}
