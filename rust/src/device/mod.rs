//! ReRAM device layer (DESIGN.md §4.2).
//!
//! Behavioural model of an Ag:Si-like ReRAM cell: programmable conductance
//! in [G_MIN, G_MAX] with lognormal write variation, and the noise physics
//! the paper's whole idea rests on — Johnson–Nyquist thermal noise (Eq. 1)
//! plus optional shot / RTN / 1-f terms for ablations (E-ABL1).

pub mod noise;
pub mod reram;
pub mod variation;

pub use noise::{NoiseModel, NoiseParams};
pub use reram::{DeviceParams, ReramCell};
pub use variation::VariationModel;

/// Boltzmann constant [J/K].
pub const K_B: f64 = 1.380649e-23;

/// Default operating temperature [K].
pub const TEMPERATURE: f64 = 300.0;

/// Low-conductance state [S] (mirrors python physics.G_MIN).
pub const G_MIN: f64 = 1e-6;

/// High-conductance state [S] (mirrors python physics.G_MAX).
pub const G_MAX: f64 = 100e-6;

/// Weight clip range: weights live in [−W_CLIP, W_CLIP].
pub const W_CLIP: f64 = 4.0;

/// sigmoid(z) ≈ Φ(z/1.702) matching constant.
pub const SIGMOID_PROBIT: f64 = 1.702;

/// Default readout bandwidth Δf [Hz].
pub const DELTA_F: f64 = 1e9;
