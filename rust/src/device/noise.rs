//! Device noise models (paper §II-A, Eq. 1 + ablation extensions).
//!
//! The headline mechanism is Johnson–Nyquist thermal current noise,
//! `σ² = 4kTGΔf`, Gaussian and conductance-proportional — exactly what
//! Eq. 13's sigmoid emulation needs.  For E-ABL1 we also model:
//!
//! * **shot noise** `σ² = 2qI̅Δf` (current-dependent),
//! * **random telegraph noise (RTN)**: a two-state conductance flicker
//!   with Markov switching, the dominant low-frequency ReRAM defect noise,
//! * **1/f (flicker)** approximated per-read as a Gaussian with amplitude
//!   `α·G·V/√f_corner-ish` — adequate for a per-decision-sample model.

use super::{K_B, TEMPERATURE};
use crate::stats::GaussianSource;

/// Elementary charge [C].
pub const Q_E: f64 = 1.602176634e-19;

/// Noise configuration for a readout.
#[derive(Debug, Clone)]
pub struct NoiseParams {
    pub temperature: f64,
    /// Readout bandwidth Δf [Hz].
    pub delta_f: f64,
    /// Enable thermal (Nyquist) noise — the paper's mechanism.
    pub thermal: bool,
    /// Enable shot noise 2qIΔf.
    pub shot: bool,
    /// RTN: relative conductance amplitude (ΔG/G) and switching prob/read.
    pub rtn_amplitude: f64,
    pub rtn_switch_prob: f64,
    /// 1/f: relative current amplitude per read (0 = off).
    pub flicker_amplitude: f64,
}

impl Default for NoiseParams {
    fn default() -> Self {
        Self {
            temperature: TEMPERATURE,
            delta_f: super::DELTA_F,
            thermal: true,
            shot: false,
            rtn_amplitude: 0.0,
            rtn_switch_prob: 0.0,
            flicker_amplitude: 0.0,
        }
    }
}

impl NoiseParams {
    /// Paper-exact configuration (thermal only).
    pub fn thermal_only(delta_f: f64) -> Self {
        Self { delta_f, ..Self::default() }
    }

    /// "Kitchen sink" configuration for robustness ablations.
    pub fn full(delta_f: f64) -> Self {
        Self {
            delta_f,
            shot: true,
            rtn_amplitude: 0.02,
            rtn_switch_prob: 0.01,
            flicker_amplitude: 0.005,
            ..Self::default()
        }
    }

    /// Thermal current-noise RMS for conductance `g` (Eq. 1).
    #[inline]
    pub fn thermal_rms(&self, g: f64) -> f64 {
        (4.0 * K_B * self.temperature * g * self.delta_f).sqrt()
    }

    /// Shot-noise RMS for mean current `i` [A].
    #[inline]
    pub fn shot_rms(&self, i: f64) -> f64 {
        (2.0 * Q_E * i.abs() * self.delta_f).sqrt()
    }
}

/// Per-device noise state (RTN needs memory between reads).
#[derive(Debug, Clone)]
pub struct NoiseModel {
    pub params: NoiseParams,
    /// RTN state per device: +1/−1 (low-high trap occupancy).
    rtn_state: Vec<i8>,
}

impl NoiseModel {
    pub fn new(params: NoiseParams, n_devices: usize) -> Self {
        Self { params, rtn_state: vec![1; n_devices] }
    }

    /// Sample the instantaneous noise current [A] for device `idx` with
    /// conductance `g` carrying mean current `i_mean` at this read.
    #[inline]
    pub fn sample(&mut self, idx: usize, g: f64, i_mean: f64,
                  gauss: &mut GaussianSource) -> f64 {
        let p = &self.params;
        let mut var = 0.0;
        if p.thermal {
            var += 4.0 * K_B * p.temperature * g * p.delta_f;
        }
        if p.shot {
            var += 2.0 * Q_E * i_mean.abs() * p.delta_f;
        }
        if p.flicker_amplitude > 0.0 {
            let a = p.flicker_amplitude * i_mean.abs();
            var += a * a;
        }
        let mut n = if var > 0.0 { gauss.next() * var.sqrt() } else { 0.0 };
        if p.rtn_amplitude > 0.0 {
            let s = &mut self.rtn_state[idx];
            if gauss.rng().next_f64() < p.rtn_switch_prob {
                *s = -*s;
            }
            // RTN shifts the conductance, hence the current, by ±ΔG·V —
            // expressed here through the mean current.
            n += *s as f64 * p.rtn_amplitude * i_mean;
        }
        n
    }

    /// Aggregate *variance* of a whole column (sum of device variances) —
    /// the fast path used by the column-level simulator when per-device
    /// sampling is disabled.  Thermal + shot only (RTN/flicker need state).
    pub fn column_variance(&self, g_sum: f64, i_sum_abs: f64) -> f64 {
        let p = &self.params;
        let mut var = 0.0;
        if p.thermal {
            var += 4.0 * K_B * p.temperature * g_sum * p.delta_f;
        }
        if p.shot {
            var += 2.0 * Q_E * i_sum_abs * p.delta_f;
        }
        var
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Summary;

    #[test]
    fn thermal_rms_matches_nyquist() {
        let p = NoiseParams::thermal_only(1e9);
        // 4kTGΔf with G = 100 µS, Δf = 1 GHz at 300 K.
        let want = (4.0 * K_B * 300.0 * 100e-6 * 1e9).sqrt();
        assert!((p.thermal_rms(100e-6) - want).abs() / want < 1e-12);
    }

    #[test]
    fn sampled_std_matches_formula() {
        let p = NoiseParams::thermal_only(1e9);
        let mut m = NoiseModel::new(p.clone(), 1);
        let mut g = GaussianSource::new(1);
        let mut s = Summary::new();
        for _ in 0..50_000 {
            s.add(m.sample(0, 50e-6, 0.0, &mut g));
        }
        let want = p.thermal_rms(50e-6);
        assert!(s.mean().abs() < want * 0.05);
        assert!((s.std() - want).abs() / want < 0.02);
    }

    #[test]
    fn noise_scales_with_bandwidth() {
        let mut g = GaussianSource::new(2);
        let mut std_at = |df: f64| {
            let mut m = NoiseModel::new(NoiseParams::thermal_only(df), 1);
            let mut s = Summary::new();
            for _ in 0..20_000 {
                s.add(m.sample(0, 50e-6, 0.0, &mut g));
            }
            s.std()
        };
        let r = std_at(4e9) / std_at(1e9);
        assert!((r - 2.0).abs() < 0.1, "ratio={r}");
    }

    #[test]
    fn rtn_switches_states() {
        let params = NoiseParams {
            thermal: false,
            rtn_amplitude: 0.1,
            rtn_switch_prob: 0.5,
            ..NoiseParams::default()
        };
        let mut m = NoiseModel::new(params, 1);
        let mut g = GaussianSource::new(3);
        let vals: Vec<f64> = (0..100).map(|_| m.sample(0, 1e-5, 1e-6, &mut g)).collect();
        let pos = vals.iter().filter(|&&v| v > 0.0).count();
        assert!(pos > 20 && pos < 80, "RTN never switched: pos={pos}");
        for v in vals {
            assert!((v.abs() - 1e-7).abs() < 1e-12); // ±amplitude·I exactly
        }
    }

    #[test]
    fn column_variance_adds_devices() {
        let m = NoiseModel::new(NoiseParams::thermal_only(1e9), 0);
        let v1 = m.column_variance(100e-6, 0.0);
        let v2 = m.column_variance(200e-6, 0.0);
        assert!((v2 / v1 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn shot_noise_depends_on_current() {
        let params = NoiseParams { thermal: false, shot: true, ..Default::default() };
        let m = NoiseModel::new(params, 0);
        assert_eq!(m.column_variance(1e-4, 0.0), 0.0);
        assert!(m.column_variance(1e-4, 1e-6) > 0.0);
    }
}
