//! Single ReRAM cell: programming + read with conductance bounds.

use super::{G_MAX, G_MIN};
use crate::stats::GaussianSource;

/// Static device parameters (per technology corner).
#[derive(Debug, Clone)]
pub struct DeviceParams {
    /// Programmable conductance range [S].
    pub g_min: f64,
    pub g_max: f64,
    /// Lognormal programming variation σ (0 = ideal write).
    pub program_sigma: f64,
    /// Conductance relaxation/drift per read, as a fraction (usually 0;
    /// exposed for failure-injection tests).
    pub drift_per_read: f64,
}

impl Default for DeviceParams {
    fn default() -> Self {
        Self { g_min: G_MIN, g_max: G_MAX, program_sigma: 0.0, drift_per_read: 0.0 }
    }
}

impl DeviceParams {
    pub fn with_variation(sigma: f64) -> Self {
        Self { program_sigma: sigma, ..Self::default() }
    }
}

/// One programmable cell.
#[derive(Debug, Clone)]
pub struct ReramCell {
    /// Actual programmed conductance [S] (may deviate from target).
    pub g: f64,
    /// Target conductance the mapper asked for [S].
    pub g_target: f64,
}

impl ReramCell {
    /// Program toward `g_target`, applying lognormal write variation:
    /// G = G_target · exp(N(0, σ²)), clamped to the physical range.
    pub fn program(g_target: f64, params: &DeviceParams, gauss: &mut GaussianSource) -> Self {
        let g_t = g_target.clamp(params.g_min, params.g_max);
        let g = if params.program_sigma > 0.0 {
            (g_t * gauss.lognormal(0.0, params.program_sigma)).clamp(params.g_min, params.g_max)
        } else {
            g_t
        };
        Self { g, g_target: g_t }
    }

    /// Ideal (variation-free) cell.
    pub fn ideal(g_target: f64, params: &DeviceParams) -> Self {
        let g_t = g_target.clamp(params.g_min, params.g_max);
        Self { g: g_t, g_target: g_t }
    }

    /// Mean read current at voltage `v` [A] (Ohm's law; noise is added by
    /// the column readout, not per-read here, to keep the hot loop tight).
    #[inline]
    pub fn read_current(&self, v: f64) -> f64 {
        v * self.g
    }

    /// Apply post-read drift (failure-injection ablation).
    pub fn drift(&mut self, params: &DeviceParams) {
        if params.drift_per_read != 0.0 {
            self.g = (self.g * (1.0 - params.drift_per_read)).clamp(params.g_min, params.g_max);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_programs_exactly() {
        let p = DeviceParams::default();
        let c = ReramCell::ideal(5e-5, &p);
        assert_eq!(c.g, 5e-5);
    }

    #[test]
    fn programming_clamps_to_range() {
        let p = DeviceParams::default();
        let lo = ReramCell::ideal(0.0, &p);
        let hi = ReramCell::ideal(1.0, &p);
        assert_eq!(lo.g, p.g_min);
        assert_eq!(hi.g, p.g_max);
    }

    #[test]
    fn variation_is_median_unbiased() {
        let p = DeviceParams::with_variation(0.1);
        let mut g = GaussianSource::new(3);
        let target = 5e-5;
        let n = 20_000;
        let below = (0..n)
            .filter(|_| ReramCell::program(target, &p, &mut g).g < target)
            .count();
        // Lognormal: median at target → ~half below.
        assert!((below as f64 / n as f64 - 0.5).abs() < 0.02);
    }

    #[test]
    fn variation_spread_scales() {
        let mut g = GaussianSource::new(4);
        let mut spread = |sigma: f64| {
            let p = DeviceParams::with_variation(sigma);
            let mut s = crate::stats::Summary::new();
            for _ in 0..5000 {
                s.add(ReramCell::program(5e-5, &p, &mut g).g);
            }
            s.std()
        };
        assert!(spread(0.2) > 1.5 * spread(0.05));
    }

    #[test]
    fn ohms_law_read() {
        let c = ReramCell::ideal(2e-5, &DeviceParams::default());
        assert!((c.read_current(0.1) - 2e-6).abs() < 1e-18);
    }

    #[test]
    fn drift_decays_conductance() {
        let p = DeviceParams { drift_per_read: 0.01, ..Default::default() };
        let mut c = ReramCell::ideal(5e-5, &p);
        for _ in 0..10 {
            c.drift(&p);
        }
        assert!(c.g < 5e-5 && c.g > 4e-5);
    }
}
