//! Device-to-device variation model (E-ABL2).
//!
//! Real crossbars never program conductances exactly; the dominant effect
//! is multiplicative (lognormal) write error plus a small additive stuck
//! probability.  The ablation sweeps σ ∈ {0..10%} and measures Fig. 6
//! accuracy degradation.

use crate::stats::GaussianSource;

/// Variation configuration for array programming.
#[derive(Debug, Clone)]
pub struct VariationModel {
    /// Lognormal σ of the multiplicative write error (0 = ideal).
    pub sigma: f64,
    /// Probability a device is stuck at G_min (dead) after programming.
    pub stuck_lo_prob: f64,
    /// Probability a device is stuck at G_max (shorted).
    pub stuck_hi_prob: f64,
}

impl Default for VariationModel {
    fn default() -> Self {
        Self { sigma: 0.0, stuck_lo_prob: 0.0, stuck_hi_prob: 0.0 }
    }
}

impl VariationModel {
    pub fn lognormal(sigma: f64) -> Self {
        Self { sigma, ..Default::default() }
    }

    pub fn with_defects(sigma: f64, stuck_lo: f64, stuck_hi: f64) -> Self {
        Self { sigma, stuck_lo_prob: stuck_lo, stuck_hi_prob: stuck_hi }
    }

    pub fn is_ideal(&self) -> bool {
        self.sigma == 0.0 && self.stuck_lo_prob == 0.0 && self.stuck_hi_prob == 0.0
    }

    /// Apply variation to a target conductance, clamped to [g_min, g_max].
    pub fn apply(&self, g_target: f64, g_min: f64, g_max: f64,
                 gauss: &mut GaussianSource) -> f64 {
        if self.is_ideal() {
            return g_target.clamp(g_min, g_max);
        }
        let u = gauss.rng().next_f64();
        if u < self.stuck_lo_prob {
            return g_min;
        }
        if u < self.stuck_lo_prob + self.stuck_hi_prob {
            return g_max;
        }
        let g = if self.sigma > 0.0 {
            g_target * gauss.lognormal(0.0, self.sigma)
        } else {
            g_target
        };
        g.clamp(g_min, g_max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_passthrough() {
        let v = VariationModel::default();
        let mut g = GaussianSource::new(1);
        assert_eq!(v.apply(5e-5, 1e-6, 1e-4, &mut g), 5e-5);
    }

    #[test]
    fn clamps() {
        let v = VariationModel::default();
        let mut g = GaussianSource::new(1);
        assert_eq!(v.apply(1.0, 1e-6, 1e-4, &mut g), 1e-4);
        assert_eq!(v.apply(0.0, 1e-6, 1e-4, &mut g), 1e-6);
    }

    #[test]
    fn stuck_fractions() {
        let v = VariationModel::with_defects(0.0, 0.1, 0.05);
        let mut g = GaussianSource::new(2);
        let n = 50_000;
        let mut lo = 0;
        let mut hi = 0;
        for _ in 0..n {
            let gv = v.apply(5e-5, 1e-6, 1e-4, &mut g);
            if gv == 1e-6 {
                lo += 1;
            } else if gv == 1e-4 {
                hi += 1;
            }
        }
        assert!((lo as f64 / n as f64 - 0.10).abs() < 0.01);
        assert!((hi as f64 / n as f64 - 0.05).abs() < 0.01);
    }

    #[test]
    fn sigma_widens_distribution() {
        let mut g = GaussianSource::new(3);
        let spread = |sigma: f64, g: &mut GaussianSource| {
            let v = VariationModel::lognormal(sigma);
            let mut s = crate::stats::Summary::new();
            for _ in 0..10_000 {
                s.add(v.apply(5e-5, 1e-9, 1e-3, g));
            }
            s.std()
        };
        assert!(spread(0.10, &mut g) > 3.0 * spread(0.02, &mut g));
    }
}
