//! [`Backend`] #3: one model sharded layer-ranges-per-die, activations
//! streamed die-to-die — capacity and throughput scale with fleet size.
//!
//! An [`crate::arch::ShardPlan`] (floorplan-balanced contiguous layer
//! partition) assigns each die its layer range; each die runs on its own
//! thread and the binary hidden activations flow die-to-die over
//! channels, exactly like the chip-to-chip links of a tiled multi-die
//! deployment.  The first die holds the input crossbar and caches the
//! deterministic layer-0 pre-activation per request (the mean column
//! current is fixed per image — only comparator noise resamples between
//! trials), the last die runs the WTA race.
//!
//! **Bit-parity invariant:** every die continues the *same* per-trial
//! noise stream the unsharded [`NativeEngine`] would use — the stream is
//! seeded from `(backend seed, trial index)` and each die skips exactly
//! the draws its upstream neighbours consumed
//! ([`crate::arch::ShardPlan::noise_skip`]).  With `variation: None` the
//! sharded pipeline therefore reproduces `NativeEngine` votes
//! bit-for-bit at equal `(seed, trial_idx)`, across any die count —
//! `rust/tests/serve.rs` holds it to that.  With a variation model, each
//! die programs its slice through the conductance mapping with its own
//! `(fleet_seed, die)` draw, like any other fleet chip.
//!
//! A control thread owns vote state: it keeps up to `depth` trials in
//! flight across the pipeline (round-robin over active requests, so the
//! slowest die stays saturated), counts returned winners, applies the
//! Wilson-interval early stopper, and answers tickets.  Trials travel in
//! blocks of up to [`PipelineOptions::batch`] per die-to-die message —
//! one channel send moves a whole activation slab, amortizing per-message
//! overhead without touching the per-trial noise streams.  Since §Perf
//! iteration 5 each die also *executes* the block as one pass of the
//! bit-packed trial kernel ([`crate::nn::forward::stochastic_logits_block`]):
//! a `StageMsg::Trials` block maps 1:1 onto a kernel block, so every f32
//! weight row of the die's layers is read once per message instead of
//! once per trial — larger `:bN` now amortizes weight traffic, not just
//! channel overhead, still without touching the noise streams.  (§Perf
//! iteration 6: the kernel primitives each stage calls —
//! `hidden_layer_block`, `output_layer_block`, `wta_race_block`,
//! `GaussianSource::fill` — dispatch to the explicit SIMD kernels of
//! [`crate::util::simd`] internally, so every stage, and likewise the
//! replicated-fleet and HTTP-batcher paths, picks up the vectorized hot
//! loops without any topology-level changes; the bit-parity contract
//! above is unaffected because the kernels vectorize across columns
//! only.)
//!
//! [`NativeEngine`]: crate::engine::NativeEngine

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::Ordering::Relaxed;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::arch::ShardPlan;
use crate::coordinator::{Metrics, MetricsSnapshot};
use crate::device::VariationModel;
use crate::engine::{trial_rng, wta_race_block, TrialParams};
use crate::fleet::{chip_seed, program_weights};
use crate::neuron::WtaOutcome;
use crate::nn::{forward, Weights};
use crate::stats::ci::lead_is_decided;
use crate::stats::GaussianSource;
use crate::telemetry::{EventKind, Journal, MetricsTree};

use super::{trial_stream_base, Backend, InferRequest, InferResponse, RequestId};

/// Knobs of the pipelined backend.
#[derive(Debug, Clone)]
pub struct PipelineOptions {
    /// Dies to shard the model's layers across (≤ layer count).
    pub dies: usize,
    /// Crossbar tile edge for the shard-balance criterion.
    pub tile: usize,
    /// Trial physics (σ_z, θ, WTA steps) — shared by every die.
    pub params: TrialParams,
    /// Per-die programming variation.  `None` programs exact nominal
    /// weight slices (the bit-parity configuration).
    pub variation: Option<VariationModel>,
    /// Fleet seed: the shared trial-RNG identity *and* the root of
    /// per-die variation draws.
    pub seed: u64,
    /// Fleet-wide id of this pipeline's first die.  Composed deployments
    /// ([`crate::serve::plan`]) number every physical die once across the
    /// whole topology; variation draws key off `chip_seed(seed,
    /// chip_base + d)`, so two replicas of the same shard plan are
    /// distinct silicon.  0 for a standalone pipeline (the PR-2 shape).
    pub chip_base: usize,
    /// Minimum recorded trials before early stopping may fire.
    pub min_trials: u32,
    /// Maximum trials in flight across the pipeline (flow control).
    pub depth: usize,
    /// Admission cap on concurrent requests.
    pub max_in_flight: usize,
    /// Trials carried per die-to-die message.  Each channel send moves a
    /// `batch`-trial block (one activation slab), amortizing per-message
    /// overhead; trial indices inside a block stay `base + k`, so batching
    /// is invisible to the bit-parity contract.
    pub batch: usize,
    /// Deployment-wide event journal (admissions, completions, in-band
    /// failures).  `None` disables event logging for this pipeline.
    pub journal: Option<Arc<Journal>>,
}

impl Default for PipelineOptions {
    fn default() -> Self {
        Self {
            dies: 2,
            tile: 128,
            params: TrialParams::default(),
            variation: None,
            seed: 0xF1E7D,
            chip_base: 0,
            min_trials: 5,
            depth: 256,
            max_in_flight: 256,
            batch: 8,
            journal: None,
        }
    }
}

/// One die of the pipeline: a contiguous range of the model's layers.
struct LayerStage {
    /// Global index of this die's first layer.
    first_layer: usize,
    /// This die's programmed weight slice (`widths[first..=last+1]`).
    weights: Weights,
    /// Noise draws consumed upstream per trial (skipped off the stream).
    noise_skip: usize,
    /// Shared logical-chip RNG identity (equal across dies — the pipeline
    /// *is* one chip's trial stream, spread over dies).
    engine_seed: u64,
    /// Whether this die owns the output layer (runs the WTA race).
    is_output: bool,
}

impl LayerStage {
    /// Position one noise stream per trial of a block at this die's first
    /// neuron: the engine's own [`trial_rng`] derivation per
    /// `base_idx + k`, then skip the upstream dies' draws.
    fn block_gauss(&self, base_idx: u64, count: usize, out: &mut Vec<GaussianSource>) {
        out.clear();
        out.reserve(count);
        for k in 0..count as u64 {
            let mut g =
                GaussianSource::from_rng(trial_rng(self.engine_seed, base_idx.wrapping_add(k)));
            for _ in 0..self.noise_skip {
                g.next();
            }
            out.push(g);
        }
    }

    /// Run this die's layers for one `count`-trial block through the
    /// bit-packed kernel (§Perf iteration 5): a `StageMsg::Trials` block
    /// maps 1:1 onto a kernel block, so each weight row of every local
    /// layer is read once per *message*, not once per trial.  `input` is
    /// the cached z1 pre-activation when this die holds the input layer
    /// (shared by the whole block — trials of one request), otherwise the
    /// upstream die's slab of `count` binary activation rows.  Non-output
    /// dies append their outgoing slab to `out_h`; the output die pushes
    /// one WTA winner per trial onto `winners`.  Per trial this consumes
    /// the exact draws the scalar path did, so bit-parity with the
    /// unsharded engine is preserved at any batch size.
    fn run_block(
        &self,
        input: &[f32],
        p: TrialParams,
        base_idx: u64,
        count: usize,
        s: &mut forward::BlockScratch,
        out_h: &mut Vec<f32>,
        winners: &mut Vec<i32>,
    ) {
        let sigma = p.sigma_z as f64;
        let n_local = self.weights.spec.num_layers();
        self.block_gauss(base_idx, count, &mut s.gauss);
        let start = if self.first_layer == 0 {
            forward::binarize_shared_block(input, sigma, s);
            1
        } else {
            forward::pack_rows_block(input, self.weights.spec.input_dim(), count, s);
            0
        };
        for l in start..n_local {
            let (rows, cols, m) = self.weights.layer(l);
            if self.is_output && l == n_local - 1 {
                forward::output_layer_block(rows, cols, m, s);
                wta_race_block(&s.logits, cols, p, &mut s.gauss, winners);
                return;
            }
            forward::hidden_layer_block(rows, cols, m, sigma, s);
        }
        forward::unpack_block_rows(s, out_h);
    }
}

enum CtrlMsg {
    Submit(InferRequest, mpsc::Sender<InferResponse>, Instant),
    Shutdown,
}

enum StageMsg {
    /// New request: the input die computes and caches its z1.
    Open { req: RequestId, image: Vec<f32> },
    /// A block of `count` consecutive trials (`base_idx + k`, k < count)
    /// flowing down the pipeline as one message — the die-to-die channel
    /// amortization.  `h` holds `count` concatenated activation rows
    /// (empty into die 0, which reads its cached z1 instead).  `gen` is
    /// the admission generation of the request — it lets the control
    /// thread discard speculative winners that land after the request
    /// completed (and possibly after its id was reused).
    Trials { req: RequestId, gen: u64, base_idx: u64, count: u32, h: Vec<f32> },
    /// Request finished: the input die drops its cache entry.
    Close { req: RequestId },
}

enum StageSink {
    Next(mpsc::Sender<StageMsg>),
    Collect(mpsc::Sender<(RequestId, u64, Vec<i32>)>),
}

/// Pipeline-sharded serving session.
pub struct PipelinedFleetBackend {
    sub_tx: mpsc::Sender<CtrlMsg>,
    control: Option<JoinHandle<()>>,
    stages: Vec<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    stage_metrics: Vec<Arc<Metrics>>,
    plan: ShardPlan,
    /// Telemetry name (`pipeline:<dies> [chips a..b]`).
    label: String,
    journal: Option<Arc<Journal>>,
}

impl PipelinedFleetBackend {
    /// Shard `nominal`'s layers across `opts.dies` dies and spawn the
    /// pipeline (one thread per die + a control thread).  Errors — rather
    /// than panicking downstream — when the die count exceeds the layer
    /// count.
    ///
    /// Crate-private: deployments are built by [`crate::serve::plan`]
    /// (a standalone pipeline is the `pipeline:<dies>` topology leaf).
    pub(crate) fn start(nominal: &Weights, opts: PipelineOptions) -> Result<Self> {
        ensure!(
            nominal.spec.num_layers() >= 2,
            "pipelined backend needs a model with at least 2 layers"
        );
        let plan = ShardPlan::balanced(&nominal.spec, opts.tile, opts.dies)
            .map_err(|e| anyhow!("building shard plan: {e}"))?;
        let dies = plan.dies();

        let mut stage_defs = Vec::with_capacity(dies);
        for d in 0..dies {
            let r = plan.ranges[d].clone();
            let mut w = Weights {
                spec: plan.sub_spec(d),
                mats: nominal.mats[r.clone()].to_vec(),
                ideal_test_accuracy: nominal.ideal_test_accuracy,
            };
            if let Some(v) = &opts.variation {
                // Each die is still a real programmed chip: its slice goes
                // through the conductance mapping with a private draw keyed
                // by its *fleet-wide* id, so replicated pipelines program
                // distinct silicon.
                let mut gauss =
                    GaussianSource::new(chip_seed(opts.seed, opts.chip_base + d) ^ 0xD1E_5EED);
                w = program_weights(&w, v, &mut gauss);
            }
            stage_defs.push(LayerStage {
                first_layer: r.start,
                weights: w,
                noise_skip: plan.noise_skip(d),
                engine_seed: opts.seed,
                is_output: d == dies - 1,
            });
        }

        // Wire die-to-die channels back-to-front so each thread owns the
        // sender to its successor; the last die reports winners to the
        // control thread.
        let (win_tx, win_rx) = mpsc::channel();
        let mut next_sink = StageSink::Collect(win_tx);
        let mut stages = Vec::with_capacity(dies);
        let mut stage_metrics = Vec::with_capacity(dies);
        for (d, stage) in stage_defs.into_iter().enumerate().rev() {
            let (tx, rx) = mpsc::channel::<StageMsg>();
            let sink = std::mem::replace(&mut next_sink, StageSink::Next(tx));
            let m = Metrics::new();
            stage_metrics.push(m.clone());
            let params = opts.params;
            let handle = std::thread::Builder::new()
                .name(format!("raca-die-{d}"))
                .spawn(move || stage_loop(stage, rx, sink, params, m))
                .expect("spawning pipeline die thread");
            stages.push(handle);
        }
        stages.reverse();
        stage_metrics.reverse();
        let StageSink::Next(stage0_tx) = next_sink else { unreachable!("dies >= 1") };

        let metrics = Metrics::new();
        let (sub_tx, sub_rx) = mpsc::channel();
        let classes = nominal.spec.output_dim();
        let ctrl_metrics = metrics.clone();
        let ctrl_opts = opts.clone();
        let control = std::thread::Builder::new()
            .name("raca-pipeline-ctrl".into())
            .spawn(move || control_loop(sub_rx, stage0_tx, win_rx, ctrl_metrics, ctrl_opts, classes))
            .expect("spawning pipeline control thread");

        let label =
            format!("pipeline:{dies} [chips {}..{}]", opts.chip_base, opts.chip_base + dies);
        Ok(Self {
            sub_tx,
            control: Some(control),
            stages,
            metrics,
            stage_metrics,
            plan,
            label,
            journal: opts.journal,
        })
    }

    /// The layer-to-die assignment this backend executes.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn num_dies(&self) -> usize {
        self.stage_metrics.len()
    }

    /// Per-die trial counts and stage latencies.
    pub fn per_die_metrics(&self) -> Vec<MetricsSnapshot> {
        self.stage_metrics.iter().map(|m| m.snapshot()).collect()
    }
}

impl Backend for PipelinedFleetBackend {
    fn submit_to(&self, req: InferRequest, reply: mpsc::Sender<InferResponse>) -> Result<()> {
        ensure!(
            req.image.len() == self.plan.spec.input_dim(),
            "request {} has {} features, the sharded model expects {}",
            req.id,
            req.image.len(),
            self.plan.spec.input_dim()
        );
        self.sub_tx
            .send(CtrlMsg::Submit(req, reply, Instant::now()))
            .map_err(|_| anyhow!("pipelined backend is shut down"))
    }

    fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    fn metrics_tree(&self) -> MetricsTree {
        // One child per stage: its counters are per-die (trials through
        // that die, per-message stage latency), so a slow shard stands
        // out against its siblings.
        let children = self
            .stage_metrics
            .iter()
            .enumerate()
            .map(|(d, m)| {
                let r = &self.plan.ranges[d];
                MetricsTree::leaf(
                    format!("stage{d} [layers {}..{}]", r.start, r.end),
                    m.snapshot(),
                )
            })
            .collect();
        MetricsTree::leaf(self.label.clone(), self.metrics()).with_children(children)
    }

    fn journal(&self) -> Option<Arc<Journal>> {
        self.journal.clone()
    }

    fn shutdown(self: Box<Self>) {
        // Drop signals the control thread, which drains in-flight work,
        // then the die threads cascade-exit as their inputs close.
        drop(self);
    }
}

impl Drop for PipelinedFleetBackend {
    fn drop(&mut self) {
        let _ = self.sub_tx.send(CtrlMsg::Shutdown);
        if let Some(c) = self.control.take() {
            let _ = c.join();
        }
        for s in self.stages.drain(..) {
            let _ = s.join();
        }
    }
}

fn stage_loop(
    stage: LayerStage,
    rx: mpsc::Receiver<StageMsg>,
    sink: StageSink,
    params: TrialParams,
    metrics: Arc<Metrics>,
) {
    // Input-die cache: request id → deterministic z1 pre-activation.
    let mut z1_cache: HashMap<RequestId, Vec<f32>> = HashMap::new();
    let mut scratch = forward::BlockScratch::default();
    while let Ok(msg) = rx.recv() {
        match msg {
            StageMsg::Open { req, image } => {
                z1_cache.insert(req, forward::layer0_preactivation(&stage.weights, &image));
            }
            StageMsg::Close { req } => {
                z1_cache.remove(&req);
            }
            StageMsg::Trials { req, gen, base_idx, count, h } => {
                // The control thread sends every Trials block before the
                // Close of the same request on this FIFO channel, so a
                // cache miss here is a protocol bug, not a race.
                let out_width = stage.weights.spec.output_dim();
                let t0 = Instant::now();
                let mut out_h: Vec<f32> = Vec::new();
                let mut winners: Vec<i32> = Vec::new();
                if stage.is_output {
                    winners.reserve(count as usize);
                } else {
                    out_h.reserve(count as usize * out_width);
                }
                // One blocked-kernel pass per message: the input die reads
                // its cached z1 (shared across the block — the trials all
                // belong to `req`), downstream dies read the slab.
                let input: &[f32] = if stage.first_layer == 0 {
                    z1_cache.get(&req).expect("trials for unopened request")
                } else {
                    &h
                };
                stage.run_block(
                    input,
                    params,
                    base_idx,
                    count as usize,
                    &mut scratch,
                    &mut out_h,
                    &mut winners,
                );
                metrics.trials_executed.fetch_add(count as u64, Relaxed);
                metrics.record_latency(t0.elapsed());
                let delivered = match &sink {
                    StageSink::Next(tx) => tx
                        .send(StageMsg::Trials { req, gen, base_idx, count, h: out_h })
                        .is_ok(),
                    StageSink::Collect(tx) => tx.send((req, gen, winners)).is_ok(),
                };
                if !delivered {
                    return; // downstream died — tear the pipeline down
                }
            }
        }
    }
}

/// Vote state of one in-flight request on the control thread.  An entry
/// is removed the moment its response is sent; speculative winners that
/// land later are discarded by the `gen` tag, so a caller may reuse the
/// id immediately after `wait` returns.
struct Active {
    req: InferRequest,
    reply: mpsc::Sender<InferResponse>,
    submitted: Instant,
    outcome: WtaOutcome,
    /// Admission generation (unique across the backend's lifetime).
    gen: u64,
    base: u64,
    issued: u32,
}

fn control_loop(
    sub_rx: mpsc::Receiver<CtrlMsg>,
    stage0: mpsc::Sender<StageMsg>,
    win_rx: mpsc::Receiver<(RequestId, u64, Vec<i32>)>,
    metrics: Arc<Metrics>,
    opts: PipelineOptions,
    classes: usize,
) {
    let depth = opts.depth.max(1);
    let batch = opts.batch.max(1) as u32;
    let max_in_flight = opts.max_in_flight.max(1);
    // (journal, node label) — resolved once so the hot loop formats the
    // label zero times when event logging is off.
    let jlabel: Option<(Arc<Journal>, String)> = opts.journal.clone().map(|j| {
        let label = format!(
            "pipeline:{} [chips {}..{}]",
            opts.dies,
            opts.chip_base,
            opts.chip_base + opts.dies
        );
        (j, label)
    });
    let mut active: HashMap<RequestId, Active> = HashMap::new();
    // Round-robin issue order over requests with budget left (may hold
    // stale ids of completed requests; skipped at issue time).
    let mut queue: VecDeque<RequestId> = VecDeque::new();
    let mut pending: VecDeque<(InferRequest, mpsc::Sender<InferResponse>, Instant)> =
        VecDeque::new();
    let mut outstanding: usize = 0;
    let mut next_gen: u64 = 0;
    let mut shutdown = false;

    loop {
        // Drain the submission inbox without blocking.
        loop {
            match sub_rx.try_recv() {
                Ok(CtrlMsg::Submit(req, reply, t0)) => pending.push_back((req, reply, t0)),
                Ok(CtrlMsg::Shutdown) => shutdown = true,
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }
        // Admit pending requests up to the in-flight cap.
        while active.len() < max_in_flight {
            let Some((req, reply, t0)) = pending.pop_front() else { break };
            let id = req.id;
            if active.contains_key(&id) {
                // Duplicate in-flight id: reject in-band rather than
                // corrupting the first request's vote state.
                if let Some((j, label)) = &jlabel {
                    j.record(
                        EventKind::RequestFailed,
                        label,
                        format!("id {id}: duplicate in-flight id"),
                    );
                }
                let _ = reply.send(InferResponse::failed(
                    id,
                    format!("request id {id} is already in flight on this pipeline"),
                ));
                continue;
            }
            if req.past_deadline(t0.elapsed()) {
                // The budget died in the admission queue: shed before the
                // pipeline spends a single stage slot on it.
                if let Some((j, label)) = &jlabel {
                    j.record(
                        EventKind::DeadlineExceeded,
                        label,
                        format!("id {id}: shed at admission"),
                    );
                }
                metrics.engine_errors.fetch_add(1, Relaxed);
                let _ = reply.send(InferResponse::failed(
                    id,
                    crate::serve::deadline_exceeded_msg(
                        "pipeline",
                        t0.elapsed(),
                        req.deadline_ms.unwrap_or(0),
                    ),
                ));
                continue;
            }
            metrics.requests_admitted.fetch_add(1, Relaxed);
            if let Some((j, label)) = &jlabel {
                j.record(EventKind::RequestAdmitted, label, format!("id {id}"));
            }
            if req.max_trials == 0 {
                let latency = t0.elapsed();
                metrics.requests_completed.fetch_add(1, Relaxed);
                metrics.record_latency(latency);
                let _ = reply.send(InferResponse {
                    id,
                    prediction: -1,
                    outcome: WtaOutcome::new(classes),
                    trials_used: 0,
                    latency,
                    error: None,
                });
                continue;
            }
            if stage0.send(StageMsg::Open { req: id, image: req.image.clone() }).is_err() {
                return;
            }
            let base = trial_stream_base(opts.seed, id);
            next_gen += 1;
            active.insert(
                id,
                Active {
                    req,
                    reply,
                    submitted: t0,
                    outcome: WtaOutcome::new(classes),
                    gen: next_gen,
                    base,
                    issued: 0,
                },
            );
            queue.push_back(id);
        }
        // Keep the pipeline full: one block of up to `batch` trials per
        // issuable request, round-robin, while the in-flight window has
        // room (`outstanding` counts trials, not messages).
        while outstanding < depth {
            let Some(id) = queue.pop_front() else { break };
            let Some(a) = active.get_mut(&id) else { continue };
            if a.issued >= a.req.max_trials {
                continue;
            }
            let room = (depth - outstanding) as u32;
            let take = batch.min(a.req.max_trials - a.issued).min(room);
            let base_idx = a.base.wrapping_add(a.issued as u64);
            let msg =
                StageMsg::Trials { req: id, gen: a.gen, base_idx, count: take, h: Vec::new() };
            if stage0.send(msg).is_err() {
                return;
            }
            a.issued += take;
            outstanding += take as usize;
            if a.issued < a.req.max_trials {
                queue.push_back(id);
            }
        }
        // Reap winner blocks: block only when trials are in flight (they
        // are guaranteed to come back — a dead die closes win_rx instead).
        if outstanding > 0 {
            match win_rx.recv() {
                Ok((id, gen, w)) => handle_winners(
                    id, gen, w, &mut active, &mut queue, &mut outstanding, &stage0, &metrics,
                    &opts, jlabel.as_ref(),
                ),
                Err(_) => return,
            }
            while let Ok((id, gen, w)) = win_rx.try_recv() {
                handle_winners(
                    id, gen, w, &mut active, &mut queue, &mut outstanding, &stage0, &metrics,
                    &opts, jlabel.as_ref(),
                );
            }
        } else if pending.is_empty() && active.is_empty() {
            if shutdown {
                return;
            }
            // Idle: block for the next submission.
            match sub_rx.recv() {
                Ok(CtrlMsg::Submit(req, reply, t0)) => pending.push_back((req, reply, t0)),
                Ok(CtrlMsg::Shutdown) => shutdown = true,
                Err(_) => return,
            }
        }
        if shutdown && pending.is_empty() && active.is_empty() && outstanding == 0 {
            return;
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_winners(
    id: RequestId,
    gen: u64,
    winners: Vec<i32>,
    active: &mut HashMap<RequestId, Active>,
    queue: &mut VecDeque<RequestId>,
    outstanding: &mut usize,
    stage0: &mpsc::Sender<StageMsg>,
    metrics: &Metrics,
    opts: &PipelineOptions,
    jlabel: Option<&(Arc<Journal>, String)>,
) {
    *outstanding -= winners.len();
    metrics.trials_executed.fetch_add(winners.len() as u64, Relaxed);
    // Stale speculation: the request completed (and its id may even have
    // been reused by a new request — the `gen` mismatch catches that)
    // while this block was in the pipe.  It is paid for, not counted.
    let Some(a) = active.get_mut(&id) else { return };
    if a.gen != gen {
        return;
    }
    let mut done = false;
    for winner in winners {
        a.outcome.record(winner);
        let recorded = a.outcome.trials as u32;
        let decided = a.req.confidence > 0.0 && recorded >= opts.min_trials && {
            let (lead, runner) = a.outcome.top_two();
            lead_is_decided(lead, runner, a.req.confidence)
        };
        if recorded >= a.req.max_trials || decided {
            // The tail of this block past the decision point is paid-for
            // speculation: counted as executed above, never recorded.
            done = true;
            break;
        }
    }
    if !done {
        return;
    }
    let a = active.remove(&id).expect("completed request still active");
    let recorded = a.outcome.trials as u32;
    // Budget never issued is saved; trials already in the pipe are
    // speculation and stay counted as executed when they land.
    metrics
        .trials_saved
        .fetch_add((a.req.max_trials - a.issued) as u64, Relaxed);
    let latency = a.submitted.elapsed();
    metrics.requests_completed.fetch_add(1, Relaxed);
    metrics.record_latency(latency);
    if let Some((j, label)) = jlabel {
        j.record(EventKind::RequestCompleted, label, format!("id {id} trials {recorded}"));
    }
    let _ = a.reply.send(InferResponse {
        id,
        prediction: a.outcome.prediction(),
        outcome: a.outcome,
        trials_used: recorded,
        latency,
        error: None,
    });
    // Purge any stale issue-queue entry (early stop can leave one), so a
    // later request reusing this id never gets two round-robin slots.
    queue.retain(|&q| q != id);
    // FIFO on the control→die-0 channel guarantees every Trials block of
    // this request is processed before this Close drops the z1 cache entry.
    let _ = stage0.send(StageMsg::Close { req: id });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;
    use crate::nn::ModelSpec;
    use std::sync::Arc as StdArc;

    fn model() -> Weights {
        Weights::random(ModelSpec::new(vec![784, 16, 12, 10]), 11)
    }

    #[test]
    fn rejects_more_dies_than_layers() {
        let w = model(); // 3 layers
        let opts = PipelineOptions { dies: 4, ..Default::default() };
        let err = PipelinedFleetBackend::start(&w, opts).unwrap_err();
        assert!(format!("{err:#}").contains("3-layer"), "unexpected error: {err:#}");
    }

    #[test]
    fn pipeline_votes_match_the_unsharded_engine() {
        let w = model();
        let seed = 0xAB5E;
        let p = TrialParams::default();
        let engine = NativeEngine::new(StdArc::new(w.clone()), seed);
        let opts =
            PipelineOptions { dies: 3, seed, params: p, ..Default::default() };
        let b = PipelinedFleetBackend::start(&w, opts).unwrap();
        for id in 0..3u64 {
            let x: Vec<f32> = (0..784).map(|j| ((j as u64 + id * 31) % 13) as f32 / 13.0).collect();
            let want = engine.infer(&x, p, 20, trial_stream_base(seed, id));
            let t = b.submit(InferRequest::new(id, x).with_budget(20, 0.0)).unwrap();
            let got = b.wait(t).unwrap();
            assert_eq!(got.outcome.counts, want.counts, "request {id} votes diverged");
            assert_eq!(got.outcome.abstentions, want.abstentions);
            assert_eq!(got.trials_used, 20);
        }
        // Every die saw every trial.
        for (d, m) in b.per_die_metrics().iter().enumerate() {
            assert_eq!(m.trials_executed, 60, "die {d} trial count");
        }
    }

    #[test]
    fn batching_is_invisible_to_votes() {
        // Trial indices inside a block stay `base + k`, so the die-to-die
        // message batch size must never change a single vote.
        let w = model();
        let votes = |batch: usize| -> Vec<Vec<u64>> {
            let opts = PipelineOptions { dies: 3, batch, ..Default::default() };
            let b = PipelinedFleetBackend::start(&w, opts).unwrap();
            let tickets: Vec<_> = (0..4u64)
                .map(|i| {
                    let x: Vec<f32> =
                        (0..784).map(|j| ((j + i as usize * 7) % 11) as f32 / 11.0).collect();
                    b.submit(InferRequest::new(i, x).with_budget(23, 0.0)).unwrap()
                })
                .collect();
            tickets.into_iter().map(|t| b.wait(t).unwrap().outcome.counts).collect()
        };
        assert_eq!(votes(1), votes(5));
        assert_eq!(votes(1), votes(64));
    }

    #[test]
    fn early_stop_responds_before_the_pipe_drains() {
        // Plant a dominant class so the Wilson stopper fires quickly.
        let mut w = model();
        let last = w.mats.len() - 1;
        let cols = 10;
        for row in 0..12 {
            w.mats[last][row * cols + 4] = 3.0;
        }
        let b = PipelinedFleetBackend::start(&w, PipelineOptions::default()).unwrap();
        let t = b
            .submit(InferRequest::new(1, vec![0.7; 784]).with_budget(400, 0.95))
            .unwrap();
        let r = b.wait(t).unwrap();
        assert_eq!(r.prediction, 4);
        assert!(r.trials_used < 400, "expected early stop, used {}", r.trials_used);
    }

    #[test]
    fn zero_budget_answers_immediately() {
        let w = model();
        let b = PipelinedFleetBackend::start(&w, PipelineOptions::default()).unwrap();
        let t = b.submit(InferRequest::new(9, vec![0.1; 784]).with_budget(0, 0.0)).unwrap();
        let r = b.wait(t).unwrap();
        assert_eq!(r.trials_used, 0);
        assert_eq!(r.prediction, -1);
    }

    #[test]
    fn wrong_feature_count_is_rejected_at_submit() {
        let w = model();
        let b = PipelinedFleetBackend::start(&w, PipelineOptions::default()).unwrap();
        assert!(b.submit(InferRequest::new(1, vec![0.1; 100])).is_err());
    }
}
