//! The wire layer: topology trees that span hosts.
//!
//! The RACA pitch is system-level — drop the DACs/ADCs and scale out as
//! cheap dies instead of fat chips — and at deployment scale the binding
//! constraint moves to inter-chip and inter-node communication (Marinella
//! et al.'s multiscale co-design analysis; the accelerator-network
//! organizations in Smagulova et al.'s survey).  This module makes the
//! process boundary an ordinary edge of the [`crate::serve::Topology`]
//! tree:
//!
//! ```text
//!   host A (raca serve --listen 0.0.0.0:7433 --topology "pipeline:3")
//!   host B (raca serve --listen 0.0.0.0:7433 --topology "pipeline:3")
//!   client: --topology "(remote:a:7433, remote:b:7433)"
//!            └ RouterBackend health-steers across machines,
//!              zero new routing code
//! ```
//!
//! Three pieces:
//! * [`wire`] — the codec: length-prefixed JSON frames (vendored
//!   [`crate::util::json`], no serde), protocol version handshake,
//!   request ids as strings so full-width u64 ids survive;
//! * [`server`] — the listener: an accept loop hosting *any*
//!   `Box<dyn Backend>`; each connection is a session multiplexing
//!   tickets over one completion channel;
//! * [`client`] — [`RemoteBackend`]: the same [`crate::serve::Backend`]
//!   trait over a TCP session, compiled from the `remote:<host:port>`
//!   topology leaf by [`crate::serve::plan`].
//!
//! The parity discipline survives the wire: ids and images cross
//! bit-exactly, the remote host derives trial streams from its own seed
//! and the unchanged id, so `remote:die` ≡ local `die` at equal seeds
//! with `variation: None`.

pub mod client;
pub mod server;
pub mod wire;

pub use client::RemoteBackend;
pub use server::{serve, serve_registry, NetServer, RegistryConfig};
pub use wire::{WireError, WireMsg, PROTOCOL_VERSION};
