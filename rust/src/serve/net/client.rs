//! Client side: a [`Backend`] whose dies live on another host.
//!
//! [`RemoteBackend`] dials a `raca serve --listen` peer and speaks the
//! [`super::wire`] protocol over one TCP connection.  It implements the
//! same [`Backend`] trait as every local deployment shape, so
//! `remote:<host:port>` is a first-class [`crate::serve::Topology`] leaf:
//! a `(remote:a, remote:b)` group routes across machines with the exact
//! router/health code that steers local replicas, and a tree can mix
//! local pipelines with remote peers freely.
//!
//! Multiplexing: `submit_to` registers the caller's reply channel in a
//! pending map keyed by request id and writes one `Submit` frame; a
//! single reader thread routes incoming `Response` frames (completion
//! order) back to their callers.  No per-request threads, no
//! head-of-line blocking.
//!
//! Parity: the remote host derives trial indices from its *own*
//! deployment seed and the request id, exactly as a local backend would —
//! so with both ends seeded alike and `variation: None`, `remote:die`
//! votes bit-identically to a local `die` (held to that in
//! `rust/tests/serve.rs`).  The client's `BuildOptions::seed` does not
//! reach the wire.

use std::collections::{HashMap, VecDeque};
use std::io::BufReader;
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::Ordering::Relaxed;
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::coordinator::{Metrics, MetricsSnapshot};
use crate::util::json;

use super::super::{Backend, InferRequest, InferResponse, RequestId};
use super::wire::{self, WireMsg, PROTOCOL_VERSION};

/// How long `metrics()` waits for the remote snapshot before falling
/// back to the locally tracked counters.
const METRICS_TIMEOUT: Duration = Duration::from_secs(10);

type Pending = Arc<Mutex<HashMap<RequestId, mpsc::Sender<InferResponse>>>>;
type MetricsWaiters = Arc<Mutex<VecDeque<mpsc::Sender<MetricsSnapshot>>>>;

/// A serving session against a remote listener (one TCP connection).
pub struct RemoteBackend {
    addr: String,
    write: Mutex<TcpStream>,
    pending: Pending,
    waiters: MetricsWaiters,
    /// Local admission counters — the fallback when the peer cannot
    /// answer a metrics request in time.
    local: Arc<Metrics>,
    reader: Option<JoinHandle<()>>,
}

impl RemoteBackend {
    /// Dial `addr` and complete the protocol handshake.
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to remote backend {addr}"))?;
        stream.set_nodelay(true).context("setting TCP_NODELAY")?;
        let mut read = BufReader::new(stream.try_clone().context("cloning stream")?);
        let mut wstream = stream;

        // The listener speaks first; refuse anything that is not a
        // version-compatible raca hello.
        let j = json::read_frame(&mut read)
            .with_context(|| format!("reading hello from {addr}"))?
            .ok_or_else(|| anyhow!("{addr} closed the connection during the handshake"))?;
        match wire::decode(&j).with_context(|| format!("bad hello from {addr}"))? {
            WireMsg::Hello { version } => {
                wire::check_version(version).with_context(|| format!("peer {addr}"))?
            }
            WireMsg::Error { msg, .. } => bail!("{addr} refused the session: {msg}"),
            other => bail!("{addr} opened with {other:?} instead of hello"),
        }
        json::write_frame(&mut wstream, &wire::encode(&WireMsg::Hello { version: PROTOCOL_VERSION }))
            .with_context(|| format!("answering hello to {addr}"))?;

        let pending: Pending = Arc::new(Mutex::new(HashMap::new()));
        let waiters: MetricsWaiters = Arc::new(Mutex::new(VecDeque::new()));
        let reader = {
            let pending = pending.clone();
            let waiters = waiters.clone();
            let addr = addr.to_string();
            std::thread::Builder::new()
                .name("raca-remote-read".into())
                .spawn(move || reader_loop(read, pending, waiters, addr))
                .context("spawning remote reader thread")?
        };
        Ok(Self {
            addr: addr.to_string(),
            write: Mutex::new(wstream),
            pending,
            waiters,
            local: Metrics::new(),
            reader: Some(reader),
        })
    }

    /// The peer this session is connected to.
    pub fn peer(&self) -> &str {
        &self.addr
    }

    /// Requests currently awaiting a remote response.
    pub fn in_flight(&self) -> usize {
        self.pending.lock().unwrap().len()
    }
}

impl Backend for RemoteBackend {
    fn submit_to(&self, req: InferRequest, reply: mpsc::Sender<InferResponse>) -> Result<()> {
        let id = req.id;
        {
            let mut p = self.pending.lock().unwrap();
            ensure!(
                !p.contains_key(&id),
                "request id {id} is already in flight on the session to {}",
                self.addr
            );
            p.insert(id, reply);
        }
        let frame = wire::encode(&WireMsg::Submit(req));
        let sent = {
            let mut w = self.write.lock().unwrap();
            json::write_frame(&mut *w, &frame)
        };
        if let Err(e) = sent {
            self.pending.lock().unwrap().remove(&id);
            bail!("sending request {id} to {}: {e}", self.addr);
        }
        self.local.requests_admitted.fetch_add(1, Relaxed);
        Ok(())
    }

    /// The *remote* backend's metrics (the listener answers for the whole
    /// hosted deployment — shared across every client connection).  Falls
    /// back to this session's local admission counters if the peer does
    /// not answer within [`METRICS_TIMEOUT`].
    fn metrics(&self) -> MetricsSnapshot {
        let (tx, rx) = mpsc::channel();
        let sent = {
            // Holding the waiter lock across the write keeps the waiter
            // queue aligned with the request order on the wire.
            let mut ws = self.waiters.lock().unwrap();
            let ok = {
                let mut w = self.write.lock().unwrap();
                json::write_frame(&mut *w, &wire::encode(&WireMsg::MetricsReq)).is_ok()
            };
            if ok {
                ws.push_back(tx);
            }
            ok
        };
        if sent {
            if let Ok(m) = rx.recv_timeout(METRICS_TIMEOUT) {
                return m;
            }
            log::warn!("{}: no metrics answer in {METRICS_TIMEOUT:?}; using local counters", self.addr);
        }
        self.local.snapshot()
    }

    fn shutdown(self: Box<Self>) {
        drop(self);
    }
}

impl Drop for RemoteBackend {
    fn drop(&mut self) {
        // Polite goodbye + half-close: the listener finishes in-flight
        // work and flushes every remaining Response before closing its
        // end, which is what unblocks (and ends) our reader thread.
        {
            let mut w = self.write.lock().unwrap();
            let _ = json::write_frame(&mut *w, &wire::encode(&WireMsg::Goodbye));
            let _ = w.shutdown(Shutdown::Write);
        }
        if let Some(r) = self.reader.take() {
            let _ = r.join();
        }
    }
}

fn reader_loop(mut read: BufReader<TcpStream>, pending: Pending, waiters: MetricsWaiters, addr: String) {
    loop {
        let j = match json::read_frame(&mut read) {
            Ok(Some(j)) => j,
            Ok(None) => break, // peer closed
            Err(e) => {
                log::warn!("{addr}: unreadable frame, dropping session: {e}");
                break;
            }
        };
        match wire::decode(&j) {
            Ok(WireMsg::Response(resp)) => {
                if let Some(tx) = pending.lock().unwrap().remove(&resp.id) {
                    let _ = tx.send(resp); // caller may have given up; fine
                } else {
                    log::warn!("{addr}: response for unknown request {}", resp.id);
                }
            }
            Ok(WireMsg::Metrics(m)) => {
                if let Some(tx) = waiters.lock().unwrap().pop_front() {
                    let _ = tx.send(m);
                }
            }
            Ok(WireMsg::Error { id: Some(id), msg }) => {
                log::warn!("{addr}: rejected request {id}: {msg}");
                // An in-band failure (not a dropped sender): shared
                // completion channels — a router relay, another session —
                // need the response to learn which request died.
                if let Some(tx) = pending.lock().unwrap().remove(&id) {
                    let _ = tx.send(InferResponse::failed(id, format!("{addr}: {msg}")));
                }
            }
            Ok(WireMsg::Error { id: None, msg }) => {
                log::warn!("{addr}: session error: {msg}");
            }
            Ok(other) => log::warn!("{addr}: unexpected {other:?}"),
            Err(e) => {
                log::warn!("{addr}: undecodable frame, dropping session: {e}");
                break;
            }
        }
    }
    // Anything still pending will never complete: answer every waiter
    // with an in-band failure (shared completion channels cannot observe
    // a dropped sender clone, so silence would hang a routing caller).
    for (id, tx) in pending.lock().unwrap().drain() {
        let _ = tx.send(InferResponse::failed(id, format!("session to {addr} closed")));
    }
    waiters.lock().unwrap().clear();
}
