//! Client side: a [`Backend`] whose dies live on another host.
//!
//! [`RemoteBackend`] dials a `raca serve --listen` peer and speaks the
//! [`super::wire`] protocol over one TCP connection.  It implements the
//! same [`Backend`] trait as every local deployment shape, so
//! `remote:<host:port>` is a first-class [`crate::serve::Topology`] leaf:
//! a `(remote:a, remote:b)` group routes across machines with the exact
//! router/health code that steers local replicas, and a tree can mix
//! local pipelines with remote peers freely.
//!
//! Multiplexing: `submit_to` registers the caller's reply channel in a
//! pending map keyed by request id and writes one `Submit` frame; a
//! single reader thread routes incoming `Response` frames (completion
//! order) back to their callers.  No per-request threads, no
//! head-of-line blocking.
//!
//! Death: the reader thread flips a `dead` flag when the session ends
//! (peer closed, unreadable frame).  From then on every `submit_to`
//! answers in-band with `InferResponse::failed` — so a routing parent
//! keeps observing the failures and evicts this leaf — and telemetry
//! calls return the **last cached** peer snapshot tagged `stale: true`
//! instead of stalling on a wire that will never answer.
//!
//! Parity: the remote host derives trial indices from its *own*
//! deployment seed and the request id, exactly as a local backend would —
//! so with both ends seeded alike and `variation: None`, `remote:die`
//! votes bit-identically to a local `die` (held to that in
//! `rust/tests/serve.rs`).  The client's `BuildOptions::seed` does not
//! reach the wire.

use std::collections::{HashMap, VecDeque};
use std::io::BufReader;
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::coordinator::{Metrics, MetricsSnapshot};
use crate::telemetry::{Event, EventKind, Journal, MetricsTree};
use crate::util::json;

use super::super::{Backend, InferRequest, InferResponse, RequestId};
use super::wire::{self, WireMsg, PROTOCOL_VERSION};

/// TCP connect budget for [`RemoteBackend::connect`].
const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// How long the dialer waits for the listener's hello.  A TCP endpoint
/// that accepts but never speaks the protocol (a web server, a silent
/// port) must fail the connect, not hang it.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(3);

/// Socket write budget (kept for the session's whole life): a wedged
/// peer with a full receive window cannot hang `submit`/telemetry
/// inside the write lock forever.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// How long telemetry calls wait for the remote answer before falling
/// back to cached / locally tracked numbers.  Only reached on a *live*
/// but slow session — a known-dead one fails fast — so it is short:
/// telemetry is advisory and `metrics()` is called from render loops.
const METRICS_TIMEOUT: Duration = Duration::from_secs(2);

/// What one metrics exchange yields: the peer's tree plus the tail of
/// its journal (empty when the peer is v1 and answered flat metrics).
type TreeReply = (MetricsTree, Vec<Event>);

type Pending = Arc<Mutex<HashMap<RequestId, mpsc::Sender<InferResponse>>>>;
/// FIFO of outstanding metrics requests.  Each waiter carries a unique
/// token so a caller that *times out* can remove its own entry — a
/// stale waiter left in the queue would consume the next answer and
/// misalign every exchange after it.
type MetricsWaiters = Arc<Mutex<VecDeque<(u64, mpsc::Sender<TreeReply>)>>>;
type TreeCache = Arc<Mutex<Option<TreeReply>>>;
type JournalSlot = Arc<Mutex<Option<Arc<Journal>>>>;

/// A serving session against a remote listener (one TCP connection).
pub struct RemoteBackend {
    addr: String,
    write: Mutex<TcpStream>,
    pending: Pending,
    waiters: MetricsWaiters,
    /// Waiter-token source (see [`MetricsWaiters`]).
    waiter_seq: AtomicU64,
    /// Local admission counters — the fallback when the peer has never
    /// answered a metrics request.
    local: Arc<Metrics>,
    /// Set by the reader thread when the session ends; checked by every
    /// path that would otherwise wait on the wire.
    dead: Arc<AtomicBool>,
    /// Last successfully fetched peer telemetry, served (tagged stale)
    /// once the session is dead.
    last_tree: TreeCache,
    /// Deployment journal, attached after connect by [`Self::with_journal`]
    /// (shared with the reader thread so session drop is recorded).
    journal: JournalSlot,
    /// Registry bundle id this leaf was resolved from (`remote:@` leaves
    /// only); surfaces in [`Backend::metrics_tree`] node notes.
    bundle: Option<String>,
    reader: Option<JoinHandle<()>>,
}

impl RemoteBackend {
    /// Dial `addr` and complete the protocol handshake.  Bounded end to
    /// end: [`CONNECT_TIMEOUT`] for TCP establishment and
    /// [`HANDSHAKE_TIMEOUT`] for the hello, so dialing a non-raca
    /// endpoint (or a black-holed route) errors instead of blocking the
    /// deployment build indefinitely.
    pub fn connect(addr: &str) -> Result<Self> {
        let resolved: Vec<_> = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving remote backend address {addr}"))?
            .collect();
        ensure!(!resolved.is_empty(), "remote backend address {addr} resolved to nothing");
        let mut stream = None;
        let mut last_err = None;
        for sa in &resolved {
            match TcpStream::connect_timeout(sa, CONNECT_TIMEOUT) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) => last_err = Some(e),
            }
        }
        let stream = match stream {
            Some(s) => s,
            None => {
                return Err(last_err.expect("resolved is non-empty"))
                    .with_context(|| format!("connecting to remote backend {addr}"))
            }
        };
        stream.set_nodelay(true).context("setting TCP_NODELAY")?;
        // Deadline for the hello; lifted once the session is up (the
        // timeout is a property of the socket, shared with the clone).
        stream
            .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
            .context("setting handshake read timeout")?;
        stream.set_write_timeout(Some(WRITE_TIMEOUT)).context("setting write timeout")?;
        let mut read = BufReader::new(stream.try_clone().context("cloning stream")?);
        let mut wstream = stream;

        // The listener speaks first; refuse anything that is not a
        // version-compatible raca hello.
        let j = json::read_frame(&mut read)
            .with_context(|| {
                format!("reading hello from {addr} (is it a raca listener? gave it {HANDSHAKE_TIMEOUT:?})")
            })?
            .ok_or_else(|| anyhow!("{addr} closed the connection during the handshake"))?;
        match wire::decode(&j).with_context(|| format!("bad hello from {addr}"))? {
            WireMsg::Hello { version, .. } => {
                wire::check_version(version).with_context(|| format!("peer {addr}"))?
            }
            WireMsg::Error { msg, .. } => bail!("{addr} refused the session: {msg}"),
            other => bail!("{addr} opened with {other:?} instead of hello"),
        }
        json::write_frame(
            &mut wstream,
            &wire::encode(&WireMsg::Hello { version: PROTOCOL_VERSION, bundles: Vec::new() }),
        )
        .with_context(|| format!("answering hello to {addr}"))?;
        // Sessions are long-lived and idle reads are normal: clear the
        // handshake deadline so the reader thread never sees a spurious
        // timeout and drops a healthy session.
        wstream.set_read_timeout(None).context("clearing handshake read timeout")?;

        let pending: Pending = Arc::new(Mutex::new(HashMap::new()));
        let waiters: MetricsWaiters = Arc::new(Mutex::new(VecDeque::new()));
        let dead = Arc::new(AtomicBool::new(false));
        let last_tree: TreeCache = Arc::new(Mutex::new(None));
        let journal: JournalSlot = Arc::new(Mutex::new(None));
        let reader = {
            let ctx = ReaderCtx {
                pending: pending.clone(),
                waiters: waiters.clone(),
                dead: dead.clone(),
                last_tree: last_tree.clone(),
                journal: journal.clone(),
                addr: addr.to_string(),
            };
            std::thread::Builder::new()
                .name("raca-remote-read".into())
                .spawn(move || reader_loop(read, ctx))
                .context("spawning remote reader thread")?
        };
        Ok(Self {
            addr: addr.to_string(),
            write: Mutex::new(wstream),
            pending,
            waiters,
            waiter_seq: AtomicU64::new(0),
            local: Metrics::new(),
            dead,
            last_tree,
            journal,
            bundle: None,
            reader: Some(reader),
        })
    }

    /// Route this session's connect/drop events into the deployment's
    /// shared journal (records the connect immediately).
    pub(crate) fn with_journal(self, journal: Arc<Journal>) -> Self {
        journal.record(
            EventKind::SessionConnect,
            &format!("remote:{}", self.addr),
            format!("proto v{PROTOCOL_VERSION}"),
        );
        *self.journal.lock().unwrap() = Some(journal);
        self
    }

    /// Tag this session with the registry bundle id it was resolved from
    /// (set by `serve::plan` for `remote:@<registry>/<bundle>` leaves).
    pub(crate) fn with_bundle(mut self, bundle: String) -> Self {
        self.bundle = Some(bundle);
        self
    }

    /// The peer this session is connected to.
    pub fn peer(&self) -> &str {
        &self.addr
    }

    /// Requests currently awaiting a remote response.
    pub fn in_flight(&self) -> usize {
        self.pending.lock().unwrap().len()
    }

    /// The session ended (peer closed or protocol error); all calls now
    /// answer from local/cached state.
    pub fn is_dead(&self) -> bool {
        self.dead.load(Relaxed)
    }

    /// One metrics exchange with the peer: its [`MetricsTree`] plus
    /// recent journal [`Event`]s (empty from a v1 peer, which answers
    /// flat metrics — wrapped here into a single-node tree).
    ///
    /// `None` when the session is dead or the peer did not answer within
    /// [`METRICS_TIMEOUT`]; callers then fall back to [`Self::cached`].
    pub fn remote_telemetry(&self) -> Option<TreeReply> {
        if self.is_dead() {
            return None;
        }
        let token = self.waiter_seq.fetch_add(1, Relaxed);
        let (tx, rx) = mpsc::channel();
        let sent = {
            // Holding the waiter lock across the write keeps the waiter
            // queue aligned with the request order on the wire.
            let mut ws = self.waiters.lock().unwrap();
            let ok = {
                let mut w = self.write.lock().unwrap();
                json::write_frame(&mut *w, &wire::encode(&WireMsg::MetricsReq { tree: true }))
                    .is_ok()
            };
            if ok {
                ws.push_back((token, tx));
                // Reader may have died (and cleared the queue) before the
                // push — reclaim the waiter ourselves in that case.
                if self.is_dead() {
                    ws.pop_back();
                    return None;
                }
            }
            ok
        };
        // The reader clears the waiter queue when it exits, so a session
        // dying mid-wait drops our sender and recv fails immediately —
        // no timeout-long stall, no leaked waiter.
        if !sent {
            return None;
        }
        match rx.recv_timeout(METRICS_TIMEOUT) {
            Ok(reply) => Some(reply),
            Err(_) => {
                // Withdraw from the queue: leaving the stale waiter
                // behind would let it swallow the *next* answer and feed
                // every later caller an off-by-one reply.
                self.waiters.lock().unwrap().retain(|(t, _)| *t != token);
                if self.is_dead() {
                    return None;
                }
                // The answer may have raced the retain; use it if so.
                match rx.try_recv() {
                    Ok(reply) => Some(reply),
                    Err(_) => {
                        log::warn!(
                            "{}: no metrics answer in {METRICS_TIMEOUT:?}; using cached/local",
                            self.addr
                        );
                        None
                    }
                }
            }
        }
    }

    /// Last successfully fetched peer telemetry, tree tagged `stale`.
    pub fn cached(&self) -> Option<TreeReply> {
        self.last_tree
            .lock()
            .unwrap()
            .clone()
            .map(|(tree, events)| (tree.tagged_stale(), events))
    }
}

impl Backend for RemoteBackend {
    fn submit_to(&self, req: InferRequest, reply: mpsc::Sender<InferResponse>) -> Result<()> {
        let id = req.id;
        if self.is_dead() {
            // In-band failure, not Err: a routing parent sees the failed
            // response through its relay, records it against this child's
            // health, and evicts the leaf — an Err from submit would
            // bypass that accounting.
            self.local.engine_errors.fetch_add(1, Relaxed);
            let _ = reply.send(InferResponse::failed(
                id,
                format!("session to {} is closed", self.addr),
            ));
            return Ok(());
        }
        {
            let mut p = self.pending.lock().unwrap();
            ensure!(
                !p.contains_key(&id),
                "request id {id} is already in flight on the session to {}",
                self.addr
            );
            p.insert(id, reply);
        }
        let frame = wire::encode(&WireMsg::Submit(req));
        let sent = {
            let mut w = self.write.lock().unwrap();
            json::write_frame(&mut *w, &frame)
        };
        if let Err(e) = sent {
            self.pending.lock().unwrap().remove(&id);
            bail!("sending request {id} to {}: {e}", self.addr);
        }
        // The reader may have died (and drained pending) between the
        // liveness check and our insert; reclaim the entry ourselves so
        // the caller is not left waiting on a response that never comes.
        if self.is_dead() {
            if let Some(tx) = self.pending.lock().unwrap().remove(&id) {
                let _ = tx.send(InferResponse::failed(
                    id,
                    format!("session to {} is closed", self.addr),
                ));
            }
            return Ok(());
        }
        self.local.requests_admitted.fetch_add(1, Relaxed);
        Ok(())
    }

    /// The *remote* backend's metrics (the listener answers for the whole
    /// hosted deployment — shared across every client connection).  Falls
    /// back to the cached peer snapshot, then to this session's local
    /// admission counters.
    fn metrics(&self) -> MetricsSnapshot {
        self.remote_telemetry()
            .or_else(|| self.cached())
            .map(|(tree, _)| tree.snapshot)
            .unwrap_or_else(|| self.local.snapshot())
    }

    /// `remote:<addr>` node carrying this session's local counters, with
    /// the peer's whole subtree as its one child (tagged stale if it is
    /// a cached copy of a dead session).
    fn metrics_tree(&self) -> MetricsTree {
        let mut root = MetricsTree::leaf(format!("remote:{}", self.addr), self.local.snapshot());
        root.notes.bundle = self.bundle.clone();
        match self.remote_telemetry().or_else(|| self.cached()) {
            Some((tree, _)) => root.with_children(vec![tree]),
            None if self.is_dead() => root.tagged_stale(),
            None => root,
        }
    }

    fn journal(&self) -> Option<Arc<Journal>> {
        self.journal.lock().unwrap().clone()
    }

    fn shutdown(self: Box<Self>) {
        drop(self);
    }
}

impl Drop for RemoteBackend {
    fn drop(&mut self) {
        // Polite goodbye + half-close: the listener finishes in-flight
        // work and flushes every remaining Response before closing its
        // end, which is what unblocks (and ends) our reader thread.
        {
            let mut w = self.write.lock().unwrap();
            let _ = json::write_frame(&mut *w, &wire::encode(&WireMsg::Goodbye));
            let _ = w.shutdown(Shutdown::Write);
        }
        if let Some(r) = self.reader.take() {
            let _ = r.join();
        }
    }
}

/// Everything the reader thread shares with the session object.
struct ReaderCtx {
    pending: Pending,
    waiters: MetricsWaiters,
    dead: Arc<AtomicBool>,
    last_tree: TreeCache,
    journal: JournalSlot,
    addr: String,
}

fn reader_loop(mut read: BufReader<TcpStream>, ctx: ReaderCtx) {
    let ReaderCtx { pending, waiters, dead, last_tree, journal, addr } = ctx;
    let mut why = "peer closed";
    loop {
        let j = match json::read_frame(&mut read) {
            Ok(Some(j)) => j,
            Ok(None) => break, // peer closed
            Err(e) => {
                log::warn!("{addr}: unreadable frame, dropping session: {e}");
                why = "unreadable frame";
                break;
            }
        };
        match wire::decode(&j) {
            Ok(WireMsg::Response(resp)) => {
                if let Some(tx) = pending.lock().unwrap().remove(&resp.id) {
                    let _ = tx.send(resp); // caller may have given up; fine
                } else {
                    log::warn!("{addr}: response for unknown request {}", resp.id);
                }
            }
            // v1 peers answer flat metrics even when we asked for the
            // tree — wrap into a single-node tree so every waiter sees
            // one shape.
            Ok(WireMsg::Metrics(m)) => {
                let reply = (MetricsTree::leaf("peer", m), Vec::new());
                *last_tree.lock().unwrap() = Some(reply.clone());
                if let Some((_, tx)) = waiters.lock().unwrap().pop_front() {
                    let _ = tx.send(reply);
                }
            }
            Ok(WireMsg::MetricsTree { tree, events }) => {
                let reply = (tree, events);
                *last_tree.lock().unwrap() = Some(reply.clone());
                if let Some((_, tx)) = waiters.lock().unwrap().pop_front() {
                    let _ = tx.send(reply);
                }
            }
            Ok(WireMsg::Error { id: Some(id), msg }) => {
                log::warn!("{addr}: rejected request {id}: {msg}");
                // An in-band failure (not a dropped sender): shared
                // completion channels — a router relay, another session —
                // need the response to learn which request died.
                if let Some(tx) = pending.lock().unwrap().remove(&id) {
                    let _ = tx.send(InferResponse::failed(id, format!("{addr}: {msg}")));
                }
            }
            Ok(WireMsg::Error { id: None, msg }) => {
                log::warn!("{addr}: session error: {msg}");
            }
            Ok(other) => log::warn!("{addr}: unexpected {other:?}"),
            Err(e) => {
                log::warn!("{addr}: undecodable frame, dropping session: {e}");
                why = "undecodable frame";
                break;
            }
        }
    }
    // Known dead from here on: submit/metrics on this session fail fast.
    dead.store(true, Relaxed);
    if let Some(j) = &*journal.lock().unwrap() {
        j.record(EventKind::SessionDrop, &format!("remote:{addr}"), why);
    }
    // Anything still pending will never complete: answer every waiter
    // with an in-band failure (shared completion channels cannot observe
    // a dropped sender clone, so silence would hang a routing caller).
    for (id, tx) in pending.lock().unwrap().drain() {
        let _ = tx.send(InferResponse::failed(id, format!("session to {addr} closed")));
    }
    waiters.lock().unwrap().clear();
}
