//! Client side: a [`Backend`] whose dies live on another host.
//!
//! [`RemoteBackend`] dials a `raca serve --listen` peer and speaks the
//! [`super::wire`] protocol over one TCP connection.  It implements the
//! same [`Backend`] trait as every local deployment shape, so
//! `remote:<host:port>` is a first-class [`crate::serve::Topology`] leaf:
//! a `(remote:a, remote:b)` group routes across machines with the exact
//! router/health code that steers local replicas, and a tree can mix
//! local pipelines with remote peers freely.
//!
//! Multiplexing: `submit_to` registers the caller's reply channel in a
//! pending map keyed by request id and writes one `Submit` frame; a
//! single reader thread routes incoming `Response` frames (completion
//! order) back to their callers.  No per-request threads, no
//! head-of-line blocking.
//!
//! Death and reconnect: the reader thread flips `dead` when the session
//! ends (peer closed, unreadable frame) and wakes a supervisor thread,
//! which redials with capped exponential backoff plus jitter.  While the
//! session is down, every *new* `submit_to` answers in-band with
//! `InferResponse::failed` — so a routing parent keeps observing the
//! failures and can evict this leaf — and telemetry calls return the
//! **last cached** peer snapshot tagged `stale: true` instead of
//! stalling on a wire that will never answer.  Requests that were
//! *already in flight* at the drop are retained and **resubmitted** once
//! the session is restored: votes are pure functions of
//! `(seed, trial_idx)`, so a resubmitted request is bit-identical to the
//! original, and a duplicate completion from a half-dead session is
//! deduped by request id (the second `Response` finds no pending entry).
//! A retained request is failed in-band the moment its deadline budget
//! expires, or after [`RESUBMIT_WINDOW`] if it carries no deadline — a
//! caller never hangs on a peer that stays gone.  For `remote:@` leaves
//! the supervisor re-verifies the bundle advertisement and manifest
//! signature under the local deployment key *before* adopting the new
//! session, so a peer that restarted with different weights is rejected
//! (`manifest_rejected`), not silently served.
//!
//! Parity: the remote host derives trial indices from its *own*
//! deployment seed and the request id, exactly as a local backend would —
//! so with both ends seeded alike and `variation: None`, `remote:die`
//! votes bit-identically to a local `die` (held to that in
//! `rust/tests/serve.rs`).  The client's `BuildOptions::seed` does not
//! reach the wire.

use std::collections::{HashMap, VecDeque};
use std::io::BufReader;
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::coordinator::{Metrics, MetricsSnapshot};
use crate::registry::SigningKey;
use crate::telemetry::{Event, EventKind, Journal, MetricsTree};
use crate::util::json::{self, Json};

use super::super::{
    deadline_exceeded_msg, Backend, InferRequest, InferResponse, RequestId,
};
use super::wire::{self, WireMsg, PROTOCOL_VERSION};

/// TCP connect budget for [`RemoteBackend::connect`] and each redial.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// How long the dialer waits for the listener's hello.  A TCP endpoint
/// that accepts but never speaks the protocol (a web server, a silent
/// port) must fail the connect, not hang it.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(3);

/// Socket write budget (kept for the session's whole life): a wedged
/// peer with a full receive window cannot hang `submit`/telemetry
/// inside the write lock forever.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// How long telemetry calls wait for the remote answer before falling
/// back to cached / locally tracked numbers.  Only reached on a *live*
/// but slow session — a known-dead one fails fast — so it is short:
/// telemetry is advisory and `metrics()` is called from render loops.
const METRICS_TIMEOUT: Duration = Duration::from_secs(2);

/// First redial delay; doubles per failed attempt up to
/// [`RECONNECT_BACKOFF_CAP`], each with up to 25% added jitter so a
/// fleet of clients does not stampede a listener the moment it returns.
const RECONNECT_BACKOFF_BASE: Duration = Duration::from_millis(50);

/// Ceiling on the redial delay.  The supervisor never gives up on the
/// *leaf* (a peer may come back hours later); only retained requests
/// are bounded.
const RECONNECT_BACKOFF_CAP: Duration = Duration::from_secs(1);

/// How long an in-flight request without a deadline survives a dead
/// session awaiting resubmission before it is failed in-band.  Requests
/// *with* deadlines are failed the moment their own budget expires.
pub const RESUBMIT_WINDOW: Duration = Duration::from_secs(5);

/// What one metrics exchange yields: the peer's tree plus the tail of
/// its journal (empty when the peer is v1 and answered flat metrics).
type TreeReply = (MetricsTree, Vec<Event>);

/// A request awaiting its remote response: everything needed to answer
/// the caller *or* to resubmit the request verbatim after a reconnect.
struct PendingEntry {
    req: InferRequest,
    reply: mpsc::Sender<InferResponse>,
    /// When the request was accepted on this session — deadlines and the
    /// resubmission budget are measured from here.
    since: Instant,
}

/// FIFO of outstanding metrics requests.  Each waiter carries a unique
/// token so a caller that *times out* can remove its own entry — a
/// stale waiter left in the queue would consume the next answer and
/// misalign every exchange after it.
type MetricsWaiters = Mutex<VecDeque<(u64, mpsc::Sender<TreeReply>)>>;

/// Supervisor wake-ups.
enum SupMsg {
    /// The reader thread exited: redial unless the backend is dropping.
    Died,
    /// The backend is dropping: join the reader and drain.
    Shutdown,
}

/// Session state shared by the backend object, the reader thread, and
/// the reconnect supervisor.
struct Shared {
    addr: String,
    /// Current session socket; the supervisor swaps in a fresh stream at
    /// reconnect (every writer re-locks per frame, so the swap is safe).
    write: Mutex<TcpStream>,
    pending: Mutex<HashMap<RequestId, PendingEntry>>,
    waiters: MetricsWaiters,
    /// Waiter-token source (see [`MetricsWaiters`]).
    waiter_seq: AtomicU64,
    /// Local admission counters — the fallback when the peer has never
    /// answered a metrics request.
    local: Arc<Metrics>,
    /// Set by the reader thread when the session ends; cleared by the
    /// supervisor when a redial is adopted.  Checked by every path that
    /// would otherwise wait on the wire.
    dead: AtomicBool,
    /// The backend is dropping: the supervisor must stop redialing.
    stop: AtomicBool,
    /// The supervisor is mid-redial (rendered as `RECONNECTING`).
    reconnecting: AtomicBool,
    /// Last successfully fetched peer telemetry, served (tagged stale)
    /// while the session is down.
    last_tree: Mutex<Option<TreeReply>>,
    /// Deployment journal, attached after connect by
    /// [`RemoteBackend::with_journal`].
    journal: Mutex<Option<Arc<Journal>>>,
    /// `remote:@` leaves: the bundle id this session must keep serving
    /// and the local key to re-verify it under at every reconnect.
    verify: Mutex<Option<(String, SigningKey)>>,
}

impl Shared {
    fn node(&self) -> String {
        format!("remote:{}", self.addr)
    }

    fn record(&self, kind: EventKind, detail: impl Into<String>) {
        if let Some(j) = &*self.journal.lock().unwrap() {
            j.record(kind, &self.node(), detail);
        }
    }
}

/// A serving session against a remote listener (one TCP connection at a
/// time; the supervisor may replace the connection, never the session).
pub struct RemoteBackend {
    shared: Arc<Shared>,
    sup_tx: mpsc::Sender<SupMsg>,
    /// Registry bundle id this leaf was resolved from (`remote:@` leaves
    /// only); surfaces in [`Backend::metrics_tree`] node notes.
    bundle: Option<String>,
    supervisor: Option<JoinHandle<()>>,
}

/// Dial `addr`, complete the protocol handshake, and return the session
/// halves plus the listener's advertised bundle ids.  Bounded end to
/// end: [`CONNECT_TIMEOUT`] for TCP establishment and
/// [`HANDSHAKE_TIMEOUT`] for the hello.
fn dial(addr: &str) -> Result<(BufReader<TcpStream>, TcpStream, Vec<String>)> {
    let resolved: Vec<_> = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving remote backend address {addr}"))?
        .collect();
    ensure!(!resolved.is_empty(), "remote backend address {addr} resolved to nothing");
    let mut stream = None;
    let mut last_err = None;
    for sa in &resolved {
        match TcpStream::connect_timeout(sa, CONNECT_TIMEOUT) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(e) => last_err = Some(e),
        }
    }
    let stream = match stream {
        Some(s) => s,
        None => {
            return Err(last_err.expect("resolved is non-empty"))
                .with_context(|| format!("connecting to remote backend {addr}"))
        }
    };
    stream.set_nodelay(true).context("setting TCP_NODELAY")?;
    // Deadline for the hello; lifted once the session is up (the
    // timeout is a property of the socket, shared with the clone).
    stream
        .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
        .context("setting handshake read timeout")?;
    stream.set_write_timeout(Some(WRITE_TIMEOUT)).context("setting write timeout")?;
    let mut read = BufReader::new(stream.try_clone().context("cloning stream")?);
    let mut wstream = stream;

    // The listener speaks first; refuse anything that is not a
    // version-compatible raca hello.
    let j = json::read_frame(&mut read)
        .with_context(|| {
            format!("reading hello from {addr} (is it a raca listener? gave it {HANDSHAKE_TIMEOUT:?})")
        })?
        .ok_or_else(|| anyhow!("{addr} closed the connection during the handshake"))?;
    let advertised = match wire::decode(&j).with_context(|| format!("bad hello from {addr}"))? {
        WireMsg::Hello { version, bundles } => {
            wire::check_version(version).with_context(|| format!("peer {addr}"))?;
            bundles
        }
        WireMsg::Error { msg, .. } => bail!("{addr} refused the session: {msg}"),
        other => bail!("{addr} opened with {other:?} instead of hello"),
    };
    json::write_frame(
        &mut wstream,
        &wire::encode(&WireMsg::Hello { version: PROTOCOL_VERSION, bundles: Vec::new() }),
    )
    .with_context(|| format!("answering hello to {addr}"))?;
    // Sessions are long-lived and idle reads are normal: clear the
    // handshake deadline so the reader thread never sees a spurious
    // timeout and drops a healthy session.
    wstream.set_read_timeout(None).context("clearing handshake read timeout")?;
    Ok((read, wstream, advertised))
}

impl RemoteBackend {
    /// Dial `addr`, complete the protocol handshake, and start the
    /// session: one reader thread routing completions, one supervisor
    /// thread that redials on drop.  The *initial* connect still fails
    /// hard — a deployment build should not come up pointing at nothing.
    pub fn connect(addr: &str) -> Result<Self> {
        let (read, wstream, _advertised) = dial(addr)?;
        let shared = Arc::new(Shared {
            addr: addr.to_string(),
            write: Mutex::new(wstream),
            pending: Mutex::new(HashMap::new()),
            waiters: Mutex::new(VecDeque::new()),
            waiter_seq: AtomicU64::new(0),
            local: Metrics::new(),
            dead: AtomicBool::new(false),
            stop: AtomicBool::new(false),
            reconnecting: AtomicBool::new(false),
            last_tree: Mutex::new(None),
            journal: Mutex::new(None),
            verify: Mutex::new(None),
        });
        let (sup_tx, sup_rx) = mpsc::channel();
        let reader = spawn_reader(read, shared.clone(), sup_tx.clone())?;
        let supervisor = {
            let sh = shared.clone();
            let tx = sup_tx.clone();
            std::thread::Builder::new()
                .name("raca-remote-sup".into())
                .spawn(move || supervisor_loop(sh, sup_rx, tx, reader))
                .context("spawning remote supervisor thread")?
        };
        Ok(Self { shared, sup_tx, bundle: None, supervisor: Some(supervisor) })
    }

    /// Route this session's connect/drop events into the deployment's
    /// shared journal (records the connect immediately).
    pub(crate) fn with_journal(self, journal: Arc<Journal>) -> Self {
        journal.record(
            EventKind::SessionConnect,
            &self.shared.node(),
            format!("proto v{PROTOCOL_VERSION}"),
        );
        *self.shared.journal.lock().unwrap() = Some(journal);
        self
    }

    /// Tag this session with the registry bundle id it was resolved from
    /// and the deployment key it verified under (set by `serve::plan`
    /// for `remote:@<registry>/<bundle>` leaves).  The supervisor
    /// re-runs the full resolve under this key at every reconnect.
    pub(crate) fn with_bundle(mut self, bundle: String, key: SigningKey) -> Self {
        self.bundle = Some(bundle.clone());
        *self.shared.verify.lock().unwrap() = Some((bundle, key));
        self
    }

    /// The peer this session is connected to.
    pub fn peer(&self) -> &str {
        &self.shared.addr
    }

    /// Requests currently awaiting a remote response (or resubmission).
    pub fn in_flight(&self) -> usize {
        self.shared.pending.lock().unwrap().len()
    }

    /// The session is down (peer closed or protocol error); submits
    /// answer in-band failures and telemetry serves cached state.  Flips
    /// back to `false` if the supervisor restores the session.
    pub fn is_dead(&self) -> bool {
        self.shared.dead.load(Relaxed)
    }

    /// The supervisor is currently redialing the peer.
    pub fn is_reconnecting(&self) -> bool {
        self.shared.reconnecting.load(Relaxed)
    }

    /// One metrics exchange with the peer: its [`MetricsTree`] plus
    /// recent journal [`Event`]s (empty from a v1 peer, which answers
    /// flat metrics — wrapped here into a single-node tree).
    ///
    /// `None` when the session is dead or the peer did not answer within
    /// [`METRICS_TIMEOUT`]; callers then fall back to [`Self::cached`].
    pub fn remote_telemetry(&self) -> Option<TreeReply> {
        let sh = &self.shared;
        if self.is_dead() {
            return None;
        }
        let token = sh.waiter_seq.fetch_add(1, Relaxed);
        let (tx, rx) = mpsc::channel();
        let sent = {
            // Holding the waiter lock across the write keeps the waiter
            // queue aligned with the request order on the wire.
            let mut ws = sh.waiters.lock().unwrap();
            let ok = {
                let mut w = sh.write.lock().unwrap();
                json::write_frame(&mut *w, &wire::encode(&WireMsg::MetricsReq { tree: true }))
                    .is_ok()
            };
            if ok {
                ws.push_back((token, tx));
                // Reader may have died (and cleared the queue) before the
                // push — reclaim the waiter ourselves in that case.
                if self.is_dead() {
                    ws.pop_back();
                    return None;
                }
            }
            ok
        };
        // The reader clears the waiter queue when it exits, so a session
        // dying mid-wait drops our sender and recv fails immediately —
        // no timeout-long stall, no leaked waiter.
        if !sent {
            return None;
        }
        match rx.recv_timeout(METRICS_TIMEOUT) {
            Ok(reply) => Some(reply),
            Err(_) => {
                // Withdraw from the queue: leaving the stale waiter
                // behind would let it swallow the *next* answer and feed
                // every later caller an off-by-one reply.
                sh.waiters.lock().unwrap().retain(|(t, _)| *t != token);
                if self.is_dead() {
                    return None;
                }
                // The answer may have raced the retain; use it if so.
                match rx.try_recv() {
                    Ok(reply) => Some(reply),
                    Err(_) => {
                        log::warn!(
                            "{}: no metrics answer in {METRICS_TIMEOUT:?}; using cached/local",
                            sh.addr
                        );
                        None
                    }
                }
            }
        }
    }

    /// Last successfully fetched peer telemetry, tree tagged `stale`.
    pub fn cached(&self) -> Option<TreeReply> {
        self.shared
            .last_tree
            .lock()
            .unwrap()
            .clone()
            .map(|(tree, events)| (tree.tagged_stale(), events))
    }
}

impl Backend for RemoteBackend {
    fn submit_to(&self, req: InferRequest, reply: mpsc::Sender<InferResponse>) -> Result<()> {
        let sh = &self.shared;
        let id = req.id;
        if self.is_dead() {
            // In-band failure, not Err: a routing parent sees the failed
            // response through its relay, records it against this child's
            // health, and evicts the leaf — an Err from submit would
            // bypass that accounting.  Only requests in flight *at the
            // drop* ride the resubmission path; work arriving while the
            // session is down fails fast so callers can route around.
            sh.local.engine_errors.fetch_add(1, Relaxed);
            let _ = reply.send(InferResponse::failed(
                id,
                format!("session to {} is closed", sh.addr),
            ));
            return Ok(());
        }
        let frame = wire::encode(&WireMsg::Submit(req.clone()));
        {
            let mut p = sh.pending.lock().unwrap();
            ensure!(
                !p.contains_key(&id),
                "request id {id} is already in flight on the session to {}",
                sh.addr
            );
            p.insert(id, PendingEntry { req, reply, since: Instant::now() });
        }
        let sent = {
            let mut w = sh.write.lock().unwrap();
            json::write_frame(&mut *w, &frame)
        };
        if let Err(e) = sent {
            sh.pending.lock().unwrap().remove(&id);
            bail!("sending request {id} to {}: {e}", sh.addr);
        }
        // The reader may have died between the liveness check and our
        // insert.  If the supervisor restored the session already, our
        // frame went to the *new* stream (the write lock serializes
        // against the swap) or our entry made the resubmission snapshot —
        // either way exactly one live submission exists.  If the session
        // is still down, reclaim the entry ourselves: the supervisor may
        // be deep in backoff and this call promised fail-fast.
        if self.is_dead() {
            if let Some(e) = sh.pending.lock().unwrap().remove(&id) {
                let _ = e.reply.send(InferResponse::failed(
                    id,
                    format!("session to {} is closed", sh.addr),
                ));
            }
            return Ok(());
        }
        sh.local.requests_admitted.fetch_add(1, Relaxed);
        Ok(())
    }

    /// The *remote* backend's metrics (the listener answers for the whole
    /// hosted deployment — shared across every client connection).  Falls
    /// back to the cached peer snapshot, then to this session's local
    /// admission counters.
    fn metrics(&self) -> MetricsSnapshot {
        self.remote_telemetry()
            .or_else(|| self.cached())
            .map(|(tree, _)| tree.snapshot)
            .unwrap_or_else(|| self.shared.local.snapshot())
    }

    /// `remote:<addr>` node carrying this session's local counters, with
    /// the peer's whole subtree as its one child (tagged stale if it is
    /// a cached copy of a dead session).
    fn metrics_tree(&self) -> MetricsTree {
        let mut root =
            MetricsTree::leaf(self.shared.node(), self.shared.local.snapshot());
        root.notes.bundle = self.bundle.clone();
        root.notes.reconnecting = self.is_reconnecting();
        match self.remote_telemetry().or_else(|| self.cached()) {
            Some((tree, _)) => root.with_children(vec![tree]),
            None if self.is_dead() => root.tagged_stale(),
            None => root,
        }
    }

    fn journal(&self) -> Option<Arc<Journal>> {
        self.shared.journal.lock().unwrap().clone()
    }

    fn shutdown(self: Box<Self>) {
        drop(self);
    }
}

impl Drop for RemoteBackend {
    fn drop(&mut self) {
        // Stop first so a reader death racing the drop cannot trigger a
        // redial, then polite goodbye + half-close: the listener finishes
        // in-flight work and flushes every remaining Response before
        // closing its end, which is what unblocks the reader thread.
        self.shared.stop.store(true, Relaxed);
        {
            let mut w = self.shared.write.lock().unwrap();
            let _ = json::write_frame(&mut *w, &wire::encode(&WireMsg::Goodbye));
            let _ = w.shutdown(Shutdown::Write);
        }
        let _ = self.sup_tx.send(SupMsg::Shutdown);
        if let Some(s) = self.supervisor.take() {
            let _ = s.join(); // joins the reader too
        }
    }
}

fn spawn_reader(
    read: BufReader<TcpStream>,
    shared: Arc<Shared>,
    sup_tx: mpsc::Sender<SupMsg>,
) -> Result<JoinHandle<()>> {
    std::thread::Builder::new()
        .name("raca-remote-read".into())
        .spawn(move || reader_loop(read, shared, sup_tx))
        .context("spawning remote reader thread")
}

fn reader_loop(mut read: BufReader<TcpStream>, sh: Arc<Shared>, sup_tx: mpsc::Sender<SupMsg>) {
    let addr = sh.addr.clone();
    let mut why = "peer closed";
    loop {
        let j = match json::read_frame(&mut read) {
            Ok(Some(j)) => j,
            Ok(None) => break, // peer closed
            Err(e) => {
                log::warn!("{addr}: unreadable frame, dropping session: {e}");
                why = "unreadable frame";
                break;
            }
        };
        match wire::decode(&j) {
            Ok(WireMsg::Response(resp)) => {
                // `remove` is also the duplicate-completion dedup: a
                // response already answered (e.g. delivered by a
                // half-dead session just before a resubmission raced it)
                // finds no entry and is dropped here.
                if let Some(e) = sh.pending.lock().unwrap().remove(&resp.id) {
                    let _ = e.reply.send(resp); // caller may have given up; fine
                } else {
                    log::warn!("{addr}: response for unknown request {}", resp.id);
                }
            }
            // v1 peers answer flat metrics even when we asked for the
            // tree — wrap into a single-node tree so every waiter sees
            // one shape.
            Ok(WireMsg::Metrics(m)) => {
                let reply = (MetricsTree::leaf("peer", m), Vec::new());
                *sh.last_tree.lock().unwrap() = Some(reply.clone());
                if let Some((_, tx)) = sh.waiters.lock().unwrap().pop_front() {
                    let _ = tx.send(reply);
                }
            }
            Ok(WireMsg::MetricsTree { tree, events }) => {
                let reply = (tree, events);
                *sh.last_tree.lock().unwrap() = Some(reply.clone());
                if let Some((_, tx)) = sh.waiters.lock().unwrap().pop_front() {
                    let _ = tx.send(reply);
                }
            }
            Ok(WireMsg::Error { id: Some(id), msg }) => {
                log::warn!("{addr}: rejected request {id}: {msg}");
                // An in-band failure (not a dropped sender): shared
                // completion channels — a router relay, another session —
                // need the response to learn which request died.
                if let Some(e) = sh.pending.lock().unwrap().remove(&id) {
                    let _ = e.reply.send(InferResponse::failed(id, format!("{addr}: {msg}")));
                }
            }
            Ok(WireMsg::Error { id: None, msg }) => {
                log::warn!("{addr}: session error: {msg}");
            }
            Ok(other) => log::warn!("{addr}: unexpected {other:?}"),
            Err(e) => {
                log::warn!("{addr}: undecodable frame, dropping session: {e}");
                why = "undecodable frame";
                break;
            }
        }
    }
    // Known dead from here on: submit/metrics on this session fail fast.
    sh.dead.store(true, Relaxed);
    sh.record(EventKind::SessionDrop, why);
    // Metrics waiters cannot survive a reconnect (their asks died with
    // the old socket): dropping the senders fails each `recv` fast.
    // Pending *requests* are deliberately NOT drained — the supervisor
    // owns them now, for resubmission or bounded in-band expiry.
    sh.waiters.lock().unwrap().clear();
    let _ = sup_tx.send(SupMsg::Died);
}

fn supervisor_loop(
    sh: Arc<Shared>,
    rx: mpsc::Receiver<SupMsg>,
    sup_tx: mpsc::Sender<SupMsg>,
    mut reader: JoinHandle<()>,
) {
    loop {
        match rx.recv() {
            Ok(SupMsg::Died) => {
                let _ = reader.join();
                if sh.stop.load(Relaxed) {
                    break;
                }
                match reconnect(&sh, &sup_tx) {
                    Some(r) => reader = r,
                    None => break, // stop raised mid-redial
                }
            }
            Ok(SupMsg::Shutdown) | Err(_) => {
                // The half-closed socket EOFs the reader promptly; join
                // so no thread outlives the backend.
                let _ = reader.join();
                break;
            }
        }
    }
    fail_pending(&sh, |_| true, |_| format!("session to {} closed", sh.addr));
}

/// Redial until the session is restored or the backend drops.  Returns
/// the new reader thread on success.
fn reconnect(sh: &Arc<Shared>, sup_tx: &mpsc::Sender<SupMsg>) -> Option<JoinHandle<()>> {
    sh.reconnecting.store(true, Relaxed);
    let dropped_at = Instant::now();
    let mut attempt = 0u32;
    let restored = loop {
        if sh.stop.load(Relaxed) {
            break None;
        }
        expire_retained(sh, dropped_at);
        match try_restore(sh, sup_tx, attempt, dropped_at) {
            Ok(reader) => break Some(reader),
            Err(e) => {
                attempt += 1;
                if attempt <= 3 || attempt % 16 == 0 {
                    log::warn!("{}: redial attempt {attempt} failed: {e:#}", sh.addr);
                }
                sleep_unless_stopped(sh, backoff(attempt));
            }
        }
    };
    sh.reconnecting.store(false, Relaxed);
    restored
}

/// One redial: dial, re-verify the bundle for `remote:@` leaves, swap
/// the session socket, restart the reader, and resubmit what is still
/// worth resubmitting.
fn try_restore(
    sh: &Arc<Shared>,
    sup_tx: &mpsc::Sender<SupMsg>,
    attempts_before: u32,
    dropped_at: Instant,
) -> Result<JoinHandle<()>> {
    let (read, wstream, advertised) = dial(&sh.addr)?;
    let verify = sh.verify.lock().unwrap().clone();
    if let Some((bundle, key)) = verify {
        // The restarted peer must still serve the exact bundle this leaf
        // was built against — advertisement, signature under the *local*
        // key, and re-derived id, the full build-time discipline.
        let checked = (|| -> Result<()> {
            ensure!(
                advertised.iter().any(|b| b == &bundle),
                "peer came back without bundle {bundle} (advertises {})",
                advertised.len()
            );
            crate::registry::resolve(&sh.addr, &bundle, &key)?;
            Ok(())
        })();
        if let Err(e) = checked {
            sh.record(EventKind::ManifestRejected, format!("at reconnect: {e:#}"));
            bail!("reconnect rejected: {e:#}");
        }
    }
    *sh.write.lock().unwrap() = wstream;
    let reader = spawn_reader(read, sh.clone(), sup_tx.clone())?;

    // Snapshot and revive *under the pending lock*: a new `submit_to`
    // needs this lock to insert its entry, so everything it submits on
    // the fresh session is provably absent from the snapshot — no
    // request ever has two live submissions.  The write happens after
    // release (the reader needs the lock to route completions).  Entries
    // keep their original reply sender, so each request completes
    // exactly once no matter how many sessions its frames crossed.
    let resubmit: Vec<(RequestId, Json)> = {
        let p = sh.pending.lock().unwrap();
        let snap = p
            .values()
            .map(|e| {
                let mut r = e.req.clone();
                if let Some(d) = r.deadline_ms {
                    // The budget kept draining while the session was
                    // down; forward only what is left.
                    r.deadline_ms = Some(d.saturating_sub(e.since.elapsed().as_millis() as u64));
                }
                (r.id, wire::encode(&WireMsg::Submit(r)))
            })
            .collect();
        sh.dead.store(false, Relaxed);
        snap
    };
    sh.record(
        EventKind::SessionReconnect,
        format!(
            "restored after {} attempt(s), {}ms down; resubmitting {} in-flight",
            attempts_before + 1,
            dropped_at.elapsed().as_millis(),
            resubmit.len()
        ),
    );
    for (id, frame) in resubmit {
        let sent = {
            let mut w = sh.write.lock().unwrap();
            json::write_frame(&mut *w, &frame)
        };
        match sent {
            Ok(()) => sh.record(EventKind::Resubmit, format!("request {id}")),
            Err(e) => {
                // The fresh session is already broken; its reader will
                // notice and wake us again with the entries still
                // pending.
                log::warn!("{}: resubmitting request {id} failed: {e}", sh.addr);
                break;
            }
        }
    }
    Ok(reader)
}

/// Fail (in-band) every retained request whose own deadline expired, and
/// — once the session has been down longer than [`RESUBMIT_WINDOW`] —
/// every deadline-less request too.  Bounded wait, never a hang.
fn expire_retained(sh: &Shared, dropped_at: Instant) {
    let window_over = dropped_at.elapsed() >= RESUBMIT_WINDOW;
    fail_pending(
        sh,
        |e| {
            e.req.past_deadline(e.since.elapsed())
                || (window_over && e.req.deadline_ms.is_none())
        },
        |e| {
            if let Some(d) = e.req.deadline_ms {
                let waited = e.since.elapsed();
                if waited.as_millis() as u64 >= d {
                    return deadline_exceeded_msg(&format!("remote:{}", sh.addr), waited, d);
                }
            }
            format!(
                "session to {} closed (no reconnect within {RESUBMIT_WINDOW:?})",
                sh.addr
            )
        },
    );
}

/// Remove every pending entry matching `cond` and answer it in-band.
fn fail_pending(
    sh: &Shared,
    cond: impl Fn(&PendingEntry) -> bool,
    msg: impl Fn(&PendingEntry) -> String,
) {
    let expired: Vec<(RequestId, PendingEntry)> = {
        let mut p = sh.pending.lock().unwrap();
        let ids: Vec<RequestId> =
            p.iter().filter(|(_, e)| cond(e)).map(|(id, _)| *id).collect();
        ids.into_iter().filter_map(|id| p.remove(&id).map(|e| (id, e))).collect()
    };
    for (id, e) in expired {
        sh.local.engine_errors.fetch_add(1, Relaxed);
        let m = msg(&e);
        if m.starts_with(super::super::DEADLINE_EXCEEDED) {
            sh.record(EventKind::DeadlineExceeded, format!("request {id} while disconnected"));
        }
        let _ = e.reply.send(InferResponse::failed(id, m));
    }
}

/// Exponential backoff with jitter: `base * 2^(attempt-1)` capped at
/// [`RECONNECT_BACKOFF_CAP`], plus up to 25% random extra.
fn backoff(attempt: u32) -> Duration {
    let base = RECONNECT_BACKOFF_BASE
        .saturating_mul(1u32 << attempt.saturating_sub(1).min(16))
        .min(RECONNECT_BACKOFF_CAP);
    base + jitter(base / 4, attempt)
}

/// Cheap per-process random jitter in `[0, cap)` (no RNG dependency:
/// `RandomState` is seeded randomly per process).
fn jitter(cap: Duration, salt: u32) -> Duration {
    use std::collections::hash_map::RandomState;
    use std::hash::{BuildHasher, Hasher};
    let cap_us = cap.as_micros() as u64;
    if cap_us == 0 {
        return Duration::ZERO;
    }
    let mut h = RandomState::new().build_hasher();
    h.write_u32(salt);
    Duration::from_micros(h.finish() % cap_us)
}

/// Sleep `d` in small slices, returning early if the backend drops.
fn sleep_unless_stopped(sh: &Shared, d: Duration) {
    let until = Instant::now() + d;
    loop {
        if sh.stop.load(Relaxed) {
            return;
        }
        let left = until.saturating_duration_since(Instant::now());
        if left.is_zero() {
            return;
        }
        std::thread::sleep(Duration::from_millis(10).min(left));
    }
}
