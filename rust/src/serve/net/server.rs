//! Listener side: host any compiled topology behind a TCP accept loop.
//!
//! [`serve`] takes the `Box<dyn Backend>` that [`crate::serve::plan`]
//! built — a die, a pipeline, a whole replicated tree — and exposes it on
//! a socket.  Each accepted connection becomes a *session*: the session's
//! read loop admits `Submit` frames straight into the shared backend via
//! [`Backend::submit_to`], handing every request the session's one
//! completion channel; a pump thread drains that channel and writes
//! `Response` frames back in **completion order**.  A remote host is
//! therefore just another backend — same trait, same ticket semantics —
//! and one listener serves any number of client connections
//! concurrently.
//!
//! Request ids pass through the wire *verbatim* (they key the remote
//! host's trial streams — the bit-parity discipline), so id uniqueness is
//! the clients' contract: clients of a shared listener must carve up the
//! id space (the natural fleet idiom: client `k` of `n` uses ids
//! `k + i*n`).  A colliding id is rejected per-request with an `Error`
//! frame, never by dropping the session.
//!
//! Teardown: client EOF/`Goodbye` ends the read loop; the pump still
//! flushes every in-flight response before the session closes (the
//! backend finishes admitted work by contract).  Dropping the
//! [`NetServer`] stops the accept loop; live sessions keep the backend
//! alive through their `Arc` until they drain.
//!
//! Registry (v4): [`serve_registry`] additionally attaches a
//! [`RegistryConfig`] — a content-addressed [`Store`] plus the
//! deployment [`SigningKey`].  The hello then advertises the served
//! bundle ids, and sessions answer the registry vocabulary
//! (`bundles_req`, `manifest_fetch`, `blob_fetch`, `publish`).  The
//! listener *re-verifies* before vouching: a fetched manifest's blobs
//! are re-hashed and a published envelope's signature and blob digests
//! are checked, so a tampered store or a forged publish is refused with
//! an `Error` frame and a `manifest_rejected` journal event rather than
//! propagated.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::registry::{sign, SignedManifest, SigningKey, Store};
use crate::telemetry::EventKind;
use crate::util::json;

use super::super::{Backend, InferResponse};
use super::wire::{self, WireMsg, PROTOCOL_VERSION};

/// How many recent journal events ride along with a metrics-tree answer.
const JOURNAL_TAIL: usize = 32;

/// A topology hosted behind a socket.  Dropping it stops the accept
/// loop; [`NetServer::join`] instead blocks forever (the `raca serve
/// --listen` foreground mode).
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    sessions_started: Arc<AtomicU64>,
    /// One clone of every accepted session socket, so [`NetServer::kill`]
    /// can sever live sessions abruptly (chaos testing).
    sessions: Arc<Mutex<Vec<TcpStream>>>,
    /// Keeps the hosted backend alive at least as long as the listener.
    _backend: Arc<dyn Backend>,
}

/// What a registry-serving listener holds: the artifact store it
/// advertises and publishes into, and the deployment key it verifies
/// manifests against.
pub struct RegistryConfig {
    pub store: Store,
    pub key: SigningKey,
}

/// Bind `addr` (e.g. `"0.0.0.0:7433"`; port 0 picks a free port — see
/// [`NetServer::addr`]) and serve `backend` to every connection.
pub fn serve(backend: Box<dyn Backend>, addr: &str) -> Result<NetServer> {
    serve_inner(backend, addr, None)
}

/// [`serve`] plus a registry: the hello advertises the store's bundle
/// ids and sessions answer the v4 registry vocabulary.
pub fn serve_registry(
    backend: Box<dyn Backend>,
    addr: &str,
    registry: RegistryConfig,
) -> Result<NetServer> {
    serve_inner(backend, addr, Some(Arc::new(registry)))
}

fn serve_inner(
    backend: Box<dyn Backend>,
    addr: &str,
    registry: Option<Arc<RegistryConfig>>,
) -> Result<NetServer> {
    let backend: Arc<dyn Backend> = Arc::from(backend);
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("binding serve listener on {addr}"))?;
    let local = listener.local_addr().context("reading listener address")?;
    // Non-blocking accept + poll, so the accept thread can notice `stop`
    // without a connection arriving to wake it.
    listener
        .set_nonblocking(true)
        .context("setting listener non-blocking")?;
    let stop = Arc::new(AtomicBool::new(false));
    let sessions_started = Arc::new(AtomicU64::new(0));
    let sessions = Arc::new(Mutex::new(Vec::new()));
    let accept = {
        let stop = stop.clone();
        let backend = backend.clone();
        let sessions_started = sessions_started.clone();
        let sessions = sessions.clone();
        let registry = registry.clone();
        std::thread::Builder::new()
            .name("raca-net-accept".into())
            .spawn(move || {
                accept_loop(listener, backend, registry, stop, sessions_started, sessions)
            })
            .context("spawning accept thread")?
    };
    log::info!("serve listener on {local} (protocol v{PROTOCOL_VERSION})");
    Ok(NetServer {
        addr: local,
        stop,
        accept: Some(accept),
        sessions_started,
        sessions,
        _backend: backend,
    })
}

impl NetServer {
    /// The actually-bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Sessions accepted since start.
    pub fn sessions_started(&self) -> u64 {
        self.sessions_started.load(Relaxed)
    }

    /// Block on the accept loop — the foreground `--listen` mode.  Only
    /// ends if the listener socket breaks; kill the process to stop.
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Tear the listener down *abruptly*: stop accepting, then sever
    /// every live session socket mid-frame (`shutdown(Both)`) — the
    /// process-local equivalent of `kill -9` on the listener, for chaos
    /// testing reconnect/resubmission paths.  Clients observe an
    /// immediate EOF/reset with requests still in flight; no goodbye, no
    /// response flush.  The port is released, so a fresh listener can
    /// rebind the same address (std sets `SO_REUSEADDR` on bind).
    pub fn kill(mut self) {
        self.stop.store(true, Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for s in self.sessions.lock().unwrap().drain(..) {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        // Drop runs next; accept is already joined, so it is a no-op.
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop.store(true, Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // Session threads are deliberately not joined: they hold their own
        // Arc<dyn Backend> and exit when their client hangs up.
    }
}

fn accept_loop(
    listener: TcpListener,
    backend: Arc<dyn Backend>,
    registry: Option<Arc<RegistryConfig>>,
    stop: Arc<AtomicBool>,
    sessions_started: Arc<AtomicU64>,
    sessions: Arc<Mutex<Vec<TcpStream>>>,
) {
    while !stop.load(Relaxed) {
        match listener.accept() {
            Ok((stream, peer)) => {
                // Frames are small request/response messages: Nagle would
                // add artificial latency to every round trip.
                let _ = stream.set_nodelay(true);
                let _ = stream.set_nonblocking(false);
                sessions_started.fetch_add(1, Relaxed);
                if let Ok(clone) = stream.try_clone() {
                    sessions.lock().unwrap().push(clone);
                }
                let backend = backend.clone();
                let registry = registry.clone();
                let spawned = std::thread::Builder::new()
                    .name("raca-net-session".into())
                    .spawn(move || {
                        if let Err(e) = session(stream, backend, registry) {
                            log::warn!("session with {peer} ended with error: {e:#}");
                        }
                    });
                if spawned.is_err() {
                    log::warn!("could not spawn session thread for {peer}");
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => {
                log::warn!("accept failed: {e}; listener exiting");
                return;
            }
        }
    }
}

/// Write one frame under the session's write lock (frames from the pump
/// and the read loop interleave whole, never byte-wise).
fn send(w: &Mutex<TcpStream>, msg: &WireMsg) -> std::io::Result<()> {
    let mut guard = w.lock().unwrap();
    json::write_frame(&mut *guard, &wire::encode(msg))
}

fn session(
    stream: TcpStream,
    backend: Arc<dyn Backend>,
    registry: Option<Arc<RegistryConfig>>,
) -> Result<()> {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "?".to_string());
    let write = Arc::new(Mutex::new(stream.try_clone().context("cloning session stream")?));
    let mut read = BufReader::new(stream);

    // Handshake: the listener speaks first, the client must answer with a
    // matching hello before anything else.  With a registry attached, the
    // hello advertises the served bundle ids (a failed listing is logged
    // and advertised as nothing — advertisement is advisory, resolution
    // re-verifies everything anyway).
    let bundles = match &registry {
        Some(r) => r.store.list().unwrap_or_else(|e| {
            log::warn!("listing registry bundles for hello: {e:#}");
            Vec::new()
        }),
        None => Vec::new(),
    };
    send(&write, &WireMsg::Hello { version: PROTOCOL_VERSION, bundles })
        .context("sending hello")?;
    let Some(j) = json::read_frame(&mut read).context("reading client hello")? else {
        return Ok(()); // probed-and-closed (port scan, health check)
    };
    match wire::decode(&j) {
        Ok(WireMsg::Hello { version, .. }) => {
            if let Err(e) = wire::check_version(version) {
                let _ = send(&write, &WireMsg::Error { id: None, msg: e.to_string() });
                bail!("{e}");
            }
        }
        Ok(other) => {
            let _ = send(
                &write,
                &WireMsg::Error { id: None, msg: format!("expected hello, got {other:?}") },
            );
            bail!("client opened with {other:?} instead of hello");
        }
        Err(e) => {
            let _ = send(&write, &WireMsg::Error { id: None, msg: e.to_string() });
            bail!("bad client hello: {e}");
        }
    }

    if let Some(j) = backend.journal() {
        j.record(EventKind::SessionConnect, "listener", format!("client {peer}"));
    }

    // One completion channel per session: every submitted request replies
    // here, and the pump writes Response frames in completion order.
    let (done_tx, done_rx) = mpsc::channel::<InferResponse>();
    let pump = {
        let write = write.clone();
        std::thread::Builder::new()
            .name("raca-net-pump".into())
            .spawn(move || {
                while let Ok(resp) = done_rx.recv() {
                    if send(&write, &WireMsg::Response(resp)).is_err() {
                        return; // client is gone; stop writing
                    }
                }
            })
            .context("spawning session pump")?
    };

    let result = session_read_loop(&mut read, &write, &backend, registry.as_deref(), &done_tx);

    // Close our half of the completion channel; the pump drains whatever
    // in-flight requests still hold clones, then exits.
    drop(done_tx);
    let _ = pump.join();
    if let Some(j) = backend.journal() {
        let how = if result.is_ok() { "clean" } else { "error" };
        j.record(EventKind::SessionDrop, "listener", format!("client {peer} ({how})"));
    }
    result
}

fn session_read_loop(
    read: &mut BufReader<TcpStream>,
    write: &Mutex<TcpStream>,
    backend: &Arc<dyn Backend>,
    registry: Option<&RegistryConfig>,
    done_tx: &mpsc::Sender<InferResponse>,
) -> Result<()> {
    loop {
        let j = match json::read_frame(read) {
            Ok(Some(j)) => j,
            Ok(None) => return Ok(()), // clean client EOF
            Err(e) => {
                let _ = send(
                    write,
                    &WireMsg::Error { id: None, msg: format!("unreadable frame: {e}") },
                );
                bail!("unreadable frame from client: {e}");
            }
        };
        match wire::decode(&j) {
            Ok(WireMsg::Submit(req)) => {
                let id = req.id;
                if let Err(e) = backend.submit_to(req, done_tx.clone()) {
                    // Per-request failure (id collision, unhealthy tree):
                    // report it, keep the session alive.
                    let _ =
                        send(write, &WireMsg::Error { id: Some(id), msg: format!("{e:#}") });
                }
            }
            Ok(WireMsg::MetricsReq { tree: false }) => {
                // v1 clients (and v2 clients asking flat): old answer shape.
                let m = backend.metrics();
                send(write, &WireMsg::Metrics(m)).context("sending metrics")?;
            }
            Ok(WireMsg::MetricsReq { tree: true }) => {
                let tree = backend.metrics_tree();
                let events =
                    backend.journal().map(|j| j.tail(JOURNAL_TAIL)).unwrap_or_default();
                send(write, &WireMsg::MetricsTree { tree, events })
                    .context("sending metrics tree")?;
            }
            Ok(WireMsg::Goodbye) => return Ok(()),
            Ok(
                msg @ (WireMsg::BundlesReq
                | WireMsg::ManifestFetch { .. }
                | WireMsg::BlobFetch { .. }
                | WireMsg::Publish { .. }),
            ) => {
                // Registry requests answer in-line (they are rare control
                // traffic, not the serving path) and never end the
                // session: a refused manifest is an Error frame, exactly
                // what a pre-v4 listener would have answered.
                let reply = match registry {
                    Some(r) => registry_answer(r, backend, msg),
                    None => Err(anyhow::anyhow!("this listener serves no registry")),
                };
                match reply {
                    Ok(m) => send(write, &m).context("sending registry answer")?,
                    Err(e) => {
                        let _ = send(write, &WireMsg::Error { id: None, msg: format!("{e:#}") });
                    }
                }
            }
            Ok(other) => {
                let _ = send(
                    write,
                    &WireMsg::Error { id: None, msg: format!("unexpected {other:?}") },
                );
            }
            Err(e) => {
                let _ = send(write, &WireMsg::Error { id: None, msg: e.to_string() });
                bail!("undecodable frame from client: {e}");
            }
        }
    }
}

/// Answer one registry frame against the listener's store.  Everything
/// handed out is re-verified first — the listener vouches for what it
/// serves — and every refusal lands in the journal as
/// `manifest_rejected` on node `listener`.
fn registry_answer(
    reg: &RegistryConfig,
    backend: &Arc<dyn Backend>,
    msg: WireMsg,
) -> Result<WireMsg> {
    let reject = |what: &str, e: &anyhow::Error| {
        if let Some(j) = backend.journal() {
            j.record(EventKind::ManifestRejected, "listener", format!("{what}: {e:#}"));
        }
    };
    match msg {
        WireMsg::BundlesReq => Ok(WireMsg::Bundles { ids: reg.store.list()? }),
        WireMsg::ManifestFetch { bundle } => {
            let vouch = || -> Result<SignedManifest> {
                let env = reg.store.get_manifest(&bundle)?;
                env.verify(&reg.key)?;
                // Re-hash every referenced blob before vouching: a
                // tampered artifact is refused here, not discovered by
                // the peer after it built a deployment on it.
                for h in env.manifest.blob_hashes() {
                    reg.store.get_blob(h)?;
                }
                Ok(env)
            };
            match vouch() {
                Ok(env) => Ok(WireMsg::Manifest { envelope: env.to_json() }),
                Err(e) => {
                    reject(&format!("fetch {bundle}"), &e);
                    Err(e.context(format!("bundle {bundle} refused")))
                }
            }
        }
        WireMsg::BlobFetch { hash } => {
            // get_blob re-hashes; corrupt bytes never reach the wire.
            let bytes = reg.store.get_blob(&hash)?;
            Ok(WireMsg::Blob { hash, data: sign::hex(&bytes) })
        }
        WireMsg::Publish { envelope, blobs } => {
            let admit = || -> Result<String> {
                let env = SignedManifest::from_json(&envelope)?;
                let id = env.verify(&reg.key)?;
                // Every hash the manifest references must arrive in this
                // frame (or already sit in the store), and every payload
                // must hash to its claimed name.
                for (hash, data) in &blobs {
                    let bytes = sign::unhex(data)?;
                    anyhow::ensure!(
                        sign::sha256_hex(&bytes) == *hash,
                        "published blob does not hash to its claimed id {hash}"
                    );
                    reg.store.put_blob(&bytes)?;
                }
                for h in env.manifest.blob_hashes() {
                    anyhow::ensure!(reg.store.has_blob(h), "published manifest references missing blob {h}");
                }
                reg.store.put_manifest(&env)?;
                Ok(id)
            };
            match admit() {
                Ok(bundle) => {
                    if let Some(j) = backend.journal() {
                        j.record(
                            EventKind::BundlePublished,
                            "listener",
                            format!("bundle {bundle} ({} blobs)", blobs.len()),
                        );
                    }
                    Ok(WireMsg::PublishOk { bundle })
                }
                Err(e) => {
                    reject("publish", &e);
                    Err(e.context("publish refused"))
                }
            }
        }
        other => anyhow::bail!("not a registry frame: {other:?}"),
    }
}
