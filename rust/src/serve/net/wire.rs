//! Wire codec: the serving vocabulary as length-prefixed JSON frames.
//!
//! Every message is one [`crate::util::json`] frame (4-byte big-endian
//! length + compact JSON) whose object carries a `"t"` tag.  The codec is
//! deliberately boring — the vendored JSON layer, no serde — because the
//! interesting contract is semantic, not syntactic: a request's `id` and
//! `image` must survive the wire **bit-identically** so that
//! `trial_stream_base(seed, id)` derives the same trial indices on the
//! remote host as it would locally.  Pixels are f32; f32 → f64 → shortest
//! round-trip decimal → f64 → f32 is exact, so JSON numbers are safe for
//! them.  Request ids are full-width u64 (probe ids live at `1 << 63`),
//! which JSON's f64 numbers would silently round — ids therefore travel
//! as decimal *strings* (the decoder also accepts small integers for
//! hand-written frames).
//!
//! Handshake: the listener speaks first with [`WireMsg::Hello`]; the
//! client checks `magic`/`proto` ([`check_version`]) and answers with its
//! own hello.  Either side closes on a mismatch.
//!
//! # Protocol bump rules
//!
//! [`PROTOCOL_VERSION`] is this build's revision; [`check_version`]
//! accepts any peer in `1..=PROTOCOL_VERSION` and refuses *newer* peers
//! (they know about frames we can't parse; an older peer is safe because
//! every revision so far is additive).  The rules when changing frames:
//!
//! * **Additive change** (new message type, new optional field): bump
//!   [`PROTOCOL_VERSION`], keep decoding the old shapes, and degrade
//!   gracefully when the peer is older — e.g. a v1 peer ignores the
//!   `tree` flag in [`WireMsg::MetricsReq`] and answers with a flat
//!   [`WireMsg::Metrics`]; the v2 client wraps that into a single-node
//!   tree instead of failing.  Decoders must ignore unknown fields (the
//!   vendored JSON layer does this for free) so the *next* additive bump
//!   stays backward compatible too.
//! * **Breaking change** (field removed/renamed, semantics changed):
//!   bump [`PROTOCOL_VERSION`] **and** raise the floor in
//!   [`check_version`] so pre-break peers are refused outright — a wrong
//!   answer on the serving path is worse than no answer.
//!
//! History: v1 — initial protocol; v2 (PR-6) — `metrics_req` gained the
//! `tree` flag, new `metrics_tree` reply carrying a recursive
//! [`MetricsTree`] plus recent journal [`Event`]s; v3 (PR-7) — new
//! journal event kinds (`ingress_shed`, `batch_formed`) may ride in
//! `metrics_tree` frames, and the decoder now *skips* events it cannot
//! decode instead of failing the whole frame, so future kind additions
//! are non-breaking; v4 (PR-8) — the listener's `hello` gains an
//! optional `bundles` field advertising served registry bundle ids
//! (omitted when empty, so the v1 hello bytes are unchanged), plus the
//! registry vocabulary: `bundles_req`/`bundles`, `manifest_fetch`/
//! `manifest`, `blob_fetch`/`blob` (hex payloads — blobs must fit the
//! 16 MiB frame cap), and `publish`/`publish_ok`.  All additive: the
//! v1 floor stands, and an older peer that receives a registry frame
//! answers with the generic `error` it already has; v5 (PR-10) —
//! `submit` gains an optional `deadline_ms` field (the request's
//! remaining deadline budget in milliseconds, decremented as it
//! propagates down the deployment tree; omitted when unset, so the
//! undeadlined submit stays byte-identical to v1).  A pre-v5 listener
//! ignores the field and serves the request unbounded — degraded but
//! correct, so the v1 floor stands.  New journal event kinds
//! (`session_reconnect`, `resubmit`, `deadline_exceeded`) ride the v3
//! tolerant event decode.  Reconnect-on-drop resubmits in-flight
//! `submit` frames verbatim on a fresh session: no new frame type is
//! needed because votes are pure functions of `(seed, trial_idx)`, so a
//! listener serves a resubmission exactly like a fresh request and
//! duplicate completions are deduped client-side by request id.

use std::time::Duration;

use crate::coordinator::MetricsSnapshot;
use crate::neuron::WtaOutcome;
use crate::telemetry::{Event, MetricsTree};
use crate::util::json::{obj, Json};

use super::super::{InferRequest, InferResponse, RequestId};

/// Bump on any frame-shape change; see the module docs for the rules.
pub const PROTOCOL_VERSION: u32 = 5;

/// Oldest peer revision this build still understands (see the breaking-
/// change rule in the module docs).
pub const MIN_PROTOCOL_VERSION: u32 = 1;

/// Distinguishes a raca listener from an arbitrary TCP service.
pub const MAGIC: &str = "raca-serve";

/// One protocol message (either direction).
#[derive(Debug, Clone, PartialEq)]
pub enum WireMsg {
    /// Handshake: listener sends first, client answers.  `bundles`
    /// (v4+) advertises the registry bundle ids the listener serves —
    /// empty from clients, pre-v4 peers, and listeners without a
    /// registry, and omitted from the encoding when empty so the frame
    /// stays byte-identical to the pre-v4 hello.
    Hello { version: u32, bundles: Vec<String> },
    /// Client → server: admit this request.
    Submit(InferRequest),
    /// Server → client: a completed request (completion order, not
    /// submission order — the session multiplexes tickets).
    Response(InferResponse),
    /// Client → server: snapshot the hosted backend's metrics.  With
    /// `tree: true` (v2+) the server answers [`WireMsg::MetricsTree`];
    /// a v1 listener ignores the flag and answers flat
    /// [`WireMsg::Metrics`] — callers must accept either reply.
    MetricsReq { tree: bool },
    /// Server → client: flat answer to [`WireMsg::MetricsReq`].
    Metrics(MetricsSnapshot),
    /// Server → client (v2+): recursive per-node metrics for the hosted
    /// deployment, plus the tail of its event journal.
    MetricsTree { tree: MetricsTree, events: Vec<Event> },
    /// Either direction: a request-level (`id: Some`) or session-level
    /// (`id: None`) failure.
    Error { id: Option<RequestId>, msg: String },
    /// Client → server: clean session end (EOF works too).
    Goodbye,
    /// Client → server (v4+): list the bundle ids the listener serves.
    BundlesReq,
    /// Server → client (v4+): answer to [`WireMsg::BundlesReq`].
    Bundles { ids: Vec<String> },
    /// Client → server (v4+): fetch the signed manifest of one bundle.
    ManifestFetch { bundle: String },
    /// Server → client (v4+): the signed manifest envelope (the
    /// `registry::SignedManifest` JSON shape) answering a fetch.
    Manifest { envelope: Json },
    /// Client → server (v4+): fetch one blob by content hash.
    BlobFetch { hash: String },
    /// Server → client (v4+): blob bytes, hex-encoded (a blob must fit
    /// the 16 MiB frame cap — ~8 MiB raw — which holds for paper-scale
    /// weights at ~2.2 MiB).
    Blob { hash: String, data: String },
    /// Client → server (v4+): publish a signed bundle — the envelope
    /// plus every referenced blob as `(hash, hex bytes)` pairs.
    Publish { envelope: Json, blobs: Vec<(String, String)> },
    /// Server → client (v4+): the publish was verified and stored.
    PublishOk { bundle: String },
}

/// Decode failure: the peer sent bytes we refuse to act on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// The peer speaks a different protocol revision.
    Version { peer: u32, ours: u32 },
    /// A frame decoded as JSON but not as a protocol message.
    Malformed { what: &'static str, detail: String },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Version { peer, ours } => write!(
                f,
                "protocol version mismatch: peer speaks v{peer}, this build speaks v{ours}"
            ),
            WireError::Malformed { what, detail } => {
                write!(f, "malformed {what} frame: {detail}")
            }
        }
    }
}

impl std::error::Error for WireError {}

fn malformed(what: &'static str, detail: impl Into<String>) -> WireError {
    WireError::Malformed { what, detail: detail.into() }
}

/// Refuse peers we cannot serve correctly: anything *newer* than this
/// build (they may send frames we can't parse) or older than
/// [`MIN_PROTOCOL_VERSION`] (pre-break revisions).
pub fn check_version(peer: u32) -> Result<(), WireError> {
    if (MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION).contains(&peer) {
        Ok(())
    } else {
        Err(WireError::Version { peer, ours: PROTOCOL_VERSION })
    }
}

// ---------------------------------------------------------------------------
// Encode
// ---------------------------------------------------------------------------

fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn n(v: f64) -> Json {
    Json::Num(v)
}

/// u64 ids travel as decimal strings — JSON numbers are f64 and would
/// round ids above 2^53 (probe ids sit at 2^63).
fn id_json(v: RequestId) -> Json {
    Json::Str(v.to_string())
}

fn u64_arr(xs: &[u64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

/// Encode a message as the JSON value of one frame.
pub fn encode(msg: &WireMsg) -> Json {
    match msg {
        WireMsg::Hello { version, bundles } => {
            let mut pairs = vec![
                ("t", s("hello")),
                ("magic", s(MAGIC)),
                ("proto", n(*version as f64)),
            ];
            // Omitted when empty: the common hello stays byte-identical
            // to every pre-v4 revision.
            if !bundles.is_empty() {
                pairs.push(("bundles", str_arr(bundles)));
            }
            obj(pairs)
        }
        WireMsg::Submit(r) => request_to_json(r),
        WireMsg::Response(r) => response_to_json(r),
        WireMsg::MetricsReq { tree } => {
            // `tree: false` encodes byte-identically to the v1 frame, so
            // a v2 client asking for flat metrics is indistinguishable
            // from a v1 client.
            let mut pairs = vec![("t", s("metrics_req"))];
            if *tree {
                pairs.push(("tree", Json::Bool(true)));
            }
            obj(pairs)
        }
        WireMsg::Metrics(m) => metrics_to_json(m),
        WireMsg::MetricsTree { tree, events } => obj(vec![
            ("t", s("metrics_tree")),
            ("tree", tree.to_json()),
            ("events", Json::Arr(events.iter().map(Event::to_json).collect())),
        ]),
        WireMsg::Error { id, msg } => {
            let mut pairs = vec![("t", s("error")), ("msg", s(msg))];
            if let Some(id) = id {
                pairs.push(("id", id_json(*id)));
            }
            obj(pairs)
        }
        WireMsg::Goodbye => obj(vec![("t", s("goodbye"))]),
        WireMsg::BundlesReq => obj(vec![("t", s("bundles_req"))]),
        WireMsg::Bundles { ids } => obj(vec![("t", s("bundles")), ("ids", str_arr(ids))]),
        WireMsg::ManifestFetch { bundle } => {
            obj(vec![("t", s("manifest_fetch")), ("bundle", s(bundle))])
        }
        WireMsg::Manifest { envelope } => {
            obj(vec![("t", s("manifest")), ("envelope", envelope.clone())])
        }
        WireMsg::BlobFetch { hash } => obj(vec![("t", s("blob_fetch")), ("hash", s(hash))]),
        WireMsg::Blob { hash, data } => {
            obj(vec![("t", s("blob")), ("hash", s(hash)), ("data", s(data))])
        }
        WireMsg::Publish { envelope, blobs } => obj(vec![
            ("t", s("publish")),
            ("envelope", envelope.clone()),
            (
                "blobs",
                Json::Arr(
                    blobs
                        .iter()
                        .map(|(hash, data)| obj(vec![("hash", s(hash)), ("data", s(data))]))
                        .collect(),
                ),
            ),
        ]),
        WireMsg::PublishOk { bundle } => {
            obj(vec![("t", s("publish_ok")), ("bundle", s(bundle))])
        }
    }
}

fn str_arr(xs: &[String]) -> Json {
    Json::Arr(xs.iter().map(|x| s(x)).collect())
}

fn request_to_json(r: &InferRequest) -> Json {
    let mut pairs = vec![
        ("t", s("submit")),
        ("id", id_json(r.id)),
        ("image", Json::Arr(r.image.iter().map(|&p| Json::Num(p as f64)).collect())),
        ("max_trials", n(r.max_trials as f64)),
        ("confidence", n(r.confidence)),
    ];
    if let Some(l) = r.label {
        pairs.push(("label", n(l as f64)));
    }
    if let Some(d) = r.deadline_ms {
        pairs.push(("deadline_ms", n(d as f64)));
    }
    obj(pairs)
}

fn response_to_json(r: &InferResponse) -> Json {
    let mut pairs = vec![
        ("t", s("response")),
        ("id", id_json(r.id)),
        ("prediction", n(r.prediction as f64)),
        ("counts", u64_arr(&r.outcome.counts)),
        ("abstentions", n(r.outcome.abstentions as f64)),
        ("trials", n(r.outcome.trials as f64)),
        ("trials_used", n(r.trials_used as f64)),
        ("latency_us", n(r.latency.as_micros() as f64)),
    ];
    if let Some(e) = &r.error {
        pairs.push(("error", s(e)));
    }
    obj(pairs)
}

fn metrics_to_json(m: &MetricsSnapshot) -> Json {
    obj(vec![
        ("t", s("metrics")),
        ("requests_admitted", n(m.requests_admitted as f64)),
        ("requests_completed", n(m.requests_completed as f64)),
        ("trials_executed", n(m.trials_executed as f64)),
        ("batches_executed", n(m.batches_executed as f64)),
        ("rows_packed", n(m.rows_packed as f64)),
        ("trials_saved", n(m.trials_saved as f64)),
        ("engine_errors", n(m.engine_errors as f64)),
        ("latency_p50_us", n(m.latency_p50_us as f64)),
        ("latency_p99_us", n(m.latency_p99_us as f64)),
    ])
}

// ---------------------------------------------------------------------------
// Decode
// ---------------------------------------------------------------------------

/// Decode one frame's JSON value into a protocol message.
pub fn decode(j: &Json) -> Result<WireMsg, WireError> {
    let t = j
        .get("t")
        .and_then(Json::as_str)
        .ok_or_else(|| malformed("frame", "missing 't' tag"))?;
    match t {
        "hello" => {
            let magic = j.get("magic").and_then(Json::as_str).unwrap_or("");
            if magic != MAGIC {
                return Err(malformed(
                    "hello",
                    format!("bad magic '{magic}' — peer is not a raca serve listener"),
                ));
            }
            // Absent from clients and pre-v4 listeners: default empty.
            let bundles = match j.get("bundles") {
                Some(v) => str_arr_field(v, "hello", "bundles")?,
                None => Vec::new(),
            };
            Ok(WireMsg::Hello { version: u64_field(j, "hello", "proto")? as u32, bundles })
        }
        "submit" => Ok(WireMsg::Submit(request_from_json(j)?)),
        "response" => Ok(WireMsg::Response(response_from_json(j)?)),
        // v1 frames carry no `tree` field: default false.
        "metrics_req" => Ok(WireMsg::MetricsReq {
            tree: matches!(j.get("tree"), Some(Json::Bool(true))),
        }),
        "metrics" => Ok(WireMsg::Metrics(metrics_from_json(j)?)),
        "metrics_tree" => {
            let tree = j
                .get("tree")
                .ok_or_else(|| malformed("metrics_tree", "missing 'tree' object"))
                .and_then(|v| {
                    MetricsTree::from_json(v)
                        .map_err(|e| malformed("metrics_tree", e.to_string()))
                })?;
            // Events are advisory telemetry: skip what we can't decode
            // (e.g. a kind added after this build shipped) rather than
            // refusing the whole frame.  See the v3 history note.
            let events = j
                .get("events")
                .and_then(Json::as_arr)
                .map(|arr| arr.iter().filter_map(|e| Event::from_json(e).ok()).collect())
                .unwrap_or_default();
            Ok(WireMsg::MetricsTree { tree, events })
        }
        "error" => {
            let id = match j.get("id") {
                Some(v) => Some(parse_u64("error", "id", v)?),
                None => None,
            };
            let msg =
                j.get("msg").and_then(Json::as_str).unwrap_or("unspecified").to_string();
            Ok(WireMsg::Error { id, msg })
        }
        "goodbye" => Ok(WireMsg::Goodbye),
        "bundles_req" => Ok(WireMsg::BundlesReq),
        "bundles" => {
            let ids = j
                .get("ids")
                .ok_or_else(|| malformed("bundles", "missing 'ids' array"))?;
            Ok(WireMsg::Bundles { ids: str_arr_field(ids, "bundles", "ids")? })
        }
        "manifest_fetch" => Ok(WireMsg::ManifestFetch {
            bundle: str_field(j, "manifest_fetch", "bundle")?,
        }),
        "manifest" => {
            let envelope = j
                .get("envelope")
                .ok_or_else(|| malformed("manifest", "missing 'envelope' object"))?;
            Ok(WireMsg::Manifest { envelope: envelope.clone() })
        }
        "blob_fetch" => Ok(WireMsg::BlobFetch { hash: str_field(j, "blob_fetch", "hash")? }),
        "blob" => Ok(WireMsg::Blob {
            hash: str_field(j, "blob", "hash")?,
            data: str_field(j, "blob", "data")?,
        }),
        "publish" => {
            let envelope = j
                .get("envelope")
                .ok_or_else(|| malformed("publish", "missing 'envelope' object"))?
                .clone();
            let blobs = j
                .get("blobs")
                .and_then(Json::as_arr)
                .ok_or_else(|| malformed("publish", "missing 'blobs' array"))?
                .iter()
                .map(|b| {
                    let get = |k: &str| {
                        b.get(k).and_then(Json::as_str).map(str::to_string).ok_or_else(|| {
                            malformed("publish", format!("blob entry missing '{k}'"))
                        })
                    };
                    Ok((get("hash")?, get("data")?))
                })
                .collect::<Result<Vec<_>, WireError>>()?;
            Ok(WireMsg::Publish { envelope, blobs })
        }
        "publish_ok" => Ok(WireMsg::PublishOk { bundle: str_field(j, "publish_ok", "bundle")? }),
        other => Err(malformed("frame", format!("unknown message type '{other}'"))),
    }
}

fn str_field(j: &Json, what: &'static str, field: &str) -> Result<String, WireError> {
    j.get(field)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| malformed(what, format!("missing or non-string field '{field}'")))
}

fn str_arr_field(v: &Json, what: &'static str, field: &str) -> Result<Vec<String>, WireError> {
    v.as_arr()
        .ok_or_else(|| malformed(what, format!("field '{field}' is not an array")))?
        .iter()
        .map(|x| {
            x.as_str()
                .map(str::to_string)
                .ok_or_else(|| malformed(what, format!("non-string entry in '{field}'")))
        })
        .collect()
}

/// Accepts decimal strings (the canonical id encoding) and exact
/// non-negative integers (hand-written frames, counters).
fn parse_u64(what: &'static str, field: &str, v: &Json) -> Result<u64, WireError> {
    match v {
        Json::Num(f) if *f >= 0.0 && f.fract() == 0.0 && *f <= 9007199254740992.0 => {
            Ok(*f as u64)
        }
        Json::Str(sv) => sv
            .parse()
            .map_err(|_| malformed(what, format!("field '{field}': bad u64 '{sv}'"))),
        other => Err(malformed(what, format!("field '{field}': expected u64, got {other}"))),
    }
}

fn u64_field(j: &Json, what: &'static str, field: &str) -> Result<u64, WireError> {
    let v = j
        .get(field)
        .ok_or_else(|| malformed(what, format!("missing field '{field}'")))?;
    parse_u64(what, field, v)
}

fn f64_field(j: &Json, what: &'static str, field: &str) -> Result<f64, WireError> {
    j.get(field)
        .and_then(Json::as_f64)
        .ok_or_else(|| malformed(what, format!("missing or non-numeric field '{field}'")))
}

fn request_from_json(j: &Json) -> Result<InferRequest, WireError> {
    let id = u64_field(j, "submit", "id")?;
    let image: Vec<f32> = j
        .get("image")
        .and_then(Json::as_arr)
        .ok_or_else(|| malformed("submit", "missing 'image' array"))?
        .iter()
        .map(|p| p.as_f64().map(|v| v as f32))
        .collect::<Option<_>>()
        .ok_or_else(|| malformed("submit", "non-numeric pixel in 'image'"))?;
    let max_trials = u64_field(j, "submit", "max_trials")? as u32;
    let confidence = f64_field(j, "submit", "confidence")?;
    let label = match j.get("label") {
        Some(v) => Some(
            v.as_f64()
                .ok_or_else(|| malformed("submit", "non-numeric 'label'"))? as i32,
        ),
        None => None,
    };
    let deadline_ms = match j.get("deadline_ms") {
        Some(v) => Some(parse_u64("submit", "deadline_ms", v)?),
        None => None,
    };
    Ok(InferRequest { id, image, max_trials, confidence, label, deadline_ms })
}

fn response_from_json(j: &Json) -> Result<InferResponse, WireError> {
    let counts: Vec<u64> = j
        .get("counts")
        .and_then(Json::as_arr)
        .ok_or_else(|| malformed("response", "missing 'counts' array"))?
        .iter()
        .map(|c| parse_u64("response", "counts[]", c))
        .collect::<Result<_, _>>()?;
    Ok(InferResponse {
        id: u64_field(j, "response", "id")?,
        prediction: f64_field(j, "response", "prediction")? as i32,
        outcome: WtaOutcome {
            counts,
            abstentions: u64_field(j, "response", "abstentions")?,
            trials: u64_field(j, "response", "trials")?,
        },
        trials_used: u64_field(j, "response", "trials_used")? as u32,
        latency: Duration::from_micros(u64_field(j, "response", "latency_us")?),
        error: j.get("error").and_then(Json::as_str).map(str::to_string),
    })
}

fn metrics_from_json(j: &Json) -> Result<MetricsSnapshot, WireError> {
    Ok(MetricsSnapshot {
        requests_admitted: u64_field(j, "metrics", "requests_admitted")?,
        requests_completed: u64_field(j, "metrics", "requests_completed")?,
        trials_executed: u64_field(j, "metrics", "trials_executed")?,
        batches_executed: u64_field(j, "metrics", "batches_executed")?,
        rows_packed: u64_field(j, "metrics", "rows_packed")?,
        trials_saved: u64_field(j, "metrics", "trials_saved")?,
        engine_errors: u64_field(j, "metrics", "engine_errors")?,
        latency_p50_us: u64_field(j, "metrics", "latency_p50_us")?,
        latency_p99_us: u64_field(j, "metrics", "latency_p99_us")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::probe::PROBE_ID_BASE;

    /// Encode → serialize → parse → decode: the full wire path of a value.
    fn round_trip(msg: &WireMsg) -> WireMsg {
        let text = encode(msg).to_string();
        decode(&Json::parse(&text).unwrap()).unwrap()
    }

    #[test]
    fn request_round_trips_bit_identically() {
        // Awkward pixels: values whose decimal forms exercise the shortest
        // round-trip printer, not just tidy fractions.
        let image: Vec<f32> = (0..784).map(|i| (i as f32 / 783.0).powf(1.37)).collect();
        let req = InferRequest::new(7, image).with_budget(64, 0.95).with_label(3);
        let WireMsg::Submit(got) = round_trip(&WireMsg::Submit(req.clone())) else {
            panic!("wrong variant")
        };
        assert_eq!(got, req); // f32 pixels must survive exactly
        // Unlabeled, undeadlined requests omit both optional fields
        // entirely — the v5 submit stays byte-identical to v1.
        let req = InferRequest::new(9, vec![0.5; 4]);
        let j = encode(&WireMsg::Submit(req.clone()));
        assert!(j.get("label").is_none());
        assert!(j.get("deadline_ms").is_none());
        assert_eq!(round_trip(&WireMsg::Submit(req.clone())), WireMsg::Submit(req));
    }

    #[test]
    fn deadline_is_additive_over_v1_submits() {
        // A deadlined submit round-trips the budget…
        let req = InferRequest::new(11, vec![0.25; 4]).with_deadline_ms(1500);
        let WireMsg::Submit(got) = round_trip(&WireMsg::Submit(req.clone())) else {
            panic!("wrong variant")
        };
        assert_eq!(got.deadline_ms, Some(1500));
        assert_eq!(got, req);
        // …a pre-v5 submit (no field) decodes to the unbounded default…
        let v1 = Json::parse(
            r#"{"t":"submit","id":"3","image":[0.5],"max_trials":4,"confidence":0.0}"#,
        )
        .unwrap();
        let WireMsg::Submit(old) = decode(&v1).unwrap() else { panic!("wrong variant") };
        assert_eq!(old.deadline_ms, None);
        // …and a garbage budget is refused, naming the field.
        let bad = Json::parse(
            r#"{"t":"submit","id":"3","image":[0.5],"max_trials":4,"confidence":0.0,"deadline_ms":"soon"}"#,
        )
        .unwrap();
        let e = decode(&bad).unwrap_err();
        assert!(format!("{e}").contains("deadline_ms"), "{e}");
    }

    #[test]
    fn full_width_ids_survive_the_wire() {
        // Probe ids live at 2^63 — far beyond f64's exact-integer range.
        let id = PROBE_ID_BASE + 12_345;
        let req = InferRequest::new(id, vec![0.0; 4]);
        let WireMsg::Submit(got) = round_trip(&WireMsg::Submit(req)) else {
            panic!("wrong variant")
        };
        assert_eq!(got.id, id);
    }

    #[test]
    fn response_and_metrics_round_trip() {
        let resp = InferResponse {
            id: 42,
            prediction: 7,
            outcome: WtaOutcome { counts: vec![0, 1, 2, 3, 4, 5, 6, 9, 0, 0], abstentions: 2, trials: 32 },
            trials_used: 30,
            latency: Duration::from_micros(1234),
            error: None,
        };
        assert_eq!(round_trip(&WireMsg::Response(resp.clone())), WireMsg::Response(resp));

        // In-band failures survive the wire too (the signal a shared
        // completion channel needs to name the request that died).
        let failed = InferResponse::failed(43, "peer went away");
        assert_eq!(
            round_trip(&WireMsg::Response(failed.clone())),
            WireMsg::Response(failed)
        );

        let m = MetricsSnapshot {
            requests_admitted: 10,
            requests_completed: 9,
            trials_executed: 288,
            batches_executed: 9,
            rows_packed: 288,
            trials_saved: 32,
            engine_errors: 0,
            latency_p50_us: 120,
            latency_p99_us: 900,
        };
        assert_eq!(round_trip(&WireMsg::Metrics(m.clone())), WireMsg::Metrics(m));
    }

    #[test]
    fn control_messages_round_trip() {
        assert_eq!(
            round_trip(&WireMsg::Hello { version: PROTOCOL_VERSION, bundles: Vec::new() }),
            WireMsg::Hello { version: PROTOCOL_VERSION, bundles: Vec::new() }
        );
        assert_eq!(
            round_trip(&WireMsg::MetricsReq { tree: false }),
            WireMsg::MetricsReq { tree: false }
        );
        assert_eq!(
            round_trip(&WireMsg::MetricsReq { tree: true }),
            WireMsg::MetricsReq { tree: true }
        );
        assert_eq!(round_trip(&WireMsg::Goodbye), WireMsg::Goodbye);
        assert_eq!(
            round_trip(&WireMsg::Error { id: Some(5), msg: "no healthy children".into() }),
            WireMsg::Error { id: Some(5), msg: "no healthy children".into() }
        );
        assert_eq!(
            round_trip(&WireMsg::Error { id: None, msg: "x".into() }),
            WireMsg::Error { id: None, msg: "x".into() }
        );
    }

    #[test]
    fn malformed_frames_are_rejected_with_field_names() {
        // Not an object / missing tag.
        assert!(decode(&Json::parse("[1,2]").unwrap()).is_err());
        let e = decode(&Json::parse(r#"{"t":"warp"}"#).unwrap()).unwrap_err();
        assert!(format!("{e}").contains("warp"), "{e}");
        // Submit with a missing image.
        let e = decode(
            &Json::parse(r#"{"t":"submit","id":"1","max_trials":4,"confidence":0}"#).unwrap(),
        )
        .unwrap_err();
        assert!(format!("{e}").contains("image"), "{e}");
        // Response with a non-numeric count.
        let e = decode(
            &Json::parse(
                r#"{"t":"response","id":"1","prediction":0,"counts":[1,"x"],
                    "abstentions":0,"trials":2,"trials_used":2,"latency_us":5}"#,
            )
            .unwrap(),
        )
        .unwrap_err();
        assert!(format!("{e}").contains("counts"), "{e}");
        // Hello from something that is not a raca listener.
        let e = decode(&Json::parse(r#"{"t":"hello","magic":"http","proto":1}"#).unwrap())
            .unwrap_err();
        assert!(format!("{e}").contains("magic"), "{e}");
    }

    #[test]
    fn version_gate() {
        // Every revision from the floor to the current one is welcome…
        for v in MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION {
            assert!(check_version(v).is_ok(), "v{v} should be accepted");
        }
        // …but peers newer than this build, or pre-floor, are refused.
        let e = check_version(PROTOCOL_VERSION + 1).unwrap_err();
        assert_eq!(
            e,
            WireError::Version { peer: PROTOCOL_VERSION + 1, ours: PROTOCOL_VERSION }
        );
        assert!(format!("{e}").contains("version mismatch"), "{e}");
        assert!(check_version(0).is_err());
    }

    #[test]
    fn hello_bundles_are_additive_over_v1() {
        // A bundle-less hello must encode byte-identically to the pre-v4
        // frame (no `bundles` key at all)…
        let plain = WireMsg::Hello { version: PROTOCOL_VERSION, bundles: Vec::new() };
        assert!(encode(&plain).get("bundles").is_none());
        // …and a v1 hello (which has never heard of bundles) must decode
        // to the empty advertisement.
        let v1 = Json::parse(r#"{"t":"hello","magic":"raca-serve","proto":1}"#).unwrap();
        assert_eq!(decode(&v1).unwrap(), WireMsg::Hello { version: 1, bundles: Vec::new() });
        // An advertising listener's hello round-trips the ids.
        let ids = vec!["a".repeat(64), "b".repeat(64)];
        let msg = WireMsg::Hello { version: PROTOCOL_VERSION, bundles: ids.clone() };
        assert_eq!(round_trip(&msg), msg);
    }

    #[test]
    fn registry_frames_round_trip() {
        let envelope = Json::parse(
            r#"{"key_id":"deadbeef","manifest":{"model":"fcnn"},"sig":"00ff"}"#,
        )
        .unwrap();
        for msg in [
            WireMsg::BundlesReq,
            WireMsg::Bundles { ids: vec!["c".repeat(64)] },
            WireMsg::Bundles { ids: Vec::new() },
            WireMsg::ManifestFetch { bundle: "d".repeat(64) },
            WireMsg::Manifest { envelope: envelope.clone() },
            WireMsg::BlobFetch { hash: "e".repeat(64) },
            WireMsg::Blob { hash: "e".repeat(64), data: "00112233".into() },
            WireMsg::Publish {
                envelope,
                blobs: vec![("e".repeat(64), "00112233".into()), ("f".repeat(64), "aa".into())],
            },
            WireMsg::PublishOk { bundle: "d".repeat(64) },
        ] {
            assert_eq!(round_trip(&msg), msg);
        }
        // Malformed registry frames name the offending field.
        let e = decode(&Json::parse(r#"{"t":"manifest_fetch"}"#).unwrap()).unwrap_err();
        assert!(format!("{e}").contains("bundle"), "{e}");
        let e = decode(&Json::parse(r#"{"t":"publish","envelope":{},"blobs":[{"hash":"aa"}]}"#).unwrap())
            .unwrap_err();
        assert!(format!("{e}").contains("data"), "{e}");
    }

    #[test]
    fn v1_metrics_req_decodes_as_flat() {
        // A v1 peer sends the bare frame — no `tree` field.  It must
        // decode to the flat-metrics request, and our own flat request
        // must encode byte-identically to the v1 shape.
        let old = Json::parse(r#"{"t":"metrics_req"}"#).unwrap();
        assert_eq!(decode(&old).unwrap(), WireMsg::MetricsReq { tree: false });
        assert_eq!(
            encode(&WireMsg::MetricsReq { tree: false }).to_string(),
            r#"{"t":"metrics_req"}"#
        );
    }

    #[test]
    fn metrics_tree_round_trips_with_notes_and_events() {
        use crate::telemetry::{EventKind, Journal, NodeNotes};

        let m = |c: u64| MetricsSnapshot {
            requests_admitted: c + 1,
            requests_completed: c,
            trials_executed: 32 * c,
            batches_executed: c,
            rows_packed: 32 * c,
            trials_saved: 3,
            engine_errors: 0,
            latency_p50_us: 120,
            latency_p99_us: 900,
        };
        let mut child = MetricsTree::leaf("die#0", m(5));
        child.notes = NodeNotes {
            service_us: Some(118.5),
            queue_wait_us: Some(42.0),
            probe_accuracy: Some(0.875),
            evicted: Some(false),
            errors: Some(2),
            weight: Some(0.5),
            bundle: Some("ab".repeat(32)),
            stale: true,
        };
        let tree = MetricsTree::leaf("replicate ×2", m(11)).with_children(vec![
            child,
            MetricsTree::leaf("die#1", m(6)),
        ]);

        let journal = Journal::new(8);
        journal.record(EventKind::RequestAdmitted, "die#0", "id 1");
        journal.record(EventKind::HealthEvict, "die#1", "accuracy 0.12");
        let events = journal.tail(8);

        let msg = WireMsg::MetricsTree { tree, events };
        assert_eq!(round_trip(&msg), msg);

        // Missing subtree is an error with the frame name in it.
        let e = decode(&Json::parse(r#"{"t":"metrics_tree"}"#).unwrap()).unwrap_err();
        assert!(format!("{e}").contains("metrics_tree"), "{e}");
    }

    #[test]
    fn metrics_tree_skips_undecodable_events_instead_of_failing() {
        use crate::telemetry::{EventKind, Journal};

        // A frame from a hypothetical v4 peer: one event kind we know,
        // one we don't, one that isn't even an object.  The tree and the
        // decodable event must survive.
        let journal = Journal::new(8);
        journal.record(EventKind::IngressShed, "http:1.2.3.4:80", "queue full");
        let known = journal.tail(1).pop().unwrap().to_json();
        let snap = MetricsSnapshot {
            requests_admitted: 1,
            requests_completed: 1,
            trials_executed: 32,
            batches_executed: 1,
            rows_packed: 32,
            trials_saved: 0,
            engine_errors: 0,
            latency_p50_us: 100,
            latency_p99_us: 200,
        };
        let frame = obj(vec![
            ("t", Json::Str("metrics_tree".into())),
            ("tree", MetricsTree::leaf("die", snap).to_json()),
            (
                "events",
                Json::Arr(vec![
                    known,
                    Json::parse(r#"{"seq":9,"t_us":1,"kind":"from_the_future","node":"x","detail":""}"#)
                        .unwrap(),
                    Json::Num(3.0),
                ]),
            ),
        ]);
        match decode(&frame).unwrap() {
            WireMsg::MetricsTree { events, .. } => {
                assert_eq!(events.len(), 1);
                assert_eq!(events[0].kind, EventKind::IngressShed);
            }
            other => panic!("decoded {other:?}"),
        }
    }
}
